"""Row-level slot-cache ops: reset_rows / insert_rows / migrate_cache and
their interaction with the strided owner mask and ring-buffer appends."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cache.slot_cache import (
    PlanArrays,
    SlotCache,
    append_token,
    gather_head_layout,
    init_cache,
    insert_rows,
    migrate_cache,
    reset_rows,
    rows_to_mask,
)
from repro.core import PlannerConfig, build_plan, synthetic_profile

L, B, CAP, DH = 2, 4, 6, 4


def _plan(mode="sha", n_heads=2, n_shards=4, slots=1, ch=0, seed=1):
    prof = synthetic_profile(L, n_heads, budget=8, skew=1.0, seed=seed)
    return build_plan(prof, n_shards,
                      PlannerConfig(mode=mode, slots_per_shard=slots,
                                    extra_copies=ch, batch_cap=B))


def _filled_cache(pa, rng_seed=0):
    """A cache with ownership-respecting random contents and lengths."""
    rng = np.random.default_rng(rng_seed)
    S = int(pa.slot_head.shape[1])
    cache = init_cache(L, S, B, CAP, DH, dtype=jnp.float32)
    own = np.asarray(pa.owner_mask_all(B))  # (L, S, B)
    lens = rng.integers(1, CAP, size=(L, S, B)).astype(np.int32) * own
    ent = np.arange(CAP)[None, None, None, :]
    valid = ent < lens[..., None]
    k = rng.normal(size=(L, S, B, CAP, DH)).astype(np.float32) * valid[..., None]
    v = rng.normal(size=(L, S, B, CAP, DH)).astype(np.float32) * valid[..., None]
    pos = np.where(valid, ent, -1).astype(np.int32)
    return SlotCache(k=jnp.asarray(k), v=jnp.asarray(v),
                     lengths=jnp.asarray(lens), pos=jnp.asarray(pos),
                     positions=jnp.asarray(lens.max(axis=(0, 1)), jnp.int32))


# ---------------------------------------------------------------------------
# owner_mask_rows
# ---------------------------------------------------------------------------


def test_owner_mask_rows_matches_global_rows():
    pa = PlanArrays.from_plan(_plan())  # 2 heads on 4 shards -> rc == 2
    full = np.asarray(pa.owner_mask(0, B))  # (S, B)
    sub = np.asarray(pa.owner_mask_rows(0, jnp.asarray([1, 3])))
    np.testing.assert_array_equal(sub, full[:, [1, 3]])
    # a replicated plan must disagree between row 0 and row 1 somewhere
    assert (full[:, 0] != full[:, 1]).any()


def test_owner_mask_all_matches_per_layer():
    pa = PlanArrays.from_plan(_plan(mode="fairkv_dp", n_heads=3, ch=4, slots=2))
    allm = np.asarray(pa.owner_mask_all(B))
    for l in range(L):
        np.testing.assert_array_equal(allm[l], np.asarray(pa.owner_mask(l, B)))


# ---------------------------------------------------------------------------
# reset_rows
# ---------------------------------------------------------------------------


def test_reset_rows_clears_only_target_rows():
    pa = PlanArrays.from_plan(_plan())
    cache = _filled_cache(pa)
    before = np.asarray(cache.lengths)
    out = reset_rows(cache, jnp.asarray([1]))
    # row 1 fully cleared
    assert np.asarray(out.lengths)[:, :, 1].sum() == 0
    assert np.abs(np.asarray(out.k)[:, :, 1]).sum() == 0
    assert (np.asarray(out.pos)[:, :, 1] == -1).all()
    assert int(np.asarray(out.positions)[1]) == 0
    # other rows untouched
    keep = [0, 2, 3]
    np.testing.assert_array_equal(np.asarray(out.lengths)[:, :, keep],
                                  before[:, :, keep])
    np.testing.assert_array_equal(np.asarray(out.k)[:, :, keep],
                                  np.asarray(cache.k)[:, :, keep])


def test_reset_rows_accepts_bool_mask():
    pa = PlanArrays.from_plan(_plan())
    cache = _filled_cache(pa)
    m = jnp.asarray([True, False, True, False])
    out = reset_rows(cache, m)
    lens = np.asarray(out.lengths)
    assert lens[:, :, [0, 2]].sum() == 0
    assert lens[:, :, [1, 3]].sum() > 0


def test_rows_to_mask_roundtrip():
    m = np.asarray(rows_to_mask(jnp.asarray([0, 3]), B))
    np.testing.assert_array_equal(m, [True, False, False, True])
    passthrough = rows_to_mask(jnp.asarray(m), B)
    np.testing.assert_array_equal(np.asarray(passthrough), m)


# ---------------------------------------------------------------------------
# insert_rows
# ---------------------------------------------------------------------------


def test_insert_rows_splices_with_target_row_ownership():
    """A sub-cache built at global row 3 lands on the slots that own row 3."""
    plan = _plan()  # 2 heads, 4 shards, rc == 2
    pa = PlanArrays.from_plan(plan)
    S = int(pa.slot_head.shape[1])
    live = _filled_cache(pa)
    live = reset_rows(live, jnp.asarray([3]))

    # build a 1-row sub-cache with ownership evaluated at global row 3
    sub = init_cache(L, S, 1, CAP, DH, dtype=jnp.float32)
    own3 = np.asarray(pa.owner_mask_all(B))[:, :, 3]  # (L, S)
    sub_len = (2 * own3).astype(np.int32)[:, :, None]
    sub = SlotCache(
        k=jnp.asarray(np.ones((L, S, 1, CAP, DH), np.float32)
                      * own3[:, :, None, None, None]),
        v=sub.v, lengths=jnp.asarray(sub_len), pos=sub.pos,
        positions=jnp.asarray([7], jnp.int32))

    out = insert_rows(live, sub, jnp.asarray([3]))
    lens = np.asarray(out.lengths)
    np.testing.assert_array_equal(lens[:, :, 3], 2 * own3)
    assert int(np.asarray(out.positions)[3]) == 7
    # rows 0-2 untouched
    np.testing.assert_array_equal(lens[:, :, :3],
                                  np.asarray(live.lengths)[:, :, :3])
    # the spliced row only has nonzero lengths on slots owning row 3
    assert (lens[:, :, 3][~own3.astype(bool)] == 0).all()


def test_insert_rows_rejects_layout_mismatch():
    pa = PlanArrays.from_plan(_plan())
    S = int(pa.slot_head.shape[1])
    live = init_cache(L, S, B, CAP, DH, dtype=jnp.float32)
    bad = init_cache(L, S, 1, CAP + 1, DH, dtype=jnp.float32)
    with pytest.raises(ValueError):
        insert_rows(live, bad, jnp.asarray([0]))


def test_insert_then_append_continues_at_correct_index():
    """Ring-buffer appends pick up at the spliced row's lengths."""
    plan = _plan()
    pa = PlanArrays.from_plan(plan)
    S = int(pa.slot_head.shape[1])
    live = init_cache(L, S, B, CAP, DH, dtype=jnp.float32)
    own3 = np.asarray(pa.owner_mask_all(B))[:, :, 3]
    sub_len = (3 * own3).astype(np.int32)[:, :, None]
    sub = init_cache(L, S, 1, CAP, DH, dtype=jnp.float32)
    sub = SlotCache(k=sub.k, v=sub.v, lengths=jnp.asarray(sub_len),
                    pos=sub.pos, positions=jnp.asarray([10], jnp.int32))
    live = insert_rows(live, sub, jnp.asarray([3]))

    own = pa.owner_mask(0, B)
    k_new = jnp.full((S, B, DH), 5.0, jnp.float32)
    out = append_token(live, 0, k_new, k_new, own, jnp.int32(0), ring=2)
    lens = np.asarray(out.lengths[0])
    # spliced row grew 3 -> 4 on owning slots; empty owned rows grew 0 -> 1
    np.testing.assert_array_equal(lens[:, 3], (3 * own3[0] + 1)
                                  * np.asarray(own)[:, 3])
    np.testing.assert_array_equal(
        lens[:, 0], np.asarray(own)[:, 0].astype(np.int32))
    # the new entry landed at index == old length for the spliced row
    k_np = np.asarray(out.k[0])
    for s in range(S):
        if own3[0, s] and np.asarray(own)[s, 3]:
            assert k_np[s, 3, 3, 0] == 5.0  # written at position 3
            assert k_np[s, 3, 4, 0] == 0.0


# ---------------------------------------------------------------------------
# gather / migrate (online replanning)
# ---------------------------------------------------------------------------


def test_gather_head_layout_inverts_ownership():
    pa = PlanArrays.from_plan(_plan(mode="fairkv_dp", n_heads=3, ch=4,
                                    slots=2))
    cache = _filled_cache(pa)
    k_h, v_h, len_h, pos_h = gather_head_layout(cache, pa)
    H = 3
    assert k_h.shape == (L, H, B, CAP, DH)
    # per (head, row): the owning slot's lengths match
    sh = np.asarray(pa.slot_head)
    own = np.asarray(pa.owner_mask_all(B))
    lens = np.asarray(cache.lengths)
    for l in range(L):
        for h in range(H):
            for b in range(B):
                owners = [s for s in range(sh.shape[1])
                          if sh[l, s] == h and own[l, s, b]]
                assert len(owners) == 1
                assert int(np.asarray(len_h)[l, h, b]) == lens[l, owners[0], b]


def test_migrate_cache_roundtrip_preserves_head_layout():
    """old plan -> new plan migration preserves the per-head contents."""
    plan_a = _plan(mode="sha")
    plan_b = _plan(mode="fairkv_dp", ch=4, seed=2)
    pa, pb = PlanArrays.from_plan(plan_a), PlanArrays.from_plan(plan_b)
    cache = _filled_cache(pa)
    orig = gather_head_layout(cache, pa)
    migrated = migrate_cache(cache, pa, pb)
    back = gather_head_layout(migrated, pb)
    for a, b in zip(orig, back):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    # positions carried through untouched
    np.testing.assert_array_equal(np.asarray(migrated.positions),
                                  np.asarray(cache.positions))
    # ownership respected in the new layout: unowned (slot, row) empty
    own_b = np.asarray(pb.owner_mask_all(B))
    lens_b = np.asarray(migrated.lengths)
    assert (lens_b[~own_b] == 0).all()


def test_migrate_cache_rejects_grid_mismatch():
    plan_a = _plan(n_shards=4)
    plan_b = _plan(n_shards=2)
    pa, pb = PlanArrays.from_plan(plan_a), PlanArrays.from_plan(plan_b)
    cache = _filled_cache(pa)
    with pytest.raises(ValueError):
        migrate_cache(cache, pa, pb)
