"""``hypothesis`` shim: real library when installed, fixed-seed fallback
otherwise.

The tier-1 suite must collect and run in environments without hypothesis
(the paper-repro container doesn't ship it).  The fallback degrades each
``@given`` property test into a deterministic parametrized sweep: strategies
become seeded draw functions, and ``given`` runs the test body
``max_examples`` times with draws from a per-test ``numpy`` Generator seeded
by the test name — reproducible across runs, interpreter-hash independent.

Only the strategy surface this repo uses is implemented: ``integers``,
``floats``, ``sampled_from``, ``booleans``, ``lists``.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    import zlib

    import numpy as np

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def sampled_from(elements):
            elems = list(elements)
            return _Strategy(lambda rng: elems[int(rng.integers(len(elems)))])

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(2)))

        @staticmethod
        def lists(elem, min_size=0, max_size=10):
            return _Strategy(lambda rng: [
                elem.draw(rng)
                for _ in range(int(rng.integers(min_size, max_size + 1)))])

    st = _Strategies()

    def settings(max_examples=20, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(*arg_strategies, **kw_strategies):
        def deco(fn):
            # zero-arg wrapper (no functools.wraps: pytest would follow
            # __wrapped__ and misread the strategy params as fixtures)
            def runner():
                n = getattr(runner, "_max_examples",
                            getattr(fn, "_max_examples", 20))
                rng = np.random.default_rng(
                    zlib.crc32(fn.__qualname__.encode()))
                for _ in range(n):
                    drawn_args = tuple(s.draw(rng) for s in arg_strategies)
                    drawn_kw = {k: s.draw(rng)
                                for k, s in kw_strategies.items()}
                    fn(*drawn_args, **drawn_kw)
            runner.__name__ = fn.__name__
            runner.__qualname__ = fn.__qualname__
            runner.__doc__ = fn.__doc__
            runner.__module__ = fn.__module__
            return runner
        return deco
