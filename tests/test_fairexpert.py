"""FairExpert (beyond-paper MoE extension): expert-load balancing."""
import numpy as np

from repro.core.fairexpert import (
    expert_dispatch_stats,
    plan_experts,
    simulate_expert_balance,
)


def _skewed_router(T=4096, E=32, alpha=1.2, seed=0):
    rng = np.random.default_rng(seed)
    pref = rng.dirichlet(np.full(E, 1.0 / alpha))
    logits = np.log(pref[None, :] + 1e-9) + rng.gumbel(size=(T, E)) * 0.7
    z = np.exp(logits - logits.max(1, keepdims=True))
    return z / z.sum(1, keepdims=True)


def test_dispatch_stats_conserve_tokens():
    probs = _skewed_router()
    load = expert_dispatch_stats(probs, top_k=8)
    assert load.sum() == probs.shape[0] * 8
    assert load.std() > 0  # skewed


def test_fairexpert_beats_sha():
    probs = _skewed_router()
    res = simulate_expert_balance(probs, top_k=8, n_shards=8, extra_copies=4)
    assert res["fairkv_nodp"] >= res["sha"] - 1e-9
    assert res["fairkv_dp"] >= res["fairkv_nodp"] - 1e-9
    assert res["fairkv_dp"] > res["sha"] + 0.02  # strict improvement


def test_plan_experts_valid():
    load = expert_dispatch_stats(_skewed_router(E=128), top_k=8)
    plan = plan_experts(load, 16, extra_copies=8)
    plan.validate()
    assert plan.n_heads == 128
