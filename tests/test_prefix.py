"""Shared-prefix block reuse + chunked prefill (DESIGN.md §14).

Covers the full §14 surface: the content-addressed index (hash-chain keys,
longest-prefix lookup, LRU eviction vs pins, refcount bookkeeping), chunked
prefill parity against monolithic prefill, block sharing through the
scheduler (token parity with refcount > 1 actually observed mid-trace),
copy-on-write under ring-wrap decode appends, safe materialization of
shared blocks (paged_to_slot / migrate_cache pool conservation), admission
discounting, TTFT accounting across prefill chunks, and local/mesh chunked
parity on a multi-device subprocess.

All engine-level tests use policy "none" in float32: compression quotas are
per-chunk ceilings, so exact chunked-vs-monolithic parity is guaranteed
only without compression (DESIGN.md §14 caveat).
"""
import json
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (
    CompressionConfig,
    Engine,
    EngineConfig,
    PagingConfig,
    PlannerConfig,
    PrefixConfig,
    SchedulerConfig,
    synthesize_requests,
)
from repro.paging.block_pool import BlockPool
from repro.paging.paged_cache import paged_to_slot
from repro.prefix import PrefixIndex
from repro.serving.request import Request
from tests._hypothesis_compat import given, settings, st

ARCH = "minitron-8b"
BS = 16  # block size used by every engine-level test here


def _cfg(enabled=False, chunk=0, budget=128, margin=8, n_blocks=256,
         rows=3, max_seq=256, entries=256, kv_dtype="fp32", **sched_kw):
    scfg = dict(max_rows=rows, enable_replan=False, collect_logits=True)
    scfg.update(sched_kw)
    return EngineConfig.smoke(
        ARCH, max_seq_len=max_seq,
        compression=CompressionConfig(policy="none", budget=budget,
                                      capacity=budget, decode_margin=margin,
                                      obs_window=8),
        planner=PlannerConfig(batch_cap=rows),
        scheduler=SchedulerConfig(**scfg),
        cache_backend="paged",
        paging=PagingConfig(block_size=BS, n_blocks=n_blocks,
                            kv_dtype=kv_dtype),
        prefix=PrefixConfig(enabled=enabled, chunk_tokens=chunk,
                            max_entries=entries))


_PARAMS_CACHE: dict = {}


def _shared_params():
    """One parameter set shared by every engine in this module (engines
    differ only in prefix/chunk/capacity config, never in model shape).
    A plain memo rather than a fixture so the hypothesis-shim property
    test (whose runner takes no pytest fixtures) can reach it too."""
    if "p" not in _PARAMS_CACHE:
        _PARAMS_CACHE["p"] = Engine.build(_cfg()).params
    return _PARAMS_CACHE["p"]


@pytest.fixture(scope="module")
def params():
    return _shared_params()


def _shared_reqs(vocab, shared_len=48, n_shared=3, suffix=20, gen=6,
                 spacing=8, seed=0):
    """n_shared requests sharing a `shared_len` prefix (full chunks at
    chunk=16), spaced so the donor registers before the next arrival,
    plus one fully random request."""
    rng = np.random.default_rng(seed)
    shared = rng.integers(1, vocab, size=shared_len).astype(np.int32)
    reqs = []
    for i in range(n_shared):
        sfx = rng.integers(1, vocab, size=suffix).astype(np.int32)
        reqs.append(Request(req_id=i, prompt=np.concatenate([shared, sfx]),
                            arrival_step=i * spacing, max_new_tokens=gen))
    reqs.append(Request(req_id=n_shared,
                        prompt=rng.integers(1, vocab, size=40).astype(np.int32),
                        arrival_step=1, max_new_tokens=gen))
    return reqs


def _clone(reqs):
    return [Request(req_id=r.req_id, prompt=r.prompt.copy(),
                    arrival_step=r.arrival_step,
                    max_new_tokens=r.max_new_tokens) for r in reqs]


def _tokens(eng):
    return {r.req_id: list(r.generated) for r in eng.scheduler.finished}


# ---------------------------------------------------------------------------
# PrefixIndex unit tests (no engine)
# ---------------------------------------------------------------------------


def test_chain_keys_commit_to_every_prior_token():
    idx = PrefixIndex(chunk_tokens=4)
    a = np.arange(16, dtype=np.int32)
    b = a.copy()
    b[9] = 99  # diverge inside chunk 2
    ka, kb = dict(idx.chain_keys(a)), dict(idx.chain_keys(b))
    assert sorted(ka) == sorted(kb) == [4, 8, 12, 16]
    assert ka[4] == kb[4] and ka[8] == kb[8]
    assert ka[12] != kb[12] and ka[16] != kb[16]  # chain: divergence sticks
    # deterministic across instances
    assert dict(PrefixIndex(chunk_tokens=4).chain_keys(a)) == ka
    # partial tail chunks get no boundary
    assert [t for t, _ in idx.chain_keys(a[:11])] == [4, 8]


def _register_boundary(idx, pool, prompt, tokens, blocks_per_layer=2):
    """Register `tokens` boundary of `prompt` with freshly-alloc'd blocks."""
    key = dict(idx.chain_keys(prompt))[tokens]
    L, H, M = pool.n_layers, 2, 4
    table = np.zeros((L, H, M), np.int32)
    lengths = np.zeros((L, H), np.int32)
    for l in range(L):
        ids = pool.alloc(l, blocks_per_layer * H)
        table[l, :, :blocks_per_layer] = np.asarray(ids).reshape(
            H, blocks_per_layer)
        lengths[l, :] = blocks_per_layer * idx.chunk_tokens
    assert idx.register(key, tokens, table, lengths)
    return idx._entries[key]


def test_lookup_longest_match_is_strict():
    pool = BlockPool(2, 64)
    idx = PrefixIndex(chunk_tokens=4)
    idx.pool = pool
    prompt = np.arange(20, dtype=np.int32)
    e4 = _register_boundary(idx, pool, prompt, 4)
    e8 = _register_boundary(idx, pool, prompt, 8)
    assert idx.lookup(prompt) is e8          # longest boundary wins
    assert idx.lookup(prompt[:8]) is e4      # strict: 8 == len -> not usable
    assert idx.lookup(prompt[:4]) is None    # nothing strictly shorter
    assert idx.lookup(prompt[::-1].copy()) is None  # different content
    assert idx.stats()["hits"] == 2 and idx.stats()["misses"] == 2
    # a hole in the chain (middle boundary evicted) must not stop the scan
    assert idx.lookup(prompt) is e8  # refreshes e8 -> e4 is now LRU
    assert idx.evict_lru()
    assert e8.key in idx._entries and len(idx) == 1
    assert idx.lookup(prompt) is e8


def test_register_increfs_and_evict_decrefs():
    pool = BlockPool(2, 64)
    idx = PrefixIndex(chunk_tokens=4)
    idx.pool = pool
    prompt = np.arange(12, dtype=np.int32)
    entry = _register_boundary(idx, pool, prompt, 8)
    held = entry.block_count()
    assert held == 2 * 2 * 2  # L * H * blocks_per_layer
    for l in range(2):
        ids = entry.table[l][entry.table[l] > 0]
        assert (pool.refcount[l, ids] == 2).all()  # alloc ref + index ref
    # duplicate registration is a refresh, not a second incref
    assert not idx.register(entry.key, 8, entry.table, entry.lengths)
    for l in range(2):
        ids = entry.table[l][entry.table[l] > 0]
        assert (pool.refcount[l, ids] == 2).all()
    # drop the alloc-time refs (donor retired), then evict: blocks free
    for l in range(2):
        pool.decref(l, entry.table[l][entry.table[l] > 0].tolist())
    assert idx.evict_lru()
    assert pool.blocks_in_use() == 0
    pool.check_invariants()


def test_eviction_respects_pins_and_flush_raises():
    pool = BlockPool(1, 64)
    idx = PrefixIndex(chunk_tokens=4, max_entries=2)
    idx.pool = pool
    prompt = np.arange(24, dtype=np.int32)
    e1 = _register_boundary(idx, pool, prompt, 4)
    idx.pin(e1)
    assert not idx.evict_lru()  # only entry is pinned
    _register_boundary(idx, pool, prompt, 8)
    _register_boundary(idx, pool, prompt, 12)  # over max_entries=2
    assert len(idx) == 2 and e1.key in idx._entries  # LRU victim was e2
    assert idx.stats()["evictions"] == 1
    with pytest.raises(RuntimeError):
        idx.flush()  # pinned entry still live
    idx.unpin(e1)
    with pytest.raises(ValueError):
        idx.unpin(e1)  # double-unpin
    idx.flush()
    assert len(idx) == 0
    pool.check_invariants()


def test_prefix_config_validation():
    with pytest.raises(ValueError):
        PrefixConfig(enabled=True, chunk_tokens=0)  # sharing needs chunking
    with pytest.raises(ValueError):
        _cfg(enabled=True, chunk=16).replace(cache_backend="slot")


# ---------------------------------------------------------------------------
# chunked prefill parity + TTFT accounting
# ---------------------------------------------------------------------------


def test_chunked_matches_monolithic_local(params):
    """Chunked prefill (prefix sharing off) is a pure re-chunking of the
    same math: identical tokens AND logits per request, including a prompt
    shorter than one chunk (monolithic fast path)."""
    vocab = _cfg().model.vocab_size
    rng = np.random.default_rng(3)
    reqs = [Request(req_id=i, prompt=rng.integers(1, vocab, size=t)
                    .astype(np.int32), arrival_step=a, max_new_tokens=5)
            for i, (t, a) in enumerate([(50, 0), (12, 1), (33, 2), (64, 4)])]
    mono = Engine.build(_cfg(), params=params)
    mono.run_trace(_clone(reqs), max_steps=400)
    chunked = Engine.build(_cfg(chunk=16), params=params)
    out = chunked.run_trace(reqs, max_steps=400)
    assert out["finished"] == out["total"]
    assert _tokens(mono) == _tokens(chunked)
    # logits agree to float32 reduction-order noise (chunked attention
    # accumulates per chunk); the sampled tokens are bitwise identical
    by_id = {r.req_id: r for r in mono.scheduler.finished}
    for r in chunked.scheduler.finished:
        for la, lb in zip(by_id[r.req_id].logits, r.logits):
            np.testing.assert_allclose(la, lb, rtol=1e-4, atol=1e-4)
    # every block returned once all requests retired
    assert chunked.scheduler.backend.pool.blocks_in_use() == 0
    chunked.scheduler.backend.pool.check_invariants()


def test_ttft_spans_all_prefill_chunks(params):
    """TTFT is measured from submission across *all* chunks: a 64-token
    prompt at chunk 16 takes 4 ticks to first token, vs 0 monolithic."""
    vocab = _cfg().model.vocab_size
    prompt = np.random.default_rng(5).integers(1, vocab, size=64)
    results = {}
    for name, cfg in [("mono", _cfg(rows=1)), ("chunked", _cfg(chunk=16,
                                                               rows=1))]:
        eng = Engine.build(cfg, params=params)
        r = Request(req_id=0, prompt=prompt.astype(np.int32),
                    max_new_tokens=4)
        eng.run_trace([r], max_steps=100)
        assert r.first_token_step is not None
        assert r.first_token_time is not None and r.ttft_seconds() > 0
        results[name] = r
    assert results["mono"].first_token_step == results["mono"].admit_step
    chunked = results["chunked"]
    # 64 tokens / 16-token chunks = 4 chunks, one per tick, first token
    # stamped when the last chunk finishes
    assert chunked.first_token_step - chunked.admit_step == 3
    assert chunked.ttft_steps() == 3
    assert results["mono"].generated == chunked.generated


# ---------------------------------------------------------------------------
# block sharing through the scheduler
# ---------------------------------------------------------------------------


def test_prefix_sharing_parity_with_observed_refcounts(params):
    """The acceptance-gate test: a shared-prefix trace through the
    prefix-enabled engine produces hits, drives pool_max_refcount > 1
    while requests are live, and generates exactly the tokens of a
    no-sharing chunked engine and a monolithic engine."""
    vocab = _cfg().model.vocab_size
    reqs = _shared_reqs(vocab)
    eng = Engine.build(_cfg(enabled=True, chunk=16), params=params)
    max_ref = 0
    for _ in eng.stream(_clone(reqs), max_steps=400):
        max_ref = max(max_ref, int(eng.scheduler.backend.pool.refcount.max()))
    sched = eng.scheduler
    assert all(r.is_finished for r in sched.finished)
    assert len(sched.finished) == len(reqs)
    st_ = eng.prefix_stats()
    assert st_["hits"] >= 1, st_
    assert st_["entries"] >= 1, st_
    assert max_ref > 1, "sharing never materialized (no refcount > 1)"
    # hit requests were stamped with their discount
    hit = [r for r in sched.finished if r.prefix_hit_tokens > 0]
    assert hit and all(r.prefix_shared_blocks.sum() > 0 for r in hit)
    sched.backend.pool.check_invariants()

    plain = Engine.build(_cfg(chunk=16), params=params)
    plain.run_trace(_clone(reqs), max_steps=400)
    assert _tokens(eng) == _tokens(plain)
    mono = Engine.build(_cfg(), params=params)
    mono.run_trace(_clone(reqs), max_steps=400)
    assert _tokens(eng) == _tokens(mono)

    # after every request retired, only the index holds blocks; flushing
    # it returns the pool to empty (conservation over the whole trace).
    # blocks_held is ref-weighted (nested boundary entries share blocks),
    # so compare in-use against the DISTINCT block set
    distinct = {(l, int(b)) for e in sched.prefix._entries.values()
                for l in range(e.table.shape[0])
                for b in e.table[l].ravel() if b > 0}
    assert sched.backend.pool.blocks_in_use() == len(distinct)
    assert len(distinct) <= st_["blocks_held"]
    sched.prefix.flush()
    assert sched.backend.pool.blocks_in_use() == 0
    sched.backend.pool.check_invariants()

    # §12 wiring: hit/miss counters and sharing gauges were exported
    m = eng.metrics()
    assert m["prefix_hits_total"]["series"][0]["value"] == st_["hits"]
    assert "prefix_shared_blocks" in m and "prefix_bytes_saved" in m


@settings(max_examples=3, deadline=None)
@given(seed=st.integers(0, 10_000), frac=st.floats(0.3, 1.0))
def test_property_no_cross_request_corruption(seed, frac):
    """Random shared-prefix traces: sharing never changes any request's
    tokens (no cross-request corruption), and the pool survives intact."""
    cfg = _cfg(enabled=True, chunk=16)
    vocab = cfg.model.vocab_size
    reqs = synthesize_requests(6, 0.4, vocab, min_prompt=36, max_prompt=56,
                               max_new_tokens=5, seed=seed,
                               prefix_templates=2, prefix_len=32,
                               shared_fraction=frac)
    eng = Engine.build(cfg, params=_shared_params())
    out = eng.run_trace(reqs, max_steps=600)
    assert out["finished"] == out["total"]
    eng.scheduler.backend.pool.check_invariants()
    plain = Engine.build(_cfg(chunk=16), params=_shared_params())
    plain.run_trace(_clone(reqs), max_steps=600)
    assert _tokens(eng) == _tokens(plain)


def test_cow_privatizes_ring_wrap_writes(params):
    """Static capacity 64 (cap 32 + margin 32), ring 32, shared prefix 48
    tokens: once the donor's lengths hit capacity, its ring-wrap appends
    land inside the index-held prefix range (blocks 2-3 of 4) and MUST
    copy-on-write — writing in place would corrupt the registered entry.

    The proof is a LATE second request that hits the prefix only after the
    donor has wrapped: it stays below capacity (small gen), so its tokens
    are ring-phase independent and must equal the no-sharing engine's —
    which can only happen if the entry content survived the donor's
    overwrites bit-identically.  (Concurrent sharers can't be compared
    across engines once the ring wraps: chunk-count differences shift
    their decode phase — that head start IS the TTFT win.)"""
    cfg = _cfg(enabled=True, chunk=16, budget=32, margin=32, max_seq=128)
    vocab = cfg.model.vocab_size
    rng = np.random.default_rng(7)
    shared = rng.integers(1, vocab, size=48).astype(np.int32)
    sfx = [rng.integers(1, vocab, size=8).astype(np.int32) for _ in range(2)]
    reqs = [
        # donor: wraps (56 + 24 > 64) over its own registered blocks
        Request(req_id=0, prompt=np.concatenate([shared, sfx[0]]),
                arrival_step=0, max_new_tokens=24),
        # late hit: seeds from the entry after the donor's wrap-writes
        Request(req_id=1, prompt=np.concatenate([shared, sfx[1]]),
                arrival_step=40, max_new_tokens=6),
    ]
    eng = Engine.build(cfg, params=params)
    out = eng.run_trace(reqs, max_steps=400)
    assert out["finished"] == out["total"]
    backend = eng.scheduler.backend
    assert backend.cow_copies > 0, "trace never exercised copy-on-write"
    assert not backend._pending_cow  # every queued copy was flushed
    assert reqs[1].prefix_hit_tokens == 48  # the late request did share
    backend.pool.check_invariants()

    plain = Engine.build(_cfg(chunk=16, budget=32, margin=32, max_seq=128),
                         params=params)
    plain.run_trace(_clone(reqs), max_steps=400)
    assert _tokens(eng) == _tokens(plain)
    assert plain.scheduler.backend.cow_copies == 0  # nothing shared there


def test_cow_privatizes_quantized_scales(params):
    """The ring-wrap CoW scenario above with ``kv_dtype='int8'``: a shared
    quantized block privatized before a wrap append must copy the per-block
    *scale* entries along with the payload (DESIGN.md §15).

    Exact no-sharing parity (the fp32 oracle above) does not transfer: a
    seeded row shares the donor's codes and scales bit-for-bit, while a
    self-prefilled row quantizes its own block layout — same values,
    different grain, legitimately different rounding.  Two sharing-specific
    oracles replace it:

    - the *donor* still matches a quantized no-sharing engine token-for-
      token (its grain is self-prefilled in both) — so the privatized
      copies it decodes through carry the right codes AND scales;
    - the late sharer's tokens are invariant to whether the donor wrapped:
      a second sharing run whose donor stops before wrapping (no CoW at
      all) seeds the identical entry, so any divergence would mean the
      wrap run's CoW let the donor corrupt the registered codes or scales.
    """
    def sharing_run(donor_gen):
        cfg = _cfg(enabled=True, chunk=16, budget=32, margin=32, max_seq=128,
                   kv_dtype="int8")
        vocab = cfg.model.vocab_size
        rng = np.random.default_rng(7)
        shared = rng.integers(1, vocab, size=48).astype(np.int32)
        sfx = [rng.integers(1, vocab, size=8).astype(np.int32)
               for _ in range(2)]
        reqs = [
            Request(req_id=0, prompt=np.concatenate([shared, sfx[0]]),
                    arrival_step=0, max_new_tokens=donor_gen),
            Request(req_id=1, prompt=np.concatenate([shared, sfx[1]]),
                    arrival_step=40, max_new_tokens=6),
        ]
        eng = Engine.build(cfg, params=params)
        out = eng.run_trace(reqs, max_steps=400)
        assert out["finished"] == out["total"]
        assert reqs[1].prefix_hit_tokens == 48
        return eng, reqs

    wrap, wrap_reqs = sharing_run(donor_gen=24)  # 56 + 24 > 64: ring wraps
    backend = wrap.scheduler.backend
    assert backend.cow_copies > 0, "trace never exercised copy-on-write"
    assert not backend._pending_cow
    assert not backend._pending_scale_reset  # flushed with the copies
    # the state really is quantized storage with live scale pools
    cache = wrap.scheduler.state.cache
    assert cache.k_pool.dtype == jnp.int8
    assert cache.k_scale is not None and float(cache.k_scale.max()) > 0
    backend.pool.check_invariants()

    # donor oracle: same grain as a quantized no-sharing engine
    plain = Engine.build(_cfg(chunk=16, budget=32, margin=32, max_seq=128,
                              kv_dtype="int8"), params=params)
    plain.run_trace(_clone(wrap_reqs), max_steps=400)
    assert plain.scheduler.backend.cow_copies == 0
    assert _tokens(wrap)[0] == _tokens(plain)[0]

    # sharer oracle: identical seeded entry, donor never wraps
    nowrap, _ = sharing_run(donor_gen=2)
    assert nowrap.scheduler.backend.cow_copies == 0
    assert _tokens(wrap)[1] == _tokens(nowrap)[1]


def test_admission_discounts_shared_blocks():
    """Admission charges only unshared blocks for a stamped hit."""
    from repro.paging.backend import PagedBackend
    need = np.asarray([4, 4, 4], np.int64)
    req = Request(req_id=0, prompt=np.arange(8, dtype=np.int32))
    np.testing.assert_array_equal(
        PagedBackend._discount_shared(need, req), need)  # miss: full
    req.prefix_shared_blocks = np.asarray([3, 5, 0], np.int64)
    np.testing.assert_array_equal(
        PagedBackend._discount_shared(need, req), [1, 0, 4])


def test_shared_admission_fits_where_private_cannot(params):
    """Effective capacity: a pool sized so a single private 64-token
    prompt blocks the next admission supports overlapping requests when
    48 of those tokens are shared (the fig11 capacity claim, scaled to
    the smoke model)."""
    base = _cfg()
    vocab, H = base.model.vocab_size, base.model.n_kv_heads
    # admission charges ceil(64·H/16) + 2H = 6H blocks for a private
    # request; size the usable pool at 9H so one private request (5H live
    # after growth) starves the second (free 4H < 6H), while a 48-token
    # hit (discounted to 3H) still fits
    n_blocks = 9 * H + 1  # +1: block 0 is the null block

    def build(enabled):
        return Engine.build(_cfg(enabled=enabled, chunk=16,
                                 n_blocks=n_blocks, rows=4), params=params)

    reqs = _shared_reqs(vocab, shared_len=48, n_shared=4, suffix=16, gen=8,
                        spacing=4, seed=11)[:-1]  # drop the random req

    def peak_active(eng, reqs):
        peak = 0
        for _ in eng.stream(reqs, max_steps=600):
            sched = eng.scheduler
            peak = max(peak, len(sched.active) + len(sched.prefilling))
        assert all(r.is_finished for r in eng.scheduler.finished)
        return peak

    p_shared = peak_active(build(True), _clone(reqs))
    p_private = peak_active(build(False), _clone(reqs))
    assert p_shared > p_private, (p_shared, p_private)


# ---------------------------------------------------------------------------
# safe materialization of shared blocks
# ---------------------------------------------------------------------------


def test_materialize_and_migrate_conserve_shared_pool(params):
    """paged_to_slot is a pure gather (deep copy) and a migrate trial
    leaves the live pool untouched — with refcount > 1 blocks live."""
    vocab = _cfg().model.vocab_size
    reqs = _shared_reqs(vocab, gen=12)
    eng = Engine.build(_cfg(enabled=True, chunk=16), params=params)
    sched = eng._ensure_scheduler()
    it = eng.stream(reqs, max_steps=400)
    for _ in it:
        if int(sched.backend.pool.refcount.max()) > 1 and len(
                sched.active) >= 2 and not sched.prefilling:
            break
    backend = sched.backend
    assert int(backend.pool.refcount.max()) > 1  # sharing is live NOW
    ref0 = backend.pool.refcount.copy()
    in_use0 = backend.pool.blocks_in_use()
    table0 = backend.table.copy()

    slot = paged_to_slot(sched.state.cache, backend.capacity)
    # shared rows materialized identical content (same blocks gathered)
    shared_rows = sorted(sched.active)[:2]
    k = np.asarray(slot.k)
    lens = np.asarray(slot.lengths)
    for l in range(k.shape[0]):
        for s in range(k.shape[1]):
            n = int(min(lens[l, s, shared_rows[0]],
                        lens[l, s, shared_rows[1]], 48))
            if n > 0 and np.array_equal(
                    table0[l, s, shared_rows[0], :n // BS],
                    table0[l, s, shared_rows[1], :n // BS]):
                np.testing.assert_array_equal(
                    k[l, s, shared_rows[0], :n], k[l, s, shared_rows[1], :n])
    # the gather copied, never aliased or mutated, the pool
    np.testing.assert_array_equal(backend.pool.refcount, ref0)
    assert backend.pool.blocks_in_use() == in_use0
    np.testing.assert_array_equal(backend.table, table0)

    # a migrate *trial* (uncommitted — the hysteresis-rejected common case)
    # must also leave pool, refcounts, and mirror untouched
    rows = np.asarray(sorted(sched.active))
    lens2, _commit = backend.migrate_cache(sched.state.cache, sched.pa,
                                           sched.pa, active_rows=rows)
    np.testing.assert_array_equal(backend.pool.refcount, ref0)
    np.testing.assert_array_equal(backend.table, table0)
    backend.pool.check_invariants()
    # migration materialized every live row's full length
    np.testing.assert_array_equal(np.asarray(lens2), lens)

    for _ in it:  # drain to completion: sharing still winds down cleanly
        pass
    assert all(r.is_finished for r in sched.finished)
    sched.prefix.flush()
    assert backend.pool.blocks_in_use() == 0
    backend.pool.check_invariants()


# ---------------------------------------------------------------------------
# local / mesh chunked parity (multi-device subprocess)
# ---------------------------------------------------------------------------


SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, __SRC__)
import json
import numpy as np
from repro.api import (CompressionConfig, Engine, EngineConfig, PagingConfig,
                       PlannerConfig, PrefixConfig, SchedulerConfig)
from repro.launch.mesh import make_host_mesh
from repro.serving.request import Request


def cfg_for(executor, chunk):
    return EngineConfig.smoke(
        "minitron-8b", n_shards=4, max_seq_len=128,
        compression=CompressionConfig(policy="none", budget=96, capacity=96,
                                      decode_margin=8, obs_window=8),
        planner=PlannerConfig(mode="fairkv_dp", extra_copies=4, batch_cap=4),
        scheduler=SchedulerConfig(max_rows=4, enable_replan=False),
        cache_backend="paged", paging=PagingConfig(block_size=16),
        executor=executor,
        prefix=PrefixConfig(enabled=False, chunk_tokens=chunk))


def reqs_for(vocab):
    rng = np.random.default_rng(9)
    shared = rng.integers(1, vocab, size=32).astype(np.int32)
    out = []
    for i, (t, a) in enumerate([(52, 0), (14, 1), (37, 3), (64, 5)]):
        if t > 32:
            sfx = rng.integers(1, vocab, size=t - 32).astype(np.int32)
            prompt = np.concatenate([shared, sfx])
        else:  # shorter than one chunk: monolithic fast path on the mesh
            prompt = rng.integers(1, vocab, size=t).astype(np.int32)
        out.append(Request(req_id=i, prompt=prompt, arrival_step=a,
                           max_new_tokens=5))
    return out


loc = Engine.build(cfg_for("local", 16))
vocab = loc.cfg.model.vocab_size
out_l = loc.run_trace(reqs_for(vocab), max_steps=400)
mesh = make_host_mesh(model=4, data=2)
msh = Engine.build(cfg_for("mesh", 16), mesh=mesh, params=loc.params)
out_m = msh.run_trace(reqs_for(vocab), max_steps=400)
mono = Engine.build(cfg_for("local", 0), params=loc.params)
out_o = mono.run_trace(reqs_for(vocab), max_steps=400)
toks = [{r.req_id: list(r.generated) for r in e.scheduler.finished}
        for e in (loc, msh, mono)]
traces_after_first = msh.executor.prefill_chunk_traces
# a second identical trace must not add chunk-step compilations
msh2_reqs = reqs_for(vocab)
msh.run_trace(msh2_reqs, max_steps=400)
print(json.dumps({
    "all_finished": all(o["finished"] == o["total"]
                        for o in (out_l, out_m, out_o)),
    "mesh_eq_local": toks[0] == toks[1],
    "chunked_eq_mono": toks[0] == toks[2],
    "chunk_traces": traces_after_first,
    "chunk_traces_second_trace": msh.executor.prefill_chunk_traces,
}))
"""


def test_mesh_chunked_parity_multidevice_subprocess():
    """Chunked prefill on a 2x4 host mesh: tokens identical to the local
    executor and to monolithic prefill, with the chunk StepFn compiled a
    bounded number of times (fixed chunk width -> no per-chunk or
    per-trace recompiles)."""
    import repro
    src = list(repro.__path__)[0].rsplit("/repro", 1)[0]
    code = SUBPROC.replace("__SRC__", repr(src))
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["all_finished"]
    assert rec["mesh_eq_local"], rec
    assert rec["chunked_eq_mono"], rec
    assert rec["chunk_traces"] <= 2, rec
    assert rec["chunk_traces_second_trace"] == rec["chunk_traces"], rec
