"""Paged KV backend: BlockPool invariants, paged-vs-slot decode parity
(bit-for-bit, property-tested over random placements/lengths), append
parity including the recency ring, and slot↔paged round-trips."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.cache.slot_cache import SlotCache, append_token
from repro.kernels.ref import fairkv_decode_ref, paged_fairkv_decode_ref
from repro.paging.block_pool import BlockPool, PoolExhausted
from repro.paging.paged_cache import (
    build_table,
    init_paged_cache,
    max_blocks_per_row,
    paged_append_token,
    paged_to_slot,
    paginate_rows,
)
from repro.paging.block_pool import PagingConfig

from tests._hypothesis_compat import given, settings, st


# ---------------------------------------------------------------------------
# BlockPool invariants
# ---------------------------------------------------------------------------


def test_pool_alloc_free_round_trip():
    pool = BlockPool(n_layers=2, n_blocks=8)
    assert pool.usable_blocks == 7
    ids = pool.alloc(0, 5)
    assert len(set(ids)) == 5 and 0 not in ids
    assert pool.free_blocks(0) == 2 and pool.free_blocks(1) == 7
    assert pool.blocks_in_use() == 5
    pool.decref(0, ids)
    assert pool.free_blocks(0) == 7 and pool.blocks_in_use() == 0
    # deterministic reuse: lowest ids first, same sequence after round-trip
    assert pool.alloc(0, 5) == ids
    pool.check_invariants()


def test_pool_refcount_never_negative():
    pool = BlockPool(n_layers=1, n_blocks=4)
    (b,) = pool.alloc(0, 1)
    pool.incref(0, [b])
    pool.decref(0, [b])  # refcount 2 -> 1: still allocated
    assert pool.free_blocks(0) == 2
    pool.decref(0, [b])  # 1 -> 0: freed
    assert pool.free_blocks(0) == 3
    with pytest.raises(ValueError, match="double free"):
        pool.decref(0, [b])
    with pytest.raises(ValueError, match="null block"):
        pool.decref(0, [0])
    with pytest.raises(ValueError):
        pool.incref(0, [b])  # unallocated
    pool.check_invariants()


def test_pool_exhaustion_is_atomic():
    pool = BlockPool(n_layers=1, n_blocks=4)
    pool.alloc(0, 2)
    free_before = pool.free_blocks(0)
    with pytest.raises(PoolExhausted):
        pool.alloc(0, 2)  # only 1 free
    assert pool.free_blocks(0) == free_before  # nothing handed out
    pool.check_invariants()


def test_build_table_rolls_back_on_exhaustion():
    # layer 1 cannot satisfy the request -> layer 0's allocations must be
    # returned (atomicity), leaving the pool exactly as before
    pool = BlockPool(n_layers=2, n_blocks=4)
    lengths = np.full((2, 1, 1), 10)  # needs 3 blocks/layer at bs=4
    pool.alloc(1, 2)  # leave layer 1 with 1 free
    free0 = pool.free_blocks(0)
    with pytest.raises(PoolExhausted):
        build_table(lengths, pool, block_size=4, max_blocks=3)
    assert pool.free_blocks(0) == free0
    pool.check_invariants()


# ---------------------------------------------------------------------------
# slot -> paged construction + decode parity (property test)
# ---------------------------------------------------------------------------


def _random_slot_layer(rng, S, B, C, Dh, L=2):
    """A SlotCache with random contents and random lengths; some (slot,
    row) pairs unowned (length 0), some full (length C)."""
    k = rng.normal(size=(L, S, B, C, Dh)).astype(np.float32)
    v = rng.normal(size=(L, S, B, C, Dh)).astype(np.float32)
    lengths = rng.integers(0, C + 1, size=(L, S, B)).astype(np.int32)
    lengths[:, 0] = 0  # an entirely-unowned slot
    if S > 1:
        lengths[:, 1] = C  # a full slot (ring regime)
    pos = np.broadcast_to(np.arange(C, dtype=np.int32), (L, S, B, C)).copy()
    pos[lengths[..., None] <= np.arange(C)] = -1
    return SlotCache(k=jnp.asarray(k), v=jnp.asarray(v),
                     lengths=jnp.asarray(lengths), pos=jnp.asarray(pos),
                     positions=jnp.full((B,), C, jnp.int32))


def _paginate(slot, bs, extra_tokens=0):
    """Slot cache -> (PagedCache, pool); blocks sized for lengths (+extra
    per-entry tokens so appends have a home, mimicking prepare_decode)."""
    L, S, B, C, Dh = slot.k.shape
    M = max_blocks_per_row(C, bs)
    paged, pool = init_paged_cache(L, S, B, C, Dh,
                                   PagingConfig(block_size=bs),
                                   dtype=slot.k.dtype)
    lens = np.asarray(slot.lengths)
    alloc_for = np.minimum(lens + extra_tokens, C)
    table = build_table(alloc_for, pool, bs, M, own=lens > 0)
    paged = paginate_rows(paged, slot, jnp.arange(B, dtype=jnp.int32), table)
    return paged, pool


@settings(max_examples=12)
@given(S=st.integers(2, 5), B=st.integers(1, 4), G=st.integers(1, 4),
       C=st.integers(5, 40), bs=st.integers(2, 16), seed=st.integers(0, 10))
def test_paged_decode_parity_bitwise(S, B, G, C, bs, seed):
    """Paged decode == slot decode, bit for bit, over random placements,
    lengths (owned and unowned rows), capacities, and block sizes."""
    Dh = 8
    rng = np.random.default_rng(seed)
    slot = _random_slot_layer(rng, S, B, C, Dh, L=1)
    paged, _ = _paginate(slot, bs)
    q = jnp.asarray(rng.normal(size=(B, S, G, Dh)), jnp.float32)
    qpos = jnp.full((B,), C + 3, jnp.int32)
    for window in (0, max(2, C // 2)):
        ref = fairkv_decode_ref(q, slot.k[0], slot.v[0], slot.lengths[0],
                                k_pos=slot.pos[0], q_pos=qpos, window=window)
        out = paged_fairkv_decode_ref(
            q, paged.k_pool[0], paged.v_pool[0], paged.pos_pool[0],
            paged.block_table[0], paged.lengths[0], C,
            q_pos=qpos, window=window)
        assert np.array_equal(np.asarray(ref), np.asarray(out)), (
            f"parity broke at window={window}")


@settings(max_examples=8)
@given(S=st.integers(2, 4), B=st.integers(1, 3), C=st.integers(6, 24),
       bs=st.integers(2, 8), steps=st.integers(1, 6), seed=st.integers(0, 10))
def test_paged_append_parity(S, B, C, bs, steps, seed):
    """Decode appends (including ring overwrites on full rows) produce the
    same lengths and the same valid-prefix contents as the slot cache."""
    Dh = 4
    ring = max(1, C // 3)
    rng = np.random.default_rng(100 + seed)
    slot = _random_slot_layer(rng, S, B, C, Dh, L=1)
    paged, _ = _paginate(slot, bs, extra_tokens=steps)
    own = np.asarray(slot.lengths[0]) > 0  # owned pairs only
    own_j = jnp.asarray(own)
    for t in range(steps):
        k_new = jnp.asarray(rng.normal(size=(S, B, Dh)), jnp.float32)
        v_new = jnp.asarray(rng.normal(size=(S, B, Dh)), jnp.float32)
        slot = append_token(slot, 0, k_new, v_new, own_j, jnp.int32(t),
                            ring=ring)
        paged = paged_append_token(paged, 0, k_new, v_new, own_j,
                                   jnp.int32(t), C, ring=ring)
    assert np.array_equal(np.asarray(slot.lengths), np.asarray(paged.lengths))
    back = paged_to_slot(paged, C)
    lens = np.asarray(slot.lengths[0])
    for s in range(S):
        for b in range(B):
            n = int(lens[s, b])
            np.testing.assert_array_equal(
                np.asarray(slot.k[0, s, b, :n]), np.asarray(back.k[0, s, b, :n]))
            np.testing.assert_array_equal(
                np.asarray(slot.v[0, s, b, :n]), np.asarray(back.v[0, s, b, :n]))
            np.testing.assert_array_equal(
                np.asarray(slot.pos[0, s, b, :n]),
                np.asarray(back.pos[0, s, b, :n]))


def test_round_trip_slot_paged_slot_exact():
    """slot → paged → slot preserves every valid entry, lengths, positions;
    masked (invalid) entries come back zeroed per the §2 contract."""
    rng = np.random.default_rng(7)
    S, B, C, Dh, bs = 4, 3, 20, 8, 8
    slot = _random_slot_layer(rng, S, B, C, Dh, L=2)
    paged, pool = _paginate(slot, bs)
    back = paged_to_slot(paged, C)
    assert np.array_equal(np.asarray(slot.lengths), np.asarray(back.lengths))
    assert np.array_equal(np.asarray(slot.positions),
                          np.asarray(back.positions))
    lens = np.asarray(slot.lengths)
    valid = np.arange(C)[None, None, None, :] < lens[..., None]
    np.testing.assert_array_equal(
        np.where(valid[..., None], np.asarray(slot.k), 0), np.asarray(back.k))
    # allocation is proportional to realized lengths (+1-block floor)
    expected = sum(-(-max(int(l), 1) // bs) for l in lens.reshape(-1) if l > 0)
    assert pool.blocks_in_use() == expected
    pool.check_invariants()


def test_unowned_rows_gather_zero_output():
    """A fully-unowned (length 0) paged row decodes to exactly zero — the
    §2 psum-reassembly contract carries over to the paged layout."""
    rng = np.random.default_rng(3)
    S, B, C, Dh, bs = 3, 2, 12, 8, 4
    slot = _random_slot_layer(rng, S, B, C, Dh, L=1)
    paged, _ = _paginate(slot, bs)
    q = jnp.asarray(rng.normal(size=(B, S, 2, Dh)), jnp.float32)
    out = paged_fairkv_decode_ref(
        q, paged.k_pool[0], paged.v_pool[0], paged.pos_pool[0],
        paged.block_table[0], paged.lengths[0], C)
    assert float(np.abs(np.asarray(out)[:, 0]).max()) == 0.0  # slot 0 unowned


# ---------------------------------------------------------------------------
# quantized pools (DESIGN.md §15): slot↔paged bit-consistency + migration
# ---------------------------------------------------------------------------


def _paginate_quant(slot, bs, kinds):
    """Quantized variant of `_paginate`: int8 pools + per-block scales,
    per-slot ``kinds`` ((L, S) int32) selecting int8 vs fp8 encoding."""
    from repro.paging.kvquant import KVQuantSpec
    L, S, B, C, Dh = slot.k.shape
    M = max_blocks_per_row(C, bs)
    paged, pool = init_paged_cache(
        L, S, B, C, Dh, PagingConfig(block_size=bs, kv_dtype="int8"),
        dtype=slot.k.dtype, kv_quant=KVQuantSpec(base="int8"))
    lens = np.asarray(slot.lengths)
    table = build_table(lens, pool, bs, M, own=lens > 0)
    paged = paginate_rows(paged, slot, jnp.arange(B, dtype=jnp.int32), table,
                          kinds=np.asarray(kinds, np.int32))
    return paged, pool


def test_quantized_paged_to_slot_matches_decode_bitwise():
    """`paged_to_slot` must dequantize through the same scale pool as the
    decode path: slot-ref attention over the materialized values equals
    paged-ref attention over the codes bit for bit — the invariant that
    keeps slot↔paged migration consistent with what decode saw (§15)."""
    rng = np.random.default_rng(11)
    S, B, C, Dh, bs, L = 4, 3, 20, 8, 8, 2
    slot = _random_slot_layer(rng, S, B, C, Dh, L=L)
    # mixed kinds: alternate int8 / fp8 per slot, varied per layer
    kinds = (np.add.outer(np.arange(L), np.arange(S)) % 2).astype(np.int32)
    paged, _ = _paginate_quant(slot, bs, kinds)
    assert paged.k_pool.dtype == jnp.int8 and paged.k_scale is not None
    back = paged_to_slot(paged, C, kinds=kinds)
    q = jnp.asarray(rng.normal(size=(B, S, 2, Dh)), jnp.float32)
    qpos = jnp.full((B,), C + 3, jnp.int32)
    for layer in range(L):
        ref = fairkv_decode_ref(q, back.k[layer], back.v[layer],
                                back.lengths[layer], k_pos=back.pos[layer],
                                q_pos=qpos)
        out = paged_fairkv_decode_ref(
            q, paged.k_pool[layer], paged.v_pool[layer],
            paged.pos_pool[layer], paged.block_table[layer],
            paged.lengths[layer], C, q_pos=qpos,
            k_scale=paged.k_scale[layer], v_scale=paged.v_scale[layer],
            kinds=jnp.asarray(kinds[layer]))
        assert np.array_equal(np.asarray(ref), np.asarray(out)), layer
    # dequantized values approximate the originals within codec tolerance
    lens = np.asarray(slot.lengths)
    valid = np.arange(C)[None, None, None, :] < lens[..., None]
    err = np.abs(np.where(valid[..., None], np.asarray(slot.k), 0)
                 - np.asarray(back.k))
    assert float(err.max()) < 0.35  # fp8 e4m3 worst-case block step


def test_migrate_quantized_cache_decode_parity():
    """Migrating a quantized cache (trial-commit through `migrate_cache`)
    re-paginates via full precision: the committed pools decode within
    codec tolerance of the originals — never double-quantized garbage,
    never int8 codes reinterpreted as model values (§15)."""
    from repro.api import (CompressionConfig, Engine, EngineConfig,
                           PagingConfig as PC, PlannerConfig, SchedulerConfig)
    from repro.serving.request import Request
    cfg = EngineConfig.smoke(
        "minitron-8b", n_shards=4, max_seq_len=64,
        compression=CompressionConfig(policy="none", budget=32, capacity=32,
                                      decode_margin=8, obs_window=8),
        planner=PlannerConfig(batch_cap=2),
        scheduler=SchedulerConfig(max_rows=2, enable_replan=False),
        cache_backend="paged",
        paging=PC(block_size=8, kv_dtype="int8"))
    eng = Engine.build(cfg)
    rng = np.random.default_rng(5)
    reqs = [Request(req_id=i,
                    prompt=rng.integers(1, cfg.model.vocab_size,
                                        size=24).astype(np.int32),
                    arrival_step=i, max_new_tokens=20) for i in range(2)]
    # stop mid-generation: finished rows are released (blocks freed), and
    # migrating an empty cache would make the parity check vacuous
    eng.run_trace(reqs, max_steps=8)
    backend = eng.scheduler.backend
    cache = eng.scheduler.state.cache
    assert cache.k_pool.dtype == jnp.int8
    assert int(np.asarray(cache.lengths).max()) > 0  # live quantized rows
    _, commit = backend.migrate_cache(cache, backend.pa, backend.pa)
    cand = commit()
    assert cand.k_pool.dtype == jnp.int8  # storage format survives
    kinds = np.asarray(
        np.take_along_axis(np.asarray(backend.kv_kinds, np.int32),
                           np.maximum(np.asarray(backend.pa.slot_head), 0),
                           axis=1))
    q = jnp.asarray(rng.normal(size=(2, cache.block_table.shape[1], 2,
                                     cfg.model.head_dim)), jnp.float32)
    qpos = jnp.full((2,), 60, jnp.int32)
    for layer in (0, cache.k_pool.shape[0] - 1):
        a = paged_fairkv_decode_ref(
            q, cache.k_pool[layer], cache.v_pool[layer],
            cache.pos_pool[layer], cache.block_table[layer],
            cache.lengths[layer], backend.capacity, q_pos=qpos,
            k_scale=cache.k_scale[layer], v_scale=cache.v_scale[layer],
            kinds=jnp.asarray(kinds[layer]))
        b = paged_fairkv_decode_ref(
            q, cand.k_pool[layer], cand.v_pool[layer],
            cand.pos_pool[layer], cand.block_table[layer],
            cand.lengths[layer], backend.capacity, q_pos=qpos,
            k_scale=cand.k_scale[layer], v_scale=cand.v_scale[layer],
            kinds=jnp.asarray(kinds[layer]))
        assert np.array_equal(np.asarray(cache.lengths[layer]),
                              np.asarray(cand.lengths[layer]))
        assert float(jnp.abs(a - b).max()) < 0.05, layer
