"""Paged KV backend: BlockPool invariants, paged-vs-slot decode parity
(bit-for-bit, property-tested over random placements/lengths), append
parity including the recency ring, and slot↔paged round-trips."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.cache.slot_cache import SlotCache, append_token
from repro.kernels.ref import fairkv_decode_ref, paged_fairkv_decode_ref
from repro.paging.block_pool import BlockPool, PoolExhausted
from repro.paging.paged_cache import (
    build_table,
    init_paged_cache,
    max_blocks_per_row,
    paged_append_token,
    paged_to_slot,
    paginate_rows,
)
from repro.paging.block_pool import PagingConfig

from tests._hypothesis_compat import given, settings, st


# ---------------------------------------------------------------------------
# BlockPool invariants
# ---------------------------------------------------------------------------


def test_pool_alloc_free_round_trip():
    pool = BlockPool(n_layers=2, n_blocks=8)
    assert pool.usable_blocks == 7
    ids = pool.alloc(0, 5)
    assert len(set(ids)) == 5 and 0 not in ids
    assert pool.free_blocks(0) == 2 and pool.free_blocks(1) == 7
    assert pool.blocks_in_use() == 5
    pool.decref(0, ids)
    assert pool.free_blocks(0) == 7 and pool.blocks_in_use() == 0
    # deterministic reuse: lowest ids first, same sequence after round-trip
    assert pool.alloc(0, 5) == ids
    pool.check_invariants()


def test_pool_refcount_never_negative():
    pool = BlockPool(n_layers=1, n_blocks=4)
    (b,) = pool.alloc(0, 1)
    pool.incref(0, [b])
    pool.decref(0, [b])  # refcount 2 -> 1: still allocated
    assert pool.free_blocks(0) == 2
    pool.decref(0, [b])  # 1 -> 0: freed
    assert pool.free_blocks(0) == 3
    with pytest.raises(ValueError, match="double free"):
        pool.decref(0, [b])
    with pytest.raises(ValueError, match="null block"):
        pool.decref(0, [0])
    with pytest.raises(ValueError):
        pool.incref(0, [b])  # unallocated
    pool.check_invariants()


def test_pool_exhaustion_is_atomic():
    pool = BlockPool(n_layers=1, n_blocks=4)
    pool.alloc(0, 2)
    free_before = pool.free_blocks(0)
    with pytest.raises(PoolExhausted):
        pool.alloc(0, 2)  # only 1 free
    assert pool.free_blocks(0) == free_before  # nothing handed out
    pool.check_invariants()


def test_build_table_rolls_back_on_exhaustion():
    # layer 1 cannot satisfy the request -> layer 0's allocations must be
    # returned (atomicity), leaving the pool exactly as before
    pool = BlockPool(n_layers=2, n_blocks=4)
    lengths = np.full((2, 1, 1), 10)  # needs 3 blocks/layer at bs=4
    pool.alloc(1, 2)  # leave layer 1 with 1 free
    free0 = pool.free_blocks(0)
    with pytest.raises(PoolExhausted):
        build_table(lengths, pool, block_size=4, max_blocks=3)
    assert pool.free_blocks(0) == free0
    pool.check_invariants()


# ---------------------------------------------------------------------------
# slot -> paged construction + decode parity (property test)
# ---------------------------------------------------------------------------


def _random_slot_layer(rng, S, B, C, Dh, L=2):
    """A SlotCache with random contents and random lengths; some (slot,
    row) pairs unowned (length 0), some full (length C)."""
    k = rng.normal(size=(L, S, B, C, Dh)).astype(np.float32)
    v = rng.normal(size=(L, S, B, C, Dh)).astype(np.float32)
    lengths = rng.integers(0, C + 1, size=(L, S, B)).astype(np.int32)
    lengths[:, 0] = 0  # an entirely-unowned slot
    if S > 1:
        lengths[:, 1] = C  # a full slot (ring regime)
    pos = np.broadcast_to(np.arange(C, dtype=np.int32), (L, S, B, C)).copy()
    pos[lengths[..., None] <= np.arange(C)] = -1
    return SlotCache(k=jnp.asarray(k), v=jnp.asarray(v),
                     lengths=jnp.asarray(lengths), pos=jnp.asarray(pos),
                     positions=jnp.full((B,), C, jnp.int32))


def _paginate(slot, bs, extra_tokens=0):
    """Slot cache -> (PagedCache, pool); blocks sized for lengths (+extra
    per-entry tokens so appends have a home, mimicking prepare_decode)."""
    L, S, B, C, Dh = slot.k.shape
    M = max_blocks_per_row(C, bs)
    paged, pool = init_paged_cache(L, S, B, C, Dh,
                                   PagingConfig(block_size=bs),
                                   dtype=slot.k.dtype)
    lens = np.asarray(slot.lengths)
    alloc_for = np.minimum(lens + extra_tokens, C)
    table = build_table(alloc_for, pool, bs, M, own=lens > 0)
    paged = paginate_rows(paged, slot, jnp.arange(B, dtype=jnp.int32), table)
    return paged, pool


@settings(max_examples=12)
@given(S=st.integers(2, 5), B=st.integers(1, 4), G=st.integers(1, 4),
       C=st.integers(5, 40), bs=st.integers(2, 16), seed=st.integers(0, 10))
def test_paged_decode_parity_bitwise(S, B, G, C, bs, seed):
    """Paged decode == slot decode, bit for bit, over random placements,
    lengths (owned and unowned rows), capacities, and block sizes."""
    Dh = 8
    rng = np.random.default_rng(seed)
    slot = _random_slot_layer(rng, S, B, C, Dh, L=1)
    paged, _ = _paginate(slot, bs)
    q = jnp.asarray(rng.normal(size=(B, S, G, Dh)), jnp.float32)
    qpos = jnp.full((B,), C + 3, jnp.int32)
    for window in (0, max(2, C // 2)):
        ref = fairkv_decode_ref(q, slot.k[0], slot.v[0], slot.lengths[0],
                                k_pos=slot.pos[0], q_pos=qpos, window=window)
        out = paged_fairkv_decode_ref(
            q, paged.k_pool[0], paged.v_pool[0], paged.pos_pool[0],
            paged.block_table[0], paged.lengths[0], C,
            q_pos=qpos, window=window)
        assert np.array_equal(np.asarray(ref), np.asarray(out)), (
            f"parity broke at window={window}")


@settings(max_examples=8)
@given(S=st.integers(2, 4), B=st.integers(1, 3), C=st.integers(6, 24),
       bs=st.integers(2, 8), steps=st.integers(1, 6), seed=st.integers(0, 10))
def test_paged_append_parity(S, B, C, bs, steps, seed):
    """Decode appends (including ring overwrites on full rows) produce the
    same lengths and the same valid-prefix contents as the slot cache."""
    Dh = 4
    ring = max(1, C // 3)
    rng = np.random.default_rng(100 + seed)
    slot = _random_slot_layer(rng, S, B, C, Dh, L=1)
    paged, _ = _paginate(slot, bs, extra_tokens=steps)
    own = np.asarray(slot.lengths[0]) > 0  # owned pairs only
    own_j = jnp.asarray(own)
    for t in range(steps):
        k_new = jnp.asarray(rng.normal(size=(S, B, Dh)), jnp.float32)
        v_new = jnp.asarray(rng.normal(size=(S, B, Dh)), jnp.float32)
        slot = append_token(slot, 0, k_new, v_new, own_j, jnp.int32(t),
                            ring=ring)
        paged = paged_append_token(paged, 0, k_new, v_new, own_j,
                                   jnp.int32(t), C, ring=ring)
    assert np.array_equal(np.asarray(slot.lengths), np.asarray(paged.lengths))
    back = paged_to_slot(paged, C)
    lens = np.asarray(slot.lengths[0])
    for s in range(S):
        for b in range(B):
            n = int(lens[s, b])
            np.testing.assert_array_equal(
                np.asarray(slot.k[0, s, b, :n]), np.asarray(back.k[0, s, b, :n]))
            np.testing.assert_array_equal(
                np.asarray(slot.v[0, s, b, :n]), np.asarray(back.v[0, s, b, :n]))
            np.testing.assert_array_equal(
                np.asarray(slot.pos[0, s, b, :n]),
                np.asarray(back.pos[0, s, b, :n]))


def test_round_trip_slot_paged_slot_exact():
    """slot → paged → slot preserves every valid entry, lengths, positions;
    masked (invalid) entries come back zeroed per the §2 contract."""
    rng = np.random.default_rng(7)
    S, B, C, Dh, bs = 4, 3, 20, 8, 8
    slot = _random_slot_layer(rng, S, B, C, Dh, L=2)
    paged, pool = _paginate(slot, bs)
    back = paged_to_slot(paged, C)
    assert np.array_equal(np.asarray(slot.lengths), np.asarray(back.lengths))
    assert np.array_equal(np.asarray(slot.positions),
                          np.asarray(back.positions))
    lens = np.asarray(slot.lengths)
    valid = np.arange(C)[None, None, None, :] < lens[..., None]
    np.testing.assert_array_equal(
        np.where(valid[..., None], np.asarray(slot.k), 0), np.asarray(back.k))
    # allocation is proportional to realized lengths (+1-block floor)
    expected = sum(-(-max(int(l), 1) // bs) for l in lens.reshape(-1) if l > 0)
    assert pool.blocks_in_use() == expected
    pool.check_invariants()


def test_unowned_rows_gather_zero_output():
    """A fully-unowned (length 0) paged row decodes to exactly zero — the
    §2 psum-reassembly contract carries over to the paged layout."""
    rng = np.random.default_rng(3)
    S, B, C, Dh, bs = 3, 2, 12, 8, 4
    slot = _random_slot_layer(rng, S, B, C, Dh, L=1)
    paged, _ = _paginate(slot, bs)
    q = jnp.asarray(rng.normal(size=(B, S, 2, Dh)), jnp.float32)
    out = paged_fairkv_decode_ref(
        q, paged.k_pool[0], paged.v_pool[0], paged.pos_pool[0],
        paged.block_table[0], paged.lengths[0], C)
    assert float(np.abs(np.asarray(out)[:, 0]).max()) == 0.0  # slot 0 unowned
