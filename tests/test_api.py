"""`repro.api` facade: registries, EngineConfig validation, Engine parity.

The load-bearing test is end-to-end parity: `Engine.generate` must produce
bit-compatible logits/tokens with the hand-wired
``init → plan → slot weights → prefill → decode loop`` it replaced, so the
facade is a pure re-packaging, not a behavioral fork.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.api import (
    ASSIGNMENT_ENGINE_REGISTRY,
    POLICY_REGISTRY,
    CompressionConfig,
    Engine,
    EngineConfig,
    PlannerConfig,
    SchedulerConfig,
    list_engines,
    list_policies,
    register_assignment_engine,
    register_policy,
    synthesize_requests,
)
from repro.compression.policies import select, snapkv
from repro.core.assignment import assign_items

ARCH = "minitron-8b"


def _ccfg(**kw):
    base = dict(policy="ada_snapkv", budget=16, alpha_max=2.0, obs_window=8,
                sink=2, decode_margin=8)
    base.update(kw)
    return CompressionConfig(**base)


def _ecfg(**kw):
    base = dict(n_shards=4, max_seq_len=48, compression=_ccfg(),
                planner=PlannerConfig(mode="fairkv_dp", extra_copies=4,
                                      batch_cap=2))
    base.update(kw)
    return EngineConfig.smoke(ARCH, **base)


# ---------------------------------------------------------------------------
# registries
# ---------------------------------------------------------------------------


def test_builtin_registrations_present():
    assert set(list_policies()) >= {"streaming_llm", "snapkv", "pyramidkv",
                                    "h2o", "ada_snapkv", "headkv"}
    assert set(list_engines()) >= {"auto", "backtracking", "greedy"}


def test_duplicate_registration_rejected():
    with pytest.raises(ValueError, match="already registered"):
        register_policy("snapkv")(lambda *a, **k: None)
    with pytest.raises(ValueError, match="already registered"):
        register_assignment_engine("auto")(lambda *a, **k: None)


def test_unknown_names_list_registered():
    with pytest.raises(KeyError, match="snapkv"):
        POLICY_REGISTRY["nope"]
    # Mapping .get keeps the standard default-returning contract
    assert POLICY_REGISTRY.get("nope") is None
    assert POLICY_REGISTRY.get("nope", snapkv) is snapkv
    with pytest.raises(KeyError, match="greedy"):
        assign_items([1.0, 2.0], 2, 1, engine="nope")
    with pytest.raises(KeyError, match="ada_snapkv"):
        select("nope", jnp.zeros((1, 2, 8)), _ccfg(), 0, 1)


def test_local_policy_roundtrip():
    """A test-local @register_policy flows through EngineConfig validation
    and compression.policies.select without touching core files."""
    name = "test_local_policy"

    @register_policy(name)
    def _policy(scores, cfg, layer_idx, n_layers, **kw):
        return snapkv(scores, cfg, layer_idx, n_layers)

    try:
        assert name in list_policies()
        cfg = _ecfg(compression=_ccfg(policy=name))  # validates
        assert cfg.compression.policy == name
        scores = jnp.asarray(
            np.random.default_rng(0).random((1, 2, 24)), jnp.float32)
        idx, keep = select(name, scores, cfg.compression, 0, 2)
        ref_idx, ref_keep = select("snapkv", scores, cfg.compression, 0, 2)
        np.testing.assert_array_equal(np.asarray(idx), np.asarray(ref_idx))
        np.testing.assert_array_equal(np.asarray(keep), np.asarray(ref_keep))
    finally:
        POLICY_REGISTRY.unregister(name)
    assert name not in list_policies()


def test_local_engine_roundtrip():
    name = "test_local_engine"

    @register_assignment_engine(name)
    def _engine(weights, n_shards, slots_per_shard, **kw):
        # worst possible solver: everything on shard 0 (capacity allowing)
        out = [[] for _ in range(n_shards)]
        for i in range(len(weights)):
            out[i // slots_per_shard].append(i)
        return out

    try:
        cfg = _ecfg(planner=PlannerConfig(engine=name))  # validates
        assert cfg.planner.engine == name
        assert assign_items([3.0, 1.0], 2, 1, engine=name) == [[0], [1]]
    finally:
        ASSIGNMENT_ENGINE_REGISTRY.unregister(name)


def test_backtracking_rejects_item_group():
    """Regression (core/assignment): an explicit engine='backtracking' with
    replica groups used to silently degrade to greedy; it must raise."""
    with pytest.raises(ValueError, match="backtracking"):
        assign_items([3.0, 2.0, 2.0, 1.0], 2, 2, engine="backtracking",
                     item_group=[0, 0, 1, 1])
    # 'auto' still handles replica groups by falling back to greedy
    out = assign_items([3.0, 2.0, 2.0, 1.0], 2, 2, engine="auto",
                       item_group=[0, 0, 1, 1])
    for shard in out:
        groups = [[0, 0, 1, 1][i] for i in shard]
        assert len(groups) == len(set(groups))  # replicas on distinct shards


# ---------------------------------------------------------------------------
# EngineConfig validation
# ---------------------------------------------------------------------------


def test_config_rejects_unknown_policy():
    with pytest.raises(ValueError, match=r"ada_snapkv"):
        _ecfg(compression=_ccfg(policy="bogus"))


def test_config_rejects_unknown_planner_mode():
    with pytest.raises(ValueError, match=r"fairkv_dp"):
        _ecfg(planner=PlannerConfig(mode="bogus"))


def test_config_rejects_unknown_engine():
    with pytest.raises(ValueError, match=r"greedy"):
        _ecfg(planner=PlannerConfig(engine="bogus"))


def test_config_rejects_bad_scalars():
    with pytest.raises(ValueError, match="dtype"):
        _ecfg(dtype="float8")
    with pytest.raises(ValueError, match="n_shards"):
        _ecfg(n_shards=0)
    with pytest.raises(ValueError, match="max_rows"):
        _ecfg(scheduler=SchedulerConfig(max_rows=0))


def test_config_replace_revalidates():
    cfg = _ecfg()
    with pytest.raises(ValueError):
        cfg.replace(compression=_ccfg(policy="bogus"))


# ---------------------------------------------------------------------------
# Engine parity with the hand-wired path
# ---------------------------------------------------------------------------


def test_generate_parity_with_handwired_loop():
    from repro.cache.slot_cache import PlanArrays
    from repro.core import build_plan, synthetic_profile
    from repro.serving import decode_step, prefill, slotify_params

    T, B, GEN = 24, 2, 4
    cfg = _ecfg(max_seq_len=T + GEN + 8)
    eng = Engine.build(cfg)
    prompts = np.random.default_rng(0).integers(
        0, cfg.model.vocab_size, (B, T))
    res = eng.generate(prompts, GEN)

    # hand-wired: same params, same profile inputs -> same plan
    profile = synthetic_profile(cfg.model.n_layers, cfg.model.n_kv_heads,
                                budget=cfg.compression.budget,
                                skew=cfg.profile_skew, seed=cfg.profile_seed)
    plan = build_plan(profile, cfg.n_shards, cfg.planner)
    pa = PlanArrays.from_plan(plan)
    sp = slotify_params(eng.params, plan, cfg.model)
    batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
    state, logits, lengths = prefill(sp, batch, cfg.model, pa,
                                     cfg.compression)
    ref_logits = [np.asarray(logits)]
    ref_tokens = [np.asarray(state.last_tokens)]
    for _ in range(GEN):
        state, logits = decode_step(sp, state, cfg.model, pa,
                                    cfg.compression)
        ref_logits.append(np.asarray(logits))
        ref_tokens.append(np.asarray(state.last_tokens))

    np.testing.assert_array_equal(res.tokens, np.stack(ref_tokens, axis=1))
    np.testing.assert_allclose(res.logits, np.stack(ref_logits, axis=1),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(res.lengths, np.asarray(lengths))
    assert res.efficiency == pytest.approx(
        plan.efficiency(np.asarray(lengths, np.float64).mean(axis=2)))


def test_generate_teacher_forcing_feeds_given_tokens():
    T, B, GEN = 16, 1, 3
    cfg = _ecfg(max_seq_len=T + GEN + 8)
    eng = Engine.build(cfg)
    rng = np.random.default_rng(1)
    prompts = rng.integers(0, cfg.model.vocab_size, (B, T))
    teacher = rng.integers(0, cfg.model.vocab_size, (B, GEN))
    free = eng.generate(prompts, GEN)
    eng2 = Engine.build(cfg, params=eng.params)
    forced = eng2.generate(prompts, GEN, teacher_tokens=teacher)
    # prefill logits identical; decode logits diverge once fed tokens differ
    np.testing.assert_allclose(free.logits[:, 0], forced.logits[:, 0],
                               rtol=1e-5, atol=1e-5)
    assert not np.allclose(free.logits[:, -1], forced.logits[:, -1])


# ---------------------------------------------------------------------------
# continuous mode through the facade
# ---------------------------------------------------------------------------


def test_stream_yields_every_token_in_order():
    cfg = _ecfg(scheduler=SchedulerConfig(max_rows=2, enable_replan=False),
                max_seq_len=32)
    eng = Engine.build(cfg)
    reqs = synthesize_requests(3, 0.5, cfg.model.vocab_size, min_prompt=8,
                               max_prompt=12, max_new_tokens=4, seed=0)
    events = list(eng.stream(reqs, max_steps=200))
    assert len(eng.finished_requests) == 3
    by_req = {}
    for ev in events:
        by_req.setdefault(ev.req_id, []).append(ev)
    for req in reqs:
        evs = by_req[req.req_id]
        assert [e.index for e in evs] == list(range(req.n_generated))
        assert [e.token for e in evs] == req.generated
        assert evs[-1].finished and not any(e.finished for e in evs[:-1])
    steps = [e.step for e in events]
    assert steps == sorted(steps)  # stream is step-ordered


def test_replan_with_speeds_reaches_live_scheduler():
    """Regression: replan(shard_speeds=...) on a continuous-mode engine must
    flow into the scheduler (live-cache migration + accept/reject), not
    silently rebuild a plan the next step() reverts."""
    cfg = _ecfg(scheduler=SchedulerConfig(max_rows=2, enable_replan=False),
                max_seq_len=32)
    eng = Engine.build(cfg)
    reqs = synthesize_requests(2, 10.0, cfg.model.vocab_size, min_prompt=8,
                               max_prompt=10, max_new_tokens=8, seed=0)
    for r in reqs:
        eng.submit(r)
    eng.step()
    eng.step()
    ev = eng.replan(shard_speeds=[1.0, 1.0, 1.0, 0.5])
    assert "accepted" in ev  # scheduler-path event, not the one-shot dict
    assert eng.plan is eng.scheduler.plan  # engine refs follow the scheduler
    # speeds persist so later trigger-fired replans don't revert mitigation
    np.testing.assert_array_equal(eng.scheduler.shard_speeds,
                                  [1.0, 1.0, 1.0, 0.5])


def test_replan_oneshot_swaps_plan():
    cfg = _ecfg()
    eng = Engine.build(cfg)
    old_plan = eng.plan
    prof = np.asarray(eng.profile) * np.linspace(
        1.0, 3.0, eng.profile.shape[1])[None, :]
    out = eng.replan(profile=prof)
    assert eng.plan is not old_plan
    assert out["migrated_cache"] is False  # no live cache yet


# ---------------------------------------------------------------------------
# EngineConfig.to_dict / from_dict round-trip (DESIGN.md §8)
# ---------------------------------------------------------------------------


def _roundtrip(cfg):
    import json
    data = json.loads(json.dumps(cfg.to_dict()))  # force JSON types
    return EngineConfig.from_dict(data)


def test_config_dict_roundtrip_defaults():
    cfg = _ecfg()
    assert _roundtrip(cfg) == cfg


def test_config_dict_roundtrip_property():
    """Round-trip over the registered option space: every policy, backend,
    executor, and planner mode survives ``to_dict -> json -> from_dict``
    unchanged (tuples, nested sub-configs, and dtype-override dicts
    included)."""
    from tests._hypothesis_compat import given, settings, st
    from repro.api import (PagingConfig, SpeculationConfig, list_engines,
                           list_executors, list_policies)
    from repro.core.planner import PLANNER_MODES

    @settings(max_examples=15)
    @given(policy=st.sampled_from(sorted(list_policies())),
           mode=st.sampled_from(sorted(PLANNER_MODES)),
           engine=st.sampled_from(sorted(list_engines())),
           executor=st.sampled_from(sorted(list_executors())),
           backend=st.sampled_from(["slot", "paged"]),
           kv=st.sampled_from(["fp32", "int8"]),
           spec=st.booleans(), max_k=st.integers(1, 6))
    def run(policy, mode, engine, executor, backend, kv, spec, max_k):
        if spec or kv != "fp32":
            backend = "paged"  # speculation / quantized pools need paged
        overrides = {(0, 1): "int8"} if kv == "int8" else {}
        cfg = _ecfg(
            compression=_ccfg(policy=policy),
            planner=PlannerConfig(mode=mode, engine=engine, extra_copies=4,
                                  batch_cap=2),
            cache_backend=backend, executor=executor,
            paging=PagingConfig(block_size=8, kv_dtype=kv,
                                kv_dtype_overrides=overrides),
            speculation=SpeculationConfig(enabled=spec, max_k=max_k))
        back = _roundtrip(cfg)
        assert back == cfg
        assert back.paging.kv_dtype_overrides == \
            cfg.paging.kv_dtype_overrides

    run()


def test_config_from_dict_rejects_unknown_keys():
    data = _ecfg().to_dict()
    data["speculation"]["maxk"] = 3  # typo'd nested key
    with pytest.raises(ValueError) as ei:
        EngineConfig.from_dict(data)
    msg = str(ei.value)
    assert "maxk" in msg and "engine.speculation" in msg
    assert "max_k" in msg  # valid keys listed for the typo'd level
    data = _ecfg().to_dict()
    data["bogus_top"] = 1
    with pytest.raises(ValueError, match="bogus_top"):
        EngineConfig.from_dict(data)


def test_config_from_dict_revalidates():
    """from_dict goes through the constructors, so semantic validation
    (registry names, cross-field rules) still fires on edited files."""
    data = _ecfg().to_dict()
    data["compression"]["policy"] = "not_a_policy"
    with pytest.raises(ValueError, match="not_a_policy"):
        EngineConfig.from_dict(data)
    data = _ecfg().to_dict()
    data["speculation"]["enabled"] = True  # slot backend + speculation
    with pytest.raises(ValueError, match="paged"):
        EngineConfig.from_dict(data)


# ---------------------------------------------------------------------------
# Engine.stats(): the consolidated snapshot vs the legacy accessors
# ---------------------------------------------------------------------------


def test_stats_idle_engine_always_constructible():
    from repro.api import EngineStats
    eng = Engine.build(_ecfg())
    st = eng.stats()
    assert isinstance(st, EngineStats)
    assert st.scheduler.mode == "idle"
    assert st.pool.detail == {} and st.pool.backend is None
    assert st.plan.n_shards == 4  # plan exists from build
    assert not st.speculation.enabled
    assert isinstance(st.to_dict(), dict)
    # legacy accessors keep their historical raising behavior when empty
    with pytest.raises(RuntimeError):
        eng.memory_stats()
    with pytest.raises(RuntimeError):
        eng.imbalance()


def test_stats_continuous_matches_legacy_accessors():
    cfg = _ecfg(scheduler=SchedulerConfig(max_rows=2, enable_replan=False),
                max_seq_len=32)
    eng = Engine.build(cfg)
    reqs = synthesize_requests(3, 0.5, cfg.model.vocab_size, min_prompt=8,
                               max_prompt=12, max_new_tokens=4, seed=0)
    out = eng.run_trace(reqs, max_steps=200)
    assert out["finished"] == 3
    st = eng.stats()
    assert st.scheduler.mode == "continuous"
    assert st.scheduler.finished == 3
    assert st.scheduler.steps == eng.scheduler.step_idx
    assert st.scheduler.imbalance == pytest.approx(eng.imbalance())
    assert st.scheduler.replan_log == eng.replan_log
    assert st.pool.detail == eng.memory_stats()
    assert st.pool.backend == "slot"
    assert st.to_dict()["scheduler"]["finished"] == 3
