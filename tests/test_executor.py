"""Executor layer (DESIGN.md §10): registry/config wiring, StepFn no-retrace
guarantees, per-shard admission, partitioned block pool, and local↔mesh
parity on a multi-device host mesh.

The parity tests run in a subprocess (the fake-device count must be set
before the first jax import, like tests/test_distributed.py): one process
drives `Engine.generate` through the ``local`` and ``mesh`` executors on
identical weights/plans — imbalanced profiles WITH replicas, both cache
backends, 2- and 8-device meshes — and asserts identical tokens and cache
lengths, plus a replan that must not recompile the decode StepFn.
"""
import json
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.api import (
    CompressionConfig,
    Engine,
    EngineConfig,
    ExecutorConfig,
    PlannerConfig,
    SchedulerConfig,
    list_executors,
    make_executor,
    synthesize_requests,
)

ARCH = "minitron-8b"


def _ecfg(**kw):
    base = dict(
        n_shards=4, max_seq_len=48,
        compression=CompressionConfig(policy="ada_snapkv", budget=16,
                                      alpha_max=2.0, obs_window=8, sink=2,
                                      decode_margin=8),
        planner=PlannerConfig(mode="fairkv_dp", extra_copies=4, batch_cap=2))
    base.update(kw)
    return EngineConfig.smoke(ARCH, **base)


# ---------------------------------------------------------------------------
# registry / config / mesh plumbing
# ---------------------------------------------------------------------------


def test_builtin_executors_registered():
    assert set(list_executors()) >= {"local", "mesh"}


def test_config_rejects_unknown_executor():
    with pytest.raises(ValueError, match="local"):
        _ecfg(executor="bogus")


def test_executor_config_rejects_same_axes():
    with pytest.raises(ValueError, match="differ"):
        ExecutorConfig(data_axis="x", model_axis="x")


def test_engine_rejects_mesh_with_local_executor():
    """Regression: Engine(..., mesh=) used to store the mesh as 'reserved'
    and silently ignore it; it must now be either used (executor='mesh')
    or rejected."""
    cfg = _ecfg()  # executor defaults to "local"
    with pytest.raises(ValueError, match="executor='mesh'"):
        Engine.build(cfg, mesh=object())


def test_local_executor_rejects_mesh():
    cfg = _ecfg()
    with pytest.raises(ValueError, match="mesh"):
        make_executor("local", cfg.model, cfg.compression, mesh=object())


def test_mesh_executor_requires_mesh():
    cfg = _ecfg(executor="mesh")
    with pytest.raises(ValueError, match="make_host_mesh"):
        Engine.build(cfg)


def test_mesh_executor_rejects_moe():
    """MoE's capacity-bounded dispatch sizes expert capacity from the
    global token count — data-sharded replication changes drop behavior
    (verified non-equivalent), so the mesh executor must refuse it."""
    from repro.launch.mesh import make_host_mesh
    cfg = EngineConfig.smoke("qwen3-moe-30b-a3b", executor="mesh")
    with pytest.raises(NotImplementedError, match="expert parallelism"):
        Engine.build(cfg, mesh=make_host_mesh(model=1, data=1))


def test_make_host_mesh_oversubscription_raises():
    """Regression: was a bare assert (vanishes under python -O)."""
    from repro.launch.mesh import make_host_mesh
    n = len(jax.devices())
    with pytest.raises(ValueError, match=f"only {n} available"):
        make_host_mesh(model=n + 1, data=2)


# ---------------------------------------------------------------------------
# StepFn no-retrace (local executor; the mesh variant runs in the subprocess)
# ---------------------------------------------------------------------------


def test_decode_compiles_once_across_requests_and_replan():
    """The decode StepFn must compile exactly once per (shape, backend):
    weights and plan arrays are arguments, so admissions and replans swap
    values through the same executable.  The aggressive trigger settings
    (the serve_continuous example's) make the trace fire a live replan —
    slot weights and plan arrays actually swap mid-flight."""
    cfg = _ecfg(scheduler=SchedulerConfig(max_rows=4, replan_window=4,
                                          replan_threshold=1.05,
                                          replan_cooldown=10),
                planner=PlannerConfig(mode="fairkv_dp", extra_copies=4,
                                      batch_cap=4),
                max_seq_len=64)
    eng = Engine.build(cfg)
    reqs = synthesize_requests(8, 0.4, cfg.model.vocab_size, min_prompt=12,
                               max_prompt=28, max_new_tokens=10, seed=3)
    out = eng.run_trace(reqs, max_steps=500)
    assert out["finished"] == 8
    assert any(ev["accepted"] for ev in out["replan_log"]), out["replan_log"]
    assert eng.executor.decode_traces == 1


def test_oneshot_replan_does_not_retrace():
    cfg = _ecfg(max_seq_len=40)
    eng = Engine.build(cfg)
    prompts = np.random.default_rng(0).integers(0, cfg.model.vocab_size,
                                                (2, 16))
    eng.generate(prompts, 3)
    assert eng.executor.decode_traces == 1
    prof = np.asarray(eng.profile)[:, ::-1].copy()
    eng.replan(profile=prof)
    eng.generate(prompts, 3)
    assert eng.executor.decode_traces == 1


# ---------------------------------------------------------------------------
# per-model-shard admission (slot backend)
# ---------------------------------------------------------------------------


def test_per_shard_budget_gates_admission():
    """A per-shard budget must gate on the bottleneck shard: a request that
    fits the global sum but overloads one shard is not admissible."""
    from repro.serving.cache_backend import make_cache_backend
    from repro.serving.request import Request

    cfg = _ecfg()
    eng = Engine.build(cfg)  # supplies a live plan geometry (4 shards)
    backend = make_cache_backend(
        "slot", cfg.model, cfg.compression, n_shards=cfg.n_shards,
        max_live_tokens_per_shard=10_000)
    state = backend.init_state(eng.plan_arrays, 2, jnp.float32)
    req = Request(req_id=0, prompt=np.zeros(16, np.int32), arrival_step=0,
                  max_new_tokens=4)
    cost = backend.per_shard_cost(req)
    assert cost.shape == (cfg.n_shards,)
    assert cost.sum() > 0
    assert backend.admissible(state, req)
    # shrink the per-shard budget below the hottest shard's projected cost
    backend.max_live_tokens_per_shard = int(cost.max()) - 1
    assert not backend.admissible(state, req)
    assert "per-shard" in backend.never_fits(req)


def test_scheduler_rejects_request_never_fitting_per_shard():
    cfg = _ecfg(scheduler=SchedulerConfig(max_rows=2, enable_replan=False,
                                          max_live_tokens_per_shard=8),
                max_seq_len=40)
    eng = Engine.build(cfg)
    with pytest.raises(ValueError, match="never be admitted"):
        eng.submit(np.zeros(16, np.int32), max_new_tokens=4)


# ---------------------------------------------------------------------------
# partitioned block pool (mesh paged layout)
# ---------------------------------------------------------------------------


def test_block_pool_partitions():
    from repro.paging.block_pool import BlockPool, PoolExhausted

    pool = BlockPool(n_layers=2, n_blocks=12, n_partitions=3)
    assert pool.part_size == 4
    assert pool.usable_blocks == 12 - 3  # one null block per partition
    ids0 = pool.alloc(0, 2, partition=0)
    ids2 = pool.alloc(0, 3, partition=2)
    assert all(0 < b < 4 for b in ids0)  # partition 0: global ids 1..3
    assert all(8 < b < 12 for b in ids2)  # partition 2: global ids 9..11
    with pytest.raises(PoolExhausted, match="partition 1"):
        pool.alloc(0, 4, partition=1)  # only 3 usable per partition
    pool.decref(0, ids0 + ids2)  # partition inferred from the id
    pool.check_invariants()
    assert pool.free_blocks(0) == 9
    with pytest.raises(ValueError, match="null block"):
        pool.decref(0, [8])  # partition 2's null block


def test_build_table_respects_partitions():
    from repro.paging.block_pool import BlockPool
    from repro.paging.paged_cache import build_table

    L, S, B, bs, M = 1, 4, 4, 4, 2
    pool = BlockPool(L, 4 * (2 * 2 * M + 1), n_partitions=4)  # (2 slot, 2 row)
    lengths = np.full((L, S, B), 5)  # 2 blocks each
    table = build_table(lengths, pool, bs, M, partitions=(2, 2),
                        rows=np.arange(B), n_rows=B)
    part = pool.part_size
    for s in range(S):
        for b in range(B):
            p = (s // 2) * 2 + (b // 2)
            ids = table[0, s, b]
            assert all(p * part < i < (p + 1) * part for i in ids), (s, b, ids)
    pool.check_invariants()


# ---------------------------------------------------------------------------
# local ↔ mesh parity + mesh no-retrace (multi-device subprocess)
# ---------------------------------------------------------------------------


SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, __SRC__)
import json
import numpy as np
import jax, jax.numpy as jnp
from repro.api import (CompressionConfig, Engine, EngineConfig,
                       PlannerConfig, SchedulerConfig, synthesize_requests)
from repro.launch.mesh import make_host_mesh

B, T, GEN = 4, 20, 4

def cfg_for(backend, n_shards, skew, seed, executor="local", rows=4):
    from repro.api import PagingConfig
    return EngineConfig.smoke(
        "minitron-8b", n_shards=n_shards, max_seq_len=T + GEN + 8,
        compression=CompressionConfig(policy="ada_snapkv", budget=16,
                                      alpha_max=2.0, obs_window=8, sink=2,
                                      decode_margin=8),
        planner=PlannerConfig(mode="fairkv_dp", extra_copies=4,
                              batch_cap=rows),
        scheduler=SchedulerConfig(max_rows=rows, enable_replan=False),
        cache_backend=backend, paging=PagingConfig(block_size=8),
        executor=executor, profile_skew=skew, profile_seed=seed)

results = []
CASES = __CASES__
for backend, data, model, n_shards, skew, seed in CASES:
    prompts = np.random.default_rng(seed).integers(0, 256, (B, T))
    loc = Engine.build(cfg_for(backend, n_shards, skew, seed))
    res_l = loc.generate(prompts, GEN)
    mesh = make_host_mesh(model=model, data=data)
    msh = Engine.build(cfg_for(backend, n_shards, skew, seed,
                               executor="mesh"),
                       mesh=mesh, params=loc.params)
    res_m = msh.generate(prompts, GEN)
    has_replicas = any(int(lp.replica_count.max()) > 1
                       for lp in msh.plan.layers)
    rec = {
        "case": [backend, data, model, n_shards, skew, seed],
        "replicas": has_replicas,
        "tokens_equal": bool(np.array_equal(res_l.tokens, res_m.tokens)),
        "lengths_equal": bool(np.array_equal(res_l.lengths, res_m.lengths)),
        "state_lengths_equal": bool(np.array_equal(
            np.asarray(loc.state.cache.lengths),
            np.asarray(msh.state.cache.lengths))),
        "logits_close": bool(np.allclose(res_l.logits, res_m.logits,
                                         rtol=1e-4, atol=1e-4)),
        "decode_traces_after_gen": msh.executor.decode_traces,
    }
    # replan on both (same inputs -> same plan) and decode again: tokens
    # must still agree and the mesh decode StepFn must NOT recompile
    prof = np.asarray(loc.profile)[:, ::-1].copy()
    loc.replan(profile=prof)
    msh.replan(profile=prof)
    res_l2 = loc.generate(prompts, GEN)
    res_m2 = msh.generate(prompts, GEN)
    rec["tokens_equal_after_replan"] = bool(
        np.array_equal(res_l2.tokens, res_m2.tokens))
    rec["decode_traces_after_replan"] = msh.executor.decode_traces
    results.append(rec)

# continuous mode on the mesh: identical trace tokens vs local, one trace
backend = CASES[0][0]
mesh = make_host_mesh(model=4, data=2)
eng_l = Engine.build(cfg_for(backend, 4, 2.0, 1))
eng_m = Engine.build(cfg_for(backend, 4, 2.0, 1, executor="mesh"),
                     mesh=mesh, params=eng_l.params)
for eng in (eng_l, eng_m):
    reqs = synthesize_requests(5, 0.6, 256, min_prompt=10, max_prompt=18,
                               max_new_tokens=4, seed=2)
    out = eng.run_trace(reqs, max_steps=300)
    assert out["finished"] == out["total"], out
toks_l = {r.req_id: r.generated for r in eng_l.finished_requests}
toks_m = {r.req_id: r.generated for r in eng_m.finished_requests}
results.append({"case": ["continuous", backend],
                "tokens_equal": toks_l == toks_m,
                "decode_traces": eng_m.executor.decode_traces})
print(json.dumps(results))
"""


def _run_subproc(cases):
    import repro
    src = list(repro.__path__)[0].rsplit("/repro", 1)[0]
    code = SUBPROC.replace("__SRC__", repr(src)).replace(
        "__CASES__", repr(cases))
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.parametrize("backend", ["slot", "paged"])
def test_mesh_parity_multidevice_subprocess(backend):
    """local and mesh executors produce identical tokens and cache lengths
    on imbalanced plans with replicas — 2-device (1x2) and 8-device (2x4)
    meshes, profile-seed variation on the 8-device case — and the decode
    StepFn compiles exactly once per engine across generate + replan."""
    cases = [(backend, 1, 2, 2, 2.0, 1),
             (backend, 2, 4, 4, 2.0, 1)]
    if backend == "slot":  # property-style variation (kept off the slow arm)
        cases.append((backend, 2, 4, 4, 3.0, 7))
    results = _run_subproc(cases)
    gen = [r for r in results if r["case"][0] == backend]
    cont = [r for r in results if r["case"][0] == "continuous"]
    assert any(r["replicas"] for r in gen), "no case exercised replicas"
    for r in gen:
        assert r["tokens_equal"], r
        assert r["lengths_equal"], r
        assert r["state_lengths_equal"], r
        assert r["logits_close"], r
        assert r["tokens_equal_after_replan"], r
        assert r["decode_traces_after_gen"] == 1, r
        assert r["decode_traces_after_replan"] == 1, r
    for r in cont:
        assert r["tokens_equal"], r
        assert r["decode_traces"] == 1, r
