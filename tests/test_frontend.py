"""Frontend subsystem (DESIGN.md §13): DRR fair-queue properties
(starvation-freedom, token conservation), the SLO admission decision
table, the FCFS baseline queue, cancellation pool conservation on the
paged backend, and the synchronous frontend pump end to end."""
import math

import numpy as np
import pytest

from repro.api import (
    CompressionConfig,
    Engine,
    EngineConfig,
    PagingConfig,
    PlannerConfig,
    SchedulerConfig,
    synthesize_requests,
)
from repro.frontend import (
    AdmissionController,
    DeficitRoundRobin,
    FCFSController,
    FrontendConfig,
    FrontendScheduler,
    SingleQueue,
    run_frontend_trace,
)
from repro.frontend import queues as q
from repro.frontend.admission import ADMIT, DEGRADE, QUEUE, REJECT
from repro.serving.request import Request, RequestState
from tests._hypothesis_compat import given, settings, st

ARCH = "minitron-8b"


def _cfg(backend="slot", rows=2, n_blocks=0, block_size=8, **sched_kw):
    scfg = dict(max_rows=rows, enable_replan=False)
    scfg.update(sched_kw)
    return EngineConfig.smoke(
        ARCH, n_shards=4, max_seq_len=64,
        compression=CompressionConfig(policy="ada_snapkv", budget=12,
                                      alpha_max=2.0, obs_window=8, sink=2,
                                      decode_margin=8),
        planner=PlannerConfig(mode="fairkv_dp", extra_copies=4,
                              batch_cap=rows),
        scheduler=SchedulerConfig(**scfg),
        cache_backend=backend,
        paging=PagingConfig(block_size=block_size, n_blocks=n_blocks))


@pytest.fixture(scope="module")
def shared_params():
    """Build once; every engine in this module reuses the params (and the
    jit cache, since shapes match)."""
    cfg = _cfg("slot")
    return cfg, Engine.build(cfg).params


# ---------------------------------------------------------------------------
# DRR properties (pure queue, no engine)
# ---------------------------------------------------------------------------


@settings(max_examples=12)
@given(st.integers(1, 32), st.integers(1, 4),
       st.lists(st.integers(1, 50), min_size=1, max_size=6),
       st.integers(2, 30))
def test_drr_never_starves_backlogged_tenant(quantum, cap_mult, victim_costs,
                                             n_aggressor):
    """A victim tenant competing with an aggressor flooding cheap requests
    still admits each head item within ceil(cost/quantum) ticks of it
    reaching the head (costs clamped to cap, offers always accepted)."""
    cap = quantum * cap_mult
    drr = DeficitRoundRobin(quantum, cap)
    victim_costs = [min(c, cap) for c in victim_costs]
    for i, c in enumerate(victim_costs):
        drr.push("victim", ("v", i, c))
    for i in range(n_aggressor):
        drr.push("aggressor", ("a", i, 1))

    def cost(item):
        return item[2]

    ticks_waited = 0
    while drr.backlog("victim"):
        head = drr.items("victim")[0]
        admitted = drr.tick(cost, lambda t, item: q.ADMITTED)
        ticks_waited += 1
        if any(i == head for _, i in admitted):
            bound = math.ceil(head[2] / quantum)
            assert ticks_waited <= bound, (
                f"head {head} took {ticks_waited} ticks, bound {bound}")
            ticks_waited = 0
        # refill aggressor pressure so the victim is never alone
        drr.push("aggressor", ("a", 10_000 + ticks_waited, 1))
        assert ticks_waited <= math.ceil(cap / quantum) + 1, "starved"


@settings(max_examples=12)
@given(st.integers(1, 24), st.integers(1, 4),
       st.lists(st.integers(1, 60), min_size=1, max_size=10),
       st.integers(0, 2**31 - 1))
def test_drr_token_conservation(quantum, cap_mult, costs, seed):
    """For every tenant after every tick:
    ``deficit == refilled - charged - forfeited`` exactly, and
    ``0 <= deficit <= cap`` — across mixed admit/reject/block/stall
    verdicts, mid-stream pushes, and *oversized* costs above the cap
    (admitted by draining the banked deficit)."""
    cap = quantum * cap_mult
    drr = DeficitRoundRobin(quantum, cap)
    rng = np.random.default_rng(seed)
    tenants = ["a", "b", "c"]
    for i, c in enumerate(costs):
        drr.push(tenants[i % len(tenants)], (i, c))

    verdicts = (q.ADMITTED, q.REJECTED, q.BLOCKED, q.STALL)

    def offer(tenant, item):
        return verdicts[int(rng.integers(len(verdicts)))]

    for tick in range(12):
        drr.tick(lambda item: item[1], offer)
        if tick == 4:  # mid-stream arrival exercises re-backlogging
            drr.push(tenants[tick % len(tenants)], (1000 + tick, quantum))
        for t in tenants:
            refilled, charged, forfeited = drr.counters(t)
            assert drr.deficit(t) == pytest.approx(
                refilled - charged - forfeited)
            assert 0.0 <= drr.deficit(t) <= cap + 1e-9


def test_drr_oversized_item_reaches_controller():
    """A head item priced above the banked-deficit cap can never be
    covered by quota — it must still be offered once the deficit saturates
    at the cap (charging the whole bank), not head-of-line block its
    tenant forever (regression: clients of such requests hung)."""
    drr = DeficitRoundRobin(4, 8)
    drr.push("t", ("big", 100))  # cost 100 >> cap 8
    drr.push("t", ("small", 2))
    offered, admitted = [], []
    for _ in range(math.ceil(8 / 4) + 1):
        admitted += drr.tick(
            lambda item: item[1],
            lambda t, item: (offered.append(item), q.ADMITTED)[1])
    assert ("big", 100) in offered, "oversized item never reached offer()"
    assert [i for _, i in admitted] == [("big", 100), ("small", 2)]
    refilled, charged, forfeited = drr.counters("t")
    assert drr.deficit("t") == pytest.approx(refilled - charged - forfeited)
    assert 0.0 <= drr.deficit("t") <= 8.0


def test_drr_validates_config():
    with pytest.raises(ValueError, match="quantum"):
        DeficitRoundRobin(0, 10)
    with pytest.raises(ValueError, match="cap"):
        DeficitRoundRobin(16, 8)


def test_drr_backlog_bound_and_remove():
    drr = DeficitRoundRobin(4, 8, max_queue_per_tenant=2)
    assert drr.push("t", "x") and drr.push("t", "y")
    assert not drr.push("t", "z"), "backlog bound must refuse"
    assert drr.remove("t", "x")
    assert not drr.remove("t", "x"), "double-remove must be False"
    assert drr.items("t") == ["y"]


def test_single_queue_is_strict_fcfs():
    """The baseline queue admits in global arrival order regardless of
    tenant, and a blocked head blocks everyone behind it."""
    sq = SingleQueue()
    for i, tenant in enumerate(["a", "b", "a", "c"]):
        sq.push(tenant, i)
    admitted = sq.tick(lambda i: 1.0,
                       lambda t, i: q.ADMITTED if i < 2 else q.BLOCKED)
    assert [i for _, i in admitted] == [0, 1]
    assert sq.items() == [2, 3], "head-of-line block keeps order intact"
    assert sq.deficit("a") == 0.0  # quota-free surface


# ---------------------------------------------------------------------------
# admission decision table (stub scheduler, no engine)
# ---------------------------------------------------------------------------


class _StubBackend:
    def __init__(self, never=None, fits_upto=10_000):
        self.never = never
        self.fits_upto = fits_upto  # admissible iff max_new_tokens <= this

    def never_fits(self, req):
        return self.never

    def admissible(self, state, req, pending=()):
        return req.max_new_tokens <= self.fits_upto

    def request_cost(self, req):
        return req.prompt_len + req.max_new_tokens


class _StubSched:
    def __init__(self, free=1, step_idx=0, backend=None):
        self.freelist = list(range(free))
        self.step_idx = step_idx
        self.backend = backend if backend is not None else _StubBackend()
        self.state = None


def _req(priority=1, arrival=0, gen=16, deadline_s=None, arrival_time=None):
    return Request(req_id=0, prompt=np.zeros(4, np.int32),
                   arrival_step=arrival, max_new_tokens=gen,
                   priority=priority, deadline_s=deadline_s,
                   arrival_time=arrival_time)


def test_admission_admit_when_fits():
    d = AdmissionController(FrontendConfig()).decide(_StubSched(), _req())
    assert d.action == ADMIT


def test_admission_queue_blocks_globally_when_no_row():
    d = AdmissionController(FrontendConfig()).decide(
        _StubSched(free=0), _req())
    assert d.action == QUEUE and d.global_block and not d.preempt


def test_admission_preempt_arms_for_urgent_class():
    cfg = FrontendConfig()
    cls = cfg.class_for(0)  # interactive: preempt_below
    assert cls.preempt_below
    sched = _StubSched(free=0, step_idx=cls.ttft_slo_steps // 2)
    d = AdmissionController(cfg).decide(sched, _req(priority=0))
    assert d.action == QUEUE and d.preempt
    young = AdmissionController(cfg).decide(
        _StubSched(free=0), _req(priority=0))
    assert not young.preempt, "young requests must not thrash rows"


def test_admission_sheds_blown_slo():
    cfg = FrontendConfig()
    waited = cfg.class_for(0).shed_after_steps + 1
    d = AdmissionController(cfg).decide(
        _StubSched(step_idx=waited), _req(priority=0))
    assert d.action == REJECT and d.reason == "slo_blown"


def test_admission_rejects_exceeded_deadline():
    d = AdmissionController(FrontendConfig()).decide(
        _StubSched(), _req(deadline_s=0.0, arrival_time=0.0))
    assert d.action == REJECT and d.reason == "deadline_exceeded"


def test_admission_degrades_under_pressure_to_largest_fit():
    """Full ask inadmissible, backend fits asks <= 6, batch class floor 4:
    once the SLO clock is half-spent the controller offers exactly 6."""
    cfg = FrontendConfig()
    cls = cfg.class_for(2)  # batch: degrade_floor 4
    assert cls.degrade_floor == 4
    sched = _StubSched(backend=_StubBackend(fits_upto=6),
                       step_idx=cls.ttft_slo_steps // 2)
    d = AdmissionController(cfg).decide(sched, _req(priority=2, gen=16))
    assert d.action == DEGRADE and d.degrade_to == 6
    # a young request prefers waiting for its full ask
    young = AdmissionController(cfg).decide(
        _StubSched(backend=_StubBackend(fits_upto=6)), _req(priority=2))
    assert young.action == QUEUE


def test_admission_never_fits_degrades_or_rejects():
    cfg = FrontendConfig()
    sched = _StubSched(backend=_StubBackend(never="too long"))
    d = AdmissionController(cfg).decide(sched, _req(priority=1))
    assert d.action == REJECT and "never_fits" in d.reason
    # batch class has a floor; the stub still reports never_fits for the
    # floor probe, so the degrade escape must NOT fire
    d2 = AdmissionController(cfg).decide(sched, _req(priority=2))
    assert d2.action == REJECT


def test_fcfs_controller_is_naive():
    cfg = FrontendConfig(admission="fcfs")
    c = FCFSController(cfg)
    assert c.decide(_StubSched(), _req()).action == ADMIT
    d = c.decide(_StubSched(free=0), _req())
    assert d.action == QUEUE and d.global_block
    assert c.decide(_StubSched(backend=_StubBackend(never="x")),
                    _req()).action == REJECT


# ---------------------------------------------------------------------------
# cancellation conserves the paged pool (engine-level regression)
# ---------------------------------------------------------------------------


def test_cancel_returns_blocks_to_pool(shared_params):
    """Cancel a mid-decode request on the paged backend: its blocks return
    to the pool immediately (admitting a new request proves capacity), the
    allocator invariants hold, and full drain-out ends at zero in-use."""
    _, params = shared_params
    eng = Engine.build(_cfg("paged", rows=2), params=params)
    vocab = eng.cfg.model.vocab_size
    reqs = synthesize_requests(3, 5.0, vocab, min_prompt=12, max_prompt=20,
                               max_new_tokens=6, seed=3)
    for r in reqs:
        eng.submit(r)
    sched = eng.scheduler
    while not sched.active:
        eng.step()
    victim_id = next(iter(sched.active.values())).req_id
    pool = sched.backend.pool
    in_use_before = pool.blocks_in_use()
    assert in_use_before > 0
    assert eng.cancel(victim_id)
    assert pool.blocks_in_use() < in_use_before, "blocks must free now"
    pool.check_invariants()
    victim = next(r for r in sched.finished if r.req_id == victim_id)
    assert victim.state is RequestState.CANCELLED
    assert not eng.cancel(victim_id), "already-terminal id must be False"
    assert not eng.cancel(10_000), "unknown id must be False"
    # freed capacity is immediately reusable
    extra = synthesize_requests(1, 5.0, vocab, min_prompt=12, max_prompt=20,
                                max_new_tokens=6, seed=9)[0]
    extra.req_id = 50
    eng.submit(extra)
    for _ in range(200):
        if len(sched.finished) == 4:
            break
        eng.step()
    assert len(sched.finished) == 4
    assert all(r.is_finished for r in sched.finished)
    assert pool.blocks_in_use() == 0
    pool.check_invariants()
    assert sched.n_cancellations == 1


# ---------------------------------------------------------------------------
# the synchronous frontend pump end to end
# ---------------------------------------------------------------------------


def _frontend(eng, **fe_kw):
    fe_kw.setdefault("quantum_tokens", 64)
    fe_kw.setdefault("quota_cap_tokens", 512)
    return FrontendScheduler(eng._ensure_scheduler(), FrontendConfig(**fe_kw))


def _tenant_trace(vocab, n=8, gen=4, seed=11):
    return synthesize_requests(
        n, 2.0, vocab, min_prompt=8, max_prompt=16, max_new_tokens=gen,
        seed=seed, tenant_mix={"fast": 1.0, "slow": 1.0},
        tenant_priorities={"fast": 0, "slow": 2})


def test_frontend_trace_slo_mode(shared_params):
    cfg, params = shared_params
    eng = Engine.build(cfg, params=params)
    fe = _frontend(eng)
    out = run_frontend_trace(fe, _tenant_trace(cfg.model.vocab_size),
                             max_steps=400)
    assert out["converged"] and out["finished"] == out["total"]
    assert out["admission"] == "slo"
    assert out["generated_tokens"] >= out["goodput_tokens"] > 0
    assert set(out["tenants"]) == {"fast", "slow"}
    assert out["slo_attained"] + out["slo_missed"] == out["total"]
    # §13 observability contract on the engine's own registry
    prom = eng.metrics_prometheus()
    for family in ("slo_attained_total", "slo_missed_total",
                   "goodput_tokens_total", "frontend_admission_total"):
        assert f"{family}{{" in prom, family
    assert 'tenant="fast"' in prom


def test_frontend_drain_sheds_queued_finishes_live(shared_params):
    cfg, params = shared_params
    eng = Engine.build(cfg, params=params)
    fe = _frontend(eng)
    reqs = _tenant_trace(cfg.model.vocab_size, n=6, seed=13)
    for r in reqs:
        r.arrival_step = 0
        fe.submit(r)
    fe.pump()  # admits up to the 2 free rows, rest stays tenant-queued
    live = len(fe.sched.active)
    assert live > 0 and len(fe.queue) > 0
    fe.drain()
    assert len(fe.queue) == 0, "queued requests shed at drain"
    for _ in range(200):
        if fe.idle:
            break
        fe.pump()
    assert fe.idle
    assert len(fe.finished) == len(reqs)
    shed = [r for r in fe.finished if fe.reject_reasons.get(r.req_id)]
    assert all(fe.reject_reasons[r.req_id] == "draining" for r in shed)
    done = [r for r in fe.finished if r.state is RequestState.FINISHED]
    assert len(done) == live, "live rows decode to completion"
    # post-drain ingress is refused outright
    late = _tenant_trace(cfg.model.vocab_size, n=1, seed=17)[0]
    late.req_id = 99
    assert not fe.submit(late)
    assert fe.reject_reasons[99] == "draining"


def test_frontend_backlog_bound_and_cancel(shared_params):
    cfg, params = shared_params
    eng = Engine.build(cfg, params=params)
    fe = _frontend(eng, max_queue_per_tenant=1, quantum_tokens=128)
    reqs = _tenant_trace(cfg.model.vocab_size, n=4, seed=19)
    for i, r in enumerate(reqs):
        r.req_id = i
        r.tenant, r.priority = "fast", 0
    # fill both rows (pump between submissions: the tenant queue is
    # bounded at one waiter, so admissions must drain it first)
    fe.submit(reqs[0])
    fe.pump()
    fe.submit(reqs[1])
    fe.pump()
    assert len(fe.sched.active) == 2
    assert fe.submit(reqs[2])  # queued (backlog 1/1)
    assert not fe.submit(reqs[3]), "tenant backlog bound must refuse"
    assert fe.reject_reasons[3] == "tenant_backlog_full"
    # cancel the queued one before admission: terminal, engine never sees it
    assert fe.cancel(2)
    assert fe.reject_reasons[2] == "cancelled"
    assert len(fe.queue) == 0
    assert fe.cancel(2) is False


def test_frontend_serves_requests_costing_more_than_quota_cap(shared_params):
    """A quota cap far below every request's projected cost must not hang
    the trace: the DRR's saturation path still surfaces each request to
    the admission controller, which admits (or sheds) it (regression:
    such requests were never offered, admitted, or rejected)."""
    cfg, params = shared_params
    eng = Engine.build(cfg, params=params)
    fe = _frontend(eng, quantum_tokens=1, quota_cap_tokens=1)
    assert all(
        fe.sched.backend.request_cost(r) > fe.queue.cap
        for r in _tenant_trace(cfg.model.vocab_size, n=4, seed=23)), \
        "precondition: every request must outprice the quota cap"
    out = run_frontend_trace(fe, _tenant_trace(cfg.model.vocab_size, n=4,
                                               seed=23), max_steps=400)
    assert out["converged"] and out["finished"] == out["total"]


def test_frontend_quota_calibrated_to_backend_units(shared_params):
    """FrontendConfig quotas are denominated in request tokens; the DRR
    charges backend cost units (L·H-scaled).  The constructor must scale
    the knobs so a default-sized cap covers a typical request's cost."""
    cfg, params = shared_params
    eng = Engine.build(cfg, params=params)
    fe = _frontend(eng)  # quantum 64 / cap 512 request tokens
    req = _tenant_trace(cfg.model.vocab_size, n=1)[0]  # <= 20 tokens
    assert fe.sched.backend.request_cost(req) <= fe.queue.cap, (
        "a ~20-token request must fit a 512-token quota cap after "
        "unit calibration")


def test_frontend_submit_clamps_priority_to_configured_classes(
        shared_params):
    """A client-supplied out-of-range priority (e.g. -5) would outrank
    every configured class and arm preemption; submit clamps it to the
    configured ladder on both ends."""
    cfg, params = shared_params
    eng = Engine.build(cfg, params=params)
    fe = _frontend(eng)
    lo = _tenant_trace(cfg.model.vocab_size, n=2, seed=29)
    lo[0].priority, lo[1].priority = -5, 99
    for r in lo:
        fe.submit(r)
    assert lo[0].priority == 0, "clamped to the most urgent class"
    assert lo[1].priority == 2, "clamped to the least urgent class"


def test_backend_admissible_charges_pending(shared_params):
    """Several admissions in one pump tick are checked against the same
    un-spliced state; the ``pending`` charge must make the joint check
    fail where each individual one passes."""
    cfg, params = shared_params
    eng = Engine.build(cfg, params=params)
    sched = eng._ensure_scheduler()
    b = sched.backend
    req = Request(req_id=0, prompt=np.zeros(16, np.int32), max_new_tokens=4)
    old = b.max_live_tokens
    try:
        b.max_live_tokens = int(b.request_cost(req) * 1.5)  # one fits
        assert b.admissible(sched.state, req)
        assert not b.admissible(sched.state, req, pending=[req])
    finally:
        b.max_live_tokens = old
