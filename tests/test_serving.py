"""Serving-engine system tests: the two FairKV runtime invariants
(plan-invariance of logits; decode == train-forward without compression),
compression-policy behaviour, and cache mechanics."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cache.slot_cache import PlanArrays, init_cache, append_token, ring_write_index
from repro.compression.base import CompressionConfig
from repro.compression.policies import BALANCED, IMBALANCED, POLICIES, select
from repro.configs import get_smoke_config
from repro.core import PlannerConfig, build_plan, synthetic_profile
from repro.models import forward_train, init_params
from repro.serving import decode_step, prefill, slotify_params

FAST_ARCHS = ["minitron-8b", "gemma2-9b", "granite-moe-1b-a400m",
              "hymba-1.5b", "mamba2-1.3b", "whisper-small"]


def _setup(arch, policy="none", budget=64, n_shards=4, T=24, B=2, extra=6):
    cfg = get_smoke_config(arch)
    if cfg.moe.num_experts:
        cfg = cfg.with_overrides(moe=dataclasses.replace(
            cfg.moe, capacity_factor=float(cfg.moe.num_experts)))
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32,
                         max_seq_len=128)
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T + extra)),
                         jnp.int32)
    batch = {"tokens": tokens[:, :T]}
    if cfg.is_vlm:
        batch["image_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.num_image_tokens, cfg.d_model)) * 0.1,
            jnp.float32)
    if cfg.is_encoder_decoder:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq_len, cfg.d_model)) * 0.1,
            jnp.float32)
    full_T = T + (cfg.num_image_tokens if cfg.is_vlm else 0)
    ccfg = CompressionConfig(policy=policy, budget=budget, alpha_max=1.0,
                             obs_window=8, sink=2, decode_margin=8,
                             capacity=full_T if policy == "none" else 0)
    return cfg, params, batch, tokens, ccfg


def _run(cfg, params, batch, tokens, ccfg, mode, ch, n_shards=4, steps=5):
    T = batch["tokens"].shape[1]
    if cfg.attention_free:
        plan = build_plan(np.ones((cfg.n_layers, 1)), 1,
                          PlannerConfig(mode="sha", slots_per_shard=1))
    else:
        prof = synthetic_profile(cfg.n_layers, cfg.n_kv_heads, budget=64,
                                 skew=1.0, seed=1)
        plan = build_plan(prof, n_shards,
                          PlannerConfig(mode=mode, extra_copies=ch))
    pa = PlanArrays.from_plan(plan)
    sp = slotify_params(params, plan, cfg)
    state, logits0, lens = prefill(sp, batch, cfg, pa, ccfg)
    out = [logits0]
    for t in range(steps):
        state, lg = decode_step(sp, state, cfg, pa, ccfg,
                                tokens=tokens[:, T + t])
        out.append(lg)
    return jnp.stack(out, 1), lens


@pytest.mark.parametrize("arch", FAST_ARCHS)
def test_plan_invariance(arch):
    """SHA and FairKV-DP plans must produce identical logits: the plan is a
    layout, not a math change."""
    cfg, params, batch, tokens, ccfg = _setup(arch)
    a, _ = _run(cfg, params, batch, tokens, ccfg, "sha", 0)
    if cfg.attention_free:
        pytest.skip("attention-free: single trivial plan")
    b, _ = _run(cfg, params, batch, tokens, ccfg, "fairkv_dp", 6)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)


@pytest.mark.parametrize("arch", FAST_ARCHS)
def test_decode_matches_train_forward(arch):
    """With no compression, serve logits == train logits position-wise."""
    cfg, params, batch, tokens, ccfg = _setup(arch)
    serve, _ = _run(cfg, params, batch, tokens, ccfg, "sha", 0)
    T = batch["tokens"].shape[1]
    full = dict(batch)
    full["tokens"] = tokens[:, :T + 5]
    gold, _ = forward_train(params, full, cfg, remat=False)
    gold = gold[:, T - 1:T + 5]
    rel = float(jnp.abs(serve - gold).max() / jnp.abs(gold).max())
    assert rel < 2e-3, rel


def test_compressed_decode_close_to_uncompressed():
    """Ada-SnapKV at half budget should still approximate the full-cache
    logits (sanity, not a quality benchmark)."""
    cfg, params, batch, tokens, _ = _setup("minitron-8b", T=48)
    ccfg_full = CompressionConfig(policy="none", budget=48, capacity=48,
                                  obs_window=8, sink=2, decode_margin=8)
    ccfg_ada = CompressionConfig(policy="ada_snapkv", budget=24, alpha_max=2.0,
                                 obs_window=8, sink=2, decode_margin=8)
    full, _ = _run(cfg, params, batch, tokens, ccfg_full, "sha", 0)
    ada, lens = _run(cfg, params, batch, tokens, ccfg_ada, "fairkv_dp", 4)
    # imbalanced budgets realized
    assert int(lens.max()) > int(lens.min())
    # sanity only: random-weight attention is diffuse, so fidelity at half
    # budget is far below a trained model's; the quality ordering across
    # policies is measured by benchmarks/table3_quality_proxy.py
    cos = float((full * ada).sum()
                / (jnp.linalg.norm(full) * jnp.linalg.norm(ada)))
    assert np.isfinite(cos) and cos > 0.5, cos


# ---------------------------------------------------------------------------
# compression policies
# ---------------------------------------------------------------------------


def _scores(B=2, H=4, T=64, seed=0):
    rng = np.random.default_rng(seed)
    base = rng.exponential(1.0, size=(B, H, T))
    return jnp.asarray(base, jnp.float32)


@pytest.mark.parametrize("policy", sorted(POLICIES))
def test_policy_shapes_and_bounds(policy):
    cfg = CompressionConfig(policy=policy, budget=16, alpha_max=2.0,
                            obs_window=4, sink=2, decode_margin=4)
    idx, keep = select(policy, _scores(), cfg, layer_idx=1, n_layers=4)
    B, H, T = 2, 4, 64
    cap = cfg.static_capacity()
    assert idx.shape == (B, H, min(cap, T) if cap <= T else cap)
    assert keep.shape == (B, H)
    assert int(keep.max()) <= cap
    assert int(idx.max()) < T and int(idx.min()) >= 0


def test_balanced_policies_uniform_budgets():
    for policy in sorted(BALANCED):
        cfg = CompressionConfig(policy=policy, budget=16, obs_window=4, sink=2)
        _, keep = select(policy, _scores(), cfg, 0, 4)
        per_head = np.asarray(keep)
        assert (per_head == per_head[0, 0]).all(), policy


def test_imbalanced_policies_nonuniform_budgets():
    scores = _scores(seed=3)
    # concentrate mass on head 0 to force imbalance
    scores = scores.at[:, 0].mul(8.0)
    for policy in sorted(IMBALANCED):
        cfg = CompressionConfig(policy=policy, budget=16, alpha_max=2.0,
                                obs_window=4, sink=2)
        _, keep = select(policy, scores, cfg, 0, 4)
        per_head = np.asarray(keep)
        assert per_head.std() > 0, policy
        # head 0 gets more than the mean (it is the heavy head)
        assert per_head[:, 0].mean() > per_head.mean()


def test_pyramid_budgets_decay_with_depth():
    cfg = CompressionConfig(policy="pyramidkv", budget=32, obs_window=4, sink=2)
    keeps = []
    for layer in range(4):
        _, keep = select("pyramidkv", _scores(), cfg, layer, 4)
        keeps.append(int(np.asarray(keep)[0, 0]))
    assert keeps[0] > keeps[-1], keeps


def test_ada_snapkv_conserves_pool():
    """Ada-KV redistributes the layer pool: Σ budgets ≈ H·budget."""
    cfg = CompressionConfig(policy="ada_snapkv", budget=16, alpha_max=4.0,
                            obs_window=2, sink=1, decode_margin=0)
    scores = _scores(B=1, H=4, T=256, seed=2)
    _, keep = select("ada_snapkv", scores, cfg, 0, 1)
    total = int(np.asarray(keep).sum())
    assert abs(total - 4 * 16) <= 16, total  # ties/floors allow slack


# ---------------------------------------------------------------------------
# slot cache mechanics
# ---------------------------------------------------------------------------


def test_ring_write_index_cycles_in_tail():
    lengths = jnp.asarray([[10]], jnp.int32)
    cap, ring = 10, 4
    idxs = [int(ring_write_index(lengths, jnp.int32(t), cap, ring)[0, 0])
            for t in range(8)]
    assert all(cap - ring <= i < cap for i in idxs)
    assert len(set(idxs)) == ring  # visits the whole ring


def test_append_token_ownership():
    cache = init_cache(n_layers=1, n_slots=4, batch=4, capacity=8,
                       head_dim=4, dtype=jnp.float32)
    prof = np.ones((1, 2))
    plan = build_plan(prof, 4, PlannerConfig(mode="sha", slots_per_shard=1))
    pa = PlanArrays.from_plan(plan)
    own = pa.owner_mask(0, 4)
    k_new = jnp.ones((4, 4, 4))
    cache = append_token(cache, 0, k_new, k_new, own, jnp.int32(0), ring=2)
    lens = np.asarray(cache.lengths[0])
    assert (lens == np.asarray(own).astype(np.int32)).all()
