"""Shared fixtures.  NOTE: no XLA device-count forcing here — smoke tests and
benches see the real single CPU device; only launch/dryrun.py forces 512."""
import jax
import numpy as np
import pytest


@pytest.fixture(scope="session", autouse=True)
def _x64_off():
    jax.config.update("jax_enable_x64", False)


@pytest.fixture()
def rng():
    return np.random.default_rng(0)
