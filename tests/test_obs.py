"""Observability subsystem (DESIGN.md §12): registry semantics, trace
export validity, engine integration, local↔mesh metrics parity, the
zero-recompile invariant as an asserted metric, and the disabled path.

The mesh parity test runs in a subprocess (the fake-device count must be
set before the first jax import, like tests/test_executor.py): the same
continuous trace drives a local and a mesh engine, and every deterministic
counter/gauge family must agree between the two registries.
"""
import json
import subprocess
import sys
import time

import numpy as np
import pytest

from repro.api import (
    CompressionConfig,
    Engine,
    EngineConfig,
    PagingConfig,
    PlannerConfig,
    SchedulerConfig,
    synthesize_requests,
)
from repro.obs import (
    NULL_OBS,
    MetricsRegistry,
    Obs,
    ObsConfig,
    TraceBuffer,
)

ARCH = "minitron-8b"


# ---------------------------------------------------------------------------
# registry semantics
# ---------------------------------------------------------------------------


def test_counter_labels_and_total():
    reg = MetricsRegistry()
    c = reg.counter("req_total", help="requests")
    c.inc()
    c.inc(2, tenant="a")
    c.inc(3, tenant="b")
    assert c.value() == 1.0
    assert c.value(tenant="a") == 2.0
    assert c.total() == 6.0
    assert reg.counter_value("req_total", tenant="b") == 3.0
    assert reg.counter_value("never_touched") == 0.0
    with pytest.raises(ValueError, match="decrease"):
        c.inc(-1)


def test_counter_preregister_zero_series():
    reg = MetricsRegistry()
    reg.counter("outcomes").inc(0, outcome="accepted")
    snap = reg.snapshot()["outcomes"]["series"]
    assert snap == [{"labels": {"outcome": "accepted"}, "value": 0.0}]


def test_gauge_last_write_wins():
    reg = MetricsRegistry()
    g = reg.gauge("load")
    g.set(3.0, shard="0")
    g.set(7.0, shard="0")
    assert g.value(shard="0") == 7.0
    assert g.value(shard="9", default=-1.0) == -1.0


def test_registry_memoizes_and_rejects_kind_mismatch():
    reg = MetricsRegistry()
    assert reg.counter("x") is reg.counter("x")
    with pytest.raises(TypeError, match="registered as counter"):
        reg.gauge("x")


def test_histogram_buckets_cumulative():
    reg = MetricsRegistry()
    h = reg.histogram("lat", buckets=(0.1, 1.0, 10.0))
    for v in (0.05, 0.5, 0.5, 5.0, 50.0):
        h.observe(v)
    s = reg.snapshot()["lat"]["series"][0]
    assert s["count"] == 5
    assert s["buckets"] == {"0.1": 1, "1": 3, "10": 4, "+Inf": 5}
    assert s["sum"] == pytest.approx(56.05)
    assert h.mean() == pytest.approx(56.05 / 5)
    # boundary lands in the bucket whose upper bound it equals
    h2 = reg.histogram("edge", buckets=(1.0, 2.0))
    h2.observe(1.0)
    assert reg.snapshot()["edge"]["series"][0]["buckets"]["1"] == 1


def test_histogram_rejects_bad_buckets():
    reg = MetricsRegistry()
    with pytest.raises(ValueError, match="strictly"):
        reg.histogram("bad", buckets=(1.0, 1.0))
    with pytest.raises(ValueError, match="strictly"):
        reg.histogram("bad2", buckets=())


def test_snapshot_deterministic():
    def build():
        reg = MetricsRegistry()
        reg.gauge("b").set(1, z="1", a="2")
        reg.counter("a").inc(5, shard="3")
        reg.histogram("c", buckets=(1.0,)).observe(0.5)
        return reg

    r1, r2 = build(), build()
    assert r1.snapshot() == r2.snapshot()
    assert r1.to_prometheus() == r2.to_prometheus()
    assert r1.to_jsonl() == r2.to_jsonl()
    assert list(r1.snapshot()) == sorted(r1.snapshot())


def test_prometheus_exposition_format():
    reg = MetricsRegistry()
    reg.counter("req_total", help="all requests").inc(2, shard="0")
    reg.gauge("depth").set(3.5)
    reg.histogram("lat", buckets=(0.5, 1.0)).observe(0.2)
    text = reg.to_prometheus()
    lines = text.splitlines()
    assert "# TYPE req_total counter" in lines
    assert "# HELP req_total all requests" in lines
    assert 'req_total{shard="0"} 2' in lines
    assert "depth 3.5" in lines
    assert 'lat_bucket{le="0.5"} 1' in lines
    assert 'lat_bucket{le="+Inf"} 1' in lines
    assert "lat_sum 0.2" in lines
    assert "lat_count 1" in lines
    # every non-comment line is "<name or name{labels}> <value>"
    for ln in lines:
        if ln.startswith("#"):
            continue
        body, val = ln.rsplit(" ", 1)
        float(val)
        assert body and not body.startswith("{")


def test_prometheus_escapes_label_values():
    reg = MetricsRegistry()
    reg.counter("c").inc(1, path='a"b\\c')
    text = reg.to_prometheus()
    assert 'path="a\\"b\\\\c"' in text


def test_jsonl_parses_per_line():
    reg = MetricsRegistry()
    reg.counter("a").inc(1, k="v")
    reg.histogram("h", buckets=(1.0,)).observe(2.0)
    lines = reg.to_jsonl().strip().splitlines()
    assert len(lines) == 2
    recs = [json.loads(ln) for ln in lines]
    assert {r["name"] for r in recs} == {"a", "h"}


# ---------------------------------------------------------------------------
# trace buffer
# ---------------------------------------------------------------------------


def test_trace_chrome_schema():
    tr = TraceBuffer(capacity=16)
    with tr.span("step", rows=3):
        pass
    tr.instant("compile", kind="decode")
    tr.complete("external", time.perf_counter(), 0.25)
    doc = json.loads(tr.export_json())
    evs = doc["traceEvents"]
    assert len(evs) == 3
    for ev in evs:
        assert ev["ph"] in ("X", "i")
        assert isinstance(ev["ts"], float) and ev["ts"] >= 0
        assert "name" in ev and "pid" in ev and "tid" in ev
        if ev["ph"] == "X":
            assert ev["dur"] >= 0
    assert evs[0]["args"] == {"rows": 3}
    assert evs[2]["dur"] == pytest.approx(0.25e6, rel=0.05)


def test_trace_ring_is_bounded():
    tr = TraceBuffer(capacity=4)
    for i in range(10):
        tr.instant("e", i=i)
    evs = json.loads(tr.export_json())["traceEvents"]
    assert [e["args"]["i"] for e in evs] == [6, 7, 8, 9]


def test_trace_span_records_exception():
    tr = TraceBuffer()
    with pytest.raises(RuntimeError):
        with tr.span("boom"):
            raise RuntimeError("x")
    (ev,) = tr.to_chrome()["traceEvents"]
    assert ev["args"]["error"] == "RuntimeError"


# ---------------------------------------------------------------------------
# Obs handle + disabled path
# ---------------------------------------------------------------------------


def test_obs_config_validation():
    with pytest.raises(ValueError, match="trace_capacity"):
        ObsConfig(trace_capacity=0)
    with pytest.raises(ValueError, match="print_every"):
        ObsConfig(print_every=-1)


def test_obs_build_disabled_is_null():
    obs = Obs.build(ObsConfig(enabled=False))
    assert not obs.enabled
    assert obs.metrics is NULL_OBS.metrics
    assert obs.trace is NULL_OBS.trace


def test_null_obs_noops():
    m, tr = NULL_OBS.metrics, NULL_OBS.trace
    m.counter("a", help="h").inc(5, k="v")
    m.gauge("b").set(1.0)
    m.histogram("c").observe(0.5)
    with tr.span("s"):
        tr.instant("i")
    assert m.snapshot() == {}
    assert m.to_prometheus() == ""
    assert m.counter_value("a", k="v") == 0.0
    assert json.loads(tr.export_json())["traceEvents"] == []


def test_null_obs_overhead_smoke():
    """The disabled path must stay cheap: 100k no-op observations in well
    under a second (loose bound — this guards against accidentally putting
    real work on the disabled path, not against CI jitter)."""
    m = NULL_OBS.metrics
    c = m.counter("x")
    t0 = time.perf_counter()
    for _ in range(100_000):
        c.inc(1.0, shard="0")
    assert time.perf_counter() - t0 < 1.0


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------


def _ecfg(**kw):
    base = dict(
        n_shards=4, max_seq_len=48,
        compression=CompressionConfig(policy="ada_snapkv", budget=16,
                                      alpha_max=2.0, obs_window=8, sink=2,
                                      decode_margin=8),
        planner=PlannerConfig(mode="fairkv_dp", extra_copies=4, batch_cap=4),
        scheduler=SchedulerConfig(max_rows=4, enable_replan=False))
    base.update(kw)
    return EngineConfig.smoke(ARCH, **base)


REQUIRED_FAMILIES = {
    "sched_admissions_total", "sched_retirements_total",
    "sched_replans_total", "shard_load_tokens", "shard_projected_load",
    "sched_imbalance", "sched_active_rows", "sched_queue_depth",
    "ttft_s", "itl_s", "e2e_s", "stepfn_wall_s", "stepfn_compiles_total",
}


def _drive(eng, n=5, seed=2, gen=4):
    reqs = synthesize_requests(n, 0.6, eng.cfg.model.vocab_size,
                               min_prompt=10, max_prompt=18,
                               max_new_tokens=gen, seed=seed)
    out = eng.run_trace(reqs, max_steps=300)
    assert out["finished"] == out["total"], out
    return out


def test_engine_continuous_populates_metrics_and_trace():
    eng = Engine.build(_ecfg(cache_backend="paged",
                             paging=PagingConfig(block_size=8)))
    out = _drive(eng)
    snap = eng.metrics()
    assert REQUIRED_FAMILIES <= set(snap), sorted(REQUIRED_FAMILIES - set(snap))
    # paged backend adds the pool-pressure gauges
    assert {"pool_free_blocks", "pool_blocks_in_use",
            "pool_free_blocks_partition", "pool_fragmentation_blocks",
            "pool_max_refcount", "pool_alloc_blocks_total",
            "pool_freed_blocks_total", "cache_live_tokens"} <= set(snap)
    m = eng.obs.metrics
    assert m.counter_value("sched_admissions_total") == out["finished"]
    assert m.counter_value("sched_retirements_total") == out["finished"]
    assert m.get("ttft_s").count() == out["finished"]
    # per-shard gauges exist for every model shard
    shard_series = snap["shard_load_tokens"]["series"]
    assert {s["labels"]["shard"] for s in shard_series} == {"0", "1", "2", "3"}
    # the export surfaces parse
    assert "sched_admissions_total" in eng.metrics_prometheus()
    doc = json.loads(eng.trace_export())
    names = {e["name"] for e in doc["traceEvents"]}
    assert {"admit", "decode_tick", "retire",
            "stepfn_prefill", "stepfn_decode"} <= names
    # run() summary carries the satellite telemetry
    assert out["latency"]["n_finished"] == out["finished"]
    assert "p50_ttft_s" in out["latency"] and "p50_itl_s" in out["latency"]
    assert np.isfinite(out["tokens_per_s"])


def test_engine_oneshot_populates_ttft_itl():
    eng = Engine.build(_ecfg())
    prompts = np.random.default_rng(0).integers(
        0, eng.cfg.model.vocab_size, (2, 12))
    eng.generate(prompts, 3)
    assert eng.obs.metrics.get("ttft_s").count() == 1
    assert eng.obs.metrics.get("itl_s").count() == 3
    assert eng.obs.metrics.get("stepfn_wall_s").count(
        kind="decode", executor="local") == 3


def test_zero_recompile_invariant_as_metric():
    """The PR-4 no-retrace contract, asserted through the obs counter: a
    live replan (weights + plan arrays swapped mid-flight) must leave
    stepfn_compiles_total{kind=decode} at its warm value."""
    eng = Engine.build(_ecfg(
        scheduler=SchedulerConfig(max_rows=4, replan_window=4,
                                  replan_threshold=1.05, replan_cooldown=10),
        max_seq_len=64))
    reqs = synthesize_requests(8, 0.4, eng.cfg.model.vocab_size,
                               min_prompt=12, max_prompt=28,
                               max_new_tokens=10, seed=3)
    out = eng.run_trace(reqs, max_steps=500)
    assert out["finished"] == 8
    assert any(ev["accepted"] for ev in out["replan_log"])
    m = eng.obs.metrics
    assert m.counter_value("stepfn_compiles_total", kind="decode",
                           executor="local") == 1
    assert m.counter_value("sched_replans_total", outcome="accepted") >= 1
    assert (m.counter_value("sched_replans_total", outcome="accepted")
            == out["replans"])
    # the metric agrees with the executor's own trace counter
    assert eng.executor.decode_traces == 1


def test_disabled_obs_keeps_outputs_identical():
    """enabled=False must change nothing but the telemetry: same tokens,
    empty exports."""
    outs = {}
    for enabled in (True, False):
        eng = Engine.build(_ecfg(obs=ObsConfig(enabled=enabled)))
        _drive(eng, n=3)
        outs[enabled] = {r.req_id: list(r.generated)
                         for r in eng.finished_requests}
        if not enabled:
            assert eng.metrics() == {}
            assert eng.metrics_prometheus() == ""
            assert json.loads(eng.trace_export())["traceEvents"] == []
    assert outs[True] == outs[False]


# ---------------------------------------------------------------------------
# local ↔ mesh metrics parity (multi-device subprocess)
# ---------------------------------------------------------------------------


SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, __SRC__)
import json
import numpy as np
from repro.api import (CompressionConfig, Engine, EngineConfig, ObsConfig,
                       PagingConfig, PlannerConfig, SchedulerConfig,
                       synthesize_requests)
from repro.launch.mesh import make_host_mesh

def cfg_for(executor):
    return EngineConfig.smoke(
        "minitron-8b", n_shards=4, max_seq_len=32,
        compression=CompressionConfig(policy="ada_snapkv", budget=16,
                                      alpha_max=2.0, obs_window=8, sink=2,
                                      decode_margin=8),
        planner=PlannerConfig(mode="fairkv_dp", extra_copies=4, batch_cap=4),
        scheduler=SchedulerConfig(max_rows=4, enable_replan=False),
        cache_backend="paged", paging=PagingConfig(block_size=8),
        executor=executor, profile_skew=2.0, profile_seed=1)

eng_l = Engine.build(cfg_for("local"))
eng_m = Engine.build(cfg_for("mesh"), mesh=make_host_mesh(model=4, data=2),
                     params=eng_l.params)
snaps = {}
for name, eng in (("local", eng_l), ("mesh", eng_m)):
    reqs = synthesize_requests(5, 0.6, 256, min_prompt=10, max_prompt=16,
                               max_new_tokens=4, seed=2)
    out = eng.run_trace(reqs, max_steps=300)
    assert out["finished"] == out["total"], out
    snap = eng.metrics()
    # deterministic families only: counts and end-state gauges, not wall time
    snaps[name] = {
        "families": sorted(snap),
        "admissions": snap["sched_admissions_total"]["series"],
        "retirements": snap["sched_retirements_total"]["series"],
        "shard_load": snap["shard_load_tokens"]["series"],
        "imbalance": snap["sched_imbalance"]["series"],
        "pool_alloc": snap["pool_alloc_blocks_total"]["series"],
        "pool_freed": snap["pool_freed_blocks_total"]["series"],
        "cache_live": snap["cache_live_tokens"]["series"],
        "ttft_count": eng.obs.metrics.get("ttft_s").count(),
        "itl_count": eng.obs.metrics.get("itl_s").count(),
        "decode_compiles": eng.obs.metrics.counter_value(
            "stepfn_compiles_total", kind="decode", executor=eng.cfg.executor),
        "trace_names": sorted({e["name"] for e in json.loads(
            eng.trace_export())["traceEvents"]}),
    }
print(json.dumps(snaps))
"""


def test_mesh_metrics_parity_multidevice_subprocess():
    """The same continuous trace on a local engine and a 2x4-mesh engine
    must land identical deterministic metrics (admissions, retirements,
    per-shard load, pool counters, latency-sample counts) in both
    registries — and both decode StepFns compile exactly once, observed
    through the metric itself."""
    import repro
    src = list(repro.__path__)[0].rsplit("/repro", 1)[0]
    code = SUBPROC.replace("__SRC__", repr(src))
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    snaps = json.loads(out.stdout.strip().splitlines()[-1])
    loc, msh = snaps["local"], snaps["mesh"]
    for key in ("families", "admissions", "retirements", "shard_load",
                "imbalance", "pool_alloc", "pool_freed", "cache_live",
                "ttft_count", "itl_count", "trace_names"):
        assert loc[key] == msh[key], (key, loc[key], msh[key])
    assert loc["decode_compiles"] == 1
    assert msh["decode_compiles"] == 1
