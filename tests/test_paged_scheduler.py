"""Paged cache backend through the scheduler/Engine: trace parity with the
slot backend, pool-exhaustion preemption (no corruption), fail-fast on
never-fits requests, online replan migration, and config validation."""
import numpy as np
import pytest

from repro.api import (
    CompressionConfig,
    Engine,
    EngineConfig,
    PagingConfig,
    PlannerConfig,
    SchedulerConfig,
    synthesize_requests,
)
from repro.serving.request import Request

ARCH = "minitron-8b"


def _cfg(backend="slot", n_blocks=0, rows=2, block_size=8, replan=False,
         **sched_kw):
    scfg = dict(max_rows=rows, enable_replan=replan, collect_logits=True)
    if replan:
        scfg.update(replan_window=2, replan_threshold=1.01, replan_cooldown=2,
                    replan_min_rows=1)
    scfg.update(sched_kw)
    return EngineConfig.smoke(
        ARCH, n_shards=4, max_seq_len=64,
        compression=CompressionConfig(policy="ada_snapkv", budget=12,
                                      alpha_max=2.0, obs_window=8, sink=2,
                                      decode_margin=8),
        planner=PlannerConfig(mode="fairkv_dp", extra_copies=4,
                              batch_cap=rows),
        scheduler=SchedulerConfig(**scfg),
        cache_backend=backend,
        paging=PagingConfig(block_size=block_size, n_blocks=n_blocks))


def _reqs(vocab, n=5, gen=6, seed=0):
    return synthesize_requests(n, 0.5, vocab, min_prompt=12, max_prompt=24,
                               max_new_tokens=gen, seed=seed)


@pytest.fixture(scope="module")
def slot_run():
    """Reference trace on the slot backend (+ shared params)."""
    cfg = _cfg("slot")
    eng = Engine.build(cfg)
    reqs = _reqs(cfg.model.vocab_size)
    out = eng.run_trace(reqs, max_steps=500)
    assert out["finished"] == out["total"]
    return cfg, eng.params, reqs, out


def test_paged_trace_matches_slot_exactly(slot_run):
    """Same trace, paged backend: identical tokens and logits per request
    (the backend is storage, not math)."""
    cfg, params, slot_reqs, _ = slot_run
    eng = Engine.build(_cfg("paged"), params=params)
    reqs = _reqs(cfg.model.vocab_size)
    out = eng.run_trace(reqs, max_steps=500)
    assert out["finished"] == out["total"]
    for a, b in zip(slot_reqs, reqs):
        assert a.generated == b.generated, a.req_id
        for la, lb in zip(a.logits, b.logits):
            np.testing.assert_array_equal(la, lb)
    # every block returned to the pool once all requests retired
    backend = eng.scheduler.backend
    assert backend.pool.blocks_in_use() == 0
    backend.pool.check_invariants()
    assert out["memory"]["backend"] == "paged"


def test_pool_exhaustion_preempts_not_corrupts(slot_run):
    """An undersized pool forces decode-growth preemption; the preempted
    request replays deterministically, so final tokens still match the
    slot reference and the allocator stays consistent."""
    cfg, params, _, _ = slot_run
    # pool sized so two requests co-run at prefill but their decode growth
    # (lengths -> static capacity, 4 blocks/head at bs=8) cannot both fit:
    # steady state needs 2 req x 2 heads x 4 blocks = 16 > 15 usable.
    paged_cfg = _cfg("paged", n_blocks=16)
    eng = Engine.build(paged_cfg, params=params)
    reqs = [Request(req_id=0, prompt=np.arange(12, dtype=np.int32) % 50,
                    arrival_step=0, max_new_tokens=18),
            Request(req_id=1, prompt=(np.arange(12, dtype=np.int32) + 7) % 50,
                    arrival_step=0, max_new_tokens=18)]
    out = eng.run_trace(reqs, max_steps=500)
    assert out["finished"] == out["total"] == 2
    assert out["preemptions"] >= 1
    assert sum(r.n_preemptions for r in reqs) == out["preemptions"]
    backend = eng.scheduler.backend
    assert backend.pool.blocks_in_use() == 0
    backend.pool.check_invariants()
    # no corruption: replay tokens equal an ample-pool run of the same trace
    eng2 = Engine.build(_cfg("paged"), params=params)
    reqs2 = [Request(req_id=r.req_id, prompt=r.prompt.copy(),
                     arrival_step=r.arrival_step,
                     max_new_tokens=r.max_new_tokens) for r in reqs]
    out2 = eng2.run_trace(reqs2, max_steps=500)
    assert out2["preemptions"] == 0
    for a, b in zip(reqs, reqs2):
        assert a.generated == b.generated, a.req_id


def test_never_fits_fails_fast(slot_run):
    """A request whose worst-case block need exceeds the whole pool is
    rejected at submit (no head-of-line blocking)."""
    cfg, params, _, _ = slot_run
    eng = Engine.build(_cfg("paged", n_blocks=4), params=params)
    with pytest.raises(ValueError, match="never be admitted"):
        eng.submit(np.arange(20, dtype=np.int32) % 50, max_new_tokens=18)


def test_paged_online_replan_matches_slot_tokens(slot_run):
    """Online replanning (slot<->paged migration path) is a layout change:
    an aggressive replan schedule on the paged backend must not alter the
    generated tokens vs the replan-free slot reference."""
    cfg, params, slot_reqs, _ = slot_run
    eng = Engine.build(_cfg("paged", replan=True), params=params)
    reqs = _reqs(cfg.model.vocab_size)
    out = eng.run_trace(reqs, max_steps=500)
    assert out["finished"] == out["total"]
    assert len(eng.replan_log) >= 1  # the trigger actually fired
    for a, b in zip(slot_reqs, reqs):
        assert a.generated == b.generated, a.req_id
    backend = eng.scheduler.backend
    backend.pool.check_invariants()


def test_unknown_cache_backend_rejected():
    with pytest.raises(ValueError, match="unknown cache backend"):
        _cfg("pagedd")


def test_paging_config_validated():
    with pytest.raises(ValueError, match="block_size"):
        PagingConfig(block_size=0)


def test_paged_memory_smaller_than_slot(slot_run):
    """The point of the subsystem: under an imbalanced policy the paged
    footprint undercuts the dense slot cache."""
    cfg, params, _, _ = slot_run
    eng = Engine.build(_cfg("paged"), params=params)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.model.vocab_size, size=(2, 20))
    eng.generate(prompts.astype(np.int32), 4)
    mem = eng.memory_stats()
    assert mem["cache_bytes"] < mem["slot_equivalent_bytes"]
    assert mem["blocks_in_use"] > 0
