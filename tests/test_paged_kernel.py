"""Native paged decode kernel (`kernels/paged_fairkv_decode.py`): interpret
mode vs the ``ref.paged_fairkv_decode_ref`` oracle over ragged lengths,
null-block tables, partial last blocks, window + softcap, and dtypes; the
``ops.paged_fairkv_decode`` impl dispatch; and gather↔native↔slot three-way
token parity through `Engine.generate` on the local and 2x4-mesh executors
(the mesh case runs in a subprocess so the fake-device count is set before
the first jax import, mirroring tests/test_executor.py).
"""
import json
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops as K
from repro.kernels.paged_fairkv_decode import paged_fairkv_decode_pallas
from repro.kernels.ref import paged_fairkv_decode_ref
from repro.paging.kvquant import KIND_FP8, KIND_INT8, fp8_supported
from repro.paging.testing import make_paged_layer, quantize_paged_layer

from tests._hypothesis_compat import given, settings, st


def _compare(rng, S, B, G, Dh, C, bs, window=0, cap=0.0, dtype=jnp.float32,
             lengths=None):
    kp, vp, pp, tbl, lens = make_paged_layer(
        rng, S, B, C, bs, Dh, dtype=np.dtype(dtype), lengths=lengths)
    q = jnp.asarray(rng.normal(size=(B, S, G, Dh)), dtype)
    qpos = jnp.full((B,), C + 7, jnp.int32)
    ref = paged_fairkv_decode_ref(q, kp, vp, pp, tbl, lens, C, cap,
                                  q_pos=qpos, window=window)
    out = paged_fairkv_decode_pallas(q, kp, vp, pp, tbl, lens, C,
                                     attn_cap=cap, q_pos=qpos, window=window,
                                     interpret=True)
    return float(jnp.abs(out.astype(jnp.float32)
                         - ref.astype(jnp.float32)).max())


# ---------------------------------------------------------------------------
# kernel vs oracle (interpret mode)
# ---------------------------------------------------------------------------


@settings(max_examples=10)
@given(S=st.integers(2, 5), B=st.integers(1, 4), G=st.integers(1, 8),
       C=st.integers(6, 200), bs=st.sampled_from([2, 8, 16, 32, 64]),
       seed=st.integers(0, 10))
def test_paged_kernel_ragged_lengths(S, B, G, C, bs, seed):
    """Random ragged lengths (empty rows included), shuffled block ids,
    partial last blocks — the kernel must match the oracle everywhere."""
    rng = np.random.default_rng(seed)
    assert _compare(rng, S, B, G, 32, C, bs) < 1e-5


@pytest.mark.parametrize("S,B,G,Dh,C,bs", [
    (4, 3, 4, 64, 96, 16),    # several blocks, ragged
    (2, 2, 8, 64, 256, 32),   # GQA 8:1
    (3, 2, 1, 128, 200, 64),  # MHA, capacity not a block multiple
    (2, 2, 2, 32, 64, 64),    # single block per row
])
def test_paged_kernel_shapes(S, B, G, Dh, C, bs):
    rng = np.random.default_rng(0)
    assert _compare(rng, S, B, G, Dh, C, bs) < 1e-5


def test_paged_kernel_null_block_tables():
    """Rows with zero length hold all-null tables; their output must be
    exactly 0 (the §2 psum-reassembly contract) even though the null block
    holds garbage."""
    rng = np.random.default_rng(1)
    S, B, G, Dh, C, bs = 3, 2, 4, 32, 96, 16
    lengths = np.zeros((S, B), np.int32)
    kp, vp, pp, tbl, lens = make_paged_layer(rng, S, B, C, bs, Dh,
                                             lengths=lengths)
    assert int(np.asarray(tbl).max()) == 0  # nothing allocated
    q = jnp.asarray(rng.normal(size=(B, S, G, Dh)), jnp.float32)
    out = paged_fairkv_decode_pallas(q, kp, vp, pp, tbl, lens, C,
                                     interpret=True)
    assert float(jnp.abs(out).max()) == 0.0


def test_paged_kernel_mixed_null_rows():
    """Empty and full rows in one grid: the null-row clamp must not leak
    into neighbouring (slot, row) programs."""
    rng = np.random.default_rng(2)
    S, B, C, bs = 2, 3, 64, 16
    lengths = np.array([[0, C, 7], [C - 1, 0, bs]], np.int32)
    assert _compare(rng, S, B, 4, 32, C, bs, lengths=lengths) < 1e-5


def test_paged_kernel_last_block_partial_fill():
    """Lengths straddling a block boundary: the final block's tail past
    ``len`` holds garbage and must be masked."""
    rng = np.random.default_rng(3)
    S, B, C, bs = 3, 2, 96, 16
    lengths = np.array([[1, bs - 1], [bs, bs + 1], [C - 1, C]], np.int32)
    assert _compare(rng, S, B, 4, 32, C, bs, lengths=lengths) < 1e-5


def test_paged_kernel_window():
    rng = np.random.default_rng(4)
    assert _compare(rng, 3, 3, 4, 32, 96, 16, window=40) < 1e-5


def test_paged_kernel_softcap():
    rng = np.random.default_rng(5)
    assert _compare(rng, 2, 2, 8, 64, 128, 16, cap=50.0) < 1e-5


def test_paged_kernel_window_and_softcap():
    rng = np.random.default_rng(6)
    assert _compare(rng, 3, 2, 4, 32, 96, 16, window=30, cap=30.0) < 1e-5


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-5),
                                       (jnp.bfloat16, 0.03)])
def test_paged_kernel_dtypes(dtype, tol):
    rng = np.random.default_rng(7)
    assert _compare(rng, 3, 2, 4, 64, 96, 16, dtype=dtype) < tol


def test_paged_kernel_rejects_short_table():
    rng = np.random.default_rng(8)
    kp, vp, pp, tbl, lens = make_paged_layer(rng, 2, 2, 32, 16, 8)
    q = jnp.zeros((2, 2, 2, 8), jnp.float32)
    with pytest.raises(ValueError, match="capacity"):
        paged_fairkv_decode_pallas(q, kp, vp, pp, tbl, lens, 64,
                                   interpret=True)


# ---------------------------------------------------------------------------
# quantized pools (DESIGN.md §15): kernel vs oracle vs fp32
# ---------------------------------------------------------------------------

# dequantized output vs the fp32 reference on the same values: int8 keeps
# ~2 decimal digits per block, fp8 (e4m3) ~1; attention averaging keeps the
# output error well under one quantization step of the inputs
QUANT_TOL = {KIND_INT8: 0.05, KIND_FP8: 0.2}

needs_fp8 = pytest.mark.skipif(not fp8_supported(),
                               reason="jax lacks float8_e4m3fn")


def _compare_quant(rng, S, B, G, Dh, C, bs, kinds, window=0, cap=0.0,
                   lengths=None):
    """(pallas-vs-ref, gather-vs-ref, quantized-ref-vs-fp32-ref) max errors
    for one random quantized layer; ``kinds`` is the (S,) per-slot grid."""
    kp, vp, pp, tbl, lens = make_paged_layer(rng, S, B, C, bs, Dh,
                                             lengths=lengths)
    kinds = jnp.asarray(np.broadcast_to(kinds, (S,)), jnp.int32)
    kq, vq, ks, vs = quantize_paged_layer(kp, vp, tbl, kinds)
    q = jnp.asarray(rng.normal(size=(B, S, G, Dh)), jnp.float32)
    qpos = jnp.full((B,), C + 7, jnp.int32)
    fp32 = paged_fairkv_decode_ref(q, kp, vp, pp, tbl, lens, C, cap,
                                   q_pos=qpos, window=window)
    quant_kw = dict(k_scale=ks, v_scale=vs, kinds=kinds)
    ref = paged_fairkv_decode_ref(q, kq, vq, pp, tbl, lens, C, cap,
                                  q_pos=qpos, window=window, **quant_kw)
    out = paged_fairkv_decode_pallas(q, kq, vq, pp, tbl, lens, C,
                                     attn_cap=cap, q_pos=qpos, window=window,
                                     interpret=True, **quant_kw)
    gat = K.paged_fairkv_decode(q, kq, vq, pp, tbl, lens, C, attn_cap=cap,
                                q_pos=qpos, window=window, impl="gather",
                                **quant_kw)

    def err(a, b):
        return float(jnp.abs(a - b).max())

    return err(out, ref), err(gat, ref), err(ref, fp32)


@settings(max_examples=8)
@given(S=st.integers(2, 5), B=st.integers(1, 4), G=st.integers(1, 8),
       C=st.integers(6, 200), bs=st.sampled_from([2, 8, 16, 32, 64]),
       kind=st.sampled_from([KIND_INT8, KIND_FP8]), seed=st.integers(0, 10))
def test_paged_kernel_quantized_ragged(S, B, G, C, bs, kind, seed):
    """Quantized kernel parity over the same adversarial space as the fp32
    sweep: ragged lengths, shuffled blocks, null rows, partial last blocks.
    All three impls dequantize identically (tight bound vs the quantized
    oracle) and the codec error vs fp32 stays inside the per-dtype bound."""
    if kind == KIND_FP8 and not fp8_supported():
        return
    rng = np.random.default_rng(seed)
    pallas_err, gather_err, quant_err = _compare_quant(
        rng, S, B, G, 32, C, bs, kind)
    assert pallas_err < 1e-5
    assert gather_err < 1e-5
    assert quant_err < QUANT_TOL[kind]


@pytest.mark.parametrize("kind", [KIND_INT8,
                                  pytest.param(KIND_FP8, marks=needs_fp8)])
def test_paged_kernel_quantized_window_softcap(kind):
    rng = np.random.default_rng(21)
    pallas_err, gather_err, quant_err = _compare_quant(
        rng, 3, 2, 4, 32, 96, 16, kind, window=40, cap=30.0)
    assert pallas_err < 1e-5 and gather_err < 1e-5
    assert quant_err < QUANT_TOL[kind]


@needs_fp8
def test_paged_kernel_quantized_mixed_kinds():
    """int8 and fp8 slots in one grid: the per-slot kind prefetch operand
    must select the right dequant interpretation per program."""
    rng = np.random.default_rng(22)
    kinds = np.arange(4) % 2  # alternating int8 / fp8
    pallas_err, gather_err, quant_err = _compare_quant(
        rng, 4, 3, 4, 32, 96, 16, kinds)
    assert pallas_err < 1e-5 and gather_err < 1e-5
    assert quant_err < QUANT_TOL[KIND_FP8]


def test_paged_kernel_quantized_null_block_tables():
    """All-null quantized rows still output exactly 0 — garbage codes and
    zero scales never leak past the length mask (and fp8 NaN bit patterns
    are flushed, not propagated, in the masked tail)."""
    rng = np.random.default_rng(23)
    S, B, G, Dh, C, bs = 3, 2, 4, 32, 96, 16
    lengths = np.zeros((S, B), np.int32)
    kp, vp, pp, tbl, lens = make_paged_layer(rng, S, B, C, bs, Dh,
                                             lengths=lengths)
    kinds = jnp.ones((S,), jnp.int32) if fp8_supported() \
        else jnp.zeros((S,), jnp.int32)
    kq, vq, ks, vs = quantize_paged_layer(kp, vp, tbl, kinds)
    q = jnp.asarray(rng.normal(size=(B, S, G, Dh)), jnp.float32)
    out = paged_fairkv_decode_pallas(q, kq, vq, pp, tbl, lens, C,
                                     interpret=True, k_scale=ks, v_scale=vs,
                                     kinds=kinds)
    assert float(jnp.abs(out).max()) == 0.0


# ---------------------------------------------------------------------------
# multi-query q (speculative verify, DESIGN.md §16): kernel vs mq oracle
# ---------------------------------------------------------------------------


def _compare_mq(rng, S, B, Q, G, Dh, C, bs, window=0, cap=0.0,
                dtype=jnp.float32, q_lens=None, kinds=None):
    """(pallas-vs-ref, gather-vs-ref) max errors for a 5-D multi-query
    layer.  ``lengths`` count the cache AFTER the speculative appends, so
    they are drawn ≥ Q per (slot, row); ``q_lens`` defaults to a random
    ragged draw in [1, Q]."""
    lengths = rng.integers(Q, C + 1, size=(S, B)).astype(np.int32)
    kp, vp, pp, tbl, lens = make_paged_layer(rng, S, B, C, bs, Dh,
                                             dtype=np.dtype(dtype),
                                             lengths=lengths)
    quant_kw = {}
    if kinds is not None:
        kinds = jnp.asarray(np.broadcast_to(kinds, (S,)), jnp.int32)
        kq, vq, ks, vs = quantize_paged_layer(kp, vp, tbl, kinds)
        kp, vp = kq, vq
        quant_kw = dict(k_scale=ks, v_scale=vs, kinds=kinds)
    if q_lens is None:
        q_lens = rng.integers(1, Q + 1, size=(B,))
    q_lens = jnp.asarray(q_lens, jnp.int32)
    q = jnp.asarray(rng.normal(size=(B, S, Q, G, Dh)), dtype)
    qpos = jnp.full((B,), C + 7, jnp.int32)  # query 0's absolute position
    ref = paged_fairkv_decode_ref(q, kp, vp, pp, tbl, lens, C, cap,
                                  q_pos=qpos, q_lens=q_lens, window=window,
                                  **quant_kw)
    out = paged_fairkv_decode_pallas(q, kp, vp, pp, tbl, lens, C,
                                     attn_cap=cap, q_pos=qpos,
                                     q_lens=q_lens, window=window,
                                     interpret=True, **quant_kw)
    gat = K.paged_fairkv_decode(q, kp, vp, pp, tbl, lens, C, attn_cap=cap,
                                q_pos=qpos, q_lens=q_lens, window=window,
                                impl="gather", **quant_kw)

    def err(a, b):
        return float(jnp.abs(a.astype(jnp.float32)
                             - b.astype(jnp.float32)).max())

    return err(out, ref), err(gat, ref)


@settings(max_examples=10)
@given(S=st.integers(2, 4), B=st.integers(1, 4), Q=st.integers(2, 5),
       G=st.integers(1, 8), C=st.integers(8, 128),
       bs=st.sampled_from([2, 8, 16, 32]), seed=st.integers(0, 10))
def test_paged_kernel_mq_ragged(S, B, Q, G, C, bs, seed):
    """Random speculative windows (ragged ``q_lens``) over ragged cache
    lengths: the in-window causal mask must match the mq oracle in both
    the pallas and gather impls."""
    rng = np.random.default_rng(seed)
    pallas_err, gather_err = _compare_mq(rng, S, B, Q, G, 32, C, bs)
    assert pallas_err < 1e-5
    assert gather_err < 1e-5


def test_paged_kernel_mq_q1_matches_4d():
    """A 5-D call with Q == 1 must be bitwise identical to the 4-D
    single-query path — same kernel, trivial mask."""
    rng = np.random.default_rng(30)
    S, B, G, Dh, C, bs = 3, 2, 4, 32, 96, 16
    kp, vp, pp, tbl, lens = make_paged_layer(rng, S, B, C, bs, Dh)
    q4 = jnp.asarray(rng.normal(size=(B, S, G, Dh)), jnp.float32)
    qpos = jnp.full((B,), C + 7, jnp.int32)
    out4 = paged_fairkv_decode_pallas(q4, kp, vp, pp, tbl, lens, C,
                                      q_pos=qpos, interpret=True)
    out5 = paged_fairkv_decode_pallas(q4[:, :, None], kp, vp, pp, tbl, lens,
                                      C, q_pos=qpos,
                                      q_lens=jnp.ones((B,), jnp.int32),
                                      interpret=True)
    assert out5.shape == (B, S, 1, G, Dh)
    assert bool((out4 == out5[:, :, 0]).all())


def test_paged_kernel_mq_causal_window():
    """Query ``i`` must see exactly ``len - (qn - 1 - i)`` cache entries:
    with all-identical K the causal limit is invisible, so plant a marker
    value in the last cache slots and check each query's exposure via the
    oracle, then kernel parity on the same layer."""
    rng = np.random.default_rng(31)
    S, B, Q, G, Dh, C, bs = 2, 2, 3, 2, 32, 64, 16
    q_lens = np.array([3, 2], np.int32)
    pallas_err, gather_err = _compare_mq(rng, S, B, Q, G, Dh, C, bs,
                                         q_lens=q_lens)
    assert pallas_err < 1e-5 and gather_err < 1e-5


def test_paged_kernel_mq_garbage_lanes_do_not_leak():
    """Lanes at ``qi >= q_lens[b]`` are scratch (the scheduler discards
    them): perturbing their q values must not change any valid lane."""
    rng = np.random.default_rng(32)
    S, B, Q, G, Dh, C, bs = 2, 2, 4, 2, 32, 64, 16
    lengths = rng.integers(Q, C + 1, size=(S, B)).astype(np.int32)
    kp, vp, pp, tbl, lens = make_paged_layer(rng, S, B, C, bs, Dh,
                                             lengths=lengths)
    q_lens = jnp.asarray([2, 3], jnp.int32)
    qpos = jnp.full((B,), C + 7, jnp.int32)
    q = np.asarray(rng.normal(size=(B, S, Q, G, Dh)), np.float32)
    out_a = paged_fairkv_decode_pallas(jnp.asarray(q), kp, vp, pp, tbl,
                                       lens, C, q_pos=qpos, q_lens=q_lens,
                                       interpret=True)
    q2 = q.copy()
    q2[0, :, 2:] = 1e3  # garbage lanes of row 0 (q_lens=2)
    q2[1, :, 3:] = -1e3  # garbage lane of row 1 (q_lens=3)
    out_b = paged_fairkv_decode_pallas(jnp.asarray(q2), kp, vp, pp, tbl,
                                       lens, C, q_pos=qpos, q_lens=q_lens,
                                       interpret=True)
    assert bool((out_a[0, :, :2] == out_b[0, :, :2]).all())
    assert bool((out_a[1, :, :3] == out_b[1, :, :3]).all())


def test_paged_kernel_mq_window_softcap():
    rng = np.random.default_rng(33)
    pallas_err, gather_err = _compare_mq(rng, 2, 2, 3, 4, 32, 96, 16,
                                         window=40, cap=30.0)
    assert pallas_err < 1e-5 and gather_err < 1e-5


@pytest.mark.parametrize("kind", [KIND_INT8,
                                  pytest.param(KIND_FP8, marks=needs_fp8)])
def test_paged_kernel_mq_quantized(kind):
    """Quantized pools through the multi-query path: all impls dequantize
    identically under the speculative causal mask."""
    rng = np.random.default_rng(34)
    pallas_err, gather_err = _compare_mq(rng, 3, 2, 3, 4, 32, 96, 16,
                                         kinds=kind)
    assert pallas_err < 1e-5 and gather_err < 1e-5


# ---------------------------------------------------------------------------
# ops dispatch
# ---------------------------------------------------------------------------


def test_ops_dispatch_impls_agree():
    rng = np.random.default_rng(9)
    kp, vp, pp, tbl, lens = make_paged_layer(rng, 3, 2, 96, 16, 32)
    q = jnp.asarray(rng.normal(size=(2, 3, 4, 32)), jnp.float32)
    qpos = jnp.full((2,), 99, jnp.int32)
    outs = {impl: K.paged_fairkv_decode(q, kp, vp, pp, tbl, lens, 96,
                                        q_pos=qpos, impl=impl)
            for impl in ("jnp", "gather", "pallas")}
    if K._force_interpret():
        # the gather's inner slot kernel is pallas-interpret here (the CI
        # kernels-interpret gate) — reduction order differs from the ref
        assert float(jnp.abs(outs["gather"] - outs["jnp"]).max()) < 1e-5
    else:
        # jnp and gather are the same math in the same order -> exact
        assert bool((outs["jnp"] == outs["gather"]).all())
    assert float(jnp.abs(outs["pallas"] - outs["jnp"]).max()) < 1e-5


def test_ops_dispatch_rejects_unknown_impl():
    q = jnp.zeros((1, 1, 1, 8), jnp.float32)
    with pytest.raises(ValueError, match="bogus"):
        K.paged_fairkv_decode(q, q, q, q[..., 0], q[..., 0, 0], None, 8,
                              impl="bogus")


def test_force_interpret_env_routes_auto_to_pallas(monkeypatch):
    """REPRO_PALLAS_INTERPRET=1 (the CI kernels-interpret gate) must route
    "auto" dispatch onto the Pallas kernels in interpret mode off-TPU."""
    rng = np.random.default_rng(10)
    kp, vp, pp, tbl, lens = make_paged_layer(rng, 2, 2, 64, 16, 32)
    q = jnp.asarray(rng.normal(size=(2, 2, 4, 32)), jnp.float32)
    ref = K.paged_fairkv_decode(q, kp, vp, pp, tbl, lens, 64, impl="jnp")
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    assert K._force_interpret()
    out = K.paged_fairkv_decode(q, kp, vp, pp, tbl, lens, 64, impl="auto")
    assert float(jnp.abs(out - ref).max()) < 1e-5
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    assert not K._force_interpret()


def test_paging_config_validates_decode_impl():
    from repro.api import EngineConfig, PagingConfig
    with pytest.raises(ValueError, match="pallas"):
        PagingConfig(decode_impl="cuda")
    cfg = EngineConfig.smoke("minitron-8b",
                             paging=PagingConfig(decode_impl="pallas"))
    assert cfg.paging.decode_impl == "pallas"


# ---------------------------------------------------------------------------
# three-way token parity through Engine.generate (local executor)
# ---------------------------------------------------------------------------


def _engine_cfg(backend, impl="auto", rows=2, T=16, gen=3, kv_dtype="fp32"):
    from repro.api import (CompressionConfig, EngineConfig, PagingConfig,
                           PlannerConfig, SchedulerConfig)
    return EngineConfig.smoke(
        "minitron-8b", n_shards=4, max_seq_len=T + gen + 8,
        compression=CompressionConfig(policy="ada_snapkv", budget=16,
                                      alpha_max=2.0, obs_window=8, sink=2,
                                      decode_margin=8),
        planner=PlannerConfig(mode="fairkv_dp", extra_copies=4,
                              batch_cap=rows),
        scheduler=SchedulerConfig(max_rows=rows, enable_replan=False),
        cache_backend=backend,
        paging=PagingConfig(block_size=8, decode_impl=impl,
                            kv_dtype=kv_dtype))


def test_engine_generate_three_way_token_parity_local():
    """gather, native-pallas (interpret), and jnp paged decode — and the
    slot backend — produce identical tokens through `Engine.generate`."""
    from repro.api import Engine
    B, T, GEN = 2, 16, 3
    prompts = np.random.default_rng(0).integers(0, 256, (B, T))
    slot_eng = Engine.build(_engine_cfg("slot"))
    base = slot_eng.generate(prompts, GEN)
    for impl in ("jnp", "gather", "pallas"):
        eng = Engine.build(_engine_cfg("paged", impl), params=slot_eng.params)
        res = eng.generate(prompts, GEN)
        assert np.array_equal(base.tokens, res.tokens), impl
        assert np.array_equal(base.lengths, res.lengths), impl
        # one decode trace per engine: the impl knob is static config
        assert eng.executor.decode_traces == 1, impl


@pytest.mark.parametrize("kv_dtype", ["int8",
                                      pytest.param("fp8", marks=needs_fp8)])
def test_engine_generate_quantized_impl_agreement(kv_dtype):
    """Quantized end-to-end: all three paged decode impls see the identical
    codes/scales, so their tokens must agree with each other; lengths match
    the fp32 slot baseline; and the kv_dtype knob is static StepFn config —
    exactly one decode trace per engine (compile-once per dtype)."""
    from repro.api import Engine
    B, T, GEN = 2, 16, 3
    prompts = np.random.default_rng(0).integers(0, 256, (B, T))
    slot_eng = Engine.build(_engine_cfg("slot"))
    base = slot_eng.generate(prompts, GEN)
    results = {}
    for impl in ("jnp", "gather", "pallas"):
        eng = Engine.build(_engine_cfg("paged", impl, kv_dtype=kv_dtype),
                           params=slot_eng.params)
        res = eng.generate(prompts, GEN)
        assert np.array_equal(base.lengths, res.lengths), impl
        assert eng.executor.decode_traces == 1, impl
        results[impl] = res.tokens
    assert np.array_equal(results["jnp"], results["gather"])
    assert np.array_equal(results["jnp"], results["pallas"])


# ---------------------------------------------------------------------------
# three-way token parity on the 2x4 mesh executor (subprocess: the fake
# device count must be set before the first jax import)
# ---------------------------------------------------------------------------


SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, __SRC__)
import json
import numpy as np
from repro.api import (CompressionConfig, Engine, EngineConfig, PagingConfig,
                       PlannerConfig, SchedulerConfig)
from repro.launch.mesh import make_host_mesh

B, T, GEN = 4, 16, 3

def cfg_for(backend, impl, executor):
    return EngineConfig.smoke(
        "minitron-8b", n_shards=4, max_seq_len=T + GEN + 8,
        compression=CompressionConfig(policy="ada_snapkv", budget=16,
                                      alpha_max=2.0, obs_window=8, sink=2,
                                      decode_margin=8),
        planner=PlannerConfig(mode="fairkv_dp", extra_copies=4, batch_cap=B),
        scheduler=SchedulerConfig(max_rows=B, enable_replan=False),
        cache_backend=backend, executor=executor,
        paging=PagingConfig(block_size=8, decode_impl=impl))

prompts = np.random.default_rng(0).integers(0, 256, (B, T))
loc = Engine.build(cfg_for("slot", "auto", "local"))
base = loc.generate(prompts, GEN)
out = {}
for impl in ("jnp", "gather", "pallas"):
    mesh = make_host_mesh(model=4, data=2)
    eng = Engine.build(cfg_for("paged", impl, "mesh"), mesh=mesh,
                       params=loc.params)
    res = eng.generate(prompts, GEN)
    out[impl] = {
        "tokens_equal": bool(np.array_equal(base.tokens, res.tokens)),
        "lengths_equal": bool(np.array_equal(base.lengths, res.lengths)),
        "decode_traces": eng.executor.decode_traces,
    }
print(json.dumps(out))
"""


def test_engine_generate_three_way_token_parity_mesh_2x4():
    """All three paged decode impls on the (data=2, model=4) mesh executor
    match the local slot baseline token-for-token, one decode trace each."""
    import repro
    src = list(repro.__path__)[0].rsplit("/repro", 1)[0]
    code = SUBPROC.replace("__SRC__", repr(src))
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    results = json.loads(out.stdout.strip().splitlines()[-1])
    for impl, rec in results.items():
        assert rec["tokens_equal"], (impl, rec)
        assert rec["lengths_equal"], (impl, rec)
        assert rec["decode_traces"] == 1, (impl, rec)
