"""FairKV planner: unit + hypothesis property tests on the plan invariants."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    PlannerConfig,
    build_plan,
    synthetic_profile,
)
from repro.core.assignment import backtracking, greedy_lpt


# ---------------------------------------------------------------------------
# assignment engines
# ---------------------------------------------------------------------------


def test_lpt_basic():
    w = [10, 9, 8, 1, 1, 1]
    a = greedy_lpt(w, 3, 2)
    loads = sorted(sum(w[i] for i in s) for s in a)
    assert loads == [9, 10, 11] or max(loads) <= 11


def test_backtracking_beats_or_matches_lpt():
    rng = np.random.default_rng(0)
    for _ in range(10):
        w = rng.integers(1, 100, size=10).astype(float)
        lpt = greedy_lpt(list(w), 4, 4)
        lpt_ms = max(sum(w[i] for i in s) for s in lpt)
        _, bt_ms = backtracking(list(w), 4, 4, incumbent=lpt)
        assert bt_ms <= lpt_ms + 1e-9


def test_backtracking_optimal_small():
    # known optimum: weights {5,4,3,3,3} on 2 shards -> makespan 9
    w = [5.0, 4.0, 3.0, 3.0, 3.0]
    _, ms = backtracking(w, 2, 5)
    assert ms == pytest.approx(9.0)


def test_shard_speeds_shift_load():
    w = [10.0] * 8
    a = greedy_lpt(w, 2, 8, shard_speeds=[1.0, 3.0])
    # fast shard should get ~3x the items
    assert len(a[1]) > len(a[0])


# ---------------------------------------------------------------------------
# plan invariants (Eq. 2 / Eq. 3 / distinct shards) under random profiles
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(
    n_heads=st.integers(2, 24),
    n_shards=st.sampled_from([2, 4, 8, 16]),
    n_layers=st.integers(1, 4),
    skew=st.floats(0.1, 2.0),
    mode=st.sampled_from(["sha", "fairkv_nodp", "fairkv_dp"]),
    ch=st.integers(0, 8),
)
def test_plan_invariants(n_heads, n_shards, n_layers, skew, mode, ch):
    prof = synthetic_profile(n_layers, n_heads, budget=256, skew=skew, seed=1)
    slots = max(1, -(-n_heads // n_shards))
    plan = build_plan(prof, n_shards,
                      PlannerConfig(mode=mode, extra_copies=ch,
                                    slots_per_shard=slots))
    plan.validate()  # Eq.2 coverage, Eq.3 cap, distinct shards, replica idx
    assert 0.0 < plan.efficiency(prof) <= 1.0 + 1e-9


@settings(max_examples=20, deadline=None)
@given(
    n_heads=st.sampled_from([4, 5, 8]),
    skew=st.floats(0.5, 1.5),
)
def test_fairkv_no_worse_than_sha(n_heads, skew):
    """FairKV-DP's planned makespan never exceeds SHA's on the profile it
    planned for (the paper's core claim, in expectation)."""
    prof = synthetic_profile(8, n_heads, budget=512, skew=skew, seed=2)
    sha = build_plan(prof, 16, PlannerConfig(mode="sha", slots_per_shard=1))
    dp = build_plan(prof, 16, PlannerConfig(mode="fairkv_dp", extra_copies=4,
                                            slots_per_shard=1))
    assert dp.makespan(prof) <= sha.makespan(prof) * 1.001


def test_ablation_ordering():
    """Fig. 4: SHA <= NoDP <= DP in efficiency (on the planning profile)."""
    prof = synthetic_profile(16, 8, budget=1024, skew=1.0, seed=3)
    cfgs = {
        "sha": PlannerConfig(mode="sha", slots_per_shard=1),
        "nodp": PlannerConfig(mode="fairkv_nodp", slots_per_shard=1),
        "dp": PlannerConfig(mode="fairkv_dp", extra_copies=8, slots_per_shard=1),
    }
    eff = {k: build_plan(prof, 16, c).efficiency(prof) for k, c in cfgs.items()}
    assert eff["dp"] >= eff["nodp"] - 1e-9
    assert eff["dp"] >= eff["sha"] - 1e-9


def test_ch_monotone_efficiency():
    """Fig. 5: efficiency is (weakly) monotone in the copied-head count."""
    prof = synthetic_profile(4, 8, budget=1024, skew=1.2, seed=5)
    effs = []
    for ch in [0, 1, 2, 4, 8]:
        plan = build_plan(prof, 16, PlannerConfig(
            mode="fairkv_dp", extra_copies=ch, slots_per_shard=2))
        effs.append(plan.efficiency(prof))
    assert all(b >= a - 0.02 for a, b in zip(effs, effs[1:])), effs


def test_serialization_roundtrip():
    from repro.core.placement import HeadPlacement
    prof = synthetic_profile(3, 8, budget=128, skew=1.0, seed=0)
    plan = build_plan(prof, 4, PlannerConfig(mode="fairkv_dp", extra_copies=2))
    plan2 = HeadPlacement.from_json(plan.to_json())
    for a, b in zip(plan.layers, plan2.layers):
        np.testing.assert_array_equal(a.slot_head, b.slot_head)
        np.testing.assert_array_equal(a.replica_idx, b.replica_idx)
        np.testing.assert_array_equal(a.replica_count, b.replica_count)


def test_straggler_replan():
    from repro.core import replan_for_stragglers
    prof = synthetic_profile(8, 8, budget=512, skew=0.8, seed=4)
    plan = build_plan(prof, 4, PlannerConfig(mode="fairkv_dp", extra_copies=4))
    speeds = np.array([1.0, 1.0, 1.0, 0.5])  # shard 3 at half speed
    replanned = replan_for_stragglers(prof, plan, speeds)
    loads = replanned.per_shard_load(prof)
    # slow shard receives the least load
    assert loads[3] == pytest.approx(loads.min())
    # heterogeneous makespan (load/speed) beats using the naive plan
    naive = (plan.per_shard_load(prof) / speeds).max()
    assert (loads / speeds).max() <= naive + 1e-9
