"""Speculative decoding (DESIGN.md §16): token parity vs single-token
greedy decode at acceptance 1.0 (full-depth self-draft), partial
(truncated draft), and 0 (adversarial proposals) — on the local executor
in-process and the 2x4 host mesh in a subprocess — plus zero-recompile
trace accounting, scheduler-level parity through `Engine.run_trace`
(including int8 pools and ring-wrap CoW under shared prefixes), pool
conservation under reject-rollback, and the §12 speculation metrics.
"""
import json
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (
    CompressionConfig,
    Engine,
    EngineConfig,
    PagingConfig,
    PlannerConfig,
    PrefixConfig,
    Request,
    SchedulerConfig,
    SpeculationConfig,
    synthesize_requests,
)

ARCH = "minitron-8b"
B, T, GEN = 4, 20, 10
CAP = T + GEN + 8


def _cfg(executor="local", spec=None, kv_dtype="fp32", rows=B, max_seq=CAP,
         budget=64, margin=8, prefix=None, **sched_kw):
    scfg = dict(max_rows=rows, enable_replan=False)
    scfg.update(sched_kw)
    return EngineConfig.smoke(
        ARCH, n_shards=4, max_seq_len=max_seq,
        compression=CompressionConfig(policy="none", budget=budget,
                                      capacity=budget, alpha_max=1.0,
                                      obs_window=8, sink=2,
                                      decode_margin=margin),
        planner=PlannerConfig(mode="fairkv_dp", extra_copies=6,
                              batch_cap=rows),
        scheduler=SchedulerConfig(**scfg),
        cache_backend="paged",
        paging=PagingConfig(block_size=8, kv_dtype=kv_dtype),
        executor=executor,
        prefix=prefix or PrefixConfig(),
        speculation=spec or SpeculationConfig())


_PARAMS_CACHE: dict = {}


def _shared_params():
    if "p" not in _PARAMS_CACHE:
        _PARAMS_CACHE["p"] = Engine.build(_cfg()).params
    return _PARAMS_CACHE["p"]


@pytest.fixture(scope="module")
def params():
    return _shared_params()


# ---------------------------------------------------------------------------
# executor level: propose/verify vs sequential decode (local, in-process)
# ---------------------------------------------------------------------------

_PROMPTS = np.random.default_rng(0).integers(0, 256, (B, T))


def _fresh(eng):
    eng.prefill(_PROMPTS)
    eng.state = eng.backend.from_prefill(eng.state, eng.pa)
    return eng.state


def _run_ref(eng):
    """GEN single-token greedy decode steps -> (B, GEN) tokens."""
    state = _fresh(eng)
    toks = []
    for _ in range(GEN):
        state = eng.backend.prepare_decode(state, None)
        state, _ = eng.executor.decode(eng.sp, state, eng.pa,
                                       state.last_tokens)
        toks.append(np.asarray(state.last_tokens))
    eng.state = state
    return np.stack(toks, 1)


def _run_spec(eng, draft_layers, max_k, adversarial=False):
    """The scheduler's speculation tick protocol, hand-driven: returns
    (tokens (B, GEN), acceptance, ticks).  With ``adversarial`` every
    proposal is replaced by a guaranteed-wrong token, forcing acceptance
    0 (n_commit == 1 on every tick)."""
    vocab = eng.cfg.model.vocab_size
    state = _fresh(eng)
    committed = [[] for _ in range(B)]
    accepted = proposed = ticks = 0
    while min(len(c) for c in committed) < GEN:
        lens = np.asarray(state.cache.lengths)
        headroom = CAP - lens.max(axis=(0, 1))
        depth = np.minimum(max_k, np.maximum(headroom - 1, 0)).astype(
            np.int32)
        if ticks % 2 == 1:  # vary traced depths: must not retrace
            depth = np.minimum(depth, np.maximum(1, max_k - 1))
        ticks += 1
        q_len = depth + 1
        state = eng.backend.prepare_decode(state, None,
                                           n_tokens=int(q_len.max()))
        st, props = eng.executor.propose(eng.sp, state, eng.pa,
                                         jnp.asarray(depth),
                                         draft_layers=draft_layers,
                                         max_k=max_k)
        props = np.asarray(props)
        if adversarial:
            # full-depth drafts propose exactly the greedy continuation,
            # so shifting every lane guarantees a first-position mismatch
            props = (props + 1) % vocab
        tokens = np.concatenate([np.asarray(st.last_tokens)[:, None],
                                 props], axis=1)
        st2, g, n_commit, _ = eng.executor.verify(eng.sp, st, eng.pa,
                                                  jnp.asarray(tokens),
                                                  jnp.asarray(q_len),
                                                  draft_layers=draft_layers)
        st2 = eng.backend.trim_rows(st2, np.arange(B))
        g_np, nc = np.asarray(g), np.asarray(n_commit)
        if adversarial:
            assert (nc == 1).all(), nc  # every proposal rejected
        for b in range(B):
            committed[b].extend(g_np[b, :nc[b]].tolist())
        proposed += int(depth.sum())
        accepted += int((nc - 1).sum())
        state = st2
        eng.state = state
    eng.backend.pool.check_invariants()  # conservation after rollbacks
    return (np.stack([np.array(c[:GEN]) for c in committed]),
            accepted / max(proposed, 1), ticks)


def test_spec_executor_local_parity_and_zero_recompile(params):
    """Full-depth draft (acceptance 1.0) and truncated draft (partial
    acceptance) both reproduce the sequential greedy tokens bit-exactly;
    propose/verify each compile once per (draft_layers, max_k) static key
    and survive varying traced depths AND an online replan uncompiled."""
    eng = Engine.build(_cfg(), params=params)
    ref = _run_ref(eng)
    nL = eng.cfg.model.n_layers

    spec, acc, _ = _run_spec(eng, nL, 3)  # self-check mode: acc = 1.0
    assert np.array_equal(ref, spec)
    assert acc == 1.0
    assert eng.executor.step_traces["propose"] == 1
    assert eng.executor.step_traces["verify"] == 1

    spec, acc, ticks = _run_spec(eng, max(1, nL // 2), 3)  # new static key
    assert np.array_equal(ref, spec)
    assert 0.0 <= acc <= 1.0 and ticks <= GEN
    assert eng.executor.step_traces["propose"] == 2
    assert eng.executor.step_traces["verify"] == 2

    prof = np.asarray(eng.profile)[:, ::-1].copy()
    eng.replan(profile=prof)
    ref2 = _run_ref(eng)
    spec2, acc2, _ = _run_spec(eng, nL, 3)
    assert np.array_equal(ref2, spec2)
    assert acc2 == 1.0
    assert eng.executor.step_traces["propose"] == 2  # cached: no retrace
    assert eng.executor.step_traces["verify"] == 2


def test_spec_executor_acceptance_zero_parity(params):
    """Adversarial wrong proposals: the verify pass must reject the whole
    window every tick (n_commit == 1) yet still commit the exact greedy
    token — speculation at acceptance 0 degrades to single-token decode,
    never to wrong tokens.  Rollback must leave the pool conserved."""
    eng = Engine.build(_cfg(), params=params)
    ref = _run_ref(eng)
    nL = eng.cfg.model.n_layers
    spec, acc, ticks = _run_spec(eng, nL, 3, adversarial=True)
    assert np.array_equal(ref, spec)
    assert acc == 0.0
    assert ticks == GEN  # one committed token per tick


# ---------------------------------------------------------------------------
# executor level: 2x4 host mesh (subprocess so XLA_FLAGS lands pre-import)
# ---------------------------------------------------------------------------

SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, __SRC__)
import json
import numpy as np
import jax, jax.numpy as jnp
from repro.api import (CompressionConfig, Engine, EngineConfig,
                       PagingConfig, PlannerConfig, SchedulerConfig)
from repro.launch.mesh import make_host_mesh

B, T, GEN = 4, 20, 8
CAP = T + GEN + 8

def cfg_for(executor="local"):
    return EngineConfig.smoke(
        "minitron-8b", n_shards=4, max_seq_len=CAP,
        compression=CompressionConfig(policy="none", budget=64,
                                      capacity=CAP, alpha_max=1.0,
                                      obs_window=8, sink=2, decode_margin=8),
        planner=PlannerConfig(mode="fairkv_dp", extra_copies=6, batch_cap=B),
        scheduler=SchedulerConfig(max_rows=B, enable_replan=False),
        cache_backend="paged", paging=PagingConfig(block_size=8),
        executor=executor)

prompts = np.random.default_rng(0).integers(0, 256, (B, T))

def fresh(eng):
    eng.prefill(prompts)
    eng.state = eng.backend.from_prefill(eng.state, eng.pa)
    return eng.state

def run_ref(eng):
    state = fresh(eng)
    toks = []
    for _ in range(GEN):
        state = eng.backend.prepare_decode(state, None)
        state, _ = eng.executor.decode(eng.sp, state, eng.pa,
                                       state.last_tokens)
        toks.append(np.asarray(state.last_tokens))
    eng.state = state
    return np.stack(toks, 1)

def run_spec(eng, draft_layers, max_k):
    state = fresh(eng)
    committed = [[] for _ in range(B)]
    accepted = proposed = ticks = 0
    while min(len(c) for c in committed) < GEN:
        lens = np.asarray(state.cache.lengths)
        headroom = CAP - lens.max(axis=(0, 1))
        depth = np.minimum(max_k, np.maximum(headroom - 1, 0)).astype(
            np.int32)
        if ticks % 2 == 1:
            depth = np.minimum(depth, np.maximum(1, max_k - 1))
        ticks += 1
        q_len = depth + 1
        state = eng.backend.prepare_decode(state, None,
                                           n_tokens=int(q_len.max()))
        st, props = eng.executor.propose(eng.sp, state, eng.pa,
                                         jnp.asarray(depth),
                                         draft_layers=draft_layers,
                                         max_k=max_k)
        tokens = np.concatenate([np.asarray(st.last_tokens)[:, None],
                                 np.asarray(props)], axis=1)
        st2, g, n_commit, _ = eng.executor.verify(eng.sp, st, eng.pa,
                                                  jnp.asarray(tokens),
                                                  jnp.asarray(q_len),
                                                  draft_layers=draft_layers)
        st2 = eng.backend.trim_rows(st2, np.arange(B))
        g_np, nc = np.asarray(g), np.asarray(n_commit)
        for b in range(B):
            committed[b].extend(g_np[b, :nc[b]].tolist())
        proposed += int(depth.sum())
        accepted += int((nc - 1).sum())
        state = st2
        eng.state = state
    eng.backend.pool.check_invariants()
    return (np.stack([np.array(c[:GEN]) for c in committed]),
            accepted / max(proposed, 1))

loc = Engine.build(cfg_for())
ref = run_ref(loc)
nL = loc.cfg.model.n_layers
mesh = make_host_mesh(model=4, data=2)
msh = Engine.build(cfg_for("mesh"), mesh=mesh, params=loc.params)
refm = run_ref(msh)
out = {"mesh_ref_equals_local": bool(np.array_equal(ref, refm))}
spec_f, acc_f = run_spec(msh, nL, 3)
out["full_match"] = bool(np.array_equal(refm, spec_f))
out["full_acc"] = acc_f
spec_p, acc_p = run_spec(msh, max(1, nL // 2), 3)
out["partial_match"] = bool(np.array_equal(refm, spec_p))
out["partial_acc"] = acc_p
out["traces_before_replan"] = dict(msh.executor.step_traces)
msh.replan(profile=np.asarray(msh.profile).copy())
refm2 = run_ref(msh)
spec_r, _ = run_spec(msh, nL, 3)
out["replan_match"] = bool(np.array_equal(refm2, spec_r))
out["traces"] = dict(msh.executor.step_traces)
print(json.dumps(out))
"""


def test_spec_mesh_parity_multidevice_subprocess():
    """Mesh propose/verify: bit-identical to sequential decode on a 2x4
    host mesh at full and partial acceptance, matching the local
    executor's reference, with one compile per static key surviving an
    online replan."""
    import repro
    src = list(repro.__path__)[0].rsplit("/repro", 1)[0]
    code = SUBPROC.replace("__SRC__", repr(src))
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["mesh_ref_equals_local"], res
    assert res["full_match"] and res["full_acc"] == 1.0, res
    assert res["partial_match"], res
    assert res["replan_match"], res
    assert res["traces"]["propose"] == 2, res  # full + partial keys only
    assert res["traces"]["verify"] == 2, res
    assert res["traces"] == res["traces_before_replan"], res


# ---------------------------------------------------------------------------
# scheduler level: Engine.run_trace with speculation on
# ---------------------------------------------------------------------------


def _run_trace(cfg, params, reqs=None):
    eng = Engine.build(cfg, params=params)
    reqs = reqs or synthesize_requests(6, 0.5, 256, min_prompt=8,
                                       max_prompt=20, max_new_tokens=10,
                                       seed=3)
    out = eng.run_trace(reqs, max_steps=400)
    assert out["finished"] == out["total"], out
    toks = {r.req_id: tuple(r.generated) for r in eng.finished_requests}
    return eng, toks, out


def test_spec_scheduler_full_draft_parity_and_metrics(params):
    """Full-depth self-draft through the continuous scheduler: identical
    per-request tokens in strictly fewer decode ticks, acceptance 1.0 in
    the §12 counters, spec_depth gauge and per-request acceptance
    histogram exported, pool conserved, stats() consistent."""
    _, ref, out_ref = _run_trace(_cfg(), params)
    spec = SpeculationConfig(enabled=True, max_k=3)
    eng, toks, out = _run_trace(_cfg(spec=spec), params)
    assert toks == ref
    assert out["steps"] < out_ref["steps"]
    m = eng.scheduler.obs.metrics
    prop = m.counter_value("spec_proposed_total")
    acc = m.counter_value("spec_accepted_total")
    assert prop > 0 and acc == prop  # full-depth draft: all accepted
    snap = eng.metrics()
    assert any(k.startswith("spec_depth") for k in snap)
    assert any(k.startswith("spec_acceptance") for k in snap)
    eng.scheduler.backend.pool.check_invariants()
    st = eng.stats()
    assert st.speculation.enabled and st.speculation.max_k == 3
    assert st.speculation.proposed == int(prop)
    assert st.speculation.acceptance == 1.0


def test_spec_scheduler_partial_draft_parity_and_adaptive_depth(params):
    """A 1-layer draft accepts rarely: tokens still match the plain run
    bit-exactly, per-request accounting stays within bounds, and the
    adaptive controller walks depth down toward min_k."""
    _, ref, _ = _run_trace(_cfg(), params)
    spec = SpeculationConfig(enabled=True, max_k=3, draft_layers=1,
                             min_k=1, low_acceptance=0.4)
    eng, toks, _ = _run_trace(_cfg(spec=spec), params)
    assert toks == ref
    reqs = eng.finished_requests
    assert all(0 <= r.spec_accepted <= r.spec_proposed for r in reqs)
    total_p = sum(r.spec_proposed for r in reqs)
    total_a = sum(r.spec_accepted for r in reqs)
    assert total_a < total_p  # the truncated draft did get rejected
    st = eng.stats()
    assert st.speculation.acceptance == pytest.approx(total_a / total_p)
    eng.scheduler.backend.pool.check_invariants()


def test_spec_scheduler_int8_pool_conservation(params):
    """Reject-rollback over quantized pools: a low-acceptance draft on
    int8 KV must match the plain int8 run token-for-token (scale
    evolution included) and leave zero leaked blocks."""
    _, ref_i8, _ = _run_trace(_cfg(kv_dtype="int8"), params)
    spec = SpeculationConfig(enabled=True, max_k=3, draft_layers=1)
    eng, toks, _ = _run_trace(_cfg(spec=spec, kv_dtype="int8"), params)
    assert toks == ref_i8
    pool = eng.scheduler.backend.pool
    pool.check_invariants()
    assert sum(r.spec_proposed for r in eng.finished_requests) > 0


def test_spec_scheduler_ring_wrap_cow(params):
    """Speculation over shared prefixes with ring-wrap: the donor hits
    capacity (the headroom clamp drops its depth to 0, so no speculative
    window ever contains a ring write) and its ring appends copy-on-write
    out of the registered entry.

    The donor's own post-wrap tokens are ring-phase dependent — the phase
    is the global ``decode_steps`` counter, and speculation reaches
    capacity in fewer ticks than plain decode shifts it (the same
    phase-dependence chunked prefill has, see
    ``test_cow_privatizes_ring_wrap_writes``) — so parity is asserted on
    its below-capacity prefix only.  The proof that CoW kept the shared
    entry intact is the LATE second request: it stays below capacity, so
    its tokens are phase-independent and must match the no-speculation
    engine exactly."""
    vocab = _cfg().model.vocab_size
    rng = np.random.default_rng(7)
    shared = rng.integers(1, vocab, size=48).astype(np.int32)
    sfx = [rng.integers(1, vocab, size=8).astype(np.int32)
           for _ in range(2)]

    def reqs():
        # donor: 56-token prompt, capacity 64 -> wraps after 8 of 24
        return [Request(req_id=0, prompt=np.concatenate([shared, sfx[0]]),
                        arrival_step=0, max_new_tokens=24),
                Request(req_id=1, prompt=np.concatenate([shared, sfx[1]]),
                        arrival_step=40, max_new_tokens=6)]

    def cow_cfg(spec=None):
        return _cfg(spec=spec, rows=3, budget=32, margin=32, max_seq=128,
                    prefix=PrefixConfig(enabled=True, chunk_tokens=16))

    _, ref, _ = _run_trace(cow_cfg(), params, reqs=reqs())
    spec = SpeculationConfig(enabled=True, max_k=3)
    eng, toks, _ = _run_trace(cow_cfg(spec=spec), params, reqs=reqs())
    assert toks[1] == ref[1]  # late sharer: full parity through CoW
    assert toks[0][:9] == ref[0][:9]  # donor parity up to the wrap
    backend = eng.scheduler.backend
    assert backend.cow_copies > 0, "trace never exercised copy-on-write"
    assert not backend._pending_cow
    backend.pool.check_invariants()
