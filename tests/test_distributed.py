"""Distribution layer: sharding rules, param specs, HLO collective parser,
and a subprocess multi-device lowering test (8 fake CPU devices)."""
import json
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.hlo_stats import collective_stats, while_body_stats
from repro.distributed.param_specs import guarded, tree_pspecs
from repro.distributed.sharding import ShardingRules, serve_rules, train_rules
from repro.launch.mesh import make_host_mesh


def _mesh11():
    return make_host_mesh(model=1, data=1)


def test_guarded_divisibility():
    mesh = _mesh11()
    rules = ShardingRules(mesh=mesh, rules={"heads": "model"})
    # 25 heads on a 1-wide axis: divisible, keeps the axis
    assert guarded(rules, 25, "heads") == "model"
    assert guarded(rules, 25, "missing") is None


def test_tree_pspecs_train_layout():
    mesh = _mesh11()
    rules = train_rules(mesh)
    tree = {"layers": [{"w1": jnp.zeros((8, 16)), "ln1": jnp.zeros((8,))}],
            "embed": jnp.zeros((32, 8))}
    specs = tree_pspecs(tree, rules, "train")
    assert specs["layers"][0]["w1"] == P("data", "model")
    assert specs["layers"][0]["ln1"] == P()
    assert specs["embed"] == P("model", "data")


def test_qtensor_specs_follow_parent():
    from repro.serving.quant import quantize_weight
    mesh = _mesh11()
    rules = serve_rules(mesh)
    qt = quantize_weight(jnp.ones((8, 16)), channel_axis=1)
    specs = tree_pspecs({"layers": [{"w1": qt}]}, rules, "serve")
    assert specs["layers"][0]["w1"].q == P(None, "model")
    assert specs["layers"][0]["w1"].scale == P()


def test_collective_parser():
    hlo = textwrap.dedent("""\
    HloModule test
    %body (x: bf16[4,8]) -> bf16[4,8] {
      ROOT %ar = bf16[4,8]{1,0} all-reduce(bf16[4,8] %x), replica_groups={}
    }
    ENTRY %main (a: bf16[16,8]) -> bf16[16,8] {
      %ag = bf16[16,8]{1,0} all-gather(bf16[4,8]{1,0} %a), dimensions={0}
      %rs = f32[2,8]{1,0} reduce-scatter(f32[16,8]{1,0} %x), dimensions={0}
      ROOT %out = bf16[16,8]{1,0} all-reduce(bf16[16,8]{1,0} %ag)
    }
    """)
    stats = collective_stats(hlo)
    assert stats["all-gather"]["count"] == 1
    assert stats["all-gather"]["bytes"] == 16 * 8 * 2
    # 2 all-reduce (body + entry), each 2x bytes
    assert stats["all-reduce"]["count"] == 2
    assert stats["reduce-scatter"]["bytes"] == 2 * 8 * 4
    bodies = while_body_stats(hlo)
    assert "body" in bodies
    assert bodies["body"]["bytes"] == 2 * 4 * 8 * 2


SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, {src!r})
import json
import jax, jax.numpy as jnp
from repro.configs import get_smoke_config, SHAPES
from repro.configs.base import InputShape
from repro.launch.mesh import _axis_type_kwargs
from repro.launch.specs import build_cell

mesh = jax.make_mesh((2, 4), ("data", "model"), **_axis_type_kwargs(2))
cfg = get_smoke_config({arch!r})
shape = InputShape("mini_{kind}", 64, 4, {kind!r})
cell = build_cell(cfg, shape, mesh, quantize=False)
with mesh:
    compiled = jax.jit(cell.fn, donate_argnums=cell.donate_argnums).lower(
        *cell.args).compile()
ma = compiled.memory_analysis()
print(json.dumps({{"ok": True, "args": ma.argument_size_in_bytes}}))
"""


@pytest.mark.parametrize("arch,kind", [
    ("minitron-8b", "decode"),
    ("gemma2-9b", "train"),
    ("qwen3-moe-30b-a3b", "decode"),
    ("mamba2-1.3b", "decode"),
])
def test_multidevice_lowering_subprocess(arch, kind):
    """Lower + compile a reduced cell on an 8-device CPU mesh in a clean
    subprocess (device count must be set before jax import)."""
    import repro
    # repro is a namespace package: __file__ is None, use __path__
    src = list(repro.__path__)[0].rsplit("/repro", 1)[0]
    code = SUBPROC.format(src=src, arch=arch, kind=kind)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["ok"]
