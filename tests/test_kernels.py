"""Pallas kernels: shape/dtype sweeps vs the ref.py pure-jnp oracles
(interpret mode executes the kernel body on CPU)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.fairkv_decode import fairkv_decode_pallas
from repro.kernels.ref import fairkv_decode_ref, snapkv_scores_ref
from repro.kernels.snapkv_select import snapkv_scores_pallas

RNG = np.random.default_rng(0)


def _decode_case(B, S, G, Dh, C, block_c, window=0, cap=0.0,
                 dtype=jnp.float32, empty_rows=False):
    q = jnp.asarray(RNG.normal(size=(B, S, G, Dh)), dtype)
    k = jnp.asarray(RNG.normal(size=(S, B, C, Dh)), dtype)
    v = jnp.asarray(RNG.normal(size=(S, B, C, Dh)), dtype)
    lo = 0 if empty_rows else 1
    lengths = jnp.asarray(RNG.integers(lo, C + 1, size=(S, B)), jnp.int32)
    if empty_rows:
        lengths = lengths.at[0].set(0)  # a fully-empty slot
    kpos = jnp.broadcast_to(jnp.arange(C, dtype=jnp.int32), (S, B, C))
    qpos = jnp.full((B,), C + 7, jnp.int32)
    ref = fairkv_decode_ref(q, k, v, lengths, cap, k_pos=kpos, q_pos=qpos,
                            window=window)
    out = fairkv_decode_pallas(q, k, v, lengths, attn_cap=cap, k_pos=kpos,
                               q_pos=qpos, window=window, block_c=block_c,
                               interpret=True)
    return float(jnp.abs(out.astype(jnp.float32)
                         - ref.astype(jnp.float32)).max())


@pytest.mark.parametrize("B,S,G,Dh,C,block", [
    (4, 8, 8, 64, 256, 128),   # GQA 8:1, qwen-like
    (2, 16, 1, 128, 200, 64),  # MHA, ragged capacity
    (3, 5, 4, 32, 96, 32),     # hymba-ish odd slots
    (1, 16, 8, 128, 1600, 256),  # decode_32k operating point, B=1
    (2, 4, 2, 16, 64, 64),     # single block
])
def test_fairkv_decode_shapes(B, S, G, Dh, C, block):
    assert _decode_case(B, S, G, Dh, C, block) < 1e-5


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-5), (jnp.bfloat16, 0.03)])
def test_fairkv_decode_dtypes(dtype, tol):
    assert _decode_case(4, 8, 8, 64, 256, 128, dtype=dtype) < tol


def test_fairkv_decode_window():
    assert _decode_case(3, 5, 4, 32, 96, 32, window=40) < 1e-5


def test_fairkv_decode_softcap():
    assert _decode_case(2, 4, 8, 64, 256, 128, cap=50.0) < 1e-5


def test_fairkv_decode_empty_rows_zero_output():
    """Unowned rows (len==0) must give exactly 0 — the psum-reassembly
    contract (DESIGN.md §2)."""
    B, S, G, Dh, C = 2, 4, 4, 32, 64
    q = jnp.asarray(RNG.normal(size=(B, S, G, Dh)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(S, B, C, Dh)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(S, B, C, Dh)), jnp.float32)
    lengths = jnp.zeros((S, B), jnp.int32)
    out = fairkv_decode_pallas(q, k, v, lengths, interpret=True)
    assert float(jnp.abs(out).max()) == 0.0


def _scores_case(B, W, Hq, Hkv, Dh, T, block_t, cap=0.0, dtype=jnp.float32):
    q = jnp.asarray(RNG.normal(size=(B, W, Hq, Dh)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, T, Hkv, Dh)), dtype)
    kpos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    opos = jnp.broadcast_to(jnp.arange(T - W, T, dtype=jnp.int32), (B, W))
    ref = snapkv_scores_ref(q, k, opos, kpos, cap)
    out = snapkv_scores_pallas(q, k, opos, kpos, attn_cap=cap,
                               block_t=block_t, interpret=True)
    return float(jnp.abs(out - ref).max())


@pytest.mark.parametrize("B,W,Hq,Hkv,Dh,T,block", [
    (2, 8, 8, 2, 64, 256, 128),
    (1, 4, 4, 4, 32, 100, 32),   # MHA, ragged T
    (2, 16, 8, 8, 64, 128, 128),  # single block
])
def test_snapkv_scores_shapes(B, W, Hq, Hkv, Dh, T, block):
    assert _scores_case(B, W, Hq, Hkv, Dh, T, block) < 1e-5


def test_snapkv_scores_softcap():
    assert _scores_case(2, 8, 8, 2, 64, 256, 64, cap=50.0) < 1e-5


def test_snapkv_scores_mass_conservation():
    """Each query distributes prob mass 1 over its causal prefix, so the
    total importance mass equals W·G per (b, h)."""
    B, W, Hq, Hkv, Dh, T = 2, 8, 8, 2, 32, 96
    q = jnp.asarray(RNG.normal(size=(B, W, Hq, Dh)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, T, Hkv, Dh)), jnp.float32)
    kpos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    opos = jnp.broadcast_to(jnp.arange(T - W, T, dtype=jnp.int32), (B, W))
    out = snapkv_scores_pallas(q, k, opos, kpos, interpret=True)
    mass = np.asarray(out.sum(axis=-1))
    np.testing.assert_allclose(mass, W * (Hq // Hkv), rtol=1e-4)
