"""Continuous-batching scheduler: freelist, admission, retirement, replan
hysteresis, and end-to-end per-row isolation (co-scheduled logits == solo)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compression.base import CompressionConfig
from repro.configs import get_smoke_config
from repro.core import PlannerConfig, build_plan, synthetic_profile
from repro.kernels import ops as K
from repro.models import init_params
from repro.serving import (
    Request,
    RequestState,
    ReplanTrigger,
    RowFreelist,
    Scheduler,
    SchedulerConfig,
)

ARCH = "minitron-8b"


# ---------------------------------------------------------------------------
# freelist
# ---------------------------------------------------------------------------


def test_freelist_lowest_first_and_exhaustion():
    fl = RowFreelist(3)
    assert [fl.acquire() for _ in range(3)] == [0, 1, 2]
    assert fl.acquire() is None
    assert fl.in_use == 3
    fl.release(1)
    fl.release(0)
    assert fl.acquire() == 0  # lowest-index-first after release
    assert fl.acquire() == 1
    assert len(fl) == 0


def test_freelist_rejects_double_free_and_bad_row():
    fl = RowFreelist(2)
    with pytest.raises(ValueError):
        fl.release(0)  # never acquired -> still free
    row = fl.acquire()
    fl.release(row)
    with pytest.raises(ValueError):
        fl.release(row)
    with pytest.raises(ValueError):
        fl.release(7)


# ---------------------------------------------------------------------------
# replan trigger hysteresis
# ---------------------------------------------------------------------------


def test_trigger_requires_full_window_above_threshold():
    tr = ReplanTrigger(window=4, threshold=1.2, cooldown=10)
    for _ in range(20):
        tr.observe(1.1)
    assert not tr.ready(20)  # never above threshold
    for step, imb in enumerate([1.5, 1.5, 1.5], start=21):
        tr.observe(imb)
        assert not tr.ready(step)  # window not yet full of high values
    tr.observe(1.5)
    assert tr.ready(24)


def test_trigger_dip_resets_hysteresis():
    tr = ReplanTrigger(window=3, threshold=1.2, cooldown=0)
    for imb in [1.5, 1.5, 1.1, 1.5, 1.5]:
        tr.observe(imb)
    assert not tr.ready(5)  # the dip is still inside the window
    tr.observe(1.5)
    assert tr.ready(6)


def test_trigger_cooldown_blocks_refire():
    tr = ReplanTrigger(window=2, threshold=1.2, cooldown=5)
    tr.observe(1.5)
    tr.observe(1.5)
    assert tr.ready(10)
    tr.fire(10)
    for step in range(11, 15):
        tr.observe(1.5)
        assert not tr.ready(step)  # window refills but cooldown holds
    tr.observe(1.5)
    assert tr.ready(15)


# ---------------------------------------------------------------------------
# scheduler fixtures
# ---------------------------------------------------------------------------


def _setup(max_rows=2, mode="fairkv_dp", ch=4, **scfg_kw):
    cfg = get_smoke_config(ARCH)
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32,
                         max_seq_len=64)
    ccfg = CompressionConfig(policy="ada_snapkv", budget=12, alpha_max=2.0,
                             obs_window=8, sink=2, decode_margin=8)
    prof = synthetic_profile(cfg.n_layers, cfg.n_kv_heads, budget=12,
                             skew=1.0, seed=1)
    pcfg = PlannerConfig(mode=mode, extra_copies=ch, batch_cap=max_rows)
    plan = build_plan(prof, 4, pcfg)
    scfg = SchedulerConfig(max_rows=max_rows, collect_logits=True, **scfg_kw)
    sched = Scheduler(cfg, params, plan, ccfg, scfg, planner_cfg=pcfg)
    return cfg, sched


def _req(req_id, T, arrival=0, gen=4, seed=0, vocab=256):
    rng = np.random.default_rng(seed + 100 * req_id)
    prompt = rng.integers(0, vocab, size=T).astype(np.int32)
    return Request(req_id=req_id, prompt=prompt, arrival_step=arrival,
                   max_new_tokens=gen)


# ---------------------------------------------------------------------------
# admission
# ---------------------------------------------------------------------------


def test_admission_blocks_on_full_batch_then_reuses_freed_row():
    cfg, sched = _setup(max_rows=2, enable_replan=False)
    reqs = [_req(i, 14, gen=3, vocab=cfg.vocab_size) for i in range(3)]
    for r in reqs:
        sched.submit(r)
    ev = sched.step()
    assert sorted(row for _, row in ev["admitted"]) == [0, 1]
    assert reqs[2].state is RequestState.QUEUED
    assert not sched.admissible(reqs[2])  # no free rows
    # run until a row frees; the queued request must land in it
    for _ in range(8):
        ev = sched.step()
        if reqs[2].state is not RequestState.QUEUED:
            break
    assert reqs[2].row in (0, 1) or reqs[2].is_finished
    assert reqs[2].admit_step > reqs[0].admit_step


def test_admission_rejects_impossible_token_budget():
    cfg, sched = _setup(max_rows=2, enable_replan=False,
                        max_live_tokens=1)  # absurdly small budget
    r = _req(0, 14, vocab=cfg.vocab_size)
    # the request could never fit -> fail fast instead of head-of-line block
    with pytest.raises(ValueError, match="never be admitted"):
        sched.submit(r)


def test_admission_respects_token_budget():
    cfg, probe = _setup(max_rows=2, enable_replan=False)
    a = _req(0, 14, gen=5, vocab=cfg.vocab_size)
    b = _req(1, 14, gen=8, vocab=cfg.vocab_size)
    # budget fits one request (the larger of the two) but not both at once
    budget = probe._estimated_cost(b) + 1
    _, sched = _setup(max_rows=2, enable_replan=False,
                      max_live_tokens=budget)
    sched.submit(a)
    sched.submit(b)
    sched.step()
    # free rows exist, but the projected total exceeds the budget -> b waits
    assert a.state is RequestState.DECODING
    assert b.state is RequestState.QUEUED
    assert len(sched.freelist) == 1
    while not b.is_finished:
        sched.step()
    assert b.admit_step >= a.finish_step  # admitted only after a freed tokens


def test_admission_projection_uses_policy_pool_bound():
    """Audit regression (PR-3): the projected live-token cost must come
    from the per-policy keep bounds (pool conservation), not the static
    capacity C — the old ``L·H·min(prompt+gen, C)`` charge blocked
    admissions the cache could easily hold.  The tighter bound must remain
    a true upper bound on the realized footprint."""
    cfg, probe = _setup(max_rows=2, enable_replan=False)
    prompt, gen = 30, 4
    a = _req(0, prompt, gen=gen, vocab=cfg.vocab_size)
    cap = probe.ccfg.static_capacity()
    old_cost = cfg.n_layers * cfg.n_kv_heads * min(prompt + gen, cap)
    new_cost = probe._estimated_cost(a)
    assert new_cost < old_cost, (new_cost, old_cost)

    # validity: the realized footprint of a full solo run never exceeds
    # the projection (otherwise the tighter bound would overcommit)
    probe.submit(a)
    live_max = 0
    while not a.is_finished:
        probe.step()
        live_max = max(live_max, probe.live_tokens())
    live_a_prefill = None  # prefill-only footprint for the budget below
    assert live_max <= new_cost, (live_max, new_cost)

    # behavior: a budget the old projection would refuse now admits two
    # requests concurrently
    _, m = _setup(max_rows=2, enable_replan=False)
    a1 = _req(0, prompt, gen=gen, vocab=cfg.vocab_size)
    m.submit(a1)
    m.step()
    live_a_prefill = m.live_tokens()
    budget = live_a_prefill + new_cost
    assert budget < live_a_prefill + old_cost  # old rule: b would wait
    _, sched = _setup(max_rows=2, enable_replan=False,
                      max_live_tokens=budget)
    a2 = _req(0, prompt, gen=gen, vocab=cfg.vocab_size)
    b2 = _req(1, prompt, gen=gen, vocab=cfg.vocab_size)
    sched.submit(a2)
    sched.submit(b2)
    sched.step()
    assert a2.state is RequestState.DECODING
    assert b2.state is RequestState.DECODING  # co-admitted under the budget


# ---------------------------------------------------------------------------
# retirement
# ---------------------------------------------------------------------------


def test_retired_row_is_zero_and_decode_output_exactly_zero():
    cfg, sched = _setup(max_rows=2, enable_replan=False)
    a = _req(0, 14, gen=2, vocab=cfg.vocab_size)
    b = _req(1, 18, gen=8, vocab=cfg.vocab_size)
    sched.submit(a)
    sched.submit(b)
    while not a.is_finished:
        sched.step()
    assert a.state is RequestState.FINISHED
    assert not b.is_finished  # b still decoding on its row
    row = 0  # a was admitted first -> row 0
    cache = sched.state.cache
    lens = np.asarray(cache.lengths)
    assert lens[:, :, row].sum() == 0
    assert (np.asarray(cache.positions)[row] == 0)
    # the decode kernel's output for the retired row is exactly zero
    S = cache.k.shape[1]
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(2, S, cfg.q_per_kv, cfg.head_dim)),
                    jnp.float32)
    out = K.fairkv_decode(q, cache.k[0], cache.v[0], cache.lengths[0],
                          k_pos=cache.pos[0],
                          q_pos=jnp.zeros((2,), jnp.int32))
    assert float(jnp.abs(out[row]).max()) == 0.0
    assert sched.freelist.in_use == 1  # the row went back to the freelist


# ---------------------------------------------------------------------------
# end-to-end stream + per-row isolation
# ---------------------------------------------------------------------------


def _run_stream(sched, reqs, max_steps=200):
    out = sched.run(reqs, max_steps=max_steps)
    assert out["finished"] == out["total"], out
    return out


def test_stream_all_finish_with_mid_stream_admissions():
    cfg, sched = _setup(max_rows=2, enable_replan=False)
    reqs = [_req(0, 14, arrival=0, gen=4, vocab=cfg.vocab_size),
            _req(1, 18, arrival=0, gen=5, vocab=cfg.vocab_size),
            _req(2, 12, arrival=1, gen=4, vocab=cfg.vocab_size),
            _req(3, 16, arrival=2, gen=3, vocab=cfg.vocab_size)]
    out = _run_stream(sched, reqs)
    assert out["mid_stream_admissions"] >= 1
    assert all(r.is_finished for r in reqs)
    assert all(r.n_generated == r.max_new_tokens for r in reqs)
    # the batch never held more rows than configured
    assert sched.freelist.n_rows == 2


def test_co_scheduled_logits_match_solo_run():
    """Per-row isolation: a request decoded alongside others produces the
    same tokens and (near-)identical logits as the same request run alone."""
    cfg, sched = _setup(max_rows=2, enable_replan=False)
    reqs = [_req(0, 14, arrival=0, gen=4, vocab=cfg.vocab_size),
            _req(1, 18, arrival=0, gen=5, vocab=cfg.vocab_size),
            _req(2, 12, arrival=1, gen=4, vocab=cfg.vocab_size)]
    _run_stream(sched, reqs)

    for shared in reqs:
        _, solo_sched = _setup(max_rows=2, enable_replan=False)
        solo = Request(req_id=shared.req_id, prompt=shared.prompt,
                       arrival_step=0,
                       max_new_tokens=shared.max_new_tokens)
        _run_stream(solo_sched, [solo])
        assert solo.generated == shared.generated, shared.req_id
        for lg_solo, lg_shared in zip(solo.logits, shared.logits):
            np.testing.assert_allclose(lg_solo, lg_shared, atol=2e-4)


def test_attention_free_arch_streams():
    """SSM models (no slot cache) ride the same lifecycle: state splicing
    covers ssm/conv rows and the load metrics degrade gracefully."""
    cfg = get_smoke_config("mamba2-1.3b")
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32,
                         max_seq_len=64)
    ccfg = CompressionConfig(policy="ada_snapkv", budget=12, obs_window=8,
                             sink=2, decode_margin=8)
    plan = build_plan(np.ones((cfg.n_layers, 1)), 1,
                      PlannerConfig(mode="sha", slots_per_shard=1))
    sched = Scheduler(cfg, params, plan, ccfg,
                      SchedulerConfig(max_rows=2))
    reqs = [_req(0, 12, arrival=0, gen=3, vocab=cfg.vocab_size),
            _req(1, 14, arrival=0, gen=4, vocab=cfg.vocab_size),
            _req(2, 12, arrival=2, gen=3, vocab=cfg.vocab_size)]
    out = _run_stream(sched, reqs)
    assert out["mid_stream_admissions"] >= 1
    assert sched.live_tokens() == 0 and sched.imbalance() == 1.0


def test_stream_with_online_replan_matches_no_replan():
    """Replanning is a layout change, not a math change: an aggressive
    replan schedule must not alter the generated tokens."""
    cfg, sched_plain = _setup(max_rows=2, enable_replan=False)
    mk = lambda: [_req(0, 14, arrival=0, gen=6, vocab=cfg.vocab_size),
                  _req(1, 18, arrival=0, gen=8, vocab=cfg.vocab_size),
                  _req(2, 12, arrival=2, gen=6, vocab=cfg.vocab_size)]
    plain = mk()
    _run_stream(sched_plain, plain)

    _, sched_replan = _setup(max_rows=2, replan_window=2,
                             replan_threshold=1.01, replan_cooldown=2,
                             replan_min_rows=1)
    replanned = mk()
    _run_stream(sched_replan, replanned)
    assert len(sched_replan.replan_log) >= 1  # trigger actually exercised
    for a, b in zip(plain, replanned):
        assert a.generated == b.generated, a.req_id
