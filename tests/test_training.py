"""Training substrate: optimizer math, checkpoint/restart bit-exactness,
elastic resharding, straggler detection, gradient compression."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_smoke_config
from repro.configs.base import InputShape
from repro.models import init_params
from repro.training import (
    OptimizerConfig,
    StragglerDetector,
    SyntheticLM,
    init_optimizer,
    latest_step,
    lr_schedule,
    make_train_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.training.grad_compress import (
    compress_tree,
    decompress_tree,
    ef_compress_leaf,
    init_error_state,
)


def test_lr_schedule_shape():
    cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_ratio=0.1)
    lrs = [float(lr_schedule(cfg, jnp.int32(s))) for s in [0, 5, 10, 50, 100]]
    assert lrs[0] < lrs[1] < lrs[2]  # warmup
    assert lrs[2] == pytest.approx(1.0, rel=1e-3)
    assert lrs[2] > lrs[3] > lrs[4]  # cosine decay
    assert lrs[4] == pytest.approx(0.1, rel=1e-2)


def _train_setup(arch="granite-3-2b"):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    opt = init_optimizer(params)
    ocfg = OptimizerConfig(lr=1e-3, warmup_steps=2, total_steps=50)
    step = jax.jit(make_train_step(cfg, ocfg, remat=True))
    data = SyntheticLM(cfg, InputShape("t", 24, 2, "train"))
    return params, opt, step, data


def test_checkpoint_restart_bit_exact():
    params, opt, step, data = _train_setup()
    for s in range(3):
        params, opt, _ = step(params, opt, data.get_batch(s))
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 3, {"params": params, "opt": opt})
        assert latest_step(d) == 3
        restored = restore_checkpoint(d, 3, {"params": params, "opt": opt})
        pa, oa, _ = step(params, opt, data.get_batch(3))
        pb, ob, _ = step(restored["params"], restored["opt"], data.get_batch(3))
        for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_prune_and_latest():
    params, opt, step, data = _train_setup()
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3, 4):
            save_checkpoint(d, s, {"p": jnp.zeros(3)}, keep=2)
        assert latest_step(d) == 4
        kept = sorted(x for x in os.listdir(d) if x.startswith("step_"))
        assert len(kept) == 2


def test_checkpoint_shape_mismatch_raises():
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, {"p": jnp.zeros((3,))})
        with pytest.raises(ValueError):
            restore_checkpoint(d, 1, {"p": jnp.zeros((4,))})


def test_deterministic_data_replay():
    cfg = get_smoke_config("minitron-8b")
    d1 = SyntheticLM(cfg, InputShape("t", 32, 4, "train"))
    d2 = SyntheticLM(cfg, InputShape("t", 32, 4, "train"))
    for s in (0, 7, 123):
        np.testing.assert_array_equal(np.asarray(d1.get_batch(s)["tokens"]),
                                      np.asarray(d2.get_batch(s)["tokens"]))
    assert not np.array_equal(np.asarray(d1.get_batch(0)["tokens"]),
                              np.asarray(d1.get_batch(1)["tokens"]))


def test_straggler_detector():
    det = StragglerDetector(n_shards=4, min_samples=3, threshold=1.3)
    for _ in range(2):
        assert det.observe(np.array([1.0, 1.0, 1.0, 1.0])) is None
    out = det.observe(np.array([1.0, 1.0, 1.0, 1.0]))
    assert out is None  # uniform: no straggler
    for _ in range(10):
        out = det.observe(np.array([1.0, 1.0, 1.0, 2.5]))
    assert out is not None
    assert out[3] < 0.6  # slow shard speed factor
    assert out[0] == pytest.approx(1.0, abs=0.05)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**32 - 1))
def test_int8_ef_quant_bounded_error(seed):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=(64,)) * rng.uniform(0.1, 10), jnp.float32)
    q, scale, err = ef_compress_leaf(g, jnp.zeros_like(g))
    deq = q.astype(jnp.float32) * scale
    # per-element error bounded by half a quantization step
    assert float(jnp.abs(deq + err - g).max()) < 1e-5
    assert float(jnp.abs(err).max()) <= float(scale) * 0.5 + 1e-6


def test_error_feedback_accumulates():
    """With EF, the *running sum* of dequantized grads tracks the true sum."""
    rng = np.random.default_rng(0)
    true_sum = np.zeros(32)
    deq_sum = np.zeros(32)
    err = init_error_state({"g": jnp.zeros(32)})
    for _ in range(50):
        g = rng.normal(size=32).astype(np.float32) * 0.01
        true_sum += g
        q, s, err = compress_tree({"g": jnp.asarray(g)}, err)
        deq_sum += np.asarray(decompress_tree(q, s)["g"])
    resid = np.abs(deq_sum - true_sum).max()
    assert resid < 0.01 * 0.5 + 1e-4  # bounded by one step's quant error
