"""Per-arch smoke tests: reduced configs, one forward + one train step on
CPU, asserting output shapes and finiteness (assignment requirement f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config, get_smoke_config
from repro.models import forward_train, init_params
from repro.training import OptimizerConfig, init_optimizer, make_train_step
from repro.training.data import SyntheticLM
from repro.configs.base import InputShape


def _batch(cfg, B=2, S=24, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if cfg.is_vlm:
        batch["image_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.num_image_tokens, cfg.d_model)) * 0.1,
            jnp.float32)
    if cfg.is_encoder_decoder:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq_len, cfg.d_model)) * 0.1,
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32,
                         max_seq_len=64)
    batch = _batch(cfg)
    logits, aux = forward_train(params, batch, cfg, remat=False)
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_reduces_loss(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32,
                         max_seq_len=64)
    opt = init_optimizer(params)
    ocfg = OptimizerConfig(lr=2e-3, warmup_steps=1, total_steps=10,
                           weight_decay=0.0)
    step = jax.jit(make_train_step(cfg, ocfg, remat=True))
    data = SyntheticLM(cfg, InputShape("smoke", 24, 2, "train"))
    first = last = None
    for s in range(4):
        params, opt, m = step(params, opt, data.get_batch(0))  # same batch
        loss = float(m["loss"])
        assert np.isfinite(loss)
        first = first if first is not None else loss
        last = loss
    assert last < first, (first, last)


def test_full_configs_match_assignment_table():
    """Exact structural parameters from the assignment."""
    spec = {
        "qwen1.5-110b": (80, 8192, 64, 8, 49152, 152064),
        "minitron-8b": (32, 4096, 32, 8, 16384, 256000),
        "gemma2-9b": (42, 3584, 16, 8, 14336, 256000),
        "granite-3-2b": (40, 2048, 32, 8, 8192, 49155),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
        "llava-next-34b": (60, 7168, 56, 8, 20480, 64000),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "mamba2-1.3b": (48, 2048, 0, 0, 0, 50280),
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
    }
    for arch, (L, D, Hq, Hkv, FF, V) in spec.items():
        cfg = get_config(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab_size) == (L, D, Hq, Hkv, FF, V), arch
    assert get_config("granite-moe-1b-a400m").moe.num_experts == 32
    assert get_config("granite-moe-1b-a400m").moe.top_k == 8
    assert get_config("qwen3-moe-30b-a3b").moe.num_experts == 128
    assert get_config("qwen3-moe-30b-a3b").moe.top_k == 8
    assert get_config("mamba2-1.3b").ssm.state_size == 128
    assert get_config("hymba-1.5b").ssm.state_size == 16
    assert get_config("qwen1.5-110b").qkv_bias
    assert get_config("gemma2-9b").logit_softcap > 0


def test_ssd_chunked_matches_sequential():
    from repro.models.ssm import ssd_chunked, ssd_decode_step
    rng = np.random.default_rng(0)
    B, T, H, P, G, N = 2, 48, 4, 8, 1, 16
    x = jnp.asarray(rng.normal(size=(B, T, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(B, T, H)), jnp.float32)
    A_log = jnp.asarray(rng.normal(size=(H,)) * 0.5, jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, T, G, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(B, T, G, N)), jnp.float32)
    D = jnp.asarray(rng.normal(size=(H,)), jnp.float32)
    y16, S16 = ssd_chunked(x, dt, A_log, Bm, Cm, D, chunk=16)
    # decode steps replay the same recurrence
    S = jnp.zeros((B, H, P, N))
    outs = []
    for t in range(T):
        y1, S = ssd_decode_step(x[:, t], dt[:, t], A_log, Bm[:, t], Cm[:, t],
                                D, S)
        outs.append(y1)
    ydec = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(y16), np.asarray(ydec), atol=2e-5)
    np.testing.assert_allclose(np.asarray(S16), np.asarray(S), atol=2e-5)


def test_flash_attention_matches_dense():
    from repro.models.layers import dense_attention, flash_attention
    rng = np.random.default_rng(0)
    B, Q, Hq, Hkv, Dh, K = 2, 16, 4, 2, 32, 300
    q = jnp.asarray(rng.normal(size=(B, Q, Hq, Dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, K, Hkv, Dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, K, Hkv, Dh)), jnp.float32)
    qp = jnp.broadcast_to(jnp.arange(K - Q, K), (B, Q))
    kp = jnp.broadcast_to(jnp.arange(K), (B, K))
    for window, cap in [(0, 0.0), (64, 0.0), (0, 30.0), (17, 50.0)]:
        d = dense_attention(q, k, v, qp, kp, window=window, attn_cap=cap)
        f = flash_attention(q, k, v, qp, kp, window=window, attn_cap=cap,
                            chunk=64)
        np.testing.assert_allclose(np.asarray(d), np.asarray(f), atol=2e-5)
