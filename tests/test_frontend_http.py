"""HTTP front end integration (DESIGN.md §13): an in-process
`FrontendServer` on an ephemeral port, driven by a raw asyncio client —
JSON generate, SSE stream ordering against `Engine.stream` ground truth,
the /metrics tenant-label contract, input validation, and drain refusal."""
import asyncio
import json

import numpy as np
import pytest

from repro.api import (
    CompressionConfig,
    Engine,
    EngineConfig,
    PlannerConfig,
    Request,
    SchedulerConfig,
)
from repro.frontend import FrontendConfig, FrontendServer

ARCH = "minitron-8b"
PROMPT = [5, 17, 42, 99, 7, 123, 56, 201, 11, 88]
GEN = 6


def _cfg(rows=2):
    return EngineConfig.smoke(
        ARCH, n_shards=4, max_seq_len=48,
        compression=CompressionConfig(policy="ada_snapkv", budget=12,
                                      alpha_max=2.0, obs_window=8, sink=2,
                                      decode_margin=8),
        planner=PlannerConfig(mode="fairkv_dp", extra_copies=4,
                              batch_cap=rows),
        scheduler=SchedulerConfig(max_rows=rows, enable_replan=False))


@pytest.fixture(scope="module")
def shared_params():
    cfg = _cfg()
    return cfg, Engine.build(cfg).params


# ---------------------------------------------------------------------------
# raw asyncio HTTP client (the server is stdlib-only; so is the test)
# ---------------------------------------------------------------------------


async def _request(host, port, method, path, payload=None, raw_body=None):
    """One HTTP/1.1 exchange; returns (status, headers, body bytes).  The
    server replies ``Connection: close``, so the body is read to EOF."""
    reader, writer = await asyncio.open_connection(host, port)
    body = (raw_body if raw_body is not None
            else b"" if payload is None else json.dumps(payload).encode())
    writer.write(
        (f"{method} {path} HTTP/1.1\r\nHost: t\r\n"
         f"Content-Type: application/json\r\n"
         f"Content-Length: {len(body)}\r\n\r\n").encode() + body)
    await writer.drain()
    status = int((await reader.readline()).split()[1])
    headers = {}
    while True:
        h = await reader.readline()
        if h in (b"\r\n", b"\n", b""):
            break
        k, _, v = h.decode("latin-1").partition(":")
        headers[k.strip().lower()] = v.strip()
    data = await reader.read()
    writer.close()
    try:
        await writer.wait_closed()
    except ConnectionError:
        pass
    return status, headers, data


def _parse_sse(raw: bytes):
    """[(event_type, payload_dict), ...] from an SSE byte stream."""
    events = []
    for block in raw.decode().strip().split("\n\n"):
        lines = block.split("\n")
        assert lines[0].startswith("event: "), lines
        assert lines[1].startswith("data: "), lines
        events.append((lines[0][len("event: "):],
                       json.loads(lines[1][len("data: "):])))
    return events


async def _with_server(engine, body, **cfg_kw):
    """Start a server on an ephemeral port, run ``body(server)``, always
    shut down (drain + stop the engine thread)."""
    cfg_kw.setdefault("port", 0)
    server = FrontendServer(engine, FrontendConfig(**cfg_kw))
    await server.start()
    try:
        return await body(server)
    finally:
        await server.shutdown(drain=True)


# ---------------------------------------------------------------------------
# tests
# ---------------------------------------------------------------------------


def test_http_generate_then_stream_matches_engine_stream(shared_params):
    """The SSE route must deliver exactly the `Engine.stream` event order:
    token events with contiguous indices, ``finished`` on the last token,
    one ``end`` event after it — and the same tokens a fresh engine
    produces for the same prompt (decode is deterministic argmax)."""
    cfg, params = shared_params
    # ground truth from the plain streaming iterator on its own engine
    ref_eng = Engine.build(cfg, params=params)
    ref_events = list(ref_eng.stream(
        [Request(req_id=0, prompt=np.asarray(PROMPT, np.int32),
                 max_new_tokens=GEN)]))
    ref_tokens = [e.token for e in ref_events]
    assert [e.index for e in ref_events] == list(range(len(ref_tokens)))
    assert [e.finished for e in ref_events[:-1]] == [False] * (
        len(ref_events) - 1) and ref_events[-1].finished

    async def body(server):
        payload = {"prompt": PROMPT, "max_new_tokens": GEN,
                   "tenant": "acme", "priority": 0}
        status, _, data = await asyncio.wait_for(
            _request(server.host, server.port, "POST", "/v1/generate",
                     payload), timeout=120)
        assert status == 200
        out = json.loads(data)
        assert out["state"] == "finished"
        assert out["tokens"] == ref_tokens
        assert out["tenant"] == "acme" and out["priority"] == 0

        status, headers, raw = await asyncio.wait_for(
            _request(server.host, server.port, "POST", "/v1/stream",
                     payload), timeout=120)
        assert status == 200
        assert headers["content-type"].startswith("text/event-stream")
        events = _parse_sse(raw)
        kinds = [k for k, _ in events]
        assert kinds == ["token"] * len(ref_tokens) + ["end"]
        tokens = [ev for k, ev in events if k == "token"]
        assert [ev["token"] for ev in tokens] == ref_tokens
        assert [ev["index"] for ev in tokens] == list(range(len(ref_tokens)))
        assert [ev["finished"] for ev in tokens[:-1]] == [False] * (
            len(tokens) - 1) and tokens[-1]["finished"]
        end = events[-1][1]
        assert end["state"] == "finished" and end["tokens"] == ref_tokens

        # the §13 observability contract over the same engine's registry
        status, headers, prom = await asyncio.wait_for(
            _request(server.host, server.port, "GET", "/metrics"),
            timeout=30)
        assert status == 200
        text = prom.decode()
        for family in ("slo_attained_total", "goodput_tokens_total",
                       "frontend_ttft_steps_bucket",
                       "frontend_admission_total"):
            assert f"{family}{{" in text, family
        assert 'tenant="acme"' in text

        status, _, health = await _request(
            server.host, server.port, "GET", "/healthz")
        assert status == 200 and json.loads(health)["status"] == "ok"

    asyncio.run(_with_server(Engine.build(cfg, params=params), body))


def test_http_validation_and_routing(shared_params):
    cfg, params = shared_params

    async def body(server):
        h, p = server.host, server.port
        status, _, data = await _request(h, p, "POST", "/v1/generate",
                                         raw_body=b"{not json")
        assert status == 400 and b"invalid JSON" in data
        status, _, data = await _request(h, p, "POST", "/v1/generate",
                                         {"prompt": []})
        assert status == 400 and b"prompt" in data
        status, _, data = await _request(h, p, "POST", "/v1/generate",
                                         {"prompt": [1, -2]})
        assert status == 400
        status, _, data = await _request(
            h, p, "POST", "/v1/generate",
            {"prompt": [1, 2, 3], "max_new_tokens": 0})
        assert status == 400 and b"max_new_tokens" in data
        status, _, data = await _request(
            h, p, "POST", "/v1/generate", {"prompt": [1] * 9})
        assert status == 400 and b"too long" in data  # max_prompt_tokens
        status, _, _ = await _request(h, p, "GET", "/nope")
        assert status == 404
        status, _, _ = await _request(h, p, "GET", "/v1/generate")
        assert status == 405

    asyncio.run(_with_server(Engine.build(cfg, params=params), body,
                             max_prompt_tokens=8))


def test_engine_loop_emits_end_for_terminal_at_submit(shared_params):
    """A request rejected synchronously at submit (here: loop already
    draining and idle) must still deliver its ``end`` event — the emission
    sweep runs on inbox absorption, not only after a pump tick
    (regression: the awaiting handler hung forever)."""
    import queue as pyqueue

    from repro.frontend.bridge import EngineLoop

    cfg, params = shared_params
    loop = EngineLoop(Engine.build(cfg, params=params)).start()
    try:
        assert loop.drain(timeout=30.0)  # loop now idles in the sleep branch
        out = pyqueue.SimpleQueue()
        loop.submit(PROMPT, max_new_tokens=2, deliver=out.put)
        ev = out.get(timeout=10.0)
        assert ev["type"] == "end"
        assert ev["state"] == "cancelled" and ev["reason"] == "draining"
        assert ev["tokens"] == [] and ev["n_generated"] == 0
    finally:
        loop.stop()


def test_http_drain_refuses_new_work(shared_params):
    cfg, params = shared_params

    async def body(server):
        loop = asyncio.get_running_loop()
        drained = await loop.run_in_executor(
            None, server.engine_loop.drain, 30.0)
        assert drained
        status, _, data = await _request(
            server.host, server.port, "POST", "/v1/generate",
            {"prompt": PROMPT, "max_new_tokens": 2})
        assert status == 503 and b"draining" in data
        status, _, health = await _request(
            server.host, server.port, "GET", "/healthz")
        assert status == 200
        assert json.loads(health)["status"] == "draining"

    asyncio.run(_with_server(Engine.build(cfg, params=params), body))
