"""Best-effort assignment (paper Technique I).

The optimization target is Eq. 4: minimize the max per-shard load
``max_j Σ_i x_ij · w_i / r_i`` — weighted multiway number partitioning
(makespan scheduling), NP-hard.  Three primitives, composable:

- ``greedy_lpt``      — Longest-Processing-Time first; 4/3-approx, O(n log n).
- ``local_search``    — move/swap refinement of any assignment.
- ``backtracking``    — the paper's Algorithm 1 (recursive backtracking over
                        partitions), upgraded to branch-and-bound: LPT gives the
                        incumbent, partial-max + remaining-lower-bound prunes,
                        and a node budget keeps worst-case time bounded.

All primitives accept ``shard_speeds`` (relative speed per shard; default
1.0) — the straggler-mitigation extension: load_j is divided by speed_j so
slower shards receive proportionally less work (DESIGN.md §6).

**Engines** (the ``engine=`` strings of ``assign_items`` /
``PlannerConfig.engine``) are registered through
``repro.api.register_assignment_engine`` — the old string if/elif is gone,
so third-party solvers plug in without touching this file.  The engine
contract::

    @register_assignment_engine("my_solver")
    def my_solver(weights, n_shards, slots_per_shard, *, shard_speeds=None,
                  item_group=None, initial_load=None,
                  node_budget=200_000) -> List[List[int]]: ...

Built-ins: ``greedy`` (LPT + feasibility fallback + local search),
``backtracking`` (greedy incumbent + branch-and-bound; **rejects**
``item_group`` — the search does not implement replica distinct-shard
exclusion), ``auto`` (backtracking when replica-free, greedy otherwise).
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.api.registry import (
    ASSIGNMENT_ENGINE_REGISTRY,
    register_assignment_engine,
)


def _loads_ok(items_per_shard: Sequence[int], cap: int) -> bool:
    return all(n <= cap for n in items_per_shard)


def greedy_lpt(
    weights: Sequence[float],
    n_shards: int,
    slots_per_shard: int,
    shard_speeds: Optional[Sequence[float]] = None,
    item_group: Optional[Sequence[int]] = None,
    initial_load: Optional[Sequence[float]] = None,
) -> List[List[int]]:
    """LPT with slot-capacity and distinct-shard-per-group constraints.

    ``weights[i]`` is the *effective* weight of item i (already divided by its
    replication factor).  ``item_group[i]`` (e.g. head id) — two items of the
    same group (replicas of one head) never share a shard.
    Returns per-shard item lists.
    """
    speeds = np.ones(n_shards) if shard_speeds is None else np.asarray(shard_speeds, float)
    order = np.argsort(-np.asarray(weights, float), kind="stable")
    assign: List[List[int]] = [[] for _ in range(n_shards)]
    groups: List[set] = [set() for _ in range(n_shards)]
    load = (np.zeros(n_shards) if initial_load is None
            else np.asarray(initial_load, float).copy())
    for i in order:
        i = int(i)
        g = item_group[i] if item_group is not None else None
        best_j, best_t = -1, np.inf
        for j in range(n_shards):
            if len(assign[j]) >= slots_per_shard:
                continue
            if g is not None and g in groups[j]:
                continue
            t = (load[j] + weights[i]) / speeds[j]
            if t < best_t:
                best_t, best_j = t, j
        if best_j < 0:
            raise ValueError(
                f"item {i} cannot be placed (capacity/group constraints exhausted)")
        assign[best_j].append(i)
        if g is not None:
            groups[best_j].add(g)
        load[best_j] += weights[i]
    return assign


def local_search(
    assign: List[List[int]],
    weights: Sequence[float],
    n_shards: int,
    slots_per_shard: int,
    shard_speeds: Optional[Sequence[float]] = None,
    item_group: Optional[Sequence[int]] = None,
    initial_load: Optional[Sequence[float]] = None,
    max_rounds: int = 64,
) -> List[List[int]]:
    """Move/swap refinement.  ``item_group[i]`` (e.g. head id) constrains moves
    so two items of the same group never share a shard."""
    speeds = np.ones(n_shards) if shard_speeds is None else np.asarray(shard_speeds, float)
    w = np.asarray(weights, float)
    base = (np.zeros(n_shards) if initial_load is None
            else np.asarray(initial_load, float))
    assign = [list(a) for a in assign]

    def shard_time(j):
        return (base[j] + sum(w[i] for i in assign[j])) / speeds[j]

    def group_conflict(i, j):
        if item_group is None:
            return False
        g = item_group[i]
        return any(item_group[k] == g for k in assign[j])

    for _ in range(max_rounds):
        times = np.array([shard_time(j) for j in range(n_shards)])
        src = int(times.argmax())
        improved = False
        # try moving an item off the bottleneck shard
        for i in sorted(assign[src], key=lambda i: -w[i]):
            for dst in np.argsort(times):
                dst = int(dst)
                if dst == src or len(assign[dst]) >= slots_per_shard:
                    continue
                if group_conflict(i, dst):
                    continue
                new_src = times[src] - w[i] / speeds[src]
                new_dst = times[dst] + w[i] / speeds[dst]
                if max(new_src, new_dst) < times[src] - 1e-12:
                    assign[src].remove(i)
                    assign[dst].append(i)
                    improved = True
                    break
            if improved:
                break
        if improved:
            continue
        # try swapping bottleneck item with a lighter one elsewhere
        swapped = False
        for i in sorted(assign[src], key=lambda i: -w[i]):
            for dst in np.argsort(times):
                dst = int(dst)
                if dst == src:
                    continue
                for k in assign[dst]:
                    if w[k] >= w[i]:
                        continue
                    if item_group is not None and (
                        any(item_group[x] == item_group[i] for x in assign[dst] if x != k)
                        or any(item_group[x] == item_group[k] for x in assign[src] if x != i)
                    ):
                        continue
                    new_src = times[src] + (w[k] - w[i]) / speeds[src]
                    new_dst = times[dst] + (w[i] - w[k]) / speeds[dst]
                    if max(new_src, new_dst) < times[src] - 1e-12:
                        assign[src].remove(i)
                        assign[dst].remove(k)
                        assign[src].append(k)
                        assign[dst].append(i)
                        swapped = True
                        break
                if swapped:
                    break
            if swapped:
                break
        if not swapped:
            break
    return assign


def backtracking(
    weights: Sequence[float],
    n_shards: int,
    slots_per_shard: int,
    shard_speeds: Optional[Sequence[float]] = None,
    incumbent: Optional[List[List[int]]] = None,
    initial_load: Optional[Sequence[float]] = None,
    node_budget: int = 200_000,
) -> Tuple[List[List[int]], float]:
    """Paper Algorithm 1 — recursive backtracking over head→shard partitions,
    as branch-and-bound.

    Items are placed in weight-descending order; a branch is cut when its
    partial makespan already meets the incumbent.  Shard-symmetry is broken by
    only allowing an item into at most one currently-empty shard.
    Returns (assignment, makespan).
    """
    w = np.asarray(weights, float)
    speeds = np.ones(n_shards) if shard_speeds is None else np.asarray(shard_speeds, float)
    order = np.argsort(-w, kind="stable")
    sorted_w = w[order]
    suffix_sum = np.concatenate([np.cumsum(sorted_w[::-1])[::-1], [0.0]])
    total_speed = speeds.sum()

    base = (np.zeros(n_shards) if initial_load is None
            else np.asarray(initial_load, float))
    if incumbent is None:
        incumbent = greedy_lpt(list(w), n_shards, slots_per_shard, shard_speeds,
                               initial_load=base)
    best_assign = [list(a) for a in incumbent]

    def makespan_of(a):
        return max(
            ((base[j] + sum(w[i] for i in a[j])) / speeds[j]) for j in range(n_shards))

    best = makespan_of(best_assign)
    load = base.copy()
    counts = np.zeros(n_shards, dtype=int)
    cur: List[List[int]] = [[] for _ in range(n_shards)]
    nodes = 0

    def rec(k: int) -> None:
        nonlocal best, best_assign, nodes
        nodes += 1
        if nodes > node_budget:
            return
        if k == len(order):
            ms = max(load[j] / speeds[j] for j in range(n_shards))
            if ms < best - 1e-12:
                best = ms
                best_assign = [list(a) for a in cur]
            return
        # lower bound: even a perfect spread of the remaining weight cannot
        # beat the incumbent
        lb = max(
            max(load[j] / speeds[j] for j in range(n_shards)),
            (load.sum() + suffix_sum[k]) / total_speed,
        )
        if lb >= best - 1e-12:
            return
        i = int(order[k])
        seen_empty_loads = set()
        cands = sorted(range(n_shards), key=lambda j: load[j] / speeds[j])
        for j in cands:
            if counts[j] >= slots_per_shard:
                continue
            if counts[j] == 0:
                key = round(float(load[j]), 9)
                if key in seen_empty_loads:
                    continue  # symmetry: empty shards with equal carry-in load
                seen_empty_loads.add(key)
            if (load[j] + w[i]) / speeds[j] >= best - 1e-12:
                continue
            load[j] += w[i]
            counts[j] += 1
            cur[j].append(i)
            rec(k + 1)
            cur[j].pop()
            counts[j] -= 1
            load[j] -= w[i]

    if len(order) * 1.0 <= n_shards * slots_per_shard:
        rec(0)
    return best_assign, best


def _greedy_refined(
    weights: Sequence[float],
    n_shards: int,
    slots_per_shard: int,
    shard_speeds: Optional[Sequence[float]] = None,
    item_group: Optional[Sequence[int]] = None,
    initial_load: Optional[Sequence[float]] = None,
) -> List[List[int]]:
    """LPT (with feasibility fallback for replica sets) + local search."""
    try:
        assign = greedy_lpt(weights, n_shards, slots_per_shard, shard_speeds,
                            item_group, initial_load)
    except ValueError:
        # weight-ordered LPT can strand a replica (its remaining shards are
        # full).  Feasibility-first: place heads with the most replicas
        # first (Hall's condition then guarantees a slot), refine after.
        assert item_group is not None
        from collections import Counter
        gcount = Counter(item_group)
        order = sorted(range(len(weights)),
                       key=lambda i: (-gcount[item_group[i]], -weights[i]))
        assign = [[] for _ in range(n_shards)]
        groups = [set() for _ in range(n_shards)]
        load = (np.zeros(n_shards) if initial_load is None
                else np.asarray(initial_load, float).copy())
        speeds = (np.ones(n_shards) if shard_speeds is None
                  else np.asarray(shard_speeds, float))
        for i in order:
            g = item_group[i]
            cands = [j for j in range(n_shards)
                     if len(assign[j]) < slots_per_shard and g not in groups[j]]
            if not cands:
                raise ValueError(
                    f"replica set infeasible: item {i} group {g}")
            j = min(cands, key=lambda j: (load[j] + weights[i]) / speeds[j])
            assign[j].append(i)
            groups[j].add(g)
            load[j] += weights[i]
    return local_search(assign, weights, n_shards, slots_per_shard,
                        shard_speeds, item_group, initial_load)


@register_assignment_engine("greedy")
def _engine_greedy(
    weights: Sequence[float],
    n_shards: int,
    slots_per_shard: int,
    *,
    shard_speeds: Optional[Sequence[float]] = None,
    item_group: Optional[Sequence[int]] = None,
    initial_load: Optional[Sequence[float]] = None,
    node_budget: int = 200_000,
) -> List[List[int]]:
    """LPT + local search; supports replica groups."""
    return _greedy_refined(weights, n_shards, slots_per_shard, shard_speeds,
                           item_group, initial_load)


@register_assignment_engine("backtracking")
def _engine_backtracking(
    weights: Sequence[float],
    n_shards: int,
    slots_per_shard: int,
    *,
    shard_speeds: Optional[Sequence[float]] = None,
    item_group: Optional[Sequence[int]] = None,
    initial_load: Optional[Sequence[float]] = None,
    node_budget: int = 200_000,
) -> List[List[int]]:
    """Branch-and-bound over a greedy incumbent; replica-free inputs only.

    ``item_group`` is rejected rather than silently downgraded to greedy
    (the historical behavior): the branch-and-bound search does not enforce
    the replicas-on-distinct-shards constraint, so honoring the request
    would return an invalid plan and ignoring it would lie about the engine
    that actually ran.
    """
    if item_group is not None:
        raise ValueError(
            "engine='backtracking' does not support replica groups "
            "(item_group): the branch-and-bound search cannot enforce the "
            "distinct-shard-per-head constraint.  Use engine='greedy', or "
            "engine='auto' to select the best supported engine "
            "automatically.")
    incumbent = _greedy_refined(weights, n_shards, slots_per_shard,
                                shard_speeds, None, initial_load)
    bt, _ = backtracking(weights, n_shards, slots_per_shard, shard_speeds,
                         incumbent=incumbent, initial_load=initial_load,
                         node_budget=node_budget)
    return bt


@register_assignment_engine("auto")
def _engine_auto(
    weights: Sequence[float],
    n_shards: int,
    slots_per_shard: int,
    *,
    shard_speeds: Optional[Sequence[float]] = None,
    item_group: Optional[Sequence[int]] = None,
    initial_load: Optional[Sequence[float]] = None,
    node_budget: int = 200_000,
) -> List[List[int]]:
    """Strongest supported engine: branch-and-bound when replica-free,
    greedy + local search otherwise."""
    if item_group is None:
        return _engine_backtracking(
            weights, n_shards, slots_per_shard, shard_speeds=shard_speeds,
            initial_load=initial_load, node_budget=node_budget)
    return _greedy_refined(weights, n_shards, slots_per_shard, shard_speeds,
                           item_group, initial_load)


def assign_items(
    weights: Sequence[float],
    n_shards: int,
    slots_per_shard: int,
    engine: str = "auto",
    shard_speeds: Optional[Sequence[float]] = None,
    item_group: Optional[Sequence[int]] = None,
    initial_load: Optional[Sequence[float]] = None,
    node_budget: int = 200_000,
) -> List[List[int]]:
    """Front door: dispatch to a registered assignment engine by name.

    Unknown names raise ``KeyError`` listing the registered engines (the
    same list ``repro.api.list_engines`` feeds into config validation).
    """
    fn = ASSIGNMENT_ENGINE_REGISTRY[engine]
    return fn(weights, n_shards, slots_per_shard, shard_speeds=shard_speeds,
              item_group=item_group, initial_load=initial_load,
              node_budget=node_budget)
