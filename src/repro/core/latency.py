"""Latency models (paper §3.2, Fig. 1).

The paper fits decode latency as linear in batch size B (``L ≈ αB + β``) and
in retained KV budget C (``L ≈ γC + δ``).  Both are cross-sections of one
bilinear surface — attention-decode work is Σ over (row, head) of retained
length, plus fixed overheads — so we fit

    t(B, C) = a + b·B + c·C + d·B·C

by least squares (``LinearLatencyModel.fit``).  ``RooflineLatencyModel`` is
the analytic v5e counterpart used when no measurements exist: decode is
HBM-bound, t = bytes/bw with bytes = weights_per_shard + Σ len·head_dim·2·dtype.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence, Tuple

import numpy as np

# TPU v5e constants (per the assignment spec)
PEAK_FLOPS_BF16 = 197e12  # FLOP/s per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link


@dataclass
class LinearLatencyModel:
    """t(B, C) = a + b·B + c·C + d·B·C   (microseconds)."""

    a: float
    b: float
    c: float
    d: float

    def latency(self, batch: float, budget: float) -> float:
        return self.a + self.b * batch + self.c * budget + self.d * batch * budget

    def shard_latency(self, per_row_lengths: np.ndarray) -> float:
        """Latency of one shard given the retained lengths it owns.

        ``per_row_lengths``: array of (owned row, slot) retained lengths.  The
        B·C term becomes Σ lengths; the B term counts owned rows once.
        """
        total_len = float(per_row_lengths.sum())
        n_rows = float((per_row_lengths > 0).sum())
        return self.a + self.b * n_rows + self.d * total_len + self.c * (
            per_row_lengths.max(initial=0.0))

    @staticmethod
    def fit(samples: Sequence[Tuple[float, float, float]]) -> "LinearLatencyModel":
        """samples: (batch, budget, measured_latency)."""
        A = np.array([[1.0, B, C, B * C] for B, C, _ in samples])
        y = np.array([t for _, _, t in samples])
        coef, *_ = np.linalg.lstsq(A, y, rcond=None)
        return LinearLatencyModel(*map(float, coef))

    def r2(self, samples: Sequence[Tuple[float, float, float]]) -> float:
        y = np.array([t for _, _, t in samples])
        pred = np.array([self.latency(B, C) for B, C, _ in samples])
        ss_res = float(((y - pred) ** 2).sum())
        ss_tot = float(((y - y.mean()) ** 2).sum())
        return 1.0 - ss_res / ss_tot if ss_tot > 0 else 1.0


@dataclass
class RooflineLatencyModel:
    """Analytic v5e decode-attention latency: HBM-bound KV reads + fixed part.

    fixed_bytes: per-shard per-step bytes independent of KV load (weight reads,
    activations).  kv_bytes_per_token: head_dim · 2(K,V) · dtype_bytes.
    """

    fixed_bytes: float
    kv_bytes_per_token: float
    hbm_bw: float = HBM_BW

    def shard_latency(self, total_retained_tokens: float) -> float:
        return (self.fixed_bytes + self.kv_bytes_per_token * total_retained_tokens) / self.hbm_bw


def decode_attention_flops(batch: int, lengths_sum: float, head_dim: int,
                           q_per_kv: int) -> float:
    """FLOPs of decode attention given Σ retained lengths (per shard)."""
    # qk^T and p·v, per query head in the group
    return 4.0 * q_per_kv * head_dim * lengths_sum


def decode_attention_bytes(lengths_sum: float, head_dim: int,
                           dtype_bytes: int = 2) -> float:
    """HBM bytes for KV reads at decode (per shard)."""
    return 2.0 * head_dim * dtype_bytes * lengths_sum
