"""Head-workload profiles (paper §3.1, Table 1).

A profile is the (L, H) matrix of expected retained-KV lengths per head under
an imbalanced compression policy.  The paper measures it once per model on a
sample dataset and shows (Table 1) it transfers across datasets
(cosine ≥ 0.94), so the planner can be static.

Here profiles come from two sources:
- ``measure_profile``: run a compression policy over sample batches and average
  the realized per-head lengths — the faithful workflow.
- ``synthetic_profile``: head-skew generators (lognormal / zipf / dirichlet)
  matched to the qualitative shape reported for Ada-SnapKV — used by unit
  tests and by benchmarks that sweep skew levels.
"""
from __future__ import annotations


import numpy as np


def synthetic_profile(
    n_layers: int,
    n_heads: int,
    budget: int,
    skew: float = 1.0,
    kind: str = "lognormal",
    seed: int = 0,
    layer_decay: float = 0.0,
) -> np.ndarray:
    """(L, H) expected retained lengths; per-layer mean == budget.

    ``skew``: 0 → perfectly balanced; larger → heavier per-head imbalance
    (σ of the lognormal / zipf exponent).  ``layer_decay``: PyramidKV-style
    per-layer budget decay (0 = flat).
    """
    rng = np.random.default_rng(seed)
    if kind == "lognormal":
        raw = rng.lognormal(mean=0.0, sigma=skew, size=(n_layers, n_heads))
    elif kind == "zipf":
        ranks = np.argsort(np.argsort(-rng.random((n_layers, n_heads)), axis=1), axis=1) + 1
        raw = 1.0 / ranks ** skew
    elif kind == "dirichlet":
        raw = rng.dirichlet(np.full(n_heads, max(1e-3, 1.0 / max(skew, 1e-6))),
                            size=n_layers)
    else:
        raise ValueError(f"unknown kind {kind!r}")
    # normalize so each layer's head-mean equals the budget (Ada-SnapKV keeps
    # the layer-total pool fixed at H·budget and redistributes it)
    raw = raw / raw.mean(axis=1, keepdims=True)
    prof = raw * budget
    if layer_decay > 0:
        scale = np.linspace(1.0 + layer_decay, 1.0 - layer_decay, n_layers)
        scale = np.clip(scale, 0.05, None)
        prof = prof * scale[:, None]
        prof = prof / prof.mean() * budget
    return np.maximum(prof, 1.0)


def profile_from_lengths(lengths: np.ndarray) -> np.ndarray:
    """(L, H, B) realized lengths → (L, H) profile (mean over batch rows)."""
    arr = np.asarray(lengths, dtype=np.float64)
    if arr.ndim != 3:
        raise ValueError("expected (L, H, B) lengths")
    return arr.mean(axis=-1)


def profile_from_samples(samples: np.ndarray) -> np.ndarray:
    """(n_samples, L, H) per-sample profiles → (L, H) averaged profile."""
    arr = np.asarray(samples, dtype=np.float64)
    if arr.ndim != 3:
        raise ValueError("expected (n_samples, L, H)")
    return arr.mean(axis=0)


def cosine_similarity(a: np.ndarray, b: np.ndarray) -> float:
    """Table 1 metric: cosine of two flattened (L, H) profiles."""
    a = np.asarray(a, float).ravel()
    b = np.asarray(b, float).ravel()
    denom = np.linalg.norm(a) * np.linalg.norm(b)
    return float(a @ b / denom) if denom > 0 else 1.0
