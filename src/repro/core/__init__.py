"""FairKV core: the paper's contribution as a composable library.

Workflow (paper §4.1):  compression policy → per-head length statistics
(`profiles`) → best-effort assignment + fair-copying (`planner`) →
`HeadPlacement` plan → consumed by the serving runtime (weight permutation +
slot-layout KV cache) and by the efficiency/throughput simulators.
"""
from repro.core.assignment import assign_items, backtracking, greedy_lpt, local_search  # noqa: F401
from repro.core.efficiency import SimResult, simulate, utilization_from_loads  # noqa: F401
from repro.core.latency import (  # noqa: F401
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS_BF16,
    LinearLatencyModel,
    RooflineLatencyModel,
)
from repro.core.placement import HeadPlacement, LayerPlacement, layer_from_assignment  # noqa: F401
from repro.core.planner import (  # noqa: F401
    PLANNER_MODES,
    PlannerConfig,
    build_plan,
    plan_kv_dtypes,
    plan_layer,
    replan_for_stragglers,
)
from repro.core.profiles import (  # noqa: F401
    cosine_similarity,
    profile_from_lengths,
    profile_from_samples,
    synthetic_profile,
)
