"""FairKV planner: profile → (best-effort assignment + fair-copying) → plan.

Modes (paper Fig. 2 / Fig. 4 ablation arms):

- ``sha``          Static Head Allocation — heads spread uniformly, replicas
                   (when shards > heads, the GQA base case) split the batch
                   uniformly.  The paper's baseline.
- ``fairkv_nodp``  Best-effort assignment only (Technique I): load-aware
                   placement, no replication beyond the forced base.
- ``fairkv_dp``    + Fair-copying (Technique II): up to ``extra_copies`` (the
                   paper's CH parameter) additional replicas of the heaviest
                   heads, each replica taking ``w/r`` load (Eq. 4), subject to
                   ``R_max`` (Eq. 3) and the slot capacity.

The planner works per layer (paper §4.3: heads are rearranged *across layers*
independently — each layer's head set is partitioned on the same shard grid).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional, Sequence

import numpy as np

from repro.core.assignment import assign_items
from repro.core.placement import HeadPlacement, LayerPlacement, layer_from_assignment


# planner modes (paper Fig. 2 arms) — the list EngineConfig validates against
PLANNER_MODES = ("sha", "fairkv_nodp", "fairkv_dp")


@dataclass(frozen=True)
class PlannerConfig:
    mode: str = "fairkv_dp"  # one of PLANNER_MODES
    extra_copies: int = 4  # CH, paper Fig. 5
    r_max: Optional[int] = None  # Eq. 3 cap; default = n_shards
    slots_per_shard: Optional[int] = None  # default: ceil-based minimum
    engine: str = "auto"  # assignment engine
    fill_empty_slots: bool = True  # use spare slots for free replicas
    # replicas split the batch, so r can never usefully exceed it (a replica
    # owning zero rows idles its slot); set to the serving batch size
    batch_cap: Optional[int] = None
    node_budget: int = 20_000  # branch-and-bound nodes per layer


def _min_slots(n_heads: int, n_shards: int) -> int:
    return max(1, math.ceil(n_heads / n_shards))


def _sha_layer(n_heads: int, n_shards: int, slots_per_shard: int,
               fill: bool = True, r_cap: Optional[int] = None) -> List[List[int]]:
    """Uniform static allocation.  With shards > heads each head gets
    floor/ceil(n_slots/H) replicas laid out contiguously — the standard GQA
    replication pattern (e.g. 8 kv heads on 16 shards -> every head on 2
    consecutive shards)."""
    n_slots = n_shards * slots_per_shard
    if n_heads > n_slots:
        raise ValueError("not enough slots for heads")
    # uniform base replication (the GQA fill); fill=False keeps one replica
    # per head (the paper's single-copy SHA baseline)
    reps = n_slots // n_heads if fill else 1
    if r_cap is not None:
        reps = min(reps, r_cap)
    if reps > n_shards:
        raise ValueError(
            f"uniform replication {reps} exceeds shard count {n_shards}")
    assign: List[List[int]] = [[] for _ in range(n_shards)]
    # replica k of the flattened list goes to shard k % n_shards, so replicas
    # of one head always land on distinct shards
    for k in range(n_heads * reps):
        assign[k % n_shards].append(k // reps)
    return assign


def plan_layer(
    weights: np.ndarray,
    n_shards: int,
    cfg: PlannerConfig,
    shard_speeds: Optional[Sequence[float]] = None,
    initial_load: Optional[np.ndarray] = None,
) -> LayerPlacement:
    """Plan one layer given the cumulative per-shard load of earlier layers.

    Eq. 4 minimizes the max of the *total* (summed over layers) shard load, so
    each layer is placed against the carry-in ``initial_load`` — the paper's
    "rearrange attention heads across layers".
    ``weights[h]`` = expected per-head workload.
    """
    n_heads = int(weights.shape[0])
    slots_per_shard = cfg.slots_per_shard or _min_slots(n_heads, n_shards)
    n_slots = n_shards * slots_per_shard
    r_max = cfg.r_max or n_shards

    r_hard = min(r_max, n_shards, cfg.batch_cap or n_shards)

    if cfg.mode == "sha":
        assign = _sha_layer(n_heads, n_shards, slots_per_shard,
                            fill=cfg.fill_empty_slots, r_cap=r_hard)
        return layer_from_assignment(assign, n_shards, slots_per_shard)

    if cfg.mode not in PLANNER_MODES:
        raise ValueError(
            f"unknown planner mode {cfg.mode!r}; known: {list(PLANNER_MODES)}")

    # ---- choose replica counts ----------------------------------------------
    # Base: uniform replication filling the slot grid (identical to SHA's
    # replica budget — when shards > heads this is the forced GQA fill; when
    # heads >= slots it is r == 1).  NoDP keeps the base; DP redistributes /
    # extends it with up to ``extra_copies`` (CH) load-aware copies.
    base = max(1, n_slots // n_heads) if cfg.fill_empty_slots else 1
    base = min(base, r_hard)
    reps = np.full(n_heads, base, dtype=int)
    r_cap = r_hard
    if cfg.mode == "fairkv_dp":
        reps = _water_fill_replicas(weights, reps, n_slots, r_cap,
                                    cfg.extra_copies)

    # ---- assign replicas as items -------------------------------------------
    items_head: List[int] = []
    for h in range(n_heads):
        items_head.extend([h] * int(reps[h]))
    item_w = [float(weights[h]) / int(reps[h]) for h in items_head]

    # replicas of a head must land on distinct shards (item_group constraint);
    # branch-and-bound only runs for the replica-free case.
    any_reps = any(r > 1 for r in reps)
    assign = assign_items(
        item_w, n_shards, slots_per_shard,
        engine=cfg.engine,
        shard_speeds=shard_speeds,
        item_group=items_head if any_reps else None,
        initial_load=initial_load,
        node_budget=cfg.node_budget,
    )
    head_assign = [[items_head[i] for i in shard] for shard in assign]
    return layer_from_assignment(head_assign, n_shards, slots_per_shard)


def _water_fill_replicas(weights: np.ndarray, base: np.ndarray, n_slots: int,
                         r_cap: int, ch: int) -> np.ndarray:
    """Fair-copying replica counts (Technique II).

    Minimize ``max_h w_h / r_h`` by (a) adding replicas of the heaviest heads
    into spare slots, then (b) moving replicas from the lightest to the
    heaviest heads — spending at most ``ch`` copy operations total (the
    paper's CH knob), keeping Σ r == n_slots capacity and r ≤ r_cap (Eq. 3).
    """
    w = np.asarray(weights, float)
    reps = base.copy()
    moves = 0

    def hottest():
        per = np.where(reps < r_cap, w / reps, -np.inf)
        h = int(per.argmax())
        return h if np.isfinite(per[h]) else -1

    # (a) pure additions into spare slots
    spare = n_slots - int(reps.sum())
    while spare > 0 and moves < ch:
        h = hottest()
        if h < 0:
            break
        reps[h] += 1
        spare -= 1
        moves += 1

    # (b) redistribution: take one replica from the coldest donor, give to the
    # hottest head, while it strictly reduces the max per-replica load
    while moves < ch:
        per = w / reps
        cur_max = float(per.max())
        rec = hottest()
        if rec < 0:
            break
        donors = [h for h in range(len(w)) if reps[h] > 1 and h != rec]
        if not donors:
            break
        donor = min(donors, key=lambda h: w[h] / (reps[h] - 1))
        new_donor = w[donor] / (reps[donor] - 1)
        new_rec = w[rec] / (reps[rec] + 1)
        others = np.delete(per, [donor, rec])
        new_max = max(new_donor, new_rec, float(others.max(initial=0.0)))
        if new_max >= cur_max - 1e-12:
            break
        reps[donor] -= 1
        reps[rec] += 1
        moves += 1
    return reps


def build_plan(
    profile: np.ndarray,
    n_shards: int,
    cfg: Optional[PlannerConfig] = None,
    shard_speeds: Optional[Sequence[float]] = None,
) -> HeadPlacement:
    """Plan all layers.  ``profile`` is (L, H) expected per-head workload."""
    cfg = cfg or PlannerConfig()
    profile = np.asarray(profile, dtype=np.float64)
    if profile.ndim != 2:
        raise ValueError("profile must be (n_layers, n_heads)")
    n_layers, n_heads = profile.shape
    slots_per_shard = cfg.slots_per_shard or _min_slots(n_heads, n_shards)
    cfg = PlannerConfig(**{**cfg.__dict__, "slots_per_shard": slots_per_shard})
    layers = []
    carry = np.zeros(n_shards, dtype=np.float64)
    for li in range(n_layers):
        lp = plan_layer(profile[li], n_shards, cfg, shard_speeds,
                        initial_load=None if cfg.mode == "sha" else carry)
        carry += lp.per_shard_load(profile[li], n_shards, slots_per_shard)
        layers.append(lp)
    plan = HeadPlacement(
        layers=tuple(layers), n_heads=n_heads, n_shards=n_shards,
        slots_per_shard=slots_per_shard, mode=cfg.mode,
        r_max=cfg.r_max or n_shards)
    plan.validate()
    return plan


def replan_for_stragglers(
    profile: np.ndarray,
    plan: HeadPlacement,
    shard_speeds: Sequence[float],
    cfg: Optional[PlannerConfig] = None,
) -> HeadPlacement:
    """Straggler mitigation: rebuild the plan with per-shard speed factors so a
    slow shard receives proportionally less KV load (DESIGN.md §6)."""
    cfg = cfg or PlannerConfig(mode=plan.mode,
                               slots_per_shard=plan.slots_per_shard,
                               r_max=plan.r_max)
    return build_plan(profile, plan.n_shards, cfg, shard_speeds)


def plan_kv_dtypes(
    profile: np.ndarray,
    base: str = "int8",
    low_dtype: str = "fp8",
    low_fraction: float = 0.5,
) -> tuple:
    """Per-head KV storage format as an allocatable budget axis (§15).

    Quantized pools give every head the same bytes per token; what the
    planner can still allocate is *fidelity*.  Int8 codes spend their 8
    bits on one block-wide scale (fine uniform steps — lower error for the
    amplitude-stable distributions of heavily-attended heads), while fp8
    (e4m3) spends bits on exponent (graceful under outliers, coarser
    steps).  This helper turns the same (L, H) expected-workload profile
    the placement planner consumes into the `PagingConfig.kv_dtype_overrides`
    tuple: per layer, the coldest ``low_fraction`` of heads — the ones
    whose retained KV contributes least attention mass — are stored as
    ``low_dtype`` while the hot heads keep ``base``.

    Returns the canonical sorted ``((layer, head, dtype), ...)`` tuple
    (empty when ``low_fraction`` rounds to zero heads or the two formats
    are equal), ready to pass to `PagingConfig`.
    """
    from repro.paging.kvquant import QUANT_DTYPES

    for name, dt in (("base", base), ("low_dtype", low_dtype)):
        if dt not in QUANT_DTYPES:
            raise ValueError(
                f"{name} must be one of {list(QUANT_DTYPES)}, got {dt!r}")
    if not 0.0 <= low_fraction <= 1.0:
        raise ValueError(
            f"low_fraction must be in [0, 1], got {low_fraction}")
    profile = np.asarray(profile, dtype=np.float64)
    if profile.ndim != 2:
        raise ValueError("profile must be (n_layers, n_heads)")
    n_layers, n_heads = profile.shape
    n_low = int(low_fraction * n_heads)
    if base == low_dtype or n_low == 0:
        return ()
    overrides = []
    for li in range(n_layers):
        # stable sort: ties resolve to lower head ids, deterministically
        cold = np.argsort(profile[li], kind="stable")[:n_low]
        overrides.extend((li, int(h), low_dtype) for h in cold)
    return tuple(sorted(overrides))


def draft_plan(plan, n_layers: int):
    """Head placement for the layer-truncated draft model (DESIGN.md §16).

    Self-speculative decoding's draft is the target's first ``n_layers``
    blocks, so its placement *rides* the target plan: the draft plan is
    literally the leading per-layer slice of the target's — same slot grid,
    same replica/owner rule, no separate planning pass — and every target
    replan re-plans the draft for free (the propose step re-slices whatever
    plan the executor holds).  Accepts the planning-time `HeadPlacement`
    or any runtime plan container whose fields are (L, ...)-leading stacked
    arrays (e.g. ``cache.slot_cache.PlanArrays``); returns the same type.
    """
    import dataclasses

    if isinstance(plan, HeadPlacement):
        if not 0 < n_layers <= plan.n_layers:
            raise ValueError(
                f"draft n_layers must be in [1, {plan.n_layers}], "
                f"got {n_layers}")
        return dataclasses.replace(plan, layers=plan.layers[:n_layers])
    return dataclasses.replace(plan, **{
        f.name: getattr(plan, f.name)[:n_layers]
        for f in dataclasses.fields(plan)})
