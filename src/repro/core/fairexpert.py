"""FairExpert — the paper's §6 future work: FairKV-style balancing for MoE.

Expert load under top-k routing is skewed exactly like per-head KV budgets
(hot experts receive many times the mean token count).  The same machinery
applies verbatim with (expert ↔ head, token count ↔ retained length):

- *best-effort assignment*: place experts on shards against the measured
  routing distribution instead of round-robin;
- *fair-copying*: replicate hot experts; replicas split the token stream
  (capacity is per-replica, so a 2-replica expert serves 2× tokens without
  drops — this is the EPLB idea, derived here from the paper's Eq. 4).

``plan_experts`` returns a HeadPlacement over experts (slot = expert copy on
a shard); ``expert_dispatch_stats`` turns router probabilities into the
workload profile; ``simulate_expert_balance`` measures the max/mean token
load per shard for SHA vs FairExpert — the MoE analog of Table 2 / Fig. 4.
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro.core.placement import HeadPlacement
from repro.core.planner import PlannerConfig, build_plan


def expert_dispatch_stats(router_probs: np.ndarray, top_k: int) -> np.ndarray:
    """(T, E) router probabilities → (E,) expected token load (top-k greedy)."""
    T, E = router_probs.shape
    idx = np.argsort(-router_probs, axis=1)[:, :top_k]
    counts = np.bincount(idx.reshape(-1), minlength=E)
    return counts.astype(np.float64)


def plan_experts(load: np.ndarray, n_shards: int, mode: str = "fairkv_dp",
                 extra_copies: int = 4,
                 slots_per_shard: Optional[int] = None) -> HeadPlacement:
    """Plan expert placement from a measured (E,) load profile."""
    E = load.shape[0]
    slots = slots_per_shard or max(1, -(-E // n_shards))
    return build_plan(load[None, :], n_shards, PlannerConfig(
        mode=mode, extra_copies=extra_copies, slots_per_shard=slots,
        fill_empty_slots=E < n_shards * slots))


def simulate_expert_balance(router_probs: np.ndarray, top_k: int,
                            n_shards: int, extra_copies: int = 4
                            ) -> Dict[str, float]:
    """Per-shard token-load balance E (Eq. 5) for SHA vs FairExpert plans."""
    load = expert_dispatch_stats(router_probs, top_k)
    out = {}
    for mode in ("sha", "fairkv_nodp", "fairkv_dp"):
        plan = plan_experts(load, n_shards, mode=mode,
                            extra_copies=extra_copies)
        out[mode] = plan.efficiency(load[None, :])
    return out
