"""System-efficiency metric (Eq. 5) and throughput simulation.

Given a plan, per-(layer, head, row) retained lengths, and a latency model,
simulate the per-shard decode time and derive:

- utilization  E = mean_j t_j / max_j t_j   (Eq. 5 — "GPU utilization" in the
  paper's Tables/Figures is exactly this quantity),
- throughput ∝ batch / max_j t_j,
- the per-shard load vector itself (for plots / debugging).

This is the measurement harness behind benchmarks/table2, fig3, fig4, fig5.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.latency import LinearLatencyModel
from repro.core.placement import HeadPlacement


@dataclass(frozen=True)
class SimResult:
    per_shard_time: np.ndarray  # (n_shards,)
    utilization: float  # Eq. 5
    throughput: float  # rows per unit time
    makespan: float

    def gain_over(self, other: "SimResult") -> float:
        return self.throughput / other.throughput


def owned_mask(replica_idx: int, replica_count: int, batch: int) -> np.ndarray:
    """Strided batch ownership: replica i owns rows where b % r == i."""
    rows = np.arange(batch)
    return (rows % replica_count) == replica_idx


def simulate(
    plan: HeadPlacement,
    lengths: np.ndarray,
    model: LinearLatencyModel,
    uniform_overhead: float = 0.0,
) -> SimResult:
    """Simulate one decode step.

    ``lengths``: (L, H, B) retained KV length per layer/head/batch-row — the
    *actual* compression outcome (not just the profile means).
    ``uniform_overhead``: per-shard latency of the load-independent part
    (q/o projections, FFN, collectives) added to every shard.
    """
    L, H, B = lengths.shape
    assert L == plan.n_layers and H == plan.n_heads
    S = plan.slots_per_shard
    times = np.zeros(plan.n_shards)
    for j in range(plan.n_shards):
        total_len = 0.0
        n_rows = 0.0
        for li, lp in enumerate(plan.layers):
            for s in range(S):
                slot = j * S + s
                h = int(lp.slot_head[slot])
                if h < 0:
                    continue
                mask = owned_mask(int(lp.replica_idx[slot]),
                                  int(lp.replica_count[slot]), B)
                owned = lengths[li, h, mask]
                total_len += float(owned.sum())
                n_rows += float(mask.sum())
        # bilinear model over the shard's aggregate load
        times[j] = (model.a + model.b * (n_rows / max(L, 1))
                    + model.d * total_len) + uniform_overhead
    makespan = float(times.max())
    util = float(times.mean() / makespan) if makespan > 0 else 1.0
    return SimResult(per_shard_time=times, utilization=util,
                     throughput=B / makespan if makespan > 0 else np.inf,
                     makespan=makespan)


def utilization_from_loads(loads: np.ndarray) -> float:
    mx = loads.max()
    return float(loads.mean() / mx) if mx > 0 else 1.0
