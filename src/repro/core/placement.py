"""Head-placement plan: the artifact FairKV produces and the runtime consumes.

A *slot* is one (kv-head replica) position on one model shard.  Every model
shard owns exactly ``slots_per_shard`` slots so the SPMD program is uniform;
an empty slot has ``head == -1`` and carries zero retained length, i.e. ~zero
work inside the decode kernel.

Replicas of one head split the batch by a strided ownership rule
(``global_row % replica_count == replica_idx``) so the split stays balanced
within every data-axis shard (DESIGN.md §2).  For global_batch == 1
(long-context decode) replicas split the retained-KV range instead — the same
arrays describe both, the runtime chooses the split dimension.
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import numpy as np


@dataclass(frozen=True)
class LayerPlacement:
    """Slot layout of one layer.  Arrays have shape (n_shards * slots_per_shard,)."""

    slot_head: np.ndarray  # int32, head id or -1
    replica_idx: np.ndarray  # int32, 0-based index among slots sharing the head
    replica_count: np.ndarray  # int32, total replicas of that head (1 for empty)

    @property
    def n_slots(self) -> int:
        return int(self.slot_head.shape[0])

    def shard_of_slot(self, slots_per_shard: int) -> np.ndarray:
        return np.arange(self.n_slots) // slots_per_shard

    def heads_on_shard(self, shard: int, slots_per_shard: int) -> List[int]:
        lo, hi = shard * slots_per_shard, (shard + 1) * slots_per_shard
        return [int(h) for h in self.slot_head[lo:hi] if h >= 0]

    def validate(self, n_heads: int, n_shards: int, slots_per_shard: int,
                 r_max: Optional[int] = None) -> None:
        sh = self.slot_head
        assert sh.shape == (n_shards * slots_per_shard,), sh.shape
        assert self.replica_idx.shape == sh.shape
        assert self.replica_count.shape == sh.shape
        seen: Dict[int, List[int]] = {}
        for j in range(self.n_slots):
            h = int(sh[j])
            if h < 0:
                assert int(self.replica_count[j]) == 1
                assert int(self.replica_idx[j]) == 0
                continue
            assert 0 <= h < n_heads, f"slot {j} head {h} out of range"
            seen.setdefault(h, []).append(j)
        # Eq. 2: every head assigned at least once
        missing = set(range(n_heads)) - set(seen)
        assert not missing, f"heads never placed: {sorted(missing)}"
        for h, slots in seen.items():
            r = len(slots)
            if r_max is not None:
                # Eq. 3: replication budget
                assert r <= r_max, f"head {h} has {r} replicas > R_max={r_max}"
            idxs = sorted(int(self.replica_idx[j]) for j in slots)
            assert idxs == list(range(r)), f"head {h} replica idxs {idxs}"
            for j in slots:
                assert int(self.replica_count[j]) == r
            # replicas must land on distinct shards (copying onto the same
            # shard is meaningless — paper §4.3.3)
            shards = [j // slots_per_shard for j in slots]
            assert len(set(shards)) == r, f"head {h} replicas share a shard"

    def per_shard_load(self, weights: np.ndarray, n_shards: int,
                       slots_per_shard: int) -> np.ndarray:
        """Eq. 4 inner sum: Σ_slots w_h / r_h per shard."""
        load = np.zeros(n_shards, dtype=np.float64)
        for j in range(self.n_slots):
            h = int(self.slot_head[j])
            if h >= 0:
                load[j // slots_per_shard] += float(weights[h]) / float(self.replica_count[j])
        return load


@dataclass(frozen=True)
class HeadPlacement:
    """Whole-model plan: one LayerPlacement per layer + mesh metadata."""

    layers: tuple  # Tuple[LayerPlacement, ...]
    n_heads: int
    n_shards: int
    slots_per_shard: int
    mode: str  # "sha" | "fairkv_nodp" | "fairkv_dp"
    r_max: int

    @property
    def n_layers(self) -> int:
        return len(self.layers)

    @property
    def n_slots(self) -> int:
        return self.n_shards * self.slots_per_shard

    def validate(self) -> None:
        for lp in self.layers:
            lp.validate(self.n_heads, self.n_shards, self.slots_per_shard, self.r_max)

    # ---- runtime arrays ----------------------------------------------------
    def as_arrays(self) -> Dict[str, np.ndarray]:
        """Stacked (L, n_slots) int32 arrays for use inside jitted steps."""
        return {
            "slot_head": np.stack([lp.slot_head for lp in self.layers]).astype(np.int32),
            "replica_idx": np.stack([lp.replica_idx for lp in self.layers]).astype(np.int32),
            "replica_count": np.stack([lp.replica_count for lp in self.layers]).astype(np.int32),
        }

    # ---- metrics -----------------------------------------------------------
    def per_shard_load(self, weights: np.ndarray) -> np.ndarray:
        """Total load per shard across layers; weights (L, H)."""
        load = np.zeros(self.n_shards, dtype=np.float64)
        for li, lp in enumerate(self.layers):
            load += lp.per_shard_load(weights[li], self.n_shards, self.slots_per_shard)
        return load

    def makespan(self, weights: np.ndarray) -> float:
        return float(self.per_shard_load(weights).max())

    def efficiency(self, weights: np.ndarray) -> float:
        """Eq. 5: mean-shard-load / max-shard-load."""
        load = self.per_shard_load(weights)
        mx = load.max()
        return float(load.mean() / mx) if mx > 0 else 1.0

    def replication_overhead(self) -> float:
        """Fraction of extra head-copies materialized (weight-memory cost)."""
        total = sum(int((lp.slot_head >= 0).sum()) for lp in self.layers)
        base = self.n_layers * self.n_heads
        return total / base - 1.0

    # ---- serialization -----------------------------------------------------
    def to_json(self) -> str:
        return json.dumps({
            "n_heads": self.n_heads,
            "n_shards": self.n_shards,
            "slots_per_shard": self.slots_per_shard,
            "mode": self.mode,
            "r_max": self.r_max,
            "layers": [{
                "slot_head": lp.slot_head.tolist(),
                "replica_idx": lp.replica_idx.tolist(),
                "replica_count": lp.replica_count.tolist(),
            } for lp in self.layers],
        })

    @staticmethod
    def from_json(s: str) -> "HeadPlacement":
        d = json.loads(s)
        layers = tuple(
            LayerPlacement(
                slot_head=np.asarray(l["slot_head"], dtype=np.int32),
                replica_idx=np.asarray(l["replica_idx"], dtype=np.int32),
                replica_count=np.asarray(l["replica_count"], dtype=np.int32),
            )
            for l in d["layers"]
        )
        return HeadPlacement(layers=layers, n_heads=d["n_heads"],
                             n_shards=d["n_shards"],
                             slots_per_shard=d["slots_per_shard"],
                             mode=d["mode"], r_max=d["r_max"])


def layer_from_assignment(assignment: Sequence[Sequence[int]], n_shards: int,
                          slots_per_shard: int) -> LayerPlacement:
    """Build a LayerPlacement from a per-shard list of head ids.

    ``assignment[j]`` = heads (with multiplicity across shards = replication)
    placed on shard j; each inner list must fit in ``slots_per_shard``.
    """
    n_slots = n_shards * slots_per_shard
    slot_head = np.full(n_slots, -1, dtype=np.int32)
    replica_idx = np.zeros(n_slots, dtype=np.int32)
    replica_count = np.ones(n_slots, dtype=np.int32)
    counts: Dict[int, int] = {}
    positions: Dict[int, List[int]] = {}
    for shard, heads in enumerate(assignment):
        assert len(heads) <= slots_per_shard, (
            f"shard {shard} got {len(heads)} heads > {slots_per_shard} slots")
        for k, h in enumerate(heads):
            j = shard * slots_per_shard + k
            slot_head[j] = h
            replica_idx[j] = counts.get(h, 0)
            counts[h] = counts.get(h, 0) + 1
            positions.setdefault(h, []).append(j)
    for h, slots in positions.items():
        for j in slots:
            replica_count[j] = counts[h]
    return LayerPlacement(slot_head=slot_head, replica_idx=replica_idx,
                          replica_count=replica_count)
