"""`PagedBackend`: the block-pool cache backend (DESIGN.md §9).

Bridges the host-side allocator (`BlockPool`) and the device-side arrays
(`PagedCache`) behind the `CacheBackend` interface.  The backend keeps a
host ``numpy`` mirror of the block table as the single source of truth for
*topology* (which blocks belong to which (layer, slot, row)); every
topology change rebuilds the device table from the mirror, while *content*
(K/V values, lengths) flows only through the pure array ops so the jitted
decode step stays functional.

Admission is a free-**block** budget: a request is admissible when every
layer's free list covers its projected prefill blocks plus one growth block
per owned head.  Growth beyond that is intentionally *not* reserved —
decode-time exhaustion is handled by the scheduler preempting the youngest
request (the recompute policy), which this backend signals via
``PoolExhausted``.  A request whose worst-case need exceeds the whole pool
fails fast at submit (`never_fits`).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.api.registry import register_cache_backend
from repro.cache.slot_cache import PlanArrays
from repro.cache.slot_cache import migrate_cache as migrate_slot_cache
from repro.compression.policies import layer_keep_bound
from repro.paging import kvquant
from repro.paging.block_pool import BlockPool
from repro.paging.paged_cache import (
    PagedCache,
    block_hbm_bytes,
    build_table,
    init_paged_cache,
    max_blocks_per_row,
    paged_to_slot,
    paginate_rows,
    release_rows,
)
from repro.serving import engine as _serve
from repro.serving.cache_backend import CacheBackend


def _owner_mask_np(pa: PlanArrays, rows: np.ndarray) -> np.ndarray:
    """(L, S, len(rows)) bool — the §2 strided owner rule, on the host."""
    sh = np.asarray(pa.slot_head)
    rc = np.asarray(pa.replica_count)[:, :, None]
    ri = np.asarray(pa.replica_idx)[:, :, None]
    rows = np.asarray(rows, np.int64)[None, None, :]
    return (sh >= 0)[:, :, None] & ((rows % rc) == ri)


@register_cache_backend("paged")
class PagedBackend(CacheBackend):
    name = "paged"

    def __init__(self, model_cfg, ccfg, max_live_tokens=None, paging=None,
                 n_shards=1, max_live_tokens_per_shard=None,
                 pool_partitions=1, row_partitions=1, obs=None):
        super().__init__(model_cfg, ccfg, max_live_tokens=max_live_tokens,
                         paging=paging, n_shards=n_shards,
                         max_live_tokens_per_shard=max_live_tokens_per_shard,
                         pool_partitions=pool_partitions,
                         row_partitions=row_partitions, obs=obs)
        self.capacity = ccfg.static_capacity()
        self.block_size = self.paging.block_size
        self.max_blocks = max_blocks_per_row(self.capacity, self.block_size)
        self.pool: Optional[BlockPool] = None
        self.table: Optional[np.ndarray] = None  # host mirror (L, S, B, M)
        self.pa: Optional[PlanArrays] = None
        self.n_rows: Optional[int] = None  # global batch width
        # copy-on-write backlog (DESIGN.md §14): (layer, old_id, new_id)
        # device content copies queued by `prepare_decode` when a row's next
        # append would land in a shared (refcount > 1) block.  Kept across
        # calls so a PoolExhausted mid-CoW retries without losing the queue
        # (the old block's content stays live — someone still holds a ref).
        self._pending_cow: list = []
        self.cow_copies = 0  # lifetime count of privatized blocks
        # quantized storage (DESIGN.md §15): resolved spec, the static
        # (L, H) kind grid, and the scale-reset backlog — freshly allocated
        # growth blocks reuse pool slots whose scale entries are stale, so
        # their scales must reset to 0 before the first quantize-on-write
        # append (the running max would otherwise inherit a huge stale
        # scale and flush small tokens to code 0).  Same PoolExhausted
        # retry semantics as the CoW queue.
        self.kv_quant = kvquant.spec_from_paging(self.paging)
        self.kv_kinds = (kvquant.kind_grid(self.kv_quant, self.cfg.n_layers,
                                           self.cfg.n_kv_heads)
                         if self.kv_quant is not None else None)
        self.model_dtype = None  # stashed by init_state (the logical dtype)
        self._pending_scale_reset: list = []  # (layer, [block ids])

    @property
    def partitions(self):
        """(slot_parts, row_parts) — the mesh pool split (DESIGN.md §10)."""
        return (self.pool_partitions, self.row_partitions)

    # ---- state lifecycle ---------------------------------------------------

    def _slot_kinds(self, pa) -> Optional[np.ndarray]:
        """(L, S) per-slot kind codes under ``pa``'s head placement (None on
        the fp32 path) — the host-side twin of the decode step's in-trace
        ``slot_head`` → kind lookup."""
        if self.kv_kinds is None:
            return None
        return kvquant.slot_kinds(self.kv_kinds, np.asarray(pa.slot_head))

    def init_state(self, pa, batch, dtype):
        self.pa = pa
        self.n_rows = int(batch)
        self.model_dtype = dtype
        if self.cfg.attention_free:
            return _serve.init_serve_state(self.cfg, pa, batch, self.ccfg,
                                           dtype=dtype)
        cache, self.pool = init_paged_cache(
            self.cfg.n_layers, int(pa.slot_head.shape[1]), batch,
            self.capacity, self.cfg.head_dim, self.paging, dtype=dtype,
            partitions=self.partitions, kv_quant=self.kv_quant)
        self.pool.obs = self.obs  # alloc/free/exhaustion counters (§12)
        self.table = np.zeros(cache.block_table.shape, np.int32)
        return _serve.init_serve_state(self.cfg, pa, batch, self.ccfg,
                                       dtype=dtype, cache=cache)

    def from_prefill(self, state, pa):
        """One-shot adoption: re-house a full-batch slot prefill in blocks
        sized to its realized retained lengths (all rows live)."""
        if state.cache is None:
            self.pa = pa
            return state
        slot = state.cache
        L, S, B, C, Dh = slot.k.shape
        if C != self.capacity:
            raise ValueError(f"prefill capacity {C} != backend capacity "
                             f"{self.capacity}")
        empty = self.init_state(pa, B, slot.k.dtype)  # fresh pool + mirror
        own = _owner_mask_np(pa, np.arange(B))
        table = build_table(np.asarray(slot.lengths), self.pool,
                            self.block_size, self.max_blocks, own=own,
                            partitions=self.partitions, n_rows=B)
        self.table = table.copy()
        cache = paginate_rows(empty.cache, slot, jnp.arange(B, dtype=jnp.int32),
                              table, kinds=self._slot_kinds(pa))
        self._observe_quant_error(slot)
        return dataclasses.replace(state, cache=cache)

    def splice(self, state, sub, rows, shared_blocks=None):
        """Admit: allocate blocks for the sub-state's realized lengths and
        scatter its contents in.  Atomic on ``PoolExhausted``.

        ``shared_blocks`` (optional, (L, S, len(rows), M) int32) carries
        prefix-cache donor block ids (DESIGN.md §14): each (layer, slot,
        row)'s shared *full* blocks, contiguous from column 0, already
        holding the matched prefix content on device.  Fresh blocks are
        allocated only for the remainder; shared ids are incref'd (never
        written — `paginate_rows` null-redirects their columns) and the
        stored table maps the row onto the shared blocks directly, so a
        cache hit costs ``need − shared`` new blocks.
        """
        if state.cache is None:
            return _serve.splice_state(state, sub, rows)
        rows_np = np.asarray(rows, np.int64)
        leftovers = self.table[:, :, rows_np, :]
        if (leftovers > 0).any():  # defensive: target rows must be retired
            self.pool.free_table(leftovers.reshape(self.table.shape[0], -1))
            self.table[:, :, rows_np, :] = 0
        own = _owner_mask_np(self.pa, rows_np)
        lengths = np.asarray(sub.cache.lengths)
        if shared_blocks is None:
            table_sub = build_table(lengths, self.pool,
                                    self.block_size, self.max_blocks, own=own,
                                    partitions=self.partitions, rows=rows_np,
                                    n_rows=self.n_rows)
            self.table[:, :, rows_np, :] = table_sub
            cache = paginate_rows(state.cache, sub.cache,
                                  jnp.asarray(rows_np, jnp.int32), table_sub,
                                  kinds=self._slot_kinds(self.pa))
            self._observe_quant_error(sub.cache)
            return _serve.splice_state(state, sub, rows, cache=cache)
        shared = np.asarray(shared_blocks, np.int32)
        n_sh = (shared > 0).sum(axis=-1)  # (L, S, R) full shared blocks
        # fresh blocks cover only tokens past the shared full blocks; the
        # allocation trial runs BEFORE any incref/mirror change so a
        # PoolExhausted here leaves pool and table untouched (atomicity)
        lens_adj = np.maximum(lengths - n_sh * self.block_size, 0)
        fresh = build_table(lens_adj, self.pool,
                            self.block_size, self.max_blocks, own=own,
                            partitions=self.partitions, rows=rows_np,
                            n_rows=self.n_rows)
        L, S, R, M = fresh.shape
        for l in range(L):
            ids = shared[l][shared[l] > 0]
            if ids.size:
                self.pool.incref(l, ids)
        table_full = np.zeros_like(fresh)
        for l, s, r in zip(*np.nonzero(own | (n_sh > 0))):
            f = int(n_sh[l, s, r])
            fr = fresh[l, s, r][fresh[l, s, r] > 0]
            nf = min(fr.size, M - f)
            table_full[l, s, r, :f] = shared[l, s, r, :f]
            table_full[l, s, r, f:f + nf] = fr[:nf]
            if fr.size > nf:  # fully-shared row at capacity: growth block
                self.pool.decref(l, fr[nf:])  # has no table home, return it
        self.table[:, :, rows_np, :] = table_full
        # write addressing zeroes the shared columns (null-redirect): the
        # shared blocks already hold the prefix content and must never be
        # written through a refcount > 1 table entry
        col = np.arange(M)[None, None, None, :]
        table_write = np.where(col < n_sh[..., None], 0, table_full)
        cache = paginate_rows(state.cache, sub.cache,
                              jnp.asarray(rows_np, jnp.int32), table_write,
                              table_store=table_full,
                              kinds=self._slot_kinds(self.pa))
        self._observe_quant_error(sub.cache)
        return _serve.splice_state(state, sub, rows, cache=cache)

    def _observe_quant_error(self, slot) -> None:
        """Quantization-error observability (DESIGN.md §15): on each
        admission, roundtrip the spliced sub-cache through the codec and
        record the relative error — the live quality signal for the
        kv_dtype / override-map knobs.  Skipped when obs is off (the
        roundtrip costs a second encode pass)."""
        if self.kv_kinds is None or not self.obs.enabled:
            return
        # (L, S, 1, 1): broadcasts over the (L, S, B, M) block axes
        kinds = jnp.asarray(self._slot_kinds(self.pa))[:, :, None, None]
        err_k, den_k = kvquant.roundtrip_error(slot.k, slot.pos,
                                               self.block_size, kinds)
        err_v, den_v = kvquant.roundtrip_error(slot.v, slot.pos,
                                               self.block_size, kinds)
        tokens = int(np.asarray(slot.lengths).sum())
        self.obs.metrics.counter(
            "kv_quant_tokens_total",
            help="KV tokens quantized into the paged pools").inc(tokens)
        self.obs.metrics.gauge(
            "kv_quant_rel_err",
            help="mean relative KV quantization error over the last "
                 "admitted sub-cache (Σ|deq(q(x))−x| / Σ|x|)"
        ).set(float((err_k + err_v) / max(den_k + den_v, 1e-9)))

    def release_rows(self, state, rows):
        if state.cache is None:
            return _serve.reset_state_rows(state, rows)
        rows_np = np.asarray(rows, np.int64)
        held = self.table[:, :, rows_np, :]
        self.pool.free_table(held.reshape(self.table.shape[0], -1))
        self.table[:, :, rows_np, :] = 0
        cache = release_rows(state.cache, jnp.asarray(rows_np, jnp.int32))
        return _serve.reset_state_rows(state, rows, cache=cache)

    def prepare_decode(self, state, active, n_tokens: int = 1):
        """Allocate the blocks backing each active row's next appends.

        The next write index is ``lengths`` while a row is below capacity
        (the recency ring past that only revisits already-allocated
        blocks); ``n_tokens`` consecutive appends need the blocks through
        ``(min(len + n_tokens, capacity) - 1) // bs``, so an owned
        (layer, slot, row) may take several *provisional* blocks before
        the tick (speculative decoding, DESIGN.md §16 — rejected windows
        hand them back through `trim_rows`).  Raises ``PoolExhausted``
        when a layer's free list runs dry — the scheduler's preemption
        signal.

        Copy-on-write (DESIGN.md §14): before allocating growth, any owned
        next write that would land in a *shared* (refcount > 1) block —
        only the recency ring can wrap into the shared prefix region —
        gets a private block first: alloc in the same partition, decref
        the shared id, queue a device content copy.  Checking the *first*
        write block suffices for any ``n_tokens``: later writes of the
        window land in blocks this call allocates fresh (refcount 1), and
        at-capacity rows (the only ring-wrap case) are clamped to a
        single-token window by the scheduler.  A defensive recheck after
        allocation turns any surviving shared-write into a hard error
        instead of silent corruption.
        """
        if state.cache is None:
            return state
        if n_tokens < 1:
            raise ValueError(f"n_tokens must be >= 1, got {n_tokens}")
        cache = state.cache
        B = cache.positions.shape[0]
        rows = np.arange(B) if active is None else np.asarray(list(active))
        if rows.size == 0:
            return state
        lens = np.asarray(cache.lengths)[:, :, rows]  # (L, S, R)
        own = _owner_mask_np(self.pa, rows)
        blk = self._next_write_blocks(state, lens)  # (L, S, R)
        dirty = False
        if int(self.pool.refcount.max()) > 1:
            dirty = self._cow_next_writes(rows, own, blk)
        have = (self.table[:, :, rows, :] > 0).sum(axis=-1)  # (L, S, R)
        growing = own & (lens < self.capacity)
        end = np.minimum(lens + n_tokens, self.capacity)  # exclusive
        need = np.where(growing, (end - 1) // self.block_size + 1, have)
        missing = need - have
        if missing.max(initial=0) > 0:
            dirty = True
            L, S = self.table.shape[0], self.table.shape[1]
            slot_parts, row_parts = self.partitions
            s_per = S // slot_parts
            b_per = -(-self.n_rows // row_parts)
            for l in range(L):
                for sp in range(slot_parts):
                    sl = slice(sp * s_per, (sp + 1) * s_per)
                    for rp in range(row_parts):
                        cols = np.nonzero(rows // b_per == rp)[0]
                        if cols.size == 0:
                            continue
                        miss = missing[l, sl][:, cols]
                        n_lp = int(np.maximum(miss, 0).sum())
                        if n_lp == 0:
                            continue
                        ids = self.pool.alloc(l, n_lp,
                                              partition=sp * row_parts + rp)
                        if self.kv_kinds is not None:
                            # reused pool slots carry stale scales; zero
                            # them before the first quantize-on-write
                            self._pending_scale_reset.append((l, list(ids)))
                        hv = have[l, sl][:, cols]
                        at = 0
                        for s, c in zip(*np.nonzero(miss > 0)):
                            m, h = int(miss[s, c]), int(hv[s, c])
                            self.table[l, sp * s_per + s, rows[cols[c]],
                                       h:h + m] = ids[at:at + m]
                            at += m
        if int(self.pool.refcount.max()) > 1:
            # defensive recheck: CoW above must have privatized every owned
            # next write — reject in-place mutation of shared blocks
            tbl = self.table[:, :, rows, :]
            bid = np.take_along_axis(tbl, blk[..., None], axis=-1)[..., 0]
            l_ix = np.arange(tbl.shape[0])[:, None, None]
            still = own & (bid > 0) & (self.pool.refcount[l_ix, bid] > 1)
            if still.any():
                l, s, r = next(zip(*np.nonzero(still)))
                raise RuntimeError(
                    f"next decode append for (layer {l}, slot {s}, row "
                    f"{rows[r]}) targets shared block "
                    f"{int(bid[l, s, r])} (refcount > 1); copy-on-write "
                    f"failed to privatize it")
        if (not dirty and not self._pending_cow
                and not self._pending_scale_reset):
            return state
        cache = self._apply_pending_cow(cache)
        return dataclasses.replace(state, cache=dataclasses.replace(
            cache, block_table=jnp.asarray(self.table)))

    def trim_rows(self, state, rows):
        """Release provisional blocks no longer covered by ``lengths``.

        Speculative verify rolls rejected window entries back *in-trace*
        (device ``lengths`` drop to the committed run, DESIGN.md §16); the
        host mirror still maps the blocks that backed them.  For the given
        rows, decref every mapped block past ``ceil(len / bs)`` — blocks
        taken by `prepare_decode(n_tokens=...)` for writes that were
        rejected or never made — and zero its mirror entries.  Refcounts
        make this safe under sharing: a block another row still references
        merely drops a reference.  Returns the state with the updated
        device table (identity when nothing was trimmed).
        """
        if state.cache is None:
            return state
        rows_np = np.asarray(list(rows), np.int64)
        if rows_np.size == 0:
            return state
        lens = np.asarray(state.cache.lengths)[:, :, rows_np]  # (L, S, R)
        keep = -(-lens // self.block_size)  # ceil: blocks still covered
        tbl = self.table[:, :, rows_np, :]  # (L, S, R, M)
        M = tbl.shape[-1]
        past = np.arange(M)[None, None, None, :] >= keep[..., None]
        drop = np.where(past, tbl, 0)
        if drop.max(initial=0) == 0:
            return state
        self.pool.free_table(drop.reshape(self.table.shape[0], -1))
        self.table[:, :, rows_np, :] = np.where(past, 0, tbl)
        return dataclasses.replace(state, cache=dataclasses.replace(
            state.cache, block_table=jnp.asarray(self.table)))

    def _next_write_blocks(self, state, lens: np.ndarray) -> np.ndarray:
        """(L, S, R) block index of each pair's next append — the host
        mirror of `ring_write_index` (below capacity: ``lens``; at
        capacity: the shared ring phase)."""
        cap = self.capacity
        ring = max(1, min(max(1, self.ccfg.decode_margin), cap))
        cyc = (cap - ring) + int(state.decode_steps) % ring
        return np.where(lens < cap, lens, cyc) // self.block_size

    def _cow_next_writes(self, rows, own, blk) -> bool:
        """Privatize shared blocks under the next write index.  Mutates
        the mirror + pool and queues content copies; returns True if any
        block was replaced.  PoolExhausted mid-loop is safe to retry: the
        queue survives and completed replacements stay consistent."""
        tbl = self.table[:, :, rows, :]  # (L, S, R, M)
        bid = np.take_along_axis(tbl, blk[..., None], axis=-1)[..., 0]
        L = tbl.shape[0]
        l_ix = np.arange(L)[:, None, None]
        hit = own & (bid > 0) & (self.pool.refcount[l_ix, bid] > 1)
        if not hit.any():
            return False
        for l, s, r in zip(*np.nonzero(hit)):
            old = int(bid[l, s, r])
            new = int(self.pool.alloc(
                l, 1, partition=self.pool.partition_of(old))[0])
            self.pool.decref(l, np.asarray([old]))
            self.table[l, s, rows[r], int(blk[l, s, r])] = new
            self._pending_cow.append((int(l), old, new))
            self.cow_copies += 1
        return True

    def _apply_pending_cow(self, cache):
        """Flush queued CoW content copies into the device pools.

        Applied strictly in queue order: a freed-then-reallocated id can
        appear as a copy *destination* only after all entries reading it
        as a *source* (they were queued while it was still shared), so
        sequential application never reads clobbered content.

        Quantized pools (DESIGN.md §15): a privatized block copies codes
        AND scale verbatim — bit-exact, never a second quantization — and
        queued scale resets (fresh growth blocks) flush here too, before
        the first append can run a quantize-on-write against them.
        """
        if not self._pending_cow and not self._pending_scale_reset:
            return cache
        kp, vp, pp = cache.k_pool, cache.v_pool, cache.pos_pool
        ks, vs = cache.k_scale, cache.v_scale
        if ks is not None:
            # resets before copies: a reset-queued id freed by preemption
            # and re-handed-out as a CoW destination must end with the
            # donor's copied scale, not a zero
            for l, ids in self._pending_scale_reset:
                idx = jnp.asarray(ids, jnp.int32)
                ks = ks.at[l, idx].set(0.0)
                vs = vs.at[l, idx].set(0.0)
        for l, old, new in self._pending_cow:
            kp = kp.at[l, new].set(kp[l, old])
            vp = vp.at[l, new].set(vp[l, old])
            pp = pp.at[l, new].set(pp[l, old])
            if ks is not None:
                ks = ks.at[l, new].set(ks[l, old])
                vs = vs.at[l, new].set(vs[l, old])
        self._pending_cow.clear()
        self._pending_scale_reset.clear()
        return dataclasses.replace(cache, k_pool=kp, v_pool=vp, pos_pool=pp,
                                   k_scale=ks, v_scale=vs)

    def migrate_cache(self, cache, old_pa, new_pa, active_rows=None):
        """Trial re-layout for a replan: materialize → migrate → allocate
        in a *fresh* trial allocator; the expensive device re-pagination is
        deferred into ``commit()`` (rejection — the common case under
        hysteresis — then never pays it).

        Raising ``PoolExhausted`` (ownership moves can change block
        rounding) happens during the allocation trial, before scoring, and
        leaves the backend untouched — the scheduler records the replan as
        rejected.
        """
        # dequantize through the live scale pools (same scale/kind lookup as
        # the decode kernel) so the trial sees real values, and back in the
        # model dtype so re-pagination re-quantizes from full precision —
        # the slot↔paged bit-consistency rule (DESIGN.md §15)
        slot = paged_to_slot(cache, self.capacity,
                             kinds=self._slot_kinds(old_pa),
                             out_dtype=self.model_dtype)
        slot2 = migrate_slot_cache(slot, old_pa, new_pa)
        B = int(cache.positions.shape[0])
        rows = np.arange(B) if active_rows is None else np.asarray(
            list(active_rows))
        own = np.zeros((self.table.shape[0], self.table.shape[1], B), bool)
        if rows.size:
            own[:, :, rows] = _owner_mask_np(new_pa, rows)
        trial = BlockPool(self.pool.n_layers, self.pool.n_blocks,
                          n_partitions=self.pool.n_partitions)
        trial.obs = self.obs  # trial allocations are real allocator work
        table = build_table(np.asarray(slot2.lengths), trial,
                            self.block_size, self.max_blocks, own=own,
                            partitions=self.partitions, n_rows=B)

        def commit():
            # pin the pool size to the live cache's (pool_hbm_bytes and
            # n_blocks are mutually exclusive sizing modes, and the byte
            # budget already resolved to this block count); dtype is the
            # *logical* model dtype — the storage dtype falls out of
            # kv_quant (the pre-fix code passed cache.k_pool.dtype, which
            # under quantization is int8 and would have desugared the
            # re-paginated pools into int8-as-model-dtype garbage)
            empty, _ = init_paged_cache(
                self.cfg.n_layers, int(new_pa.slot_head.shape[1]), B,
                self.capacity, self.cfg.head_dim,
                dataclasses.replace(self.paging, n_blocks=cache.n_blocks,
                                    pool_hbm_bytes=0),
                dtype=self.model_dtype or cache.k_pool.dtype,
                partitions=self.partitions, kv_quant=self.kv_quant)
            cand = paginate_rows(empty, slot2,
                                 jnp.arange(B, dtype=jnp.int32), table,
                                 kinds=self._slot_kinds(new_pa))
            self.pool, self.table, self.pa = trial, table, new_pa
            return cand

        return slot2.lengths, commit

    # ---- admission accounting ----------------------------------------------

    def _layer_blocks(self, prompt_len: int, max_new: int,
                      worst_case: bool) -> np.ndarray:
        """(L,) projected block need per layer.

        ``worst_case=False``: prefill bound + one growth block per owned
        head (the admission check; later growth is preemption's problem).
        ``worst_case=True``: the full-generation bound (fail-fast check).
        """
        H, L = self.cfg.n_kv_heads, self.cfg.n_layers
        bs = self.block_size
        out = np.zeros(L, np.int64)
        for l in range(L):
            tokens = layer_keep_bound(self.ccfg.policy, self.ccfg,
                                      prompt_len, H, l, L)
            if worst_case:
                tokens = min(tokens + H * max_new,
                             H * min(prompt_len + max_new, self.capacity))
                out[l] = tokens // bs + H
            else:
                out[l] = tokens // bs + 2 * H  # rounding + 1 growth block/head
        return out

    def _partition_need(self, prompt_len: int, max_new: int,
                        worst_case: bool) -> np.ndarray:
        """(L, P) projected block need per (layer, pool partition).

        The per-layer token bound splits across partitions proportional to
        the plan's occupied slots there (replicas split rows, so a
        partition's expected share of a request's tokens tracks its share
        of owned slots); the per-head growth/rounding slack charges where
        the heads physically sit.  Budgets and admission are therefore
        **per model shard** — one shard's full partition blocks admission
        even when the pool has global headroom (DESIGN.md §10).
        """
        P = self.pool_partitions
        sh = np.asarray(self.pa.slot_head)  # (L, S)
        L, S = sh.shape
        occ = (sh >= 0).reshape(L, P, S // P).sum(axis=2)  # (L, P)
        frac = occ / np.maximum(occ.sum(axis=1, keepdims=True), 1)
        H, bs = self.cfg.n_kv_heads, self.block_size
        out = np.zeros((L, P), np.int64)
        for l in range(L):
            tokens = layer_keep_bound(self.ccfg.policy, self.ccfg,
                                      prompt_len, H, l, L)
            if worst_case:
                tokens = min(tokens + H * max_new,
                             H * min(prompt_len + max_new, self.capacity))
                slack = occ[l]
            else:
                slack = 2 * occ[l]  # rounding + 1 growth block per slot
            out[l] = (np.ceil(tokens * frac[l] / bs).astype(np.int64)
                      + slack)
        return out

    def request_cost(self, req):
        if self.cfg.attention_free:
            return 0
        return int(self._layer_blocks(req.prompt_len, req.max_new_tokens,
                                      worst_case=True).sum())

    def admissible(self, state, req, pending=()):
        if self.cfg.attention_free or self.pool is None:
            return True
        if self.pool.n_partitions > 1:
            need = self._partition_need(req.prompt_len, req.max_new_tokens,
                                        worst_case=False)  # (L, slot_parts)
            for p in pending:  # accepted-not-yet-spliced charge (see base)
                need = need + self._partition_need(
                    p.prompt_len, p.max_new_tokens, worst_case=False)
            free = self.pool.free_blocks_by_partition()
            L = free.shape[0]
            # the request lands in one (unknown) row partition — require the
            # worst one to fit, so admission never over-commits a shard
            free = free.reshape(L, self.pool_partitions,
                                self.row_partitions).min(axis=2)
            return bool((free >= need).all())
        need = self._discount_shared(
            self._layer_blocks(req.prompt_len, req.max_new_tokens,
                               worst_case=False), req)
        for p in pending:
            need = need + self._discount_shared(
                self._layer_blocks(p.prompt_len, p.max_new_tokens,
                                   worst_case=False), p)
        return bool((self.pool.free_blocks() >= need).all())

    @staticmethod
    def _discount_shared(need: np.ndarray, req) -> np.ndarray:
        """Admission charges only *unshared* blocks (DESIGN.md §14): a
        prefix-cache hit stamps ``req.prefix_shared_blocks`` ((L,) full
        blocks reused from the index) and those never leave the pool's
        allocated set twice."""
        sh = getattr(req, "prefix_shared_blocks", None)
        if sh is None:
            return need
        return np.maximum(need - np.asarray(sh, np.int64), 0)

    def never_fits(self, req):
        if self.cfg.attention_free:
            return None
        if self.pool is not None and self.pool.n_partitions > 1:
            need = self._partition_need(req.prompt_len, req.max_new_tokens,
                                        worst_case=True)
            usable = self.pool.part_size - 1
            if int(need.max()) > usable:
                return (f"worst-case need of {int(need.max())} blocks in "
                        f"one (layer, model-shard) partition exceeds the "
                        f"partition ({usable} usable blocks)")
            return None
        need = self._layer_blocks(req.prompt_len, req.max_new_tokens,
                                  worst_case=True)
        usable = (self.pool.usable_blocks if self.pool is not None
                  else self.paging.n_blocks - 1 if self.paging.n_blocks
                  else None)
        if usable is not None and int(need.max()) > usable:
            return (f"worst-case need of {int(need.max())} blocks/layer "
                    f"exceeds the pool ({usable} usable blocks/layer)")
        return None

    # ---- telemetry ---------------------------------------------------------

    def sample_metrics(self, state) -> None:
        if self.pool is None:
            return
        self.pool.sample_gauges(self.obs.metrics)
        if state.cache is not None:
            live = int(np.asarray(state.cache.lengths).sum())
            self.obs.metrics.gauge(
                "cache_live_tokens",
                help="Σ retained KV tokens across the live cache"
            ).set(live)
            if isinstance(state.cache, PagedCache):
                per_block = block_hbm_bytes(
                    self.block_size, self.cfg.head_dim,
                    state.cache.k_pool.dtype, self.kv_kinds is not None)
                self.obs.metrics.gauge(
                    "kv_bytes_per_token",
                    help="HBM bytes pinned per live KV token (allocated "
                         "blocks x per-block footprint incl. scales / "
                         "live tokens) — the decode-bandwidth unit the "
                         "kv_dtype knob halves (DESIGN.md §15)"
                ).set(self.pool.blocks_in_use() * per_block / max(live, 1))

    def memory_stats(self, state) -> dict:
        if (state.cache is not None
                and not isinstance(state.cache, PagedCache)):
            # prefill() leaves the cache in slot layout until generate()
            # adopts it — report the dense footprint it actually occupies
            c = state.cache
            L, S, B, C, Dh = c.k.shape
            return {"backend": self.name, "layout": "slot (pre-adoption)",
                    "block_size": self.block_size,
                    "blocks_in_use": 0, "blocks_total": 0,
                    "cache_bytes": int(2 * L * S * B * C * Dh
                                       * c.k.dtype.itemsize),
                    "pool_bytes": 0, "slot_equivalent_bytes": 0,
                    "live_tokens": int(np.asarray(c.lengths).sum())}
        if state.cache is None or self.pool is None:
            return {"backend": self.name, "block_size": self.block_size,
                    "blocks_in_use": 0, "blocks_total": 0, "cache_bytes": 0,
                    "pool_bytes": 0, "slot_equivalent_bytes": 0,
                    "live_tokens": 0}
        c = state.cache
        L, N, bs, Dh = c.k_pool.shape
        _, S, B, M = c.block_table.shape
        quantized = c.k_scale is not None
        # K + V payload + (quantized) the two fp32 scale entries; the
        # slot-equivalent baseline stays in the *model* dtype — that is the
        # dense cache this pool replaces, and the ratio between the two is
        # the bytes-aware capacity win (DESIGN.md §15)
        block_bytes = block_hbm_bytes(bs, Dh, c.k_pool.dtype, quantized)
        model_item = jnp.dtype(self.model_dtype or c.k_pool.dtype).itemsize
        in_use = self.pool.blocks_in_use()
        usable = self.pool.usable_blocks
        return {
            "backend": self.name,
            "block_size": bs,
            "kv_dtype": self.paging.kv_dtype,
            "blocks_in_use": in_use,
            "blocks_total": L * usable,
            "cache_bytes": in_use * block_bytes,
            "pool_bytes": L * usable * block_bytes,
            "slot_equivalent_bytes": int(2 * L * S * B * self.capacity
                                         * Dh * model_item),
            "live_tokens": int(np.asarray(c.lengths).sum()),
        }
