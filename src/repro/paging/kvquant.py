"""Per-block KV quantization for the paged cache (DESIGN.md §15).

Decode on the paged path is HBM-bound: the native kernel (§11) already made
traffic proportional to allocated blocks, and the remaining factor sits in
the *bytes per block*.  This module defines the storage codec the paged
backend uses when ``PagingConfig.kv_dtype`` is quantized:

- the K/V pools physically store **int8 codes** (1 byte/value); values
  quantized as fp8 (``float8_e4m3fn``) are bitcast into the same int8 pool,
  so per-head format mixing never changes the pool's dtype or itemsize;
- a parallel ``(L, N)`` fp32 **scale pool** per tensor (one scale per
  block — a block belongs to exactly one (slot, row), hence one head)
  carries the per-block symmetric scale: ``value = decode(code) * scale``;
- a static per-``(layer, head)`` **kind grid** (0 = int8, 1 = fp8) selects
  the dequant interpretation.  Per-*slot* kinds are derived from the plan's
  ``slot_head`` — in-trace on the decode path (so one StepFn trace serves
  every replan) and on the host for pagination.

The codec is symmetric per block: ``scale = amax / qmax`` over the block's
*valid* entries, codes are ``round(x / scale)`` clipped to ±127 for int8
and ``cast(x / scale)`` (then bitcast to int8) for fp8.  Scales only ever
grow on append (running max), so previously written codes are rescaled by
``old/new`` — never re-quantized from already-lossy values twice unless the
scale actually grew.  Copy-on-write privatization copies codes and scale
verbatim (bit-exact, no second quantization — DESIGN.md §14/§15).

Stored fp8 bit patterns always come from a genuine fp8 cast; arbitrary
garbage interpreted as fp8 could decode to NaN, so ``decode`` flushes NaN
to 0 defensively — such entries are always masked by length before they
can reach an output, but 0·NaN would still poison a masked-out
probability-weighted sum.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# kv_dtype values accepted by PagingConfig ("fp32" = no quantization: pools
# stay in the engine dtype and no scale pools exist)
KV_DTYPES = ("fp32", "int8", "fp8")
QUANT_DTYPES = ("int8", "fp8")

INT8_QMAX = 127.0
FP8_QMAX = 448.0  # max finite magnitude of float8_e4m3fn

KIND_INT8 = 0
KIND_FP8 = 1
_KIND_OF = {"int8": KIND_INT8, "fp8": KIND_FP8}


def fp8_supported() -> bool:
    """True when this jax exposes float8_e4m3fn (the fp8 storage format)."""
    return hasattr(jnp, "float8_e4m3fn")


@dataclass(frozen=True)
class KVQuantSpec:
    """Resolved KV quantization: base format + per-(layer, head) overrides.

    ``base`` is "int8" or "fp8"; ``overrides`` is a canonical sorted tuple
    of ``(layer, head, dtype)`` triples (the hashable form
    ``PagingConfig.kv_dtype_overrides`` normalizes to).  Physical storage
    is int8 either way; the spec only decides each head's *interpretation*.
    """

    base: str
    overrides: Tuple[Tuple[int, int, str], ...] = ()


def spec_from_paging(paging) -> Optional[KVQuantSpec]:
    """The quantization spec a PagingConfig implies (None = fp32 path)."""
    if paging is None or getattr(paging, "kv_dtype", "fp32") == "fp32":
        return None
    return KVQuantSpec(base=paging.kv_dtype,
                       overrides=tuple(paging.kv_dtype_overrides))


def kind_grid(spec: KVQuantSpec, n_layers: int, n_heads: int) -> np.ndarray:
    """(L, H) int32 kind codes — the static dequant-interpretation grid."""
    grid = np.full((n_layers, n_heads), _KIND_OF[spec.base], np.int32)
    for layer, head, dt in spec.overrides:
        if layer >= n_layers or head >= n_heads:
            raise ValueError(
                f"kv_dtype override ({layer}, {head}) out of range for "
                f"{n_layers} layers x {n_heads} kv heads")
        grid[layer, head] = _KIND_OF[dt]
    return grid


def slot_kinds(grid: np.ndarray, slot_head: np.ndarray) -> np.ndarray:
    """(L, S) int32 per-slot kinds from the plan's ``slot_head`` (host side;
    empty slots (head −1) borrow head 0's kind — they own nothing, so the
    interpretation is never read)."""
    sh = np.maximum(np.asarray(slot_head, np.int64), 0)
    return np.take_along_axis(np.asarray(grid, np.int32), sh, axis=1)


def qmax_of(kind):
    """Per-kind quantization range (broadcasts over a kind array)."""
    return jnp.where(kind == KIND_FP8, FP8_QMAX, INT8_QMAX)


def encode(x, scale, kind) -> jnp.ndarray:
    """float → int8 codes under per-block ``scale`` and per-slot ``kind``.

    ``scale``/``kind`` broadcast against ``x``; a zero scale (empty block)
    encodes everything to 0.
    """
    safe = jnp.where(scale > 0, scale, 1.0)
    y = x.astype(jnp.float32) / safe
    codes = jnp.clip(jnp.round(y), -INT8_QMAX, INT8_QMAX).astype(jnp.int8)
    if fp8_supported():
        y8 = jnp.clip(y, -FP8_QMAX, FP8_QMAX).astype(jnp.float8_e4m3fn)
        codes = jnp.where(kind == KIND_FP8,
                          jax.lax.bitcast_convert_type(y8, jnp.int8), codes)
    return codes


def decode(codes, scale, kind) -> jnp.ndarray:
    """int8 codes → fp32 values (inverse of `encode`; NaN-flushing — module
    docstring)."""
    f = codes.astype(jnp.float32)
    if fp8_supported():
        f8 = jax.lax.bitcast_convert_type(
            codes, jnp.float8_e4m3fn).astype(jnp.float32)
        f8 = jnp.where(f8 == f8, f8, 0.0)
        f = jnp.where(kind == KIND_FP8, f8, f)
    return f * scale


def quantize_blocks(x, pos, block_size: int, kind):
    """Block-quantize a contiguous slot-layout tensor → (codes, scales).

    ``x`` is (..., C, Dh) with per-entry positions ``pos`` (..., C); C must
    be a multiple of ``block_size`` (callers pad).  Entries with ``pos < 0``
    are invalid: they are excluded from each block's amax and their codes
    are zeroed, so slot-cache garbage can neither blow up a block's scale
    nor survive as decodable content.  ``kind`` broadcasts against the
    block axes (e.g. (L, S, 1, 1) against (L, S, B, M) blocks).
    Returns codes shaped like ``x`` (int8) and scales (..., C//bs) fp32.
    """
    bs = int(block_size)
    *lead, C, Dh = x.shape
    if C % bs:
        raise ValueError(f"capacity {C} not a multiple of block size {bs}")
    M = C // bs
    xb = x.reshape(*lead, M, bs, Dh).astype(jnp.float32)
    valid = (jnp.asarray(pos) >= 0).reshape(*lead, M, bs)
    amax = jnp.max(jnp.abs(xb) * valid[..., None], axis=(-2, -1))
    scales = amax / qmax_of(kind)
    codes = encode(xb, scales[..., None, None], kind[..., None, None])
    codes = jnp.where(valid[..., None], codes, jnp.int8(0))
    return codes.reshape(*lead, C, Dh), scales


def roundtrip_error(x, pos, block_size: int, kind) -> Tuple[float, float]:
    """(Σ|deq(q(x)) − x|, Σ|x|) over valid entries — the backend's
    quantization-error observability sample (DESIGN.md §15)."""
    C = x.shape[-2]
    bs = int(block_size)
    pad = (-C) % bs
    if pad:
        x = jnp.pad(x, ((0, 0),) * (x.ndim - 2) + ((0, pad), (0, 0)))
        pos = jnp.pad(pos, ((0, 0),) * (pos.ndim - 1) + ((0, pad),),
                      constant_values=-1)
    M = x.shape[-2] // bs
    codes, scales = quantize_blocks(x, pos, bs, kind)
    *lead, C2, Dh = codes.shape
    deq = decode(codes.reshape(*lead, M, bs, Dh),
                 scales[..., None, None],
                 kind[..., None, None]).reshape(*lead, C2, Dh)
    valid = (jnp.asarray(pos) >= 0)[..., None]
    err = jnp.abs(deq - x.astype(jnp.float32)) * valid
    den = jnp.abs(x.astype(jnp.float32)) * valid
    return float(err.sum()), float(den.sum())
