"""Paged slot-layout KV cache: block pools + block tables (DESIGN.md §9).

The slot cache (`cache/slot_cache.py`) pads every (slot, row) to the static
capacity ``C``, so a head compressed to 12% of ``C`` still reserves 100% of
it.  The paged layout stores the same logical cache in fixed-size blocks
allocated proportional to each (slot, row)'s *realized* retained length:

    k_pool, v_pool : (L, N, bs, Dh)   N blocks of bs tokens per layer
    pos_pool       : (L, N, bs) int32 absolute entry positions (−1 = empty)
    block_table    : (L, S, B, M) int32  block ids per (slot, row);
                                         0 = the reserved null block
    lengths        : (L, S, B) int32  same semantics as the slot cache
    positions      : (B,) int32       next absolute position per row
    k_scale, v_scale : (L, N) fp32    per-block dequant scales, present only
                                      under a quantized ``kv_dtype``
                                      (None on the fp32 path — DESIGN.md §15)

``M = ceil(C / bs)`` so a fully-retained row is still representable; the win
is that *partially* retained rows (the common case under imbalanced
compression) only pin ``ceil(len / bs)`` blocks.  Logical column ``c`` of a
(slot, row) lives at offset ``c % bs`` of block ``table[c // bs]``, so a
block gather followed by a reshape reconstructs the exact contiguous
``(S, B, C, Dh)`` view the decode kernel already understands — decode
masking, ring appends, and the ownership rule (§2) all carry over unchanged.

Allocation topology (which table entries are nonzero) is owned by the
host-side ``BlockPool``; every function here trusts the table it is given.
All ops are pure on the array pytree, mirroring the slot-cache API.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.cache.slot_cache import SlotCache, ring_write_index, rows_to_mask
from repro.paging import kvquant
from repro.paging.block_pool import BlockPool, PagingConfig, blocks_for_tokens


@jax.tree_util.register_dataclass
@dataclass
class PagedCache:
    k_pool: jnp.ndarray  # (L, N, bs, Dh)
    v_pool: jnp.ndarray  # (L, N, bs, Dh)
    pos_pool: jnp.ndarray  # (L, N, bs) int32
    block_table: jnp.ndarray  # (L, S, B, M) int32; 0 = null block
    lengths: jnp.ndarray  # (L, S, B) int32
    positions: jnp.ndarray  # (B,) int32
    k_scale: Optional[jnp.ndarray] = None  # (L, N) fp32 per-block scales
    v_scale: Optional[jnp.ndarray] = None  # (L, N) fp32 per-block scales

    @property
    def block_size(self) -> int:
        return self.k_pool.shape[2]

    @property
    def n_blocks(self) -> int:
        return self.k_pool.shape[1]

    @property
    def max_blocks(self) -> int:
        return self.block_table.shape[3]

    @property
    def n_slots(self) -> int:
        return self.block_table.shape[1]


def max_blocks_per_row(capacity: int, block_size: int) -> int:
    return blocks_for_tokens(capacity, block_size)


def block_hbm_bytes(block_size: int, head_dim: int, dtype,
                    quantized: bool) -> int:
    """HBM bytes one K+V block pins: payload plus, when quantized, the two
    fp32 scale-pool entries (the bytes-aware admission unit, DESIGN.md §15)."""
    item = jnp.dtype(dtype).itemsize
    return 2 * block_size * head_dim * item + (8 if quantized else 0)


def init_paged_cache(
    n_layers: int, n_slots: int, batch: int, capacity: int, head_dim: int,
    paging: PagingConfig, dtype=jnp.bfloat16,
    partitions: Tuple[int, int] = (1, 1),
    kv_quant: Optional[kvquant.KVQuantSpec] = None,
) -> Tuple[PagedCache, BlockPool]:
    """Empty paged cache + its allocator.

    ``paging.n_blocks == 0`` sizes the pool to the slot-cache worst case
    (``S·B·M + 1`` per layer-partition): every (slot, row) can be fully
    allocated, so this mode can never preempt — it trades no memory but
    validates the paged data path end to end.  ``paging.pool_hbm_bytes``
    instead sizes the pool from a byte budget using the *actual* storage
    dtype's block footprint — the bytes-aware admission mode (§15): a
    quantized pool fits ~itemsize-ratio more blocks in the same budget, and
    the downstream block-count admission needs no other change.

    ``partitions = (slot_parts, row_parts)`` (the mesh executor,
    DESIGN.md §10) splits each layer's pool into equal partitions indexed
    ``p = slot_part · row_parts + row_part`` — blocks for (slot s, row r)
    live in the partition of (s's model shard, r's data shard), so the
    pool array shards cleanly over ``(model, data)`` and every append and
    gather stays device-local.  A configured ``paging.n_blocks`` is
    rounded up to a multiple of the partition count.

    ``kv_quant`` (from ``kvquant.spec_from_paging``) switches the pools to
    int8 code storage with zero-initialized (L, N) scale pools; ``dtype``
    then only enters the worst-case/byte pool sizing as the *logical* model
    dtype, not the storage dtype.
    """
    bs = paging.block_size
    M = max_blocks_per_row(capacity, bs)
    slot_parts, row_parts = partitions
    if slot_parts < 1 or n_slots % slot_parts:
        raise ValueError(
            f"{n_slots} slots do not split into {slot_parts} partitions")
    if row_parts < 1 or batch % row_parts:
        raise ValueError(
            f"{batch} rows do not split into {row_parts} partitions")
    n_partitions = slot_parts * row_parts
    pool_dtype = jnp.int8 if kv_quant is not None else dtype
    if paging.n_blocks:
        part = -(-paging.n_blocks // n_partitions)  # ceil: round up
    elif paging.pool_hbm_bytes:
        per_block = block_hbm_bytes(bs, head_dim, pool_dtype,
                                    kv_quant is not None)
        total = paging.pool_hbm_bytes // (n_layers * per_block)
        part = max(2, total // n_partitions)  # floor: budget is a cap
    else:
        part = (n_slots // slot_parts) * (batch // row_parts) * M + 1
    n_blocks = part * n_partitions
    scale = (jnp.zeros((n_layers, n_blocks), jnp.float32)
             if kv_quant is not None else None)
    cache = PagedCache(
        k_pool=jnp.zeros((n_layers, n_blocks, bs, head_dim), pool_dtype),
        v_pool=jnp.zeros((n_layers, n_blocks, bs, head_dim), pool_dtype),
        pos_pool=jnp.full((n_layers, n_blocks, bs), -1, jnp.int32),
        block_table=jnp.zeros((n_layers, n_slots, batch, M), jnp.int32),
        lengths=jnp.zeros((n_layers, n_slots, batch), jnp.int32),
        positions=jnp.zeros((batch,), jnp.int32),
        k_scale=scale, v_scale=scale,
    )
    return cache, BlockPool(n_layers, n_blocks, n_partitions=n_partitions)


# ---------------------------------------------------------------------------
# Views
# ---------------------------------------------------------------------------
# The single-layer block gather lives in kernels/paged_decode
# .paged_gather_views, next to its consumer; ref.paged_fairkv_decode_ref
# deliberately carries an independent copy (oracles stay self-contained so
# the parity test cannot compare a bug against itself).


def paged_to_slot(cache: PagedCache, capacity: int,
                  kinds: Optional[jnp.ndarray] = None,
                  out_dtype=None) -> SlotCache:
    """Full materialization into a SlotCache (migration / debugging).

    Entries outside each (slot, row)'s valid prefix are zeroed (pos −1) so
    the result obeys the slot-cache masking contract exactly; the decode
    output over the result is bit-identical to the paged path.

    **Deep copy by construction**: the result is a pure gather — pool
    tensors are never aliased into the output, so materializing rows whose
    blocks are shared (refcount > 1 under prefix reuse, DESIGN.md §14)
    copies the shared content and can never mutate it.  The pool-
    conservation regression test in tests/test_prefix.py pins this down.

    Quantized pools dequantize through the scale pools — the *same*
    scale/kind interpretation the decode kernel applies (DESIGN.md §15), so
    slot↔paged migration stays bit-consistent with the decode path.
    ``kinds`` is the (L, S) per-slot kind grid (``kvquant.slot_kinds``;
    all-int8 assumed when omitted); ``out_dtype`` casts the dequantized
    values (the model dtype — default fp32).
    """
    L, N, bs, Dh = cache.k_pool.shape
    _, S, B, M = cache.block_table.shape
    gids = (jnp.arange(L, dtype=jnp.int32)[:, None, None, None] * N
            + jnp.maximum(cache.block_table, 0))  # (L, S, B, M)
    k = cache.k_pool.reshape(L * N, bs, Dh)[gids]  # (L, S, B, M, bs, Dh)
    v = cache.v_pool.reshape(L * N, bs, Dh)[gids]
    if cache.k_scale is not None:
        kind = (jnp.zeros((L, S), jnp.int32) if kinds is None
                else jnp.asarray(kinds, jnp.int32))
        kind = kind[:, :, None, None, None, None]
        ksc = cache.k_scale.reshape(-1)[gids][..., None, None]
        vsc = cache.v_scale.reshape(-1)[gids][..., None, None]
        k = kvquant.decode(k, ksc, kind)
        v = kvquant.decode(v, vsc, kind)
        if out_dtype is not None:
            k, v = k.astype(out_dtype), v.astype(out_dtype)
    k = k.reshape(L, S, B, M * bs, Dh)
    v = v.reshape(L, S, B, M * bs, Dh)
    pos = cache.pos_pool.reshape(L * N, bs)[gids].reshape(L, S, B, M * bs)
    k, v, pos = k[..., :capacity, :], v[..., :capacity, :], pos[..., :capacity]
    valid = (jnp.arange(capacity, dtype=jnp.int32)[None, None, None, :]
             < cache.lengths[..., None])  # (L, S, B, C)
    return SlotCache(
        k=jnp.where(valid[..., None], k, 0),
        v=jnp.where(valid[..., None], v, 0),
        lengths=cache.lengths,
        pos=jnp.where(valid, pos, -1),
        positions=cache.positions,
    )


# ---------------------------------------------------------------------------
# Writes
# ---------------------------------------------------------------------------


def paged_append_token(
    cache: PagedCache,
    layer: int,
    k_new: jnp.ndarray,  # (S, B, Dh) post-RoPE
    v_new: jnp.ndarray,  # (S, B, Dh)
    own: jnp.ndarray,  # (S, B) bool
    decode_step: jnp.ndarray,  # scalar int32: appends since prefill
    capacity: int,
    ring: int = 128,
    table_layer: Optional[jnp.ndarray] = None,  # (S, B, M) addressing override
    kinds: Optional[jnp.ndarray] = None,  # (S,) per-slot kind codes
) -> PagedCache:
    """Append one token for owned (slot, row) pairs — `append_token` parity.

    The write index (including the full-row recency ring) is identical to
    the slot cache's `ring_write_index`; the backend must have allocated the
    block covering it (`prepare_decode`) before the jitted step runs.
    Unowned pairs — and, defensively, owned pairs whose block is missing —
    are redirected into the null block, never corrupting live data.
    Length accounting matches the slot cache exactly (`own` increments).

    ``table_layer`` overrides the table used for *addressing* only (the
    stored ``block_table`` is untouched): the mesh executor passes a
    partition-localized view when pool ids in the stored table are global
    but the pool array in scope is one shard's partition (DESIGN.md §10).

    Quantized pools (``cache.k_scale is not None``) quantize on write
    (DESIGN.md §15): the target block's scale grows monotonically
    (``max(old, amax(|token|)/qmax)``), the whole block is dequantized at
    the old scale, the token inserted, and the block re-encoded at the new
    scale.  When the scale did not grow the re-encode is an exact identity
    on the untouched entries (codes round-trip), so repeated appends into
    one block never compound error.  ``kinds`` carries the (S,) per-slot
    interpretation (``kvquant.slot_kinds`` row — all-int8 when omitted);
    invalid pairs rewrite their gathered null-block values unchanged, so
    duplicate null-redirected scatters stay write-idempotent.
    """
    bs = cache.block_size
    lengths = cache.lengths[layer]  # (S, B)
    idx = ring_write_index(lengths, decode_step, capacity, ring)  # (S, B)
    blk, off = idx // bs, idx % bs
    table = cache.block_table[layer] if table_layer is None else table_layer
    bid = jnp.take_along_axis(table, blk[..., None], axis=2)[..., 0]  # (S, B)
    valid = own & (bid > 0)
    bid = jnp.where(valid, bid, 0)
    kl, vl, pl = cache.k_pool[layer], cache.v_pool[layer], cache.pos_pool[layer]
    p_new = jnp.broadcast_to(cache.positions[None, :], own.shape)
    p_upd = jnp.where(valid, p_new, pl[bid, off]).astype(jnp.int32)
    new_len = jnp.where(own, jnp.minimum(lengths + 1, capacity), lengths)
    if cache.k_scale is None:
        k_upd = jnp.where(valid[..., None], k_new.astype(kl.dtype),
                          kl[bid, off])
        v_upd = jnp.where(valid[..., None], v_new.astype(vl.dtype),
                          vl[bid, off])
        k_pool = cache.k_pool.at[layer].set(kl.at[bid, off].set(k_upd))
        v_pool = cache.v_pool.at[layer].set(vl.at[bid, off].set(v_upd))
        k_scale = v_scale = None
    else:
        kind = (jnp.zeros((own.shape[0],), jnp.int32) if kinds is None
                else jnp.asarray(kinds, jnp.int32))
        kind_sb = jnp.broadcast_to(kind[:, None], own.shape)  # (S, B)
        qmax = kvquant.qmax_of(kind_sb)
        ksc, vsc = cache.k_scale[layer], cache.v_scale[layer]  # (N,)
        onehot = (jnp.arange(bs, dtype=jnp.int32)[None, None, :]
                  == off[..., None])  # (S, B, bs)
        ins = valid[..., None] & onehot  # entries receiving the new token

        def requant(pool_l, scale_l, token):
            token = token.astype(jnp.float32)
            old_s = scale_l[bid]  # (S, B)
            new_s = jnp.where(
                valid,
                jnp.maximum(old_s, jnp.max(jnp.abs(token), axis=-1) / qmax),
                old_s)
            block = kvquant.decode(pool_l[bid], old_s[..., None, None],
                                   kind_sb[..., None, None])  # (S, B, bs, Dh)
            block = jnp.where(ins[..., None], token[:, :, None, :], block)
            codes = kvquant.encode(block, new_s[..., None, None],
                                   kind_sb[..., None, None])
            codes = jnp.where(valid[..., None, None], codes, pool_l[bid])
            return (pool_l.at[bid].set(codes),
                    scale_l.at[bid].set(jnp.where(valid, new_s, old_s)))

        kl_new, ksc_new = requant(kl, ksc, k_new)
        vl_new, vsc_new = requant(vl, vsc, v_new)
        k_pool = cache.k_pool.at[layer].set(kl_new)
        v_pool = cache.v_pool.at[layer].set(vl_new)
        k_scale = cache.k_scale.at[layer].set(ksc_new)
        v_scale = cache.v_scale.at[layer].set(vsc_new)
    return PagedCache(
        k_pool=k_pool,
        v_pool=v_pool,
        pos_pool=cache.pos_pool.at[layer].set(pl.at[bid, off].set(p_upd)),
        block_table=cache.block_table,
        lengths=cache.lengths.at[layer].set(new_len.astype(jnp.int32)),
        positions=cache.positions,
        k_scale=k_scale, v_scale=v_scale,
    )


def paginate_rows(
    cache: PagedCache,
    sub: SlotCache,
    rows: jnp.ndarray,  # (B_sub,) target global rows
    table_sub: np.ndarray,  # (L, S, B_sub, M) int32 freshly allocated ids
    table_store: Optional[np.ndarray] = None,  # (L, S, B_sub, M) stored ids
    kinds: Optional[np.ndarray] = None,  # (L, S) per-slot kind codes
) -> PagedCache:
    """Copy a prefilled slot sub-cache into freshly allocated blocks.

    ``table_sub`` comes from the backend's allocator (`BlockPool.alloc`):
    entry ``[l, s, b, j]`` is the block holding columns
    ``[j·bs, (j+1)·bs)`` of that (slot, row), 0 past the allocated count.
    One global scatter per tensor; unallocated tail blocks are redirected
    into the null block.  The target rows' table/lengths/positions are fully
    replaced (they must have been released first).

    ``table_store`` (optional) decouples the *stored* block table from the
    write addressing: shared-prefix admission (DESIGN.md §14) stores the
    full table (shared donor blocks + fresh tail) while passing a write
    table whose shared entries are zeroed — the null-redirect then
    guarantees refcount>1 blocks are never written, which is the
    copy-on-write immutability rule.  Default: store ``table_sub`` itself.

    Quantized pools block-quantize the sub-cache on the way in
    (``kvquant.quantize_blocks``): per-block scales from the valid-entry
    amax, invalid entries zero-coded, scales scattered through the same
    null-redirected gids as the payload (DESIGN.md §15).  ``kinds`` is the
    (L, S) per-slot interpretation grid (all-int8 when omitted).
    """
    L, N, bs, Dh = cache.k_pool.shape
    _, S, B_sub, C, _ = sub.k.shape
    M = table_sub.shape[3]
    pad = M * bs - C
    if pad < 0:
        raise ValueError(f"sub capacity {C} exceeds table span {M * bs}")
    k_sub = jnp.pad(sub.k, ((0, 0),) * 3 + ((0, pad), (0, 0)))
    v_sub = jnp.pad(sub.v, ((0, 0),) * 3 + ((0, pad), (0, 0)))
    p_sub = jnp.pad(sub.pos, ((0, 0),) * 3 + ((0, pad),), constant_values=-1)
    k_scales = v_scales = None
    if cache.k_scale is not None:
        kind = (jnp.zeros((L, S), jnp.int32) if kinds is None
                else jnp.asarray(kinds, jnp.int32))
        kind = kind[:, :, None, None]  # broadcasts over (L, S, B_sub, M)
        k_sub, k_scales = kvquant.quantize_blocks(k_sub, p_sub, bs, kind)
        v_sub, v_scales = kvquant.quantize_blocks(v_sub, p_sub, bs, kind)
    k_sub = k_sub.reshape(L, S, B_sub, M, bs, Dh)
    v_sub = v_sub.reshape(L, S, B_sub, M, bs, Dh)
    p_sub = p_sub.reshape(L, S, B_sub, M, bs)
    tbl = np.asarray(table_sub, np.int64)
    gids = np.where(tbl > 0,
                    np.arange(L, dtype=np.int64)[:, None, None, None] * N + tbl,
                    0)  # null-redirect: block 0 of layer 0
    gids = jnp.asarray(gids.reshape(-1), jnp.int32)
    k_pool = (cache.k_pool.reshape(L * N, bs, Dh)
              .at[gids].set(k_sub.reshape(-1, bs, Dh).astype(cache.k_pool.dtype))
              .reshape(L, N, bs, Dh))
    v_pool = (cache.v_pool.reshape(L * N, bs, Dh)
              .at[gids].set(v_sub.reshape(-1, bs, Dh).astype(cache.v_pool.dtype))
              .reshape(L, N, bs, Dh))
    pos_pool = (cache.pos_pool.reshape(L * N, bs)
                .at[gids].set(p_sub.reshape(-1, bs))
                .reshape(L, N, bs))
    k_scale, v_scale = cache.k_scale, cache.v_scale
    if k_scales is not None:
        k_scale = (k_scale.reshape(-1).at[gids].set(k_scales.reshape(-1))
                   .reshape(L, N))
        v_scale = (v_scale.reshape(-1).at[gids].set(v_scales.reshape(-1))
                   .reshape(L, N))
    rows = jnp.asarray(rows, jnp.int32)
    stored = table_sub if table_store is None else table_store
    return PagedCache(
        k_pool=k_pool, v_pool=v_pool, pos_pool=pos_pool,
        block_table=cache.block_table.at[:, :, rows, :].set(
            jnp.asarray(stored, jnp.int32)),
        lengths=cache.lengths.at[:, :, rows].set(sub.lengths),
        positions=cache.positions.at[rows].set(sub.positions),
        k_scale=k_scale, v_scale=v_scale,
    )


def release_rows(cache: PagedCache, rows) -> PagedCache:
    """Device half of row retirement: clear table/lengths/positions.

    ``rows`` is a (B,) bool mask or an int index array (like
    `slot_cache.reset_rows`).  Pool contents are left in place —
    unreferenced blocks are recycled by the host allocator
    (`BlockPool.decref`), which the backend drives.
    """
    m = rows_to_mask(rows, cache.positions.shape[0])
    return PagedCache(
        k_pool=cache.k_pool, v_pool=cache.v_pool, pos_pool=cache.pos_pool,
        block_table=jnp.where(m[None, None, :, None], 0, cache.block_table),
        lengths=jnp.where(m[None, None, :], 0, cache.lengths),
        positions=jnp.where(m, 0, cache.positions),
        k_scale=cache.k_scale, v_scale=cache.v_scale,
    )


def build_table(
    lengths: np.ndarray,  # (L, S, B_sub) realized retained lengths
    pool: BlockPool,
    block_size: int,
    max_blocks: int,
    own: Optional[np.ndarray] = None,  # (L, S, B_sub) bool ownership
    partitions: Tuple[int, int] = (1, 1),  # (slot_parts, row_parts)
    rows: Optional[np.ndarray] = None,  # (B_sub,) target *global* row ids
    n_rows: Optional[int] = None,  # global batch width (row partitioning)
) -> np.ndarray:
    """Allocate blocks proportional to realized lengths → (L, S, B_sub, M)
    table.

    Owned (slot, row) pairs get at least one block even at length 0 so the
    first decode append always has a home (matching the slot cache, where
    every owned pair can append immediately).  Under a partitioned pool
    (mesh executor) a (slot s, global row r) pair draws from partition
    ``(s // (S/slot_parts)) · row_parts + r // (n_rows/row_parts)`` — its
    (model, data) shard's pool slice; ``rows`` are the target global row
    ids of the (possibly sub-batch) ``lengths`` columns.  Atomic: on
    ``PoolExhausted`` everything allocated so far is returned to the pool
    before re-raising.
    """
    L, S, B = lengths.shape
    slot_parts, row_parts = partitions
    if pool.n_partitions != slot_parts * row_parts:
        raise ValueError(
            f"pool has {pool.n_partitions} partitions, expected "
            f"{slot_parts}x{row_parts}")
    if S % slot_parts:
        raise ValueError(
            f"{S} slots do not split into {slot_parts} partitions")
    s_per = S // slot_parts
    rows = np.arange(B) if rows is None else np.asarray(rows, np.int64)
    n_rows = B if n_rows is None else int(n_rows)
    b_per = -(-n_rows // row_parts)
    row_part = rows // b_per  # (B_sub,) data partition of each column
    need = -(-np.asarray(lengths, np.int64) // block_size)  # ceil-div
    if own is not None:
        need = np.maximum(need, np.asarray(own, np.int64))
    if need.max(initial=0) > max_blocks:
        raise ValueError(
            f"row needs {need.max()} blocks > max_blocks {max_blocks}")
    table = np.zeros((L, S, B, max_blocks), np.int32)
    fill = (np.arange(max_blocks, dtype=np.int64)[None, None, :]
            < need[..., None])  # (L, S, B, M) slots to fill
    done = []  # (layer, ids) already allocated, for rollback
    try:
        for l in range(L):
            for sp in range(slot_parts):
                sl = slice(sp * s_per, (sp + 1) * s_per)
                for rp in range(row_parts):
                    cols = np.nonzero(row_part == rp)[0]
                    if cols.size == 0:
                        continue
                    sub_need = need[l, sl][:, cols]
                    ids = pool.alloc(l, int(sub_need.sum()),
                                     partition=sp * row_parts + rp)
                    done.append((l, ids))
                    # row-major mask == sequential per-(slot,row) fill
                    part = np.zeros((s_per, cols.size, max_blocks), np.int32)
                    part[fill[l, sl][:, cols]] = ids
                    sub = table[l, sl]
                    sub[:, cols] = part
                    table[l, sl] = sub
    except Exception:
        for l, ids in done:
            if ids:
                pool.decref(l, ids)
        raise
    return table
