"""Shared paged-layer fixture for kernel parity tests and benchmarks.

Builds one layer's (pools, table, lengths) the adversarial way: block ids
handed out in *shuffled* order (so nothing accidentally relies on
contiguity), every pool entry a valid column does not overwrite left as
garbage (so missing masking surfaces as a parity failure, not silent
zeros), absolute positions written per column.  Used by
``tests/test_paged_kernel.py`` and ``benchmarks/fig9_paged_kernel.py`` so
the committed fig9 parity number always validates the same construction
the tests gate.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np


def make_paged_layer(rng, S, B, C, bs, Dh, empty_frac=0.3, dtype=np.float32,
                     lengths: Optional[np.ndarray] = None):
    """One layer's (k_pool, v_pool, pos_pool, block_table, lengths) as jnp
    arrays; ``lengths`` defaults to a ragged draw with ``empty_frac`` of
    the (slot, row) pairs fully empty (all-null table rows)."""
    M = -(-C // bs)
    if lengths is None:
        lengths = rng.integers(1, C + 1, size=(S, B)).astype(np.int32)
        lengths[rng.random((S, B)) < empty_frac] = 0
    else:
        lengths = np.asarray(lengths, np.int32)
    need = -(-lengths // bs)
    N = int(need.sum()) + 2
    ids = list(rng.permutation(np.arange(1, N)))
    table = np.zeros((S, B, M), np.int32)  # 0 = null block
    k_pool = rng.normal(size=(N, bs, Dh)).astype(dtype)
    v_pool = rng.normal(size=(N, bs, Dh)).astype(dtype)
    # garbage positions everywhere a valid column does not overwrite them
    pos_pool = rng.integers(-1, 10**6, size=(N, bs)).astype(np.int32)
    for s in range(S):
        for b in range(B):
            n = int(need[s, b])
            blocks = [ids.pop() for _ in range(n)]
            table[s, b, :n] = blocks
            for c in range(int(lengths[s, b])):
                pos_pool[blocks[c // bs], c % bs] = c  # absolute positions
    return (jnp.asarray(k_pool), jnp.asarray(v_pool), jnp.asarray(pos_pool),
            jnp.asarray(table), jnp.asarray(lengths))


def quantize_paged_layer(k_pool, v_pool, block_table, kinds):
    """Quantize a `make_paged_layer` fp32 pool pair into the storage the
    quantized decode path consumes (DESIGN.md §15).

    Each block is encoded whole (garbage tail entries included — they are
    the same magnitude as real data in this fixture, so they exercise the
    masking without distorting scales) at its owning slot's ``kinds`` entry,
    resolved through ``block_table``; unowned blocks (the null block and
    spares) encode as int8.  Returns
    ``(k_codes, v_codes, k_scale, v_scale)`` with codes shaped like the
    pools (int8) and (N,) fp32 per-block scales.
    """
    from repro.paging import kvquant

    N = k_pool.shape[0]
    tbl = np.asarray(block_table)
    kinds = np.asarray(kinds, np.int32)
    block_kind = np.zeros((N,), np.int32)
    for s in range(tbl.shape[0]):
        owned = np.unique(tbl[s][tbl[s] > 0])
        block_kind[owned] = kinds[s]
    qmax = np.where(block_kind == kvquant.KIND_FP8,
                    kvquant.FP8_QMAX, kvquant.INT8_QMAX)
    k = np.asarray(k_pool, np.float32)
    v = np.asarray(v_pool, np.float32)
    k_scale = np.abs(k).max(axis=(1, 2)) / qmax
    v_scale = np.abs(v).max(axis=(1, 2)) / qmax
    kb = jnp.asarray(block_kind)[:, None, None]
    k_codes = kvquant.encode(jnp.asarray(k),
                             jnp.asarray(k_scale, np.float32)[:, None, None],
                             kb)
    v_codes = kvquant.encode(jnp.asarray(v),
                             jnp.asarray(v_scale, np.float32)[:, None, None],
                             kb)
    return (k_codes, v_codes, jnp.asarray(k_scale, jnp.float32),
            jnp.asarray(v_scale, jnp.float32))
