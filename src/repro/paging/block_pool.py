"""Host-side block allocator for the paged KV backend (DESIGN.md §9).

The device-side cache is a per-layer pool of fixed-size K/V blocks
(``paged_cache.PagedCache``); this module owns the *topology*: which blocks
of each layer's pool are free, and how many references each allocated block
holds.  Allocation decisions are host-side Python (the scheduler runs on the
host anyway), while the arrays the decisions describe live on device — the
same split vLLM uses between its block manager and its paged attention
kernel.

Block id 0 of every layer is the reserved **null block**: block-table entries
that point nowhere hold 0, and masked writes (unowned rows, unallocated
slots) are redirected into it, so a scatter never needs data-dependent shape
logic.  The null block's contents are garbage by design; every read path
masks by retained length before the garbage can surface.

Refcounts exist so a future copy-on-write fork (shared-prefix requests) can
reuse blocks; today every block has refcount 1 while allocated.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, List, Optional

import numpy as np

from repro.obs import NULL_OBS


class PoolExhausted(RuntimeError):
    """Raised when an allocation cannot be satisfied.

    The scheduler treats this as a *preemption signal*, not an error: it
    frees the youngest active request back to QUEUED and retries — the pool
    never hands out a block it does not have, so exhaustion can never
    corrupt live cache contents.
    """


@dataclass(frozen=True)
class PagingConfig:
    """Knobs for the paged cache backend.

    ``block_size``: tokens per K/V block (per slot-row, per layer).
    ``n_blocks``: per-layer pool size *including* the reserved null block;
    0 sizes the pool to the slot-cache worst case (every (slot, row) fully
    allocated) so nothing can ever be preempted — useful as a drop-in
    correctness mode.  Undersize it deliberately to trade preemptions for
    HBM (the fig7 benchmark's equal-HBM comparison).
    ``decode_impl``: the paged decode-attention implementation
    (``kernels.ops.PAGED_DECODE_IMPLS``): "pallas" is the native
    block-table kernel (HBM traffic proportional to allocated blocks,
    DESIGN.md §11), "gather" materializes capacity-sized views and reuses
    the slot kernel, "jnp" is the pure-jnp oracle, and "auto" (default)
    picks pallas on TPU and jnp elsewhere.  Validated here at construction
    (`EngineConfig` composes this config), so a typo fails before any
    StepFn traces.
    ``kv_dtype``: KV pool storage format (DESIGN.md §15) — "fp32" (the
    default: pools in the engine dtype, no quantization, bit-identical to
    pre-quantization behavior), "int8", or "fp8" (requires a jax with
    float8_e4m3fn).  Quantized pools carry parallel per-block scale pools
    and dequantize in the decode inner loop.
    ``kv_dtype_overrides``: per-(layer, head) format overrides — a mapping
    ``{(layer, head): "int8"|"fp8"}`` (or the equivalent tuple of triples),
    the planner's per-head precision axis; only meaningful when
    ``kv_dtype`` is quantized.
    ``pool_hbm_bytes``: size the per-layer pool from an HBM byte budget
    instead of a block count (mutually exclusive with ``n_blocks > 0``) —
    the bytes-aware admission knob: at the same byte budget an int8 pool
    holds ~2x the blocks of an fp32-equivalent pool, so admission
    (block-count based) automatically admits ~2x the tokens.
    """

    block_size: int = 16
    n_blocks: int = 0
    decode_impl: str = "auto"
    kv_dtype: str = "fp32"
    kv_dtype_overrides: tuple = ()
    pool_hbm_bytes: int = 0

    def __post_init__(self):
        if self.block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {self.block_size}")
        if self.n_blocks < 0:
            raise ValueError(f"n_blocks must be >= 0, got {self.n_blocks}")
        from repro.kernels.ops import PAGED_DECODE_IMPLS
        if self.decode_impl not in PAGED_DECODE_IMPLS:
            raise ValueError(
                f"unknown decode_impl {self.decode_impl!r}; known: "
                f"{list(PAGED_DECODE_IMPLS)}")
        from repro.paging import kvquant
        if self.kv_dtype not in kvquant.KV_DTYPES:
            raise ValueError(
                f"unknown kv_dtype {self.kv_dtype!r}; known: "
                f"{list(kvquant.KV_DTYPES)}")
        if self.kv_dtype == "fp8" and not kvquant.fp8_supported():
            raise ValueError(
                "kv_dtype='fp8' requires a jax with float8_e4m3fn support")
        # canonicalize the override map to a sorted hashable tuple (the
        # frozen dataclass must stay usable as a static jit argument)
        ov = self.kv_dtype_overrides
        if isinstance(ov, dict):
            ov = tuple((lh[0], lh[1], dt) for lh, dt in ov.items())
        ov = tuple(sorted((int(l), int(h), str(dt)) for l, h, dt in ov))
        object.__setattr__(self, "kv_dtype_overrides", ov)
        if ov and self.kv_dtype == "fp32":
            raise ValueError(
                "kv_dtype_overrides require a quantized base kv_dtype")
        for l, h, dt in ov:
            if dt not in kvquant.QUANT_DTYPES:
                raise ValueError(
                    f"kv_dtype override ({l}, {h}) -> {dt!r}: must be one "
                    f"of {list(kvquant.QUANT_DTYPES)}")
            if dt == "fp8" and not kvquant.fp8_supported():
                raise ValueError(
                    f"kv_dtype override ({l}, {h}) -> 'fp8' requires a jax "
                    "with float8_e4m3fn support")
            if l < 0 or h < 0:
                raise ValueError(
                    f"kv_dtype override ({l}, {h}): indices must be >= 0")
        if self.pool_hbm_bytes < 0:
            raise ValueError(
                f"pool_hbm_bytes must be >= 0, got {self.pool_hbm_bytes}")
        if self.pool_hbm_bytes and self.n_blocks:
            raise ValueError(
                "pool_hbm_bytes and n_blocks are mutually exclusive pool "
                "sizing modes; set exactly one (or neither for worst-case)")


def blocks_for_tokens(tokens: int, block_size: int) -> int:
    """ceil(tokens / block_size) — blocks needed to hold ``tokens`` entries."""
    return -(-int(tokens) // int(block_size))


class BlockPool:
    """Free-list + refcounts over each layer's block pool.

    Deterministic: blocks are handed out lowest-id-first per layer, so
    identical request traces produce identical block tables (mirrors the
    scheduler's lowest-row-first freelist).

    ``n_partitions > 1`` splits every layer's pool into equal contiguous
    partitions with *independent* free lists — the mesh executor's layout
    (DESIGN.md §10), where partition ``p`` is the slice of the pool that
    physically lives on model shard ``p`` and only blocks of that partition
    may back the shard's slots.  Every partition reserves its local block 0
    (global id ``p · part_size``) as a null block, so a shard-local view of
    the pool keeps the null-redirect convention.  Block ids remain *global*
    everywhere on the host; the partition of an id is ``id // part_size``.
    """

    def __init__(self, n_layers: int, n_blocks: int, n_partitions: int = 1):
        if n_partitions < 1:
            raise ValueError(f"n_partitions must be >= 1, got {n_partitions}")
        if n_blocks % n_partitions:
            raise ValueError(
                f"{n_blocks} blocks/layer do not split into "
                f"{n_partitions} equal partitions")
        part = n_blocks // n_partitions
        if part < 2:
            raise ValueError(
                f"need >= 2 blocks per partition (1 null + 1 usable), got "
                f"{part} ({n_blocks} blocks / {n_partitions} partitions)")
        self.n_layers = int(n_layers)
        self.n_blocks = int(n_blocks)
        self.n_partitions = int(n_partitions)
        self.part_size = part
        # observability handle (DESIGN.md §12): alloc/free/exhaustion
        # counters; the owning backend swaps in the engine's live Obs
        self.obs = NULL_OBS
        nulls = [p * part for p in range(n_partitions)]
        self.refcount = np.zeros((n_layers, n_blocks), np.int32)
        self.refcount[:, nulls] = 1  # null blocks: pinned forever
        # descending so list.pop() returns the lowest free id
        self._free: List[List[List[int]]] = [
            [list(range((p + 1) * part - 1, p * part, -1))
             for p in range(n_partitions)]
            for _ in range(n_layers)]

    # ---- introspection -----------------------------------------------------

    def free_blocks(self, layer: Optional[int] = None,
                    partition: Optional[int] = None):
        """Free count for one layer (summed over partitions unless one is
        named), or (L,) array for all layers."""
        if layer is not None:
            if partition is not None:
                return len(self._free[layer][partition])
            return sum(len(f) for f in self._free[layer])
        return np.asarray([sum(len(f) for f in fs) for fs in self._free],
                          np.int64)

    def free_blocks_by_partition(self) -> np.ndarray:
        """(L, n_partitions) free counts."""
        return np.asarray([[len(f) for f in fs] for fs in self._free],
                          np.int64)

    def blocks_in_use(self) -> int:
        """Total allocated blocks across layers (null blocks excluded)."""
        usable = self.n_layers * self.usable_blocks
        return int(usable - int(self.free_blocks().sum()))

    @property
    def usable_blocks(self) -> int:
        """Allocatable blocks per layer (null blocks are never handed out)."""
        return self.n_blocks - self.n_partitions

    def partition_of(self, block_id: int) -> int:
        return int(block_id) // self.part_size

    def sample_gauges(self, metrics) -> None:
        """Record the pool-pressure gauges (DESIGN.md §12): free/in-use
        totals, per-partition free counts, max refcount, and fragmentation
        — free blocks stranded outside each layer's *tightest* partition.
        Admission gates on the worst partition, so stranded blocks are free
        yet unusable for the next admission."""
        free = self.free_blocks_by_partition()  # (L, P)
        metrics.gauge(
            "pool_free_blocks",
            help="free KV blocks, summed over layers and partitions"
        ).set(int(free.sum()))
        metrics.gauge(
            "pool_blocks_in_use",
            help="allocated KV blocks across all layers (nulls excluded)"
        ).set(self.blocks_in_use())
        g = metrics.gauge(
            "pool_free_blocks_partition",
            help="free KV blocks per pool partition (one partition per "
                 "(model shard, data shard) pair), summed over layers")
        for p, v in enumerate(free.sum(axis=0)):
            g.set(int(v), partition=str(p))
        metrics.gauge(
            "pool_fragmentation_blocks",
            help="free blocks outside each layer's tightest partition — "
                 "free but unusable for the admission the tightest "
                 "partition is about to refuse"
        ).set(int((free - free.min(axis=1, keepdims=True)).sum()))
        metrics.gauge(
            "pool_max_refcount",
            help="max block refcount (copy-on-write sharing depth; 1 = "
                 "no sharing)"
        ).set(int(self.refcount.max()))

    # ---- alloc / free ------------------------------------------------------

    def alloc(self, layer: int, n: int, partition: int = 0) -> List[int]:
        """Allocate ``n`` blocks in ``layer``'s ``partition`` (refcount 1
        each); returned ids are global.

        Atomic: raises ``PoolExhausted`` without handing out anything if the
        partition has fewer than ``n`` free blocks.
        """
        free = self._free[layer][partition]
        if n > len(free):
            self.obs.metrics.counter(
                "pool_exhausted_total",
                help="allocations refused by an empty free list (the "
                     "scheduler's preemption signal)").inc()
            raise PoolExhausted(
                f"layer {layer} partition {partition}: requested {n} "
                f"blocks, {len(free)} free "
                f"(pool {self.usable_blocks}/layer)")
        ids = [free.pop() for _ in range(n)]
        self.refcount[layer, ids] = 1
        self.obs.metrics.counter(
            "pool_alloc_blocks_total",
            help="KV blocks handed out by the pool").inc(n)
        return ids

    def incref(self, layer: int, ids: Iterable[int]) -> None:
        for b in ids:
            if self.refcount[layer, b] < 1:
                raise ValueError(f"incref of unallocated block {b} "
                                 f"in layer {layer}")
            self.refcount[layer, b] += 1

    def decref(self, layer: int, ids: Iterable[int]) -> None:
        """Drop one reference per id; blocks reaching 0 return to their
        partition's free list.  Refcounts can never go negative:
        over-freeing raises."""
        freed: List[int] = []
        for b in ids:
            b = int(b)
            if b % self.part_size == 0:
                raise ValueError(f"null block {b} cannot be freed")
            rc = int(self.refcount[layer, b])
            if rc <= 0:
                raise ValueError(
                    f"double free: block {b} of layer {layer} has "
                    f"refcount {rc}")
            self.refcount[layer, b] = rc - 1
            if rc == 1:
                freed.append(b)
        if freed:
            self.obs.metrics.counter(
                "pool_freed_blocks_total",
                help="KV blocks returned to the pool "
                     "(refcount reached 0)").inc(len(freed))
            for p in {self.partition_of(b) for b in freed}:
                fl = self._free[layer][p]
                fl.extend(b for b in freed if self.partition_of(b) == p)
                fl.sort(reverse=True)  # lowest-id-first via pop()

    def free_table(self, table: np.ndarray) -> None:
        """Decref every nonzero entry of an (L, ..., M) id table slice."""
        for layer in range(self.n_layers):
            ids = table[layer].reshape(-1)
            ids = ids[ids > 0]
            if ids.size:
                self.decref(layer, ids.tolist())

    def clone(self) -> "BlockPool":
        """Deep copy — used to *trial* a migration before committing."""
        out = BlockPool.__new__(BlockPool)
        out.n_layers, out.n_blocks = self.n_layers, self.n_blocks
        out.n_partitions, out.part_size = self.n_partitions, self.part_size
        out.obs = self.obs
        out.refcount = self.refcount.copy()
        out._free = [[list(f) for f in fs] for fs in self._free]
        return out

    def check_invariants(self) -> None:
        """Debug/test hook: free lists and refcounts partition the pool."""
        nulls = {p * self.part_size for p in range(self.n_partitions)}
        for layer in range(self.n_layers):
            free = set()
            for p, fl in enumerate(self._free[layer]):
                assert all(self.partition_of(b) == p for b in fl), (
                    f"layer {layer}: foreign id in partition {p} free list")
                free.update(fl)
            n_free = sum(len(f) for f in self._free[layer])
            assert not (free & nulls), "null block leaked into a free list"
            assert len(free) == n_free, "duplicate free ids"
            for b in range(self.n_blocks):
                if b in nulls:
                    continue
                rc = int(self.refcount[layer, b])
                assert rc >= 0, f"negative refcount {rc}"
                assert (b in free) == (rc == 0), (
                    f"layer {layer} block {b}: refcount {rc} but "
                    f"{'free' if b in free else 'allocated'}")
