"""Host-side block allocator for the paged KV backend (DESIGN.md §9).

The device-side cache is a per-layer pool of fixed-size K/V blocks
(``paged_cache.PagedCache``); this module owns the *topology*: which blocks
of each layer's pool are free, and how many references each allocated block
holds.  Allocation decisions are host-side Python (the scheduler runs on the
host anyway), while the arrays the decisions describe live on device — the
same split vLLM uses between its block manager and its paged attention
kernel.

Block id 0 of every layer is the reserved **null block**: block-table entries
that point nowhere hold 0, and masked writes (unowned rows, unallocated
slots) are redirected into it, so a scatter never needs data-dependent shape
logic.  The null block's contents are garbage by design; every read path
masks by retained length before the garbage can surface.

Refcounts exist so a future copy-on-write fork (shared-prefix requests) can
reuse blocks; today every block has refcount 1 while allocated.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, List, Optional, Sequence

import numpy as np


class PoolExhausted(RuntimeError):
    """Raised when an allocation cannot be satisfied.

    The scheduler treats this as a *preemption signal*, not an error: it
    frees the youngest active request back to QUEUED and retries — the pool
    never hands out a block it does not have, so exhaustion can never
    corrupt live cache contents.
    """


@dataclass(frozen=True)
class PagingConfig:
    """Knobs for the paged cache backend.

    ``block_size``: tokens per K/V block (per slot-row, per layer).
    ``n_blocks``: per-layer pool size *including* the reserved null block;
    0 sizes the pool to the slot-cache worst case (every (slot, row) fully
    allocated) so nothing can ever be preempted — useful as a drop-in
    correctness mode.  Undersize it deliberately to trade preemptions for
    HBM (the fig7 benchmark's equal-HBM comparison).
    """

    block_size: int = 16
    n_blocks: int = 0

    def __post_init__(self):
        if self.block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {self.block_size}")
        if self.n_blocks < 0:
            raise ValueError(f"n_blocks must be >= 0, got {self.n_blocks}")


def blocks_for_tokens(tokens: int, block_size: int) -> int:
    """ceil(tokens / block_size) — blocks needed to hold ``tokens`` entries."""
    return -(-int(tokens) // int(block_size))


class BlockPool:
    """Free-list + refcounts over each layer's block pool.

    Deterministic: blocks are handed out lowest-id-first per layer, so
    identical request traces produce identical block tables (mirrors the
    scheduler's lowest-row-first freelist).
    """

    def __init__(self, n_layers: int, n_blocks: int):
        if n_blocks < 2:
            raise ValueError(
                f"need >= 2 blocks per layer (1 null + 1 usable), "
                f"got {n_blocks}")
        self.n_layers = int(n_layers)
        self.n_blocks = int(n_blocks)
        self.refcount = np.zeros((n_layers, n_blocks), np.int32)
        self.refcount[:, 0] = 1  # null block: pinned forever
        # descending so list.pop() returns the lowest free id
        self._free: List[List[int]] = [
            list(range(n_blocks - 1, 0, -1)) for _ in range(n_layers)]

    # ---- introspection -----------------------------------------------------

    def free_blocks(self, layer: Optional[int] = None):
        """Free count for one layer, or (L,) array for all layers."""
        if layer is not None:
            return len(self._free[layer])
        return np.asarray([len(f) for f in self._free], np.int64)

    def blocks_in_use(self) -> int:
        """Total allocated blocks across layers (null blocks excluded)."""
        return int(sum(self.n_blocks - 1 - len(f) for f in self._free))

    @property
    def usable_blocks(self) -> int:
        """Allocatable blocks per layer (the null block is never handed out)."""
        return self.n_blocks - 1

    # ---- alloc / free ------------------------------------------------------

    def alloc(self, layer: int, n: int) -> List[int]:
        """Allocate ``n`` blocks in ``layer`` (refcount 1 each).

        Atomic: raises ``PoolExhausted`` without handing out anything if the
        layer has fewer than ``n`` free blocks.
        """
        free = self._free[layer]
        if n > len(free):
            raise PoolExhausted(
                f"layer {layer}: requested {n} blocks, {len(free)} free "
                f"(pool {self.usable_blocks}/layer)")
        ids = [free.pop() for _ in range(n)]
        self.refcount[layer, ids] = 1
        return ids

    def incref(self, layer: int, ids: Iterable[int]) -> None:
        for b in ids:
            if self.refcount[layer, b] < 1:
                raise ValueError(f"incref of unallocated block {b} "
                                 f"in layer {layer}")
            self.refcount[layer, b] += 1

    def decref(self, layer: int, ids: Iterable[int]) -> None:
        """Drop one reference per id; blocks reaching 0 return to the
        free list.  Refcounts can never go negative: over-freeing raises."""
        freed = []
        for b in ids:
            b = int(b)
            if b == 0:
                raise ValueError("null block cannot be freed")
            rc = int(self.refcount[layer, b])
            if rc <= 0:
                raise ValueError(
                    f"double free: block {b} of layer {layer} has "
                    f"refcount {rc}")
            self.refcount[layer, b] = rc - 1
            if rc == 1:
                freed.append(b)
        if freed:
            self._free[layer].extend(freed)
            self._free[layer].sort(reverse=True)  # lowest-id-first via pop()

    def free_table(self, table: np.ndarray) -> None:
        """Decref every nonzero entry of an (L, ..., M) id table slice."""
        for layer in range(self.n_layers):
            ids = table[layer].reshape(-1)
            ids = ids[ids > 0]
            if ids.size:
                self.decref(layer, ids.tolist())

    def clone(self) -> "BlockPool":
        """Deep copy — used to *trial* a migration before committing."""
        out = BlockPool.__new__(BlockPool)
        out.n_layers, out.n_blocks = self.n_layers, self.n_blocks
        out.refcount = self.refcount.copy()
        out._free = [list(f) for f in self._free]
        return out

    def check_invariants(self) -> None:
        """Debug/test hook: free lists and refcounts partition the pool."""
        for layer in range(self.n_layers):
            free = set(self._free[layer])
            assert 0 not in free, "null block leaked into the free list"
            assert len(free) == len(self._free[layer]), "duplicate free ids"
            for b in range(1, self.n_blocks):
                rc = int(self.refcount[layer, b])
                assert rc >= 0, f"negative refcount {rc}"
                assert (b in free) == (rc == 0), (
                    f"layer {layer} block {b}: refcount {rc} but "
                    f"{'free' if b in free else 'allocated'}")
