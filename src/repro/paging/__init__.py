"""Paged KV backend (DESIGN.md §9): block-pool allocator, paged cache
arrays, and the `CacheBackend` implementation that plugs them into the
serving stack via ``EngineConfig.cache_backend = "paged"``.

Import graph note: ``paged_cache``/``block_pool`` are leaves (no serving
imports) so the serving engine can dispatch on `PagedCache` without a
cycle; ``backend`` sits on top of serving and registers itself.
"""
from repro.paging.block_pool import (  # noqa: F401
    BlockPool,
    PagingConfig,
    PoolExhausted,
    blocks_for_tokens,
)
from repro.paging.paged_cache import (  # noqa: F401
    PagedCache,
    build_table,
    init_paged_cache,
    max_blocks_per_row,
    paged_append_token,
    paged_to_slot,
    paginate_rows,
)
from repro.paging.backend import PagedBackend  # noqa: F401
