"""Slot-layout budgeted KV cache (FairKV-native)."""
from repro.cache.slot_cache import (  # noqa: F401
    PlanArrays,
    SlotCache,
    append_token,
    fill_from_selection,
    init_cache,
    ring_write_index,
)
