"""Slot-layout budgeted KV cache — the FairKV-native runtime structure.

Layout (see DESIGN.md §2):  per layer, every model shard owns
``slots_per_shard`` *slots*; globally the cache tensors are

    k, v     : (L, S, B, C, Dh)   S = total slots (sharded over "model"),
                                   C = static capacity per slot-row
    lengths  : (L, S, B) int32     retained tokens per (slot, row); 0 for
                                   unowned rows and empty slots
    positions: (B,) int32          next absolute position per row (for RoPE)

Replicas of one head split the batch by the strided rule
``owner(slot, b) = (b % replica_count) == replica_idx``; a slot only ever has
nonzero ``lengths`` on rows it owns, which simultaneously implements
best-effort assignment, fair-copying, and empty-slot padding: work inside the
decode kernel is proportional to Σ lengths.

Decode appends are ring-buffered in the tail of the capacity region once a
row is full: keys are stored post-RoPE (rotation at absolute positions), so
attention is order-independent and overwriting the oldest *dynamic* entry
implements a recency window without any re-sorting.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.placement import HeadPlacement


@jax.tree_util.register_dataclass
@dataclass
class PlanArrays:
    """Runtime form of a HeadPlacement.

    slot_head / replica_idx / replica_count: (L, S) int32.
    first_slot: (L, Hkv) int32 — the replica-0 slot of each head (used by
    prefill to recover original-layout weights from the slot layout without
    storing a second copy).
    """

    slot_head: jnp.ndarray
    replica_idx: jnp.ndarray
    replica_count: jnp.ndarray
    first_slot: jnp.ndarray

    @staticmethod
    def from_plan(plan: HeadPlacement) -> "PlanArrays":
        arrs = plan.as_arrays()
        sh = arrs["slot_head"]
        L, S = sh.shape
        first = np.zeros((L, plan.n_heads), dtype=np.int32)
        for l in range(L):
            for h in range(plan.n_heads):
                slots = np.nonzero(sh[l] == h)[0]
                first[l, h] = int(slots[0])
        return PlanArrays(
            slot_head=jnp.asarray(arrs["slot_head"]),
            replica_idx=jnp.asarray(arrs["replica_idx"]),
            replica_count=jnp.asarray(arrs["replica_count"]),
            first_slot=jnp.asarray(first),
        )

    def owner_mask(self, layer: int, batch: int) -> jnp.ndarray:
        """(S, B) bool — slot owns row."""
        rows = jnp.arange(batch, dtype=jnp.int32)[None, :]
        rc = self.replica_count[layer][:, None]
        ri = self.replica_idx[layer][:, None]
        valid = (self.slot_head[layer] >= 0)[:, None]
        return valid & ((rows % rc) == ri)


@jax.tree_util.register_dataclass
@dataclass
class SlotCache:
    k: jnp.ndarray  # (L, S, B, C, Dh)
    v: jnp.ndarray  # (L, S, B, C, Dh)
    lengths: jnp.ndarray  # (L, S, B) int32
    pos: jnp.ndarray  # (L, S, B, C) int32 — absolute position of each entry
    positions: jnp.ndarray  # (B,) int32

    @property
    def capacity(self) -> int:
        return self.k.shape[3]

    @property
    def n_slots(self) -> int:
        return self.k.shape[1]


def init_cache(n_layers: int, n_slots: int, batch: int, capacity: int,
               head_dim: int, dtype=jnp.bfloat16) -> SlotCache:
    return SlotCache(
        k=jnp.zeros((n_layers, n_slots, batch, capacity, head_dim), dtype),
        v=jnp.zeros((n_layers, n_slots, batch, capacity, head_dim), dtype),
        lengths=jnp.zeros((n_layers, n_slots, batch), jnp.int32),
        pos=jnp.full((n_layers, n_slots, batch, capacity), -1, jnp.int32),
        positions=jnp.zeros((batch,), jnp.int32),
    )


def ring_write_index(lengths: jnp.ndarray, total_appended: jnp.ndarray,
                     capacity: int, ring: int) -> jnp.ndarray:
    """Write position for the next token.

    While a row is below capacity, append at ``lengths``.  Once full, cycle
    through the last ``ring`` positions (a recency window) — overwritten
    entries are the oldest *dynamic* tokens; the head of the buffer (the
    compression-selected prefix) is preserved.
    ``total_appended`` counts decode appends so far (for the cycle phase).
    """
    ring = max(1, min(ring, capacity))
    ring_start = capacity - ring
    cyc = ring_start + total_appended % ring  # phase shared across rows; a ring
    return jnp.where(lengths < capacity, lengths, cyc).astype(jnp.int32)


def append_token(
    cache: SlotCache,
    layer: int,
    k_new: jnp.ndarray,  # (S, B, Dh) post-RoPE
    v_new: jnp.ndarray,  # (S, B, Dh)
    own: jnp.ndarray,  # (S, B) bool
    decode_step: jnp.ndarray,  # scalar int32: appends since prefill
    ring: int = 128,
    mode: str = "scatter",
) -> SlotCache:
    """Append one token into layer ``layer`` for owned (slot, row) pairs.

    ``mode="scatter"`` uses advanced-index scatter (baseline; XLA SPMD falls
    back to a replicated scatter — ~4 collectives per layer on the (S,B,Dh)
    projections).  ``mode="onehot"`` writes via an elementwise mask over the
    capacity dim — fully local under (slot, batch) sharding at the cost of a
    full cache-slice rewrite (measured trade in EXPERIMENTS.md §Perf).
    """
    L, S, B, C, Dh = cache.k.shape
    lengths = cache.lengths[layer]  # (S, B)
    idx = ring_write_index(lengths, decode_step, C, ring)  # (S, B)
    k_layer = cache.k[layer]
    v_layer = cache.v[layer]
    p_layer = cache.pos[layer]
    k_new = k_new.astype(cache.k.dtype)
    v_new = v_new.astype(cache.v.dtype)
    p_new = jnp.broadcast_to(cache.positions[None, :], (S, B))
    if mode == "onehot":
        sel = (jnp.arange(C, dtype=jnp.int32)[None, None, :] == idx[:, :, None])
        sel &= own[:, :, None]  # (S, B, C)
        k_layer = jnp.where(sel[..., None], k_new[:, :, None, :], k_layer)
        v_layer = jnp.where(sel[..., None], v_new[:, :, None, :], v_layer)
        p_layer = jnp.where(sel, p_new[:, :, None], p_layer)
    else:
        s_ix = jnp.arange(S)[:, None].repeat(B, 1)
        b_ix = jnp.arange(B)[None, :].repeat(S, 0)
        # write only where owned (unowned rows keep old values)
        k_upd = jnp.where(own[..., None], k_new, k_layer[s_ix, b_ix, idx])
        v_upd = jnp.where(own[..., None], v_new, v_layer[s_ix, b_ix, idx])
        p_upd = jnp.where(own, p_new, p_layer[s_ix, b_ix, idx])
        k_layer = k_layer.at[s_ix, b_ix, idx].set(k_upd)
        v_layer = v_layer.at[s_ix, b_ix, idx].set(v_upd)
        p_layer = p_layer.at[s_ix, b_ix, idx].set(p_upd.astype(jnp.int32))
    new_len = jnp.where(own, jnp.minimum(lengths + 1, C), lengths)
    return SlotCache(
        k=cache.k.at[layer].set(k_layer),
        v=cache.v.at[layer].set(v_layer),
        lengths=cache.lengths.at[layer].set(new_len.astype(jnp.int32)),
        pos=cache.pos.at[layer].set(p_layer),
        positions=cache.positions,
    )


def fill_from_selection(
    cache: SlotCache,
    layer: int,
    k_full: jnp.ndarray,  # (B, T, Hkv, Dh) post-RoPE prefill keys
    v_full: jnp.ndarray,  # (B, T, Hkv, Dh)
    sel_idx: jnp.ndarray,  # (B, Hkv, C) selected positions into T
    sel_len: jnp.ndarray,  # (B, Hkv) int32 retained counts (<= C)
    plan: PlanArrays,
) -> SlotCache:
    """Scatter the compression-selected prefill KV into slot layout."""
    L, S, B, C, Dh = cache.k.shape
    heads = plan.slot_head[layer]  # (S,)
    safe_heads = jnp.maximum(heads, 0)
    own = plan.owner_mask(layer, B)  # (S, B)
    # per-slot gather: idx (S, B, C) over T
    idx = jnp.take(sel_idx, safe_heads, axis=1).transpose(1, 0, 2)  # (S, B, C)

    def gather_one(kf, vf, ix):  # kf: (T, Hkv, Dh), ix: (S, C)
        hh = safe_heads  # (S,)
        kv_h = kf[:, hh, :]  # (T, S, Dh)
        vv_h = vf[:, hh, :]
        k_s = jnp.take_along_axis(kv_h.transpose(1, 0, 2), ix[..., None], axis=1)
        v_s = jnp.take_along_axis(vv_h.transpose(1, 0, 2), ix[..., None], axis=1)
        return k_s, v_s  # (S, C, Dh)

    k_sel, v_sel = jax.vmap(gather_one)(k_full, v_full, idx.transpose(1, 0, 2))
    # (B, S, Csel, Dh) -> (S, B, Csel, Dh); pad Csel up to cache capacity
    k_sel = k_sel.transpose(1, 0, 2, 3).astype(cache.k.dtype)
    v_sel = v_sel.transpose(1, 0, 2, 3).astype(cache.v.dtype)
    if k_sel.shape[2] < C:
        pad = C - k_sel.shape[2]
        k_sel = jnp.pad(k_sel, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v_sel = jnp.pad(v_sel, ((0, 0), (0, 0), (0, pad), (0, 0)))
    elif k_sel.shape[2] > C:
        raise ValueError(
            f"selection capacity {k_sel.shape[2]} exceeds cache capacity {C}")
    lens = jnp.take(sel_len, safe_heads, axis=1).T  # (S, B)
    lens = jnp.where(own, lens, 0).astype(jnp.int32)
    k_sel = jnp.where(own[..., None, None], k_sel, 0)
    v_sel = jnp.where(own[..., None, None], v_sel, 0)
    # entry positions == selected indices (prefill positions are arange(T));
    # pad/invalid entries get -1 (always outside any window, masked by length)
    pos_sel = idx.astype(jnp.int32)  # (S, B, C_sel)
    if pos_sel.shape[2] < C:
        pos_sel = jnp.pad(pos_sel, ((0, 0), (0, 0), (0, C - pos_sel.shape[2])),
                          constant_values=-1)
    pos_sel = jnp.where(own[..., None], pos_sel, -1)
    return SlotCache(
        k=cache.k.at[layer].set(k_sel),
        v=cache.v.at[layer].set(v_sel),
        lengths=cache.lengths.at[layer].set(lens),
        pos=cache.pos.at[layer].set(pos_sel),
        positions=cache.positions,
    )
