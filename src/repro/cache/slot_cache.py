"""Slot-layout budgeted KV cache — the FairKV-native runtime structure.

Layout (see DESIGN.md §2):  per layer, every model shard owns
``slots_per_shard`` *slots*; globally the cache tensors are

    k, v     : (L, S, B, C, Dh)   S = total slots (sharded over "model"),
                                   C = static capacity per slot-row

Under the ``mesh`` executor (DESIGN.md §10) this sharding is physical:
S splits over the model mesh axis and B over the data axis inside the
decode StepFn's ``shard_map``; every op below is written batch- and
slot-local, so it runs unchanged on one device or per-shard slices
(``migrate_cache``'s head-layout round-trip runs on global arrays between
steps, where XLA repartitions freely).
    lengths  : (L, S, B) int32     retained tokens per (slot, row); 0 for
                                   unowned rows and empty slots
    positions: (B,) int32          next absolute position per row (for RoPE)

Replicas of one head split the batch by the strided rule
``owner(slot, b) = (b % replica_count) == replica_idx``; a slot only ever has
nonzero ``lengths`` on rows it owns, which simultaneously implements
best-effort assignment, fair-copying, and empty-slot padding: work inside the
decode kernel is proportional to Σ lengths.

Decode appends are ring-buffered in the tail of the capacity region once a
row is full: keys are stored post-RoPE (rotation at absolute positions), so
attention is order-independent and overwriting the oldest *dynamic* entry
implements a recency window without any re-sorting.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.placement import HeadPlacement


@jax.tree_util.register_dataclass
@dataclass
class PlanArrays:
    """Runtime form of a HeadPlacement.

    slot_head / replica_idx / replica_count: (L, S) int32.
    first_slot: (L, Hkv) int32 — the replica-0 slot of each head (used by
    prefill to recover original-layout weights from the slot layout without
    storing a second copy).
    """

    slot_head: jnp.ndarray
    replica_idx: jnp.ndarray
    replica_count: jnp.ndarray
    first_slot: jnp.ndarray

    @staticmethod
    def from_plan(plan: HeadPlacement) -> "PlanArrays":
        arrs = plan.as_arrays()
        sh = arrs["slot_head"]
        L, S = sh.shape
        first = np.zeros((L, plan.n_heads), dtype=np.int32)
        for l in range(L):
            for h in range(plan.n_heads):
                slots = np.nonzero(sh[l] == h)[0]
                first[l, h] = int(slots[0])
        return PlanArrays(
            slot_head=jnp.asarray(arrs["slot_head"]),
            replica_idx=jnp.asarray(arrs["replica_idx"]),
            replica_count=jnp.asarray(arrs["replica_count"]),
            first_slot=jnp.asarray(first),
        )

    def owner_mask(self, layer: int, batch: int) -> jnp.ndarray:
        """(S, B) bool — slot owns row."""
        return self.owner_mask_rows(layer, jnp.arange(batch, dtype=jnp.int32))

    def owner_mask_rows(self, layer: int, rows: jnp.ndarray) -> jnp.ndarray:
        """(S, len(rows)) bool ownership for explicit *global* row ids.

        The strided owner rule keys on the global batch-row index, so a
        sub-batch (e.g. a freshly admitted request prefilled alone) must be
        masked with the rows it will occupy in the live cache, not with
        ``arange(sub_batch)`` — otherwise its KV lands on the wrong replica.
        """
        rows = jnp.asarray(rows, jnp.int32)[None, :]
        rc = self.replica_count[layer][:, None]
        ri = self.replica_idx[layer][:, None]
        valid = (self.slot_head[layer] >= 0)[:, None]
        return valid & ((rows % rc) == ri)

    def owner_mask_all(self, batch: int) -> jnp.ndarray:
        """(L, S, B) bool — vectorized owner_mask over every layer."""
        rows = jnp.arange(batch, dtype=jnp.int32)[None, None, :]
        rc = self.replica_count[:, :, None]
        ri = self.replica_idx[:, :, None]
        valid = (self.slot_head >= 0)[:, :, None]
        return valid & ((rows % rc) == ri)


@jax.tree_util.register_dataclass
@dataclass
class SlotCache:
    k: jnp.ndarray  # (L, S, B, C, Dh)
    v: jnp.ndarray  # (L, S, B, C, Dh)
    lengths: jnp.ndarray  # (L, S, B) int32
    pos: jnp.ndarray  # (L, S, B, C) int32 — absolute position of each entry
    positions: jnp.ndarray  # (B,) int32

    @property
    def capacity(self) -> int:
        return self.k.shape[3]

    @property
    def n_slots(self) -> int:
        return self.k.shape[1]


def init_cache(n_layers: int, n_slots: int, batch: int, capacity: int,
               head_dim: int, dtype=jnp.bfloat16) -> SlotCache:
    return SlotCache(
        k=jnp.zeros((n_layers, n_slots, batch, capacity, head_dim), dtype),
        v=jnp.zeros((n_layers, n_slots, batch, capacity, head_dim), dtype),
        lengths=jnp.zeros((n_layers, n_slots, batch), jnp.int32),
        pos=jnp.full((n_layers, n_slots, batch, capacity), -1, jnp.int32),
        positions=jnp.zeros((batch,), jnp.int32),
    )


def ring_write_index(lengths: jnp.ndarray, total_appended: jnp.ndarray,
                     capacity: int, ring: int) -> jnp.ndarray:
    """Write position for the next token.

    While a row is below capacity, append at ``lengths``.  Once full, cycle
    through the last ``ring`` positions (a recency window) — overwritten
    entries are the oldest *dynamic* tokens; the head of the buffer (the
    compression-selected prefix) is preserved.
    ``total_appended`` counts decode appends so far (for the cycle phase).
    """
    ring = max(1, min(ring, capacity))
    ring_start = capacity - ring
    cyc = ring_start + total_appended % ring  # phase shared across rows; a ring
    return jnp.where(lengths < capacity, lengths, cyc).astype(jnp.int32)


def append_token(
    cache: SlotCache,
    layer: int,
    k_new: jnp.ndarray,  # (S, B, Dh) post-RoPE
    v_new: jnp.ndarray,  # (S, B, Dh)
    own: jnp.ndarray,  # (S, B) bool
    decode_step: jnp.ndarray,  # scalar int32: appends since prefill
    ring: int = 128,
    mode: str = "scatter",
) -> SlotCache:
    """Append one token into layer ``layer`` for owned (slot, row) pairs.

    ``mode="scatter"`` uses advanced-index scatter (baseline; XLA SPMD falls
    back to a replicated scatter — ~4 collectives per layer on the (S,B,Dh)
    projections).  ``mode="onehot"`` writes via an elementwise mask over the
    capacity dim — fully local under (slot, batch) sharding at the cost of a
    full cache-slice rewrite (measured trade in EXPERIMENTS.md §Perf).
    """
    L, S, B, C, Dh = cache.k.shape
    lengths = cache.lengths[layer]  # (S, B)
    idx = ring_write_index(lengths, decode_step, C, ring)  # (S, B)
    k_layer = cache.k[layer]
    v_layer = cache.v[layer]
    p_layer = cache.pos[layer]
    k_new = k_new.astype(cache.k.dtype)
    v_new = v_new.astype(cache.v.dtype)
    p_new = jnp.broadcast_to(cache.positions[None, :], (S, B))
    if mode == "onehot":
        sel = (jnp.arange(C, dtype=jnp.int32)[None, None, :] == idx[:, :, None])
        sel &= own[:, :, None]  # (S, B, C)
        k_layer = jnp.where(sel[..., None], k_new[:, :, None, :], k_layer)
        v_layer = jnp.where(sel[..., None], v_new[:, :, None, :], v_layer)
        p_layer = jnp.where(sel, p_new[:, :, None], p_layer)
    else:
        s_ix = jnp.arange(S)[:, None].repeat(B, 1)
        b_ix = jnp.arange(B)[None, :].repeat(S, 0)
        # write only where owned (unowned rows keep old values)
        k_upd = jnp.where(own[..., None], k_new, k_layer[s_ix, b_ix, idx])
        v_upd = jnp.where(own[..., None], v_new, v_layer[s_ix, b_ix, idx])
        p_upd = jnp.where(own, p_new, p_layer[s_ix, b_ix, idx])
        k_layer = k_layer.at[s_ix, b_ix, idx].set(k_upd)
        v_layer = v_layer.at[s_ix, b_ix, idx].set(v_upd)
        p_layer = p_layer.at[s_ix, b_ix, idx].set(p_upd.astype(jnp.int32))
    new_len = jnp.where(own, jnp.minimum(lengths + 1, C), lengths)
    return SlotCache(
        k=cache.k.at[layer].set(k_layer),
        v=cache.v.at[layer].set(v_layer),
        lengths=cache.lengths.at[layer].set(new_len.astype(jnp.int32)),
        pos=cache.pos.at[layer].set(p_layer),
        positions=cache.positions,
    )


def fill_from_selection(
    cache: SlotCache,
    layer: int,
    k_full: jnp.ndarray,  # (B, T, Hkv, Dh) post-RoPE prefill keys
    v_full: jnp.ndarray,  # (B, T, Hkv, Dh)
    sel_idx: jnp.ndarray,  # (B, Hkv, C) selected positions into T
    sel_len: jnp.ndarray,  # (B, Hkv) int32 retained counts (<= C)
    plan: PlanArrays,
    rows: Optional[jnp.ndarray] = None,  # (B,) global row ids for ownership
) -> SlotCache:
    """Scatter the compression-selected prefill KV into slot layout.

    ``rows`` overrides the global row ids used by the strided owner rule —
    required when prefilling a sub-batch destined for specific rows of a
    larger live cache (continuous batching admission, DESIGN.md §7).
    """
    L, S, B, C, Dh = cache.k.shape
    heads = plan.slot_head[layer]  # (S,)
    safe_heads = jnp.maximum(heads, 0)
    own = (plan.owner_mask(layer, B) if rows is None
           else plan.owner_mask_rows(layer, rows))  # (S, B)
    # per-slot gather: idx (S, B, C) over T
    idx = jnp.take(sel_idx, safe_heads, axis=1).transpose(1, 0, 2)  # (S, B, C)

    def gather_one(kf, vf, ix):  # kf: (T, Hkv, Dh), ix: (S, C)
        hh = safe_heads  # (S,)
        kv_h = kf[:, hh, :]  # (T, S, Dh)
        vv_h = vf[:, hh, :]
        k_s = jnp.take_along_axis(kv_h.transpose(1, 0, 2), ix[..., None], axis=1)
        v_s = jnp.take_along_axis(vv_h.transpose(1, 0, 2), ix[..., None], axis=1)
        return k_s, v_s  # (S, C, Dh)

    k_sel, v_sel = jax.vmap(gather_one)(k_full, v_full, idx.transpose(1, 0, 2))
    # (B, S, Csel, Dh) -> (S, B, Csel, Dh); pad Csel up to cache capacity
    k_sel = k_sel.transpose(1, 0, 2, 3).astype(cache.k.dtype)
    v_sel = v_sel.transpose(1, 0, 2, 3).astype(cache.v.dtype)
    if k_sel.shape[2] < C:
        pad = C - k_sel.shape[2]
        k_sel = jnp.pad(k_sel, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v_sel = jnp.pad(v_sel, ((0, 0), (0, 0), (0, pad), (0, 0)))
    elif k_sel.shape[2] > C:
        raise ValueError(
            f"selection capacity {k_sel.shape[2]} exceeds cache capacity {C}")
    lens = jnp.take(sel_len, safe_heads, axis=1).T  # (S, B)
    lens = jnp.where(own, lens, 0).astype(jnp.int32)
    k_sel = jnp.where(own[..., None, None], k_sel, 0)
    v_sel = jnp.where(own[..., None, None], v_sel, 0)
    # entry positions == selected indices (prefill positions are arange(T));
    # pad/invalid entries get -1 (always outside any window, masked by length)
    pos_sel = idx.astype(jnp.int32)  # (S, B, C_sel)
    if pos_sel.shape[2] < C:
        pos_sel = jnp.pad(pos_sel, ((0, 0), (0, 0), (0, C - pos_sel.shape[2])),
                          constant_values=-1)
    pos_sel = jnp.where(own[..., None], pos_sel, -1)
    return SlotCache(
        k=cache.k.at[layer].set(k_sel),
        v=cache.v.at[layer].set(v_sel),
        lengths=cache.lengths.at[layer].set(lens),
        pos=cache.pos.at[layer].set(pos_sel),
        positions=cache.positions,
    )


def append_selection(
    cache: SlotCache,
    layer: int,
    k_full: jnp.ndarray,  # (B, Ck, Hkv, Dh) post-RoPE chunk keys
    v_full: jnp.ndarray,  # (B, Ck, Hkv, Dh)
    sel_idx: jnp.ndarray,  # (B, Hkv, Csel) selected positions into Ck
    sel_len: jnp.ndarray,  # (B, Hkv) int32 retained counts (<= Csel)
    plan: PlanArrays,
    rows: jnp.ndarray,  # (B,) global row ids for ownership
    start: jnp.ndarray,  # (B,) int32 absolute position of chunk token 0
) -> SlotCache:
    """Append a chunk's compression-selected KV *after* existing entries.

    The chunked-prefill counterpart of `fill_from_selection` (DESIGN.md
    §14): instead of replacing the layer's slice, selected entries land at
    columns ``lengths .. lengths+keep`` and entry positions are made
    absolute (``start + sel_idx``), so each chunk's keep-set accumulates
    into the slot layout and attention over the cache stays
    order-independent (keys are post-RoPE, positions explicit).  The caller
    guarantees headroom (``keep <= C - lengths``); columns past capacity are
    dropped defensively.
    """
    L, S, B, C, Dh = cache.k.shape
    heads = plan.slot_head[layer]  # (S,)
    safe_heads = jnp.maximum(heads, 0)
    own = plan.owner_mask_rows(layer, rows)  # (S, B)
    idx = jnp.take(sel_idx, safe_heads, axis=1).transpose(1, 0, 2)  # (S,B,Cs)

    def gather_one(kf, vf, ix):  # kf: (Ck, Hkv, Dh), ix: (S, Csel)
        hh = safe_heads  # (S,)
        kv_h = kf[:, hh, :]  # (Ck, S, Dh)
        vv_h = vf[:, hh, :]
        k_s = jnp.take_along_axis(kv_h.transpose(1, 0, 2), ix[..., None], axis=1)
        v_s = jnp.take_along_axis(vv_h.transpose(1, 0, 2), ix[..., None], axis=1)
        return k_s, v_s  # (S, Csel, Dh)

    k_sel, v_sel = jax.vmap(gather_one)(k_full, v_full, idx.transpose(1, 0, 2))
    k_sel = k_sel.transpose(1, 0, 2, 3).astype(cache.k.dtype)  # (S,B,Cs,Dh)
    v_sel = v_sel.transpose(1, 0, 2, 3).astype(cache.v.dtype)
    Csel = k_sel.shape[2]
    lens_new = jnp.take(sel_len, safe_heads, axis=1).T  # (S, B)
    lens_new = jnp.where(own, lens_new, 0).astype(jnp.int32)
    # absolute entry positions; invalid tail masked out by the column drop
    pos_sel = (start[None, :, None] + idx).astype(jnp.int32)  # (S, B, Csel)
    cur = cache.lengths[layer]  # (S, B)
    j = jnp.arange(Csel, dtype=jnp.int32)
    cols = cur[:, :, None] + j[None, None, :]  # (S, B, Csel)
    valid = j[None, None, :] < lens_new[:, :, None]
    cols = jnp.where(valid, cols, C)  # C = out of range -> mode="drop"
    s_ix = jnp.arange(S)[:, None, None]
    b_ix = jnp.arange(B)[None, :, None]
    k_layer = cache.k[layer].at[s_ix, b_ix, cols].set(k_sel, mode="drop")
    v_layer = cache.v[layer].at[s_ix, b_ix, cols].set(v_sel, mode="drop")
    p_layer = cache.pos[layer].at[s_ix, b_ix, cols].set(pos_sel, mode="drop")
    new_len = jnp.minimum(cur + lens_new, C)
    return SlotCache(
        k=cache.k.at[layer].set(k_layer),
        v=cache.v.at[layer].set(v_layer),
        lengths=cache.lengths.at[layer].set(new_len),
        pos=cache.pos.at[layer].set(p_layer),
        positions=cache.positions,
    )


# ---------------------------------------------------------------------------
# Row-level ops (continuous batching, DESIGN.md §7)
# ---------------------------------------------------------------------------


def rows_to_mask(rows, batch: int) -> jnp.ndarray:
    """(B,) bool mask from int row indices (bool input passes through)."""
    rows = jnp.asarray(rows)
    if rows.dtype == jnp.bool_:
        return rows
    return jnp.zeros((batch,), jnp.bool_).at[rows].set(True)


def reset_rows(cache: SlotCache, rows) -> SlotCache:
    """Retire batch rows: zero K/V and ``lengths``, invalidate ``pos``, and
    reset ``positions`` for every (layer, slot) of the given rows.

    ``rows`` is a (B,) bool mask or an int index array.  A reset row's decode
    output is exactly zero (the kernel masks by length), so retired rows ride
    along in the batched decode step for free until re-admission.
    """
    B = cache.k.shape[2]
    m = rows_to_mask(rows, B)
    return SlotCache(
        k=jnp.where(m[None, None, :, None, None], 0, cache.k),
        v=jnp.where(m[None, None, :, None, None], 0, cache.v),
        lengths=jnp.where(m[None, None, :], 0, cache.lengths),
        pos=jnp.where(m[None, None, :, None], -1, cache.pos),
        positions=jnp.where(m, 0, cache.positions),
    )


def insert_rows(cache: SlotCache, sub: SlotCache, rows: jnp.ndarray) -> SlotCache:
    """Splice a freshly prefilled sub-cache into the live cache.

    ``sub`` has batch ``len(rows)`` and must share (L, S, C, Dh) with
    ``cache``; its contents fully replace the target rows (lengths, pos and
    per-row ``positions`` included).  The sub-cache must have been filled with
    ownership computed at the *target* global row ids
    (``fill_from_selection(..., rows=rows)``), or replicas will disagree about
    who owns the spliced rows.
    """
    L, S, B, C, Dh = cache.k.shape
    if sub.k.shape[0] != L or sub.k.shape[1] != S or sub.k.shape[3:] != (C, Dh):
        raise ValueError(
            f"sub-cache layout {sub.k.shape} incompatible with {cache.k.shape}")
    rows = jnp.asarray(rows, jnp.int32)
    return SlotCache(
        k=cache.k.at[:, :, rows].set(sub.k.astype(cache.k.dtype)),
        v=cache.v.at[:, :, rows].set(sub.v.astype(cache.v.dtype)),
        lengths=cache.lengths.at[:, :, rows].set(sub.lengths),
        pos=cache.pos.at[:, :, rows].set(sub.pos),
        positions=cache.positions.at[rows].set(sub.positions),
    )


def gather_head_layout(cache: SlotCache, plan: PlanArrays):
    """Slot layout → original head layout.

    Returns ``(k, v, lengths, pos)`` with shapes ``(L, H, B, C, Dh)`` /
    ``(L, H, B)`` / ``(L, H, B, C)``.  Every (head, row) pair has exactly one
    owning slot (replicas partition the batch), so a masked sum over slots
    recovers the unique per-head entry.
    """
    L, S, B, C, Dh = cache.k.shape
    H = int(plan.first_slot.shape[1])
    own = plan.owner_mask_all(B)  # (L, S, B)
    onehot = (plan.slot_head[:, :, None]
              == jnp.arange(H, dtype=jnp.int32)[None, None, :])  # (L, S, H)
    ow = own.astype(jnp.float32)
    oh = onehot.astype(jnp.float32)
    k = jnp.einsum("lsh,lsb,lsbcd->lhbcd", oh, ow, cache.k.astype(jnp.float32))
    v = jnp.einsum("lsh,lsb,lsbcd->lhbcd", oh, ow, cache.v.astype(jnp.float32))
    lens = jnp.einsum("lsh,lsb,lsb->lhb", oh, ow,
                      cache.lengths.astype(jnp.float32))
    pos = jnp.einsum("lsh,lsb,lsbc->lhbc", oh, ow,
                     cache.pos.astype(jnp.float32))
    return (k.astype(cache.k.dtype), v.astype(cache.v.dtype),
            lens.astype(jnp.int32), jnp.round(pos).astype(jnp.int32))


def migrate_cache(cache: SlotCache, old_plan: PlanArrays,
                  new_plan: PlanArrays) -> SlotCache:
    """Re-layout a live cache for a new HeadPlacement (online replanning).

    Gathers the cache back to original head layout under ``old_plan``, then
    scatters it into the slot/ownership layout of ``new_plan``.  Capacity and
    the slot-grid width must match (replans keep ``slots_per_shard`` fixed);
    row ``positions`` are plan-independent and carried through unchanged.
    """
    L, S, B, C, Dh = cache.k.shape
    if new_plan.slot_head.shape != old_plan.slot_head.shape:
        raise ValueError(
            f"plan slot grids differ: {old_plan.slot_head.shape} vs "
            f"{new_plan.slot_head.shape}")
    k_h, v_h, len_h, pos_h = gather_head_layout(cache, old_plan)
    heads = jnp.maximum(new_plan.slot_head, 0)  # (L, S)
    own = new_plan.owner_mask_all(B)  # (L, S, B)
    idx = heads[:, :, None, None, None]
    k_s = jnp.take_along_axis(k_h, idx, axis=1)  # (L, S, B, C, Dh)
    v_s = jnp.take_along_axis(v_h, idx, axis=1)
    len_s = jnp.take_along_axis(len_h, heads[:, :, None], axis=1)
    pos_s = jnp.take_along_axis(pos_h, heads[:, :, None, None], axis=1)
    return SlotCache(
        k=jnp.where(own[..., None, None], k_s, 0),
        v=jnp.where(own[..., None, None], v_s, 0),
        lengths=jnp.where(own, len_s, 0).astype(jnp.int32),
        pos=jnp.where(own[..., None], pos_s, -1),
        positions=cache.positions,
    )
