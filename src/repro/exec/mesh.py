"""`MeshExecutor`: the StepFns under ``shard_map`` on a (data, model) mesh.

This is the execution path that makes Fair-Copying *physical* (DESIGN.md
§10): the slot dim — slot-layout attention weights, the slot cache, and the
paged backend's block tables and pools — shards over the ``model`` axis, so
each model shard owns exactly the head replicas the planner placed on it;
batch rows shard over ``data``, and replicas of one head split those rows
by the strided owner rule evaluated at *global* row ids.  Each (head, row)
pair then has exactly one owning slot somewhere on the mesh, so the decode
o-projection's per-shard partial contractions psum to the full batch — the
step's single collective.

Decode runs fully local otherwise: per-slot attention, cache appends, MLP
and unembed (replicated weights, batch-sharded rows).  Prefill runs in
original head layout, which needs every head's replica-0 weights — those
are all-gathered over ``model`` per layer (cheap next to prompt attention),
while the compression selection and per-slot cache fill stay local.
Prefill's non-cache outputs are replicated over ``model`` by construction
(identical math from identical gathered inputs), which shard_map's static
replication checker cannot prove — hence ``check_rep=False`` there.

Paged backend: the pool shards over ``model`` into per-shard partitions;
the partition-aware allocator (`repro.paging.block_pool.BlockPool` with
``n_partitions > 1``) guarantees a slot's blocks live in its shard's
partition, and the decode step localizes the stored global block ids by
subtracting the partition offset (`serving.engine._decode_attention`).

Constraints (checked at construction / call time): dense decoder-only
attention models, unquantized weights, ``n_slots`` divisible by the
model-axis size, decode batch divisible by the data-axis size (prefill
pads sub-batches automatically — continuous admission prefills one
request at a time).  MoE is excluded: its capacity-bounded dispatch sizes
expert capacity from the *global* token count (``models/moe.py``), so a
data-sharded batch changes drop behavior — supporting it needs expert
parallelism or per-shard capacity scaling, not replication.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.api.registry import register_executor
from repro.cache.slot_cache import PlanArrays, SlotCache
from repro.exec.base import Executor
from repro.paging.paged_cache import PagedCache
from repro.serving import engine as _serve

_FAMILIES = ("dense",)


@register_executor("mesh")
class MeshExecutor(Executor):
    name = "mesh"

    def __init__(self, model_cfg, ccfg, exec_cfg=None, mesh=None,
                 paging=None, obs=None):
        super().__init__(model_cfg, ccfg, exec_cfg=exec_cfg, mesh=mesh,
                         paging=paging, obs=obs)
        if mesh is None:
            raise ValueError(
                "executor='mesh' needs a mesh; build one with "
                "repro.launch.mesh.make_host_mesh(model=..., data=...) and "
                "pass it via Engine.build(..., mesh=...)")
        ec = self.exec_cfg
        for ax in (ec.data_axis, ec.model_axis):
            if ax not in mesh.axis_names:
                raise ValueError(
                    f"mesh axes {mesh.axis_names} do not include "
                    f"{ax!r}; ExecutorConfig names axes "
                    f"({ec.data_axis!r}, {ec.model_axis!r})")
        if model_cfg.family not in _FAMILIES:
            raise NotImplementedError(
                f"mesh executor supports dense decoder-only attention "
                f"models, got family {model_cfg.family!r} "
                f"({model_cfg.name}); use executor='local' (moe needs "
                f"expert parallelism: capacity-bounded dispatch is global-"
                f"batch dependent)")
        self.data_size = int(mesh.shape[ec.data_axis])
        self.model_size = int(mesh.shape[ec.model_axis])
        # memoized (shard_map + jit) StepFns keyed by arg structure
        self._prefill_jits = {}
        self._prefill_chunk_jits = {}
        self._decode_jits = {}
        self._propose_jits = {}
        self._verify_jits = {}

    @property
    def pool_partitions(self) -> int:
        return self.model_size

    @property
    def row_partitions(self) -> int:
        return self.data_size

    # ---- partition specs ---------------------------------------------------

    def _check_quant(self, sp):
        from repro.serving.quant import QTensor
        leaves = jax.tree.leaves(
            sp, is_leaf=lambda t: isinstance(t, QTensor))
        if any(isinstance(t, QTensor) for t in leaves):
            raise NotImplementedError(
                "mesh executor does not support quantized slot weights yet")

    def _sp_specs(self, sp):
        """Slot-layout leaves (dict key '*_s', slot dim leading) shard over
        model; everything else — embeddings, norms, MLP/MoE weights, the
        unembed table — is replicated (batch rows carry the data axis)."""
        m = self.exec_cfg.model_axis

        def leaf_spec(path, leaf):
            key = getattr(path[-1], "key", None)
            if isinstance(key, str) and key.endswith("_s"):
                return P(m, *([None] * (leaf.ndim - 1)))
            return P()

        return jax.tree_util.tree_map_with_path(leaf_spec, sp)

    def _pa_specs(self):
        m = self.exec_cfg.model_axis
        # first_slot holds *global* slot ids (prefill's replica-0 gather) —
        # it stays replicated while the (L, S) arrays shard over model
        return PlanArrays(slot_head=P(None, m), replica_idx=P(None, m),
                          replica_count=P(None, m), first_slot=P())

    def _cache_specs(self, cache):
        d, m = self.exec_cfg.data_axis, self.exec_cfg.model_axis
        if isinstance(cache, PagedCache):
            # the pool splits over BOTH axes: blocks of (slot, row) live on
            # the (slot's model shard, row's data shard) device, so appends
            # and gathers stay device-local (module docstring)
            n_dev = self.model_size * self.data_size
            if cache.n_blocks % n_dev:
                raise ValueError(
                    f"paged pool of {cache.n_blocks} blocks/layer does not "
                    f"split over model x data = {n_dev} devices; the "
                    f"backend must be built with pool_partitions="
                    f"{self.model_size}, row_partitions={self.data_size}")
            # quantized pools carry (L, N) per-block scale arrays that shard
            # over the same (model, data) split of the block axis as the
            # payload pools (DESIGN.md §15); None on the fp32 path keeps the
            # pytree structure matching
            scale = P(None, (m, d)) if cache.k_scale is not None else None
            return PagedCache(
                k_pool=P(None, (m, d)), v_pool=P(None, (m, d)),
                pos_pool=P(None, (m, d)),
                block_table=P(None, m, d), lengths=P(None, m, d),
                positions=P(d), k_scale=scale, v_scale=scale)
        return SlotCache(k=P(None, m, d), v=P(None, m, d),
                         lengths=P(None, m, d), pos=P(None, m, d),
                         positions=P(d))

    def _state_specs(self, state):
        d = self.exec_cfg.data_axis
        return _serve.ServeState(
            cache=self._cache_specs(state.cache),
            ssm_state=None, conv_state=None, cross_k=None, cross_v=None,
            last_tokens=P(d), decode_steps=P())

    def _check_grid(self, pa):
        S = int(pa.slot_head.shape[1])
        if S % self.model_size:
            raise ValueError(
                f"{S} slots do not split over model={self.model_size}; "
                f"plan with n_shards (or slots_per_shard) a multiple of "
                f"the mesh model-axis size")

    # ---- prefill -----------------------------------------------------------

    def _build_prefill(self, sp_specs, state_specs, has_hi):
        cfg, ccfg = self.cfg, self.ccfg
        ec = self.exec_cfg

        def inner(sp, batch, pa, rows, head_importance):
            self.prefill_traces += 1  # runs at trace time only
            return _serve.prefill(sp, batch, cfg, pa, ccfg,
                                  head_importance=head_importance, rows=rows,
                                  model_axis=ec.model_axis)

        d = ec.data_axis
        fn = shard_map(
            inner, mesh=self.mesh,
            in_specs=(sp_specs, {"tokens": P(d)}, self._pa_specs(), P(d),
                      P() if has_hi else None),
            out_specs=(state_specs, P(d), P(None, None, d)),
            # non-cache outputs are replicated over model by construction
            # (identical math from all-gathered weights); not statically
            # provable, so the rep checker is off here (module docstring)
            check_rep=False)
        return jax.jit(fn)

    def prefill(self, sp, batch, pa, rows=None, head_importance=None):
        self._check_quant(sp)
        self._check_grid(pa)
        tokens = batch["tokens"]
        B = int(tokens.shape[0])
        if set(batch) != {"tokens"}:
            raise NotImplementedError(
                f"mesh prefill supports token prompts, got batch keys "
                f"{sorted(batch)}")
        if rows is None:
            rows = jnp.arange(B, dtype=jnp.int32)
        rows = jnp.asarray(rows, jnp.int32)
        # pad the sub-batch up to the data-axis width (continuous admission
        # prefills one request at a time); padded rows reuse the last real
        # row id — their output is sliced off before anything consumes it
        pad = (-B) % self.data_size
        if pad:
            tokens = jnp.concatenate(
                [tokens, jnp.zeros((pad, tokens.shape[1]), tokens.dtype)])
            rows = jnp.concatenate([rows, jnp.repeat(rows[-1:], pad)])
        hi = None if head_importance is None else jnp.asarray(head_importance)

        # a template state fixes the out-spec structure (always slot layout)
        state_specs = _serve.ServeState(
            cache=self._cache_specs(SlotCache(None, None, None, None, None)),
            ssm_state=None, conv_state=None, cross_k=None, cross_v=None,
            last_tokens=P(self.exec_cfg.data_axis), decode_steps=P())
        sp_specs = self._sp_specs(sp)
        key = (jax.tree.structure(sp_specs), hi is not None)
        if key not in self._prefill_jits:
            self._prefill_jits[key] = self._build_prefill(
                sp_specs, state_specs, hi is not None)
        args = (sp, {"tokens": tokens}, pa, rows, hi)
        if self.obs.enabled:
            state, logits, lengths = self._observe_step(
                "prefill", self._prefill_jits[key], args)
        else:
            state, logits, lengths = self._prefill_jits[key](*args)
        if pad:
            state = _slice_state_rows(state, B)
            logits, lengths = logits[:B], lengths[..., :B]
        return state, logits, lengths

    # ---- chunked prefill (DESIGN.md §14) -----------------------------------

    def _build_prefill_chunk(self, sp_specs, state_specs, has_hi):
        cfg, ccfg = self.cfg, self.ccfg
        ec = self.exec_cfg

        def inner(sp, tokens, pa, state, rows, start, valid, quota,
                  head_importance):
            self.prefill_chunk_traces += 1  # runs at trace time only
            return _serve.prefill_chunk(sp, tokens, cfg, pa, ccfg, state,
                                        rows, start, valid, quota,
                                        head_importance=head_importance,
                                        model_axis=ec.model_axis)

        d = ec.data_axis
        fn = shard_map(
            inner, mesh=self.mesh,
            in_specs=(sp_specs, P(d), self._pa_specs(), state_specs, P(d),
                      P(d), P(d), P(), P() if has_hi else None),
            out_specs=(state_specs, P(d), P(None, None, d)),
            # chunk attention all-gathers the cache over model; non-cache
            # outputs are replicated by construction (same as prefill)
            check_rep=False)
        donate = (3,) if ec.donate_state else ()
        return jax.jit(fn, donate_argnums=donate)

    def prefill_chunk(self, sp, tokens, pa, state, rows, start, valid, quota,
                      head_importance=None):
        self._check_quant(sp)
        self._check_grid(pa)
        if not isinstance(state.cache, SlotCache):
            raise NotImplementedError(
                "mesh chunked prefill accumulates into a slot-layout "
                "sub-state (pagination happens at splice)")
        tokens = jnp.asarray(tokens, jnp.int32)
        B = int(tokens.shape[0])
        rows = jnp.asarray(rows, jnp.int32)
        start = jnp.asarray(start, jnp.int32)
        valid = jnp.asarray(valid, jnp.int32)
        # pad the sub-batch up to the data-axis width; padded rows repeat
        # the last real row with valid=0, so they select nothing and their
        # state columns are sliced off before anything consumes them
        pad = (-B) % self.data_size
        if pad:
            tokens = jnp.concatenate(
                [tokens, jnp.zeros((pad, tokens.shape[1]), tokens.dtype)])
            rows = jnp.concatenate([rows, jnp.repeat(rows[-1:], pad)])
            start = jnp.concatenate([start, jnp.zeros((pad,), jnp.int32)])
            valid = jnp.concatenate([valid, jnp.zeros((pad,), jnp.int32)])
            state = _pad_state_rows(state, pad)
        hi = None if head_importance is None else jnp.asarray(head_importance)
        state_specs = _serve.ServeState(
            cache=self._cache_specs(SlotCache(None, None, None, None, None)),
            ssm_state=None, conv_state=None, cross_k=None, cross_v=None,
            last_tokens=P(self.exec_cfg.data_axis), decode_steps=P())
        sp_specs = self._sp_specs(sp)
        key = (jax.tree.structure(sp_specs), hi is not None)
        if key not in self._prefill_chunk_jits:
            self._prefill_chunk_jits[key] = self._build_prefill_chunk(
                sp_specs, state_specs, hi is not None)
        args = (sp, tokens, pa, state, rows, start, valid,
                jnp.asarray(quota, jnp.int32), hi)
        if self.obs.enabled:
            state, logits, lengths = self._observe_step(
                "prefill_chunk", self._prefill_chunk_jits[key], args)
        else:
            state, logits, lengths = self._prefill_chunk_jits[key](*args)
        if pad:
            state = _slice_state_rows(state, B)
            logits, lengths = logits[:B], lengths[..., :B]
        return state, logits, lengths

    # ---- decode ------------------------------------------------------------

    def _build_decode(self, sp_specs, state_specs):
        cfg, ccfg, impl = self.cfg, self.ccfg, self.paged_impl
        ec = self.exec_cfg
        kinds = self.kv_kinds

        def inner(sp, state, pa, tokens, active, rows):
            self.decode_traces += 1  # runs at trace time only
            return _serve.decode_step(sp, state, cfg, pa, ccfg,
                                      tokens=tokens, active=active, rows=rows,
                                      model_axis=ec.model_axis,
                                      data_axis=ec.data_axis,
                                      paged_impl=impl, kv_kinds=kinds)

        d = ec.data_axis
        # the static replication checker stays on for XLA-only decode; a
        # Pallas kernel in the trace (TPU, impl="pallas", or forced
        # interpret) has no replication rule, so the check is dropped there
        # (semantics unchanged — ops.pallas_in_decode)
        from repro.kernels.ops import pallas_in_decode
        fn = shard_map(
            inner, mesh=self.mesh,
            in_specs=(sp_specs, state_specs, self._pa_specs(), P(d), P(d),
                      P(d)),
            out_specs=(state_specs, P(d)),
            check_rep=not pallas_in_decode(self.paged_impl))
        donate = (1,) if ec.donate_state else ()
        return jax.jit(fn, donate_argnums=donate)

    def _decode_jit_for(self, sp, state):
        self._check_quant(sp)
        sp_specs = self._sp_specs(sp)
        state_specs = self._state_specs(state)
        key = (type(state.cache).__name__, jax.tree.structure(sp_specs))
        if key not in self._decode_jits:
            self._decode_jits[key] = self._build_decode(sp_specs, state_specs)
        return self._decode_jits[key]

    def decode(self, sp, state, pa, tokens, active=None, rows=None):
        self._check_grid(pa)
        tokens, active, rows = self._norm_decode_args(tokens, active, rows)
        B = int(tokens.shape[0])
        if B % self.data_size:
            raise ValueError(
                f"decode batch {B} does not split over data="
                f"{self.data_size}; size the batch (scheduler max_rows / "
                f"generate batch) as a multiple of the data-axis width")
        jit = self._decode_jit_for(sp, state)
        args = (sp, state, pa, tokens, active, rows)
        if not self.obs.enabled:
            return jit(*args)
        return self._observe_step("decode", jit, args)

    # ---- speculative propose / verify (DESIGN.md §16) ----------------------

    def _build_propose(self, sp_specs, state_specs, draft_layers, max_k):
        cfg, ccfg, impl = self.cfg, self.ccfg, self.paged_impl
        ec = self.exec_cfg
        kinds = self.kv_kinds

        def inner(sp, state, pa, depths, active, rows):
            self.propose_traces += 1  # runs at trace time only
            return _serve.propose_step(sp, state, cfg, pa, ccfg, depths,
                                       active=active, rows=rows,
                                       model_axis=ec.model_axis,
                                       data_axis=ec.data_axis,
                                       paged_impl=impl, kv_kinds=kinds,
                                       draft_layers=draft_layers, max_k=max_k)

        d = ec.data_axis
        from repro.kernels.ops import pallas_in_decode
        fn = shard_map(
            inner, mesh=self.mesh,
            in_specs=(sp_specs, state_specs, self._pa_specs(), P(d), P(d),
                      P(d)),
            out_specs=(state_specs, P(d, None)),
            check_rep=not pallas_in_decode(self.paged_impl))
        donate = (1,) if ec.donate_state else ()
        return jax.jit(fn, donate_argnums=donate)

    def _build_verify(self, sp_specs, state_specs, draft_layers):
        cfg, ccfg, impl = self.cfg, self.ccfg, self.paged_impl
        ec = self.exec_cfg
        kinds = self.kv_kinds

        def inner(sp, state, pa, tokens, q_lens, active, rows):
            self.verify_traces += 1  # runs at trace time only
            return _serve.verify_step(sp, state, cfg, pa, ccfg, tokens,
                                      q_lens, active=active, rows=rows,
                                      model_axis=ec.model_axis,
                                      data_axis=ec.data_axis,
                                      paged_impl=impl, kv_kinds=kinds,
                                      draft_layers=draft_layers)

        d = ec.data_axis
        from repro.kernels.ops import pallas_in_decode
        fn = shard_map(
            inner, mesh=self.mesh,
            in_specs=(sp_specs, state_specs, self._pa_specs(), P(d, None),
                      P(d), P(d), P(d)),
            out_specs=(state_specs, P(d, None), P(d), P(d, None, None)),
            check_rep=not pallas_in_decode(self.paged_impl))
        donate = (1,) if ec.donate_state else ()
        return jax.jit(fn, donate_argnums=donate)

    def _check_spec_batch(self, B):
        if B % self.data_size:
            raise ValueError(
                f"speculative batch {B} does not split over data="
                f"{self.data_size}; size the batch as a multiple of the "
                f"data-axis width")

    def propose(self, sp, state, pa, depths, active=None, rows=None, *,
                draft_layers, max_k):
        self._check_grid(pa)
        _, active, rows = self._norm_decode_args(state.last_tokens, active,
                                                 rows)
        B = int(active.shape[0])
        self._check_spec_batch(B)
        self._check_quant(sp)
        sp_specs = self._sp_specs(sp)
        key = (type(state.cache).__name__, jax.tree.structure(sp_specs),
               draft_layers, max_k)
        if key not in self._propose_jits:
            self._propose_jits[key] = self._build_propose(
                sp_specs, self._state_specs(state), draft_layers, max_k)
        args = (sp, state, pa, jnp.asarray(depths, jnp.int32), active, rows)
        if not self.obs.enabled:
            return self._propose_jits[key](*args)
        return self._observe_step("propose", self._propose_jits[key], args)

    def verify(self, sp, state, pa, tokens, q_lens, active=None, rows=None, *,
               draft_layers):
        self._check_grid(pa)
        tokens = jnp.asarray(tokens, jnp.int32)
        _, active, rows = self._norm_decode_args(tokens[:, 0], active, rows)
        B = int(tokens.shape[0])
        self._check_spec_batch(B)
        self._check_quant(sp)
        sp_specs = self._sp_specs(sp)
        key = (type(state.cache).__name__, jax.tree.structure(sp_specs),
               draft_layers)
        if key not in self._verify_jits:
            self._verify_jits[key] = self._build_verify(
                sp_specs, self._state_specs(state), draft_layers)
        args = (sp, state, pa, tokens, jnp.asarray(q_lens, jnp.int32),
                active, rows)
        if not self.obs.enabled:
            return self._verify_jits[key](*args)
        return self._observe_step("verify", self._verify_jits[key], args)

    def shard_state(self, state):
        from jax.sharding import NamedSharding
        specs = self._state_specs(state)
        return jax.tree.map(
            lambda x, s: jax.device_put(x, NamedSharding(self.mesh, s)),
            state, specs)

    def decode_hlo(self, sp, state, pa, tokens):
        tokens, active, rows = self._norm_decode_args(tokens, None, None)
        lowered = self._decode_jit_for(sp, state).lower(
            sp, state, pa, tokens, active, rows)
        return lowered.compile().as_text()


def _pad_state_rows(state, pad: int):
    """Widen a slot-layout sub-state by ``pad`` batch rows (repeat the last
    row's content) so it splits over the data axis; inverse of
    `_slice_state_rows`."""
    c = state.cache

    def rep(x, axis):
        last = jnp.take(x, jnp.asarray([x.shape[axis] - 1]), axis=axis)
        return jnp.concatenate([x, jnp.repeat(last, pad, axis=axis)],
                               axis=axis)

    cache = None if c is None else SlotCache(
        k=rep(c.k, 2), v=rep(c.v, 2), lengths=rep(c.lengths, 2),
        pos=rep(c.pos, 2), positions=rep(c.positions, 0))
    return _serve.ServeState(
        cache=cache, ssm_state=None, conv_state=None, cross_k=None,
        cross_v=None, last_tokens=rep(state.last_tokens, 0),
        decode_steps=state.decode_steps)


def _slice_state_rows(state, n: int):
    """Drop padded batch rows from a prefill result (slot layout)."""
    c = state.cache
    cache = None if c is None else SlotCache(
        k=c.k[:, :, :n], v=c.v[:, :, :n], lengths=c.lengths[:, :, :n],
        pos=c.pos[:, :, :n], positions=c.positions[:n])
    return _serve.ServeState(
        cache=cache, ssm_state=None, conv_state=None, cross_k=None,
        cross_v=None, last_tokens=state.last_tokens[:n],
        decode_steps=state.decode_steps)
