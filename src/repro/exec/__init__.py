"""`repro.exec` — device-execution strategies (DESIGN.md §10).

`Executor` owns the compiled prefill/decode StepFns; built-ins ``local``
(single-device jit) and ``mesh`` (``shard_map`` over a (data, model) mesh)
register via ``@repro.api.register_executor`` and are selected through
``EngineConfig.executor``.
"""
from repro.exec.base import Executor, ExecutorConfig, make_executor  # noqa: F401
