"""`Executor`: the device-execution strategy behind the serving stack.

An executor owns the *compiled step functions* (StepFns) of the serving hot
path — one prefill step and one decode step — and nothing else: what to
compute (prefill/compression/decode math) lives in ``repro.serving.engine``;
where and how it runs (which devices, which sharding, which donation) lives
here (DESIGN.md §10).  Two built-ins register with
``@repro.api.register_executor``:

- ``"local"`` — single-device ``jax.jit`` (the PR-1..3 baseline path).
- ``"mesh"``  — ``shard_map`` over a ``(data, model)`` mesh: slot-dim
  weights and both cache backends shard over ``model``, batch rows over
  ``data``; the o-projection contraction over slots is the step's one
  collective (a psum that reassembles the full batch).

StepFn contract (the no-retrace rule): the jitted callables close over the
*static* configuration only (`ModelConfig`, `CompressionConfig`, mesh/axis
names).  Everything a replan changes — slot-layout weights and plan arrays —
is a **traced argument**, so swapping placements re-executes the same
executable; as long as the slot grid and capacity are shape-stable the
decode StepFn compiles exactly once per (batch shape, cache backend).
``tokens``/``active``/``rows`` are always materialized arrays (never None
inside the trace) so one decode trace serves one-shot generation, teacher
forcing, and continuous batching alike.  The decode ``state`` argument is
donated by default (``ExecutorConfig.donate_state``) so the cache updates
in place across the hot loop.

StepFns come in the named kinds of the ``STEP_KINDS`` table — prefill,
prefill_chunk, decode, propose, verify (the last two are the speculative-
decoding pair, DESIGN.md §16).  ``step_traces[kind]`` counts actual
(re)traces per kind — the regression observable for "replans must not
recompile" — and the ``stepfn_compiles_total{kind=}`` metric keys off the
same table; the legacy ``decode_traces`` / ``prefill_traces`` /
``prefill_chunk_traces`` attributes remain as views into it.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.registry import get_executor
from repro.compression.base import CompressionConfig
from repro.configs.base import ModelConfig
from repro.obs import NULL_OBS

# the StepFn kind table: every compiled step an executor owns is one of
# these, and everything keyed per-kind — trace counters, the
# `stepfn_compiles_total{kind=}` / `stepfn_wall_s{kind=}` metrics, trace
# spans — derives from this tuple rather than hand-written attribute pairs.
STEP_KINDS = ("prefill", "prefill_chunk", "decode", "propose", "verify")


@dataclass(frozen=True)
class ExecutorConfig:
    """Execution-level knobs (validated by `EngineConfig`).

    ``donate_state``: donate the decode StepFn's state argument (the cache
    buffers are rewritten in place; keep True unless debugging aliasing).
    ``data_axis`` / ``model_axis``: mesh axis names the ``mesh`` executor
    binds batch rows / the slot dim to.
    """

    donate_state: bool = True
    data_axis: str = "data"
    model_axis: str = "model"

    def __post_init__(self):
        if not self.data_axis or not self.model_axis:
            raise ValueError("data_axis and model_axis must be non-empty")
        if self.data_axis == self.model_axis:
            raise ValueError(
                f"data_axis and model_axis must differ, both are "
                f"{self.data_axis!r}")


class Executor:
    """Interface; see the module docstring for the StepFn contract.

    ``paging`` (a `PagingConfig`, optional) carries the *static* paged
    decode knobs the StepFns close over — today ``decode_impl``, the paged
    decode-attention implementation (DESIGN.md §11).  Like the model and
    compression configs it is trace-static: changing it means a new
    executor, never a silent retrace.
    """

    name: str = "?"

    def __init__(self, model_cfg: ModelConfig, ccfg: CompressionConfig,
                 exec_cfg: Optional[ExecutorConfig] = None, mesh=None,
                 paging=None, obs=None):
        self.cfg = model_cfg
        self.ccfg = ccfg
        self.exec_cfg = exec_cfg or ExecutorConfig()
        self.mesh = mesh
        self.paging = paging
        self.paged_impl = "auto" if paging is None else paging.decode_impl
        # static per-(layer, head) KV storage-kind grid (DESIGN.md §15):
        # resolved once from the paging config, closed over by the decode
        # StepFns, and indexed by the *traced* plan's slot_head in-trace —
        # so a replan that moves heads across slots changes dequant kinds
        # without retracing.  None on the fp32 path.
        if paging is not None and getattr(paging, "kv_dtype", "fp32") != "fp32":
            from repro.paging import kvquant
            spec = kvquant.spec_from_paging(paging)
            self.kv_kinds = kvquant.kind_grid(
                spec, model_cfg.n_layers, model_cfg.n_kv_heads)
        else:
            self.kv_kinds = None
        # observability handle (DESIGN.md §12): StepFn wall-time histograms
        # + compile instant events; NULL_OBS (no-op) unless the Engine
        # facade threads its live Obs through
        self.obs = obs if obs is not None else NULL_OBS
        # actual (re)trace counts per StepFn kind, incremented from inside
        # the traced fns — the no-retrace regression observable (a replan
        # must not bump them).  One entry per STEP_KINDS row.
        self.step_traces = {k: 0 for k in STEP_KINDS}

    # legacy per-kind trace attributes — views into the STEP_KINDS table
    # (kept so existing zero-recompile assertions read unchanged)

    @property
    def prefill_traces(self) -> int:
        return self.step_traces["prefill"]

    @prefill_traces.setter
    def prefill_traces(self, v: int) -> None:
        self.step_traces["prefill"] = v

    @property
    def prefill_chunk_traces(self) -> int:
        return self.step_traces["prefill_chunk"]

    @prefill_chunk_traces.setter
    def prefill_chunk_traces(self, v: int) -> None:
        self.step_traces["prefill_chunk"] = v

    @property
    def decode_traces(self) -> int:
        return self.step_traces["decode"]

    @decode_traces.setter
    def decode_traces(self, v: int) -> None:
        self.step_traces["decode"] = v

    @property
    def propose_traces(self) -> int:
        return self.step_traces["propose"]

    @propose_traces.setter
    def propose_traces(self, v: int) -> None:
        self.step_traces["propose"] = v

    @property
    def verify_traces(self) -> int:
        return self.step_traces["verify"]

    @verify_traces.setter
    def verify_traces(self, v: int) -> None:
        self.step_traces["verify"] = v

    # ---- geometry ----------------------------------------------------------

    @property
    def pool_partitions(self) -> int:
        """Model-axis partitions the paged block pool must be split into
        (1 = single flat pool; the mesh executor returns its model size)."""
        return 1

    @property
    def row_partitions(self) -> int:
        """Data-axis partitions of the paged pool / batch rows (1 = no
        batch sharding; the mesh executor returns its data size)."""
        return 1

    def shard_state(self, state):
        """Lay a freshly initialized ServeState out for this executor.

        The continuous scheduler's empty state is created by the cache
        backend with no layout information; the mesh executor places it
        under its decode in_specs here so the cache is sharded before the
        first step instead of living replicated on one device until the
        first call reshards it.  Identity on single-device executors."""
        return state

    # ---- StepFns -----------------------------------------------------------

    def prefill(self, sp: dict, batch: dict, pa,
                rows: Optional[jnp.ndarray] = None,
                head_importance: Optional[np.ndarray] = None) -> Tuple:
        """Compiled prefill step → (ServeState, logits (B, V),
        lengths (L, Hkv, B)).  ``rows`` are the global batch-row ids the
        strided owner rule is evaluated at (default arange(B))."""
        raise NotImplementedError

    def prefill_chunk(self, sp: dict, tokens: jnp.ndarray, pa, state,
                      rows: jnp.ndarray, start, valid, quota,
                      head_importance: Optional[np.ndarray] = None) -> Tuple:
        """Compiled chunked-prefill step (DESIGN.md §14) → (ServeState,
        logits (B, V), lengths (L, Hkv, B)).

        ``tokens`` is a fixed-width (B, chunk_tokens) slice (last chunk
        zero-padded, ``valid`` (B,) counts real tokens), ``start`` (B,) the
        absolute position of each row's chunk, and ``quota`` (L,) the
        per-head keep cap the boundary compression is clamped to.  All are
        traced arguments, so one trace serves every chunk of every prompt."""
        raise NotImplementedError

    def decode(self, sp: dict, state, pa, tokens: jnp.ndarray,
               active: Optional[jnp.ndarray] = None,
               rows: Optional[jnp.ndarray] = None) -> Tuple:
        """Compiled decode step → (ServeState, logits (B, V)).

        ``active``/``rows`` default to all-active / arange(B); they are
        materialized before the call so every mode shares one trace."""
        raise NotImplementedError

    def propose(self, sp: dict, state, pa, depths: jnp.ndarray,
                active: Optional[jnp.ndarray] = None,
                rows: Optional[jnp.ndarray] = None, *,
                draft_layers: int, max_k: int) -> Tuple:
        """Compiled speculative propose step (DESIGN.md §16) →
        (ServeState, proposals (B, max_k)).

        ``depths`` ((B,) int32) is the per-row speculation depth — a traced
        argument, so adaptive depth changes reuse the compiled step;
        ``draft_layers``/``max_k`` are static (one trace per pair)."""
        raise NotImplementedError

    def verify(self, sp: dict, state, pa, tokens: jnp.ndarray,
               q_lens: jnp.ndarray, active: Optional[jnp.ndarray] = None,
               rows: Optional[jnp.ndarray] = None, *,
               draft_layers: int) -> Tuple:
        """Compiled speculative verify step (DESIGN.md §16) →
        (ServeState, g (B, Q), n_commit (B,), logits (B, Q, V)).

        ``tokens`` is the fixed-width (B, max_k + 1) window [t0, p1..pk]
        (one trace per width), ``q_lens`` ((B,) int32) the per-row valid
        window — traced, so depth changes never recompile."""
        raise NotImplementedError

    # ---- observability -----------------------------------------------------

    def _observe_step(self, kind: str, fn, args) -> Tuple:
        """Run one jitted StepFn call under observation (DESIGN.md §12).

        Records a wall-time histogram sample and a trace span per call, and
        a compile instant event + counter whenever the call actually
        (re)traced — turning the §10 zero-recompile invariant into an
        asserted metric (``stepfn_compiles_total{kind="decode"}`` must stay
        at its warm value).  Blocks on the result so the sample is real
        device time, not dispatch time; the host consumes the result
        synchronously right after in every caller, so no pipelining is
        lost.  Collection is host-side only — nothing here runs inside the
        trace.  Callers skip this entirely when obs is disabled.
        """
        if kind not in STEP_KINDS:
            raise ValueError(
                f"unknown StepFn kind {kind!r}; known: {list(STEP_KINDS)}")
        before = self.step_traces[kind]
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        obs = self.obs
        m = obs.metrics
        obs.trace.complete(f"stepfn_{kind}", t0, dt, executor=self.name)
        if self.step_traces[kind] > before:
            m.counter(
                "stepfn_compiles_total",
                help="StepFn (re)traces; decode must stay at one per "
                     "(shape, backend) across replans (DESIGN.md §10)",
            ).inc(kind=kind, executor=self.name)
            obs.trace.instant(f"stepfn_{kind}_compile", executor=self.name)
        m.histogram(
            "stepfn_wall_s",
            help="StepFn wall time per invocation, seconds (blocked on "
                 "device completion)",
        ).observe(dt, kind=kind, executor=self.name)
        return out

    # ---- shared normalization ---------------------------------------------

    def _norm_decode_args(self, tokens, active, rows):
        if isinstance(tokens, jax.ShapeDtypeStruct):
            # abstract lowering (dry-run audit): no values to materialize
            B = tokens.shape[0]
            return (tokens, jax.ShapeDtypeStruct((B,), jnp.bool_),
                    jax.ShapeDtypeStruct((B,), jnp.int32))
        tokens = jnp.asarray(tokens, jnp.int32)
        B = tokens.shape[0]
        if active is None:
            active = jnp.ones((B,), jnp.bool_)
        if rows is None:
            rows = jnp.arange(B, dtype=jnp.int32)
        return tokens, jnp.asarray(active), jnp.asarray(rows, jnp.int32)

    # ---- audit -------------------------------------------------------------

    def decode_hlo(self, sp: dict, state, pa, tokens: jnp.ndarray) -> str:
        """Compiled (post-SPMD) HLO of the decode StepFn for the given
        arguments — feed to ``repro.distributed.hlo_stats`` for the
        collective audit.  Lowering traces, so call it outside any
        trace-count assertion window."""
        raise NotImplementedError


def make_executor(name: str, model_cfg: ModelConfig, ccfg: CompressionConfig,
                  exec_cfg: Optional[ExecutorConfig] = None,
                  mesh=None, paging=None, obs=None) -> Executor:
    """Instantiate a registered executor by name."""
    return get_executor(name)(model_cfg, ccfg, exec_cfg=exec_cfg, mesh=mesh,
                              paging=paging, obs=obs)
