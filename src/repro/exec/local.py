"""`LocalExecutor`: single-device jit StepFns (the default path).

Owns exactly the two jitted callables the serving stack used to scatter
across `api.engine.Engine._decode_fn` and `serving.scheduler._make_decode`.
Weights (``sp``) and plan arrays (``pa``) are traced *arguments*, so a
replan swaps placements by passing different values through the same
executable — no retrace (DESIGN.md §10).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.api.registry import register_executor
from repro.exec.base import Executor
from repro.serving import engine as _serve


@register_executor("local")
class LocalExecutor(Executor):
    name = "local"

    def __init__(self, model_cfg, ccfg, exec_cfg=None, mesh=None,
                 paging=None, obs=None):
        if mesh is not None:
            raise ValueError(
                "the 'local' executor runs on a single device and ignores "
                "meshes; pass executor='mesh' to run on one, or drop mesh=")
        super().__init__(model_cfg, ccfg, exec_cfg=exec_cfg, mesh=None,
                         paging=paging, obs=obs)
        self._prefill_jit = None
        self._prefill_chunk_jit = None
        self._decode_jit = None
        # speculative StepFns memoized per static (draft_layers, max_k) —
        # per-row depths are traced, so adaptive depth reuses these
        self._propose_jits = {}
        self._verify_jits = {}

    # ---- StepFn construction ----------------------------------------------

    def _build_prefill(self):
        cfg, ccfg = self.cfg, self.ccfg

        def fn(sp, batch, pa, rows, head_importance):
            self.prefill_traces += 1  # runs at trace time only
            return _serve.prefill(sp, batch, cfg, pa, ccfg,
                                  head_importance=head_importance, rows=rows)

        return jax.jit(fn)

    def _build_prefill_chunk(self):
        cfg, ccfg = self.cfg, self.ccfg

        def fn(sp, tokens, pa, state, rows, start, valid, quota,
               head_importance):
            self.prefill_chunk_traces += 1  # runs at trace time only
            return _serve.prefill_chunk(sp, tokens, cfg, pa, ccfg, state,
                                        rows, start, valid, quota,
                                        head_importance=head_importance)

        donate = (3,) if self.exec_cfg.donate_state else ()
        return jax.jit(fn, donate_argnums=donate)

    def _build_decode(self):
        cfg, ccfg, impl = self.cfg, self.ccfg, self.paged_impl
        kinds = self.kv_kinds

        def fn(sp, state, pa, tokens, active, rows):
            self.decode_traces += 1  # runs at trace time only
            return _serve.decode_step(sp, state, cfg, pa, ccfg,
                                      tokens=tokens, active=active, rows=rows,
                                      paged_impl=impl, kv_kinds=kinds)

        donate = (1,) if self.exec_cfg.donate_state else ()
        return jax.jit(fn, donate_argnums=donate)

    def _build_propose(self, draft_layers, max_k):
        cfg, ccfg, impl = self.cfg, self.ccfg, self.paged_impl
        kinds = self.kv_kinds

        def fn(sp, state, pa, depths, active, rows):
            self.propose_traces += 1  # runs at trace time only
            return _serve.propose_step(sp, state, cfg, pa, ccfg, depths,
                                       active=active, rows=rows,
                                       paged_impl=impl, kv_kinds=kinds,
                                       draft_layers=draft_layers, max_k=max_k)

        donate = (1,) if self.exec_cfg.donate_state else ()
        return jax.jit(fn, donate_argnums=donate)

    def _build_verify(self, draft_layers):
        cfg, ccfg, impl = self.cfg, self.ccfg, self.paged_impl
        kinds = self.kv_kinds

        def fn(sp, state, pa, tokens, q_lens, active, rows):
            self.verify_traces += 1  # runs at trace time only
            return _serve.verify_step(sp, state, cfg, pa, ccfg, tokens,
                                      q_lens, active=active, rows=rows,
                                      paged_impl=impl, kv_kinds=kinds,
                                      draft_layers=draft_layers)

        donate = (1,) if self.exec_cfg.donate_state else ()
        return jax.jit(fn, donate_argnums=donate)

    # ---- entry points ------------------------------------------------------

    def prefill(self, sp, batch, pa, rows=None, head_importance=None):
        if self._prefill_jit is None:
            self._prefill_jit = self._build_prefill()
        B = batch["tokens"].shape[0]
        if rows is None:
            rows = jnp.arange(B, dtype=jnp.int32)
        hi = None if head_importance is None else jnp.asarray(head_importance)
        args = (sp, batch, pa, jnp.asarray(rows, jnp.int32), hi)
        if not self.obs.enabled:
            return self._prefill_jit(*args)
        return self._observe_step("prefill", self._prefill_jit, args)

    def prefill_chunk(self, sp, tokens, pa, state, rows, start, valid, quota,
                      head_importance=None):
        if self._prefill_chunk_jit is None:
            self._prefill_chunk_jit = self._build_prefill_chunk()
        hi = None if head_importance is None else jnp.asarray(head_importance)
        args = (sp, jnp.asarray(tokens, jnp.int32), pa, state,
                jnp.asarray(rows, jnp.int32), jnp.asarray(start, jnp.int32),
                jnp.asarray(valid, jnp.int32), jnp.asarray(quota, jnp.int32),
                hi)
        if not self.obs.enabled:
            return self._prefill_chunk_jit(*args)
        return self._observe_step("prefill_chunk", self._prefill_chunk_jit,
                                  args)

    def decode(self, sp, state, pa, tokens, active=None, rows=None):
        if self._decode_jit is None:
            self._decode_jit = self._build_decode()
        tokens, active, rows = self._norm_decode_args(tokens, active, rows)
        args = (sp, state, pa, tokens, active, rows)
        if not self.obs.enabled:
            return self._decode_jit(*args)
        return self._observe_step("decode", self._decode_jit, args)

    def propose(self, sp, state, pa, depths, active=None, rows=None, *,
                draft_layers, max_k):
        key = (draft_layers, max_k)
        if key not in self._propose_jits:
            self._propose_jits[key] = self._build_propose(draft_layers, max_k)
        _, active, rows = self._norm_decode_args(state.last_tokens, active,
                                                 rows)
        args = (sp, state, pa, jnp.asarray(depths, jnp.int32), active, rows)
        if not self.obs.enabled:
            return self._propose_jits[key](*args)
        return self._observe_step("propose", self._propose_jits[key], args)

    def verify(self, sp, state, pa, tokens, q_lens, active=None, rows=None, *,
               draft_layers):
        if draft_layers not in self._verify_jits:
            self._verify_jits[draft_layers] = self._build_verify(draft_layers)
        tokens = jnp.asarray(tokens, jnp.int32)
        _, active, rows = self._norm_decode_args(tokens[:, 0], active, rows)
        args = (sp, state, pa, tokens, jnp.asarray(q_lens, jnp.int32),
                active, rows)
        if not self.obs.enabled:
            return self._verify_jits[draft_layers](*args)
        return self._observe_step("verify", self._verify_jits[draft_layers],
                                  args)

    def decode_hlo(self, sp, state, pa, tokens):
        if self._decode_jit is None:
            self._decode_jit = self._build_decode()
        tokens, active, rows = self._norm_decode_args(tokens, None, None)
        lowered = self._decode_jit.lower(sp, state, pa, tokens, active, rows)
        return lowered.compile().as_text()
