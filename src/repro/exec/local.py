"""`LocalExecutor`: single-device jit StepFns (the default path).

Owns exactly the two jitted callables the serving stack used to scatter
across `api.engine.Engine._decode_fn` and `serving.scheduler._make_decode`.
Weights (``sp``) and plan arrays (``pa``) are traced *arguments*, so a
replan swaps placements by passing different values through the same
executable — no retrace (DESIGN.md §10).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.api.registry import register_executor
from repro.exec.base import Executor
from repro.serving import engine as _serve


@register_executor("local")
class LocalExecutor(Executor):
    name = "local"

    def __init__(self, model_cfg, ccfg, exec_cfg=None, mesh=None,
                 paging=None, obs=None):
        if mesh is not None:
            raise ValueError(
                "the 'local' executor runs on a single device and ignores "
                "meshes; pass executor='mesh' to run on one, or drop mesh=")
        super().__init__(model_cfg, ccfg, exec_cfg=exec_cfg, mesh=None,
                         paging=paging, obs=obs)
        self._prefill_jit = None
        self._prefill_chunk_jit = None
        self._decode_jit = None

    # ---- StepFn construction ----------------------------------------------

    def _build_prefill(self):
        cfg, ccfg = self.cfg, self.ccfg

        def fn(sp, batch, pa, rows, head_importance):
            self.prefill_traces += 1  # runs at trace time only
            return _serve.prefill(sp, batch, cfg, pa, ccfg,
                                  head_importance=head_importance, rows=rows)

        return jax.jit(fn)

    def _build_prefill_chunk(self):
        cfg, ccfg = self.cfg, self.ccfg

        def fn(sp, tokens, pa, state, rows, start, valid, quota,
               head_importance):
            self.prefill_chunk_traces += 1  # runs at trace time only
            return _serve.prefill_chunk(sp, tokens, cfg, pa, ccfg, state,
                                        rows, start, valid, quota,
                                        head_importance=head_importance)

        donate = (3,) if self.exec_cfg.donate_state else ()
        return jax.jit(fn, donate_argnums=donate)

    def _build_decode(self):
        cfg, ccfg, impl = self.cfg, self.ccfg, self.paged_impl
        kinds = self.kv_kinds

        def fn(sp, state, pa, tokens, active, rows):
            self.decode_traces += 1  # runs at trace time only
            return _serve.decode_step(sp, state, cfg, pa, ccfg,
                                      tokens=tokens, active=active, rows=rows,
                                      paged_impl=impl, kv_kinds=kinds)

        donate = (1,) if self.exec_cfg.donate_state else ()
        return jax.jit(fn, donate_argnums=donate)

    # ---- entry points ------------------------------------------------------

    def prefill(self, sp, batch, pa, rows=None, head_importance=None):
        if self._prefill_jit is None:
            self._prefill_jit = self._build_prefill()
        B = batch["tokens"].shape[0]
        if rows is None:
            rows = jnp.arange(B, dtype=jnp.int32)
        hi = None if head_importance is None else jnp.asarray(head_importance)
        args = (sp, batch, pa, jnp.asarray(rows, jnp.int32), hi)
        if not self.obs.enabled:
            return self._prefill_jit(*args)
        return self._observe_step("prefill", self._prefill_jit, args)

    def prefill_chunk(self, sp, tokens, pa, state, rows, start, valid, quota,
                      head_importance=None):
        if self._prefill_chunk_jit is None:
            self._prefill_chunk_jit = self._build_prefill_chunk()
        hi = None if head_importance is None else jnp.asarray(head_importance)
        args = (sp, jnp.asarray(tokens, jnp.int32), pa, state,
                jnp.asarray(rows, jnp.int32), jnp.asarray(start, jnp.int32),
                jnp.asarray(valid, jnp.int32), jnp.asarray(quota, jnp.int32),
                hi)
        if not self.obs.enabled:
            return self._prefill_chunk_jit(*args)
        return self._observe_step("prefill_chunk", self._prefill_chunk_jit,
                                  args)

    def decode(self, sp, state, pa, tokens, active=None, rows=None):
        if self._decode_jit is None:
            self._decode_jit = self._build_decode()
        tokens, active, rows = self._norm_decode_args(tokens, active, rows)
        args = (sp, state, pa, tokens, active, rows)
        if not self.obs.enabled:
            return self._decode_jit(*args)
        return self._observe_step("decode", self._decode_jit, args)

    def decode_hlo(self, sp, state, pa, tokens):
        if self._decode_jit is None:
            self._decode_jit = self._build_decode()
        tokens, active, rows = self._norm_decode_args(tokens, None, None)
        lowered = self._decode_jit.lower(sp, state, pa, tokens, active, rows)
        return lowered.compile().as_text()
