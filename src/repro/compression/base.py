"""KV-cache compression policy interface.

A policy looks at per-position importance scores gathered during prefill and
decides, per (batch row, kv head), *which* positions to retain and *how many*
(the per-head budget).  Balanced policies give every head the same budget;
imbalanced policies (Ada-SnapKV, HeadKV — the paper's targets) redistribute a
layer-wide pool across heads, which is what creates the unfair head load.

Scores come from the SnapKV observation-window statistic: softmax attention of
the last ``obs_window`` queries onto all positions, summed over the window and
the query group, then 1-D max-pooled (kernel ``pool``) for locality.

All selections are jit-friendly: static top-``capacity`` per head plus a
length mask (``arange < keep``), so every policy lowers to the same shapes.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class CompressionConfig:
    policy: str = "ada_snapkv"
    budget: int = 1024  # mean retained tokens per kv head
    capacity: int = 0  # static per-head cap; 0 -> alpha_max * budget
    alpha_max: float = 2.0  # capacity multiplier for imbalanced policies
    obs_window: int = 32
    pool: int = 7
    sink: int = 4  # always-keep prefix tokens (StreamingLLM sinks)
    decode_margin: int = 64  # extra capacity for decode appends
    # HeadKV: fraction of the pool pre-allocated uniformly ("base budget")
    headkv_base_ratio: float = 0.2
    # PyramidKV: budget decays linearly across layers by +/- this fraction
    pyramid_beta: float = 0.6
    # decode-append implementation: "scatter" (jnp .at[] — the baseline used
    # for the §Dry-run sweep) or "onehot" (elementwise masked write —
    # SPMD-local, avoids XLA's replicated-scatter fallback; 47x collective
    # reduction measured, EXPERIMENTS.md §Perf).  Production default: onehot.
    append_mode: str = "onehot"

    def static_capacity(self) -> int:
        cap = self.capacity or int(round(self.alpha_max * self.budget))
        return cap + self.decode_margin


def pool_scores(scores: jnp.ndarray, pool: int) -> jnp.ndarray:
    """1-D max pool along the last axis (SnapKV's clustering trick)."""
    if pool <= 1:
        return scores
    pad = pool // 2
    padded = jnp.pad(scores, [(0, 0)] * (scores.ndim - 1) + [(pad, pad)],
                     constant_values=-jnp.inf)
    windows = [padded[..., i:i + scores.shape[-1]] for i in range(pool)]
    return jnp.stack(windows, axis=0).max(axis=0)


def observation_scores(
    q_obs: jnp.ndarray,  # (B, W, Hq, Dh) — already RoPE'd
    k: jnp.ndarray,  # (B, T, Hkv, Dh)
    obs_positions: jnp.ndarray,  # (B, W)
    k_positions: jnp.ndarray,  # (B, T)
    pool: int = 7,
    attn_cap: float = 0.0,
) -> jnp.ndarray:
    """(B, Hkv, T) pooled importance of every position."""
    B, W, Hq, Dh = q_obs.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q_obs.reshape(B, W, Hkv, G, Dh).astype(jnp.float32)
    s = jnp.einsum("bwhgd,bthd->bhgwt", qg, k.astype(jnp.float32))
    s = s / jnp.sqrt(jnp.float32(Dh))
    if attn_cap > 0:
        s = attn_cap * jnp.tanh(s / attn_cap)
    causal = k_positions[:, None, :] <= obs_positions[:, :, None]  # (B, W, T)
    s = jnp.where(causal[:, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isfinite(s), p, 0.0)
    imp = p.sum(axis=(2, 3))  # (B, Hkv, T)
    return pool_scores(imp, pool)


def topk_select(
    scores: jnp.ndarray,  # (B, Hkv, T)
    keep: jnp.ndarray,  # (B, Hkv) int32, <= capacity
    capacity: int,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Static top-``capacity`` indices + per-head validity lengths.

    Returned indices are sorted ascending (original temporal order) so RoPE
    positions stay monotone in the cache — convenient for debugging; attention
    itself is order-independent.
    """
    T = scores.shape[-1]
    capacity = min(capacity, T)
    _, idx = jax.lax.top_k(scores, capacity)  # (B, Hkv, C)
    keep = jnp.minimum(keep, capacity).astype(jnp.int32)
    # mask invalid tail with T-1 (harmless position), sort ascending
    valid = jnp.arange(capacity)[None, None, :] < keep[..., None]
    idx = jnp.where(valid, idx, T - 1)
    idx = jnp.sort(idx, axis=-1)
    # after sorting, valid entries are a prefix only if T-1 sorts last — it
    # does (max index), except genuine selections of T-1; lengths stay `keep`.
    return idx.astype(jnp.int32), keep
