"""KV-cache compression policies (balanced + imbalanced per-head)."""
from repro.compression.base import (  # noqa: F401
    CompressionConfig,
    observation_scores,
    pool_scores,
    topk_select,
)
from repro.compression.policies import (  # noqa: F401
    BALANCED,
    IMBALANCED,
    POLICIES,
    ada_snapkv,
    h2o,
    headkv,
    pyramidkv,
    select,
    snapkv,
    streaming_llm,
)
