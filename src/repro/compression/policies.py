"""The six compression policies (4 balanced + 2 imbalanced).

Each policy maps pooled observation scores (B, Hkv, T) → (indices, lengths):
``indices`` (B, Hkv, C) positions retained per head, ``lengths`` (B, Hkv).

Balanced (fair) per-head:
- ``streaming_llm``  sinks + recent window (position-only, no scores)
- ``snapkv``         per-head top-budget by pooled obs scores
- ``pyramidkv``      snapkv with per-layer decaying budgets
- ``h2o``            accumulated-attention heavy hitters + recent window

Imbalanced (unfair) per-head — the paper's targets:
- ``ada_snapkv``     layer-wide pool of Hkv·budget entries, allocated to heads
                     by global score ranking (Ada-KV's safeguarded variant:
                     every head keeps at least ``sink + obs_window``)
- ``headkv``         static per-head importance splits the pool: uniform base
                     ratio + importance-proportional dynamic share
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.api.registry import POLICY_REGISTRY, register_policy
from repro.compression.base import CompressionConfig, topk_select

Selection = Tuple[jnp.ndarray, jnp.ndarray]  # (idx (B,Hkv,C), lengths (B,Hkv))


def _boost_guaranteed(scores: jnp.ndarray, t_len: int, cfg: CompressionConfig,
                      positions: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Force sinks + the observation window into every selection."""
    T = scores.shape[-1]
    pos = jnp.arange(T) if positions is None else positions
    guaranteed = (pos < cfg.sink) | (pos >= t_len - cfg.obs_window)
    return jnp.where(guaranteed, jnp.inf, scores)


def _uniform_budget(scores: jnp.ndarray, budget: int, capacity: int) -> Selection:
    B, Hkv, T = scores.shape
    keep = jnp.full((B, Hkv), min(budget, T, capacity), jnp.int32)
    return topk_select(scores, keep, capacity)


@register_policy("streaming_llm")
def streaming_llm(scores: jnp.ndarray, cfg: CompressionConfig,
                  layer_idx: int, n_layers: int) -> Selection:
    """Sinks + recent window; scores are ignored (balanced, position-only)."""
    B, Hkv, T = scores.shape
    pos = jnp.arange(T, dtype=jnp.float32)
    recent = cfg.budget - cfg.sink
    synthetic = jnp.where(pos < cfg.sink, 2.0, 0.0) + jnp.where(
        pos >= T - recent, 1.0, 0.0)
    synthetic = jnp.broadcast_to(synthetic, (B, Hkv, T))
    cap = cfg.static_capacity()
    keep = jnp.full((B, Hkv), min(cfg.budget, T, cap), jnp.int32)
    return topk_select(synthetic + 1e-6 * pos / T, keep, cap)


@register_policy("snapkv")
def snapkv(scores: jnp.ndarray, cfg: CompressionConfig,
           layer_idx: int, n_layers: int) -> Selection:
    scores = _boost_guaranteed(scores, scores.shape[-1], cfg)
    return _uniform_budget(scores, cfg.budget, cfg.static_capacity())


@register_policy("pyramidkv")
def pyramidkv(scores: jnp.ndarray, cfg: CompressionConfig,
              layer_idx: int, n_layers: int) -> Selection:
    """Budget decays linearly with depth (early layers keep more)."""
    beta = cfg.pyramid_beta
    frac = 1.0 + beta - 2.0 * beta * (layer_idx / max(n_layers - 1, 1))
    budget = max(cfg.sink + cfg.obs_window, int(round(cfg.budget * frac)))
    scores = _boost_guaranteed(scores, scores.shape[-1], cfg)
    return _uniform_budget(scores, budget, cfg.static_capacity())


@register_policy("h2o")
def h2o(scores: jnp.ndarray, cfg: CompressionConfig,
        layer_idx: int, n_layers: int) -> Selection:
    """Heavy hitters: half budget by accumulated score, half recent.

    Our ``scores`` are obs-window accumulated attention — the closest offline
    stand-in for H2O's running accumulation during generation.
    """
    B, Hkv, T = scores.shape
    pos = jnp.arange(T)
    half = cfg.budget // 2
    recent_boost = jnp.where(pos >= T - half, jnp.inf, 0.0)
    scores = scores + recent_boost
    scores = jnp.where(pos < cfg.sink, jnp.inf, scores)
    return _uniform_budget(scores, cfg.budget, cfg.static_capacity())


def _pooled_allocation(scores: jnp.ndarray, pool_size: jnp.ndarray,
                       floor: int, capacity: int) -> jnp.ndarray:
    """Ada-KV allocation: per-row global threshold over (Hkv·T) scores.

    keep[b, h] = #scores of head h among the layer-wide top-``pool_size``,
    safeguarded to at least ``floor`` and clipped to ``capacity``.
    """
    B, Hkv, T = scores.shape
    flat = scores.reshape(B, Hkv * T)
    k = int(pool_size)
    k = min(k, Hkv * T)
    thresh = jax.lax.top_k(flat, k)[0][:, -1]  # (B,)
    keep = (scores >= thresh[:, None, None]).sum(axis=-1)  # (B, Hkv)
    keep = jnp.clip(keep, floor, capacity)
    return keep.astype(jnp.int32)


@register_policy("ada_snapkv")
def ada_snapkv(scores: jnp.ndarray, cfg: CompressionConfig,
               layer_idx: int, n_layers: int) -> Selection:
    B, Hkv, T = scores.shape
    scores = _boost_guaranteed(scores, T, cfg)
    cap = cfg.static_capacity()
    floor = min(cfg.sink + cfg.obs_window, cfg.budget)
    keep = _pooled_allocation(scores, Hkv * cfg.budget, floor, min(cap, T))
    return topk_select(scores, keep, cap)


@register_policy("headkv")
def headkv(scores: jnp.ndarray, cfg: CompressionConfig,
           layer_idx: int, n_layers: int,
           head_importance: Optional[jnp.ndarray] = None) -> Selection:
    """Static base budget + importance-proportional dynamic share.

    ``head_importance`` (Hkv,) — offline per-head weights (from a profile
    sample); defaults to the realized mean obs score per head.
    """
    B, Hkv, T = scores.shape
    pool = Hkv * cfg.budget
    base = int(round(cfg.headkv_base_ratio * cfg.budget))
    if head_importance is None:
        imp = scores.mean(axis=(0, 2))  # (Hkv,)
    else:
        imp = jnp.asarray(head_importance, jnp.float32)
    imp = imp / jnp.maximum(imp.sum(), 1e-9)
    dynamic = (pool - Hkv * base) * imp  # (Hkv,)
    keep = jnp.broadcast_to(base + dynamic, (B, Hkv))
    cap = cfg.static_capacity()
    keep = jnp.clip(keep, min(cfg.sink + cfg.obs_window, cfg.budget),
                    min(cap, T)).astype(jnp.int32)
    scores = _boost_guaranteed(scores, T, cfg)
    return topk_select(scores, keep, cap)


def layer_keep_bound(policy: str, cfg: CompressionConfig, T: int,
                     n_heads: int, layer_idx: int, n_layers: int) -> int:
    """Tight upper bound on Σ_h keep for one layer's prefill selection.

    The scheduler's admission projection used to charge every head the full
    static capacity ``C = α·budget + margin`` — maximally wrong for exactly
    the imbalanced policies FairKV targets, whose whole point is that the
    *pool* is conserved while individual heads vary:

    - balanced policies keep ``min(budget_l, T, C)`` per head exactly;
    - ``ada_snapkv`` counts the layer-wide top-``H·budget`` scores, then the
      per-head safeguard floor (``min(sink+obs, budget)``) can only add
      ``H·floor`` more, and when the guaranteed (sink+obs) positions exceed
      the pool the count degenerates to ``H·(sink+obs)`` — all covered by
      ``H·(budget + sink + obs_window)``;
    - ``headkv`` splits a pool of exactly ``H·budget`` (base + dynamic
      shares sum to it), with the same floor slack.

    Unknown (third-party) policies fall back to the conservative
    ``H·min(T, C)`` — correct, just not tight.  Bounds here are *proven*
    upper bounds on the realized selection, so admission never overcommits
    (asserted by the regression test in tests/test_scheduler.py).
    """
    H = int(n_heads)
    cap = cfg.static_capacity()
    per_head_max = max(0, min(cap, T))
    if policy == "none":
        return H * per_head_max
    if policy in ("snapkv", "streaming_llm", "h2o"):
        return H * min(cfg.budget, per_head_max)
    if policy == "pyramidkv":
        beta = cfg.pyramid_beta
        frac = 1.0 + beta - 2.0 * beta * (layer_idx / max(n_layers - 1, 1))
        budget_l = max(cfg.sink + cfg.obs_window, int(round(cfg.budget * frac)))
        return H * min(budget_l, per_head_max)
    if policy in ("ada_snapkv", "headkv"):
        return H * min(cfg.budget + cfg.sink + cfg.obs_window, per_head_max)
    return H * per_head_max


def projected_request_tokens(policy: str, cfg: CompressionConfig,
                             prompt_len: int, max_new_tokens: int,
                             n_layers: int, n_heads: int) -> int:
    """Upper bound on Σ lengths a request can ever pin across the cache.

    Per layer: the prefill selection bound plus one decode append per head
    per generated token, each head clipped at static capacity (appends stop
    growing ``lengths`` there — the recency ring overwrites in place).
    """
    H, cap = int(n_heads), cfg.static_capacity()
    total = 0
    for l in range(n_layers):
        prefill = layer_keep_bound(policy, cfg, prompt_len, H, l, n_layers)
        total += min(prefill + H * max_new_tokens,
                     H * min(prompt_len + max_new_tokens, cap))
    return total


# Live Mapping view over the registry: third-party ``@register_policy``
# providers appear here automatically (the old hardcoded dict literal is gone).
POLICIES = POLICY_REGISTRY

BALANCED = {"streaming_llm", "snapkv", "pyramidkv", "h2o"}
IMBALANCED = {"ada_snapkv", "headkv"}


def select(policy: str, scores: jnp.ndarray, cfg: CompressionConfig,
           layer_idx: int, n_layers: int, **kw) -> Selection:
    """Dispatch to a registered policy; ``"none"`` retains every position."""
    if policy == "none":
        B, Hkv, T = scores.shape
        idx = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, Hkv, T))
        return idx, jnp.full((B, Hkv), T, jnp.int32)
    return POLICY_REGISTRY[policy](scores, cfg, layer_idx, n_layers, **kw)
