"""Pure-jnp oracles for the Pallas kernels.

These are the semantics-defining references: kernels must match them (fp32
accumulation) across the shape/dtype sweeps in tests/test_kernels.py.  They
are also the production fallback path on CPU and in the XLA-only dry-run.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30

# fp8 availability probe — deliberately duplicated from repro.paging.kvquant:
# oracles stay self-contained (no imports from the subsystems they validate)
_HAS_FP8 = hasattr(jnp, "float8_e4m3fn")


def dequant_block_codes(codes, scale, kind):
    """int8 block codes → fp32 under per-block ``scale`` and kind
    (0 = int8, 1 = fp8-bitcast) — the oracle's own copy of the paged-pool
    dequant semantics (DESIGN.md §15).  fp8 NaN bit patterns (possible in
    never-written pool garbage) flush to 0 so masked positions cannot
    poison the probability-weighted sum through 0·NaN.
    """
    f = codes.astype(jnp.float32)
    if _HAS_FP8:
        f8 = jax.lax.bitcast_convert_type(
            codes, jnp.float8_e4m3fn).astype(jnp.float32)
        f8 = jnp.where(f8 == f8, f8, 0.0)
        f = jnp.where(kind == 1, f8, f)
    return f * scale


def fairkv_decode_ref(
    q: jnp.ndarray,  # (B, S, G, Dh) — one new query per row per slot group
    k: jnp.ndarray,  # (S, B, C, Dh) slot-layout cache keys (post-RoPE)
    v: jnp.ndarray,  # (S, B, C, Dh)
    lengths: jnp.ndarray,  # (S, B) int32 — retained tokens per (slot, row)
    attn_cap: float = 0.0,
    k_pos: Optional[jnp.ndarray] = None,  # (S, B, C) absolute entry positions
    q_pos: Optional[jnp.ndarray] = None,  # (B,) current positions
    window: int = 0,  # >0: sliding-window mask via k_pos/q_pos
) -> jnp.ndarray:
    """Decode attention over the slot-layout cache.

    Rows a slot does not own have ``lengths == 0`` and yield exactly 0 output
    (their o-projection contribution vanishes, so the cross-shard psum
    reassembles the batch — DESIGN.md §2).
    Returns (B, S, G, Dh).
    """
    B, S, G, Dh = q.shape
    C = k.shape[2]
    scores = jnp.einsum("bsgd,sbcd->bsgc", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(Dh)
    if attn_cap > 0:
        scores = attn_cap * jnp.tanh(scores / attn_cap)
    valid = jnp.arange(C)[None, None, :] < lengths.transpose(1, 0)[..., None]
    if window > 0:
        assert k_pos is not None and q_pos is not None
        in_win = k_pos.transpose(1, 0, 2) > (q_pos[:, None, None] - window)
        valid &= in_win
    scores = jnp.where(valid[:, :, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    nonempty = valid.any(axis=-1)[:, :, None, None]
    probs = jnp.where(nonempty, probs, 0.0)
    out = jnp.einsum("bsgc,sbcd->bsgd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def fairkv_decode_mq_ref(
    q: jnp.ndarray,  # (B, S, Q, G, Dh) — Q query positions per row per slot
    k: jnp.ndarray,  # (S, B, C, Dh) slot-layout cache keys (post-RoPE)
    v: jnp.ndarray,  # (S, B, C, Dh)
    lengths: jnp.ndarray,  # (S, B) int32 — retained tokens AFTER the appends
    attn_cap: float = 0.0,
    k_pos: Optional[jnp.ndarray] = None,  # (S, B, C) absolute entry positions
    q_pos: Optional[jnp.ndarray] = None,  # (B,) position of query index 0
    q_lens: Optional[jnp.ndarray] = None,  # (B,) valid queries per row (<= Q)
    window: int = 0,
) -> jnp.ndarray:
    """Multi-query decode attention — the speculative-verify oracle.

    Query index ``i`` of row ``b`` sits at absolute position ``q_pos[b]+i``
    and attends causally *within the speculative window*: with
    ``qn = q_lens[b]`` valid queries and ``lengths`` counting the cache
    after all ``qn`` appends, query ``i`` sees the first
    ``lengths - (qn - 1 - i)`` entries (its own token included, later
    speculative tokens excluded).  Query indices at or past ``qn`` are
    garbage lanes (the scheduler masks them downstream); they are clamped
    to the full length so they still compute finite values.  With Q == 1
    and ``q_lens == 1`` this is exactly `fairkv_decode_ref`.
    Returns (B, S, Q, G, Dh).
    """
    B, S, Q, G, Dh = q.shape
    C = k.shape[2]
    if q_lens is None:
        q_lens = jnp.full((B,), Q, jnp.int32)
    scores = jnp.einsum("bsqgd,sbcd->bsqgc", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(Dh)
    if attn_cap > 0:
        scores = attn_cap * jnp.tanh(scores / attn_cap)
    ln = lengths.transpose(1, 0)  # (B, S)
    qi = jnp.arange(Q)[None, None, :]  # (1, 1, Q)
    limit = ln[:, :, None] - (q_lens[:, None, None] - 1 - qi)
    limit = jnp.minimum(limit, ln[:, :, None])  # (B, S, Q)
    valid = jnp.arange(C)[None, None, None, :] < limit[..., None]  # (B,S,Q,C)
    if window > 0:
        assert k_pos is not None and q_pos is not None
        qp = q_pos[:, None, None] + qi  # (B, 1, Q)
        in_win = (k_pos.transpose(1, 0, 2)[:, :, None, :]
                  > (qp[..., None] - window))
        valid &= in_win
    scores = jnp.where(valid[:, :, :, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    nonempty = valid.any(axis=-1)[:, :, :, None, None]
    probs = jnp.where(nonempty, probs, 0.0)
    out = jnp.einsum("bsqgc,sbcd->bsqgd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def paged_fairkv_decode_ref(
    q: jnp.ndarray,  # (B, S, G, Dh) or (B, S, Q, G, Dh) multi-query
    k_pool: jnp.ndarray,  # (N, bs, Dh) — one layer's pools
    v_pool: jnp.ndarray,  # (N, bs, Dh)
    pos_pool: jnp.ndarray,  # (N, bs) int32
    block_table: jnp.ndarray,  # (S, B, M) int32; 0 = null block
    lengths: jnp.ndarray,  # (S, B) int32
    capacity: int,
    attn_cap: float = 0.0,
    q_pos: Optional[jnp.ndarray] = None,
    window: int = 0,
    k_scale: Optional[jnp.ndarray] = None,  # (N,) fp32 per-block scales
    v_scale: Optional[jnp.ndarray] = None,  # (N,)
    kinds: Optional[jnp.ndarray] = None,  # (S,) int32 per-slot kind codes
    q_lens: Optional[jnp.ndarray] = None,  # (B,) valid queries (5D q only)
) -> jnp.ndarray:
    """Oracle for the paged decode path (`kernels.paged_decode`).

    Gathers each (slot, row)'s blocks into the contiguous view the slot
    cache would hold — column ``c`` at offset ``c % bs`` of block
    ``table[c // bs]`` — then applies `fairkv_decode_ref` unchanged, so the
    paged path's semantics are *defined* as slot-path semantics over the
    gathered view.  Quantized pools (``k_scale is not None``) dequantize
    the gathered blocks first (`dequant_block_codes`) — all-int8 kinds
    assumed when ``kinds`` is omitted.  A 5-D ``q`` selects the multi-query
    (speculative-verify) semantics of `fairkv_decode_mq_ref`.
    """
    ids = jnp.maximum(block_table, 0)
    S, B, M = ids.shape
    bs, Dh = k_pool.shape[1], k_pool.shape[2]
    k = k_pool[ids]  # (S, B, M, bs, Dh)
    v = v_pool[ids]
    if k_scale is not None:
        kind = (jnp.zeros((S,), jnp.int32) if kinds is None
                else jnp.asarray(kinds, jnp.int32))
        kind = kind[:, None, None, None, None]
        k = dequant_block_codes(k, k_scale[ids][..., None, None], kind)
        v = dequant_block_codes(v, v_scale[ids][..., None, None], kind)
    k = k.reshape(S, B, M * bs, Dh)[:, :, :capacity]
    v = v.reshape(S, B, M * bs, Dh)[:, :, :capacity]
    pos = pos_pool[ids].reshape(S, B, M * bs)[:, :, :capacity]
    if q.ndim == 5:
        return fairkv_decode_mq_ref(q, k, v, lengths, attn_cap, k_pos=pos,
                                    q_pos=q_pos, q_lens=q_lens, window=window)
    return fairkv_decode_ref(q, k, v, lengths, attn_cap, k_pos=pos,
                             q_pos=q_pos, window=window)


def snapkv_scores_ref(
    q_obs: jnp.ndarray,  # (B, W, Hq, Dh) observation-window queries (RoPE'd)
    k: jnp.ndarray,  # (B, T, Hkv, Dh)
    obs_positions: jnp.ndarray,  # (B, W)
    k_positions: jnp.ndarray,  # (B, T)
    attn_cap: float = 0.0,
) -> jnp.ndarray:
    """Observation-window importance: Σ_{w,g} softmax_T(q_w · k) → (B, Hkv, T).

    (Pooling is applied by the caller — it is cheap and policy-specific.)
    """
    B, W, Hq, Dh = q_obs.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q_obs.reshape(B, W, Hkv, G, Dh).astype(jnp.float32)
    s = jnp.einsum("bwhgd,bthd->bhgwt", qg, k.astype(jnp.float32)) / math.sqrt(Dh)
    if attn_cap > 0:
        s = attn_cap * jnp.tanh(s / attn_cap)
    causal = k_positions[:, None, :] <= obs_positions[:, :, None]  # (B, W, T)
    s = jnp.where(causal[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(causal[:, None, None], p, 0.0)
    return p.sum(axis=(2, 3))  # (B, Hkv, T)


def ssd_chunk_ref(x, dt, A_log, B_, C_, D_, chunk=64):
    """Oracle for the SSD chunk kernel — delegates to the model implementation
    (itself validated against a naive sequential scan in tests)."""
    from repro.models.ssm import ssd_chunked
    return ssd_chunked(x, dt, A_log, B_, C_, D_, chunk=chunk)
