"""Pallas TPU kernel: native paged decode attention over block pools.

The gather path (`kernels/paged_decode.py`) materializes each row's blocks
into a full capacity-sized ``(S, B, C, Dh)`` contiguous view before reusing
the slot kernel, so its decode HBM traffic is paid at *slot-cache* scale
even when compression retained a fraction of the capacity.  This kernel is
the paged analog of vLLM's PagedAttention: it consumes the ``(N, bs, Dh)``
pools and the ``(S, B, M)`` block table directly, so HBM→VMEM traffic (the
decode bottleneck) is proportional to the **allocated blocks** — the
realized retained lengths FairKV balances across shards (DESIGN.md §11).

Design (TPU-adapted flash-decoding over block tables):
- grid = (S, B, M); one program attends one (slot, row) over one pool
  block of ``bs`` positions (logical columns ``[j·bs, (j+1)·bs)``).
- the block table and ``lengths`` ride in scalar prefetch; the K/V
  BlockSpec index maps resolve ``table[s, b, j]`` per grid step.  Steps
  past ``ceil(len/bs)`` clamp to the *last valid* block's pool index, so
  consecutive grid steps map to the same block and the Pallas TPU pipeline
  skips the redundant copy — null and past-length blocks cost no bandwidth.
- rows with no valid blocks resolve to the table's first entry (the null
  block); its garbage never reaches the output because the in-kernel
  length mask zeroes every score past ``lengths[s, b]``.
- online softmax (m, l, acc) in VMEM scratch, fp32; the final grid step
  writes ``acc / l`` (exact zeros for rows the slot does not own).
- sliding-window masking uses the pool's per-entry absolute positions
  (gemma2 local layers / hymba) and gemma2's attention softcap is applied
  before masking, matching the slot kernel bit-for-bit on the same math.

Quantized pools (DESIGN.md §15): when the backend stores int8 codes the
kernel takes two extra ``(N, 1)`` fp32 scale operands whose BlockSpecs ride
the *same* block-id index map as K/V — each grid step's HBM→VMEM copy is
then ``2·bs·Dh`` bytes of codes plus 8 bytes of scale instead of
``2·bs·Dh·itemsize`` bytes of floats, and the dequant
(``codes → fp32 · scale``) happens in-register inside the online-softmax
loop.  A fourth scalar-prefetch operand carries the (S,) per-slot kind
codes (0 = int8, 1 = fp8-bitcast) selecting the dequant interpretation per
program.  The fp32 path takes the original operand list — the quantized
knob off compiles a byte-identical kernel.

Validated in interpret mode against ``ref.paged_fairkv_decode_ref``
(tests/test_paged_kernel.py); dispatched via ``ops.paged_fairkv_decode``.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import compiler_params

NEG_INF = -1e30

# kernels stay self-contained (no repro.paging import): local fp8 probe,
# matching kvquant.fp8_supported / ref._HAS_FP8
_HAS_FP8 = hasattr(jnp, "float8_e4m3fn")


def _dequant(codes, scale, kind):
    """In-kernel block dequant: int8 codes → fp32 at the block's scale.

    ``kind`` selects int8 (codes are signed integers) vs fp8 (codes are
    bitcast float8_e4m3fn); fp8 NaN bit patterns — possible only in
    never-written garbage the length mask will discard — flush to 0 so they
    cannot poison ``p·v`` through 0·NaN.
    """
    f = codes.astype(jnp.float32)
    if _HAS_FP8:
        f8 = jax.lax.bitcast_convert_type(
            codes, jnp.float8_e4m3fn).astype(jnp.float32)
        f8 = jnp.where(f8 == f8, f8, 0.0)
        f = jnp.where(kind == 1, f8, f)
    return f * scale


def _kernel(
    *refs,
    bs: int,
    n_blocks: int,
    scale: float,
    attn_cap: float,
    window: int,
    quantized: bool,
):
    # operand order mirrors the two pallas_call signatures below: scalar
    # prefetch (table, lengths, q_pos[, kinds]), then inputs
    # (q, k, v, kpos[, k_scale, v_scale]), output, scratch
    if quantized:
        (table_ref, lengths_ref, q_pos_ref, kinds_ref,
         q_ref, k_ref, v_ref, kpos_ref, ksc_ref, vsc_ref,
         o_ref, acc_ref, m_ref, l_ref) = refs
    else:
        (table_ref, lengths_ref, q_pos_ref,
         q_ref, k_ref, v_ref, kpos_ref,
         o_ref, acc_ref, m_ref, l_ref) = refs
    s, b, j = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    ln = lengths_ref[s, b]
    n_valid = (ln + bs - 1) // bs

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when(j < n_valid)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (G, Dh)
        if quantized:
            kind = kinds_ref[s]
            k = _dequant(k_ref[0], ksc_ref[0, 0], kind)  # (bs, Dh)
        else:
            k = k_ref[0].astype(jnp.float32)  # (bs, Dh)
        scores = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (G, bs)
        if attn_cap > 0:
            scores = attn_cap * jnp.tanh(scores / attn_cap)
        offs = j * bs + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
        valid = offs < ln  # masks the last block's partial fill too
        if window > 0:
            kp = kpos_ref[0]  # (bs,) int32 absolute entry positions
            qp = q_pos_ref[b]
            valid &= kp[None, :] > (qp - window)
        scores = jnp.where(valid, scores, NEG_INF)
        m_prev = m_ref[...]  # (G, 1)
        m_new = jnp.maximum(m_prev, scores.max(axis=1, keepdims=True))
        # explicit mask: when every entry is masked, m_new stays NEG_INF and
        # exp(NEG_INF - NEG_INF) would be 1 — the mask zeroes it instead
        p = jnp.where(valid, jnp.exp(scores - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
        m_ref[...] = m_new
        if quantized:
            v = _dequant(v_ref[0], vsc_ref[0, 0], kinds_ref[s])  # (bs, Dh)
        else:
            v = v_ref[0].astype(jnp.float32)  # (bs, Dh)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr + pv

    @pl.when(j == n_blocks - 1)
    def _finalize():
        l = l_ref[...]
        out = acc_ref[...] / jnp.where(l > 0, l, 1.0)
        out = jnp.where(l > 0, out, 0.0)
        o_ref[0, 0] = out.astype(o_ref.dtype)


def _mq_kernel(
    *refs,
    bs: int,
    n_blocks: int,
    n_q: int,
    group: int,
    scale: float,
    attn_cap: float,
    window: int,
    quantized: bool,
):
    """Multi-query (speculative-verify) variant: one program attends the
    full (Q, G) query block of one (slot, row) over one pool block.  The
    query axis folds into the sublane dim — scores and scratch are
    ``(Q·G, ·)`` — and the causal mask within the speculative window is a
    per-query length limit: query ``i`` of a row with ``qn`` valid queries
    sees the first ``len − (qn − 1 − i)`` entries (own token included,
    later speculative tokens excluded)."""
    if quantized:
        (table_ref, lengths_ref, q_pos_ref, q_lens_ref, kinds_ref,
         q_ref, k_ref, v_ref, kpos_ref, ksc_ref, vsc_ref,
         o_ref, acc_ref, m_ref, l_ref) = refs
    else:
        (table_ref, lengths_ref, q_pos_ref, q_lens_ref,
         q_ref, k_ref, v_ref, kpos_ref,
         o_ref, acc_ref, m_ref, l_ref) = refs
    s, b, j = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    ln = lengths_ref[s, b]
    n_valid = (ln + bs - 1) // bs

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when(j < n_valid)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32).reshape(n_q * group, -1)
        if quantized:
            kind = kinds_ref[s]
            k = _dequant(k_ref[0], ksc_ref[0, 0], kind)  # (bs, Dh)
        else:
            k = k_ref[0].astype(jnp.float32)  # (bs, Dh)
        scores = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (Q·G, bs)
        if attn_cap > 0:
            scores = attn_cap * jnp.tanh(scores / attn_cap)
        offs = j * bs + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
        qi = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 0) // group
        qn = q_lens_ref[b]
        # per-query causal limit; garbage lanes (qi >= qn) clamp to ln
        limit = jnp.minimum(ln - (qn - 1 - qi), ln)
        valid = offs < limit
        if window > 0:
            kp = kpos_ref[0]  # (bs,) int32 absolute entry positions
            qp = q_pos_ref[b] + qi  # query i sits at q_pos + i
            valid &= kp[None, :] > (qp - window)
        scores = jnp.where(valid, scores, NEG_INF)
        m_prev = m_ref[...]  # (Q·G, 1)
        m_new = jnp.maximum(m_prev, scores.max(axis=1, keepdims=True))
        p = jnp.where(valid, jnp.exp(scores - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
        m_ref[...] = m_new
        if quantized:
            v = _dequant(v_ref[0], vsc_ref[0, 0], kinds_ref[s])  # (bs, Dh)
        else:
            v = v_ref[0].astype(jnp.float32)  # (bs, Dh)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr + pv

    @pl.when(j == n_blocks - 1)
    def _finalize():
        l = l_ref[...]
        out = acc_ref[...] / jnp.where(l > 0, l, 1.0)
        out = jnp.where(l > 0, out, 0.0)
        o_ref[0, 0] = out.reshape(n_q, group, -1).astype(o_ref.dtype)


def _paged_decode_pallas_mq(
    q, k_pool, v_pool, pos_pool, block_table, lengths, capacity,
    attn_cap, q_pos, q_lens, window, interpret, k_scale, v_scale, kinds,
):
    """Multi-query pallas_call assembly — same grid/index maps as the
    single-query path with ``q_lens`` riding as an extra scalar-prefetch
    operand and (Q, G)-blocked query/output BlockSpecs."""
    B, S, Q, G, Dh = q.shape
    N, bs, _ = k_pool.shape
    M = block_table.shape[2]
    if M * bs < capacity:
        raise ValueError(
            f"block table spans {M}x{bs} tokens < capacity {capacity}")
    table = jnp.asarray(block_table, jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32)
    if q_pos is None:
        q_pos = jnp.zeros((B,), jnp.int32)
    if q_lens is None:
        q_lens = jnp.full((B,), Q, jnp.int32)
    q_lens = jnp.asarray(q_lens, jnp.int32)
    quantized = k_scale is not None

    def q_map(s, b, j, tbl, lens, *rest):
        return (b, s, 0, 0, 0)

    def block_id(s, b, j, tbl, lens):
        ln = lens[s, b]
        last_valid = jnp.maximum((ln + bs - 1) // bs - 1, 0)
        jj = jnp.minimum(j, last_valid)
        return jnp.maximum(tbl[s, b, jj], 0)

    def kv_map(s, b, j, tbl, lens, *rest):
        return (block_id(s, b, j, tbl, lens), 0, 0)

    def kpos_map(s, b, j, tbl, lens, *rest):
        return (block_id(s, b, j, tbl, lens), 0)

    def scale_map(s, b, j, tbl, lens, *rest):
        return (block_id(s, b, j, tbl, lens), 0)

    def o_map(s, b, j, tbl, lens, *rest):
        return (b, s, 0, 0, 0)

    in_specs = [
        pl.BlockSpec((1, 1, Q, G, Dh), q_map),
        pl.BlockSpec((1, bs, Dh), kv_map),
        pl.BlockSpec((1, bs, Dh), kv_map),
        pl.BlockSpec((1, bs), kpos_map),
    ]
    num_prefetch = 4
    args = [table, lengths, q_pos, q_lens, q, k_pool, v_pool, pos_pool]
    if quantized:
        kind = (jnp.zeros((S,), jnp.int32) if kinds is None
                else jnp.asarray(kinds, jnp.int32))
        num_prefetch = 5
        args = [table, lengths, q_pos, q_lens, kind, q, k_pool, v_pool,
                pos_pool,
                jnp.asarray(k_scale, jnp.float32).reshape(N, 1),
                jnp.asarray(v_scale, jnp.float32).reshape(N, 1)]
        in_specs = in_specs + [
            pl.BlockSpec((1, 1), scale_map),
            pl.BlockSpec((1, 1), scale_map),
        ]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=num_prefetch,
        grid=(S, B, M),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, Q, G, Dh), o_map),
        scratch_shapes=[
            pltpu.VMEM((Q * G, Dh), jnp.float32),
            pltpu.VMEM((Q * G, 1), jnp.float32),
            pltpu.VMEM((Q * G, 1), jnp.float32),
        ],
    )
    kernel = functools.partial(
        _mq_kernel, bs=bs, n_blocks=M, n_q=Q, group=G,
        scale=1.0 / math.sqrt(Dh), attn_cap=attn_cap, window=window,
        quantized=quantized)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, S, Q, G, Dh), q.dtype),
        interpret=interpret,
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(*args)


def paged_fairkv_decode_pallas(
    q: jnp.ndarray,  # (B, S, G, Dh); (B, S, Q, G, Dh) = multi-query verify
    k_pool: jnp.ndarray,  # (N, bs, Dh) — one layer's key pool
    v_pool: jnp.ndarray,  # (N, bs, Dh)
    pos_pool: jnp.ndarray,  # (N, bs) int32
    block_table: jnp.ndarray,  # (S, B, M) int32; <=0 = null block
    lengths: jnp.ndarray,  # (S, B) int32
    capacity: int,
    attn_cap: float = 0.0,
    q_pos: Optional[jnp.ndarray] = None,  # (B,) int32
    window: int = 0,
    interpret: bool = False,
    k_scale: Optional[jnp.ndarray] = None,  # (N,) fp32 per-block scales
    v_scale: Optional[jnp.ndarray] = None,  # (N,)
    kinds: Optional[jnp.ndarray] = None,  # (S,) int32 per-slot kind codes
    q_lens: Optional[jnp.ndarray] = None,  # (B,) valid queries (5D q only)
) -> jnp.ndarray:
    """Decode attention over one paged layer — same contract as
    ``ref.paged_fairkv_decode_ref``, consuming pools + table directly.

    A 5-D ``q`` selects the multi-query speculative-verify path
    (`_mq_kernel`); the 4-D single-query path below is byte-identical to
    its pre-speculation form, so single-token decode traces are unchanged.
    """
    if q.ndim == 5:
        return _paged_decode_pallas_mq(
            q, k_pool, v_pool, pos_pool, block_table, lengths, capacity,
            attn_cap, q_pos, q_lens, window, interpret, k_scale, v_scale,
            kinds)
    B, S, G, Dh = q.shape
    N, bs, _ = k_pool.shape
    M = block_table.shape[2]
    if M * bs < capacity:
        raise ValueError(
            f"block table spans {M}x{bs} tokens < capacity {capacity}")
    table = jnp.asarray(block_table, jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32)
    if q_pos is None:
        q_pos = jnp.zeros((B,), jnp.int32)
    quantized = k_scale is not None

    # *rest absorbs the extra (kinds) scalar-prefetch ref on the quantized
    # path so one set of index maps serves both operand lists
    def q_map(s, b, j, tbl, lens, *rest):
        return (b, s, 0, 0)

    def block_id(s, b, j, tbl, lens):
        # clamp past-length grid steps to the last valid block so
        # consecutive steps map to equal indices (pipeline skips the copy);
        # rows with no valid blocks resolve to entry 0 (the null block)
        ln = lens[s, b]
        last_valid = jnp.maximum((ln + bs - 1) // bs - 1, 0)
        jj = jnp.minimum(j, last_valid)
        return jnp.maximum(tbl[s, b, jj], 0)

    def kv_map(s, b, j, tbl, lens, *rest):
        return (block_id(s, b, j, tbl, lens), 0, 0)

    def kpos_map(s, b, j, tbl, lens, *rest):
        return (block_id(s, b, j, tbl, lens), 0)

    def scale_map(s, b, j, tbl, lens, *rest):
        return (block_id(s, b, j, tbl, lens), 0)

    def o_map(s, b, j, tbl, lens, *rest):
        return (b, s, 0, 0)

    in_specs = [
        pl.BlockSpec((1, 1, G, Dh), q_map),
        pl.BlockSpec((1, bs, Dh), kv_map),
        pl.BlockSpec((1, bs, Dh), kv_map),
        pl.BlockSpec((1, bs), kpos_map),
    ]
    num_prefetch = 3
    args = [table, lengths, q_pos, q, k_pool, v_pool, pos_pool]
    if quantized:
        kind = (jnp.zeros((S,), jnp.int32) if kinds is None
                else jnp.asarray(kinds, jnp.int32))
        num_prefetch = 4
        args = [table, lengths, q_pos, kind, q, k_pool, v_pool, pos_pool,
                jnp.asarray(k_scale, jnp.float32).reshape(N, 1),
                jnp.asarray(v_scale, jnp.float32).reshape(N, 1)]
        in_specs = in_specs + [
            pl.BlockSpec((1, 1), scale_map),
            pl.BlockSpec((1, 1), scale_map),
        ]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=num_prefetch,
        grid=(S, B, M),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, G, Dh), o_map),
        scratch_shapes=[
            pltpu.VMEM((G, Dh), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
    )
    kernel = functools.partial(
        _kernel, bs=bs, n_blocks=M, scale=1.0 / math.sqrt(Dh),
        attn_cap=attn_cap, window=window, quantized=quantized)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, S, G, Dh), q.dtype),
        interpret=interpret,
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(*args)
    return out
