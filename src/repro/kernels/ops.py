"""Jit'd kernel entry points with backend dispatch.

``backend`` (slot-layout kernels):
- "jnp"       pure-jnp reference (always available; used under pjit where the
              XLA partitioner handles sharding)
- "pallas"    the Pallas TPU kernel (TARGET path; on CPU runs via
              ``interpret=True`` for correctness validation)
- "auto"      pallas on TPU, jnp elsewhere

``impl`` (paged decode):
- "pallas"    native block-table kernel (`kernels/paged_fairkv_decode.py`):
              HBM traffic proportional to allocated blocks (TARGET path)
- "gather"    materialize capacity-sized contiguous views, reuse the slot
              kernel (`kernels/paged_decode.py`) — the migration/debug path
- "jnp"       pure-jnp oracle (`ref.paged_fairkv_decode_ref`)
- "auto"      pallas on TPU, jnp elsewhere

``REPRO_PALLAS_INTERPRET=1`` forces every "auto" dispatch onto the Pallas
kernels in interpret mode even off-TPU — the CI ``kernels-interpret`` gate
uses it so kernel regressions fail in a named job instead of hiding behind
the jnp fallback.
"""
from __future__ import annotations

import os
from typing import Optional

import jax

from repro.kernels import ref as _ref

# paged decode implementations accepted by `paged_fairkv_decode` (and by
# `PagingConfig.decode_impl`, which validates against this tuple)
PAGED_DECODE_IMPLS = ("auto", "pallas", "gather", "jnp")


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _force_interpret() -> bool:
    """True when REPRO_PALLAS_INTERPRET forces Pallas-interpret off-TPU."""
    return os.environ.get("REPRO_PALLAS_INTERPRET", "") not in ("", "0")


def _use_pallas(backend: str) -> bool:
    if backend == "jnp":
        return False
    if backend == "auto":
        return _on_tpu() or _force_interpret()
    return True


def pallas_in_decode(paged_impl: str = "auto") -> bool:
    """True when the decode step's attention resolves to a Pallas kernel
    under the current backend/env — the mesh executor must then build its
    decode ``shard_map`` with ``check_rep=False`` (``pallas_call`` has no
    replication rule for the static checker; the psum-reassembly contract
    is unchanged, only its static verification is skipped)."""
    # slot kernel and "auto"/"gather" paged dispatch all hit pallas then
    return _use_pallas("auto") or paged_impl == "pallas"


def fairkv_decode(q, k, v, lengths, attn_cap: float = 0.0,
                  k_pos=None, q_pos=None, window: int = 0,
                  backend: str = "auto", block_c: int = 128,
                  interpret: Optional[bool] = None):
    """Slot-layout decode attention (see ref.fairkv_decode_ref)."""
    if not _use_pallas(backend):
        return _ref.fairkv_decode_ref(q, k, v, lengths, attn_cap,
                                      k_pos=k_pos, q_pos=q_pos, window=window)
    from repro.kernels.fairkv_decode import fairkv_decode_pallas
    ipret = (not _on_tpu()) if interpret is None else interpret
    return fairkv_decode_pallas(q, k, v, lengths, attn_cap=attn_cap,
                                k_pos=k_pos, q_pos=q_pos, window=window,
                                block_c=block_c, interpret=ipret)


def paged_fairkv_decode(q, k_pool, v_pool, pos_pool, block_table, lengths,
                        capacity: int, attn_cap: float = 0.0, q_pos=None,
                        window: int = 0, impl: str = "auto",
                        block_c: int = 128,
                        interpret: Optional[bool] = None,
                        k_scale=None, v_scale=None, kinds=None,
                        q_lens=None):
    """Paged decode attention (see ref.paged_fairkv_decode_ref).

    Same contract as ``fairkv_decode`` with (k, v, k_pos) replaced by one
    layer's (pools, block table); ``impl`` picks the implementation (module
    docstring).  All impls agree on the valid prefix — the native kernel is
    validated against the oracle in tests/test_paged_kernel.py and holds
    token parity with the gather and slot paths through `Engine.generate`.

    ``k_scale``/``v_scale`` ((N,) fp32) and ``kinds`` ((S,) int32) carry the
    quantized-pool dequant state (DESIGN.md §15); every impl applies the
    identical dequant semantics, so quantized parity tests compare real
    implementations rather than a shared helper against itself.

    A 5-D ``q`` of shape (B, S, Q, G, Dh) selects the multi-query
    speculative-verify path (DESIGN.md §16): query ``i`` of row ``b``
    attends causally within the speculative window, ``q_lens`` ((B,) int32,
    default all-Q) bounding the valid queries per row.  Every impl applies
    the same per-query mask, so the verify kernel validates against the
    same oracle chain as single-token decode.
    """
    if impl not in PAGED_DECODE_IMPLS:
        raise ValueError(
            f"unknown paged decode impl {impl!r}; known: "
            f"{list(PAGED_DECODE_IMPLS)}")
    if impl == "auto":
        impl = "pallas" if _use_pallas("auto") else "jnp"
    if impl == "jnp":
        return _ref.paged_fairkv_decode_ref(
            q, k_pool, v_pool, pos_pool, block_table, lengths, capacity,
            attn_cap, q_pos=q_pos, window=window,
            k_scale=k_scale, v_scale=v_scale, kinds=kinds, q_lens=q_lens)
    if impl == "gather":
        from repro.kernels.paged_decode import paged_fairkv_decode_gather
        return paged_fairkv_decode_gather(
            q, k_pool, v_pool, pos_pool, block_table, lengths, capacity,
            attn_cap=attn_cap, q_pos=q_pos, window=window, backend="auto",
            block_c=block_c, interpret=interpret,
            k_scale=k_scale, v_scale=v_scale, kinds=kinds, q_lens=q_lens)
    from repro.kernels.paged_fairkv_decode import paged_fairkv_decode_pallas
    ipret = (not _on_tpu()) if interpret is None else interpret
    return paged_fairkv_decode_pallas(
        q, k_pool, v_pool, pos_pool, block_table, lengths, capacity,
        attn_cap=attn_cap, q_pos=q_pos, window=window, interpret=ipret,
        k_scale=k_scale, v_scale=v_scale, kinds=kinds, q_lens=q_lens)


def snapkv_scores(q_obs, k, obs_positions, k_positions, attn_cap: float = 0.0,
                  backend: str = "auto", block_t: int = 128,
                  interpret: Optional[bool] = None):
    """Observation-window importance scores (see ref.snapkv_scores_ref)."""
    if not _use_pallas(backend):
        return _ref.snapkv_scores_ref(q_obs, k, obs_positions, k_positions,
                                      attn_cap)
    from repro.kernels.snapkv_select import snapkv_scores_pallas
    ipret = (not _on_tpu()) if interpret is None else interpret
    return snapkv_scores_pallas(q_obs, k, obs_positions, k_positions,
                                attn_cap=attn_cap, block_t=block_t,
                                interpret=ipret)
