"""Jit'd kernel entry points with backend dispatch.

``backend``:
- "jnp"       pure-jnp reference (always available; used under pjit where the
              XLA partitioner handles sharding)
- "pallas"    the Pallas TPU kernel (TARGET path; on CPU runs via
              ``interpret=True`` for correctness validation)
- "auto"      pallas on TPU, jnp elsewhere
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def fairkv_decode(q, k, v, lengths, attn_cap: float = 0.0,
                  k_pos=None, q_pos=None, window: int = 0,
                  backend: str = "auto", block_c: int = 128,
                  interpret: Optional[bool] = None):
    """Slot-layout decode attention (see ref.fairkv_decode_ref)."""
    if backend == "jnp" or (backend == "auto" and not _on_tpu()):
        return _ref.fairkv_decode_ref(q, k, v, lengths, attn_cap,
                                      k_pos=k_pos, q_pos=q_pos, window=window)
    from repro.kernels.fairkv_decode import fairkv_decode_pallas
    ipret = (not _on_tpu()) if interpret is None else interpret
    return fairkv_decode_pallas(q, k, v, lengths, attn_cap=attn_cap,
                                k_pos=k_pos, q_pos=q_pos, window=window,
                                block_c=block_c, interpret=ipret)


def snapkv_scores(q_obs, k, obs_positions, k_positions, attn_cap: float = 0.0,
                  backend: str = "auto", block_t: int = 128,
                  interpret: Optional[bool] = None):
    """Observation-window importance scores (see ref.snapkv_scores_ref)."""
    if backend == "jnp" or (backend == "auto" and not _on_tpu()):
        return _ref.snapkv_scores_ref(q_obs, k, obs_positions, k_positions,
                                      attn_cap)
    from repro.kernels.snapkv_select import snapkv_scores_pallas
    ipret = (not _on_tpu()) if interpret is None else interpret
    return snapkv_scores_pallas(q_obs, k, obs_positions, k_positions,
                                attn_cap=attn_cap, block_t=block_t,
                                interpret=ipret)
