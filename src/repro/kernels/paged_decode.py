"""Paged slot-layout decode attention: block gather + ``fairkv_decode``.

The paged cache stores each (slot, row)'s KV in fixed-size blocks
(``repro.paging``); this path reconstructs the exact contiguous
``(S, B, C, Dh)`` views the FairKV decode kernel already consumes by
gathering each row's blocks and reshaping — logical column ``c`` lives at
offset ``c % bs`` of block ``table[c // bs]``, so the gathered view is
*bit-identical* to the slot cache on every column inside the valid prefix,
and the kernel's length masking guarantees nothing outside that prefix
reaches the output.  Reusing the kernel this way keeps one set of masking /
online-softmax semantics for both backends (validated by the parity property
test in tests/test_paging.py).

The cost is bandwidth: the gather **materializes capacity-sized views** —
it writes (and the kernel re-reads) the full ``S·B·C`` columns every decode
step, null-backed garbage included — so its HBM traffic is paid at
slot-cache scale regardless of how little the compression retained.  The
native kernel (`kernels/paged_fairkv_decode.py`, ``ops.paged_fairkv_decode``
with ``impl="pallas"``) removes that materialization; the gather stays as
(a) the block→contiguous primitive migration and ``paged_to_slot`` build on
and (b) an XLA-only fallback/debug path (DESIGN.md §11).

The pure-jnp oracle is ``ref.paged_fairkv_decode_ref``.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels import ops as K


def paged_gather_views(
    k_pool: jnp.ndarray,  # (N, bs, Dh) — one layer's key pool
    v_pool: jnp.ndarray,  # (N, bs, Dh)
    pos_pool: jnp.ndarray,  # (N, bs) int32
    block_table: jnp.ndarray,  # (S, B, M) int32; 0 = null block
    capacity: int,
):
    """(S, B, C, Dh) / (S, B, C) contiguous views of one layer's paged KV.

    Null-backed columns hold garbage; callers must mask by lengths (the
    decode kernel does).
    """
    ids = jnp.maximum(block_table, 0)
    S, B, M = ids.shape
    bs, Dh = k_pool.shape[1], k_pool.shape[2]
    k = k_pool[ids].reshape(S, B, M * bs, Dh)[:, :, :capacity]
    v = v_pool[ids].reshape(S, B, M * bs, Dh)[:, :, :capacity]
    pos = pos_pool[ids].reshape(S, B, M * bs)[:, :, :capacity]
    return k, v, pos


def paged_fairkv_decode_gather(
    q: jnp.ndarray,  # (B, S, G, Dh)
    k_pool: jnp.ndarray,  # (N, bs, Dh)
    v_pool: jnp.ndarray,  # (N, bs, Dh)
    pos_pool: jnp.ndarray,  # (N, bs) int32
    block_table: jnp.ndarray,  # (S, B, M) int32
    lengths: jnp.ndarray,  # (S, B) int32
    capacity: int,
    attn_cap: float = 0.0,
    q_pos: Optional[jnp.ndarray] = None,  # (B,) int32
    window: int = 0,
    backend: str = "auto",
    block_c: int = 128,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Gather-based paged decode — same contract as
    ``ops.paged_fairkv_decode`` (which dispatches here for ``impl="gather"``)."""
    k, v, k_pos = paged_gather_views(k_pool, v_pool, pos_pool, block_table,
                                     capacity)
    return K.fairkv_decode(q, k, v, lengths, attn_cap=attn_cap, k_pos=k_pos,
                           q_pos=q_pos, window=window, backend=backend,
                           block_c=block_c, interpret=interpret)
