"""Paged slot-layout decode attention: block gather + ``fairkv_decode``.

The paged cache stores each (slot, row)'s KV in fixed-size blocks
(``repro.paging``); this path reconstructs the exact contiguous
``(S, B, C, Dh)`` views the FairKV decode kernel already consumes by
gathering each row's blocks and reshaping — logical column ``c`` lives at
offset ``c % bs`` of block ``table[c // bs]``, so the gathered view is
*bit-identical* to the slot cache on every column inside the valid prefix,
and the kernel's length masking guarantees nothing outside that prefix
reaches the output.  Reusing the kernel this way keeps one set of masking /
online-softmax semantics for both backends (validated by the parity property
test in tests/test_paging.py).

The cost is bandwidth: the gather **materializes capacity-sized views** —
it writes (and the kernel re-reads) the full ``S·B·C`` columns every decode
step, null-backed garbage included — so its HBM traffic is paid at
slot-cache scale regardless of how little the compression retained.  The
native kernel (`kernels/paged_fairkv_decode.py`, ``ops.paged_fairkv_decode``
with ``impl="pallas"``) removes that materialization; the gather stays as
(a) the block→contiguous primitive migration and ``paged_to_slot`` build on
and (b) an XLA-only fallback/debug path (DESIGN.md §11).

The pure-jnp oracle is ``ref.paged_fairkv_decode_ref``.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels import ops as K
from repro.kernels.ref import dequant_block_codes, fairkv_decode_mq_ref


def paged_gather_views(
    k_pool: jnp.ndarray,  # (N, bs, Dh) — one layer's key pool
    v_pool: jnp.ndarray,  # (N, bs, Dh)
    pos_pool: jnp.ndarray,  # (N, bs) int32
    block_table: jnp.ndarray,  # (S, B, M) int32; 0 = null block
    capacity: int,
    k_scale: Optional[jnp.ndarray] = None,  # (N,) fp32 per-block scales
    v_scale: Optional[jnp.ndarray] = None,  # (N,)
    kinds: Optional[jnp.ndarray] = None,  # (S,) int32 per-slot kind codes
):
    """(S, B, C, Dh) / (S, B, C) contiguous views of one layer's paged KV.

    Null-backed columns hold garbage; callers must mask by lengths (the
    decode kernel does).  Quantized pools dequantize through the per-block
    scale pools on the way out (DESIGN.md §15), so the views hold real
    values regardless of the storage format.
    """
    ids = jnp.maximum(block_table, 0)
    S, B, M = ids.shape
    bs, Dh = k_pool.shape[1], k_pool.shape[2]
    k = k_pool[ids]  # (S, B, M, bs, Dh)
    v = v_pool[ids]
    if k_scale is not None:
        kind = (jnp.zeros((S,), jnp.int32) if kinds is None
                else jnp.asarray(kinds, jnp.int32))
        kind = kind[:, None, None, None, None]
        k = dequant_block_codes(k, k_scale[ids][..., None, None], kind)
        v = dequant_block_codes(v, v_scale[ids][..., None, None], kind)
    k = k.reshape(S, B, M * bs, Dh)[:, :, :capacity]
    v = v.reshape(S, B, M * bs, Dh)[:, :, :capacity]
    pos = pos_pool[ids].reshape(S, B, M * bs)[:, :, :capacity]
    return k, v, pos


def paged_fairkv_decode_gather(
    q: jnp.ndarray,  # (B, S, G, Dh) or (B, S, Q, G, Dh) multi-query
    k_pool: jnp.ndarray,  # (N, bs, Dh)
    v_pool: jnp.ndarray,  # (N, bs, Dh)
    pos_pool: jnp.ndarray,  # (N, bs) int32
    block_table: jnp.ndarray,  # (S, B, M) int32
    lengths: jnp.ndarray,  # (S, B) int32
    capacity: int,
    attn_cap: float = 0.0,
    q_pos: Optional[jnp.ndarray] = None,  # (B,) int32
    window: int = 0,
    backend: str = "auto",
    block_c: int = 128,
    interpret: Optional[bool] = None,
    k_scale: Optional[jnp.ndarray] = None,
    v_scale: Optional[jnp.ndarray] = None,
    kinds: Optional[jnp.ndarray] = None,
    q_lens: Optional[jnp.ndarray] = None,  # (B,) valid queries (5D q only)
) -> jnp.ndarray:
    """Gather-based paged decode — same contract as
    ``ops.paged_fairkv_decode`` (which dispatches here for ``impl="gather"``).

    A 5-D ``q`` (speculative verify) attends the gathered views through the
    multi-query oracle math — the gather's distinguishing work is the
    block→contiguous materialization, which is query-count-independent.
    """
    k, v, k_pos = paged_gather_views(k_pool, v_pool, pos_pool, block_table,
                                     capacity, k_scale=k_scale,
                                     v_scale=v_scale, kinds=kinds)
    if q.ndim == 5:
        return fairkv_decode_mq_ref(q, k, v, lengths, attn_cap=attn_cap,
                                    k_pos=k_pos, q_pos=q_pos, q_lens=q_lens,
                                    window=window)
    return K.fairkv_decode(q, k, v, lengths, attn_cap=attn_cap, k_pos=k_pos,
                           q_pos=q_pos, window=window, backend=backend,
                           block_c=block_c, interpret=interpret)
