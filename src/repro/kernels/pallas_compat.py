"""Version shims for jax.experimental.pallas TPU APIs.

jax renamed ``TPUCompilerParams`` to ``CompilerParams``; support both and
fail with a message naming the missing symbol rather than a late
``'NoneType' object is not callable``.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu


def compiler_params(**kwargs):
    cp = (getattr(pltpu, "CompilerParams", None)
          or getattr(pltpu, "TPUCompilerParams", None))
    if cp is None:
        raise ImportError(
            "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
            "TPUCompilerParams; unsupported jax version")
    return cp(**kwargs)
