"""Pallas TPU kernel: slot-layout decode attention with per-(slot,row)
dynamic KV lengths — the FairKV hot loop.

Design (TPU-adapted flash-decoding):
- grid = (S, B, n_kv_blocks); one program attends one (slot, row) over one
  KV block of ``block_c`` positions.
- ``lengths`` (S, B) rides in scalar-prefetch; the K/V BlockSpec index maps
  clamp the block index to the last *valid* block, so all grid steps past
  ``ceil(len/block_c)`` map to the same block — the Pallas TPU pipeline skips
  the redundant copy when consecutive indices are equal, making HBM→VMEM
  traffic (the decode bottleneck) proportional to the retained length.  This
  is exactly the property FairKV balances across shards (DESIGN.md §2).
- online softmax (m, l, acc) in VMEM scratch, fp32; the final block writes
  ``acc / l`` (zeros for rows the slot does not own, i.e. len == 0).
- optional sliding-window masking via per-entry absolute positions
  (gemma2 local layers / hymba), and gemma2's attention softcap.

Validated in interpret mode against ``ref.fairkv_decode_ref`` over
shape/dtype sweeps (tests/test_kernels.py).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import compiler_params

NEG_INF = -1e30


def _kernel(
    # scalar prefetch
    lengths_ref,  # (S, B) int32
    q_pos_ref,  # (B,) int32
    # inputs
    q_ref,  # (1, 1, G, Dh)
    k_ref,  # (1, 1, block_c, Dh)
    v_ref,  # (1, 1, block_c, Dh)
    kpos_ref,  # (1, 1, block_c) int32
    # output
    o_ref,  # (1, 1, G, Dh)
    # scratch
    acc_ref,  # (G, Dh) f32
    m_ref,  # (G, 1) f32
    l_ref,  # (G, 1) f32
    *,
    block_c: int,
    n_blocks: int,
    scale: float,
    attn_cap: float,
    window: int,
):
    s, b, c = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    ln = lengths_ref[s, b]
    n_valid = (ln + block_c - 1) // block_c

    @pl.when(c == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when(c < n_valid)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (G, Dh)
        k = k_ref[0, 0].astype(jnp.float32)  # (blk, Dh)
        scores = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (G, blk)
        if attn_cap > 0:
            scores = attn_cap * jnp.tanh(scores / attn_cap)
        offs = c * block_c + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
        valid = offs < ln
        if window > 0:
            kp = kpos_ref[0, 0]  # (blk,) int32
            qp = q_pos_ref[b]
            valid &= kp[None, :] > (qp - window)
        scores = jnp.where(valid, scores, NEG_INF)
        m_prev = m_ref[...]  # (G, 1)
        m_new = jnp.maximum(m_prev, scores.max(axis=1, keepdims=True))
        # explicit mask: when every entry is masked, m_new stays NEG_INF and
        # exp(NEG_INF - NEG_INF) would be 1 — the mask zeroes it instead
        p = jnp.where(valid, jnp.exp(scores - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
        m_ref[...] = m_new
        v = v_ref[0, 0].astype(jnp.float32)  # (blk, Dh)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr + pv

    @pl.when(c == n_blocks - 1)
    def _finalize():
        l = l_ref[...]
        out = acc_ref[...] / jnp.where(l > 0, l, 1.0)
        out = jnp.where(l > 0, out, 0.0)
        o_ref[0, 0] = out.astype(o_ref.dtype)


def fairkv_decode_pallas(
    q: jnp.ndarray,  # (B, S, G, Dh)
    k: jnp.ndarray,  # (S, B, C, Dh)
    v: jnp.ndarray,  # (S, B, C, Dh)
    lengths: jnp.ndarray,  # (S, B) int32
    attn_cap: float = 0.0,
    k_pos: Optional[jnp.ndarray] = None,  # (S, B, C) int32
    q_pos: Optional[jnp.ndarray] = None,  # (B,) int32
    window: int = 0,
    block_c: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    B, S, G, Dh = q.shape
    C = k.shape[2]
    block_c = min(block_c, C)
    n_blocks = pl.cdiv(C, block_c)
    if C % block_c != 0:  # pad capacity to a block multiple
        pad = n_blocks * block_c - C
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        if k_pos is not None:
            k_pos = jnp.pad(k_pos, ((0, 0), (0, 0), (0, pad)),
                            constant_values=-1)
    if k_pos is None:
        k_pos = jnp.zeros(k.shape[:3], jnp.int32)
    if q_pos is None:
        q_pos = jnp.zeros((B,), jnp.int32)

    def q_map(s, b, c, lens, qp):
        return (b, s, 0, 0)

    def kv_map(s, b, c, lens, qp):
        ln = lens[s, b]
        last_valid = jnp.maximum((ln + block_c - 1) // block_c - 1, 0)
        return (s, b, jnp.minimum(c, last_valid), 0)

    def kpos_map(s, b, c, lens, qp):
        ln = lens[s, b]
        last_valid = jnp.maximum((ln + block_c - 1) // block_c - 1, 0)
        return (s, b, jnp.minimum(c, last_valid))

    def o_map(s, b, c, lens, qp):
        return (b, s, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(S, B, n_blocks),
        in_specs=[
            pl.BlockSpec((1, 1, G, Dh), q_map),
            pl.BlockSpec((1, 1, block_c, Dh), kv_map),
            pl.BlockSpec((1, 1, block_c, Dh), kv_map),
            pl.BlockSpec((1, 1, block_c), kpos_map),
        ],
        out_specs=pl.BlockSpec((1, 1, G, Dh), o_map),
        scratch_shapes=[
            pltpu.VMEM((G, Dh), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
    )
    kernel = functools.partial(
        _kernel, block_c=block_c, n_blocks=n_blocks,
        scale=1.0 / math.sqrt(Dh), attn_cap=attn_cap, window=window)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, S, G, Dh), q.dtype),
        interpret=interpret,
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(lengths, q_pos, q, k, v, k_pos)
    return out
