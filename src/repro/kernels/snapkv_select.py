"""Pallas TPU kernel: SnapKV observation-window importance scores.

Computes, per (batch row, kv head), the total softmax attention mass each
position receives from the last W queries:

    imp[b, h, t] = Σ_{w, g} softmax_T(q[b, w, h, g] · k[b, :, h])_t

This is the compression-policy hot spot at prefill (W·T·Dh work per head vs
T·budget for selection).  Two-phase grid over T blocks:

  phase 0 (c < nT):  online (m, l) logsumexp accumulation per query
  phase 1 (c >= nT): emit Σ_{w,g} exp(s - m)/l for block c - nT

Both phases stream the same K blocks; the q tile (W·G, Dh) stays VMEM-
resident across the whole (b, h) program.  Validated in interpret mode
against ``ref.snapkv_scores_ref``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import compiler_params

NEG_INF = -1e30


def _kernel(
    obs_pos_ref,  # (B, W) int32 scalar prefetch
    q_ref,  # (1, W*G, Dh)
    k_ref,  # (1, 1, block_t, Dh)
    kpos_ref,  # (1, block_t) int32
    o_ref,  # (1, 1, block_t) f32
    m_ref,  # (W*G, 1) f32
    l_ref,  # (W*G, 1) f32
    *,
    block_t: int,
    n_blocks: int,
    g: int,
    scale: float,
    attn_cap: float,
):
    b, h, c = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    def scores_and_mask(blk_idx):
        q = q_ref[0].astype(jnp.float32)  # (W*G, Dh)
        k = k_ref[0, 0].astype(jnp.float32)  # (blk, Dh)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (W*G, blk)
        if attn_cap > 0:
            s = attn_cap * jnp.tanh(s / attn_cap)
        kp = kpos_ref[0]  # (blk,)
        wg = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // g  # query idx
        qp = obs_pos_ref[b]  # (W,) — gather per row
        qp_row = qp[wg[:, 0]][:, None] if False else jnp.take(qp, wg[:, 0])[:, None]
        causal = kp[None, :] <= qp_row
        return jnp.where(causal, s, NEG_INF), causal

    @pl.when(c < n_blocks)
    def _phase_lse():
        s, causal = scores_and_mask(c)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.where(causal, jnp.exp(s - m_new), 0.0)
        l_ref[...] = l_ref[...] * jnp.exp(m_prev - m_new) + p.sum(
            axis=1, keepdims=True)
        m_ref[...] = m_new

    @pl.when(c >= n_blocks)
    def _phase_emit():
        s, causal = scores_and_mask(c - n_blocks)
        m = m_ref[...]
        l = l_ref[...]
        p = jnp.where(causal, jnp.exp(s - m), 0.0) / jnp.where(l > 0, l, 1.0)
        o_ref[0, 0] = p.sum(axis=0).astype(o_ref.dtype)


def snapkv_scores_pallas(
    q_obs: jnp.ndarray,  # (B, W, Hq, Dh)
    k: jnp.ndarray,  # (B, T, Hkv, Dh)
    obs_positions: jnp.ndarray,  # (B, W) int32
    k_positions: jnp.ndarray,  # (B, T) int32
    attn_cap: float = 0.0,
    block_t: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    B, W, Hq, Dh = q_obs.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    block_t = min(block_t, T)
    n_blocks = pl.cdiv(T, block_t)
    if T % block_t != 0:
        pad = n_blocks * block_t - T
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_positions = jnp.pad(k_positions, ((0, 0), (0, pad)),
                              constant_values=jnp.iinfo(jnp.int32).max)
    # (B, Hkv, W*G, Dh) query tile per (b, h)
    qt = q_obs.reshape(B, W, Hkv, G, Dh).transpose(0, 2, 1, 3, 4).reshape(
        B, Hkv, W * G, Dh)

    def q_map(b, h, c, opos):
        return (b * Hkv + h, 0, 0)

    def k_map(b, h, c, opos):
        cc = jnp.where(c < n_blocks, c, c - n_blocks)
        return (b, h, cc, 0)

    def kpos_map(b, h, c, opos):
        cc = jnp.where(c < n_blocks, c, c - n_blocks)
        return (b, cc)

    def o_map(b, h, c, opos):
        cc = jnp.maximum(c - n_blocks, 0)
        return (b, h, cc)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hkv, 2 * n_blocks),
        in_specs=[
            pl.BlockSpec((1, W * G, Dh), q_map),
            pl.BlockSpec((1, 1, block_t, Dh), k_map),
            pl.BlockSpec((1, block_t), kpos_map),
        ],
        out_specs=pl.BlockSpec((1, 1, block_t), o_map),
        scratch_shapes=[
            pltpu.VMEM((W * G, 1), jnp.float32),
            pltpu.VMEM((W * G, 1), jnp.float32),
        ],
    )
    kernel = functools.partial(
        _kernel, block_t=block_t, n_blocks=n_blocks, g=G,
        scale=1.0 / math.sqrt(Dh), attn_cap=attn_cap)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, n_blocks * block_t),
                                       jnp.float32),
        interpret=interpret,
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(obs_positions, qt.reshape(B * Hkv, W * G, Dh),
      k.transpose(0, 2, 1, 3), k_positions)
    return out[:, :, :T]
