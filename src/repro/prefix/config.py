"""`PrefixConfig`: knobs for shared-prefix reuse + chunked prefill.

One frozen dataclass, carried on `EngineConfig.prefix` and threaded into the
scheduler. ``enabled`` turns on the content-addressed prefix index (block
sharing across requests, DESIGN.md §14); ``chunk_tokens`` > 0 turns on
chunked prefill (prompts processed ``chunk_tokens`` at a time, interleaved
with decode ticks). The two compose but are independent — chunked prefill
works on any backend/executor, while block *sharing* additionally requires
the paged backend with an unpartitioned pool (§14 explains why).
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class PrefixConfig:
    """Prefix-cache + chunked-prefill configuration.

    enabled        — content-addressed prefix index: prompt-prefix blocks of
                     earlier requests are shared (refcounted) with later
                     requests whose prompts start with the same tokens.
                     Requires ``chunk_tokens > 0`` (hash-chain granularity
                     is the chunk) and the paged cache backend.
    chunk_tokens   — split prompt prefill into fixed chunks of this many
                     tokens, interleaved with decode ticks; 0 = monolithic
                     prefill (the pre-PR-8 behavior).
    max_entries    — LRU capacity of the prefix index (unpinned entries are
                     evicted beyond this, and on demand under pool pressure).
    """

    enabled: bool = False
    chunk_tokens: int = 0
    max_entries: int = 256

    def __post_init__(self):
        if self.chunk_tokens < 0:
            raise ValueError(
                f"chunk_tokens must be >= 0, got {self.chunk_tokens}")
        if self.enabled and self.chunk_tokens <= 0:
            raise ValueError(
                "prefix sharing requires chunked prefill: set chunk_tokens "
                "> 0 (the hash-chain is computed at chunk granularity)")
        if self.max_entries < 1:
            raise ValueError(
                f"max_entries must be >= 1, got {self.max_entries}")
