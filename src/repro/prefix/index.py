"""Content-addressed index over prompt-prefix blocks (DESIGN.md §14).

The index maps *prefixes of token-id sequences* to the pool blocks that
already hold their compressed KV entries.  Keys are a hash chain at chunk
granularity: ``h_j = sha256(h_{j-1} || tokens[j·c:(j+1)·c])`` — so the key
for a boundary commits to every token before it, and two prompts share an
entry iff they are byte-identical up to that boundary.

Entries are registered after a chunked prefill finishes (the donor's blocks
are final for the prefix range by then) and hold **one pool reference per
block** of their own, so the entry stays valid after the donor request
retires.  A hit bumps the refcounts again for the matching request; the
copy-on-write rule in the paged backend (refcount>1 blocks are immutable)
keeps every holder's view bit-identical.

Eviction is LRU over unpinned entries — both to bound the index
(``max_entries``) and on demand when the scheduler sees ``PoolExhausted``
(blocks pinned only by the index are the cheapest memory to reclaim).
Entries are *pinned* while a chunked prefill is actively reading from them.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs import NULL_OBS


@dataclass
class PrefixEntry:
    """Blocks + per-head retained lengths for one prompt-prefix boundary.

    ``table`` is (L, H, M) global block ids (0-padded) and ``lengths`` is
    (L, H) retained entries per kv head — *head*-indexed, not slot-indexed,
    because the slot that owns head ``h`` differs per row under replicated
    plans; the scheduler maps head -> slot for the concrete row at seed /
    register time.  The entry owns one pool reference per nonzero id.
    """

    key: bytes
    tokens: int                 # prefix length in tokens (chunk multiple)
    table: np.ndarray           # (L, H, M) int32 global block ids
    lengths: np.ndarray         # (L, H) int32 retained entries per head
    pins: int = 0

    def block_count(self) -> int:
        return int((self.table > 0).sum())


class PrefixIndex:
    """Hash-chained longest-prefix lookup with LRU eviction and pins.

    The index does not touch the pool itself except to incref at
    registration and decref at eviction; sharing refs for *matching*
    requests are taken by the paged backend's splice (symmetric with the
    decref in ``release_rows``).
    """

    def __init__(self, chunk_tokens: int, max_entries: int = 256, obs=None):
        if chunk_tokens < 1:
            raise ValueError(
                f"chunk_tokens must be >= 1, got {chunk_tokens}")
        self.chunk_tokens = int(chunk_tokens)
        self.max_entries = int(max_entries)
        self.obs = obs or NULL_OBS
        self.pool = None  # set by the owning scheduler (backend.pool)
        self._entries: "OrderedDict[bytes, PrefixEntry]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    # ---- hashing -----------------------------------------------------------

    def chain_keys(self, prompt: Sequence[int]) -> List[Tuple[int, bytes]]:
        """[(t_j, key_j)] for every *full* chunk boundary of ``prompt``."""
        toks = np.asarray(prompt, np.int32)
        c = self.chunk_tokens
        out: List[Tuple[int, bytes]] = []
        h = hashlib.sha256(b"repro.prefix.v1")
        for j in range(len(toks) // c):
            h = h.copy()
            h.update(toks[j * c:(j + 1) * c].tobytes())
            out.append(((j + 1) * c, h.digest()))
        return out

    # ---- lookup / registration ---------------------------------------------

    def lookup(self, prompt: Sequence[int]) -> Optional[PrefixEntry]:
        """Longest indexed boundary *strictly shorter* than the prompt.

        Strict so at least one chunk is always recomputed — the request
        needs fresh logits for its first sampled token.  All boundary keys
        are checked (not first-miss-stops): LRU eviction can remove a middle
        boundary while a longer one survives.
        """
        best: Optional[PrefixEntry] = None
        for t_j, key in self.chain_keys(prompt):
            if t_j >= len(prompt):
                break
            hit = self._entries.get(key)
            if hit is not None:
                best = hit
        if best is None:
            self.misses += 1
            self.obs.metrics.counter(
                "prefix_misses_total",
                help="prefix-index lookups with no usable boundary").inc()
            return None
        self._entries.move_to_end(best.key)
        self.hits += 1
        self.obs.metrics.counter(
            "prefix_hits_total",
            help="prefix-index lookups that matched a shared prefix").inc()
        return best

    def register(self, key: bytes, tokens: int, table: np.ndarray,
                 lengths: np.ndarray) -> bool:
        """Adopt one boundary's blocks into the index (increfs them).

        Returns False (and increfs nothing) if the key is already present —
        the existing entry is refreshed in LRU order instead.
        """
        if key in self._entries:
            self._entries.move_to_end(key)
            return False
        table = np.ascontiguousarray(table, np.int32)
        entry = PrefixEntry(key=key, tokens=int(tokens), table=table,
                            lengths=np.asarray(lengths, np.int32))
        for l in range(table.shape[0]):
            ids = table[l].reshape(-1)
            ids = ids[ids > 0]
            if ids.size:
                self.pool.incref(l, ids.tolist())
        self._entries[key] = entry
        while len(self._entries) > self.max_entries:
            if not self.evict_lru():
                break  # everything pinned; stay oversize until unpinned
        return True

    # ---- pinning / eviction ------------------------------------------------

    def pin(self, entry: PrefixEntry) -> None:
        entry.pins += 1

    def unpin(self, entry: PrefixEntry) -> None:
        if entry.pins <= 0:
            raise ValueError(f"unpin of unpinned entry {entry.key.hex()[:12]}")
        entry.pins -= 1

    def evict_lru(self) -> bool:
        """Drop the least-recently-used *unpinned* entry; False if none."""
        victim = next((e for e in self._entries.values() if e.pins == 0),
                      None)
        if victim is None:
            return False
        del self._entries[victim.key]
        for l in range(victim.table.shape[0]):
            ids = victim.table[l].reshape(-1)
            ids = ids[ids > 0]
            if ids.size:
                self.pool.decref(l, ids.tolist())
        self.evictions += 1
        self.obs.metrics.counter(
            "prefix_evictions_total",
            help="prefix entries dropped by LRU / pool pressure").inc()
        return True

    def flush(self, decref: bool = True) -> None:
        """Drop every entry.  ``decref=False`` after an accepted migration:
        the backend rebuilt its pool from live tables only, so the old
        references died with the old pool and must not be returned twice."""
        if decref:
            while self._entries:
                if not self.evict_lru():
                    raise RuntimeError(
                        "flush with pinned prefix entries still live")
        self._entries.clear()

    # ---- stats -------------------------------------------------------------

    def __len__(self) -> int:
        return len(self._entries)

    def stats(self) -> Dict[str, int]:
        return {
            "entries": len(self._entries),
            "pinned": sum(1 for e in self._entries.values() if e.pins > 0),
            "blocks_held": sum(e.block_count()
                               for e in self._entries.values()),
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
        }
