"""repro.prefix: shared-prefix block reuse + chunked prefill (DESIGN.md §14)."""
from repro.prefix.config import PrefixConfig
from repro.prefix.index import PrefixEntry, PrefixIndex

__all__ = ["PrefixConfig", "PrefixEntry", "PrefixIndex"]
