"""Mamba-2 SSD (state-space duality) block — chunked scan + decode step.

Follows arXiv:2405.21060 §6 (the chunked/blocked SSD algorithm):
within-chunk outputs use the quadratic dual form, cross-chunk information
flows through the (H, P, N) state carried by a sequential ``lax.scan`` over
chunks.  B/C are shared across heads (n_groups=1, the paper's default —
"multi-value attention" analog of MQA).

Shapes: x (B, T, H, P), dt (B, T, H), B/C (B, T, G, N), A_log (H,), D (H,).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax


def ssd_chunked(
    x: jnp.ndarray,  # (B, T, H, P)
    dt: jnp.ndarray,  # (B, T, H) — post-softplus
    A_log: jnp.ndarray,  # (H,)
    B_: jnp.ndarray,  # (B, T, G, N)
    C_: jnp.ndarray,  # (B, T, G, N)
    D_: jnp.ndarray,  # (H,)
    chunk: int = 256,
    init_state: Optional[jnp.ndarray] = None,  # (B, H, P, N)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y (B,T,H,P), final_state (B,H,P,N))."""
    Bsz, T, H, P = x.shape
    G, N = B_.shape[2], B_.shape[3]
    assert H % G == 0
    rep = H // G
    if T % chunk != 0:
        pad = chunk - T % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Tp = x.shape[1]
    nc = Tp // chunk
    A = -jnp.exp(A_log.astype(jnp.float32))  # (H,) negative decay rates

    # chunked views: (B, nc, Q, ...)
    xc = x.reshape(Bsz, nc, chunk, H, P).astype(jnp.float32)
    dtc = dt.reshape(Bsz, nc, chunk, H).astype(jnp.float32)
    Bc = B_.reshape(Bsz, nc, chunk, G, N).astype(jnp.float32)
    Cc = C_.reshape(Bsz, nc, chunk, G, N).astype(jnp.float32)

    dA = dtc * A  # (B, nc, Q, H) log-decay per step
    cum = jnp.cumsum(dA, axis=2)  # inclusive cumulative log decay

    # group-expanded B/C (G is 1 in all assigned configs; expanding is free)
    Bh = jnp.repeat(Bc, rep, axis=3)  # (B, nc, Q, H, N)
    Ch = jnp.repeat(Cc, rep, axis=3)  # (B, nc, Q, H, N)

    # within-chunk (dual quadratic) term:
    #   L[i, j] = exp(cum_i - cum_j) for j <= i  (segment decay)
    #   y_intra[i] = Σ_j (C_i·B_j) L[i,j] dt_j x_j
    def intra_chunk(xq, dtq, bq, cq, cumq):
        # all (B, Q, H, ...)
        s = jnp.einsum("bihN,bjhN->bhij", cq, bq)  # (B, H, Q, Q)
        seg = cumq[:, :, None, :] - cumq[:, None, :, :]  # (B, i, j, H)
        seg = jnp.transpose(seg, (0, 3, 1, 2))
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        w = s * jnp.where(mask, jnp.exp(seg), 0.0)
        return jnp.einsum("bhij,bjh,bjhp->bihp", w, dtq, xq)

    # cross-chunk state recurrence (sequential scan over chunks):
    #   S_c = S_{c-1}·exp(Σ dA) + Σ_j B_j (dt_j x_j) exp(Σ - cum_j)
    #   y_inter[i] = (C_i · S_{c-1}) exp(cum_i)
    def scan_body(S, args):
        xq, dtq, bq, cq, cumq = args  # (B, Q, H, ...) / cumq (B, Q, H)
        y_inter = jnp.einsum("bihN,bhpN,bih->bihp", cq, S, jnp.exp(cumq))
        total = jnp.exp(cumq[:, -1, :])  # (B, H)
        contrib = jnp.einsum("bjhN,bjh,bjhp,bjh->bhpN", bq, dtq, xq,
                             jnp.exp(cumq[:, -1:, :] - cumq))
        S_new = S * total[:, :, None, None] + contrib
        return S_new, y_inter

    args = (
        jnp.moveaxis(xc, 1, 0), jnp.moveaxis(dtc, 1, 0),
        jnp.moveaxis(Bh, 1, 0), jnp.moveaxis(Ch, 1, 0),
        jnp.moveaxis(cum, 1, 0),
    )
    S0 = (jnp.zeros((Bsz, H, P, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))
    S_final, y_inter = lax.scan(scan_body, S0, args)
    y_inter = jnp.moveaxis(y_inter, 0, 1)  # (B, nc, Q, H, P)
    y_intra = jax.vmap(intra_chunk, in_axes=(1, 1, 1, 1, 1), out_axes=1)(
        xc, dtc, Bh, Ch, cum)
    y = (y_intra + y_inter).reshape(Bsz, Tp, H, P)
    y = y + x.reshape(Bsz, Tp, H, P).astype(jnp.float32) * D_[None, None, :, None]
    return y[:, :T].astype(x.dtype), S_final


def ssd_decode_step(
    x: jnp.ndarray,  # (B, H, P) one token
    dt: jnp.ndarray,  # (B, H) post-softplus
    A_log: jnp.ndarray,  # (H,)
    B_: jnp.ndarray,  # (B, G, N)
    C_: jnp.ndarray,  # (B, G, N)
    D_: jnp.ndarray,  # (H,)
    state: jnp.ndarray,  # (B, H, P, N) fp32
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Single-token recurrent update: O(H·P·N) per row."""
    Bsz, H, P = x.shape
    G, N = B_.shape[1], B_.shape[2]
    rep = H // G
    A = -jnp.exp(A_log.astype(jnp.float32))
    dA = jnp.exp(dt.astype(jnp.float32) * A)  # (B, H)
    Bh = jnp.repeat(B_, rep, axis=1).astype(jnp.float32)  # (B, H, N)
    Ch = jnp.repeat(C_, rep, axis=1).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    S_new = state * dA[..., None, None] + jnp.einsum(
        "bhN,bh,bhp->bhpN", Bh, dt.astype(jnp.float32), xf)
    y = jnp.einsum("bhN,bhpN->bhp", Ch, S_new)
    y = y + xf * D_[None, :, None]
    return y.astype(x.dtype), S_new


def conv1d_causal(x: jnp.ndarray, w: jnp.ndarray,
                  state: Optional[jnp.ndarray] = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv over (B, T, C) with kernel (W, C).

    Returns (y, new_state) where state is the last W-1 inputs.
    """
    W = w.shape[0]
    Bsz, T, Cd = x.shape
    if state is None:
        state = jnp.zeros((Bsz, W - 1, Cd), x.dtype)
    xx = jnp.concatenate([state, x], axis=1)  # (B, T + W - 1, C)
    idx = jnp.arange(T)[:, None] + jnp.arange(W)[None, :]  # (T, W)
    windows = xx[:, idx, :]  # (B, T, W, C)
    y = jnp.einsum("btwc,wc->btc", windows.astype(jnp.float32),
                   w.astype(jnp.float32))
    new_state = xx[:, -(W - 1):, :] if W > 1 else jnp.zeros((Bsz, 0, Cd), x.dtype)
    return y.astype(x.dtype), new_state
