"""Mixture-of-Experts FFN: top-k routing + sort-based grouped GEMM.

Dispatch is the capacity-bounded sorted-scatter pattern (jit-friendly, no
(T, E, C) one-hot): sort token-replicas by expert id, gather each expert's
contiguous range into a (E, C, D) block, batched-einsum through the expert
weights, weighted segment-sum back to tokens.  Tokens beyond an expert's
capacity are dropped (standard Switch/GShard semantics; capacity_factor
bounds the imbalance).

The expert dimension shards over the "expert" logical axis (→ model axis);
XLA inserts the dispatch collectives.  ``expert_plan`` optionally applies the
FairKV planner to *experts* (replicate hot experts — the paper's §6 future
work, implemented here as a beyond-paper extension).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain


def moe_block(
    pl: dict,
    h: jnp.ndarray,  # (B, S, D)
    cfg: ModelConfig,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (out (B,S,D), aux_loss scalar)."""
    B, S, D = h.shape
    E, K = cfg.moe.num_experts, cfg.moe.top_k
    capacity_factor = cfg.moe.capacity_factor
    T = B * S
    x = h.reshape(T, D)
    logits = (x @ pl["router"]).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, top_idx = jax.lax.top_k(probs, K)  # (T, K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    flat_e = top_idx.reshape(-1).astype(jnp.int32)  # (T*K,)
    flat_t = (jnp.arange(T * K, dtype=jnp.int32) // K)
    flat_w = gate.reshape(-1)

    order = jnp.argsort(flat_e, stable=True)  # (T*K,)
    counts = jnp.bincount(flat_e, length=E)  # (E,)
    starts = jnp.concatenate([jnp.zeros(1, counts.dtype), jnp.cumsum(counts)[:-1]])

    Ce = int(max(K, round(T * K / E * capacity_factor)))
    Ce = min(Ce, T * K)
    pos = starts[:, None] + jnp.arange(Ce, dtype=jnp.int32)[None, :]  # (E, Ce)
    valid = jnp.arange(Ce)[None, :] < counts[:, None]
    pos = jnp.clip(pos, 0, T * K - 1)
    src = jnp.take(order, pos)  # (E, Ce) flat-replica ids
    tok = jnp.take(flat_t, src)  # (E, Ce) token ids
    wgt = jnp.take(flat_w, src) * valid  # (E, Ce)

    from repro.serving.quant import deq
    xg = jnp.take(x, tok, axis=0)  # (E, Ce, D)
    xg = constrain(xg, "expert", None, None)
    h1 = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xg, deq(pl["we1"])))
    h1 = h1 * jnp.einsum("ecd,edf->ecf", xg, deq(pl["we3"]))
    y = jnp.einsum("ecf,efd->ecd", h1, deq(pl["we2"]))  # (E, Ce, D)
    y = y * wgt[..., None].astype(y.dtype)

    seg = jnp.where(valid, tok, T).reshape(-1)  # dropped -> dummy segment
    out = jax.ops.segment_sum(y.reshape(E * Ce, D), seg, num_segments=T + 1)[:T]

    # Switch load-balancing loss: E · Σ_e f_e · p̄_e
    f = jnp.bincount(top_idx[:, 0], length=E) / T  # top-1 dispatch fraction
    pbar = probs.mean(axis=0)
    aux = E * jnp.sum(f * pbar)
    return out.reshape(B, S, D).astype(h.dtype), aux.astype(jnp.float32)


def init_moe_params(rng: jax.Array, cfg: ModelConfig, dtype) -> dict:
    E, D, Fe = cfg.moe.num_experts, cfg.d_model, cfg.moe.d_expert
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    s_in = 1.0 / jnp.sqrt(D)
    s_out = 1.0 / jnp.sqrt(Fe)
    return {
        "router": (jax.random.normal(k1, (D, E)) * s_in).astype(dtype),
        "we1": (jax.random.normal(k2, (E, D, Fe)) * s_in).astype(dtype),
        "we3": (jax.random.normal(k3, (E, D, Fe)) * s_in).astype(dtype),
        "we2": (jax.random.normal(k4, (E, Fe, D)) * s_out).astype(dtype),
    }
