"""Pure-JAX model zoo for the 10 assigned architectures."""
from repro.models.transformer import (  # noqa: F401
    embed_inputs,
    encode,
    encoder_cross_kv,
    forward_train,
    init_params,
    param_count,
)
