"""Model zoo: parameter init + block functions + training forward.

One parameterization covers all 10 assigned archs; family-specific pieces
(MoE FFN, SSD branch, cross-attention, stub frontends) are toggled by the
``ModelConfig``.  All forwards are pure functions of (params, batch).

Layer loop is an unrolled Python loop: compile times are fine up to 80
layers (measured), and unrolled HLO makes the dry-run cost analysis exact
(DESIGN.md §5).  Training wraps each layer in ``jax.checkpoint`` (remat).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import layers as L
from repro.models import ssm as S
from repro.models.moe import init_moe_params, moe_block

GLOBAL_WINDOW = 0  # sentinel: no sliding window


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _dense_attn_params(rng, cfg: ModelConfig, dtype) -> dict:
    D, Hq, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(rng, 4)
    s = 1.0 / math.sqrt(D)
    p = {
        "wq": (jax.random.normal(ks[0], (D, Hq, Dh)) * s).astype(dtype),
        "wk": (jax.random.normal(ks[1], (D, Hkv, Dh)) * s).astype(dtype),
        "wv": (jax.random.normal(ks[2], (D, Hkv, Dh)) * s).astype(dtype),
        "wo": (jax.random.normal(ks[3], (Hq, Dh, D)) * (1.0 / math.sqrt(Hq * Dh))).astype(dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((Hq, Dh), dtype)
        p["bk"] = jnp.zeros((Hkv, Dh), dtype)
        p["bv"] = jnp.zeros((Hkv, Dh), dtype)
    return p


def _mlp_params(rng, D: int, F: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(rng, 3)
    return {
        "w1": (jax.random.normal(k1, (D, F)) / math.sqrt(D)).astype(dtype),
        "w3": (jax.random.normal(k2, (D, F)) / math.sqrt(D)).astype(dtype),
        "w2": (jax.random.normal(k3, (F, D)) / math.sqrt(F)).astype(dtype),
    }


def _ssm_params(rng, cfg: ModelConfig, dtype) -> dict:
    s = cfg.ssm
    D = cfg.d_model
    d_in = s.d_inner
    proj = 2 * d_in + 2 * s.n_groups * s.state_size + s.num_heads
    conv_dim = d_in + 2 * s.n_groups * s.state_size
    k1, k2, k3 = jax.random.split(rng, 3)
    return {
        "in_proj": (jax.random.normal(k1, (D, proj)) / math.sqrt(D)).astype(dtype),
        "conv_w": (jax.random.normal(k2, (s.conv_width, conv_dim)) * 0.1).astype(dtype),
        "A_log": jnp.zeros((s.num_heads,), jnp.float32),
        "ssm_D": jnp.ones((s.num_heads,), jnp.float32),
        "dt_bias": jnp.zeros((s.num_heads,), jnp.float32),
        "ssm_norm": jnp.zeros((d_in,), dtype),
        "out_proj": (jax.random.normal(k3, (d_in, D)) / math.sqrt(d_in)).astype(dtype),
    }


def _layer_params(rng, cfg: ModelConfig, dtype, cross_attn: bool = False) -> dict:
    D = cfg.d_model
    keys = jax.random.split(rng, 8)
    p: dict = {"ln1": jnp.zeros((D,), dtype), "ln2": jnp.zeros((D,), dtype)}
    if not cfg.attention_free:
        p.update(_dense_attn_params(keys[0], cfg, dtype))
    if cfg.family in ("ssm", "hybrid"):
        p.update(_ssm_params(keys[1], cfg, dtype))
    if cfg.family == "hybrid":
        # per-branch output norms (Hymba fuses mean of normed branches)
        p["attn_out_norm"] = jnp.zeros((cfg.n_heads * cfg.head_dim,), dtype)
        p["ssm_out_norm"] = jnp.zeros((cfg.ssm.d_inner,), dtype)
    if cfg.moe.num_experts > 0:
        p.update(init_moe_params(keys[2], cfg, dtype))
    elif cfg.d_ff > 0:
        p.update(_mlp_params(keys[3], D, cfg.d_ff, dtype))
    if cross_attn:
        ca = _dense_attn_params(keys[4], cfg, dtype)
        p.update({f"c_{k}": v for k, v in ca.items()})
        p["ln_cross"] = jnp.zeros((D,), dtype)
    return p


def init_params(cfg: ModelConfig, rng: jax.Array, dtype=jnp.bfloat16,
                max_seq_len: int = 4096) -> dict:
    """Original-layout parameters (heads unpermuted)."""
    keys = jax.random.split(rng, cfg.n_layers + cfg.n_encoder_layers + 4)
    D = cfg.d_model
    params: dict = {
        "embed": (jax.random.normal(keys[0], (cfg.padded_vocab, D)) * 0.02).astype(dtype),
        "final_norm": jnp.zeros((D,), dtype),
        "layers": [
            _layer_params(keys[2 + i], cfg, dtype,
                          cross_attn=cfg.is_encoder_decoder)
            for i in range(cfg.n_layers)
        ],
    }
    if not cfg.tie_embeddings:
        params["head"] = (jax.random.normal(keys[1], (cfg.padded_vocab, D)) * 0.02).astype(dtype)
    if cfg.is_encoder_decoder:
        base = 2 + cfg.n_layers
        params["enc_layers"] = [
            _layer_params(keys[base + i], cfg, dtype)
            for i in range(cfg.n_encoder_layers)
        ]
        params["enc_final_norm"] = jnp.zeros((D,), dtype)
        params["enc_pos"] = (jax.random.normal(
            keys[-1], (cfg.encoder_seq_len, D)) * 0.02).astype(dtype)
        params["dec_pos"] = (jax.random.normal(
            keys[-2], (max_seq_len, D)) * 0.02).astype(dtype)
    return params


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


def draft_view(params: dict, n_layers: int) -> dict:
    """Layer-truncated draft model for self-speculative decoding.

    The draft is an *early exit* of the target: its first ``n_layers``
    transformer blocks followed by the target's own final norm and
    unembedding.  The returned dict shares every array with ``params`` —
    no copies, no extra memory — so it works on original-layout and
    slotified (serve-layout) params alike, and a replan that re-slotifies
    the target automatically refreshes the draft (the propose step
    re-slices).  Because the draft runs the target's leading layers over
    the target's own cache, its KV writes are *real* target KV for those
    layers — verify fills only the remaining layers (DESIGN.md §16).
    """
    if not 0 < n_layers <= len(params["layers"]):
        raise ValueError(
            f"draft n_layers must be in [1, {len(params['layers'])}], "
            f"got {n_layers}")
    out = dict(params)
    out["layers"] = list(params["layers"])[:n_layers]
    return out


# ---------------------------------------------------------------------------
# Blocks (shared by train / prefill)
# ---------------------------------------------------------------------------


def layer_window(cfg: ModelConfig, layer_idx: int) -> int:
    return cfg.sliding_window if cfg.layer_is_local(layer_idx) else GLOBAL_WINDOW


def qkv_proj(pl: dict, h: jnp.ndarray, cfg: ModelConfig, prefix: str = ""):
    """(B, T, D) → q (B,T,Hq,Dh), k/v (B,T,Hkv,Dh), pre-RoPE."""
    from repro.serving.quant import deq
    q = jnp.einsum("btd,dhx->bthx", h, deq(pl[prefix + "wq"]))
    k = jnp.einsum("btd,dhx->bthx", h, deq(pl[prefix + "wk"]))
    v = jnp.einsum("btd,dhx->bthx", h, deq(pl[prefix + "wv"]))
    if cfg.qkv_bias and (prefix + "bq") in pl:
        q = q + pl[prefix + "bq"]
        k = k + pl[prefix + "bk"]
        v = v + pl[prefix + "bv"]
    return q, k, v


def attn_block_full(
    pl: dict,
    h: jnp.ndarray,  # (B, T, D) normed input
    positions: jnp.ndarray,  # (B, T)
    cfg: ModelConfig,
    layer_idx: int,
    kv_mask: Optional[jnp.ndarray] = None,
    return_kv: bool = False,
):
    """Full-sequence causal attention (train / prefill).  Returns
    (attn_out_flat (B,T,Hq*Dh), (k_rot, v) if return_kv)."""
    q, k, v = qkv_proj(pl, h, cfg)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    # sequence-parallel attention: scores shard over the query dim, so head
    # counts need not divide the mesh (hymba's 25 heads, whisper's 12)
    q = constrain(q, "batch", "seq_act", None, None)
    k = constrain(k, "batch", None, None, None)
    v = constrain(v, "batch", None, None, None)
    out = L.attention(
        q, k, v, positions, positions,
        window=layer_window(cfg, layer_idx),
        attn_cap=cfg.attn_softcap, kv_mask=kv_mask, causal=True)
    B, T = h.shape[:2]
    out = out.reshape(B, T, cfg.n_heads * cfg.head_dim)
    return (out, (k, v)) if return_kv else (out, None)


def cross_attn_block(pl: dict, h: jnp.ndarray, enc_kv: Tuple[jnp.ndarray, jnp.ndarray],
                     cfg: ModelConfig) -> jnp.ndarray:
    """Decoder cross-attention onto precomputed encoder K/V (no RoPE)."""
    from repro.serving.quant import deq
    B, T, D = h.shape
    q = jnp.einsum("btd,dhx->bthx", h, deq(pl["c_wq"]))
    k, v = enc_kv
    T_enc = k.shape[1]
    pos_q = jnp.zeros((B, T), jnp.int32)
    pos_k = jnp.zeros((B, T_enc), jnp.int32)
    out = L.attention(q, k, v, pos_q, pos_k, causal=False)
    out = out.reshape(B, T, cfg.n_heads * cfg.head_dim)
    return jnp.einsum("bte,ed->btd",
                      out, deq(pl["c_wo"]).reshape(cfg.n_heads * cfg.head_dim, D))


def o_proj(pl: dict, attn_flat: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    from repro.serving.quant import deq
    D = cfg.d_model
    wo = deq(pl["wo"]).reshape(cfg.n_heads * cfg.head_dim, D)
    return jnp.einsum("bte,ed->btd", attn_flat, wo)


def mlp_block(pl: dict, h: jnp.ndarray, cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if cfg.moe.num_experts > 0:
        return moe_block(pl, h, cfg)
    return L.swiglu(h, pl["w1"], pl["w3"], pl["w2"]), jnp.float32(0.0)


def ssm_split(pl: dict, h: jnp.ndarray, cfg: ModelConfig):
    """in_proj → (z, x_conv_input, B, C, dt) with shapes per SSD convention."""
    s = cfg.ssm
    d_in, G, N, H = s.d_inner, s.n_groups, s.state_size, s.num_heads
    from repro.serving.quant import deq
    proj = h @ deq(pl["in_proj"])  # (B, T, 2*d_in + 2*G*N + H)
    z, xBC, dt_raw = jnp.split(proj, [d_in, d_in + d_in + 2 * G * N], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + pl["dt_bias"])  # (B,T,H)
    return z, xBC, dt


def ssm_block_full(pl: dict, h: jnp.ndarray, cfg: ModelConfig,
                   conv_state: Optional[jnp.ndarray] = None,
                   init_state: Optional[jnp.ndarray] = None,
                   return_state: bool = False):
    """Full-sequence SSD branch.  Returns (out (B,T,D), (conv_state, ssm_state))."""
    s = cfg.ssm
    d_in, G, N, H, P = s.d_inner, s.n_groups, s.state_size, s.num_heads, s.head_dim
    B, T, _ = h.shape
    z, xBC, dt = ssm_split(pl, h, cfg)
    xBC, conv_out_state = S.conv1d_causal(xBC, pl["conv_w"], conv_state)
    xBC = jax.nn.silu(xBC)
    x, B_, C_ = jnp.split(xBC, [d_in, d_in + G * N], axis=-1)
    x = x.reshape(B, T, H, P)
    B_ = B_.reshape(B, T, G, N)
    C_ = C_.reshape(B, T, G, N)
    y, state = S.ssd_chunked(x, dt, pl["A_log"], B_, C_, pl["ssm_D"],
                             chunk=s.chunk_size, init_state=init_state)
    y = y.reshape(B, T, d_in)
    from repro.serving.quant import deq as _deq
    y = L.rms_norm(y * jax.nn.silu(z), pl["ssm_norm"])  # gated norm
    out = y @ _deq(pl["out_proj"])
    if return_state:
        return out, (conv_out_state, state)
    return out, None


# ---------------------------------------------------------------------------
# Whole-layer application (training / prefill structure)
# ---------------------------------------------------------------------------


def apply_layer_full(
    pl: dict,
    h: jnp.ndarray,
    positions: jnp.ndarray,
    cfg: ModelConfig,
    layer_idx: int,
    enc_kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
    kv_mask: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One decoder layer, full-sequence.  Returns (h, aux_loss)."""
    aux = jnp.float32(0.0)
    hn = L.rms_norm(h, pl["ln1"], cfg.rms_eps)
    # SP -> TP transition: gather seq shards before head/ff-parallel compute
    hn = constrain(hn, "batch", None, None)
    if cfg.family == "hybrid":
        attn_flat, _ = attn_block_full(pl, hn, positions, cfg, layer_idx, kv_mask)
        attn_out = o_proj(pl, L.rms_norm(attn_flat, pl["attn_out_norm"], cfg.rms_eps), cfg)
        ssm_out, _ = ssm_block_full(pl, hn, cfg)
        h = h + 0.5 * (attn_out + ssm_out)
    elif cfg.family == "ssm":
        ssm_out, _ = ssm_block_full(pl, hn, cfg)
        h = h + ssm_out
    elif not cfg.attention_free:
        attn_flat, _ = attn_block_full(pl, hn, positions, cfg, layer_idx, kv_mask)
        h = h + o_proj(pl, attn_flat, cfg)
    if enc_kv is not None:
        hc = L.rms_norm(h, pl["ln_cross"], cfg.rms_eps)
        h = h + cross_attn_block(pl, hc, enc_kv, cfg)
    if cfg.d_ff > 0 or cfg.moe.num_experts > 0:
        hn2 = L.rms_norm(h, pl["ln2"], cfg.rms_eps)
        hn2 = constrain(hn2, "batch", None, None)
        mlp_out, aux = mlp_block(pl, hn2, cfg)
        h = h + mlp_out
    # TP -> SP transition: the stored residual boundary is seq-sharded
    h = constrain(h, "batch", "seq_act", "d_model")
    return h, aux


def encode(params: dict, frames: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Whisper encoder over stub-frontend frame embeddings (B, T_enc, D)."""
    h = frames + params["enc_pos"][None, : frames.shape[1]]
    B, T = h.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(T), (B, T))
    for i, pl in enumerate(params["enc_layers"]):
        hn = L.rms_norm(h, pl["ln1"], cfg.rms_eps)
        q, k, v = qkv_proj(pl, hn, cfg)
        out = L.attention(q, k, v, positions, positions, causal=False)
        h = h + o_proj(pl, out.reshape(B, T, -1), cfg)
        hn2 = L.rms_norm(h, pl["ln2"], cfg.rms_eps)
        h = h + L.swiglu(hn2, pl["w1"], pl["w3"], pl["w2"])
    return L.rms_norm(h, params["enc_final_norm"], cfg.rms_eps)


def encoder_cross_kv(params: dict, enc_out: jnp.ndarray, cfg: ModelConfig):
    """Per-decoder-layer cross K/V from encoder output."""
    kvs = []
    for pl in params["layers"]:
        from repro.serving.quant import deq
        k = jnp.einsum("btd,dhx->bthx", enc_out, deq(pl["c_wk"]))
        v = jnp.einsum("btd,dhx->bthx", enc_out, deq(pl["c_wv"]))
        if cfg.qkv_bias and "c_bk" in pl:
            k, v = k + pl["c_bk"], v + pl["c_bv"]
        kvs.append((k, v))
    return kvs


def embed_inputs(params: dict, batch: Dict[str, jnp.ndarray],
                 cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Token (+ stub-frontend) embedding.  Returns (h (B,S,D), positions)."""
    tokens = batch["tokens"]
    h = L.embed(tokens, params["embed"])
    if cfg.name.startswith("gemma2"):
        h = h * jnp.asarray(math.sqrt(cfg.d_model), h.dtype)
    if cfg.is_vlm:
        h = jnp.concatenate([batch["image_embeds"].astype(h.dtype), h], axis=1)
    if cfg.is_encoder_decoder:
        T = h.shape[1]
        h = h + params["dec_pos"][None, :T]
    B, T = h.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    return h, positions


def forward_train(params: dict, batch: Dict[str, jnp.ndarray], cfg: ModelConfig,
                  remat: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Causal-LM (or enc-dec) logits.  Returns (logits (B,S,V), aux_loss)."""
    h, positions = embed_inputs(params, batch, cfg)
    enc_kvs = None
    if cfg.is_encoder_decoder:
        enc_out = encode(params, batch["frames"], cfg)
        enc_kvs = encoder_cross_kv(params, enc_out, cfg)
    aux_total = jnp.float32(0.0)

    def run_layer(pl, h, enc_kv, idx):
        return apply_layer_full(pl, h, positions, cfg, idx, enc_kv)

    for i, pl in enumerate(params["layers"]):
        f = jax.checkpoint(partial(run_layer, idx=i)) if remat else partial(run_layer, idx=i)
        h, aux = f(pl, h, enc_kvs[i] if enc_kvs is not None else None)
        aux_total = aux_total + aux
    h = L.rms_norm(h, params["final_norm"], cfg.rms_eps)
    table = params.get("head", params["embed"])
    logits = L.unembed(h, table, cfg.logit_softcap)
    if cfg.is_vlm:  # image positions carry no next-token loss
        logits = logits[:, batch["image_embeds"].shape[1]:]
    return logits, aux_total
