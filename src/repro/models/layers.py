"""Shared model layers: norms, RoPE, attention (dense + chunked-flash), SwiGLU.

All code is pure JAX (jnp + lax); sharding is injected via
``repro.distributed.sharding.constrain`` on logical axis names.

Numerics: matmuls run in the param dtype (bf16 in production configs);
softmax / logsumexp accumulate in fp32.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.sharding import constrain

NEG_INF = -1e30


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dt)


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    return cap * jnp.tanh(x / cap) if cap > 0 else x


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    freqs = rope_freqs(x.shape[-1], theta)  # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (training / prefill): dense and chunked-flash
# ---------------------------------------------------------------------------


def _causal_mask(q_pos: jnp.ndarray, k_pos: jnp.ndarray,
                 window: int = 0) -> jnp.ndarray:
    """(..., Q, K) bool mask; window > 0 adds a sliding-window lower bound."""
    m = k_pos[..., None, :] <= q_pos[..., :, None]
    if window > 0:
        m &= k_pos[..., None, :] > (q_pos[..., :, None] - window)
    return m


def dense_attention(
    q: jnp.ndarray,  # (B, Q, Hq, Dh)
    k: jnp.ndarray,  # (B, K, Hkv, Dh)
    v: jnp.ndarray,  # (B, K, Hkv, Dh)
    q_pos: jnp.ndarray,  # (B, Q)
    k_pos: jnp.ndarray,  # (B, K)
    window: int = 0,
    attn_cap: float = 0.0,
    kv_mask: Optional[jnp.ndarray] = None,  # (B, K) bool, False = masked out
    causal: bool = True,
) -> jnp.ndarray:
    """Reference GQA attention with full score materialization."""
    B, Q, Hq, Dh = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Q, Hkv, G, Dh)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(Dh)
    scores = softcap(scores, attn_cap)
    if causal:
        mask = _causal_mask(q_pos, k_pos, window)  # (B, Q, K)
    else:
        mask = jnp.ones((B, Q, k.shape[1]), dtype=bool)
    if kv_mask is not None:
        mask &= kv_mask[:, None, :]
    scores = jnp.where(mask[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    # rows with no valid key (fully masked) produce uniform probs over garbage;
    # zero them explicitly
    any_valid = mask.any(axis=-1)[:, None, None, :, None]
    probs = jnp.where(any_valid, probs, 0.0)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out.reshape(B, Q, Hq, Dh).astype(q.dtype)


def flash_attention(
    q: jnp.ndarray,  # (B, Q, Hq, Dh)
    k: jnp.ndarray,  # (B, K, Hkv, Dh)
    v: jnp.ndarray,  # (B, K, Hkv, Dh)
    q_pos: jnp.ndarray,  # (B, Q)
    k_pos: jnp.ndarray,  # (B, K)
    window: int = 0,
    attn_cap: float = 0.0,
    kv_mask: Optional[jnp.ndarray] = None,
    causal: bool = True,
    chunk: int = 1024,
) -> jnp.ndarray:
    """Online-softmax attention, scanning KV chunks: O(Q·chunk) memory.

    Matches ``dense_attention`` to fp32 accumulation accuracy.
    """
    B, Q, Hq, Dh = q.shape
    K = k.shape[1]
    Hkv = k.shape[2]
    G = Hq // Hkv
    if K % chunk != 0:
        pad = chunk - K % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-1)
        pad_mask = jnp.zeros((B, pad), dtype=bool)
        kv_mask = (jnp.concatenate([kv_mask, pad_mask], 1)
                   if kv_mask is not None
                   else jnp.concatenate([jnp.ones((B, K), bool), pad_mask], 1))
        K += pad
    n_chunks = K // chunk
    qg = q.reshape(B, Q, Hkv, G, Dh).astype(jnp.float32)
    kc = k.reshape(B, n_chunks, chunk, Hkv, Dh)
    vc = v.reshape(B, n_chunks, chunk, Hkv, Dh)
    pc = k_pos.reshape(B, n_chunks, chunk)
    mc = (kv_mask.reshape(B, n_chunks, chunk) if kv_mask is not None
          else jnp.ones((B, n_chunks, chunk), bool))

    def body(carry, inp):
        acc, m_run, l_run = carry
        k_i, v_i, pos_i, mask_i = inp  # (B, chunk, Hkv, Dh), ..., (B, chunk)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg,
                       k_i.astype(jnp.float32)) / math.sqrt(Dh)
        s = softcap(s, attn_cap)
        if causal:
            msk = _causal_mask(q_pos, pos_i, window)
        else:
            msk = jnp.ones((B, Q, chunk), bool)
        msk &= mask_i[:, None, :]
        s = jnp.where(msk[:, None, None], s, NEG_INF)
        m_new = jnp.maximum(m_run, s.max(axis=-1))
        # explicit mask: a fully-masked chunk keeps m_new at NEG_INF, where
        # exp(NEG_INF - NEG_INF) would be 1 — the mask zeroes it instead
        p = jnp.where(msk[:, None, None], jnp.exp(s - m_new[..., None]), 0.0)
        corr = jnp.exp(m_run - m_new)
        l_new = l_run * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p, v_i.astype(jnp.float32))
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((B, Hkv, G, Q, Dh), jnp.float32)
    m0 = jnp.full((B, Hkv, G, Q), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Q), jnp.float32)
    (acc, m_run, l_run), _ = lax.scan(
        body, (acc0, m0, l0),
        (kc.swapaxes(0, 1), vc.swapaxes(0, 1), pc.swapaxes(0, 1),
         mc.swapaxes(0, 1)))
    out = acc / jnp.maximum(l_run[..., None], 1e-30)
    out = jnp.moveaxis(out, 3, 1)  # (B, Q, Hkv, G, Dh)
    return out.reshape(B, Q, Hq, Dh).astype(q.dtype)


def attention(q, k, v, q_pos, k_pos, *, window=0, attn_cap=0.0, kv_mask=None,
              causal=True, flash_threshold=2048, chunk=1024):
    """Dispatch dense vs chunked-flash on KV length."""
    if k.shape[1] <= flash_threshold:
        return dense_attention(q, k, v, q_pos, k_pos, window=window,
                               attn_cap=attn_cap, kv_mask=kv_mask, causal=causal)
    return flash_attention_vjp(q, k, v, q_pos, k_pos, window, attn_cap,
                               causal, chunk)


# ---------------------------------------------------------------------------
# Flash attention with a memory-efficient custom VJP.
#
# A plain lax.scan over KV chunks saves every chunk's probability matrix as a
# linearization residual — O(Q·K) backward memory, defeating the point.  The
# custom VJP saves only (q, k, v, out, m, l) and *recomputes* each chunk's
# scores in the backward pass (the FlashAttention backward algorithm).
# ---------------------------------------------------------------------------


def _flash_fwd_core(q, k, v, q_pos, k_pos, window, attn_cap, causal, chunk):
    """Forward returning (out, m, l); all fp32 internals, O(Q·chunk) memory."""
    B, Q, Hq, Dh = q.shape
    K, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    nc = K // chunk
    qg = q.reshape(B, Q, Hkv, G, Dh).astype(jnp.float32)
    kc = k.reshape(B, nc, chunk, Hkv, Dh)
    vc = v.reshape(B, nc, chunk, Hkv, Dh)
    pc = k_pos.reshape(B, nc, chunk)

    def body(carry, inp):
        acc, m_run, l_run = carry
        k_i, v_i, pos_i = inp
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg,
                       k_i.astype(jnp.float32)) / math.sqrt(Dh)
        s = softcap(s, attn_cap)
        msk = (_causal_mask(q_pos, pos_i, window) if causal
               else jnp.ones((B, Q, chunk), bool))
        s = jnp.where(msk[:, None, None], s, NEG_INF)
        m_new = jnp.maximum(m_run, s.max(axis=-1))
        p = jnp.where(msk[:, None, None], jnp.exp(s - m_new[..., None]), 0.0)
        corr = jnp.exp(m_run - m_new)
        l_new = l_run * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p, v_i.astype(jnp.float32))
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((B, Hkv, G, Q, Dh), jnp.float32)
    m0 = jnp.full((B, Hkv, G, Q), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Q), jnp.float32)
    (acc, m, l), _ = lax.scan(
        body, (acc0, m0, l0),
        (kc.swapaxes(0, 1), vc.swapaxes(0, 1), pc.swapaxes(0, 1)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out, m, l  # out: (B, Hkv, G, Q, Dh)


@partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8))
def flash_attention_vjp(q, k, v, q_pos, k_pos, window, attn_cap, causal,
                        chunk):
    out, _, _ = _flash_fwd_padded(q, k, v, q_pos, k_pos, window, attn_cap,
                                  causal, chunk)
    B, Q, Hq, Dh = q.shape
    return jnp.moveaxis(out, 3, 1).reshape(B, Q, Hq, Dh).astype(q.dtype)


def _flash_fwd_padded(q, k, v, q_pos, k_pos, window, attn_cap, causal, chunk):
    K = k.shape[1]
    chunk = min(chunk, K)
    if K % chunk != 0:
        pad = chunk - K % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)),
                        constant_values=jnp.iinfo(jnp.int32).max)
    return _flash_fwd_core(q, k, v, q_pos, k_pos, window, attn_cap, causal,
                           chunk)


def _flash_vjp_fwd(q, k, v, q_pos, k_pos, window, attn_cap, causal, chunk):
    out, m, l = _flash_fwd_padded(q, k, v, q_pos, k_pos, window, attn_cap,
                                  causal, chunk)
    B, Q, Hq, Dh = q.shape
    o = jnp.moveaxis(out, 3, 1).reshape(B, Q, Hq, Dh).astype(q.dtype)
    # store residuals seq-sharded (and o in the input dtype): the backward
    # re-gathers k/v; per-layer residual memory drops |model|x
    res = (
        constrain(q, "batch", "seq_act", None, None),
        constrain(k, "batch", "seq_act", None, None),
        constrain(v, "batch", "seq_act", None, None),
        q_pos, k_pos,
        constrain(o, "batch", "seq_act", None, None),
        constrain(m, "batch", None, None, "seq_act"),
        constrain(l, "batch", None, None, "seq_act"),
    )
    return o, res


def _flash_vjp_bwd(window, attn_cap, causal, chunk, res, do):
    q, k, v, q_pos, k_pos, o_saved, m, l = res
    k = constrain(k, "batch", None, None, None)  # re-gather for the K sweep
    v = constrain(v, "batch", None, None, None)
    B, Q, Hq, Dh = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    out = jnp.moveaxis(o_saved.reshape(B, Q, Hkv, G, Dh), 1, 3
                       ).astype(jnp.float32)  # (B, Hkv, G, Q, Dh)
    K_orig = k.shape[1]
    chunk_ = min(chunk, K_orig)
    Kp = -(-K_orig // chunk_) * chunk_
    if Kp != K_orig:
        pad = Kp - K_orig
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)),
                        constant_values=jnp.iinfo(jnp.int32).max)
    nc = Kp // chunk_
    qg = q.reshape(B, Q, Hkv, G, Dh).astype(jnp.float32)
    dog = do.reshape(B, Q, Hkv, G, Dh).astype(jnp.float32)
    dog = jnp.moveaxis(dog, 1, 3)  # (B, Hkv, G, Q, Dh)
    lsafe = jnp.maximum(l, 1e-30)
    # D_i = Σ_d dout_i · out_i (out already normalized)
    Dvec = (dog * out).sum(-1)  # (B, Hkv, G, Q)

    kc = k.reshape(B, nc, chunk_, Hkv, Dh).swapaxes(0, 1)
    vc = v.reshape(B, nc, chunk_, Hkv, Dh).swapaxes(0, 1)
    pc = k_pos.reshape(B, nc, chunk_).swapaxes(0, 1)

    def body(dq_acc, inp):
        k_i, v_i, pos_i = inp
        u = jnp.einsum("bqhgd,bkhd->bhgqk", qg,
                       k_i.astype(jnp.float32)) / math.sqrt(Dh)
        s = softcap(u, attn_cap)
        msk = (_causal_mask(q_pos, pos_i, window) if causal
               else jnp.ones((B, Q, chunk_), bool))
        s_m = jnp.where(msk[:, None, None], s, NEG_INF)
        p = jnp.where(msk[:, None, None],
                      jnp.exp(s_m - m[..., None]), 0.0) / lsafe[..., None]
        dv_i = jnp.einsum("bhgqk,bhgqd->bkhd", p, dog)
        dp = jnp.einsum("bhgqd,bkhd->bhgqk", dog, v_i.astype(jnp.float32))
        ds = p * (dp - Dvec[..., None])
        if attn_cap > 0:  # softcap chain rule: d tanh
            ds = ds * (1.0 - (s / attn_cap) ** 2)
        dq_i = jnp.einsum("bhgqk,bkhd->bqhgd", ds,
                          k_i.astype(jnp.float32)) / math.sqrt(Dh)
        dk_i = jnp.einsum("bhgqk,bqhgd->bkhd", ds, qg) / math.sqrt(Dh)
        return dq_acc + dq_i, (dk_i, dv_i)

    dq0 = jnp.zeros((B, Q, Hkv, G, Dh), jnp.float32)
    dq, (dk_c, dv_c) = lax.scan(body, dq0, (kc, vc, pc))
    dk = dk_c.swapaxes(0, 1).reshape(B, Kp, Hkv, Dh)[:, :K_orig]
    dv = dv_c.swapaxes(0, 1).reshape(B, Kp, Hkv, Dh)[:, :K_orig]
    dq = dq.reshape(B, Q, Hq, Dh)
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            None, None)


flash_attention_vjp.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def swiglu(x: jnp.ndarray, w1, w3, w2) -> jnp.ndarray:
    from repro.serving.quant import deq
    h = jax.nn.silu(x @ deq(w1)) * (x @ deq(w3))
    h = constrain(h, "batch", "seq", "ff")
    return h @ deq(w2)


def embed(tokens: jnp.ndarray, table) -> jnp.ndarray:
    from repro.serving.quant import QTensor
    if isinstance(table, QTensor):
        rows = jnp.take(table.q, tokens, axis=0).astype(jnp.float32)
        scale = jnp.take(table.scale, jnp.minimum(tokens, table.scale.shape[0] - 1),
                         axis=0) if table.scale.shape[0] > 1 else table.scale
        return (rows * scale).astype(jnp.bfloat16)
    return jnp.take(table, tokens, axis=0)


def unembed(x: jnp.ndarray, table, cap: float = 0.0) -> jnp.ndarray:
    from repro.serving.quant import deq
    logits = jnp.einsum("bsd,vd->bsv", x, deq(table)).astype(jnp.float32)
    return softcap(logits, cap)
