"""Serving driver: ``python -m repro.launch.serve --arch <id> [--smoke]``.

Default (one-shot) mode: `repro.api.Engine.generate` — prefill + compression
(Ada-SnapKV by default) → FairKV plan → slot-layout decode over a fixed
batch.  Prints per-step latency, the realized per-head budget imbalance, the
plan's efficiency E, and the generated tokens.

``--continuous`` mode drives the continuous-batching scheduler through the
same facade (`Engine.run_trace`, DESIGN.md §7): a Poisson trace of requests
(``--rate`` arrivals per decode step, ``--requests`` total) flows through
admission → interleaved decode → retirement, with online replanning when the
realized per-shard KV imbalance drifts.  Prints per-request latency,
p50/p99, and the replan log.

``--http`` mode serves the multi-tenant asyncio front end (DESIGN.md §13)
over the continuous engine: ``POST /v1/generate`` (JSON), ``POST
/v1/stream`` (SSE per-token events), ``GET /metrics`` (Prometheus with
per-tenant goodput/latency families), ``GET /healthz``.  Admission is
SLO-aware (``--admission slo``, priority classes with degrade/shed and
tenant-fair deficit-round-robin quotas) or the FCFS baseline; SIGINT /
SIGTERM drain gracefully (finish live decodes, shed the queue, flush
``--metrics-out`` / ``--trace-out``).

``--executor mesh`` runs both modes' StepFns under ``shard_map`` on a
(data=``--data``, model=``--shards``) host mesh (DESIGN.md §10) and prints
the decode StepFn's per-device collective audit (parsed from the compiled
HLO via ``repro.distributed.hlo_stats``) — on CPU, fake the devices with
``XLA_FLAGS=--xla_force_host_platform_device_count=N``.

Policy, planner, backend and executor names are validated by `EngineConfig`
against the live registries — ``--help`` lists whatever is registered,
including plugins.
"""
from __future__ import annotations

import argparse
import dataclasses
import sys

import numpy as np

from repro.api import (
    PLANNER_MODES,
    CompressionConfig,
    Engine,
    EngineConfig,
    ObsConfig,
    PagingConfig,
    PlannerConfig,
    PrefixConfig,
    SchedulerConfig,
    SpeculationConfig,
    latency_percentiles,
    list_cache_backends,
    list_engines,
    list_executors,
    list_policies,
    synthesize_requests,
)
from repro.configs.base import InputShape
from repro.training.data import SyntheticLM


def _engine_config(args, max_seq_len: int, batch_cap: int,
                   scheduler: SchedulerConfig = SchedulerConfig()
                   ) -> EngineConfig:
    if getattr(args, "config", ""):
        return _engine_config_from_file(args, max_seq_len, batch_cap,
                                        scheduler)
    speculate = getattr(args, "speculate", 0)
    # attention-free archs get a trivial single-shard plan inside
    # Engine.build, so n_shards/planner pass through unconditionally
    return EngineConfig.for_arch(
        args.arch, smoke=args.smoke, n_shards=args.shards,
        dtype="float32" if args.smoke else "bfloat16",
        max_seq_len=max_seq_len,
        compression=CompressionConfig(
            policy=args.policy, budget=args.budget, alpha_max=2.0,
            obs_window=8, sink=2,
            decode_margin=max(8, getattr(args, "gen", 8))),
        planner=PlannerConfig(mode=args.planner, engine=args.engine,
                              extra_copies=args.copies, batch_cap=batch_cap),
        scheduler=scheduler,
        # --prefix-cache needs block refcounts, --kv-dtype needs block
        # storage, and --speculate needs provisional-block rollback — all
        # paged-backend features; promote slot (the default) rather than
        # erroring on the common invocation — any other backend choice
        # still errors through EngineConfig validation
        cache_backend=("paged"
                       if ((getattr(args, "prefix_cache", False)
                            or getattr(args, "kv_dtype", "fp32") != "fp32"
                            or speculate > 0)
                           and args.cache_backend == "slot")
                       else args.cache_backend),
        paging=PagingConfig(block_size=args.block_size,
                            n_blocks=args.pool_blocks,
                            decode_impl=args.paged_impl,
                            kv_dtype=getattr(args, "kv_dtype", "fp32"),
                            pool_hbm_bytes=getattr(args, "pool_hbm_bytes",
                                                   0)),
        prefix=PrefixConfig(
            enabled=getattr(args, "prefix_cache", False),
            chunk_tokens=(getattr(args, "prefill_chunk", 0)
                          or (32 if getattr(args, "prefix_cache", False)
                              else 0)),
            max_entries=getattr(args, "prefix_entries", 256)),
        speculation=SpeculationConfig(
            enabled=speculate > 0, max_k=max(1, speculate),
            draft_layers=getattr(args, "draft_layers", 0)),
        executor=args.executor,
        obs=ObsConfig(enabled=not args.no_obs,
                      print_every=args.obs_print_every))


# explicit CLI flag -> EngineConfig field path, for --config overrides.
# Only flags that map 1:1 onto config fields appear here; trace-shape flags
# (--gen, --rows, ...) keep driving the workload, not the config.
_CLI_FIELD_MAP = {
    "shards": ("n_shards",),
    "policy": ("compression", "policy"),
    "budget": ("compression", "budget"),
    "planner": ("planner", "mode"),
    "engine": ("planner", "engine"),
    "copies": ("planner", "extra_copies"),
    "cache_backend": ("cache_backend",),
    "block_size": ("paging", "block_size"),
    "pool_blocks": ("paging", "n_blocks"),
    "paged_impl": ("paging", "decode_impl"),
    "kv_dtype": ("paging", "kv_dtype"),
    "pool_hbm_bytes": ("paging", "pool_hbm_bytes"),
    "executor": ("executor",),
    "draft_layers": ("speculation", "draft_layers"),
}


def _set_path(cfg: EngineConfig, path, value) -> EngineConfig:
    if len(path) == 1:
        return cfg.replace(**{path[0]: value})
    sub = dataclasses.replace(getattr(cfg, path[0]), **{path[1]: value})
    return cfg.replace(**{path[0]: sub})


def _engine_config_from_file(args, max_seq_len: int, batch_cap: int,
                             scheduler: SchedulerConfig) -> EngineConfig:
    """``--config cfg.json``: the file is the base `EngineConfig`
    (`EngineConfig.from_dict`, strict about unknown keys); flags the user
    *explicitly typed* override the file, flag defaults do not.  The
    trace-shape-derived fields (``max_seq_len``, ``planner.batch_cap``,
    scheduler rows) are raised to what the requested workload needs so a
    config written for one trace still runs a larger one."""
    import json

    with open(args.config) as f:
        cfg = EngineConfig.from_dict(json.load(f))
    explicit = getattr(args, "_explicit", set())
    for dest, path in _CLI_FIELD_MAP.items():
        if dest in explicit:
            cfg = _set_path(cfg, path, getattr(args, dest))
    if "speculate" in explicit:
        cfg = cfg.replace(speculation=dataclasses.replace(
            cfg.speculation, enabled=args.speculate > 0,
            max_k=max(1, args.speculate)))
    if cfg.speculation.enabled and cfg.cache_backend == "slot":
        cfg = cfg.replace(cache_backend="paged")
    # workload-derived floors (never shrink what the file asked for)
    cfg = cfg.replace(max_seq_len=max(cfg.max_seq_len, max_seq_len))
    if cfg.planner.batch_cap is None or cfg.planner.batch_cap < batch_cap:
        cfg = cfg.replace(planner=dataclasses.replace(
            cfg.planner, batch_cap=batch_cap))
    if scheduler.max_rows > cfg.scheduler.max_rows:
        cfg = cfg.replace(scheduler=dataclasses.replace(
            cfg.scheduler, max_rows=scheduler.max_rows))
    return cfg


def _build_engine(args, ecfg: EngineConfig) -> Engine:
    """Engine on the configured executor (mesh: a (data, model) host mesh)."""
    mesh = None
    if ecfg.executor == "mesh":
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh(model=args.shards, data=args.data)
    return Engine.build(ecfg, mesh=mesh)


def _collective_audit(eng: Engine) -> None:
    """Print the decode StepFn's per-device collective traffic (mesh only).

    The audit is the §10 contract check made visible: the decode hot loop
    should psum exactly once per attention layer (the o-projection) and
    all-gather nothing — weight gathers belong to prefill.
    """
    if eng.cfg.executor != "mesh":
        return
    from repro.distributed.hlo_stats import collective_stats
    sched = eng.scheduler
    sp, pa = (sched.sp, sched.pa) if sched is not None else (eng.sp, eng.pa)
    state = sched.state if sched is not None else eng.state
    hlo = eng.executor.decode_hlo(sp, state, pa, state.last_tokens)
    stats = collective_stats(hlo)
    total = sum(v["bytes"] for v in stats.values())
    detail = ", ".join(f"{k}×{v['count']} ({v['bytes'] / 1e3:.1f} kB)"
                       for k, v in sorted(stats.items())) or "none"
    print(f"decode StepFn collectives/device: {detail} | "
          f"total {total / 1e3:.1f} kB")


def _export_obs(eng: Engine, args) -> None:
    """Write the Prometheus / Chrome-trace exports when paths were given."""
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            f.write(eng.metrics_prometheus())
        print(f"metrics -> {args.metrics_out}")
    if args.trace_out:
        with open(args.trace_out, "w") as f:
            f.write(eng.trace_export())
        print(f"trace -> {args.trace_out} (load in Perfetto / "
              f"chrome://tracing)")


def _scheduler_config(args) -> SchedulerConfig:
    return SchedulerConfig(
        max_rows=args.rows,
        max_live_tokens=args.max_live_tokens or None,
        replan_window=args.replan_window,
        replan_threshold=args.replan_threshold,
        replan_cooldown=args.replan_cooldown,
        enable_replan=not args.no_replan,
    )


def _install_drain_handlers(eng: Engine):
    """SIGINT/SIGTERM → `Engine.drain` (graceful: stop admitting, finish
    live decodes; queued/unsubmitted requests are shed).  Returns a restore
    callback.  A second signal falls through to the previous handler, so
    Ctrl-C twice still kills a stuck drain."""
    import signal

    prev = {}

    def _drain(signum, frame):
        print(f"\nsignal {signum}: draining (live rows decode to "
              f"completion; queued requests are shed) ...", flush=True)
        eng.drain()
        # restore immediately: the next signal interrupts for real
        for sig, h in prev.items():
            signal.signal(sig, h)

    for sig in (signal.SIGINT, signal.SIGTERM):
        try:
            prev[sig] = signal.signal(sig, _drain)
        except ValueError:  # not the main thread (embedded use)
            pass

    def restore() -> None:
        for sig, h in prev.items():
            try:
                signal.signal(sig, h)
            except ValueError:
                pass

    return restore


def run_continuous(args) -> None:
    """Poisson-trace continuous batching via the facade."""
    min_prompt = args.min_prompt
    tkw = {}
    if getattr(args, "prefix_templates", 0) > 0:
        # shared templates need room for a unique suffix on every prompt
        min_prompt = max(min_prompt, args.prefix_len + 4)
        tkw = dict(prefix_templates=args.prefix_templates,
                   prefix_len=args.prefix_len,
                   shared_fraction=args.shared_fraction)
    max_prompt = max(min_prompt, args.max_prompt)
    scfg = _scheduler_config(args)
    ecfg = _engine_config(args, max_prompt + args.gen + 8, args.rows, scfg)
    eng = _build_engine(args, ecfg)
    reqs = synthesize_requests(args.requests, args.rate,
                               ecfg.model.vocab_size,
                               min_prompt=min_prompt,
                               max_prompt=max_prompt,
                               max_new_tokens=args.gen, seed=args.seed,
                               **tkw)
    print(f"continuous: {len(reqs)} requests, rate {args.rate}/step, "
          f"{args.rows} rows, planner {args.planner}")
    restore = _install_drain_handlers(eng)
    try:
        out = eng.run_trace(reqs, max_steps=args.max_steps)
    finally:
        restore()
        # a drained (signalled) run still flushes its exports — that's the
        # point of graceful shutdown
        _export_obs(eng, args)
    for r in eng.finished_requests:
        print(f"req {r.req_id}: prompt {r.prompt_len:3d} | arrive "
              f"{r.arrival_step:3d} admit {r.admit_step:3d} finish "
              f"{r.finish_step:3d} | queued {r.queueing_steps():2d} steps | "
              f"{r.n_generated} tokens")
    pct = latency_percentiles(eng.finished_requests)

    def fmt(key: str, scale: float = 1.0, unit: str = "") -> str:
        # absent key = no request recorded the observable: print n/a, not nan
        v = pct.get(key)
        return "n/a" if v is None else f"{v * scale:.0f}{unit}"

    note = (f" ({out['tokens_per_s_note']})"
            if "tokens_per_s_note" in out else "")
    print(f"steps {out['steps']} | {out['generated_tokens']} tokens in "
          f"{out['wall_s']:.1f}s = {out['tokens_per_s']:.1f} tok/s{note} | "
          f"latency p50 {fmt('p50_steps')} / p99 {fmt('p99_steps')} steps")
    print(f"ttft p50 {fmt('p50_ttft_s', 1e3, ' ms')} / p99 "
          f"{fmt('p99_ttft_s', 1e3, ' ms')} | itl p50 "
          f"{fmt('p50_itl_s', 1e3, ' ms')} / p99 "
          f"{fmt('p99_itl_s', 1e3, ' ms')}")
    print(f"mid-stream admissions: {out['mid_stream_admissions']} | "
          f"replans: {out['replans']} | preemptions: {out['preemptions']}")
    st = eng.stats()  # one typed snapshot (DESIGN.md §8)
    if st.pool.backend == "paged":
        print(f"paged cache: {st.pool.blocks_in_use}/{st.pool.blocks_total} "
              f"blocks ({st.pool.cache_bytes} B) vs slot-equivalent "
              f"{st.pool.slot_equivalent_bytes} B")
    if st.prefix.enabled:
        print(f"prefix cache: {st.prefix.hits} hits / {st.prefix.misses} "
              f"misses | {st.prefix.entries} entries holding "
              f"{st.prefix.blocks_held} blocks | {st.prefix.evictions} "
              f"evictions")
    if st.speculation.enabled:
        acc = ("n/a" if st.speculation.acceptance is None
               else f"{st.speculation.acceptance:.2f}")
        print(f"speculation: {st.speculation.accepted}/"
              f"{st.speculation.proposed} draft tokens accepted "
              f"(acceptance {acc}, max_k {st.speculation.max_k}, "
              f"draft layers {st.speculation.draft_layers or 'all'})")
    for ev in st.scheduler.replan_log:
        tag = "accepted" if ev["accepted"] else "rejected"
        print(f"  replan @ step {ev['step']} ({tag}): imbalance "
              f"{ev['imbalance_before']:.3f} -> {ev['imbalance_after']:.3f}")
    _collective_audit(eng)
    if out.get("drained"):
        # graceful shutdown: cancelled requests are expected, not a failure
        print(f"drained: {out['cancelled']} request(s) shed, "
              f"{out['finished'] - out['cancelled']} decoded to completion")
        return
    if out["finished"] != out["total"]:
        raise RuntimeError(
            f"only {out['finished']}/{out['total']} requests finished")
    if args.smoke and out["mid_stream_admissions"] < 1:
        raise RuntimeError("smoke trace produced no mid-stream admission — "
                           "raise --requests or lower --rows")


def run_http(args) -> None:
    """``--http``: the multi-tenant asyncio serving front end
    (DESIGN.md §13) over the continuous-batching engine.

    SIGINT/SIGTERM drain gracefully: the listener closes, queued requests
    are shed with 503-style terminal events, live rows decode to
    completion, and ``--metrics-out`` / ``--trace-out`` are flushed.
    """
    import asyncio
    import signal

    from repro.frontend import FrontendConfig, FrontendServer

    max_prompt = max(args.min_prompt, args.max_prompt)
    ecfg = _engine_config(args, max_prompt + args.gen + 8, args.rows,
                          _scheduler_config(args))
    fcfg = FrontendConfig(
        host=args.host, port=args.port, admission=args.admission,
        quantum_tokens=args.quantum, quota_cap_tokens=args.quota_cap,
        max_prompt_tokens=max_prompt, max_new_tokens_cap=args.gen)
    eng = _build_engine(args, ecfg)

    async def _main() -> None:
        server = FrontendServer(eng, fcfg)
        await server.start()
        print(f"serving on http://{server.host}:{server.port} "
              f"(admission={fcfg.admission}, rows={args.rows}, "
              f"backend={ecfg.cache_backend}, executor={ecfg.executor})",
              flush=True)
        stop = asyncio.Event()
        loop = asyncio.get_running_loop()
        for sig in (signal.SIGINT, signal.SIGTERM):
            try:
                loop.add_signal_handler(sig, stop.set)
            except (NotImplementedError, RuntimeError):
                signal.signal(sig, lambda *_: stop.set())
        await stop.wait()
        print("signal received: draining (live rows decode to completion, "
              "queued requests shed) ...", flush=True)
        await server.shutdown(drain=True)
        summary = server.engine_loop.fe.summary()
        print(f"drained after {summary['steps']} steps | "
              f"{summary['finished']} terminal requests | goodput "
              f"{summary['goodput_tokens']:.0f} tokens", flush=True)

    try:
        asyncio.run(_main())
    finally:
        _export_obs(eng, args)


def run_oneshot(args) -> None:
    """Fixed-batch serve: one prefill + ``--gen`` decode steps."""
    ecfg = _engine_config(args, args.prompt_len + args.gen + 8, args.batch)
    eng = _build_engine(args, ecfg)
    data = SyntheticLM(ecfg.model, InputShape("cli", args.prompt_len,
                                              args.batch, "prefill"))
    res = eng.generate(data.get_batch(0), args.gen, collect_logits=False)
    if res.lengths.size:
        lens_np = np.asarray(res.lengths, np.float64)
        print(f"prefill {res.prefill_s * 1e3:7.1f} ms | realized per-head "
              f"budget min/mean/max = {lens_np.min():.0f}/{lens_np.mean():.0f}"
              f"/{lens_np.max():.0f} | plan E = "
              f"{res.efficiency:.3f} ({args.planner})")
    print(f"decode  {np.median(res.step_s) * 1e3:7.1f} ms/step (median of "
          f"{args.gen}; first {res.step_s[0] * 1e3:.0f} ms incl. compile)")
    pool = eng.stats().pool
    if pool.backend == "paged":
        print(f"paged cache: {pool.cache_bytes} B in "
              f"{pool.blocks_in_use} blocks vs slot-equivalent "
              f"{pool.slot_equivalent_bytes} B")
    _collective_audit(eng)
    _export_obs(eng, args)
    for b in range(min(args.batch, 2)):
        print(f"row {b}: {res.tokens[b].tolist()}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="",
                    help="architecture id (required unless --config "
                         "provides the model)")
    ap.add_argument("--config", default="",
                    help="JSON EngineConfig file (EngineConfig.to_dict "
                         "format) used as the base config; explicitly "
                         "typed CLI flags override file values, flag "
                         "defaults do not")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--prompt-len", type=int, default=96)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--budget", type=int, default=32)
    ap.add_argument("--policy", default="ada_snapkv",
                    help=f"compression policy; registered: {list_policies()}")
    ap.add_argument("--planner", default="fairkv_dp",
                    choices=list(PLANNER_MODES))
    ap.add_argument("--engine", default="auto",
                    help="assignment engine; registered: "
                         f"{list_engines()}")
    ap.add_argument("--shards", type=int, default=4,
                    help="logical model shards for the plan")
    ap.add_argument("--copies", type=int, default=4, help="CH")
    # --- cache backend (DESIGN.md §9) ----------------------------------------
    ap.add_argument("--cache-backend", default="slot",
                    help=f"cache storage backend; registered: "
                         f"{list_cache_backends()}")
    ap.add_argument("--block-size", type=int, default=16,
                    help="paged backend: tokens per KV block")
    ap.add_argument("--pool-blocks", type=int, default=0,
                    help="paged backend: blocks per layer pool "
                         "(0 = slot-equivalent worst case)")
    ap.add_argument("--paged-impl", default="auto",
                    choices=["auto", "pallas", "gather", "jnp"],
                    help="paged backend: decode-attention implementation "
                         "(DESIGN.md §11; auto = native pallas kernel on "
                         "TPU, jnp oracle elsewhere)")
    ap.add_argument("--kv-dtype", default="fp32",
                    choices=["fp32", "int8", "fp8"],
                    help="paged backend: KV block storage format "
                         "(DESIGN.md §15; quantized pools carry per-block "
                         "scales and dequantize in the decode kernel)")
    ap.add_argument("--pool-hbm-bytes", type=int, default=0,
                    help="paged backend: size the per-layer pool from an "
                         "HBM byte budget instead of --pool-blocks "
                         "(bytes-aware admission: int8 pools hold ~4x the "
                         "blocks of fp32 at the same budget)")
    # --- speculative decoding (DESIGN.md §16) --------------------------------
    ap.add_argument("--speculate", type=int, default=0, metavar="K",
                    help="speculative decoding: propose up to K draft "
                         "tokens per tick and verify them in one "
                         "multi-query pass (0 = off; implies "
                         "--cache-backend paged)")
    ap.add_argument("--draft-layers", type=int, default=0,
                    help="early-exit depth of the self-speculative draft "
                         "(first N layers + the target's unembedding; "
                         "0 = all layers, acceptance 1.0 — a correctness "
                         "baseline, not a speedup)")
    # --- shared-prefix reuse + chunked prefill (DESIGN.md §14) ---------------
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="split prompt prefill into chunks of this many "
                         "tokens, interleaved with decode ticks (0 = "
                         "monolithic prefill); dense-attention models only")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="content-addressed shared-prefix block reuse "
                         "(requires --cache-backend paged; implies "
                         "--prefill-chunk 32 when no chunk size is given)")
    ap.add_argument("--prefix-entries", type=int, default=256,
                    help="prefix index capacity (LRU-evicted entries)")
    ap.add_argument("--prefix-templates", type=int, default=0,
                    help="continuous trace: number of shared prompt "
                         "templates (0 = fully random prompts)")
    ap.add_argument("--prefix-len", type=int, default=0,
                    help="continuous trace: tokens per shared template")
    ap.add_argument("--shared-fraction", type=float, default=0.8,
                    help="continuous trace: fraction of requests that "
                         "start with a template prefix")
    # --- executor (DESIGN.md §10) --------------------------------------------
    ap.add_argument("--executor", default="local",
                    help=f"device execution strategy; registered: "
                         f"{list_executors()}.  'mesh' runs the StepFns "
                         f"under shard_map on a (data, model) host mesh "
                         f"(set XLA_FLAGS=--xla_force_host_platform_"
                         f"device_count=N to fake devices on CPU) and "
                         f"prints the decode collective audit")
    ap.add_argument("--data", type=int, default=1,
                    help="mesh executor: data-axis width (batch rows shard "
                         "over it; model axis width is --shards)")
    # --- continuous batching -------------------------------------------------
    ap.add_argument("--continuous", action="store_true",
                    help="run the continuous-batching scheduler on a "
                         "Poisson request trace")
    ap.add_argument("--rows", type=int, default=2,
                    help="batch rows (concurrent requests)")
    ap.add_argument("--requests", type=int, default=5)
    ap.add_argument("--rate", type=float, default=0.5,
                    help="Poisson arrival rate, requests per decode step")
    ap.add_argument("--min-prompt", type=int, default=12)
    ap.add_argument("--max-prompt", type=int, default=24)
    ap.add_argument("--max-steps", type=int, default=2000)
    ap.add_argument("--max-live-tokens", type=int, default=0,
                    help="admission token budget (0 = rows-only admission)")
    ap.add_argument("--replan-window", type=int, default=8)
    ap.add_argument("--replan-threshold", type=float, default=1.25)
    ap.add_argument("--replan-cooldown", type=int, default=16)
    ap.add_argument("--no-replan", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    # --- HTTP serving front end (DESIGN.md §13) ------------------------------
    ap.add_argument("--http", action="store_true",
                    help="serve the multi-tenant asyncio HTTP front end "
                         "(POST /v1/generate, POST /v1/stream [SSE], "
                         "GET /metrics, GET /healthz) over the continuous "
                         "engine; SIGINT/SIGTERM drain gracefully")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8000,
                    help="listen port (0 = ephemeral, printed on start)")
    ap.add_argument("--admission", default="slo", choices=["slo", "fcfs"],
                    help="admission controller: 'slo' (priority classes, "
                         "degrade/shed, tenant-fair DRR) or 'fcfs' "
                         "(baseline global queue)")
    ap.add_argument("--quantum", type=int, default=512,
                    help="DRR per-tenant token refill per engine tick")
    ap.add_argument("--quota-cap", type=int, default=8192,
                    help="DRR banked-deficit cap per tenant (tokens)")
    # --- observability (DESIGN.md §12) ---------------------------------------
    ap.add_argument("--no-obs", action="store_true",
                    help="disable the metrics/trace subsystem entirely")
    ap.add_argument("--obs-print-every", type=int, default=0,
                    help="scheduler steps between one-line stats prints "
                         "(0 = off)")
    ap.add_argument("--metrics-out", default="",
                    help="write Prometheus text metrics here on exit")
    ap.add_argument("--trace-out", default="",
                    help="write Chrome trace-event JSON here on exit "
                         "(Perfetto-loadable)")
    args = ap.parse_args()
    if not args.arch and not args.config:
        ap.error("one of --arch or --config is required")
    # record which flags the user explicitly typed (vs argparse defaults):
    # --config merging applies only the former.  Matches both "--flag value"
    # and "--flag=value" spellings.
    argv = sys.argv[1:]
    args._explicit = {
        a.dest for a in ap._actions
        if any(tok == opt or tok.startswith(opt + "=")
               for opt in a.option_strings for tok in argv)}

    if args.http:
        run_http(args)
    elif args.continuous:
        run_continuous(args)
    else:
        run_oneshot(args)


if __name__ == "__main__":
    main()
