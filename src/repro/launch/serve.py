"""Serving driver: ``python -m repro.launch.serve --arch <id> [--smoke]``.

Prefill + compression (Ada-SnapKV by default) → FairKV plan → slot-layout
decode.  Prints per-step latency, the realized per-head budget imbalance,
the plan's efficiency E, and the generated tokens.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.cache.slot_cache import PlanArrays
from repro.compression.base import CompressionConfig
from repro.configs import get_config, get_smoke_config
from repro.configs.base import InputShape
from repro.core import PlannerConfig, build_plan, profile_from_lengths, synthetic_profile
from repro.models import init_params
from repro.serving import decode_step, prefill, slotify_params
from repro.training.data import SyntheticLM


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--prompt-len", type=int, default=96)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--budget", type=int, default=32)
    ap.add_argument("--policy", default="ada_snapkv")
    ap.add_argument("--planner", default="fairkv_dp",
                    choices=["sha", "fairkv_nodp", "fairkv_dp"])
    ap.add_argument("--shards", type=int, default=4,
                    help="logical model shards for the plan")
    ap.add_argument("--copies", type=int, default=4, help="CH")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    dtype = jnp.float32 if args.smoke else jnp.bfloat16
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=dtype,
                         max_seq_len=args.prompt_len + args.gen + 8)
    shape = InputShape("cli", args.prompt_len, args.batch, "prefill")
    data = SyntheticLM(cfg, shape)
    batch = data.get_batch(0)

    ccfg = CompressionConfig(policy=args.policy, budget=args.budget,
                             alpha_max=2.0, obs_window=8, sink=2,
                             decode_margin=8)
    if cfg.attention_free:
        plan = build_plan(np.ones((cfg.n_layers, 1)), 1,
                          PlannerConfig(mode="sha", slots_per_shard=1))
    else:
        prof = synthetic_profile(cfg.n_layers, cfg.n_kv_heads,
                                 budget=args.budget, skew=1.0, seed=1)
        plan = build_plan(prof, args.shards,
                          PlannerConfig(mode=args.planner,
                                        extra_copies=args.copies,
                                        batch_cap=args.batch))
    pa = PlanArrays.from_plan(plan)
    sp = slotify_params(params, plan, cfg)

    t0 = time.time()
    state, logits, lens = prefill(sp, batch, cfg, pa, ccfg)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0
    if lens.size:
        lens_np = np.asarray(lens, np.float64)
        prof_real = profile_from_lengths(np.transpose(lens_np, (0, 1, 2)))
        print(f"prefill {t_prefill * 1e3:7.1f} ms | realized per-head budget "
              f"min/mean/max = {lens_np.min():.0f}/{lens_np.mean():.0f}/"
              f"{lens_np.max():.0f} | plan E = "
              f"{plan.efficiency(prof_real):.3f} ({args.planner})")
    tokens = [np.asarray(state.last_tokens)]
    step = jax.jit(lambda st: decode_step(sp, st, cfg, pa, ccfg))
    times = []
    for _ in range(args.gen):
        t0 = time.time()
        state, logits = step(state)
        jax.block_until_ready(logits)
        times.append(time.time() - t0)
        tokens.append(np.asarray(state.last_tokens))
    gen = np.stack(tokens, 1)
    print(f"decode  {np.median(times) * 1e3:7.1f} ms/step (median of "
          f"{args.gen}; first {times[0] * 1e3:.0f} ms incl. compile)")
    for b in range(min(args.batch, 2)):
        print(f"row {b}: {gen[b].tolist()}")


if __name__ == "__main__":
    main()
