"""Serving driver: ``python -m repro.launch.serve --arch <id> [--smoke]``.

Default (one-shot) mode: prefill + compression (Ada-SnapKV by default) →
FairKV plan → slot-layout decode over a fixed batch.  Prints per-step
latency, the realized per-head budget imbalance, the plan's efficiency E,
and the generated tokens.

``--continuous`` mode drives the continuous-batching scheduler instead
(DESIGN.md §7): a Poisson trace of requests (``--rate`` arrivals per decode
step, ``--requests`` total) flows through admission → interleaved decode →
retirement, with online replanning when the realized per-shard KV imbalance
drifts.  Prints per-request latency, p50/p99, and the replan log.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.cache.slot_cache import PlanArrays
from repro.compression.base import CompressionConfig
from repro.configs import get_config, get_smoke_config
from repro.configs.base import InputShape
from repro.core import PlannerConfig, build_plan, profile_from_lengths, synthetic_profile
from repro.models import init_params
from repro.serving import (
    Scheduler,
    SchedulerConfig,
    decode_step,
    latency_percentiles,
    prefill,
    slotify_params,
    synthesize_requests,
)
from repro.training.data import SyntheticLM


def run_continuous(args) -> None:
    """Poisson-trace continuous batching on the scheduler."""
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    dtype = jnp.float32 if args.smoke else jnp.bfloat16
    max_prompt = max(args.min_prompt, args.max_prompt)
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=dtype,
                         max_seq_len=max_prompt + args.gen + 8)
    ccfg = CompressionConfig(policy=args.policy, budget=args.budget,
                             alpha_max=2.0, obs_window=8, sink=2,
                             decode_margin=max(8, args.gen))
    if cfg.attention_free:
        pcfg = PlannerConfig(mode="sha", slots_per_shard=1)
        plan = build_plan(np.ones((cfg.n_layers, 1)), 1, pcfg)
    else:
        prof = synthetic_profile(cfg.n_layers, cfg.n_kv_heads,
                                 budget=args.budget, skew=1.0, seed=1)
        pcfg = PlannerConfig(mode=args.planner, extra_copies=args.copies,
                             batch_cap=args.rows)
        plan = build_plan(prof, args.shards, pcfg)
    scfg = SchedulerConfig(
        max_rows=args.rows,
        max_live_tokens=args.max_live_tokens or None,
        replan_window=args.replan_window,
        replan_threshold=args.replan_threshold,
        replan_cooldown=args.replan_cooldown,
        enable_replan=not args.no_replan,
    )
    sched = Scheduler(cfg, params, plan, ccfg, scfg, planner_cfg=pcfg,
                      dtype=dtype)
    reqs = synthesize_requests(args.requests, args.rate, cfg.vocab_size,
                               min_prompt=args.min_prompt,
                               max_prompt=max_prompt,
                               max_new_tokens=args.gen, seed=args.seed)
    print(f"continuous: {len(reqs)} requests, rate {args.rate}/step, "
          f"{args.rows} rows, planner {args.planner}")
    out = sched.run(reqs, max_steps=args.max_steps)
    for r in sched.finished:
        print(f"req {r.req_id}: prompt {r.prompt_len:3d} | arrive "
              f"{r.arrival_step:3d} admit {r.admit_step:3d} finish "
              f"{r.finish_step:3d} | queued {r.queueing_steps():2d} steps | "
              f"{r.n_generated} tokens")
    pct = latency_percentiles(sched.finished)
    print(f"steps {out['steps']} | {out['generated_tokens']} tokens in "
          f"{out['wall_s']:.1f}s = {out['tokens_per_s']:.1f} tok/s | "
          f"latency p50 {pct.get('p50_steps', float('nan')):.0f} / p99 "
          f"{pct.get('p99_steps', float('nan')):.0f} steps")
    print(f"mid-stream admissions: {out['mid_stream_admissions']} | "
          f"replans: {out['replans']}")
    for ev in out["replan_log"]:
        tag = "accepted" if ev["accepted"] else "rejected"
        print(f"  replan @ step {ev['step']} ({tag}): imbalance "
              f"{ev['imbalance_before']:.3f} -> {ev['imbalance_after']:.3f}")
    if out["finished"] != out["total"]:
        raise RuntimeError(
            f"only {out['finished']}/{out['total']} requests finished")
    if args.smoke and out["mid_stream_admissions"] < 1:
        raise RuntimeError("smoke trace produced no mid-stream admission — "
                           "raise --requests or lower --rows")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--prompt-len", type=int, default=96)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--budget", type=int, default=32)
    ap.add_argument("--policy", default="ada_snapkv")
    ap.add_argument("--planner", default="fairkv_dp",
                    choices=["sha", "fairkv_nodp", "fairkv_dp"])
    ap.add_argument("--shards", type=int, default=4,
                    help="logical model shards for the plan")
    ap.add_argument("--copies", type=int, default=4, help="CH")
    # --- continuous batching -------------------------------------------------
    ap.add_argument("--continuous", action="store_true",
                    help="run the continuous-batching scheduler on a "
                         "Poisson request trace")
    ap.add_argument("--rows", type=int, default=2,
                    help="batch rows (concurrent requests)")
    ap.add_argument("--requests", type=int, default=5)
    ap.add_argument("--rate", type=float, default=0.5,
                    help="Poisson arrival rate, requests per decode step")
    ap.add_argument("--min-prompt", type=int, default=12)
    ap.add_argument("--max-prompt", type=int, default=24)
    ap.add_argument("--max-steps", type=int, default=2000)
    ap.add_argument("--max-live-tokens", type=int, default=0,
                    help="admission token budget (0 = rows-only admission)")
    ap.add_argument("--replan-window", type=int, default=8)
    ap.add_argument("--replan-threshold", type=float, default=1.25)
    ap.add_argument("--replan-cooldown", type=int, default=16)
    ap.add_argument("--no-replan", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.continuous:
        run_continuous(args)
        return

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    dtype = jnp.float32 if args.smoke else jnp.bfloat16
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=dtype,
                         max_seq_len=args.prompt_len + args.gen + 8)
    shape = InputShape("cli", args.prompt_len, args.batch, "prefill")
    data = SyntheticLM(cfg, shape)
    batch = data.get_batch(0)

    ccfg = CompressionConfig(policy=args.policy, budget=args.budget,
                             alpha_max=2.0, obs_window=8, sink=2,
                             decode_margin=8)
    if cfg.attention_free:
        plan = build_plan(np.ones((cfg.n_layers, 1)), 1,
                          PlannerConfig(mode="sha", slots_per_shard=1))
    else:
        prof = synthetic_profile(cfg.n_layers, cfg.n_kv_heads,
                                 budget=args.budget, skew=1.0, seed=1)
        plan = build_plan(prof, args.shards,
                          PlannerConfig(mode=args.planner,
                                        extra_copies=args.copies,
                                        batch_cap=args.batch))
    pa = PlanArrays.from_plan(plan)
    sp = slotify_params(params, plan, cfg)

    t0 = time.time()
    state, logits, lens = prefill(sp, batch, cfg, pa, ccfg)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0
    if lens.size:
        lens_np = np.asarray(lens, np.float64)
        prof_real = profile_from_lengths(np.transpose(lens_np, (0, 1, 2)))
        print(f"prefill {t_prefill * 1e3:7.1f} ms | realized per-head budget "
              f"min/mean/max = {lens_np.min():.0f}/{lens_np.mean():.0f}/"
              f"{lens_np.max():.0f} | plan E = "
              f"{plan.efficiency(prof_real):.3f} ({args.planner})")
    tokens = [np.asarray(state.last_tokens)]
    step = jax.jit(lambda st: decode_step(sp, st, cfg, pa, ccfg))
    times = []
    for _ in range(args.gen):
        t0 = time.time()
        state, logits = step(state)
        jax.block_until_ready(logits)
        times.append(time.time() - t0)
        tokens.append(np.asarray(state.last_tokens))
    gen = np.stack(tokens, 1)
    print(f"decode  {np.median(times) * 1e3:7.1f} ms/step (median of "
          f"{args.gen}; first {times[0] * 1e3:.0f} ms incl. compile)")
    for b in range(min(args.batch, 2)):
        print(f"row {b}: {gen[b].tolist()}")


if __name__ == "__main__":
    main()
