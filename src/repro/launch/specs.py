"""Cell builders: (arch × shape × mesh) → jit-able step + ShapeDtypeStruct args.

Every assigned cell lowers one of three steps (serving steps via the
``repro.api`` facade's low-level passthroughs):
- train_4k     → ``train_step``       (params, opt_state, batch)
- prefill_32k  → ``api.prefill``      (serve_params, batch, plan, ccfg)
- decode_32k / long_500k → ``api.decode_step`` (serve_params, state, plan,
  ccfg)

All array arguments are ShapeDtypeStructs (no allocation); plan arrays are
tiny and concrete (the planner is real).  Compression settings per cell are
the paper's operating point (Ada-SnapKV, budget 1024) except long_500k,
which exercises the uncompressed long-context path where the arch allows it
(DESIGN.md §4).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import api
from repro.cache.slot_cache import PlanArrays, SlotCache
from repro.compression.base import CompressionConfig
from repro.configs.base import InputShape, ModelConfig
from repro.core.placement import HeadPlacement
from repro.core.planner import PlannerConfig
from repro.core.profiles import synthetic_profile
from repro.distributed.param_specs import guarded, tree_shardings
from repro.distributed.sharding import ShardingRules, serve_rules, train_rules, use_rules
from repro.models import transformer as M
from repro.training.optimizer import AdamWState, OptimizerConfig
from repro.training.train_loop import train_step

BF16 = jnp.bfloat16


# ---------------------------------------------------------------------------
# Compression operating point per cell
# ---------------------------------------------------------------------------


def cell_ccfg(cfg: ModelConfig, shape: InputShape) -> CompressionConfig:
    if shape.name == "long_500k":
        if cfg.sliding_window > 0 and not cfg.local_global_alternate:
            # pure sliding-window attention (hymba): cache holds one window
            return CompressionConfig(policy="none", budget=cfg.sliding_window,
                                     capacity=cfg.sliding_window,
                                     decode_margin=64)
        # gemma2-style: global layers hold the full 500k retained context
        return CompressionConfig(policy="none", budget=shape.seq_len,
                                 capacity=shape.seq_len, decode_margin=64)
    return CompressionConfig(policy="ada_snapkv", budget=1024,
                             alpha_max=1.5, decode_margin=64)


def cell_plan(cfg: ModelConfig, n_model_shards: int,
              planner_mode: str = "fairkv_dp", extra_copies: int = 4,
              seed: int = 0, batch_cap: Optional[int] = None
              ) -> Optional[HeadPlacement]:
    if cfg.attention_free:
        return None
    profile = synthetic_profile(cfg.n_layers, cfg.n_kv_heads, budget=1024,
                                skew=1.0, seed=seed)
    return api.build_plan(profile, n_model_shards,
                          PlannerConfig(mode=planner_mode,
                                        extra_copies=extra_copies,
                                        batch_cap=batch_cap))


# ---------------------------------------------------------------------------
# ShapeDtypeStruct builders
# ---------------------------------------------------------------------------


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def batch_sds(cfg: ModelConfig, shape: InputShape, rules: ShardingRules,
              seq_len: Optional[int] = None) -> Dict[str, Any]:
    B = shape.global_batch
    S = seq_len if seq_len is not None else shape.seq_len
    if cfg.is_vlm:
        S = max(1, S - cfg.num_image_tokens)
    bspec = rules.rules.get("batch")
    out = {"tokens": _sds((B, S), jnp.int32,
                          NamedSharding(rules.mesh, P(guarded(rules, B, "batch"), None)))}
    if cfg.is_vlm:
        out["image_embeds"] = _sds(
            (B, cfg.num_image_tokens, cfg.d_model), BF16,
            NamedSharding(rules.mesh, P(guarded(rules, B, "batch"), None, None)))
    if cfg.is_encoder_decoder:
        out["frames"] = _sds(
            (B, cfg.encoder_seq_len, cfg.d_model), BF16,
            NamedSharding(rules.mesh, P(guarded(rules, B, "batch"), None, None)))
    return out


def params_sds(cfg: ModelConfig, shape: InputShape, dtype=BF16):
    """Abstract param tree via eval_shape (no allocation)."""
    max_seq = max(shape.seq_len + 64, 4096) if cfg.is_encoder_decoder else 4096
    return jax.eval_shape(
        partial(M.init_params, cfg, dtype=dtype, max_seq_len=max_seq),
        jax.random.PRNGKey(0))


def serve_params_sds(cfg: ModelConfig, shape: InputShape,
                     plan: Optional[HeadPlacement], dtype=BF16,
                     quantize: bool = False):
    from repro.serving.quant import quantize_serve_params
    base = params_sds(cfg, shape, dtype)
    if plan is not None and not cfg.attention_free:
        base = jax.eval_shape(partial(api.slotify_params, plan=plan, cfg=cfg), base)
    if quantize:
        base = jax.eval_shape(quantize_serve_params, base)
    return base


def _with_shardings(tree_sds, rules: ShardingRules, mode: str):
    sh = tree_shardings(tree_sds, rules, mode)
    return jax.tree.map(lambda s, d: _sds(s.shape, s.dtype, d), tree_sds, sh)


def opt_sds(p_sds) -> AdamWState:
    f32 = lambda t: jax.tree.map(lambda x: _sds(x.shape, jnp.float32, x.sharding), t)
    return AdamWState(step=_sds((), jnp.int32), master=f32(p_sds),
                      mu=f32(p_sds), nu=f32(p_sds))


def serve_state_sds(cfg: ModelConfig, shape: InputShape,
                    plan: Optional[HeadPlacement], ccfg: CompressionConfig,
                    rules: ShardingRules, dtype=BF16) -> api.ServeState:
    """Decode-time state, with explicit shardings."""
    B = shape.global_batch
    L = cfg.n_layers
    cap = ccfg.static_capacity()
    mesh = rules.mesh

    def ns(*logical_per_dim_and_shape):
        shape_, logical = logical_per_dim_and_shape
        return NamedSharding(mesh, P(*(guarded(rules, d, l)
                                       for d, l in zip(shape_, logical))))

    cache = None
    if not cfg.attention_free:
        S_ = plan.n_slots
        Dh = cfg.head_dim
        kv_shape = (L, S_, B, cap, Dh)
        kv_log = (None, "kv_slot", "batch", "cache_len", None)
        cache = SlotCache(
            k=_sds(kv_shape, dtype, ns(kv_shape, kv_log)),
            v=_sds(kv_shape, dtype, ns(kv_shape, kv_log)),
            lengths=_sds((L, S_, B), jnp.int32,
                         ns((L, S_, B), (None, "kv_slot", "batch"))),
            pos=_sds((L, S_, B, cap), jnp.int32,
                     ns((L, S_, B, cap), (None, "kv_slot", "batch", "cache_len"))),
            positions=_sds((B,), jnp.int32, ns((B,), ("batch",))),
        )
    ssm_state = conv_state = None
    if cfg.family in ("ssm", "hybrid"):
        s = cfg.ssm
        st_shape = (L, B, s.num_heads, s.head_dim, s.state_size)
        ssm_state = _sds(st_shape, jnp.float32,
                         ns(st_shape, (None, "batch", "heads", None, None)))
        cv_shape = (L, B, s.conv_width - 1,
                    s.d_inner + 2 * s.n_groups * s.state_size)
        conv_state = _sds(cv_shape, dtype,
                          ns(cv_shape, (None, "batch", None, "ff")))
    cross_k = cross_v = None
    if cfg.is_encoder_decoder:
        ck = (L, B, cfg.encoder_seq_len, cfg.n_kv_heads, cfg.head_dim)
        cross_k = _sds(ck, dtype, ns(ck, (None, "batch", None, "kv_heads", None)))
        cross_v = _sds(ck, dtype, ns(ck, (None, "batch", None, "kv_heads", None)))
    return api.ServeState(
        cache=cache, ssm_state=ssm_state, conv_state=conv_state,
        cross_k=cross_k, cross_v=cross_v,
        last_tokens=_sds((B,), jnp.int32, ns((B,), ("batch",))),
        decode_steps=_sds((), jnp.int32, NamedSharding(mesh, P())),
    )


def plan_arrays_concrete(plan: Optional[HeadPlacement], cfg: ModelConfig,
                         rules: ShardingRules) -> Optional[PlanArrays]:
    if plan is None:
        return None
    pa = PlanArrays.from_plan(plan)
    mesh = rules.mesh
    slot_spec = NamedSharding(
        mesh, P(None, guarded(rules, plan.n_slots, "kv_slot")))
    rep = NamedSharding(mesh, P(None, None))
    return PlanArrays(
        slot_head=jax.device_put(pa.slot_head, slot_spec),
        replica_idx=jax.device_put(pa.replica_idx, slot_spec),
        replica_count=jax.device_put(pa.replica_count, slot_spec),
        first_slot=jax.device_put(pa.first_slot, rep),
    )


# ---------------------------------------------------------------------------
# Cell artifacts: (fn, args, donate) per step kind
# ---------------------------------------------------------------------------


@dataclass
class CellArtifacts:
    fn: Any  # python callable (pre-jit)
    args: Tuple  # SDS / concrete args
    donate_argnums: Tuple[int, ...]
    in_shardings: Any
    kind: str  # train | prefill | decode
    rules: ShardingRules
    meta: Dict[str, Any]


def build_cell(cfg: ModelConfig, shape: InputShape, mesh,
               planner_mode: str = "fairkv_dp", extra_copies: int = 4,
               dtype=BF16, weights_2d: bool = False,
               quantize: Optional[bool] = None) -> CellArtifacts:
    n_model = mesh.shape["model"]
    ccfg = cell_ccfg(cfg, shape)
    if quantize is None:
        # auto: bf16 1D-TP weight residency above ~10 GB/chip -> int8 weights
        # (production practice for >=100B on 16 GiB v5e; see serving/quant.py)
        quantize = cfg.param_count() * 2 / n_model > 10e9
    if shape.kind == "train":
        rules = train_rules(mesh)
        p_sds = _with_shardings(params_sds(cfg, shape, dtype), rules, "train")
        o_sds = opt_sds(p_sds)
        b_sds = batch_sds(cfg, shape, rules)
        ocfg = OptimizerConfig()

        def fn(params, opt_state, batch):
            with use_rules(rules):
                return train_step(params, opt_state, batch, cfg, ocfg,
                                  remat=True)

        return CellArtifacts(fn=fn, args=(p_sds, o_sds, b_sds),
                             donate_argnums=(0, 1),
                             in_shardings=None, kind="train", rules=rules,
                             meta={"ccfg": ccfg})

    plan = cell_plan(cfg, n_model, planner_mode, extra_copies,
                     batch_cap=shape.global_batch)
    long_ctx = shape.name == "long_500k"
    rules = serve_rules(mesh, long_context=long_ctx, weights_2d=weights_2d)
    sp_sds = _with_shardings(
        serve_params_sds(cfg, shape, plan, dtype, quantize=quantize),
        rules, "serve")
    pa = plan_arrays_concrete(plan, cfg, rules) if plan is not None else None

    if shape.kind == "prefill":
        b_sds = batch_sds(cfg, shape, rules)

        def fn(serve_params, batch, plan_arrays):
            with use_rules(rules):
                return api.prefill(serve_params, batch, cfg, plan_arrays, ccfg)

        return CellArtifacts(fn=fn, args=(sp_sds, b_sds, pa),
                             donate_argnums=(),
                             in_shardings=None, kind="prefill", rules=rules,
                             meta={"ccfg": ccfg, "plan": plan,
                                   "weights_2d": weights_2d,
                                   "quantize": quantize})

    # decode
    st_sds = serve_state_sds(cfg, shape, plan, ccfg, rules, dtype)

    def fn(serve_params, state, plan_arrays):
        with use_rules(rules):
            return api.decode_step(serve_params, state, cfg, plan_arrays, ccfg)

    return CellArtifacts(fn=fn, args=(sp_sds, st_sds, pa),
                         donate_argnums=(1,),
                         in_shardings=None, kind="decode", rules=rules,
                         meta={"ccfg": ccfg, "plan": plan,
                               "weights_2d": weights_2d,
                               "quantize": quantize})
