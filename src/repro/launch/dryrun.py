import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this prints/records:
- memory_analysis()  — per-device bytes (proves the cell fits a v5e chip)
- cost_analysis()    — per-device FLOPs / bytes accessed
- the collective schedule (op → bytes) parsed from the compiled HLO

Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json, which the
roofline report (benchmarks/roofline.py) consumes.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun [--arch A] [--shape S]
        [--mesh single|multi|both] [--planner fairkv_dp|sha|fairkv_nodp]
"""

import argparse
import gc
import json
import time
import traceback

import jax

from repro.configs import ALL_ARCHS, SHAPES, get_config
from repro.distributed.hlo_stats import collective_stats, while_body_stats
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_cell

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             planner_mode: str = "fairkv_dp", verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape_name in cfg.shape_skips:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": cfg.shape_skips[shape_name]}
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    cell = build_cell(cfg, shape, mesh, planner_mode=planner_mode)
    jitted = jax.jit(cell.fn, donate_argnums=cell.donate_argnums)
    with mesh:
        lowered = jitted.lower(*cell.args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    colls = collective_stats(hlo)
    bodies = while_body_stats(hlo)
    # XLA:CPU emulates bf16 (and int8-dequant) matmuls by f32 upcasts of the
    # operands — a CPU-only artifact (TPU bf16 is MXU-native; int8 dequant
    # fuses into the weight read).  Subtract the bound (f32 copy = 2x bf16
    # bytes, 4x int8 bytes) to estimate the TPU peak; validated against
    # f32-compiled cells (EXPERIMENTS.md §Dry-run).
    import numpy as _np
    emu_bytes = 0
    for leaf in jax.tree.leaves(cell.args):
        dt = getattr(leaf, "dtype", None)
        if dt not in (jax.numpy.bfloat16, jax.numpy.int8):
            continue
        shd = getattr(leaf, "sharding", None)
        per_dev = (int(_np.prod(shd.shard_shape(leaf.shape)))
                   if shd is not None else leaf.size)
        emu_bytes += per_dev * (2 if dt == jax.numpy.bfloat16 else 1) * 2
        if dt == jax.numpy.int8:
            emu_bytes += per_dev * 2  # int8 -> f32 is 4x
    raw_peak = (ma.argument_size_in_bytes + ma.temp_size_in_bytes
                + ma.output_size_in_bytes - ma.alias_size_in_bytes)
    adj_peak = (ma.argument_size_in_bytes + ma.output_size_in_bytes
                - ma.alias_size_in_bytes
                + max(0, ma.temp_size_in_bytes - emu_bytes))
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "kind": cell.kind,
        "planner": planner_mode,
        "status": "ok",
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "weights_2d": bool(cell.meta.get("weights_2d", False)),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "emulation_bound_bytes": int(emu_bytes),
            "peak_per_device_gb_cpuraw": round(raw_peak / 1e9, 3),
            "peak_per_device_gb": round(adj_peak / 1e9, 3),
        },
        "cost": {
            "flops_per_device": ca.get("flops"),
            "bytes_per_device": ca.get("bytes accessed"),
        },
        "collectives": colls,
        "while_bodies": bodies,
    }
    if verbose:
        mem = rec["memory"]
        print(f"  args {mem['argument_bytes']/1e9:8.2f} GB | "
              f"temp {mem['temp_bytes']/1e9:8.2f} GB | "
              f"peak {mem['peak_per_device_gb']:8.2f} GB/dev | "
              f"flops/dev {rec['cost']['flops_per_device'] or 0:.3e} | "
              f"lower {t_lower:5.1f}s compile {t_compile:5.1f}s")
        tot = sum(c["bytes"] for c in colls.values())
        print(f"  collectives: " + ", ".join(
            f"{k}×{v['count']} ({v['bytes']/1e6:.1f} MB)"
            for k, v in sorted(colls.items())) +
            f" | total {tot/1e6:.1f} MB/dev")
    return rec


def run_executor_audit(arch: str, out_dir: str,
                       planner_mode: str = "fairkv_dp") -> dict:
    """Lower the `mesh` executor's decode StepFn (DESIGN.md §10) on the
    production (data=16, model=16) mesh from abstract args and record its
    per-device collective schedule.

    This audits the *serving* execution path the Engine actually runs —
    unlike the shape cells above, which lower the raw step functions under
    GSPMD.  The §10 contract is visible in the numbers: exactly one psum
    (all-reduce) per attention layer from the o-projection, and no
    weight all-gathers in the decode hot loop.
    """
    import jax.numpy as jnp
    from repro.cache.slot_cache import SlotCache
    from repro.compression.base import CompressionConfig
    from repro.exec.mesh import MeshExecutor
    from repro.launch.specs import cell_plan, serve_params_sds
    from repro.api import PlanArrays, ServeState

    cfg = get_config(arch)
    shape = SHAPES["decode_32k"]
    mesh = make_production_mesh()  # (data=16, model=16)
    n_model = mesh.shape["model"]
    ccfg = CompressionConfig(policy="ada_snapkv", budget=1024,
                             alpha_max=1.5, decode_margin=64)
    plan = cell_plan(cfg, n_model, planner_mode,
                     batch_cap=shape.global_batch)
    pa = PlanArrays.from_plan(plan)
    sp_sds = serve_params_sds(cfg, shape, plan, jnp.bfloat16, quantize=False)
    B, L, S = shape.global_batch, cfg.n_layers, plan.n_slots
    cap, Dh = ccfg.static_capacity(), cfg.head_dim
    sds = jax.ShapeDtypeStruct
    state_sds = ServeState(
        cache=SlotCache(
            k=sds((L, S, B, cap, Dh), jnp.bfloat16),
            v=sds((L, S, B, cap, Dh), jnp.bfloat16),
            lengths=sds((L, S, B), jnp.int32),
            pos=sds((L, S, B, cap), jnp.int32),
            positions=sds((B,), jnp.int32)),
        ssm_state=None, conv_state=None, cross_k=None, cross_v=None,
        last_tokens=sds((B,), jnp.int32), decode_steps=sds((), jnp.int32))
    executor = MeshExecutor(cfg, ccfg, mesh=mesh)
    t0 = time.time()
    hlo = executor.decode_hlo(sp_sds, state_sds, pa,
                              sds((B,), jnp.int32))
    colls = collective_stats(hlo)
    rec = {"arch": arch, "kind": "executor_decode", "planner": planner_mode,
           "mesh": "single", "shape": "decode_32k", "status": "ok",
           "compile_s": round(time.time() - t0, 2),
           "collectives": colls,
           "while_bodies": while_body_stats(hlo)}
    total = sum(c["bytes"] for c in colls.values())
    print("  executor decode StepFn collectives: " + ", ".join(
        f"{k}×{v['count']} ({v['bytes'] / 1e6:.2f} MB)"
        for k, v in sorted(colls.items())) + f" | total {total / 1e6:.2f} MB/dev")
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir,
                           f"{arch}__executor_decode.json"), "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="default: all")
    ap.add_argument("--shape", default=None, help="default: all applicable")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--planner", default="fairkv_dp",
                    choices=["sha", "fairkv_nodp", "fairkv_dp"])
    ap.add_argument("--executor-audit", action="store_true",
                    help="audit the mesh executor's decode StepFn "
                         "collectives instead of the shape-cell sweep "
                         "(requires --arch; dense attention archs)")
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args()

    if args.executor_audit:
        if not args.arch:
            raise SystemExit("--executor-audit requires --arch")
        run_executor_audit(args.arch, args.out, args.planner)
        return

    archs = [args.arch] if args.arch else ALL_ARCHS
    # cheap compiles first so partial sweeps still cover every arch
    default_order = ["decode_32k", "long_500k", "prefill_32k", "train_4k"]
    shapes = [args.shape] if args.shape else default_order
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)

    n_ok = n_skip = n_fail = 0
    for shape_name in shapes:
        for arch in archs:
            for multi in meshes:
                tag = f"{arch}__{shape_name}__{'multi' if multi else 'single'}"
                print(f"[{tag}] planner={args.planner}")
                try:
                    rec = run_cell(arch, shape_name, multi, args.planner)
                except Exception as e:  # record failures, keep going
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape_name,
                           "mesh": "multi" if multi else "single",
                           "status": "fail", "error": f"{type(e).__name__}: {e}"}
                status = rec["status"]
                n_ok += status == "ok"
                n_skip += status == "skipped"
                n_fail += status == "fail"
                if status == "skipped":
                    print(f"  SKIP: {rec['reason'][:100]}")
                with open(os.path.join(args.out, tag + ".json"), "w") as f:
                    json.dump(rec, f, indent=1)
                gc.collect()
    print(f"\ndone: {n_ok} ok, {n_skip} skipped (documented), {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
