"""Training driver: ``python -m repro.launch.train --arch <id> [--smoke]``.

On this CPU container use ``--smoke`` (reduced config); on a real cluster
the same driver runs the full config under the production mesh with the
train_rules sharding, checkpoint/restart supervision, straggler detection,
and optional int8 error-feedback gradient compression across pods.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config, get_smoke_config
from repro.configs.base import InputShape
from repro.models import init_params
from repro.training import (
    OptimizerConfig,
    SupervisorConfig,
    SyntheticLM,
    TrainingSupervisor,
    init_optimizer,
    make_train_step,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + tiny shapes (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    shape = (InputShape("cli", args.seq, args.batch, "train") if args.smoke
             else SHAPES["train_4k"])
    data = SyntheticLM(cfg, shape)
    dtype = jnp.float32 if args.smoke else jnp.bfloat16

    params = init_params(cfg, jax.random.PRNGKey(0), dtype=dtype,
                         max_seq_len=max(shape.seq_len, 4096))
    opt = init_optimizer(params)
    ocfg = OptimizerConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5),
                           total_steps=args.steps)
    step_fn = jax.jit(make_train_step(cfg, ocfg, remat=True),
                      donate_argnums=(0, 1))

    sup = TrainingSupervisor(SupervisorConfig(
        checkpoint_dir=args.ckpt_dir, checkpoint_every=args.ckpt_every))
    start = 0
    state = {"params": params, "opt": opt}
    if args.resume:
        start, state = sup.restore_or_init(state)
        print(f"resumed from step {start}")

    def one_step(st, batch):
        p, o, m = step_fn(st["params"], st["opt"], batch)
        return {"params": p, "opt": o}, m

    t0 = time.time()
    losses = []
    for s in range(start, args.steps):
        state, metrics = one_step(state, data.get_batch(s))
        loss = float(metrics["loss"])
        losses.append(loss)
        if (s + 1) % max(1, args.steps // 20) == 0 or s == start:
            dt = time.time() - t0
            print(f"step {s + 1:5d} loss {loss:8.4f} "
                  f"gnorm {float(metrics['grad_norm']):7.3f} "
                  f"lr {float(metrics['lr']):.2e} ({dt:.1f}s)", flush=True)
        if (s + 1) % args.ckpt_every == 0:
            sup.ckpt.save(s + 1, state)
    sup.ckpt.wait()
    sup.emergency_save(args.steps, state)
    print(f"done: loss {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"({time.time() - t0:.1f}s, ckpt at {args.ckpt_dir})")


if __name__ == "__main__":
    main()
