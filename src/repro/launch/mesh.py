"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (one v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips.

A FUNCTION (not a module constant) so importing never touches jax device
state; the dry-run sets ``xla_force_host_platform_device_count=512`` before
any jax import (see launch/dryrun.py lines 1-2).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def _axis_type_kwargs(n_axes: int) -> dict:
    """``axis_types`` only where the installed jax has it (added after
    0.4.x; older versions default every axis to Auto anyway)."""
    at = getattr(jax.sharding, "AxisType", None)
    return {"axis_types": (at.Auto,) * n_axes} if at is not None else {}


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_host_mesh(model: int = 1, data: int = 1) -> Mesh:
    """Small (data, model) mesh over however many (host) devices exist —
    tests/examples/the ``mesh`` executor on a dev box.

    Oversubscription is a real error, not an assert (asserts vanish under
    ``python -O``): requesting more mesh slots than devices exist would
    otherwise surface as an opaque failure deep inside ``make_mesh``.
    """
    n = len(jax.devices())
    if model * data > n:
        raise ValueError(
            f"requested mesh (data={data}, model={model}) = {model * data} "
            f"devices, but only {n} available; set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=N before "
            f"the first jax import to fake host devices")
    return jax.make_mesh((data, model), ("data", "model"),
                         **_axis_type_kwargs(2))
