"""Serving runtime: prefill + compression + FairKV slot-layout decode,
plus the continuous-batching request scheduler (DESIGN.md §7)."""
from repro.serving.engine import (  # noqa: F401
    ServeState,
    decode_step,
    first_weights,
    init_serve_state,
    prefill,
    reset_state_rows,
    slotify_params,
    splice_state,
)
from repro.serving.request import (  # noqa: F401
    Request,
    RequestState,
    latency_percentiles,
    poisson_arrivals,
    synthesize_requests,
)
from repro.serving.cache_backend import (  # noqa: F401
    CacheBackend,
    PoolExhausted,
    SlotBackend,
    make_cache_backend,
)
from repro.serving.scheduler import (  # noqa: F401
    ReplanTrigger,
    RowFreelist,
    Scheduler,
    SchedulerConfig,
)
from repro.serving.speculation import SpeculationConfig  # noqa: F401
