"""Serving runtime: prefill + compression + FairKV slot-layout decode."""
from repro.serving.engine import (  # noqa: F401
    ServeState,
    decode_step,
    first_weights,
    prefill,
    slotify_params,
)
