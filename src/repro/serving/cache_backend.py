"""Cache backends: the storage strategy behind the serving engine.

A ``CacheBackend`` owns how per-(slot, row) KV is *stored* and *accounted* —
not how it is computed: prefill, the decode math, ownership, and compression
are backend-independent.  Two built-ins register here and in
``repro.paging.backend``:

- ``"slot"``  — the dense slot cache (DESIGN.md §2): every (slot, row)
  padded to static capacity ``C``.  Simple, zero bookkeeping, memory cost
  independent of realized compression.
- ``"paged"`` — the block-pool cache (DESIGN.md §9): fixed-size blocks
  allocated proportional to realized retained lengths; admission is a
  free-*block* budget and running dry preempts instead of corrupting.

Backends are registered with ``@repro.api.register_cache_backend`` and
selected by ``EngineConfig.cache_backend``; the scheduler and the `Engine`
facade call only this interface, so a third-party backend (e.g. quantized
blocks, CPU offload) plugs in without touching either.

Contract notes: state-transforming methods are *pure* on the ServeState
pytree but may mutate backend-internal host bookkeeping (allocator state);
``splice`` / ``prepare_decode`` may raise ``PoolExhausted``, which the
scheduler treats as a preemption signal; ``migrate_cache`` returns a
``(candidate_cache, commit)`` pair so a replan can be scored and *rejected*
without leaking backend bookkeeping.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence, Tuple

import numpy as np

from repro.api.registry import register_cache_backend
from repro.cache.slot_cache import PlanArrays, migrate_cache
from repro.compression.base import CompressionConfig
from repro.compression.policies import (
    layer_keep_bound,
    projected_request_tokens,
)
from repro.configs.base import ModelConfig
from repro.obs import NULL_OBS
from repro.paging.block_pool import PagingConfig, PoolExhausted  # noqa: F401
from repro.serving import engine as _serve
from repro.serving.request import Request


class CacheBackend:
    """Interface; see module docstring for the contract.

    Budget geometry (DESIGN.md §10): ``n_shards`` is the plan's model-shard
    count, so admission can be enforced **per model shard** — the resource
    that actually runs out on a sharded mesh is one shard's memory, not the
    global sum.  ``max_live_tokens_per_shard`` is the slot backend's
    per-shard admission budget (None disables the check);
    ``pool_partitions`` / ``row_partitions`` split the paged backend's
    block pool into per-(model shard, data shard) partitions (the mesh
    executor's layout, where each partition lives on one device and its
    free list is that shard's budget).
    """

    name: str = "?"

    def __init__(self, model_cfg: ModelConfig, ccfg: CompressionConfig,
                 max_live_tokens: Optional[int] = None,
                 paging: Optional[PagingConfig] = None,
                 n_shards: int = 1,
                 max_live_tokens_per_shard: Optional[int] = None,
                 pool_partitions: int = 1,
                 row_partitions: int = 1,
                 obs=None):
        self.cfg = model_cfg
        self.ccfg = ccfg
        self.max_live_tokens = max_live_tokens
        self.paging = paging or PagingConfig()
        self.n_shards = int(n_shards)
        self.max_live_tokens_per_shard = max_live_tokens_per_shard
        self.pool_partitions = int(pool_partitions)
        self.row_partitions = int(row_partitions)
        # observability handle (DESIGN.md §12); NULL_OBS unless the Engine
        # facade threads its live Obs through
        self.obs = obs if obs is not None else NULL_OBS

    # ---- state lifecycle ---------------------------------------------------

    def init_state(self, pa: PlanArrays, batch: int, dtype):
        """Empty B-row ServeState in this backend's layout."""
        raise NotImplementedError

    def from_prefill(self, state, pa: PlanArrays):
        """Adopt a full-batch prefill result (one-shot mode)."""
        return state

    def splice(self, state, sub, rows):
        """Splice a prefilled slot-layout sub-state into ``rows``."""
        raise NotImplementedError

    def release_rows(self, state, rows):
        """Retire rows: clear state, reclaim backing memory."""
        raise NotImplementedError

    def prepare_decode(self, state, active: Optional[Sequence[int]],
                       n_tokens: int = 1):
        """Host hook before a decode tick: guarantee the next ``n_tokens``
        appends of every active row have backing storage (speculative
        ticks write up to k+1 tokens).  ``None`` = all rows."""
        return state

    def migrate_cache(self, cache, old_pa: PlanArrays, new_pa: PlanArrays,
                      active_rows: Optional[Sequence[int]] = None
                      ) -> Tuple[object, Callable[[], object]]:
        """Trial a re-layout under ``new_pa``.

        Returns ``(preview_lengths, commit)``: the candidate's (L, S, B)
        realized lengths — enough to score accept/reject — and a commit
        callback that materializes and returns the migrated cache (call it
        only on accept; rejected trials then never pay the full device
        re-layout).  Infeasibility (e.g. block rounding under the new
        ownership split) raises before scoring, never inside commit."""
        raise NotImplementedError

    # ---- admission accounting ----------------------------------------------

    def request_cost(self, req: Request) -> int:
        """Projected cost in backend units (tokens / blocks) — telemetry
        and fail-fast; an upper bound on what the request can ever pin."""
        raise NotImplementedError

    def admissible(self, state, req: Request,
                   pending: Sequence[Request] = ()) -> bool:
        """Do free resources cover the request's projected prefill need?

        ``pending`` are requests already accepted but not yet spliced into
        ``state`` (e.g. admitted earlier in the same frontend tick) — their
        projected charge counts against the budget too, so a burst of
        individually-admissible requests cannot jointly over-commit."""
        raise NotImplementedError

    def never_fits(self, req: Request) -> Optional[str]:
        """Reason string when the request cannot fit even an empty cache
        (fail fast at submit instead of head-of-line blocking), else None."""
        return None

    # ---- telemetry ---------------------------------------------------------

    def memory_stats(self, state) -> dict:
        raise NotImplementedError

    def sample_metrics(self, state) -> None:
        """Per-step gauge sampling hook (host-side, outside jit): record
        this backend's cache-pressure observables into ``self.obs``.  The
        scheduler calls it once per tick when observability is on; the
        default records nothing."""


@register_cache_backend("slot")
class SlotBackend(CacheBackend):
    """Dense static-capacity slot cache (the PR-1/PR-2 baseline layout).

    Admission budget is the projected live-token total.  The projection
    uses the per-policy prefill keep bounds (`layer_keep_bound`) — pool
    conservation makes imbalanced policies *much* cheaper than the old
    ``L·H·min(prompt+gen, C)`` static-capacity charge (see the audit note
    in DESIGN.md §7).
    """

    name = "slot"

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        self.pa: Optional[PlanArrays] = None  # for per-shard projection

    def init_state(self, pa, batch, dtype):
        self.pa = pa
        return _serve.init_serve_state(self.cfg, pa, batch, self.ccfg,
                                       dtype=dtype)

    def from_prefill(self, state, pa):
        self.pa = pa
        return state

    def splice(self, state, sub, rows):
        return _serve.splice_state(state, sub, rows)

    def release_rows(self, state, rows):
        return _serve.reset_state_rows(state, rows)

    def migrate_cache(self, cache, old_pa, new_pa, active_rows=None):
        migrated = migrate_cache(cache, old_pa, new_pa)

        def commit():
            self.pa = new_pa
            return migrated

        return migrated.lengths, commit

    def live_tokens(self, state) -> int:
        if state.cache is None:
            return 0
        return int(np.asarray(state.cache.lengths).sum())

    def per_shard_live(self, state) -> np.ndarray:
        """(n_shards,) realized Σ lengths per model shard."""
        if state.cache is None:
            return np.zeros(self.n_shards, np.int64)
        per_slot = np.asarray(state.cache.lengths).sum(axis=(0, 2))  # (S,)
        return per_slot.reshape(self.n_shards, -1).sum(axis=1)

    def per_shard_cost(self, req) -> np.ndarray:
        """(n_shards,) expected Σ-lengths a request adds per model shard.

        Each head's per-layer projected tokens (prefill keep bound / H plus
        decode growth, clipped at capacity) land on the shards holding its
        replicas, split ``1/r`` per replica — the expectation of the strided
        row split the runtime actually performs.  Requires a live plan
        (``init_state`` / ``from_prefill`` record it).
        """
        if self.cfg.attention_free or self.pa is None:
            return np.zeros(self.n_shards)
        sh = np.asarray(self.pa.slot_head)  # (L, S)
        rc = np.asarray(self.pa.replica_count)
        L, S = sh.shape
        H, cap = self.cfg.n_kv_heads, self.ccfg.static_capacity()
        row_cap = min(req.prompt_len + req.max_new_tokens, cap)
        cost = np.zeros(self.n_shards)
        for l in range(L):
            bound = layer_keep_bound(self.ccfg.policy, self.ccfg,
                                     req.prompt_len, H, l, L) / H
            per_head = min(bound + req.max_new_tokens, row_cap)
            w = np.where(sh[l] >= 0, per_head / rc[l], 0.0)  # (S,)
            cost += w.reshape(self.n_shards, -1).sum(axis=1)
        return cost

    def request_cost(self, req):
        if self.cfg.attention_free:
            return 0
        return projected_request_tokens(
            self.ccfg.policy, self.ccfg, req.prompt_len, req.max_new_tokens,
            self.cfg.n_layers, self.cfg.n_kv_heads)

    def admissible(self, state, req, pending=()):
        if self.max_live_tokens is not None:
            reserved = sum(self.request_cost(p) for p in pending)
            if (self.live_tokens(state) + reserved + self.request_cost(req)
                    > self.max_live_tokens):
                return False
        if (self.max_live_tokens_per_shard is not None
                and not self.cfg.attention_free and self.pa is not None):
            # per-model-shard budget (DESIGN.md §10): the bottleneck shard
            # gates admission, so an imbalanced plan saturates one shard's
            # budget while balanced plans keep admitting — the fig8 signal
            load = self.per_shard_live(state) + self.per_shard_cost(req)
            for p in pending:
                load = load + self.per_shard_cost(p)
            if (load > self.max_live_tokens_per_shard).any():
                return False
        return True

    def never_fits(self, req):
        if self.max_live_tokens is not None:
            cost = self.request_cost(req)
            if cost > self.max_live_tokens:
                return (f"projected cost {cost} tokens exceeds "
                        f"max_live_tokens={self.max_live_tokens} even on "
                        f"an empty cache")
        if (self.max_live_tokens_per_shard is not None
                and not self.cfg.attention_free and self.pa is not None):
            worst = self.per_shard_cost(req).max()
            if worst > self.max_live_tokens_per_shard:
                return (f"projected per-shard cost {worst:.0f} tokens "
                        f"exceeds max_live_tokens_per_shard="
                        f"{self.max_live_tokens_per_shard} even on an "
                        f"empty cache")
        return None

    def sample_metrics(self, state) -> None:
        if state.cache is None:
            return
        m = self.obs.metrics
        live = self.live_tokens(state)
        lens = np.asarray(state.cache.lengths)
        cap = int(np.prod(lens.shape)) * self.ccfg.static_capacity()
        m.gauge("cache_live_tokens",
                help="Σ retained KV tokens across the live cache"
                ).set(live)
        m.gauge("cache_utilization",
                help="live tokens / static slot capacity (slot backend "
                     "pressure; the paged analog is pool_free_blocks)"
                ).set(live / max(1, cap))

    def memory_stats(self, state) -> dict:
        if state.cache is None:
            return {"backend": self.name, "cache_bytes": 0, "live_tokens": 0}
        c = state.cache
        L, S, B, C, Dh = c.k.shape
        item = c.k.dtype.itemsize
        live = int(np.asarray(c.lengths).sum())
        return {
            "backend": self.name,
            "cache_bytes": int(2 * L * S * B * C * Dh * item),
            "live_tokens": live,
            "capacity_tokens": int(L * S * B * C),
            "utilization": live / max(1, L * S * B * C),
        }


def make_cache_backend(name: str, model_cfg: ModelConfig,
                       ccfg: CompressionConfig,
                       max_live_tokens: Optional[int] = None,
                       paging: Optional[PagingConfig] = None,
                       n_shards: int = 1,
                       max_live_tokens_per_shard: Optional[int] = None,
                       pool_partitions: int = 1,
                       row_partitions: int = 1,
                       obs=None) -> CacheBackend:
    """Instantiate a registered backend by name (geometry kwargs: see the
    `CacheBackend` docstring)."""
    from repro.api.registry import get_cache_backend
    return get_cache_backend(name)(
        model_cfg, ccfg, max_live_tokens=max_live_tokens, paging=paging,
        n_shards=n_shards,
        max_live_tokens_per_shard=max_live_tokens_per_shard,
        pool_partitions=pool_partitions, row_partitions=row_partitions,
        obs=obs)
