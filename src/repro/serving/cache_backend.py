"""Cache backends: the storage strategy behind the serving engine.

A ``CacheBackend`` owns how per-(slot, row) KV is *stored* and *accounted* —
not how it is computed: prefill, the decode math, ownership, and compression
are backend-independent.  Two built-ins register here and in
``repro.paging.backend``:

- ``"slot"``  — the dense slot cache (DESIGN.md §2): every (slot, row)
  padded to static capacity ``C``.  Simple, zero bookkeeping, memory cost
  independent of realized compression.
- ``"paged"`` — the block-pool cache (DESIGN.md §9): fixed-size blocks
  allocated proportional to realized retained lengths; admission is a
  free-*block* budget and running dry preempts instead of corrupting.

Backends are registered with ``@repro.api.register_cache_backend`` and
selected by ``EngineConfig.cache_backend``; the scheduler and the `Engine`
facade call only this interface, so a third-party backend (e.g. quantized
blocks, CPU offload) plugs in without touching either.

Contract notes: state-transforming methods are *pure* on the ServeState
pytree but may mutate backend-internal host bookkeeping (allocator state);
``splice`` / ``prepare_decode`` may raise ``PoolExhausted``, which the
scheduler treats as a preemption signal; ``migrate_cache`` returns a
``(candidate_cache, commit)`` pair so a replan can be scored and *rejected*
without leaking backend bookkeeping.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence, Tuple

import numpy as np

from repro.api.registry import register_cache_backend
from repro.cache.slot_cache import PlanArrays, migrate_cache
from repro.compression.base import CompressionConfig
from repro.compression.policies import projected_request_tokens
from repro.configs.base import ModelConfig
from repro.paging.block_pool import PagingConfig, PoolExhausted  # noqa: F401
from repro.serving import engine as _serve
from repro.serving.request import Request


class CacheBackend:
    """Interface; see module docstring for the contract."""

    name: str = "?"

    def __init__(self, model_cfg: ModelConfig, ccfg: CompressionConfig,
                 max_live_tokens: Optional[int] = None,
                 paging: Optional[PagingConfig] = None):
        self.cfg = model_cfg
        self.ccfg = ccfg
        self.max_live_tokens = max_live_tokens
        self.paging = paging or PagingConfig()

    # ---- state lifecycle ---------------------------------------------------

    def init_state(self, pa: PlanArrays, batch: int, dtype):
        """Empty B-row ServeState in this backend's layout."""
        raise NotImplementedError

    def from_prefill(self, state, pa: PlanArrays):
        """Adopt a full-batch prefill result (one-shot mode)."""
        return state

    def splice(self, state, sub, rows):
        """Splice a prefilled slot-layout sub-state into ``rows``."""
        raise NotImplementedError

    def release_rows(self, state, rows):
        """Retire rows: clear state, reclaim backing memory."""
        raise NotImplementedError

    def prepare_decode(self, state, active: Optional[Sequence[int]]):
        """Host hook before a decode tick: guarantee the next append of
        every active row has backing storage.  ``None`` = all rows."""
        return state

    def migrate_cache(self, cache, old_pa: PlanArrays, new_pa: PlanArrays,
                      active_rows: Optional[Sequence[int]] = None
                      ) -> Tuple[object, Callable[[], object]]:
        """Trial a re-layout under ``new_pa``.

        Returns ``(preview_lengths, commit)``: the candidate's (L, S, B)
        realized lengths — enough to score accept/reject — and a commit
        callback that materializes and returns the migrated cache (call it
        only on accept; rejected trials then never pay the full device
        re-layout).  Infeasibility (e.g. block rounding under the new
        ownership split) raises before scoring, never inside commit."""
        raise NotImplementedError

    # ---- admission accounting ----------------------------------------------

    def request_cost(self, req: Request) -> int:
        """Projected cost in backend units (tokens / blocks) — telemetry
        and fail-fast; an upper bound on what the request can ever pin."""
        raise NotImplementedError

    def admissible(self, state, req: Request) -> bool:
        """Do free resources cover the request's projected prefill need?"""
        raise NotImplementedError

    def never_fits(self, req: Request) -> Optional[str]:
        """Reason string when the request cannot fit even an empty cache
        (fail fast at submit instead of head-of-line blocking), else None."""
        return None

    # ---- telemetry ---------------------------------------------------------

    def memory_stats(self, state) -> dict:
        raise NotImplementedError


@register_cache_backend("slot")
class SlotBackend(CacheBackend):
    """Dense static-capacity slot cache (the PR-1/PR-2 baseline layout).

    Admission budget is the projected live-token total.  The projection
    uses the per-policy prefill keep bounds (`layer_keep_bound`) — pool
    conservation makes imbalanced policies *much* cheaper than the old
    ``L·H·min(prompt+gen, C)`` static-capacity charge (see the audit note
    in DESIGN.md §7).
    """

    name = "slot"

    def init_state(self, pa, batch, dtype):
        return _serve.init_serve_state(self.cfg, pa, batch, self.ccfg,
                                       dtype=dtype)

    def splice(self, state, sub, rows):
        return _serve.splice_state(state, sub, rows)

    def release_rows(self, state, rows):
        return _serve.reset_state_rows(state, rows)

    def migrate_cache(self, cache, old_pa, new_pa, active_rows=None):
        migrated = migrate_cache(cache, old_pa, new_pa)
        return migrated.lengths, lambda: migrated

    def live_tokens(self, state) -> int:
        if state.cache is None:
            return 0
        return int(np.asarray(state.cache.lengths).sum())

    def request_cost(self, req):
        if self.cfg.attention_free:
            return 0
        return projected_request_tokens(
            self.ccfg.policy, self.ccfg, req.prompt_len, req.max_new_tokens,
            self.cfg.n_layers, self.cfg.n_kv_heads)

    def admissible(self, state, req):
        if self.max_live_tokens is None:
            return True
        return (self.live_tokens(state) + self.request_cost(req)
                <= self.max_live_tokens)

    def never_fits(self, req):
        if self.max_live_tokens is None:
            return None
        cost = self.request_cost(req)
        if cost > self.max_live_tokens:
            return (f"projected cost {cost} tokens exceeds max_live_tokens="
                    f"{self.max_live_tokens} even on an empty cache")
        return None

    def memory_stats(self, state) -> dict:
        if state.cache is None:
            return {"backend": self.name, "cache_bytes": 0, "live_tokens": 0}
        c = state.cache
        L, S, B, C, Dh = c.k.shape
        item = c.k.dtype.itemsize
        live = int(np.asarray(c.lengths).sum())
        return {
            "backend": self.name,
            "cache_bytes": int(2 * L * S * B * C * Dh * item),
            "live_tokens": live,
            "capacity_tokens": int(L * S * B * C),
            "utilization": live / max(1, L * S * B * C),
        }


def make_cache_backend(name: str, model_cfg: ModelConfig,
                       ccfg: CompressionConfig,
                       max_live_tokens: Optional[int] = None,
                       paging: Optional[PagingConfig] = None) -> CacheBackend:
    """Instantiate a registered backend by name."""
    from repro.api.registry import get_cache_backend
    return get_cache_backend(name)(model_cfg, ccfg,
                                   max_live_tokens=max_live_tokens,
                                   paging=paging)
