"""`SpeculationConfig`: self-speculative decoding knobs (DESIGN.md §16).

The draft model is a layer-truncated *view* of the target — the first
``draft_layers`` transformer layers followed by the target's own final
norm + unembedding (`repro.models.draft_view`), reading and writing the
same paged cache.  Propose runs ``k`` draft steps per tick; one
multi-query verify pass through the full model checks the window and
commits the accepted prefix plus the target's own next token, so every
tick commits between 1 and ``k + 1`` tokens and the committed stream is
bit-identical to single-token greedy decode at any acceptance rate.

``max_k`` bounds the speculation depth; with ``adaptive`` on, each live
request carries its own depth that shrinks toward ``min_k`` when its
realized acceptance falls below ``low_acceptance`` and grows back toward
``max_k`` above ``high_acceptance``.  Depth changes are *traced values*
of the propose/verify StepFns, so adaptation never recompiles.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class SpeculationConfig:
    """Knobs for executor-level speculative decoding.

    ``draft_layers=0`` means "all layers": the draft *is* the target, so
    every proposal is accepted — useful as a correctness baseline and for
    parity tests, not a speedup.  Real configs set ``draft_layers`` to a
    small prefix of the stack (e.g. a quarter of ``n_layers``).
    """

    enabled: bool = False
    max_k: int = 4  # speculation depth ceiling (tokens proposed per tick)
    draft_layers: int = 0  # early-exit depth of the draft; 0 -> full model
    adaptive: bool = True  # per-request depth control from acceptance
    min_k: int = 1  # adaptive floor
    low_acceptance: float = 0.3  # shrink depth below this acceptance
    high_acceptance: float = 0.8  # grow depth at/above this acceptance

    def __post_init__(self):
        if self.max_k < 1:
            raise ValueError(f"max_k must be >= 1, got {self.max_k}")
        if not (1 <= self.min_k <= self.max_k):
            raise ValueError(
                f"min_k must satisfy 1 <= min_k <= max_k, got "
                f"min_k={self.min_k} max_k={self.max_k}")
        if self.draft_layers < 0:
            raise ValueError(
                f"draft_layers must be >= 0 (0 = all layers), got "
                f"{self.draft_layers}")
        if not (0.0 <= self.low_acceptance <= self.high_acceptance <= 1.0):
            raise ValueError(
                f"need 0 <= low_acceptance <= high_acceptance <= 1, got "
                f"low={self.low_acceptance} high={self.high_acceptance}")
