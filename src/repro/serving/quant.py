"""Weight-only int8 quantization for serving (AWQ/Marlin-style, TPU-adapted).

Symmetric per-output-channel int8 with a bf16 dequant at use: weight HBM
residency and read bandwidth halve vs bf16 — decisive for ≥100B params on
16 GiB chips (qwen1.5-110b: 13.9 GB/chip bf16 → 6.9 GB int8 at TP=16) and a
direct reduction of the decode memory-roofline term.

``QTensor`` is a pytree; ``deq`` materializes bf16 transiently per use (the
XLA fusion keeps it in registers ahead of the MXU on TPU).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Union

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclass
class QTensor:
    q: jnp.ndarray  # int8, same shape as the original weight
    scale: jnp.ndarray  # f32, broadcastable (per-out-channel)

    @property
    def shape(self):
        return self.q.shape

    @property
    def dtype(self):
        return jnp.bfloat16


def quantize_weight(w: jnp.ndarray, channel_axis: int = -1) -> QTensor:
    """Symmetric per-channel int8 along ``channel_axis``."""
    wf = w.astype(jnp.float32)
    reduce_axes = tuple(a for a in range(w.ndim)
                        if a != (channel_axis % w.ndim))
    amax = jnp.max(jnp.abs(wf), axis=reduce_axes, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(wf / scale), -127, 127).astype(jnp.int8)
    return QTensor(q=q, scale=scale)


def deq(w: Union[QTensor, jnp.ndarray]) -> jnp.ndarray:
    """Dequantize (or pass through a plain array)."""
    if isinstance(w, QTensor):
        return (w.q.astype(jnp.float32) * w.scale).astype(jnp.bfloat16)
    return w


# weights worth quantizing in the serve tree (big 2D+ projections)
_QUANT_KEYS = {
    "wq_s", "wk_s", "wv_s", "wo_s", "w1", "w2", "w3",
    "we1", "we2", "we3", "in_proj", "out_proj", "embed", "head",
    "c_wq", "c_wk", "c_wv", "c_wo", "wq", "wk", "wv", "wo",
}
# channel axis per key (the output/channel dim the scale attaches to)
_CHANNEL_AXIS = {
    "wq_s": 0, "wk_s": 0, "wv_s": 0, "wo_s": 3,
    "w1": 1, "w3": 1, "w2": 1,
    "we1": 2, "we3": 2, "we2": 2,
    "in_proj": 1, "out_proj": 1, "embed": 0, "head": 0,
    "c_wq": 1, "c_wk": 1, "c_wv": 1, "c_wo": 2,
    "wq": 1, "wk": 1, "wv": 1, "wo": 2,
}


def quantize_serve_params(serve_params: Any) -> Any:
    """Quantize the large projection weights of a serve-layout param tree."""

    def walk(node):
        if isinstance(node, dict):
            return {k: (QTensor(*_q(v, k)) if k in _QUANT_KEYS and _is_big(v)
                        else walk(v))
                    for k, v in node.items()}
        if isinstance(node, list):
            return [walk(v) for v in node]
        return node

    def _is_big(v):
        return hasattr(v, "ndim") and v.ndim >= 2 and v.size >= 1 << 16

    def _q(v, k):
        t = quantize_weight(v, _CHANNEL_AXIS.get(k, -1))
        return t.q, t.scale

    return walk(serve_params)
