"""Serving engine: prefill (+compression) → slot-layout cache → decode.

The FairKV plan enters the runtime in two places:

1. **Weight layout** — ``slotify_params`` permutes/replicates the attention
   projections into slot layout once at load time: per layer,
   ``wq: (S, D, G, Dh)``, ``wk/wv: (S, D, Dh)``, ``wo: (S, G, Dh, D)`` with
   slot s carrying kv-head ``slot_head[l, s]`` (zeros for empty slots).  The
   slot dim shards over the "model" mesh axis, so each shard physically owns
   exactly the heads the planner gave it.

2. **Cache ownership** — replicas split the batch by the strided owner rule;
   unowned (slot, row) pairs keep ``lengths == 0`` forever, so their decode
   output is exactly zero and the o-projection contraction over S (an
   all-reduce across model shards) reassembles the full batch.

The decode step is the paper's measured quantity; its attention inner loop is
``kernels.ops.fairkv_decode`` (Pallas on TPU, jnp ref elsewhere).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.cache.slot_cache import (
    PlanArrays,
    SlotCache,
    append_selection,
    append_token,
    fill_from_selection,
    init_cache,
    insert_rows,
    reset_rows,
    rows_to_mask,
)
from repro.compression.base import CompressionConfig
from repro.compression.policies import select as policy_select
from repro.configs.base import ModelConfig
from repro.core.placement import HeadPlacement
from repro.distributed.sharding import constrain
from repro.kernels import ops as K
from repro.models import layers as L
from repro.models import ssm as S
from repro.models import transformer as M
from repro.paging.paged_cache import PagedCache, paged_append_token
from repro.paging.paged_cache import release_rows as paged_release_rows


# ---------------------------------------------------------------------------
# Serve state
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclass
class ServeState:
    cache: Optional[SlotCache]
    ssm_state: Optional[jnp.ndarray]  # (L, B, H, P, N) fp32
    conv_state: Optional[jnp.ndarray]  # (L, B, W-1, conv_dim)
    cross_k: Optional[jnp.ndarray]  # (L, B, T_enc, Hkv, Dh)
    cross_v: Optional[jnp.ndarray]
    last_tokens: jnp.ndarray  # (B,)
    decode_steps: jnp.ndarray  # scalar int32


# ---------------------------------------------------------------------------
# Slot-layout weights
# ---------------------------------------------------------------------------


def slotify_layer(pl: dict, slot_head: np.ndarray, cfg: ModelConfig) -> dict:
    """Build slot-layout q/k/v/o (+bias) weights for one layer."""
    G, Dh, D = cfg.q_per_kv, cfg.head_dim, cfg.d_model
    S_ = slot_head.shape[0]
    heads = np.maximum(slot_head, 0)
    empty = slot_head < 0
    wq = pl["wq"].reshape(D, cfg.n_kv_heads, G, Dh)
    out = dict(pl)
    q_s = jnp.take(wq, heads, axis=1).transpose(1, 0, 2, 3)  # (S, D, G, Dh)
    k_s = jnp.take(pl["wk"], heads, axis=1).transpose(1, 0, 2)  # (S, D, Dh)
    v_s = jnp.take(pl["wv"], heads, axis=1).transpose(1, 0, 2)
    wo = pl["wo"].reshape(cfg.n_kv_heads, G, Dh, D)
    o_s = jnp.take(wo, heads, axis=0)  # (S, G, Dh, D)
    mask = jnp.asarray(~empty, q_s.dtype)
    out["wq_s"] = q_s * mask[:, None, None, None]
    out["wk_s"] = k_s * mask[:, None, None]
    out["wv_s"] = v_s * mask[:, None, None]
    out["wo_s"] = o_s * mask[:, None, None, None]
    if cfg.qkv_bias and "bq" in pl:
        bq = pl["bq"].reshape(cfg.n_kv_heads, G, Dh)
        out["bq_s"] = jnp.take(bq, heads, axis=0) * mask[:, None, None]
        out["bk_s"] = jnp.take(pl["bk"], heads, axis=0) * mask[:, None]
        out["bv_s"] = jnp.take(pl["bv"], heads, axis=0) * mask[:, None]
    if "attn_out_norm" in pl:  # hybrid: per-branch norm scale in slot layout
        sc = pl["attn_out_norm"].reshape(cfg.n_kv_heads, G, Dh)
        out["attn_out_norm_s"] = jnp.take(sc, heads, axis=0)  # (S, G, Dh)
    for k in ("wq", "wk", "wv", "wo", "bq", "bk", "bv"):
        out.pop(k, None)
    return out


def slotify_params(params: dict, plan: HeadPlacement, cfg: ModelConfig) -> dict:
    """Serve-layout params: attention weights per plan; everything else kept."""
    if cfg.attention_free:
        return params
    arrs = plan.as_arrays()["slot_head"]
    out = dict(params)
    out["layers"] = [
        slotify_layer(pl, arrs[i], cfg) for i, pl in enumerate(params["layers"])
    ]
    return out


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------


def prefill(
    serve_params: dict,
    batch: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
    plan: PlanArrays,
    ccfg: CompressionConfig,
    head_importance: Optional[np.ndarray] = None,
    rows: Optional[jnp.ndarray] = None,
    model_axis: Optional[str] = None,
) -> Tuple[ServeState, jnp.ndarray, jnp.ndarray]:
    """Run the full prompt, compress each layer's KV into the slot cache.

    Prefill attention runs in *original head layout* (slot layout only pays
    off once per-head lengths diverge); q/k/v are recovered from the slot
    weights of the replica-0 slots so only one weight copy is kept.

    ``rows`` (optional, (B,) int32) are the *global* batch-row ids this
    sub-batch will occupy in a larger live cache: the strided owner rule is
    evaluated at those ids so the resulting sub-cache can be spliced in with
    ``insert_rows`` (continuous-batching admission).  Default: arange(B).

    ``model_axis`` names the mesh axis the slot dim is sharded over when the
    call runs inside ``shard_map`` (DESIGN.md §10): the replica-0 weight
    recovery all-gathers the slot-dim weights (prefill attention needs every
    head), while the compression selection and the per-slot cache fill stay
    local — each model shard fills exactly the slots it owns.

    Returns (state, last_logits (B, V), lengths (L, Hkv, B) — the realized
    per-head retained lengths, i.e. the paper's workload observable).
    """
    h, positions = M.embed_inputs(serve_params, batch, cfg)
    B, T, D = h.shape
    Hkv, G, Dh = cfg.n_kv_heads, cfg.q_per_kv, cfg.head_dim
    n_layers = cfg.n_layers
    cap = ccfg.static_capacity()

    enc_kvs = None
    cross_k = cross_v = None
    if cfg.is_encoder_decoder:
        enc_out = M.encode(serve_params, batch["frames"], cfg)
        enc_kvs = M.encoder_cross_kv(serve_params, enc_out, cfg)
        cross_k = jnp.stack([kv[0] for kv in enc_kvs])
        cross_v = jnp.stack([kv[1] for kv in enc_kvs])

    has_attn = not cfg.attention_free
    cache = (init_cache(n_layers, plan.slot_head.shape[1], B, cap, Dh,
                        dtype=h.dtype) if has_attn else None)
    ssm_state = conv_state = None
    if cfg.family in ("ssm", "hybrid"):
        s = cfg.ssm
        ssm_state = jnp.zeros((n_layers, B, s.num_heads, s.head_dim,
                               s.state_size), jnp.float32)
        conv_state = jnp.zeros(
            (n_layers, B, s.conv_width - 1,
             s.d_inner + 2 * s.n_groups * s.state_size), h.dtype)

    lengths_all = []
    W = min(ccfg.obs_window, T)
    for i, pl in enumerate(serve_params["layers"]):
        hn = L.rms_norm(h, pl["ln1"], cfg.rms_eps)
        if cfg.family == "hybrid":
            attn_flat, cache, lens = _prefill_attention(
                pl, hn, positions, cfg, i, cache, plan, ccfg, W,
                head_importance, rows, model_axis)
            a = L.rms_norm(attn_flat, pl["attn_out_norm"], cfg.rms_eps)
            attn_out = _slot_o_proj(pl, a, cfg, plan, i, model_axis)
            ssm_out, (cs, ss) = M.ssm_block_full(pl, hn, cfg, return_state=True)
            conv_state = conv_state.at[i].set(cs)
            ssm_state = ssm_state.at[i].set(ss)
            h = h + 0.5 * (attn_out + ssm_out)
            lengths_all.append(lens)
        elif cfg.family == "ssm":
            ssm_out, (cs, ss) = M.ssm_block_full(pl, hn, cfg, return_state=True)
            conv_state = conv_state.at[i].set(cs)
            ssm_state = ssm_state.at[i].set(ss)
            h = h + ssm_out
        else:
            attn_flat, cache, lens = _prefill_attention(
                pl, hn, positions, cfg, i, cache, plan, ccfg, W,
                head_importance, rows, model_axis)
            h = h + _slot_o_proj(pl, attn_flat, cfg, plan, i, model_axis)
            lengths_all.append(lens)
        if enc_kvs is not None:
            hc = L.rms_norm(h, pl["ln_cross"], cfg.rms_eps)
            h = h + M.cross_attn_block(pl, hc, enc_kvs[i], cfg)
        if cfg.d_ff > 0 or cfg.moe.num_experts > 0:
            hn2 = L.rms_norm(h, pl["ln2"], cfg.rms_eps)
            mlp_out, _ = M.mlp_block(pl, hn2, cfg)
            h = h + mlp_out
        h = constrain(h, "batch", "seq", "d_model")

    h_last = L.rms_norm(h[:, -1:], serve_params["final_norm"], cfg.rms_eps)
    table = serve_params.get("head", serve_params["embed"])
    logits = L.unembed(h_last, table, cfg.logit_softcap)[:, 0]
    if cache is not None:
        cache = SlotCache(k=cache.k, v=cache.v, lengths=cache.lengths,
                          pos=cache.pos,
                          positions=jnp.full((B,), T, jnp.int32))
    state = ServeState(
        cache=cache, ssm_state=ssm_state, conv_state=conv_state,
        cross_k=cross_k, cross_v=cross_v,
        last_tokens=jnp.argmax(
            logits[..., :cfg.vocab_size], axis=-1).astype(jnp.int32),
        decode_steps=jnp.int32(0))
    lengths = (jnp.stack(lengths_all) if lengths_all
               else jnp.zeros((0, Hkv, B), jnp.int32))
    return state, logits, lengths


def _take0(w, idx):
    """take along axis 0 through QTensor or plain array."""
    from repro.serving.quant import QTensor
    if isinstance(w, QTensor):
        sc = (jnp.take(w.scale, idx, axis=0) if w.scale.shape[0] > 1
              else w.scale)
        return QTensor(q=jnp.take(w.q, idx, axis=0), scale=sc)
    return jnp.take(w, idx, axis=0)


def _full_slots(w, model_axis: Optional[str]):
    """Reassemble the global slot dim inside ``shard_map`` (identity
    outside).  Prefill recovers original-layout weights through
    ``first_slot``, whose indices are global — a shard's local slot slice
    does not contain every head's replica-0 slot."""
    if model_axis is None:
        return w
    return jax.lax.all_gather(w, model_axis, axis=0, tiled=True)


def first_weights(pl: dict, plan: PlanArrays, layer_idx: int,
                  model_axis: Optional[str] = None) -> dict:
    """Recover original-layout q/k/v/o weights from each head's replica-0
    slot (a cheap gather — no second weight copy is stored)."""
    from repro.serving.quant import deq
    fs = plan.first_slot[layer_idx]  # (Hkv,)
    out = {
        "wq": deq(_take0(_full_slots(pl["wq_s"], model_axis), fs)),  # (Hkv, D, G, Dh)
        "wk": deq(_take0(_full_slots(pl["wk_s"], model_axis), fs)),  # (Hkv, D, Dh)
        "wv": deq(_take0(_full_slots(pl["wv_s"], model_axis), fs)),
        "wo": deq(_take0(_full_slots(pl["wo_s"], model_axis), fs)),  # (Hkv, G, Dh, D)
    }
    if "bq_s" in pl:
        out["bq"] = jnp.take(_full_slots(pl["bq_s"], model_axis), fs, axis=0)
        out["bk"] = jnp.take(_full_slots(pl["bk_s"], model_axis), fs, axis=0)
        out["bv"] = jnp.take(_full_slots(pl["bv_s"], model_axis), fs, axis=0)
    return out


def _prefill_attention(pl, hn, positions, cfg, layer_idx, cache, plan, ccfg,
                       W, head_importance, rows=None, model_axis=None):
    """Full attention + compression + slot-cache fill for one layer."""
    B, T, D = hn.shape
    Hkv, G, Dh = cfg.n_kv_heads, cfg.q_per_kv, cfg.head_dim
    fw = first_weights(pl, plan, layer_idx, model_axis)
    q = jnp.einsum("btd,hdgx->bthgx", hn, fw["wq"])  # (B,T,Hkv,G,Dh)
    k = jnp.einsum("btd,hdx->bthx", hn, fw["wk"])
    v = jnp.einsum("btd,hdx->bthx", hn, fw["wv"])
    if "bq" in fw:
        q = q + fw["bq"]
        k = k + fw["bk"]
        v = v + fw["bv"]
    q = q.reshape(B, T, Hkv * G, Dh)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    out = L.attention(q, k, v, positions, positions,
                      window=M.layer_window(cfg, layer_idx),
                      attn_cap=cfg.attn_softcap, causal=True)
    out_flat = out.reshape(B, T, Hkv * G * Dh)

    # --- compression ---------------------------------------------------------
    q_obs = q[:, T - W:]
    scores = K.snapkv_scores(q_obs, k, positions[:, T - W:], positions,
                             attn_cap=cfg.attn_softcap)
    from repro.compression.base import pool_scores
    scores = pool_scores(scores, ccfg.pool)
    window = M.layer_window(cfg, layer_idx)
    if window > 0:
        # sliding-window layers never need positions older than the window
        pos = jnp.arange(T)
        scores = jnp.where(pos[None, None, :] >= T - window, scores, -jnp.inf)
    kw = {}
    if ccfg.policy == "headkv" and head_importance is not None:
        kw["head_importance"] = jnp.asarray(head_importance[layer_idx])
    idx, keep = policy_select(ccfg.policy, scores, ccfg, layer_idx,
                              cfg.n_layers, **kw)
    cache = fill_from_selection(cache, layer_idx, k, v, idx, keep, plan,
                                rows=rows)
    return out_flat, cache, keep.transpose(1, 0)  # lens (Hkv, B)


def _slot_o_proj(pl, attn_flat, cfg, plan, layer_idx, model_axis=None):
    """(B, T, Hkv·G·Dh) → (B, T, D) via the first-replica o weights."""
    D = cfg.d_model
    from repro.serving.quant import deq
    fs = plan.first_slot[layer_idx]
    wo = deq(_take0(_full_slots(pl["wo_s"], model_axis), fs))
    wo = wo.reshape(cfg.n_kv_heads * cfg.q_per_kv * cfg.head_dim, D)
    return jnp.einsum("bte,ed->btd", attn_flat, wo)


# ---------------------------------------------------------------------------
# Chunked prefill (DESIGN.md §14)
# ---------------------------------------------------------------------------


def _cache_head_view(cache, layer, plan, rows, n_heads, model_axis=None):
    """Head-layout view of one layer's slot cache for the given rows.

    Returns ``(k (B,H,C,Dh), v (B,H,C,Dh), len_h (B,H), pos_h (B,H,C))``.
    Every (head, row) pair has exactly one owning slot, so a 0/1-weighted
    einsum over slots recovers the head layout; under ``shard_map`` the slot
    dim (cache slices *and* plan arrays) is all-gathered over ``model_axis``
    first — chunk attention needs every head, like monolithic prefill's
    weight recovery.
    """
    sh = plan.slot_head[layer]       # (S,)
    ri = plan.replica_idx[layer]
    rc = plan.replica_count[layer]
    kl, vl = cache.k[layer], cache.v[layer]     # (S, B, C, Dh)
    ln, ps = cache.lengths[layer], cache.pos[layer]
    if model_axis is not None:
        def ag(x):
            return jax.lax.all_gather(x, model_axis, axis=0, tiled=True)
        sh, ri, rc = ag(sh), ag(ri), ag(rc)
        kl, vl, ln, ps = ag(kl), ag(vl), ag(ln), ag(ps)
    rows = jnp.asarray(rows, jnp.int32)
    own = (sh >= 0)[:, None] & ((rows[None, :] % rc[:, None]) == ri[:, None])
    oh = sh[:, None] == jnp.arange(n_heads, dtype=sh.dtype)[None, :]  # (S, H)
    w = (oh[:, None, :] & own[:, :, None]).astype(jnp.float32)  # (S, B, H)
    k_h = jnp.einsum("sbh,sbcd->bhcd", w, kl.astype(jnp.float32))
    v_h = jnp.einsum("sbh,sbcd->bhcd", w, vl.astype(jnp.float32))
    len_h = jnp.einsum("sbh,sb->bh", w, ln.astype(jnp.float32))
    pos_h = jnp.einsum("sbh,sbc->bhc", w, ps.astype(jnp.float32))
    return (k_h.astype(cache.k.dtype), v_h.astype(cache.v.dtype),
            len_h.astype(jnp.int32), jnp.round(pos_h).astype(jnp.int32))


def _chunk_attention(pl, hn, positions, valid, cfg, layer_idx, cache, plan,
                     ccfg, quota_l, head_importance, rows, model_axis=None):
    """Attention over (retained cache ‖ current chunk) + boundary compression.

    The cache is per-head (earlier chunks' keep-sets differ per head), so
    attention runs with each (row, head) pair as its own batch element of
    `dense_attention` — keys are the head's retained entries concatenated
    with the chunk's fresh keys, masked by retained length / ``valid`` and
    the standard causal+window rule over *absolute* positions (cache keys
    are post-RoPE, so order never matters).  At the chunk boundary the
    snapkv observation scores are computed over the chunk's keys only and
    the policy's selection is appended after the existing entries
    (`append_selection`), clamped to the per-chunk ``quota_l`` and the
    remaining slot capacity.
    """
    B, Ck, D = hn.shape
    Hkv, G, Dh = cfg.n_kv_heads, cfg.q_per_kv, cfg.head_dim
    C = cache.k.shape[3]
    fw = first_weights(pl, plan, layer_idx, model_axis)
    q = jnp.einsum("btd,hdgx->bthgx", hn, fw["wq"])  # (B,Ck,Hkv,G,Dh)
    k = jnp.einsum("btd,hdx->bthx", hn, fw["wk"])
    v = jnp.einsum("btd,hdx->bthx", hn, fw["wv"])
    if "bq" in fw:
        q = q + fw["bq"]
        k = k + fw["bk"]
        v = v + fw["bv"]
    q = q.reshape(B, Ck, Hkv * G, Dh)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    window = M.layer_window(cfg, layer_idx)

    k_c, v_c, len_h, pos_h = _cache_head_view(cache, layer_idx, plan, rows,
                                              Hkv, model_axis)
    # (row, head) pairs as batch: per-head caches have distinct keys
    qh = (q.reshape(B, Ck, Hkv, G, Dh).transpose(0, 2, 1, 3, 4)
          .reshape(B * Hkv, Ck, G, Dh))
    kx = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Ck, 1, Dh)
    vx = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Ck, 1, Dh)
    k_cat = jnp.concatenate([k_c.reshape(B * Hkv, C, 1, Dh).astype(kx.dtype),
                             kx], axis=1)
    v_cat = jnp.concatenate([v_c.reshape(B * Hkv, C, 1, Dh).astype(vx.dtype),
                             vx], axis=1)
    q_pos = jnp.broadcast_to(positions[:, None, :], (B, Hkv, Ck))
    k_pos = jnp.concatenate([pos_h.reshape(B * Hkv, C),
                             q_pos.reshape(B * Hkv, Ck)], axis=1)
    in_cache = jnp.arange(C, dtype=jnp.int32)[None, None, :] < len_h[..., None]
    in_chunk = jnp.arange(Ck, dtype=jnp.int32)[None, :] < valid[:, None]
    kv_mask = jnp.concatenate(
        [in_cache.reshape(B * Hkv, C),
         jnp.broadcast_to(in_chunk[:, None, :], (B, Hkv, Ck))
         .reshape(B * Hkv, Ck)], axis=1)
    out = L.dense_attention(qh, k_cat, v_cat, q_pos.reshape(B * Hkv, Ck),
                            k_pos, window=window, attn_cap=cfg.attn_softcap,
                            kv_mask=kv_mask, causal=True)
    out_flat = (out.reshape(B, Hkv, Ck, G, Dh).transpose(0, 2, 1, 3, 4)
                .reshape(B, Ck, Hkv * G * Dh))

    # --- chunk-boundary compression -------------------------------------
    W = min(ccfg.obs_window, Ck)
    obs_ix = jnp.clip(valid[:, None] - W + jnp.arange(W, dtype=jnp.int32),
                      0, Ck - 1)  # (B, W): last W *valid* chunk queries
    q_obs = jnp.take_along_axis(q, obs_ix[:, :, None, None], axis=1)
    pos_obs = jnp.take_along_axis(positions, obs_ix, axis=1)
    scores = K.snapkv_scores(q_obs, k, pos_obs, positions,
                             attn_cap=cfg.attn_softcap)
    t_ix = jnp.arange(Ck, dtype=jnp.int32)
    scores = jnp.where(t_ix[None, None, :] < valid[:, None, None],
                       scores, -jnp.inf)
    from repro.compression.base import pool_scores
    scores = pool_scores(scores, ccfg.pool)
    if window > 0:
        end = (jnp.asarray(valid, jnp.int32) + positions[:, 0])[:, None, None]
        scores = jnp.where(positions[:, None, :] >= end - window,
                           scores, -jnp.inf)
    kw = {}
    if ccfg.policy == "headkv" and head_importance is not None:
        kw["head_importance"] = jnp.asarray(head_importance[layer_idx])
    idx, keep = policy_select(ccfg.policy, scores, ccfg, layer_idx,
                              cfg.n_layers, **kw)
    keep = jnp.minimum(keep, valid[:, None])          # only real tokens
    keep = jnp.minimum(keep, quota_l)                 # incremental budget
    keep = jnp.minimum(keep, C - len_h)               # slot headroom
    keep = jnp.maximum(keep, 0).astype(jnp.int32)
    cache = append_selection(cache, layer_idx, k, v, idx, keep, plan,
                             rows=rows, start=positions[:, 0])
    return out_flat, cache, (len_h + keep).transpose(1, 0)  # (Hkv, B)


def prefill_chunk(
    serve_params: dict,
    tokens: jnp.ndarray,  # (B, Ck) fixed-width chunk (padded past ``valid``)
    cfg: ModelConfig,
    plan: PlanArrays,
    ccfg: CompressionConfig,
    state: ServeState,
    rows: jnp.ndarray,  # (B,) global row ids
    start: jnp.ndarray,  # (B,) int32 absolute position of chunk token 0
    valid: jnp.ndarray,  # (B,) int32 real tokens in this chunk (<= Ck)
    quota: jnp.ndarray,  # (L,) int32 per-head keep cap for this chunk
    head_importance: Optional[np.ndarray] = None,
    model_axis: Optional[str] = None,
) -> Tuple[ServeState, jnp.ndarray, jnp.ndarray]:
    """Process one fixed-width prompt chunk against an accumulating cache.

    The chunked twin of `prefill` (DESIGN.md §14): the prompt arrives
    ``chunk_tokens`` at a time, each chunk attends over the *retained*
    entries of earlier chunks plus its own keys, and the compression policy
    runs at the chunk boundary so per-head keep-budgets accrue
    incrementally.  ``tokens`` is always the same static width — the
    scheduler pads the last chunk and passes ``valid`` — so local and mesh
    executors trace this exactly once per shape.

    Dense decoder-only families only: ssm/hybrid recurrences and enc-dec /
    vlm inputs do not thread through a chunk boundary (the scheduler falls
    back to monolithic prefill for them).

    Returns (state, logits (B, V) at the last valid token, lengths
    (L, Hkv, B) — *cumulative* retained lengths after this chunk).
    """
    if cfg.family != "dense" or cfg.attention_free:
        raise ValueError(
            f"chunked prefill supports dense attention families only, "
            f"got family={cfg.family!r}")
    if cfg.is_encoder_decoder or cfg.is_vlm:
        raise ValueError("chunked prefill does not support enc-dec / vlm")
    h = L.embed(tokens, serve_params["embed"])
    if cfg.name.startswith("gemma2"):
        h = h * jnp.asarray(math.sqrt(cfg.d_model), h.dtype)
    B, Ck, _ = h.shape
    start = jnp.asarray(start, jnp.int32)
    valid = jnp.asarray(valid, jnp.int32)
    quota = jnp.asarray(quota, jnp.int32)
    positions = start[:, None] + jnp.arange(Ck, dtype=jnp.int32)[None, :]
    cache = state.cache
    lengths_all = []
    for i, pl in enumerate(serve_params["layers"]):
        hn = L.rms_norm(h, pl["ln1"], cfg.rms_eps)
        attn_flat, cache, lens = _chunk_attention(
            pl, hn, positions, valid, cfg, i, cache, plan, ccfg, quota[i],
            head_importance, rows, model_axis)
        h = h + _slot_o_proj(pl, attn_flat, cfg, plan, i, model_axis)
        lengths_all.append(lens)
        if cfg.d_ff > 0 or cfg.moe.num_experts > 0:
            hn2 = L.rms_norm(h, pl["ln2"], cfg.rms_eps)
            mlp_out, _ = M.mlp_block(pl, hn2, cfg)
            h = h + mlp_out
        h = constrain(h, "batch", "seq", "d_model")

    last_ix = jnp.maximum(valid - 1, 0)
    h_last = jnp.take_along_axis(h, last_ix[:, None, None], axis=1)  # (B,1,D)
    h_last = L.rms_norm(h_last, serve_params["final_norm"], cfg.rms_eps)
    table = serve_params.get("head", serve_params["embed"])
    logits = L.unembed(h_last, table, cfg.logit_softcap)[:, 0]
    cache = dataclasses.replace(
        cache, positions=(start + valid).astype(jnp.int32))
    new_state = ServeState(
        cache=cache, ssm_state=state.ssm_state, conv_state=state.conv_state,
        cross_k=state.cross_k, cross_v=state.cross_v,
        last_tokens=jnp.argmax(
            logits[..., :cfg.vocab_size], axis=-1).astype(jnp.int32),
        decode_steps=state.decode_steps)
    lengths = jnp.stack(lengths_all)
    return new_state, logits, lengths


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def decode_step(
    serve_params: dict,
    state: ServeState,
    cfg: ModelConfig,
    plan: PlanArrays,
    ccfg: CompressionConfig,
    tokens: Optional[jnp.ndarray] = None,
    active: Optional[jnp.ndarray] = None,
    rows: Optional[jnp.ndarray] = None,
    model_axis: Optional[str] = None,
    data_axis: Optional[str] = None,
    paged_impl: str = "auto",
    kv_kinds=None,
) -> Tuple[ServeState, jnp.ndarray]:
    """One decode step for the whole batch.  Returns (state, logits (B, V)).

    ``active`` ((B,) bool, optional) marks the rows that carry a live request
    under continuous batching: cache appends and position increments are
    suppressed on inactive rows, so a retired row's ``lengths`` stay 0 (its
    decode-attention output stays exactly zero) until the scheduler splices a
    new request in.  ``None`` treats every row as active (one-shot serving).

    ``rows`` ((B,) int32, optional) are the *global* batch-row ids of the
    rows this call sees — the strided replica-owner rule keys on global ids,
    so a mesh executor running this step inside ``shard_map`` (batch rows
    sharded over the data axis) must pass each shard's global row slice.
    Default: arange(B) (the full batch is visible, today's local path).

    ``model_axis`` names the mesh axis the slot dim is sharded over inside
    ``shard_map``: per-slot attention stays local, and the o-projection
    contraction over S becomes a psum that reassembles the full activation
    (DESIGN.md §10).  ``data_axis`` names the batch-row axis — the paged
    pool partitions over *both* axes (blocks of (slot, row) live on the
    (model shard of the slot, data shard of the row) device), so the
    block-id localization needs both indices.  ``None`` (default) is the
    single-device path.

    ``paged_impl`` selects the paged decode-attention implementation
    (``kernels.ops.PAGED_DECODE_IMPLS``: native "pallas" kernel, legacy
    "gather", "jnp" oracle, or "auto" — DESIGN.md §11).  It is *static*
    configuration (the executors close over ``PagingConfig.decode_impl``),
    so it never affects the StepFn's trace signature.

    ``kv_kinds`` ((L, H) int numpy, static like ``paged_impl``) is the
    per-(layer, head) quantized-storage kind grid (DESIGN.md §15).  The
    per-*slot* kinds the kernel needs are derived in-trace from the traced
    plan's ``slot_head``, so one compiled StepFn serves every replan even
    under a per-head dtype override map.
    """
    tokens = state.last_tokens if tokens is None else tokens
    B = tokens.shape[0]
    h = L.embed(tokens[:, None], serve_params["embed"])  # (B, 1, D)
    if cfg.name.startswith("gemma2"):
        h = h * jnp.asarray(math.sqrt(cfg.d_model), h.dtype)
    positions = (state.cache.positions if state.cache is not None
                 else state.decode_steps.astype(jnp.int32) + jnp.zeros((B,), jnp.int32))
    if cfg.is_encoder_decoder:
        h = h + serve_params["dec_pos"][positions][:, None]
    cache, ssm_state, conv_state = state.cache, state.ssm_state, state.conv_state

    for i, pl in enumerate(serve_params["layers"]):
        hn = L.rms_norm(h, pl["ln1"], cfg.rms_eps)
        if cfg.family == "hybrid":
            attn_flat, cache = _decode_attention(pl, hn, positions, cfg, i,
                                                 cache, plan, state.decode_steps,
                                                 ccfg, active, rows, model_axis,
                                                 data_axis, paged_impl, kv_kinds)
            a = _slot_rms_norm(attn_flat, pl["attn_out_norm_s"],
                               cfg.n_heads * cfg.head_dim, cfg.rms_eps,
                               model_axis)
            attn_out = _decode_slot_o(pl, a, cfg, model_axis)
            ssm_out, ssm_state, conv_state = _decode_ssm(
                pl, hn, cfg, i, ssm_state, conv_state)
            h = h + 0.5 * (attn_out + ssm_out)
        elif cfg.family == "ssm":
            ssm_out, ssm_state, conv_state = _decode_ssm(
                pl, hn, cfg, i, ssm_state, conv_state)
            h = h + ssm_out
        else:
            attn_flat, cache = _decode_attention(pl, hn, positions, cfg, i,
                                                 cache, plan, state.decode_steps,
                                                 ccfg, active, rows, model_axis,
                                                 data_axis, paged_impl, kv_kinds)
            h = h + _decode_slot_o(pl, attn_flat, cfg, model_axis)
        if cfg.is_encoder_decoder:
            hc = L.rms_norm(h, pl["ln_cross"], cfg.rms_eps)
            h = h + M.cross_attn_block(
                pl, hc, (state.cross_k[i], state.cross_v[i]), cfg)
        if cfg.d_ff > 0 or cfg.moe.num_experts > 0:
            hn2 = L.rms_norm(h, pl["ln2"], cfg.rms_eps)
            mlp_out, _ = M.mlp_block(pl, hn2, cfg)
            h = h + mlp_out
        h = constrain(h, "batch", None, "d_model")

    h = L.rms_norm(h, serve_params["final_norm"], cfg.rms_eps)
    table = serve_params.get("head", serve_params["embed"])
    logits = L.unembed(h, table, cfg.logit_softcap)[:, 0]  # (B, V)
    if cache is not None:
        pos_next = (cache.positions + 1 if active is None
                    else jnp.where(active, cache.positions + 1,
                                   cache.positions))
        cache = dataclasses.replace(cache, positions=pos_next)
    new_state = ServeState(
        cache=cache, ssm_state=ssm_state, conv_state=conv_state,
        cross_k=state.cross_k, cross_v=state.cross_v,
        last_tokens=jnp.argmax(
            logits[..., :cfg.vocab_size], axis=-1).astype(jnp.int32),
        decode_steps=state.decode_steps + 1)
    return new_state, logits


def _decode_attention(pl, hn, positions, cfg, layer_idx, cache, plan,
                      decode_steps, ccfg, active=None, rows=None,
                      model_axis=None, data_axis=None, paged_impl="auto",
                      kv_kinds=None):
    """Slot-layout attention for one new token; appends to the cache."""
    B = hn.shape[0]
    G, Dh = cfg.q_per_kv, cfg.head_dim
    from repro.serving.quant import deq
    x = hn[:, 0]  # (B, D)
    q = jnp.einsum("bd,sdgx->bsgx", x, deq(pl["wq_s"]))  # (B, S, G, Dh)
    k_new = jnp.einsum("bd,sdx->bsx", x, deq(pl["wk_s"]))  # (B, S, Dh)
    v_new = jnp.einsum("bd,sdx->bsx", x, deq(pl["wv_s"]))
    if "bq_s" in pl:
        q = q + pl["bq_s"]
        k_new = k_new + pl["bk_s"][None]
        v_new = v_new + pl["bv_s"][None]
    # RoPE at each row's absolute position
    q = _rope_slots(q, positions, cfg)
    k_new = _rope_slots(k_new[:, :, None, :], positions, cfg)[:, :, 0, :]
    own = (plan.owner_mask(layer_idx, B) if rows is None
           else plan.owner_mask_rows(layer_idx, rows))  # (S, B)
    if active is not None:
        own = own & active[None, :]
    window = M.layer_window(cfg, layer_idx)
    if isinstance(cache, PagedCache):
        # paged backend (DESIGN.md §9): block-pool storage, same append
        # index rule and decode masking through `ops.paged_fairkv_decode`
        # (native block-table kernel on TPU by default, §11).  Appends
        # are always scatters into the pool (the onehot trade-off does not
        # arise: writes touch one block, not a full cache slice).
        capacity = ccfg.static_capacity()
        table_l = cache.block_table[layer_idx]  # (S, B, M)
        if model_axis is not None:
            # mesh (DESIGN.md §10): the pool shards over (model, data) —
            # blocks of (slot, row) live on the (slot's model shard, row's
            # data shard) device — so each device holds an N_part-block
            # partition while the table stores *global* block ids.  The
            # partition-aware allocator guarantees locality, so subtracting
            # the partition offset localizes the ids; anything that falls
            # outside (the global null block 0 on later partitions,
            # defensively a foreign id) redirects to local block 0 — every
            # partition reserves its local block 0 as a null block.
            n_part = cache.k_pool.shape[1]
            part_idx = jax.lax.axis_index(model_axis)
            if data_axis is not None:
                row_parts = jax.lax.psum(1, data_axis)
                part_idx = (part_idx * row_parts
                            + jax.lax.axis_index(data_axis))
            loc = table_l - part_idx * n_part
            table_l = jnp.where((loc > 0) & (loc < n_part), loc, 0)
        kinds = None
        if cache.k_scale is not None:
            # per-slot dequant kinds from the *traced* plan: the static
            # (L, H) kind grid indexed by slot_head, so a replan that moves
            # heads across slots reuses the same compiled step (§15)
            grid_l = (jnp.zeros((cfg.n_kv_heads,), jnp.int32)
                      if kv_kinds is None
                      else jnp.asarray(kv_kinds[layer_idx], jnp.int32))
            kinds = jnp.take(grid_l,
                             jnp.maximum(plan.slot_head[layer_idx], 0))
        cache = paged_append_token(cache, layer_idx, k_new.swapaxes(0, 1),
                                   v_new.swapaxes(0, 1), own, decode_steps,
                                   capacity, ring=max(1, ccfg.decode_margin),
                                   table_layer=table_l, kinds=kinds)
        out = K.paged_fairkv_decode(
            q, cache.k_pool[layer_idx], cache.v_pool[layer_idx],
            cache.pos_pool[layer_idx], table_l,
            cache.lengths[layer_idx], capacity, attn_cap=cfg.attn_softcap,
            q_pos=positions, window=window, impl=paged_impl,
            k_scale=(None if cache.k_scale is None
                     else cache.k_scale[layer_idx]),
            v_scale=(None if cache.v_scale is None
                     else cache.v_scale[layer_idx]),
            kinds=kinds)
        return out, cache
    cache = append_token(cache, layer_idx, k_new.swapaxes(0, 1),
                         v_new.swapaxes(0, 1), own, decode_steps,
                         ring=max(1, ccfg.decode_margin),
                         mode=ccfg.append_mode)
    out = K.fairkv_decode(q, cache.k[layer_idx], cache.v[layer_idx],
                          cache.lengths[layer_idx], attn_cap=cfg.attn_softcap,
                          k_pos=cache.pos[layer_idx], q_pos=positions,
                          window=window)
    return out, cache  # (B, S, G, Dh)


def _rope_slots(q, positions, cfg):
    """RoPE over (B, S, G, Dh) at per-row positions."""
    B, S_, G, Dh = q.shape
    q2 = q.reshape(B, 1, S_ * G, Dh)  # one 'seq' position per row
    q2 = L.apply_rope(q2, positions[:, None], cfg.rope_theta)
    return q2.reshape(B, S_, G, Dh)


def _slot_rms_norm(x, scale_slot, n_channels, eps, model_axis=None):
    """RMS norm over the slot layout (B, S, G, Dh).

    Unowned-slot entries are exactly zero (fairkv_decode guarantees it), and
    every head contributes through exactly one owned slot per row, so
    Σx² over (S, G, Dh) equals the original-channel Σx²; the mean divides by
    the *true* channel count (Hq·Dh), not the padded slot width.  Under
    ``shard_map`` the Σ over S is a (tiny) cross-shard psum.
    """
    xf = x.astype(jnp.float32)
    ss = (xf * xf).sum(axis=(1, 2, 3), keepdims=True)
    if model_axis is not None:
        ss = jax.lax.psum(ss, model_axis)
    ss = ss / n_channels
    return (xf * jax.lax.rsqrt(ss + eps)
            * (1.0 + scale_slot.astype(jnp.float32))[None]).astype(x.dtype)


def _decode_slot_o(pl, attn, cfg, model_axis=None):
    """(B, S, G, Dh) → (B, 1, D); contraction over S psums across shards.

    This is the one collective of the mesh decode StepFn: every (head, row)
    pair has exactly one owning slot, so the per-shard partial contractions
    sum to the full batch's activation (DESIGN.md §10)."""
    from repro.serving.quant import deq
    out = jnp.einsum("bsgx,sgxd->bd", attn, deq(pl["wo_s"]))
    if model_axis is not None:
        out = jax.lax.psum(out, model_axis)
    return out[:, None]


def _decode_ssm(pl, hn, cfg, layer_idx, ssm_state, conv_state):
    s = cfg.ssm
    d_in, G, N, H, P = s.d_inner, s.n_groups, s.state_size, s.num_heads, s.head_dim
    B = hn.shape[0]
    z, xBC, dt = M.ssm_split(pl, hn, cfg)  # (B, 1, ...)
    cs = conv_state[layer_idx]  # (B, W-1, conv_dim)
    xBC, new_cs = S.conv1d_causal(xBC, pl["conv_w"], cs)
    xBC = jax.nn.silu(xBC)
    x, B_, C_ = jnp.split(xBC[:, 0], [d_in, d_in + G * N], axis=-1)
    y, new_ss = S.ssd_decode_step(
        x.reshape(B, H, P), dt[:, 0], pl["A_log"],
        B_.reshape(B, G, N), C_.reshape(B, G, N), pl["ssm_D"],
        ssm_state[layer_idx])
    y = y.reshape(B, 1, d_in)
    y = L.rms_norm(y * jax.nn.silu(z), pl["ssm_norm"])
    out = y @ pl["out_proj"]
    return out, ssm_state.at[layer_idx].set(new_ss), conv_state.at[layer_idx].set(new_cs)


# ---------------------------------------------------------------------------
# Speculative decoding: propose + verify (DESIGN.md §16)
# ---------------------------------------------------------------------------


def _spec_supported(cfg: ModelConfig) -> None:
    if cfg.family != "dense" or cfg.attention_free:
        raise ValueError(
            "speculative decoding supports dense attention families only, "
            f"got family={cfg.family!r}")
    if cfg.is_encoder_decoder or cfg.is_vlm:
        raise ValueError("speculative decoding does not support enc-dec/vlm")


def propose_step(
    serve_params: dict,
    state: ServeState,
    cfg: ModelConfig,
    plan: PlanArrays,
    ccfg: CompressionConfig,
    depths: jnp.ndarray,  # (B,) int32 — speculative tokens per row (<= max_k)
    active: Optional[jnp.ndarray] = None,
    rows: Optional[jnp.ndarray] = None,
    model_axis: Optional[str] = None,
    data_axis: Optional[str] = None,
    paged_impl: str = "auto",
    kv_kinds=None,
    draft_layers: int = 0,  # static; 0 = full depth (self-check mode)
    max_k: int = 1,  # static unroll bound; per-row depth is traced
) -> Tuple[ServeState, jnp.ndarray]:
    """Draft ``max_k`` tokens autoregressively in ONE jitted call.

    The draft is the layer-truncated early exit of the target
    (`models.draft_view`), its head placement the leading slice of the
    target plan (`core.planner.draft_plan`) — so the draft's KV appends
    land in the *target's* paged cache at the target's own layers < d
    (real KV; verify fills layers >= d).  ``max_k`` masked single-decode
    steps are unrolled into this one trace: step ``i`` runs with
    ``active & (i < depths)``, so per-row adaptive depth changes never
    retrace (the zero-recompile invariant — depth is data, not shape).

    Positions, ``decode_steps`` and ``last_tokens`` are restored to their
    pre-propose values in the returned state: the verify pass re-derives
    the position advance from the accepted run, and the tick counts as one
    ring step regardless of depth.  Returns (state, proposals (B, max_k))
    — entries past a row's depth are garbage lanes the scheduler masks.
    """
    _spec_supported(cfg)
    from repro.core.planner import draft_plan

    d = draft_layers if draft_layers > 0 else cfg.n_layers
    sp_d = M.draft_view(serve_params, d)
    plan_d = draft_plan(plan, d)
    B = state.last_tokens.shape[0]
    active_b = (jnp.ones((B,), bool) if active is None else active)
    depths = jnp.asarray(depths, jnp.int32)
    st = state
    proposals = []
    for i in range(max_k):
        act_i = active_b & (jnp.int32(i) < depths)
        st, _ = decode_step(sp_d, st, cfg, plan_d, ccfg,
                            tokens=st.last_tokens, active=act_i, rows=rows,
                            model_axis=model_axis, data_axis=data_axis,
                            paged_impl=paged_impl, kv_kinds=kv_kinds)
        proposals.append(st.last_tokens)
    props = (jnp.stack(proposals, axis=1) if proposals
             else jnp.zeros((B, 0), jnp.int32))
    cache = dataclasses.replace(st.cache, positions=state.cache.positions)
    new_state = ServeState(
        cache=cache, ssm_state=st.ssm_state, conv_state=st.conv_state,
        cross_k=st.cross_k, cross_v=st.cross_v,
        last_tokens=state.last_tokens, decode_steps=state.decode_steps)
    return new_state, props


def verify_step(
    serve_params: dict,
    state: ServeState,
    cfg: ModelConfig,
    plan: PlanArrays,
    ccfg: CompressionConfig,
    tokens: jnp.ndarray,  # (B, Q) int32: [t0, p1..p_{Q-1}] (garbage past q_lens)
    q_lens: jnp.ndarray,  # (B,) int32 valid window per row (1 <= q_len <= Q)
    active: Optional[jnp.ndarray] = None,
    rows: Optional[jnp.ndarray] = None,
    model_axis: Optional[str] = None,
    data_axis: Optional[str] = None,
    paged_impl: str = "auto",
    kv_kinds=None,
    draft_layers: int = 0,  # static; layers < d were filled by propose
) -> Tuple[ServeState, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One batched verify pass over the speculative window.

    Runs the full target over ``Q = max_k + 1`` tokens per row — the last
    committed token followed by the draft's proposals — through the
    multi-query paged kernel (5-D q, `fairkv_decode_mq_ref` semantics).
    Appends per layer restore the uniform-length invariant: draft layers
    already hold the window's first ``q_len - 1`` entries (propose wrote
    real KV), so only the final token appends there; verify-only layers
    append every valid token in query order, walking the same
    quantize-on-write scale evolution as sequential decode.

    The greedy verdicts ``g[:, i] = argmax`` are exactly what single-token
    decode would have emitted given the same prefix, so committing the
    accepted run ``g[:, :n_commit]`` is bit-identical to non-speculative
    greedy decode at any acceptance rate.  Rejected entries roll back
    *in-trace* (lengths drop to ``base + n_commit``); the host-side block
    trim (`paging.backend`) reclaims now-uncovered provisional blocks.

    Returns (state, g (B, Q), n_commit (B,), logits (B, Q, V)).
    """
    _spec_supported(cfg)
    if not isinstance(state.cache, PagedCache):
        raise ValueError("speculative verify requires the paged cache backend")
    d = draft_layers if draft_layers > 0 else cfg.n_layers
    B, Q = tokens.shape
    active_b = (jnp.ones((B,), bool) if active is None else active)
    q_lens = jnp.asarray(q_lens, jnp.int32)
    h = L.embed(tokens, serve_params["embed"])  # (B, Q, D)
    if cfg.name.startswith("gemma2"):
        h = h * jnp.asarray(math.sqrt(cfg.d_model), h.dtype)
    cache = state.cache
    positions = cache.positions
    positions_q = positions[:, None] + jnp.arange(Q, dtype=jnp.int32)[None, :]

    for i, pl in enumerate(serve_params["layers"]):
        hn = L.rms_norm(h, pl["ln1"], cfg.rms_eps)
        attn, cache = _verify_attention(
            pl, hn, positions_q, q_lens, cfg, i, cache, plan,
            state.decode_steps, ccfg, i < d, active_b, rows, model_axis,
            data_axis, paged_impl, kv_kinds)
        h = h + _verify_slot_o(pl, attn, cfg, model_axis)
        if cfg.d_ff > 0 or cfg.moe.num_experts > 0:
            hn2 = L.rms_norm(h, pl["ln2"], cfg.rms_eps)
            mlp_out, _ = M.mlp_block(pl, hn2, cfg)
            h = h + mlp_out
        h = constrain(h, "batch", None, "d_model")

    h = L.rms_norm(h, serve_params["final_norm"], cfg.rms_eps)
    table = serve_params.get("head", serve_params["embed"])
    logits = L.unembed(h, table, cfg.logit_softcap)  # (B, Q, V)
    g = jnp.argmax(logits[..., :cfg.vocab_size], axis=-1).astype(jnp.int32)

    # leading run of proposals the target itself would have emitted
    if Q > 1:
        iq = jnp.arange(Q - 1, dtype=jnp.int32)[None, :]
        ok = (tokens[:, 1:] == g[:, :-1]) & (iq + 1 < q_lens[:, None])
        n_acc = jnp.cumprod(ok.astype(jnp.int32), axis=1).sum(axis=1)
    else:
        n_acc = jnp.zeros((B,), jnp.int32)
    n_commit = jnp.minimum(n_acc + 1, q_lens)  # accepted run + bonus/fix

    # in-trace rollback: rejected speculative entries drop out of `lengths`
    # on every owned (slot, row) — the appended values become invisible to
    # the kernel's length mask; the backend's host trim frees their blocks
    rows_b = (jnp.arange(B, dtype=jnp.int32) if rows is None
              else jnp.asarray(rows, jnp.int32))
    own_all = ((plan.slot_head >= 0)[:, :, None]
               & ((rows_b[None, None, :] % plan.replica_count[:, :, None])
                  == plan.replica_idx[:, :, None]))  # (L, S, B)
    trim = jnp.where(active_b, q_lens - n_commit, 0)  # (B,)
    lengths = cache.lengths - jnp.where(own_all, trim[None, None, :], 0)
    pos_next = jnp.where(active_b, positions + n_commit, positions)
    cache = dataclasses.replace(cache, lengths=lengths, positions=pos_next)
    last = jnp.take_along_axis(
        g, jnp.maximum(n_commit - 1, 0)[:, None], axis=1)[:, 0]
    new_state = ServeState(
        cache=cache, ssm_state=state.ssm_state, conv_state=state.conv_state,
        cross_k=state.cross_k, cross_v=state.cross_v,
        last_tokens=jnp.where(active_b, last, state.last_tokens),
        decode_steps=state.decode_steps + 1)
    return new_state, g, n_commit, logits


def _verify_attention(pl, hn, positions_q, q_lens, cfg, layer_idx, cache,
                      plan, decode_steps, ccfg, draft_filled, active, rows,
                      model_axis=None, data_axis=None, paged_impl="auto",
                      kv_kinds=None):
    """Multi-query slot attention over the speculative window (one layer).

    ``hn`` is (B, Q, D); every token projects and RoPEs at its own absolute
    position, then appends into the paged cache:

    - ``draft_filled`` layers already hold the window's first ``q_len - 1``
      entries (real KV written by propose); only query index ``q_len - 1``
      appends.
    - verify-only layers append all ``q_len`` valid tokens in query order —
      per-row sequential writes into the block, so quantize-on-write scales
      evolve exactly as under single-token decode.

    After the appends every live (slot, row) sits at ``base + q_len`` and
    the multi-query kernel masks query ``i`` to its causal prefix
    ``base + i + 1`` (`fairkv_decode_mq_ref`).  Returns
    ((B, S, Q, G, Dh), cache).
    """
    B, Q, _ = hn.shape
    from repro.serving.quant import deq
    if not isinstance(cache, PagedCache):
        raise ValueError("speculative verify requires the paged cache backend")
    q = jnp.einsum("bqd,sdgx->bsqgx", hn, deq(pl["wq_s"]))  # (B, S, Q, G, Dh)
    k_new = jnp.einsum("bqd,sdx->bsqx", hn, deq(pl["wk_s"]))  # (B, S, Q, Dh)
    v_new = jnp.einsum("bqd,sdx->bsqx", hn, deq(pl["wv_s"]))
    if "bq_s" in pl:
        q = q + pl["bq_s"][None, :, None]
        k_new = k_new + pl["bk_s"][None, :, None]
        v_new = v_new + pl["bv_s"][None, :, None]
    q = _rope_slots_mq(q, positions_q, cfg)
    k_new = _rope_slots_mq(k_new[:, :, :, None, :], positions_q,
                           cfg)[:, :, :, 0, :]
    own = (plan.owner_mask(layer_idx, B) if rows is None
           else plan.owner_mask_rows(layer_idx, rows))  # (S, B)
    own = own & active[None, :]
    window = M.layer_window(cfg, layer_idx)
    capacity = ccfg.static_capacity()
    table_l = cache.block_table[layer_idx]  # (S, B, M)
    if model_axis is not None:
        # same partition-localization as `_decode_attention` (DESIGN.md §10)
        n_part = cache.k_pool.shape[1]
        part_idx = jax.lax.axis_index(model_axis)
        if data_axis is not None:
            row_parts = jax.lax.psum(1, data_axis)
            part_idx = part_idx * row_parts + jax.lax.axis_index(data_axis)
        loc = table_l - part_idx * n_part
        table_l = jnp.where((loc > 0) & (loc < n_part), loc, 0)
    kinds = None
    if cache.k_scale is not None:
        grid_l = (jnp.zeros((cfg.n_kv_heads,), jnp.int32) if kv_kinds is None
                  else jnp.asarray(kv_kinds[layer_idx], jnp.int32))
        kinds = jnp.take(grid_l, jnp.maximum(plan.slot_head[layer_idx], 0))
    for qi in range(Q):
        m_q = (q_lens == qi + 1) if draft_filled else (qi < q_lens)
        own_q = own & m_q[None, :]
        # the appended entry's recorded position is `cache.positions` —
        # point it at this token's absolute position for the write
        cache = paged_append_token(
            dataclasses.replace(cache, positions=positions_q[:, qi]),
            layer_idx, k_new[:, :, qi].swapaxes(0, 1),
            v_new[:, :, qi].swapaxes(0, 1), own_q, decode_steps, capacity,
            ring=max(1, ccfg.decode_margin), table_layer=table_l, kinds=kinds)
    cache = dataclasses.replace(cache, positions=positions_q[:, 0])
    out = K.paged_fairkv_decode(
        q, cache.k_pool[layer_idx], cache.v_pool[layer_idx],
        cache.pos_pool[layer_idx], table_l, cache.lengths[layer_idx],
        capacity, attn_cap=cfg.attn_softcap, q_pos=positions_q[:, 0],
        window=window, impl=paged_impl,
        k_scale=(None if cache.k_scale is None
                 else cache.k_scale[layer_idx]),
        v_scale=(None if cache.v_scale is None
                 else cache.v_scale[layer_idx]),
        kinds=kinds, q_lens=q_lens)
    return out, cache


def _rope_slots_mq(q, positions_q, cfg):
    """RoPE over (B, S, Q, G, Dh) at per-(row, query) positions (B, Q)."""
    B, S_, Q, G, Dh = q.shape
    q2 = q.transpose(0, 2, 1, 3, 4).reshape(B, Q, S_ * G, Dh)
    q2 = L.apply_rope(q2, positions_q, cfg.rope_theta)
    return q2.reshape(B, Q, S_, G, Dh).transpose(0, 2, 1, 3, 4)


def _verify_slot_o(pl, attn, cfg, model_axis=None):
    """(B, S, Q, G, Dh) → (B, Q, D); the same single o-projection psum as
    `_decode_slot_o` — multi-query verify adds no new mesh collective."""
    from repro.serving.quant import deq
    out = jnp.einsum("bsqgx,sgxd->bqd", attn, deq(pl["wo_s"]))
    if model_axis is not None:
        out = jax.lax.psum(out, model_axis)
    return out


# ---------------------------------------------------------------------------
# Row-level state ops (continuous batching, DESIGN.md §7)
# ---------------------------------------------------------------------------


_KEEP = object()  # sentinel: "no cache override" (None is a real value)


def init_serve_state(cfg: ModelConfig, plan: PlanArrays, batch: int,
                     ccfg: CompressionConfig, dtype=jnp.float32,
                     cache=_KEEP) -> ServeState:
    """Empty B-row ServeState: every row retired (lengths 0, positions 0).

    The continuous-batching scheduler starts from this and splices prefilled
    requests into rows as they are admitted.  ``cache`` lets a cache backend
    substitute its own layout (e.g. a `PagedCache`) while reusing the
    SSM/conv/token initialization.  Encoder-decoder models are not
    supported (their cross-KV shape depends on per-request encoder inputs).
    """
    if cfg.is_encoder_decoder:
        raise NotImplementedError(
            "continuous batching does not support encoder-decoder models")
    if cache is _KEEP:
        cache = None
        if not cfg.attention_free:
            cache = init_cache(cfg.n_layers, int(plan.slot_head.shape[1]),
                               batch, ccfg.static_capacity(), cfg.head_dim,
                               dtype=dtype)
    ssm_state = conv_state = None
    if cfg.family in ("ssm", "hybrid"):
        s = cfg.ssm
        ssm_state = jnp.zeros((cfg.n_layers, batch, s.num_heads, s.head_dim,
                               s.state_size), jnp.float32)
        conv_state = jnp.zeros(
            (cfg.n_layers, batch, s.conv_width - 1,
             s.d_inner + 2 * s.n_groups * s.state_size), dtype)
    return ServeState(cache=cache, ssm_state=ssm_state, conv_state=conv_state,
                      cross_k=None, cross_v=None,
                      last_tokens=jnp.zeros((batch,), jnp.int32),
                      decode_steps=jnp.int32(0))


def splice_state(state: ServeState, sub: ServeState,
                 rows: jnp.ndarray, cache=_KEEP) -> ServeState:
    """Splice a prefilled sub-batch state into ``rows`` of the live state.

    ``sub`` must come from ``prefill(..., rows=rows)`` so its slot-cache
    ownership matches the target global rows.  ``decode_steps`` keeps the
    live value — the ring-write phase is global, not per-request.
    ``cache`` overrides the cache splice (cache backends pass their
    already-spliced layout; the SSM/conv/token rows still merge here).
    """
    rows = jnp.asarray(rows, jnp.int32)
    if cache is _KEEP:
        cache = state.cache
        if cache is not None:
            cache = insert_rows(cache, sub.cache, rows)
    ssm = state.ssm_state
    if ssm is not None:
        ssm = ssm.at[:, rows].set(sub.ssm_state)
    conv = state.conv_state
    if conv is not None:
        conv = conv.at[:, rows].set(sub.conv_state.astype(conv.dtype))
    return ServeState(
        cache=cache, ssm_state=ssm, conv_state=conv,
        cross_k=state.cross_k, cross_v=state.cross_v,
        last_tokens=state.last_tokens.at[rows].set(sub.last_tokens),
        decode_steps=state.decode_steps)


def reset_state_rows(state: ServeState, rows, cache=_KEEP) -> ServeState:
    """Retire rows: clear their cache/SSM state so their decode output is
    exactly zero and the rows can be handed back to the freelist.
    ``cache`` overrides the cache reset (backends pass their own layout)."""
    m = rows_to_mask(rows, state.last_tokens.shape[0])
    if cache is _KEEP:
        cache = state.cache
        if cache is not None:
            cache = (paged_release_rows(cache, rows)
                     if isinstance(cache, PagedCache)
                     else reset_rows(cache, rows))
    ssm = state.ssm_state
    if ssm is not None:
        ssm = jnp.where(m[None, :, None, None, None], 0, ssm)
    conv = state.conv_state
    if conv is not None:
        conv = jnp.where(m[None, :, None, None], 0, conv)
    return ServeState(
        cache=cache, ssm_state=ssm, conv_state=conv,
        cross_k=state.cross_k, cross_v=state.cross_v,
        last_tokens=jnp.where(m, 0, state.last_tokens),
        decode_steps=state.decode_steps)
