"""Continuous-batching scheduler over the slot cache (DESIGN.md §7).

The engine's decode step is batch-shaped: every tick runs all ``max_rows``
batch rows, and a retired row (``lengths == 0`` everywhere) contributes
exactly zero work inside ``fairkv_decode`` and zero output through the
o-projection.  Continuous batching therefore reduces to *row bookkeeping*:

- a **freelist** hands out retired rows to queued requests;
- **admission** prefills the new request alone — with slot-cache ownership
  evaluated at its target global row id (``prefill(..., rows=[row])``) — and
  splices the resulting sub-state into the live batch (``splice_state``);
- **retirement** (EOS or max-new-tokens) zeroes the row's cache/SSM state
  (``reset_state_rows``) and returns the row to the freelist.

On top of the lifecycle the scheduler watches the *realized* per-shard KV
load (Σ ``lengths`` per shard, the paper's Eq. 4 observable) over a sliding
window; when the max/mean imbalance stays above a threshold for the whole
window (hysteresis) and a cooldown has elapsed, it rebuilds the
``HeadPlacement`` from the realized per-head profile (``build_plan``),
re-slotifies the weights, and migrates the live cache into the new layout
(``migrate_cache``) — the online form of ``examples/straggler_replan.py``.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.cache.slot_cache import PlanArrays
from repro.compression.base import CompressionConfig
from repro.compression.policies import layer_keep_bound
from repro.configs.base import ModelConfig
from repro.core.placement import HeadPlacement
from repro.core.planner import PlannerConfig, build_plan
from repro.exec.base import Executor, make_executor
from repro.obs import NULL_OBS, Obs
from repro.paging.block_pool import PoolExhausted
from repro.prefix import PrefixConfig, PrefixEntry, PrefixIndex
from repro.serving import engine as _serve
from repro.serving.cache_backend import CacheBackend, make_cache_backend
from repro.serving.engine import slotify_params
from repro.serving.request import (Request, RequestState,
                                   latency_percentiles)
from repro.serving.speculation import SpeculationConfig


# ---------------------------------------------------------------------------
# Row freelist
# ---------------------------------------------------------------------------


class RowFreelist:
    """Free batch rows, handed out lowest-index-first (deterministic)."""

    def __init__(self, n_rows: int):
        self.n_rows = n_rows
        self._free = sorted(range(n_rows))

    def __len__(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return self.n_rows - len(self._free)

    def acquire(self) -> Optional[int]:
        return self._free.pop(0) if self._free else None

    def release(self, row: int) -> None:
        if not 0 <= row < self.n_rows:
            raise ValueError(f"row {row} out of range [0, {self.n_rows})")
        if row in self._free:
            raise ValueError(f"row {row} double-freed")
        self._free.append(row)
        self._free.sort()


# ---------------------------------------------------------------------------
# Chunked prefill job (DESIGN.md §14)
# ---------------------------------------------------------------------------


@dataclass
class _ChunkJob:
    """One in-flight chunked prefill: the request sits in PREFILLING with a
    row reserved while `Scheduler.step` advances its private B=1 sub-state
    one chunk per tick.  No live-state blocks are held until the final
    chunk splices (atomic on PoolExhausted), so aborting a job only
    unwinds the row, the pin, and the request state."""

    req: Request
    row: int
    prompt: np.ndarray
    state: object  # B=1 ServeState accumulating retained chunks
    next_pos: int = 0  # absolute position of the next chunk's first token
    entry: Optional[PrefixEntry] = None  # pinned seed entry on a prefix hit
    seed_tokens: int = 0  # tokens covered by the seed (0 = cold start)
    # full-chunk boundary -> (L, H) cumulative retained lengths, snapshotted
    # as each chunk lands (the donor-side input to index registration)
    boundaries: Dict[int, np.ndarray] = field(default_factory=dict)
    last_logits: Optional[np.ndarray] = None


# ---------------------------------------------------------------------------
# Replan trigger (hysteresis)
# ---------------------------------------------------------------------------


@dataclass
class ReplanTrigger:
    """Fires when imbalance stays above ``threshold`` for a full sliding
    ``window`` of observations, at most once per ``cooldown`` steps.

    The window acts as hysteresis: one transient spike (e.g. right after an
    admission, before other rows catch up) never triggers a replan.
    """

    window: int = 8
    threshold: float = 1.25
    cooldown: int = 16
    _history: deque = field(default_factory=deque, repr=False)
    _last_fire: Optional[int] = None

    def observe(self, imbalance: float) -> None:
        """Record one per-step imbalance observation."""
        self._history.append(float(imbalance))
        while len(self._history) > self.window:
            self._history.popleft()

    def ready(self, step: int) -> bool:
        """Armed: full window above threshold + cooldown elapsed."""
        if len(self._history) < self.window:
            return False
        if any(x <= self.threshold for x in self._history):
            return False
        return (self._last_fire is None
                or step - self._last_fire >= self.cooldown)

    def fire(self, step: int) -> None:
        """Consume the armed state (called when a replan actually runs)."""
        self._last_fire = step
        self._history.clear()


# ---------------------------------------------------------------------------
# Scheduler
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SchedulerConfig:
    max_rows: int = 4  # fixed decode batch width (row slots)
    # admission token budget (slot backend): projected Σ lengths over (L, H)
    # the live cache may hold; None admits on free rows alone.  The paged
    # backend ignores this — its budget is the free-block pool itself.
    max_live_tokens: Optional[int] = None
    # per-model-shard admission budget (slot backend, DESIGN.md §10): the
    # projected Σ lengths any single shard may hold — the bottleneck shard
    # gates admission, which is what makes balanced (Fair-Copying) plans
    # admit more concurrent rows than imbalanced ones (benchmarks/fig8).
    # The paged backend's analog is its per-partition free-block check.
    max_live_tokens_per_shard: Optional[int] = None
    replan_window: int = 8
    replan_threshold: float = 1.25
    replan_cooldown: int = 16
    replan_min_rows: int = 2  # don't replan a near-empty batch
    enable_replan: bool = True
    collect_logits: bool = False  # keep per-token logits on each Request


class Scheduler:
    """Admission + interleaved decode + retirement + online replanning."""

    def __init__(
        self,
        cfg: ModelConfig,
        params: dict,
        plan: HeadPlacement,
        ccfg: CompressionConfig,
        scfg: SchedulerConfig,
        planner_cfg: Optional[PlannerConfig] = None,
        dtype=jnp.float32,
        serve_params: Optional[dict] = None,
        backend: Optional[CacheBackend] = None,
        executor: Optional[Executor] = None,
        head_importance: Optional[np.ndarray] = None,
        obs: Optional[Obs] = None,
        plan_profile: Optional[np.ndarray] = None,
        prefix_cfg: Optional[PrefixConfig] = None,
        spec_cfg: Optional[SpeculationConfig] = None,
    ):
        if cfg.is_encoder_decoder or cfg.is_vlm:
            raise NotImplementedError(
                "continuous batching supports token-prompt decoder models")
        self.cfg = cfg
        self.params = params  # original layout — kept for re-slotify on replan
        self.plan = plan
        self.pa = PlanArrays.from_plan(plan)
        self.ccfg = ccfg
        self.scfg = scfg
        self.pcfg = planner_cfg or PlannerConfig(
            mode=plan.mode, slots_per_shard=plan.slots_per_shard,
            r_max=plan.r_max, batch_cap=scfg.max_rows)
        self.dtype = dtype
        # serve_params: pre-slotified weights for *this plan* (the Engine
        # facade passes its own copy so the permutation isn't paid twice)
        self.sp = (serve_params if serve_params is not None
                   else slotify_params(params, plan, cfg))
        # cache backend: storage layout + admission accounting (DESIGN.md §9)
        self.backend = backend if backend is not None else make_cache_backend(
            "slot", cfg, ccfg, max_live_tokens=scfg.max_live_tokens,
            n_shards=plan.n_shards,
            max_live_tokens_per_shard=scfg.max_live_tokens_per_shard)
        # executor: the compiled StepFns the hot loop runs (DESIGN.md §10);
        # sp/pa are StepFn *arguments*, so replans swap placements through
        # the same executable — no retrace
        self.executor = (executor if executor is not None
                         else make_executor("local", cfg, ccfg,
                                            paging=self.backend.paging))
        # per-head weights for importance-driven policies (headkv): admission
        # prefills must compress with the same budgets the profile was
        # measured under, or realized loads drift from the plan
        self.head_importance = head_importance
        # observability (DESIGN.md §12): one shared registry/trace pair for
        # the whole stack.  Threading it into the backend and executor makes
        # pool counters and StepFn timings land in the same registry the
        # scheduler's load gauges use — backend *before* init_state, so the
        # paged backend's BlockPool is born with the live handle
        self.obs = obs if obs is not None else NULL_OBS
        if obs is not None:
            self.backend.obs = self.obs
            self.executor.obs = self.obs
        # the per-head profile the current plan was planned from (for the
        # shard_projected_load gauge); refreshed on every accepted replan
        self.plan_profile = (None if plan_profile is None
                             else np.asarray(plan_profile, np.float64))
        # born sharded: the mesh executor lays the empty state out under its
        # decode specs, so the cache never sits replicated on one device
        self.state = self.executor.shard_state(
            self.backend.init_state(self.pa, scfg.max_rows, dtype))

        # prefix cache + chunked prefill (DESIGN.md §14).  Chunking needs
        # only the dense-attention chunk StepFn; block *sharing* further
        # needs the paged backend with a single-partition pool (shared
        # blocks must be valid for any recipient row — a mesh pool pins
        # blocks to the donor's (shard, row-partition) device).
        self.prefix_cfg = prefix_cfg if prefix_cfg is not None \
            else PrefixConfig()
        self.prefilling: Dict[int, _ChunkJob] = {}  # row -> in-flight job
        self._chunk_ok = (self.prefix_cfg.chunk_tokens > 0
                          and cfg.family == "dense"
                          and not cfg.attention_free)
        self.prefix: Optional[PrefixIndex] = None
        pool = getattr(self.backend, "pool", None)
        if (self.prefix_cfg.enabled and self._chunk_ok
                and self.backend.name == "paged" and pool is not None
                and pool.n_partitions == 1):
            self.prefix = PrefixIndex(self.prefix_cfg.chunk_tokens,
                                      self.prefix_cfg.max_entries,
                                      obs=self.obs)
            self.prefix.pool = pool

        # speculative decoding (DESIGN.md §16): propose k draft tokens per
        # tick against the live paged cache, verify them in one multi-query
        # pass, commit the accepted run.  Provisional blocks come from the
        # same pool as ordinary decode growth; rejection trims them back.
        self.spec = spec_cfg if (spec_cfg is not None
                                 and spec_cfg.enabled) else None
        if self.spec is not None:
            _serve._spec_supported(cfg)  # dense decoder-only models
            if self.backend.name != "paged":
                raise ValueError(
                    "speculative decoding needs the paged backend "
                    "(provisional blocks + rollback), got "
                    f"cache_backend={self.backend.name!r}")
            d = self.spec.draft_layers
            if d > cfg.n_layers:
                raise ValueError(
                    f"speculation.draft_layers={d} exceeds the model's "
                    f"{cfg.n_layers} layers")
        # per-row adaptive speculation depth (request-scoped: seeded at
        # max_k on admission, dropped with the row)
        self._spec_depth: Dict[int, int] = {}
        # persisted straggler speed factors (set by a speed-aware replan):
        # imbalance() and every later replan score/plan against them, so an
        # auto-replan never silently reverts the mitigation
        self.shard_speeds: Optional[np.ndarray] = None
        self.queue: deque = deque()
        self.active: Dict[int, Request] = {}  # row -> request
        self.freelist = RowFreelist(scfg.max_rows)
        self.trigger = ReplanTrigger(window=scfg.replan_window,
                                     threshold=scfg.replan_threshold,
                                     cooldown=scfg.replan_cooldown)
        self.step_idx = 0
        self.n_replans = 0
        self.n_preemptions = 0
        self.n_cancellations = 0
        # graceful shutdown (DESIGN.md §13): once draining, admission stops
        # but live rows keep decoding to completion — set via drain()
        self.draining = False
        self.replan_log: List[dict] = []  # {step, imbalance_before/after}
        self.finished: List[Request] = []
        if self.obs.enabled:
            # pre-register outcome series so exports show explicit zeros
            c = self.obs.metrics.counter(
                "sched_replans_total",
                help="replan attempts by outcome (accepted replans migrated "
                     "the live cache; rejected left state untouched)")
            c.inc(0, outcome="accepted")
            c.inc(0, outcome="rejected")
            self._sample_plan_metrics()

    # ---- engine plumbing ---------------------------------------------------

    def _decode(self, state, active):
        """One decode tick through the executor's StepFn."""
        return self.executor.decode(self.sp, state, self.pa,
                                    state.last_tokens, active=active)

    # ---- speculative decoding (DESIGN.md §16) ------------------------------

    def _spec_depths(self) -> np.ndarray:
        """(max_rows,) speculation depth for this tick: the per-request
        adaptive depth clamped by the remaining token budget (a row never
        proposes past its own ``max_new_tokens``) and by cache headroom
        (an at-capacity row degrades to q_len = 1, i.e. plain decode)."""
        depth = np.zeros(self.scfg.max_rows, np.int32)
        lens = (np.asarray(self.state.cache.lengths)
                if self.state.cache is not None else None)
        cap = self.backend.capacity
        for row, req in self.active.items():
            want = self._spec_depth.setdefault(row, self.spec.max_k)
            remaining = req.max_new_tokens - req.n_generated
            headroom = cap - (int(lens[:, :, row].max())
                              if lens is not None else 0)
            depth[row] = max(0, min(want, remaining - 1, headroom - 1))
        return depth

    def _decode_tick_speculative(self, events: dict) -> None:
        """One speculative tick: propose up to k draft tokens per row, one
        multi-query verify pass, commit the accepted run (1..k+1 tokens).

        Provisional cache entries are appended by propose/verify through the
        ordinary block-pool path (`prepare_decode(n_tokens=...)` reserves
        them up front, preempting if the pool is dry); after verify,
        `trim_rows` returns every block past the committed lengths to the
        pool — the rollback side of the trial-commit.  TTFT is untouched
        (stamped at admission); ITL stays honest because `itl_seconds` is
        the per-request *mean* cadence, which a multi-token commit
        accelerates exactly as a client would observe."""
        spec = self.spec
        d = spec.draft_layers if spec.draft_layers > 0 else self.cfg.n_layers
        depth = self._spec_depths()
        self._prepare_decode(n_tokens=int(depth.max()) + 1)
        if not self.active:  # everything got preempted reserving blocks
            return
        q_lens = jnp.asarray(depth + 1, jnp.int32)
        mask = self.active_mask()
        with self.obs.trace.span("decode_tick", rows=len(self.active),
                                 spec_max_depth=int(depth.max())):
            st, props = self.executor.propose(
                self.sp, self.state, self.pa, jnp.asarray(depth),
                active=mask, draft_layers=d, max_k=spec.max_k)
            tokens = jnp.concatenate(
                [st.last_tokens[:, None], jnp.asarray(props)], axis=1)
            st, g, n_commit, logits = self.executor.verify(
                self.sp, st, self.pa, tokens, q_lens,
                active=mask, draft_layers=d)
        self.state = self.backend.trim_rows(st, sorted(self.active))
        g_np, nc = np.asarray(g), np.asarray(n_commit)
        logits_np = np.asarray(logits) if self.scfg.collect_logits else None
        tick_proposed = tick_accepted = 0
        for row in sorted(self.active):
            req = self.active[row]
            n, prop = int(nc[row]), int(depth[row])
            req.spec_proposed += prop
            req.spec_accepted += max(0, n - 1)
            tick_proposed += prop
            tick_accepted += max(0, n - 1)
            # commit the accepted run, truncating at EOS / max_new_tokens
            # (the cache may hold a few tokens past the cut; the row is
            # retired right below, which frees them with the row)
            for i in range(n):
                req.generated.append(int(g_np[row, i]))
                if logits_np is not None:
                    req.logits.append(logits_np[row, i])
                if self._done(req):
                    break
            if spec.adaptive and prop > 0:
                alpha = (n - 1) / prop
                want = self._spec_depth[row]
                if alpha < spec.low_acceptance:
                    self._spec_depth[row] = max(spec.min_k, want - 1)
                elif alpha >= spec.high_acceptance:
                    self._spec_depth[row] = min(spec.max_k, want + 1)
        if self.obs.enabled:
            m = self.obs.metrics
            m.counter("spec_proposed_total",
                      help="draft tokens proposed by speculative decode"
                      ).inc(tick_proposed)
            m.counter("spec_accepted_total",
                      help="draft tokens accepted by the verify pass"
                      ).inc(tick_accepted)
            depths = [self._spec_depth[r] for r in self.active]
            m.gauge("spec_depth",
                    help="mean adaptive speculation depth over live rows"
                    ).set(float(np.mean(depths)))
        for row in sorted(self.active):
            req = self.active[row]
            if self._done(req):
                self._retire(req)
                events["finished"].append(req.req_id)

    # ---- load accounting ---------------------------------------------------

    def live_tokens(self) -> int:
        """Σ retained lengths over the whole live cache (all layers/slots)."""
        if self.state.cache is None:
            return 0
        return int(np.asarray(self.state.cache.lengths).sum())

    def per_shard_load(self) -> np.ndarray:
        """(n_shards,) realized Σ lengths per shard — the Eq. 4 observable."""
        S_per = self.plan.slots_per_shard
        if self.state.cache is None:
            return np.zeros(self.plan.n_shards)
        lens = np.asarray(self.state.cache.lengths)  # (L, S, B)
        per_slot = lens.sum(axis=(0, 2))  # (S,)
        return per_slot.reshape(self.plan.n_shards, S_per).sum(axis=1)

    def _imbalance_from(self, load: np.ndarray) -> float:
        """max/mean of an already-computed per-shard load vector (the step
        loop computes the load once and feeds both this and the gauges)."""
        if self.shard_speeds is not None:
            load = load / self.shard_speeds
        mean = load.mean()
        return float(load.max() / mean) if mean > 0 else 1.0

    def imbalance(self) -> float:
        """max/mean per-shard realized load (1.0 = perfectly fair); under
        persisted ``shard_speeds`` it is the *time* imbalance load/speed."""
        return self._imbalance_from(self.per_shard_load())

    # ---- observability sampling (DESIGN.md §12) ----------------------------

    def _sample_plan_metrics(self) -> None:
        """Gauge the *projected* per-shard load of the current plan under
        the profile it was planned from — the planner's promise, against
        which ``shard_load_tokens`` shows the realized truth."""
        if self.plan_profile is None:
            return
        g = self.obs.metrics.gauge(
            "shard_projected_load",
            help="planner-projected per-shard load of the active placement "
                 "under the profile it was planned from")
        for s, v in enumerate(self.plan.per_shard_load(self.plan_profile)):
            g.set(float(v), shard=str(s))

    def _sample_step_metrics(self, load: np.ndarray, imb: float) -> None:
        """Per-tick gauges (host-side; called only when obs is on)."""
        m = self.obs.metrics
        g = m.gauge("shard_load_tokens",
                    help="realized Σ retained KV tokens per model shard "
                         "(the paper's Eq. 4 observable)")
        for s, v in enumerate(load):
            g.set(float(v), shard=str(s))
        m.gauge("sched_imbalance",
                help="max/mean per-shard realized load (1.0 = fair); "
                     "speed-normalized under persisted shard_speeds"
                ).set(imb)
        m.gauge("sched_active_rows",
                help="batch rows holding a live request").set(
            len(self.active))
        m.gauge("sched_queue_depth",
                help="requests waiting in the FCFS queue").set(
            len(self.queue))
        m.gauge("sched_prefilling_rows",
                help="rows held by in-flight chunked prefills "
                     "(DESIGN.md §14)").set(len(self.prefilling))
        if self.prefix is not None:
            st = self.prefix.stats()
            m.gauge("prefix_entries",
                    help="prompt-prefix boundaries held by the index").set(
                st["entries"])
            m.gauge("prefix_shared_blocks",
                    help="pool blocks referenced by prefix entries").set(
                st["blocks_held"])
            # bytes the pool did NOT have to duplicate: every reference
            # beyond the first on an allocated block is a block of KV the
            # sharing recipients would otherwise each hold privately
            pool = self.backend.pool
            extra = int(np.maximum(pool.refcount - 1, 0).sum())
            c = self.state.cache
            if c is not None and hasattr(c, "k_pool"):
                blk_bytes = (2 * c.k_pool.shape[2] * c.k_pool.shape[3]
                             * c.k_pool.dtype.itemsize)
                m.gauge("prefix_bytes_saved",
                        help="KV bytes deduplicated by prefix sharing "
                             "(Σ (refcount−1) · block bytes)").set(
                    extra * blk_bytes)
        self.backend.sample_metrics(self.state)
        pe = self.obs.cfg.print_every
        if pe > 0 and self.step_idx % pe == 0:
            print(f"[obs] step={self.step_idx} active={len(self.active)} "
                  f"queued={len(self.queue)} finished={len(self.finished)} "
                  f"imbalance={imb:.3f} preemptions={self.n_preemptions} "
                  f"replans={self.n_replans}", flush=True)

    def realized_profile(self) -> np.ndarray:
        """(L, H) mean retained length per head over *active* rows.

        Replicas of one head own disjoint rows, so summing ``lengths`` over
        the head's slots recovers each row's full per-head length.
        """
        lens = np.asarray(self.state.cache.lengths)  # (L, S, B)
        sh = np.asarray(self.pa.slot_head)  # (L, S)
        L, S, B = lens.shape
        H = self.plan.n_heads
        rows = sorted(self.active)
        if not rows:
            raise RuntimeError("no active rows to profile")
        prof = np.zeros((L, H), dtype=np.float64)
        for h in range(H):
            contrib = np.where(sh[:, :, None] == h, lens, 0)  # (L, S, B)
            prof[:, h] = contrib[:, :, rows].sum(axis=1).mean(axis=1)
        return np.maximum(prof, 1.0)

    # ---- admission ---------------------------------------------------------

    def submit(self, req: Request) -> None:
        # fail fast on a request that could never be admitted: FCFS would
        # head-of-line block behind it until max_steps with no diagnostic
        reason = self.backend.never_fits(req)
        if reason is not None:
            raise ValueError(
                f"request {req.req_id} can never be admitted: {reason}")
        req.state = RequestState.QUEUED
        if req.arrival_time is None:
            req.arrival_time = time.time()
        self.queue.append(req)

    def _estimated_cost(self, req: Request) -> int:
        """Projected cost in the backend's units (slot: Σ-lengths bound via
        the per-policy keep bounds; paged: worst-case blocks)."""
        return self.backend.request_cost(req)

    def admissible(self, req: Request) -> bool:
        if len(self.freelist) == 0:
            return False
        # in-flight chunked prefills hold rows but no blocks until their
        # final-chunk splice: charge them as pending so admission does not
        # promise the same free blocks twice (DESIGN.md §14)
        pending = [j.req for j in self.prefilling.values()]
        return self.backend.admissible(self.state, req, pending=pending)

    def _admit(self, req: Request) -> Optional[int]:
        """Prefill + splice; returns the row, or None when the cache
        backend ran out of memory even after preempting (caller requeues)."""
        row = self.freelist.acquire()
        assert row is not None
        req.state = RequestState.PREFILLING
        req.row = row
        req.admit_step = self.step_idx
        batch = {"tokens": jnp.asarray(req.prompt, jnp.int32)[None, :]}
        sub, logits, _lens = self.executor.prefill(
            self.sp, batch, self.pa, rows=jnp.asarray([row]),
            head_importance=self.head_importance)
        try:
            self.state = self.backend.splice(self.state, sub,
                                             jnp.asarray([row]))
        except PoolExhausted:
            # admission never preempts (only decode growth does — evicting
            # older in-flight work to admit newer would invert FCFS): undo
            # and let the caller requeue.  Unreachable for the built-in
            # backends, whose admissible() charge dominates the splice need;
            # this guards plugin backends with looser admission estimates.
            self.freelist.release(row)
            req.state = RequestState.QUEUED
            req.row = None
            req.admit_step = None
            return None
        first = int(np.asarray(sub.last_tokens)[0])
        req.generated.append(first)
        req.first_token_step = self.step_idx
        req.first_token_time = time.time()
        self.obs.metrics.counter(
            "sched_admissions_total",
            help="requests admitted (prefilled + spliced)").inc()
        ttft = req.ttft_seconds()
        if ttft is not None:
            self.obs.metrics.histogram(
                "ttft_s", help="time to first token (queue wait + prefill "
                               "wall time)").observe(ttft)
        if self.scfg.collect_logits:
            req.logits = [np.asarray(logits[0])]
        req.state = RequestState.DECODING
        self.active[row] = req
        if self._done(req):
            self._retire(req)
        return row

    def _done(self, req: Request) -> bool:
        if req.n_generated >= req.max_new_tokens:
            return True
        return req.eos_id is not None and req.generated[-1] == req.eos_id

    # ---- chunked prefill + prefix sharing (DESIGN.md §14) ------------------

    def _should_chunk(self, req: Request) -> bool:
        """Prompts longer than one chunk go through the chunked path; a
        prompt that fits in a single chunk gains nothing from it."""
        return (self._chunk_ok
                and req.prompt_len > self.prefix_cfg.chunk_tokens)

    def _stamp_prefix_hit(self, req: Request) -> Optional[PrefixEntry]:
        """Look up the longest shared prefix and stamp the request's
        admission discount (`prefix_shared_blocks`); returns the entry so
        the admission loop can seed from it without a second lookup."""
        if self.prefix is None or not self._should_chunk(req):
            req.prefix_shared_blocks = None
            return None
        entry = self.prefix.lookup(np.asarray(req.prompt, np.int32))
        if entry is None:
            req.prefix_shared_blocks = None
            req.prefix_hit_tokens = 0
            return None
        req.prefix_hit_tokens = entry.tokens
        bs = self.backend.block_size
        full = np.asarray(entry.lengths) // bs  # (L, H) full blocks per head
        req.prefix_shared_blocks = full.sum(axis=1).astype(np.int64)
        return entry

    def _head_slot_table(self, entry: PrefixEntry, row: int):
        """Map an entry's head-indexed blocks onto the slots owning each
        head *for this row* → ((L, S, 1, M) ids, (L, S, 1) lengths).
        Replicas of one head serve disjoint rows, so donor and recipient
        may home the same head in different slots; block content is
        head-level, so rehoming is purely a table rewrite."""
        sh = np.asarray(self.pa.slot_head)
        ri = np.asarray(self.pa.replica_idx)
        rc = np.asarray(self.pa.replica_count)
        own = (sh >= 0) & ((row % np.maximum(rc, 1)) == ri)  # (L, S)
        L, S = sh.shape
        M = self.backend.max_blocks
        tbl = np.zeros((L, S, 1, M), np.int32)
        lens = np.zeros((L, S, 1), np.int32)
        n = min(entry.table.shape[2], M)
        for l, s in zip(*np.nonzero(own)):
            h = int(sh[l, s])
            tbl[l, s, 0, :n] = entry.table[l, h, :n]
            lens[l, s, 0] = entry.lengths[l, h]
        return tbl, lens

    def _seed_from_entry(self, entry: PrefixEntry, row: int):
        """Materialize a matched prefix into a fresh B=1 sub-state.

        The entry's blocks are viewed through a synthetic one-row table and
        gathered with `paged_to_slot` — a deep copy, so the shared blocks
        are read, never aliased; the final splice maps the same full blocks
        back into the row's stored table without rewriting them."""
        from repro.paging.paged_cache import PagedCache, paged_to_slot
        live = self.state.cache
        tbl, lens = self._head_slot_table(entry, row)
        view = PagedCache(k_pool=live.k_pool, v_pool=live.v_pool,
                          pos_pool=live.pos_pool,
                          block_table=jnp.asarray(tbl),
                          lengths=jnp.asarray(lens),
                          positions=jnp.full((1,), entry.tokens, jnp.int32))
        slot = paged_to_slot(view, self.backend.capacity)
        return _serve.init_serve_state(self.cfg, self.pa, 1, self.ccfg,
                                       dtype=self.dtype, cache=slot)

    def _start_chunked(self, req: Request,
                       entry: Optional[PrefixEntry]) -> int:
        """Begin a chunked prefill: reserve the row, seed from the matched
        prefix boundary (if any), and leave the job in ``prefilling`` —
        `step` advances it one chunk per tick, so decode ticks for live
        rows interleave instead of stalling behind a long prompt."""
        row = self.freelist.acquire()
        assert row is not None
        req.state = RequestState.PREFILLING
        req.row = row
        req.admit_step = self.step_idx
        prompt = np.asarray(req.prompt, np.int32)
        if entry is not None:
            sub = self._seed_from_entry(entry, row)
            self.prefix.pin(entry)  # immune to eviction while we read it
            start = entry.tokens
        else:
            sub = _serve.init_serve_state(self.cfg, self.pa, 1, self.ccfg,
                                          dtype=self.dtype)
            start = 0
        self.prefilling[row] = _ChunkJob(req=req, row=row, prompt=prompt,
                                         state=sub, next_pos=start,
                                         entry=entry, seed_tokens=start)
        return row

    def _chunk_quota(self, T: int, n: int) -> np.ndarray:
        """(L,) per-head keep cap for an ``n``-token chunk of a ``T``-token
        prompt: the monolithic per-head bound prorated by the chunk's share
        of the prompt (floor 1, so every chunk may retain something).  The
        union over chunks then tracks the monolithic budget to within one
        block of ceil slack per chunk — exact for policy "none"."""
        H, L = self.cfg.n_kv_heads, self.cfg.n_layers
        full = np.asarray([layer_keep_bound(self.ccfg.policy, self.ccfg,
                                            T, H, l, L) // H
                           for l in range(L)], np.int64)
        return np.maximum(1, np.ceil(full * n / T)).astype(np.int32)

    def _run_chunks(self, events: dict) -> None:
        """Advance every in-flight chunked prefill by exactly one chunk —
        the §14 interleaving contract: live-row decode latency is bounded
        by one chunk plus one decode step, never a whole prefill."""
        Ck = self.prefix_cfg.chunk_tokens
        for row in sorted(self.prefilling):
            job = self.prefilling[row]
            T = int(job.prompt.shape[0])
            n = min(Ck, T - job.next_pos)
            chunk = np.zeros((1, Ck), np.int32)
            chunk[0, :n] = job.prompt[job.next_pos:job.next_pos + n]
            with self.obs.trace.span("prefill_chunk", req=job.req.req_id,
                                     start=job.next_pos, tokens=n):
                job.state, logits, lens = self.executor.prefill_chunk(
                    self.sp, chunk, self.pa, job.state,
                    rows=np.asarray([row], np.int32),
                    start=np.asarray([job.next_pos], np.int32),
                    valid=np.asarray([n], np.int32),
                    quota=self._chunk_quota(T, n),
                    head_importance=self.head_importance)
            job.next_pos += n
            if n == Ck:  # full-chunk boundary: snapshot for registration
                job.boundaries[job.next_pos] = np.asarray(lens)[:, :, 0]
            if job.next_pos >= T:
                job.last_logits = np.asarray(logits)
                self._finish_chunked(job, events)

    def _finish_chunked(self, job: _ChunkJob, events: dict) -> None:
        """Final chunk landed: splice the sub-state into the live batch
        (sharing the seed's full blocks), stamp the first token — TTFT
        spans submit → here, across every chunk — and register this
        prompt's boundaries as new prefix entries."""
        req, row = job.req, job.row
        shared = None
        if job.entry is not None:
            shared, _ = self._head_slot_table(job.entry, row)
        while True:
            try:
                if shared is not None:
                    self.state = self.backend.splice(
                        self.state, job.state, jnp.asarray([row]),
                        shared_blocks=shared)
                else:
                    self.state = self.backend.splice(self.state, job.state,
                                                     jnp.asarray([row]))
                break
            except PoolExhausted:
                # cheapest memory first: entries held only by the index
                if self.prefix is not None and self.prefix.evict_lru():
                    continue
                self._abort_job(job, requeue=True)
                return
        del self.prefilling[row]
        if job.entry is not None:
            self.prefix.unpin(job.entry)
        first = int(np.asarray(job.state.last_tokens)[0])
        req.generated.append(first)
        req.first_token_step = self.step_idx
        req.first_token_time = time.time()
        self.obs.metrics.counter(
            "sched_admissions_total",
            help="requests admitted (prefilled + spliced)").inc()
        ttft = req.ttft_seconds()
        if ttft is not None:
            self.obs.metrics.histogram(
                "ttft_s", help="time to first token (queue wait + prefill "
                               "wall time)").observe(ttft)
        if self.scfg.collect_logits:
            req.logits = [job.last_logits[0]]
        req.state = RequestState.DECODING
        self.active[row] = req
        # register before any retirement: entries take their own refs off
        # the row's table, which release_rows would zero
        self._register_boundaries(job)
        if self._done(req):
            self._retire(req)
            events["finished"].append(req.req_id)

    def _abort_job(self, job: _ChunkJob, requeue: bool) -> None:
        """Unwind a job whose splice never landed: no blocks are held, so
        only the row, the pin, and the request state roll back."""
        del self.prefilling[job.row]
        if job.entry is not None:
            self.prefix.unpin(job.entry)
        self.freelist.release(job.row)
        req = job.req
        req.row = None
        if requeue:
            req.state = RequestState.QUEUED
            req.admit_step = None
            req.generated = []
            req.prefix_shared_blocks = None
            req.prefix_hit_tokens = 0
            self.queue.appendleft(req)

    def _register_boundaries(self, job: _ChunkJob) -> None:
        """Donor side of the index: adopt this prompt's full-chunk
        boundaries.  Each entry stores *full blocks only* with lengths
        truncated to the block-aligned prefix — the partial tail block is
        private to the row (its later appends would leak into sharers);
        the dropped remainder is re-copied from the seed gather for future
        hits, trading a few tokens of retained context for safe sharing."""
        if self.prefix is None:
            return
        bs = self.backend.block_size
        sh = np.asarray(self.pa.slot_head)
        ri = np.asarray(self.pa.replica_idx)
        rc = np.asarray(self.pa.replica_count)
        row = job.row
        own = (sh >= 0) & ((row % np.maximum(rc, 1)) == ri)
        L, S = sh.shape
        H, M = self.cfg.n_kv_heads, self.backend.max_blocks
        for t_j, key in self.prefix.chain_keys(job.prompt):
            if t_j <= job.seed_tokens or t_j not in job.boundaries:
                continue
            lens_h = job.boundaries[t_j]  # (L, H) retained at the boundary
            full = (lens_h // bs) * bs  # block-aligned shareable prefix
            if not full.any():
                continue
            table = np.zeros((L, H, M), np.int32)
            for l, s in zip(*np.nonzero(own)):
                h = int(sh[l, s])
                nb = int(full[l, h]) // bs
                if nb:
                    table[l, h, :nb] = self.backend.table[l, s, row, :nb]
            self.prefix.register(key, t_j, table, full.astype(np.int32))

    def prefix_stats(self) -> dict:
        """Index counters + entry census (empty dict when sharing is off)."""
        return {} if self.prefix is None else self.prefix.stats()

    def _release_row(self, req: Request) -> None:
        """Free a live request's row and its backing storage (blocks /
        slot state) — shared by retirement, cancellation, and preemption."""
        row = req.row
        self.state = self.backend.release_rows(self.state, jnp.asarray([row]))
        del self.active[row]
        self.freelist.release(row)
        self._spec_depth.pop(row, None)

    def _retire(self, req: Request) -> None:
        self._release_row(req)
        req.state = RequestState.FINISHED
        req.finish_step = self.step_idx
        req.finish_time = time.time()
        req.row = None
        self.finished.append(req)
        m = self.obs.metrics
        m.counter("sched_retirements_total",
                  help="requests retired (EOS or max-new-tokens)").inc()
        self.obs.trace.instant("retire", req=req.req_id,
                               n_generated=req.n_generated)
        itl = req.itl_seconds()
        if itl is not None:
            m.histogram("itl_s",
                        help="inter-token latency (per-request mean in "
                             "continuous mode; per-step in one-shot mode)"
                        ).observe(itl)
        if req.arrival_time is not None:
            m.histogram("e2e_s", help="end-to-end request latency"
                        ).observe(req.finish_time - req.arrival_time)
        if req.spec_proposed > 0:
            m.histogram("spec_acceptance",
                        help="per-request draft acceptance rate "
                             "(accepted / proposed over the lifetime)"
                        ).observe(req.spec_accepted / req.spec_proposed)

    # ---- cancellation + draining (DESIGN.md §13) ---------------------------

    def cancel(self, req_id: int) -> bool:
        """Retire a request early (client disconnect, deadline shed).

        An in-flight row is released exactly like a normal retirement —
        the paged backend frees its blocks back to the pool (refcounts
        decremented), the slot backend zeroes the row — so cancellation
        conserves pool capacity.  A still-queued request is simply removed.
        The request lands in ``finished`` with state CANCELLED so trace
        drivers and streams observe a terminal state.  Returns False when
        the id is unknown or already finished.
        """
        req = next((r for r in self.active.values()
                    if r.req_id == req_id), None)
        if req is not None:
            self._release_row(req)
        else:
            job = next((j for j in self.prefilling.values()
                        if j.req.req_id == req_id), None)
            if job is not None:  # mid-chunked-prefill: no blocks held yet
                req = job.req
                self._abort_job(job, requeue=False)
            else:
                req = next((r for r in self.queue
                            if r.req_id == req_id), None)
                if req is None:
                    return False
                self.queue.remove(req)
        req.state = RequestState.CANCELLED
        req.finish_step = self.step_idx
        req.finish_time = time.time()
        req.row = None
        self.finished.append(req)
        self.n_cancellations += 1
        self.obs.metrics.counter(
            "sched_cancellations_total",
            help="requests retired early (client disconnect / deadline "
                 "shed); rows and blocks are released like a normal "
                 "retirement").inc()
        self.obs.trace.instant("cancel", req=req_id)
        return True

    def drain(self) -> None:
        """Graceful shutdown: stop admitting (queued requests stay queued
        for the driver to cancel or report), finish decoding live rows.
        `run` cancels the queue and sheds unsubmitted arrivals itself."""
        self.draining = True

    # ---- preemption (paged backend, DESIGN.md §9) --------------------------

    def _evict(self, victim: Request) -> None:
        """Preempt one live request back to QUEUED (recompute policy),
        freeing its rows/blocks.  Re-queued at the front: among equal
        priorities it is oldest by FCFS."""
        self._release_row(victim)
        victim.reset_for_requeue()
        self.queue.appendleft(victim)
        self.n_preemptions += 1
        self.obs.metrics.counter(
            "sched_preemptions_total",
            help="evictions back to QUEUED (pool exhaustion or priority "
                 "pressure), lowest-priority-youngest-first").inc()
        self.obs.trace.instant("preempt", req=victim.req_id,
                               priority=victim.priority)

    def _preempt_one(self) -> bool:
        """Evict the least-important, then youngest, active request.
        Victim choice protects invested work within a priority class: the
        most recently admitted request has the least progress to replay;
        across classes, low-priority (higher index) rows go first — the
        frontend's SLO enforcement lever (DESIGN.md §13).  Returns False
        when there is nothing (left) to evict."""
        victims = list(self.active.values())
        if not victims:
            return False
        self._evict(max(victims,
                        key=lambda r: (r.priority, r.admit_step, r.req_id)))
        return True

    def preempt_lower_priority(self, than: int) -> bool:
        """Evict one active request whose priority class is strictly less
        urgent than ``than`` (priority index greater), if any — called by
        the frontend when a high-priority request is starving behind a
        full batch.  Returns False when no such victim exists."""
        victims = [r for r in self.active.values() if r.priority > than]
        if not victims:
            return False
        self._evict(max(victims,
                        key=lambda r: (r.priority, r.admit_step, r.req_id)))
        return True

    def _prepare_decode(self, n_tokens: int = 1) -> None:
        """Backend pre-tick hook with preemption: guarantee every active
        row's next ``n_tokens`` appends have backing storage, evicting the
        youngest requests while the pool is dry."""
        while True:
            try:
                self.state = self.backend.prepare_decode(
                    self.state, sorted(self.active), n_tokens=n_tokens)
                return
            except PoolExhausted as e:
                # reclaim index-only prefix entries before evicting live
                # work — dropping a cache entry costs a future recompute,
                # preempting a request costs a guaranteed one (§14)
                if self.prefix is not None and self.prefix.evict_lru():
                    continue
                if not self._preempt_one():
                    raise RuntimeError(
                        "cache pool exhausted with nothing left to preempt "
                        "— the pool is too small for a single request "
                        f"({e}); raise PagingConfig.n_blocks") from e

    # ---- replanning --------------------------------------------------------

    def should_replan(self) -> bool:
        """Trigger armed (full window above threshold + cooldown elapsed) and
        enough live rows for the realized profile to be meaningful."""
        return (self.scfg.enable_replan
                and len(self.active) >= self.scfg.replan_min_rows
                and not self.prefilling  # sub-states pin the current plan
                and self.trigger.ready(self.step_idx))

    @staticmethod
    def _imbalance_of(lengths: np.ndarray, n_shards: int,
                      slots_per_shard: int,
                      shard_speeds: Optional[Sequence[float]] = None) -> float:
        """max/mean per-shard load; with ``shard_speeds`` the *time*
        imbalance load_j / speed_j (what a straggler-aware plan optimizes)."""
        per_slot = np.asarray(lengths).sum(axis=(0, 2))
        load = per_slot.reshape(n_shards, slots_per_shard).sum(axis=1)
        if shard_speeds is not None:
            load = load / np.asarray(shard_speeds, float)
        mean = load.mean()
        return float(load.max() / mean) if mean > 0 else 1.0

    def replan(self, profile: Optional[np.ndarray] = None,
               shard_speeds: Optional[Sequence[float]] = None) -> dict:
        """Rebuild the placement and migrate the live cache + weights into
        the new slot layout if it actually helps.

        Default: plan from the realized per-head profile of the active rows.
        ``profile`` overrides the planning input; ``shard_speeds`` plans
        against heterogeneous shard speeds (straggler mitigation,
        DESIGN.md §6) — both reachable live via ``Engine.replan``.  Passed
        speeds persist: subsequent trigger-fired replans keep planning and
        scoring against them (pass ``shard_speeds=np.ones(n_shards)`` to
        clear).

        The planner optimizes the *mean-over-rows* per-head profile, which at
        small row counts can mispredict the row-granular replica split — so
        the candidate layout is scored on the realized lengths post-migration
        and rejected (no state change, cooldown still consumed) unless it
        strictly reduces the per-shard imbalance.
        """
        with self.obs.trace.span("replan"):
            event = self._replan_impl(profile, shard_speeds)
        # outcome counter is the single source of truth for replan counts
        # (benchmarks read it instead of re-tallying replan_log)
        self.obs.metrics.counter("sched_replans_total").inc(
            outcome="accepted" if event["accepted"] else "rejected")
        return event

    def _replan_impl(self, profile: Optional[np.ndarray],
                     shard_speeds: Optional[Sequence[float]]) -> dict:
        if shard_speeds is not None:
            self.shard_speeds = np.asarray(shard_speeds, float)
        speeds = self.shard_speeds
        if self.prefilling:
            # chunked sub-states are laid out under the current plan and
            # prefix seeds reference the current pool: migrating under them
            # would corrupt both.  Reject; the trigger path never gets here
            # (should_replan), only direct Engine.replan calls can.
            before = self.imbalance()
            event = {"step": self.step_idx, "imbalance_before": before,
                     "imbalance_after": before, "accepted": False,
                     "rejected_reason": "chunked prefills in flight"}
            self.replan_log.append(event)
            return event
        # before/after under the same metric: speed-normalized when planning
        # against heterogeneous shards, raw otherwise
        before = self._imbalance_of(np.asarray(self.state.cache.lengths),
                                    self.plan.n_shards,
                                    self.plan.slots_per_shard, speeds)
        profile = (self.realized_profile() if profile is None
                   else np.asarray(profile, np.float64))
        new_plan = build_plan(profile, self.plan.n_shards, self.pcfg,
                              shard_speeds=speeds)
        new_pa = PlanArrays.from_plan(new_plan)
        try:
            cand_lengths, commit = self.backend.migrate_cache(
                self.state.cache, self.pa, new_pa,
                active_rows=sorted(self.active))
        except PoolExhausted as e:
            # block rounding under the new ownership split doesn't fit the
            # pool: reject without touching state (cooldown still consumed)
            event = {"step": self.step_idx, "imbalance_before": before,
                     "imbalance_after": before, "accepted": False,
                     "rejected_reason": f"pool exhausted: {e}"}
            self.replan_log.append(event)
            return event
        after = self._imbalance_of(np.asarray(cand_lengths),
                                   new_plan.n_shards,
                                   new_plan.slots_per_shard, speeds)
        event = {"step": self.step_idx, "imbalance_before": before,
                 "imbalance_after": after, "accepted": after < before - 1e-9}
        if not event["accepted"]:
            event["imbalance_after"] = before
            self.replan_log.append(event)
            return event
        self.state = dataclasses.replace(self.state, cache=commit())
        self.plan, self.pa = new_plan, new_pa
        self.sp = slotify_params(self.params, new_plan, self.cfg)
        if self.prefix is not None:
            # the backend rebuilt its pool from live tables only (shared
            # rows were deep-copied private): the index's references died
            # with the old pool, so drop entries without decref'ing and
            # rebind to the new pool — sharing re-warms from new admits
            self.prefix.flush(decref=False)
            self.prefix.pool = self.backend.pool
        # no StepFn rebuild: sp/pa are executor arguments, shapes unchanged
        self.n_replans += 1
        self.replan_log.append(event)
        if self.obs.enabled:
            # the new plan's promise, from the profile it was planned from
            self.plan_profile = profile
            self._sample_plan_metrics()
        return event

    # ---- main loop ---------------------------------------------------------

    def active_mask(self) -> jnp.ndarray:
        m = np.zeros(self.scfg.max_rows, dtype=bool)
        for row in self.active:
            m[row] = True
        return jnp.asarray(m)

    def step(self) -> dict:
        """One scheduler tick: admit → decode → retire → (maybe) replan."""
        events: dict = {"step": self.step_idx, "admitted": [], "finished": [],
                        "preempted": 0, "replanned": False}
        preempted_before = self.n_preemptions
        # admission: fill free rows from the queue, best (priority, FIFO)
        # first — with uniform priorities this is exactly the historical
        # strict FCFS (including preempted victims re-admitting first via
        # appendleft); a more urgent class jumps the line.  Head-of-line
        # blocking is per pick: the chosen request gates admission, so a
        # large urgent request is never starved by smaller later ones.
        # Draining (graceful shutdown) stops admission entirely.
        while self.queue and not self.draining:
            i = min(range(len(self.queue)),
                    key=lambda j: (self.queue[j].priority, j))
            req = self.queue[i]
            # prefix lookup before the admissibility check: a hit discounts
            # the shared blocks from the request's charge (DESIGN.md §14)
            entry = self._stamp_prefix_hit(req)
            if not self.admissible(req):
                break
            del self.queue[i]
            if self._should_chunk(req):
                with self.obs.trace.span("admit_chunked", req=req.req_id):
                    row = self._start_chunked(req, entry)
                events["admitted"].append((req.req_id, row))
                continue
            with self.obs.trace.span("admit", req=req.req_id):
                row = self._admit(req)
            if row is None:  # backend memory dry even after preemption
                self.queue.appendleft(req)
                break
            events["admitted"].append((req.req_id, row))
            if req.is_finished:  # max_new_tokens == 1 or instant EOS
                events["finished"].append(req.req_id)
        # one chunk for each in-flight chunked prefill, then one decode
        # tick: long prompts never head-of-line-block live rows (§14)
        if self.prefilling:
            self._run_chunks(events)
        # one interleaved decode tick for every live row — speculative
        # (k draft proposals + one multi-query verify, DESIGN.md §16) when
        # configured, single-token greedy otherwise
        if self.active and self.spec is not None:
            self._decode_tick_speculative(events)
        elif self.active:
            self._prepare_decode()  # may preempt (paged pool dry)
            if self.active:
                with self.obs.trace.span("decode_tick",
                                         rows=len(self.active)):
                    self.state, logits = self._decode(self.state,
                                                      self.active_mask())
                toks = np.asarray(self.state.last_tokens)
                logits_np = (np.asarray(logits) if self.scfg.collect_logits
                             else None)
                for row in sorted(self.active):
                    req = self.active[row]
                    req.generated.append(int(toks[row]))
                    if logits_np is not None:
                        req.logits.append(logits_np[row])
                for row in sorted(self.active):
                    req = self.active[row]
                    if self._done(req):
                        self._retire(req)
                        events["finished"].append(req.req_id)
        events["preempted"] = self.n_preemptions - preempted_before
        # load accounting + replan trigger (hysteresis inside the trigger);
        # the load vector feeds the trigger and the gauges from one compute
        load = self.per_shard_load()
        imb = self._imbalance_from(load)
        self.trigger.observe(imb)
        if self.obs.enabled:
            self._sample_step_metrics(load, imb)
        if self.should_replan():
            self.trigger.fire(self.step_idx)
            events["replan"] = self.replan()
            events["replanned"] = True
        self.step_idx += 1
        return events

    def run(self, requests: Sequence[Request],
            max_steps: int = 10_000) -> dict:
        """Drive a full trace: submit by ``arrival_step``, tick until every
        request is FINISHED (or ``max_steps``).  Returns summary telemetry."""
        pending = sorted(requests, key=lambda r: (r.arrival_step, r.req_id))
        n_total = len(pending)
        i = 0
        first_decode_step: Optional[int] = None
        mid_stream_admissions = 0
        t0 = time.time()
        while len(self.finished) < n_total and self.step_idx < max_steps:
            if self.draining:
                # graceful shutdown: cancel everything not yet decoding
                # (queued + unsubmitted arrivals) so the loop converges on
                # the in-flight rows alone, which decode to completion
                for req in list(self.queue):
                    self.cancel(req.req_id)
                while i < len(pending):
                    req = pending[i]
                    req.state = RequestState.CANCELLED
                    self.finished.append(req)
                    self.n_cancellations += 1
                    i += 1
                if not self.active and not self.prefilling:
                    break
            while (not self.draining and i < len(pending)
                   and pending[i].arrival_step <= self.step_idx):
                self.submit(pending[i])
                i += 1
            ev = self.step()
            if ev["admitted"] and first_decode_step is not None:
                mid_stream_admissions += len(ev["admitted"])
            if self.active or ev["finished"]:
                if first_decode_step is None:
                    first_decode_step = ev["step"]
        wall = time.time() - t0
        total_tokens = sum(r.n_generated for r in self.finished)
        summary = {
            "steps": self.step_idx,
            "wall_s": wall,
            "finished": len(self.finished),
            "total": n_total,
            "generated_tokens": total_tokens,
            "mid_stream_admissions": mid_stream_admissions,
            "replans": self.n_replans,
            "replan_log": list(self.replan_log),
            "preemptions": self.n_preemptions,
            "cancelled": sum(1 for r in self.finished if r.cancelled),
            "drained": self.draining,
            "latency": latency_percentiles(
                [r for r in self.finished if not r.cancelled]),
            "memory": self.backend.memory_stats(self.state),
        }
        if wall > 0:
            summary["tokens_per_s"] = total_tokens / wall
        else:
            # timer resolution can make a tiny trace's wall collapse to 0 —
            # an honest 0.0 with a note beats a division to inf
            summary["tokens_per_s"] = 0.0
            summary["tokens_per_s_note"] = "wall_too_short"
        return summary
