"""Request lifecycle for continuous batching (DESIGN.md §7).

A ``Request`` carries one prompt through the scheduler's state machine::

    QUEUED ──admit──▶ PREFILLING ──splice──▶ DECODING ──EOS/max──▶ FINISHED

PREFILLING is transient inside a single scheduler tick (prefill runs
synchronously, then the sub-state is spliced into the live batch row), but it
is modeled explicitly so telemetry can attribute time-to-first-token to the
prefill, and so a future async-prefill engine can hold requests there.

Timestamps are recorded in *scheduler steps* (one decode tick each) and in
wall-clock seconds; the benchmark reports both.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np


class RequestState(enum.Enum):
    QUEUED = "queued"
    PREFILLING = "prefilling"
    DECODING = "decoding"
    FINISHED = "finished"
    CANCELLED = "cancelled"  # retired early (client disconnect / shed)


@dataclass
class Request:
    """One generation request and its realized lifecycle telemetry."""

    req_id: int
    prompt: np.ndarray  # (T,) int32 token ids
    arrival_step: int = 0  # scheduler step at which the request exists
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    # multi-tenant serving metadata (DESIGN.md §13).  Defaults keep every
    # pre-frontend caller unchanged: one anonymous tenant, one priority
    # class, no deadline.  ``priority`` is an integer class index where
    # *lower is more urgent* (0 = interactive); the scheduler's preemption
    # victim choice and queue pick are priority-aware but degenerate to the
    # historical FIFO/youngest-first behavior when all priorities are equal.
    tenant: str = "default"
    priority: int = 1
    deadline_s: Optional[float] = None  # wall-clock budget from arrival

    state: RequestState = RequestState.QUEUED
    row: Optional[int] = None  # live batch row while DECODING
    generated: List[int] = field(default_factory=list)
    logits: Optional[List[np.ndarray]] = None  # per-token logits if collected

    admit_step: Optional[int] = None
    first_token_step: Optional[int] = None
    finish_step: Optional[int] = None
    arrival_time: Optional[float] = None
    first_token_time: Optional[float] = None  # wall clock of the first token
    finish_time: Optional[float] = None
    n_preemptions: int = 0  # times evicted back to QUEUED (paged backend)
    degraded_from: Optional[int] = None  # original max_new_tokens pre-degrade
    # prefix cache (DESIGN.md §14): stamped on a hit — (L,) full blocks per
    # layer reused from the index (admission charges only unshared blocks)
    prefix_shared_blocks: Optional[np.ndarray] = None
    prefix_hit_tokens: int = 0  # matched prefix length on admission (0 = miss)
    # speculative decoding (DESIGN.md §16): lifetime draft-token counts —
    # acceptance = spec_accepted / spec_proposed feeds the adaptive depth
    # and the per-request acceptance histogram at retirement
    spec_proposed: int = 0
    spec_accepted: int = 0

    @property
    def prompt_len(self) -> int:
        return int(np.asarray(self.prompt).shape[0])

    @property
    def n_generated(self) -> int:
        return len(self.generated)

    @property
    def is_finished(self) -> bool:
        """Terminal: the request will generate no further tokens (normal
        retirement or cancellation)."""
        return self.state in (RequestState.FINISHED, RequestState.CANCELLED)

    @property
    def cancelled(self) -> bool:
        return self.state is RequestState.CANCELLED

    def deadline_exceeded(self, now: Optional[float] = None) -> bool:
        """True when a wall-clock deadline was set and has elapsed (always
        False for requests without a deadline or an arrival stamp)."""
        if self.deadline_s is None or self.arrival_time is None:
            return False
        import time as _time
        now = _time.time() if now is None else now
        return (now - self.arrival_time) > self.deadline_s

    def reset_for_requeue(self) -> None:
        """Preemption (recompute policy): drop all generated state so a
        later re-admission replays the request from its prompt.  Decoding
        is deterministic (argmax), so the replay produces the same tokens;
        arrival/queueing telemetry is preserved, admission telemetry is
        cleared (it will be re-stamped)."""
        self.state = RequestState.QUEUED
        self.row = None
        self.generated = []
        if self.logits is not None:
            self.logits = []
        self.admit_step = None
        self.first_token_step = None
        self.first_token_time = None
        self.prefix_shared_blocks = None  # re-stamped on re-admission
        self.prefix_hit_tokens = 0
        self.spec_proposed = 0  # the replay re-speculates from scratch
        self.spec_accepted = 0
        self.n_preemptions += 1

    def queueing_steps(self) -> Optional[int]:
        if self.admit_step is None:
            return None
        return self.admit_step - self.arrival_step

    def latency_steps(self) -> Optional[int]:
        """Arrival → last token, in scheduler steps."""
        if self.finish_step is None:
            return None
        return self.finish_step - self.arrival_step

    def latency_seconds(self) -> Optional[float]:
        if self.finish_time is None or self.arrival_time is None:
            return None
        return self.finish_time - self.arrival_time

    def ttft_steps(self) -> Optional[int]:
        """Arrival → first token, in scheduler steps."""
        if self.first_token_step is None:
            return None
        return self.first_token_step - self.arrival_step

    def ttft_seconds(self) -> Optional[float]:
        """Arrival → first token, wall clock (queueing + prefill)."""
        if self.first_token_time is None or self.arrival_time is None:
            return None
        return self.first_token_time - self.arrival_time

    def itl_seconds(self) -> Optional[float]:
        """Mean inter-token latency after the first token (the streaming
        cadence a client sees); None until a second token exists."""
        if (self.finish_time is None or self.first_token_time is None
                or self.n_generated < 2):
            return None
        return (self.finish_time - self.first_token_time) / (
            self.n_generated - 1)


def poisson_arrivals(n_requests: int, rate: float,
                     rng: np.random.Generator) -> np.ndarray:
    """(n,) sorted integer arrival steps with ``rate`` requests/step.

    Inter-arrival gaps are exponential with mean ``1/rate`` (rounded down to
    whole scheduler steps), i.e. a discretized Poisson process; the first
    request always arrives at step 0 so a trace never starts idle.
    """
    if rate <= 0:
        raise ValueError("rate must be positive")
    if n_requests == 0:
        return np.zeros(0, dtype=int)
    gaps = np.floor(rng.exponential(1.0 / rate, size=n_requests)).astype(int)
    arrivals = np.cumsum(gaps)
    return arrivals - arrivals[0]


def synthesize_requests(
    n_requests: int,
    rate: float,
    vocab_size: int,
    min_prompt: int = 16,
    max_prompt: int = 48,
    max_new_tokens: int = 12,
    seed: int = 0,
    tenant_mix: Optional[Dict[str, float]] = None,
    tenant_priorities: Optional[Dict[str, int]] = None,
    prefix_templates: int = 0,
    prefix_len: int = 0,
    shared_fraction: float = 0.0,
) -> List[Request]:
    """A reproducible Poisson trace of random-token requests.

    ``tenant_mix`` assigns each request a tenant sampled from the given
    ``{name: weight}`` distribution (weights are normalized); without it
    every request belongs to the anonymous ``"default"`` tenant, so
    pre-frontend callers see identical traces.  ``tenant_priorities`` maps
    tenant names to priority-class indices (missing tenants keep the
    `Request` default).

    Shared-prefix traces (DESIGN.md §14): with ``prefix_templates > 0``,
    ``shared_fraction`` of the requests start with one of the template
    prefixes (``prefix_len`` tokens each, drawn once per template) followed
    by a unique random suffix; the rest stay fully random at the same total
    length, so sharing changes the cache topology but never the workload
    size.  Tenants bind to templates round-robin when a tenant mix is
    given, modeling per-tenant system prompts.
    """
    rng = np.random.default_rng(seed)
    arrivals = poisson_arrivals(n_requests, rate, rng)
    names, probs = None, None
    if tenant_mix:
        names = sorted(tenant_mix)
        w = np.asarray([float(tenant_mix[n]) for n in names])
        if (w < 0).any() or w.sum() <= 0:
            raise ValueError(f"tenant_mix weights must be non-negative with "
                             f"a positive sum, got {tenant_mix}")
        probs = w / w.sum()
    templates = None
    if prefix_templates > 0:
        if prefix_len <= 0:
            raise ValueError("prefix_templates > 0 requires prefix_len > 0")
        if not 0.0 <= shared_fraction <= 1.0:
            raise ValueError(f"shared_fraction must be in [0, 1], "
                             f"got {shared_fraction}")
        if prefix_len >= min_prompt:
            raise ValueError(f"prefix_len ({prefix_len}) must leave room "
                             f"for a unique suffix (min_prompt "
                             f"{min_prompt})")
        templates = [rng.integers(0, vocab_size, size=prefix_len)
                     .astype(np.int32) for _ in range(prefix_templates)]
    reqs = []
    for i, step in enumerate(arrivals):
        T = int(rng.integers(min_prompt, max_prompt + 1))
        # legacy draw order (T, prompt, tenant) when no templates are in
        # play, so pre-existing seeded traces stay bit-identical
        prompt = (rng.integers(0, vocab_size, size=T).astype(np.int32)
                  if templates is None else None)
        kw = {}
        tenant = None
        if names is not None:
            tenant = names[int(rng.choice(len(names), p=probs))]
            kw["tenant"] = tenant
            if tenant_priorities and tenant in tenant_priorities:
                kw["priority"] = int(tenant_priorities[tenant])
        if templates is not None:
            if rng.random() < shared_fraction:
                t_ix = (names.index(tenant) % len(templates)
                        if tenant is not None
                        else int(rng.integers(len(templates))))
                suffix = rng.integers(0, vocab_size,
                                      size=T - prefix_len).astype(np.int32)
                prompt = np.concatenate([templates[t_ix], suffix])
            else:
                prompt = rng.integers(0, vocab_size, size=T).astype(np.int32)
        reqs.append(Request(req_id=i, prompt=prompt, arrival_step=int(step),
                            max_new_tokens=max_new_tokens, **kw))
    return reqs


def latency_percentiles(requests: List[Request]) -> dict:
    """p50/p99 of end-to-end latency, TTFT, and mean ITL over the finished
    subset, in steps and seconds (seconds only when wall-clock stamps were
    recorded).

    Keys for an observable are present only when at least one request
    recorded it — an empty trace returns just ``{"n_finished": 0}``, never
    NaN percentiles (callers print ``n/a`` for missing keys).
    """
    samples = {
        "steps": [r.latency_steps() for r in requests],
        "s": [r.latency_seconds() for r in requests],
        "ttft_steps": [r.ttft_steps() for r in requests],
        "ttft_s": [r.ttft_seconds() for r in requests],
        "itl_s": [r.itl_seconds() for r in requests],
    }
    out = {"n_finished": sum(1 for v in samples["steps"] if v is not None)}
    for key, vals in samples.items():
        vals = [v for v in vals if v is not None]
        if vals:
            out[f"p50_{key}"] = float(np.percentile(vals, 50))
            out[f"p99_{key}"] = float(np.percentile(vals, 99))
    return out
