"""Host-side metrics registry: Counters, Gauges, fixed-bucket Histograms
(DESIGN.md §12).

The serving stack's load imbalance, cache pressure, and latency all live in
host-side Python between StepFn invocations — so the registry is plain
Python too: no device arrays, no jit interaction, nothing traced.  Every
metric is a *family* of labeled series (``shard_load_tokens{shard="2"}``,
``stepfn_wall_s{kind="decode",executor="mesh"}``); label values arrive as
keyword arguments on the observation call itself, so the hot path is one
dict lookup plus one float add.

Three export surfaces, all derived from one deterministic ``snapshot()``:

- ``snapshot()`` — a plain nested dict (sorted names, sorted label sets),
  the programmatic surface (``Engine.metrics()``, tests, benchmarks);
- ``to_prometheus()`` — Prometheus text exposition format (histograms as
  cumulative ``_bucket{le=...}`` / ``_sum`` / ``_count`` series);
- ``to_jsonl()`` — one JSON object per series, for appending to a log.

Disabling (`ObsConfig.enabled=False`) swaps in ``NULL_REGISTRY``, whose
metric handles are shared no-op singletons — the cost of an instrumented
call site is then one attribute load and one no-op call.
"""
from __future__ import annotations

import json
from bisect import bisect_left
from dataclasses import dataclass
from typing import Dict, Iterable, Optional, Tuple

# default buckets for wall-clock latencies (seconds): sub-ms jit dispatch
# through multi-second compile/prefill events
DEFAULT_LATENCY_BUCKETS: Tuple[float, ...] = (
    0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
    0.25, 0.5, 1.0, 2.5, 5.0, 10.0)


@dataclass(frozen=True)
class ObsConfig:
    """Observability knobs (composed into `EngineConfig`).

    ``enabled``: one switch for the whole subsystem — False swaps every
    collection point to shared no-op singletons (near-zero cost).
    ``trace_capacity``: bounded span-ring size; the oldest events fall off,
    so a long-running server's trace export is always the recent window.
    ``print_every``: scheduler steps between one-line stats prints
    (0 disables).
    """

    enabled: bool = True
    trace_capacity: int = 4096
    print_every: int = 0

    def __post_init__(self):
        if self.trace_capacity < 1:
            raise ValueError(
                f"trace_capacity must be >= 1, got {self.trace_capacity}")
        if self.print_every < 0:
            raise ValueError(
                f"print_every must be >= 0, got {self.print_every}")


def _series_key(labels: dict) -> Tuple[Tuple[str, str], ...]:
    """Canonical (sorted, stringified) label identity of one series."""
    if not labels:
        return ()
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


class Metric:
    """One named family of labeled series."""

    kind = "untyped"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._series: Dict[Tuple[Tuple[str, str], ...], object] = {}

    def series(self):
        """Deterministic iteration: label-key-sorted (labels_dict, state)."""
        for key in sorted(self._series):
            yield dict(key), self._series[key]

    def __len__(self) -> int:
        return len(self._series)


class Counter(Metric):
    """Monotone accumulator.  ``inc(0, **labels)`` pre-registers a series
    at 0 so exports show it before the first real event."""

    kind = "counter"

    def inc(self, amount: float = 1.0, **labels) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name} cannot decrease "
                             f"(inc {amount})")
        key = _series_key(labels)
        self._series[key] = self._series.get(key, 0.0) + amount

    def value(self, **labels) -> float:
        return float(self._series.get(_series_key(labels), 0.0))

    def total(self) -> float:
        """Sum over every labeled series of the family."""
        return float(sum(self._series.values()))


class Gauge(Metric):
    """Last-write-wins sampled value."""

    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        self._series[_series_key(labels)] = float(value)

    def value(self, default: float = 0.0, **labels) -> float:
        return float(self._series.get(_series_key(labels), default))


class Histogram(Metric):
    """Fixed-bucket histogram (upper bounds; +Inf implicit).

    Internally per-bucket (non-cumulative) counts plus sum/count; the
    Prometheus export emits the conventional cumulative ``le`` series.
    """

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: Iterable[float] = DEFAULT_LATENCY_BUCKETS):
        super().__init__(name, help)
        bounds = tuple(float(b) for b in buckets)
        if not bounds or any(b2 <= b1 for b1, b2 in zip(bounds, bounds[1:])):
            raise ValueError(
                f"histogram {name}: buckets must be non-empty and strictly "
                f"increasing, got {bounds}")
        self.buckets = bounds

    def observe(self, value: float, **labels) -> None:
        key = _series_key(labels)
        st = self._series.get(key)
        if st is None:
            st = self._series[key] = {
                "counts": [0] * (len(self.buckets) + 1),
                "sum": 0.0, "count": 0}
        st["counts"][bisect_left(self.buckets, float(value))] += 1
        st["sum"] += float(value)
        st["count"] += 1

    def count(self, **labels) -> int:
        st = self._series.get(_series_key(labels))
        return 0 if st is None else int(st["count"])

    def mean(self, **labels) -> Optional[float]:
        st = self._series.get(_series_key(labels))
        if st is None or st["count"] == 0:
            return None
        return st["sum"] / st["count"]


class MetricsRegistry:
    """Name-keyed metric families; re-requesting a name returns the same
    family (kind mismatch is a bug and raises)."""

    enabled = True

    def __init__(self):
        self._metrics: Dict[str, Metric] = {}

    def _get(self, cls, name: str, help: str, **kw) -> Metric:
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(name, help, **kw)
        elif not isinstance(m, cls):
            raise TypeError(
                f"metric {name!r} already registered as {m.kind}, "
                f"requested {cls.kind}")
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Optional[Iterable[float]] = None) -> Histogram:
        if buckets is None:
            return self._get(Histogram, name, help)
        return self._get(Histogram, name, help, buckets=tuple(buckets))

    def get(self, name: str) -> Optional[Metric]:
        return self._metrics.get(name)

    def counter_value(self, name: str, **labels) -> float:
        """0.0 when the counter (or series) was never touched — benchmarks
        read outcomes without caring whether the event ever fired."""
        m = self._metrics.get(name)
        return m.value(**labels) if isinstance(m, Counter) else 0.0

    # ---- exports -----------------------------------------------------------

    def snapshot(self) -> dict:
        """Plain nested dict, fully deterministic (sorted names and label
        sets) — equal observation sequences produce equal snapshots."""
        out = {}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            series = []
            for labels, st in m.series():
                if m.kind == "histogram":
                    cum, acc = {}, 0
                    for b, c in zip(m.buckets, st["counts"]):
                        acc += c
                        cum[f"{b:g}"] = acc
                    cum["+Inf"] = st["count"]
                    series.append({"labels": labels, "sum": st["sum"],
                                   "count": st["count"], "buckets": cum})
                else:
                    series.append({"labels": labels, "value": st})
            out[name] = {"kind": m.kind, "help": m.help, "series": series}
        return out

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (version 0.0.4)."""
        lines = []
        for name, fam in self.snapshot().items():
            if fam["help"]:
                lines.append(f"# HELP {name} {_esc_help(fam['help'])}")
            lines.append(f"# TYPE {name} {fam['kind']}")
            for s in fam["series"]:
                if fam["kind"] == "histogram":
                    for le, c in s["buckets"].items():
                        lines.append(
                            f"{name}_bucket"
                            f"{_fmt_labels({**s['labels'], 'le': le})} {c}")
                    lines.append(
                        f"{name}_sum{_fmt_labels(s['labels'])} "
                        f"{_fmt_value(s['sum'])}")
                    lines.append(
                        f"{name}_count{_fmt_labels(s['labels'])} "
                        f"{s['count']}")
                else:
                    lines.append(f"{name}{_fmt_labels(s['labels'])} "
                                 f"{_fmt_value(s['value'])}")
        return "\n".join(lines) + ("\n" if lines else "")

    def to_jsonl(self) -> str:
        """One JSON object per series (kind, name, labels, payload)."""
        lines = []
        for name, fam in self.snapshot().items():
            for s in fam["series"]:
                rec = {"name": name, "kind": fam["kind"], **s}
                lines.append(json.dumps(rec, sort_keys=True))
        return "\n".join(lines) + ("\n" if lines else "")


# ---------------------------------------------------------------------------
# disabled path: shared no-op singletons
# ---------------------------------------------------------------------------


class _NullMetric:
    """Counter/Gauge/Histogram lookalike whose operations do nothing."""

    __slots__ = ()
    name = help = ""
    buckets = DEFAULT_LATENCY_BUCKETS

    def inc(self, amount: float = 1.0, **labels) -> None:
        pass

    def set(self, value: float, **labels) -> None:
        pass

    def observe(self, value: float, **labels) -> None:
        pass

    def value(self, default: float = 0.0, **labels) -> float:
        return 0.0

    def total(self) -> float:
        return 0.0

    def count(self, **labels) -> int:
        return 0

    def mean(self, **labels) -> Optional[float]:
        return None

    def __len__(self) -> int:
        return 0


_NULL_METRIC = _NullMetric()


class NullRegistry:
    """`MetricsRegistry` lookalike for ``ObsConfig.enabled=False``: every
    family request returns one shared no-op handle, exports are empty."""

    enabled = False

    def counter(self, name: str, help: str = "") -> _NullMetric:
        return _NULL_METRIC

    def gauge(self, name: str, help: str = "") -> _NullMetric:
        return _NULL_METRIC

    def histogram(self, name: str, help: str = "",
                  buckets=None) -> _NullMetric:
        return _NULL_METRIC

    def get(self, name: str) -> None:
        return None

    def counter_value(self, name: str, **labels) -> float:
        return 0.0

    def snapshot(self) -> dict:
        return {}

    def to_prometheus(self) -> str:
        return ""

    def to_jsonl(self) -> str:
        return ""


NULL_REGISTRY = NullRegistry()


# ---------------------------------------------------------------------------
# Prometheus formatting helpers
# ---------------------------------------------------------------------------


def _esc_help(text: str) -> str:
    return text.replace("\\", "\\\\").replace("\n", "\\n")


def _esc_label(value: str) -> str:
    return (value.replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


def _fmt_labels(labels: dict) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{_esc_label(str(v))}"'
                     for k, v in sorted(labels.items()))
    return "{" + inner + "}"


def _fmt_value(v: float) -> str:
    f = float(v)
    return str(int(f)) if f.is_integer() else repr(f)
