"""`repro.obs` — metrics + tracing for the serving stack (DESIGN.md §12).

The subsystem makes the quantities FairKV argues about *observable at
runtime*: per-shard load imbalance (the paper's Figure-2/Eq-4 quantity),
block-pool pressure, StepFn wall time and (re)compiles, and per-request
TTFT/ITL — collected host-side around StepFn boundaries, never inside
traced code.

One `Obs` handle bundles the two collectors:

- ``obs.metrics`` — a `MetricsRegistry` of labeled Counters / Gauges /
  Histograms, snapshot-able as a dict and exportable as Prometheus text or
  JSONL (`repro.obs.metrics`);
- ``obs.trace``   — a bounded `TraceBuffer` of timed spans / instant
  events, exportable as Chrome trace-event JSON (`repro.obs.trace`).

`Obs.build(ObsConfig(enabled=False))` (or the shared `NULL_OBS`) swaps both
for no-op singletons, so instrumented call sites cost one attribute load
when observability is off.  The `Engine` facade builds one `Obs` per engine
from ``EngineConfig.obs`` and threads it through the scheduler, executor,
and cache backend; standalone construction of those components defaults to
`NULL_OBS`.
"""
from __future__ import annotations

from repro.obs.metrics import (  # noqa: F401
    DEFAULT_LATENCY_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    NullRegistry,
    ObsConfig,
    NULL_REGISTRY,
)
from repro.obs.trace import NULL_TRACE, NullTrace, TraceBuffer  # noqa: F401


class Obs:
    """One engine's observability handle: config + metrics + trace."""

    __slots__ = ("cfg", "metrics", "trace")

    def __init__(self, cfg: ObsConfig, metrics, trace):
        self.cfg = cfg
        self.metrics = metrics
        self.trace = trace

    @property
    def enabled(self) -> bool:
        return self.metrics.enabled

    @classmethod
    def build(cls, cfg: "ObsConfig | None" = None) -> "Obs":
        cfg = cfg if cfg is not None else ObsConfig()
        if not cfg.enabled:
            return Obs(cfg, NULL_REGISTRY, NULL_TRACE)
        return cls(cfg, MetricsRegistry(), TraceBuffer(cfg.trace_capacity))


NULL_OBS = Obs(ObsConfig(enabled=False), NULL_REGISTRY, NULL_TRACE)

__all__ = [
    "DEFAULT_LATENCY_BUCKETS", "Counter", "Gauge", "Histogram",
    "MetricsRegistry", "NullRegistry", "NullTrace", "Obs", "ObsConfig",
    "TraceBuffer", "NULL_OBS", "NULL_REGISTRY", "NULL_TRACE",
]
