"""Bounded span/event ring exportable as Chrome trace-event JSON
(DESIGN.md §12).

``TraceBuffer`` records *complete* spans (``ph="X"``: name, start, duration)
and *instant* events (``ph="i"``) into a fixed-capacity deque — old events
fall off, so the export is always the most recent window and a long-running
server can leave tracing on.  Timestamps are microseconds relative to buffer
creation (`time.perf_counter` based), which is exactly what the trace-event
format wants; the export loads directly in Perfetto / chrome://tracing.

The ``span`` context manager is the instrumentation primitive::

    with obs.trace.span("decode_step", rows=3):
        ...

and costs two ``perf_counter()`` calls plus one dict append when enabled.
``complete()`` records a span whose timing was measured externally (the
executors time around ``block_until_ready`` and report after the fact).
Everything here is host-side: spans wrap StepFn *invocations*, never code
inside a trace.
"""
from __future__ import annotations

import json
import time
from collections import deque
from contextlib import contextmanager, nullcontext


class TraceBuffer:
    """Fixed-capacity ring of Chrome trace events."""

    enabled = True

    def __init__(self, capacity: int = 4096):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._events = deque(maxlen=capacity)
        self._t0 = time.perf_counter()

    def __len__(self) -> int:
        return len(self._events)

    def _ts_us(self, t: float) -> float:
        return (t - self._t0) * 1e6

    # ---- recording ---------------------------------------------------------

    @contextmanager
    def span(self, name: str, **args):
        """Time a block as one complete ("X") event; exceptions still
        record the span (with an ``error`` arg) before propagating."""
        t0 = time.perf_counter()
        try:
            yield
        except BaseException as e:
            self.complete(name, t0, time.perf_counter() - t0,
                          error=type(e).__name__, **args)
            raise
        self.complete(name, t0, time.perf_counter() - t0, **args)

    def complete(self, name: str, t_start: float, dur_s: float,
                 **args) -> None:
        """Record an externally timed span (``t_start`` from
        ``time.perf_counter()``)."""
        ev = {"name": name, "ph": "X", "ts": self._ts_us(t_start),
              "dur": dur_s * 1e6, "pid": 0, "tid": 0}
        if args:
            ev["args"] = args
        self._events.append(ev)

    def instant(self, name: str, **args) -> None:
        """Record a point-in-time event (compiles, replans, preemptions)."""
        ev = {"name": name, "ph": "i", "ts": self._ts_us(time.perf_counter()),
              "s": "t", "pid": 0, "tid": 0}
        if args:
            ev["args"] = args
        self._events.append(ev)

    # ---- export ------------------------------------------------------------

    def to_chrome(self) -> dict:
        """Chrome trace-event JSON object (Perfetto-loadable)."""
        return {"traceEvents": list(self._events),
                "displayTimeUnit": "ms",
                "otherData": {"source": "repro.obs"}}

    def export_json(self) -> str:
        return json.dumps(self.to_chrome())


class NullTrace:
    """`TraceBuffer` lookalike for ``ObsConfig.enabled=False``."""

    enabled = False
    capacity = 0

    def __len__(self) -> int:
        return 0

    def span(self, name: str, **args):
        return nullcontext()

    def complete(self, name: str, t_start: float, dur_s: float,
                 **args) -> None:
        pass

    def instant(self, name: str, **args) -> None:
        pass

    def to_chrome(self) -> dict:
        return {"traceEvents": [], "displayTimeUnit": "ms"}

    def export_json(self) -> str:
        return json.dumps(self.to_chrome())


NULL_TRACE = NullTrace()
