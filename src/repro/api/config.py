"""`EngineConfig`: one validated config for the whole serving stack.

Composes the four sub-configs that every entry point used to wire by hand —
``ModelConfig`` (architecture), ``CompressionConfig`` (per-head KV budgets),
``PlannerConfig`` (FairKV placement), ``SchedulerConfig`` (continuous
batching) — plus the engine-level knobs (shard count, dtype, sequence
headroom, profile seeding) that previously lived as loose locals in each
caller.

``__post_init__`` validates every *name-typed* field against the live
registries (``repro.api.registry``) and the planner-mode list, so a typo'd
policy / planner mode / assignment engine fails at construction time with
the registered-name list — instead of surfacing as a bare ``KeyError`` deep
inside a jitted trace, or worse, silently selecting a fallback.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

import jax.numpy as jnp

from repro.api.registry import (
    list_cache_backends,
    list_engines,
    list_executors,
    list_policies,
)
from repro.compression.base import CompressionConfig
from repro.configs import get_config, get_smoke_config
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig
from repro.core.planner import PLANNER_MODES, PlannerConfig
from repro.exec.base import ExecutorConfig
from repro.frontend.config import FrontendConfig
from repro.obs import ObsConfig
from repro.paging.block_pool import PagingConfig
from repro.prefix import PrefixConfig
from repro.serving.scheduler import SchedulerConfig
from repro.serving.speculation import SpeculationConfig

# the one dtype-name table: validation and Engine's resolution both read it
DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
          "float16": jnp.float16}


@dataclass(frozen=True)
class EngineConfig:
    """Everything `Engine.build` needs, validated at construction.

    ``dtype`` is a string (``float32`` / ``bfloat16`` / ``float16``) so the
    config stays hashable and printable; `Engine` resolves it to a jnp dtype.
    ``profile_skew`` / ``profile_seed`` parameterize the synthetic per-head
    workload profile used when the caller does not supply a measured one.
    """

    model: ModelConfig
    compression: CompressionConfig = field(default_factory=CompressionConfig)
    planner: PlannerConfig = field(default_factory=PlannerConfig)
    scheduler: SchedulerConfig = field(default_factory=SchedulerConfig)
    n_shards: int = 1
    dtype: str = "float32"
    max_seq_len: int = 512
    seed: int = 0  # PRNG seed for default parameter init
    profile_skew: float = 1.0
    profile_seed: int = 1
    # cache storage backend: "slot" (dense static-capacity, DESIGN.md §2) or
    # "paged" (block-pool allocation proportional to realized lengths, §9);
    # third parties extend via @repro.api.register_cache_backend
    cache_backend: str = "slot"
    paging: PagingConfig = field(default_factory=PagingConfig)
    # device-execution strategy (DESIGN.md §10): "local" (single-device jit)
    # or "mesh" (shard_map over a (data, model) mesh, passed to Engine.build
    # via mesh=); third parties extend via @repro.api.register_executor
    executor: str = "local"
    executor_cfg: ExecutorConfig = field(default_factory=ExecutorConfig)
    # observability (DESIGN.md §12): metrics registry + span trace threaded
    # through scheduler/executor/backend; ObsConfig(enabled=False) swaps
    # every collection point for shared no-op singletons
    obs: ObsConfig = field(default_factory=ObsConfig)
    # multi-tenant serving front end (DESIGN.md §13): fair queuing, SLO
    # admission, HTTP ingress; only `serve --http` / `FrontendServer` read
    # it, so offline engines pay nothing for the default
    frontend: FrontendConfig = field(default_factory=FrontendConfig)
    # shared-prefix block reuse + chunked prefill (DESIGN.md §14): chunking
    # needs chunk_tokens > 0 and a dense-attention model; block sharing
    # additionally needs the paged backend on a single-partition pool —
    # the scheduler degrades gracefully when a piece is missing
    prefix: PrefixConfig = field(default_factory=PrefixConfig)
    # speculative decoding (DESIGN.md §16): draft-propose + multi-query
    # verify on the paged executor; disabled by default (zero-cost)
    speculation: SpeculationConfig = field(default_factory=SpeculationConfig)

    def __post_init__(self):
        if not isinstance(self.model, ModelConfig):
            raise TypeError(
                f"model must be a ModelConfig, got {type(self.model).__name__}")
        policy = self.compression.policy
        if policy != "none" and policy not in list_policies():
            raise ValueError(
                f"unknown compression policy {policy!r}; registered: "
                f"{list_policies()} (plus 'none'); add policies with "
                f"@repro.api.register_policy")
        if self.planner.mode not in PLANNER_MODES:
            raise ValueError(
                f"unknown planner mode {self.planner.mode!r}; known: "
                f"{list(PLANNER_MODES)}")
        if self.planner.engine not in list_engines():
            raise ValueError(
                f"unknown assignment engine {self.planner.engine!r}; "
                f"registered: {list_engines()}; add engines with "
                f"@repro.api.register_assignment_engine")
        if self.dtype not in DTYPES:
            raise ValueError(
                f"unknown dtype {self.dtype!r}; known: {list(DTYPES)}")
        if self.n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {self.n_shards}")
        if self.max_seq_len < 1:
            raise ValueError(
                f"max_seq_len must be >= 1, got {self.max_seq_len}")
        if self.compression.budget < 1:
            raise ValueError(
                f"compression.budget must be >= 1, got "
                f"{self.compression.budget}")
        if self.scheduler.max_rows < 1:
            raise ValueError(
                f"scheduler.max_rows must be >= 1, got "
                f"{self.scheduler.max_rows}")
        if self.cache_backend not in list_cache_backends():
            raise ValueError(
                f"unknown cache backend {self.cache_backend!r}; registered: "
                f"{list_cache_backends()}; add backends with "
                f"@repro.api.register_cache_backend")
        if not isinstance(self.paging, PagingConfig):
            raise TypeError(
                f"paging must be a PagingConfig, got "
                f"{type(self.paging).__name__}")
        # quantized KV pools (DESIGN.md §15) exist only on the paged
        # backend, and per-head overrides must address real (layer, head)
        # cells of this model — catch both at construction, not in-trace
        if self.paging.kv_dtype != "fp32":
            if self.cache_backend != "paged":
                raise ValueError(
                    f"paging.kv_dtype={self.paging.kv_dtype!r} (quantized "
                    f"KV pools) requires cache_backend='paged', got "
                    f"{self.cache_backend!r}; the slot backend stores KV "
                    f"in the engine dtype only")
            L, H = self.model.n_layers, self.model.n_kv_heads
            for lyr, hd, dt in self.paging.kv_dtype_overrides:
                if lyr >= L or hd >= H:
                    raise ValueError(
                        f"paging.kv_dtype override ({lyr}, {hd}) -> {dt!r} "
                        f"out of range for model {self.model.name!r} with "
                        f"{L} layers x {H} kv heads")
        if self.executor not in list_executors():
            raise ValueError(
                f"unknown executor {self.executor!r}; registered: "
                f"{list_executors()}; add executors with "
                f"@repro.api.register_executor")
        if not isinstance(self.executor_cfg, ExecutorConfig):
            raise TypeError(
                f"executor_cfg must be an ExecutorConfig, got "
                f"{type(self.executor_cfg).__name__}")
        if not isinstance(self.obs, ObsConfig):
            raise TypeError(
                f"obs must be an ObsConfig, got {type(self.obs).__name__}")
        if not isinstance(self.frontend, FrontendConfig):
            raise TypeError(
                f"frontend must be a FrontendConfig, got "
                f"{type(self.frontend).__name__}")
        if not isinstance(self.prefix, PrefixConfig):
            raise TypeError(
                f"prefix must be a PrefixConfig, got "
                f"{type(self.prefix).__name__}")
        if self.prefix.enabled and self.cache_backend != "paged":
            raise ValueError(
                "prefix.enabled (shared-prefix block reuse) requires "
                f"cache_backend='paged', got {self.cache_backend!r}; "
                "chunked prefill alone (prefix.chunk_tokens > 0, "
                "enabled=False) works on any backend")
        if not isinstance(self.speculation, SpeculationConfig):
            raise TypeError(
                f"speculation must be a SpeculationConfig, got "
                f"{type(self.speculation).__name__}")
        if self.speculation.enabled:
            if self.cache_backend != "paged":
                raise ValueError(
                    "speculation.enabled requires cache_backend='paged' "
                    "(provisional blocks + rollback-on-reject), got "
                    f"{self.cache_backend!r}")
            if (self.model.family != "dense" or self.model.attention_free
                    or self.model.is_encoder_decoder or self.model.is_vlm):
                raise ValueError(
                    "speculative decoding supports dense decoder-only "
                    f"models; got family={self.model.family!r} for "
                    f"{self.model.name!r}")
            if self.speculation.draft_layers > self.model.n_layers:
                raise ValueError(
                    f"speculation.draft_layers="
                    f"{self.speculation.draft_layers} exceeds the model's "
                    f"{self.model.n_layers} layers")

    # ---- constructors ------------------------------------------------------

    @classmethod
    def for_arch(cls, arch: str, *, smoke: bool = False,
                 **overrides) -> "EngineConfig":
        """Config for a registered architecture id (``--arch`` names).

        ``smoke=True`` uses the arch's reduced CPU-testable variant.
        Remaining keyword arguments override `EngineConfig` fields.
        """
        model = get_smoke_config(arch) if smoke else get_config(arch)
        return cls(model=model, **overrides)

    @classmethod
    def smoke(cls, arch: str, **overrides) -> "EngineConfig":
        """Shorthand for ``for_arch(arch, smoke=True, ...)``."""
        return cls.for_arch(arch, smoke=True, **overrides)

    def replace(self, **changes) -> "EngineConfig":
        """`dataclasses.replace` that re-runs validation."""
        return dataclasses.replace(self, **changes)

    # ---- JSON round-trip ---------------------------------------------------

    def to_dict(self) -> dict:
        """JSON-serializable nested dict; `from_dict` round-trips it.

        Tuples serialize as JSON lists — `from_dict` re-tuples them, and
        every sub-config's own ``__post_init__`` re-validates on rebuild,
        so ``EngineConfig.from_dict(json.loads(json.dumps(cfg.to_dict())))
        == cfg`` for any constructible config."""
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, data: dict) -> "EngineConfig":
        """Rebuild from a `to_dict()` / JSON-file dict.

        Strict: unknown keys raise ``ValueError`` naming the offending
        path and the valid field names for that (sub-)config — a typo'd
        key in a config file fails loudly instead of being ignored.
        Missing keys fall back to the field defaults (``model`` is the one
        required section)."""
        return _config_from_dict(cls, data, "engine")


# nested rebuild targets for fields whose *type annotation* names a config
# class but whose default gives no instance to sniff (e.g. the required
# ``model`` field); default-factory fields are detected structurally
_CONFIG_TYPES = {c.__name__: c for c in (
    ModelConfig, MoEConfig, SSMConfig, CompressionConfig, PlannerConfig,
    SchedulerConfig, PagingConfig, ExecutorConfig, ObsConfig,
    FrontendConfig, PrefixConfig)}
_CONFIG_TYPES["SpeculationConfig"] = SpeculationConfig


def _field_default(f):
    if f.default_factory is not dataclasses.MISSING:  # type: ignore[misc]
        return f.default_factory()
    if f.default is not dataclasses.MISSING:
        return f.default
    return None


def _nested_type(f):
    """The dataclass type a dict value of this field rebuilds into."""
    proto = _field_default(f)
    if dataclasses.is_dataclass(proto):
        return type(proto)
    name = f.type if isinstance(f.type, str) else getattr(
        f.type, "__name__", None)
    return _CONFIG_TYPES.get(name)


def _element_type(f):
    """For tuple-of-dataclass fields (e.g. ``FrontendConfig.classes``)."""
    proto = _field_default(f)
    if (isinstance(proto, tuple) and proto
            and dataclasses.is_dataclass(proto[0])):
        return type(proto[0])
    return None


def _config_from_dict(dc_cls, data, path):
    if not isinstance(data, dict):
        raise TypeError(
            f"{path}: expected an object/dict for {dc_cls.__name__}, got "
            f"{type(data).__name__}")
    fields = dataclasses.fields(dc_cls)
    names = [f.name for f in fields]
    unknown = sorted(set(data) - set(names))
    if unknown:
        raise ValueError(
            f"unknown key(s) {unknown} at {path!r} for {dc_cls.__name__}; "
            f"valid keys: {names}")
    kwargs = {}
    for f in fields:
        if f.name not in data:
            continue
        v = data[f.name]
        sub = _nested_type(f)
        elem = _element_type(f)
        if sub is not None and isinstance(v, dict):
            v = _config_from_dict(sub, v, f"{path}.{f.name}")
        elif elem is not None and isinstance(v, (list, tuple)):
            v = tuple(
                _config_from_dict(elem, e, f"{path}.{f.name}[{i}]")
                if isinstance(e, dict) else e
                for i, e in enumerate(v))
        elif isinstance(v, list):
            # JSON has no tuples; frozen-config validators expect them
            v = tuple(tuple(e) if isinstance(e, list) else e for e in v)
        kwargs[f.name] = v
    return dc_cls(**kwargs)
