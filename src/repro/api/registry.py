"""Extensibility registries for the ``repro.api`` facade.

Decorator-based registries replace what used to be hardcoded tables:

- **compression policies** — previously the ``POLICIES`` dict literal in
  ``compression/policies.py``; now any module can do::

      from repro.api import register_policy

      @register_policy("my_policy")
      def my_policy(scores, cfg, layer_idx, n_layers, **kw): ...

  and ``"my_policy"`` immediately works in ``CompressionConfig.policy``,
  ``EngineConfig`` validation, and ``compression.policies.select``.

- **assignment engines** — previously a string if/elif inside
  ``core/assignment.py``; ``@register_assignment_engine("name")`` adds a
  solver for the makespan problem (Eq. 4) that ``assign_items`` and
  ``PlannerConfig.engine`` can name.

- **cache backends** — ``@register_cache_backend("name")`` adds a cache
  storage strategy (a ``serving.cache_backend.CacheBackend`` subclass)
  selectable via ``EngineConfig.cache_backend``; built-ins ``"slot"``
  (dense static-capacity layout) and ``"paged"`` (block-pool allocation,
  DESIGN.md §9).

- **executors** — ``@register_executor("name")`` adds a device-execution
  strategy (a ``repro.exec.Executor`` subclass owning the compiled
  prefill/decode StepFns, DESIGN.md §10) selectable via
  ``EngineConfig.executor``; built-ins ``"local"`` (single-device jit)
  and ``"mesh"`` (``shard_map`` over a ``(data, model)`` mesh).

This module is a dependency *leaf*: it imports nothing from ``repro`` at
module scope, so the registered-to modules (``compression.policies``,
``core.assignment``) can import it without cycling through the heavyweight
``repro.api.engine`` facade.  ``list_policies``/``list_engines`` lazily
import the built-in providers so the listings are never empty.
"""
from __future__ import annotations

from typing import Callable, Dict, Iterator, List, Mapping, Optional


class Registry(Mapping):
    """Name → callable mapping with decorator registration.

    Duplicate names are rejected (``ValueError``); unknown lookups raise a
    ``KeyError`` that lists every registered name, so a typo'd policy/engine
    string fails loudly at the front door instead of as a bare ``KeyError``
    deep inside a jitted trace.
    """

    def __init__(self, kind: str):
        self.kind = kind
        self._items: Dict[str, Callable] = {}

    # ---- registration ------------------------------------------------------

    def register(self, name: Optional[str] = None) -> Callable:
        """Decorator: ``@registry.register("name")`` or ``@registry.register``
        (uses the function's ``__name__``)."""
        if callable(name):  # bare @register usage
            fn, name = name, None
            return self._add(fn.__name__, fn)

        def deco(fn: Callable) -> Callable:
            return self._add(name or fn.__name__, fn)

        return deco

    def _add(self, name: str, fn: Callable) -> Callable:
        if name in self._items:
            raise ValueError(
                f"{self.kind} {name!r} is already registered "
                f"(registered: {self.names()}); unregister it first or "
                f"pick a different name")
        self._items[name] = fn
        return fn

    def unregister(self, name: str) -> None:
        """Remove a registration (primarily for tests / plugin reload)."""
        if name not in self._items:
            raise KeyError(f"{self.kind} {name!r} is not registered")
        del self._items[name]

    # ---- lookup ------------------------------------------------------------

    def names(self) -> List[str]:
        return sorted(self._items)

    # ---- Mapping protocol --------------------------------------------------
    # ``registry[name]`` raises the descriptive KeyError; ``.get`` keeps the
    # standard Mapping default-returning contract (inherited mixin), so dict
    # idioms on the re-exported ``POLICIES`` object keep working.

    def __getitem__(self, name: str) -> Callable:
        try:
            return self._items[name]
        except KeyError:
            raise KeyError(
                f"unknown {self.kind} {name!r}; registered: {self.names()}"
            ) from None

    def __iter__(self) -> Iterator[str]:
        return iter(self._items)

    def __len__(self) -> int:
        return len(self._items)

    def __repr__(self) -> str:
        return f"Registry({self.kind!r}, {self.names()})"


POLICY_REGISTRY = Registry("compression policy")
ASSIGNMENT_ENGINE_REGISTRY = Registry("assignment engine")
CACHE_BACKEND_REGISTRY = Registry("cache backend")
EXECUTOR_REGISTRY = Registry("executor")

register_policy = POLICY_REGISTRY.register
register_assignment_engine = ASSIGNMENT_ENGINE_REGISTRY.register
register_cache_backend = CACHE_BACKEND_REGISTRY.register
register_executor = EXECUTOR_REGISTRY.register


def _ensure_builtin() -> None:
    """Import the built-in providers so their registrations have run.

    Deferred (function-local) imports: at module-import time the providers
    themselves import this module, and importing them eagerly here would
    cycle.
    """
    import repro.compression.policies  # noqa: F401
    import repro.core.assignment  # noqa: F401
    import repro.exec.local  # noqa: F401
    import repro.exec.mesh  # noqa: F401
    import repro.paging.backend  # noqa: F401
    import repro.serving.cache_backend  # noqa: F401


def get_policy(name: str) -> Callable:
    _ensure_builtin()
    return POLICY_REGISTRY[name]


def get_assignment_engine(name: str) -> Callable:
    _ensure_builtin()
    return ASSIGNMENT_ENGINE_REGISTRY[name]


def list_policies() -> List[str]:
    """Registered compression-policy names (built-ins + plugins)."""
    _ensure_builtin()
    return POLICY_REGISTRY.names()


def list_engines() -> List[str]:
    """Registered assignment-engine names (built-ins + plugins)."""
    _ensure_builtin()
    return ASSIGNMENT_ENGINE_REGISTRY.names()


def get_cache_backend(name: str) -> Callable:
    _ensure_builtin()
    return CACHE_BACKEND_REGISTRY[name]


def list_cache_backends() -> List[str]:
    """Registered cache-backend names (built-ins + plugins)."""
    _ensure_builtin()
    return CACHE_BACKEND_REGISTRY.names()


def get_executor(name: str) -> Callable:
    _ensure_builtin()
    return EXECUTOR_REGISTRY[name]


def list_executors() -> List[str]:
    """Registered executor names (built-ins + plugins)."""
    _ensure_builtin()
    return EXECUTOR_REGISTRY.names()
