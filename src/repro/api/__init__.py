"""`repro.api` — the unified front door for FairKV serving (DESIGN.md §8).

Every entry point (launch drivers, examples, benchmarks) composes the stack
through this package instead of hand-wiring
``ModelConfig → init params → plan → slot weights → prefill → decode``:

- `EngineConfig` — one validated config (model + compression + planner +
  scheduler); unknown policy / planner-mode / engine names fail at
  construction with the registered-name list.
- `Engine` — facade owning params, plan, slot weights, and cache;
  `generate` (one-shot batch), `submit`/`step`/`stream`/`run_trace`
  (continuous), `replan` (online replanning), `measure_profile`.
- `register_policy` / `register_assignment_engine` — decorator registries
  so third-party compression policies and placement solvers plug in
  without touching core modules; `list_policies` / `list_engines` feed
  validation and ``--help`` text.

The facade also re-exports the underlying building blocks (`build_plan`,
`slotify_params`, `prefill`, `decode_step`, the sub-configs, request/trace
helpers) for planner-level studies and sharded-launch harnesses that need
pieces below the `Engine` surface — importing them from here keeps
``repro.api`` the single dependency edge into the serving stack.

Heavyweight modules load lazily (PEP 562): the registry decorators must be
importable from ``compression``/``core`` provider modules without dragging
in the full serving stack (which would cycle back into them mid-import).
"""
from __future__ import annotations

from repro.api.registry import (  # noqa: F401
    ASSIGNMENT_ENGINE_REGISTRY,
    CACHE_BACKEND_REGISTRY,
    EXECUTOR_REGISTRY,
    POLICY_REGISTRY,
    Registry,
    get_assignment_engine,
    get_cache_backend,
    get_executor,
    get_policy,
    list_cache_backends,
    list_engines,
    list_executors,
    list_policies,
    register_assignment_engine,
    register_cache_backend,
    register_executor,
    register_policy,
)

# name -> "module:attr" table for lazy (PEP 562) exports
_LAZY = {
    # facade
    "EngineConfig": "repro.api.config:EngineConfig",
    "Engine": "repro.api.engine:Engine",
    "GenerationResult": "repro.api.engine:GenerationResult",
    "StreamEvent": "repro.api.engine:StreamEvent",
    # consolidated stats snapshot (DESIGN.md §8)
    "EngineStats": "repro.api.stats:EngineStats",
    "SchedulerStats": "repro.api.stats:SchedulerStats",
    "PoolStats": "repro.api.stats:PoolStats",
    "PrefixStats": "repro.api.stats:PrefixStats",
    "PlanStats": "repro.api.stats:PlanStats",
    "SpeculationStats": "repro.api.stats:SpeculationStats",
    # sub-configs
    "ModelConfig": "repro.configs.base:ModelConfig",
    "CompressionConfig": "repro.compression.base:CompressionConfig",
    "PlannerConfig": "repro.core.planner:PlannerConfig",
    "SchedulerConfig": "repro.serving.scheduler:SchedulerConfig",
    "SpeculationConfig": "repro.serving.speculation:SpeculationConfig",
    "PLANNER_MODES": "repro.core.planner:PLANNER_MODES",
    # arch registry
    "get_config": "repro.configs.base:get_config",
    "get_smoke_config": "repro.configs.base:get_smoke_config",
    "list_archs": "repro.configs.base:list_archs",
    # planning building blocks (planner-level studies, no model needed)
    "build_plan": "repro.core.planner:build_plan",
    "plan_kv_dtypes": "repro.core.planner:plan_kv_dtypes",
    "replan_for_stragglers": "repro.core.planner:replan_for_stragglers",
    "assign_items": "repro.core.assignment:assign_items",
    "HeadPlacement": "repro.core.placement:HeadPlacement",
    "PlanArrays": "repro.cache.slot_cache:PlanArrays",
    "synthetic_profile": "repro.core.profiles:synthetic_profile",
    "profile_from_lengths": "repro.core.profiles:profile_from_lengths",
    "select_policy": "repro.compression.policies:select",
    # low-level serving ops (sharded launch harness, parity tests)
    "init_params": "repro.models:init_params",
    "slotify_params": "repro.serving.engine:slotify_params",
    "prefill": "repro.serving.engine:prefill",
    "decode_step": "repro.serving.engine:decode_step",
    "ServeState": "repro.serving.engine:ServeState",
    # continuous-batching surface
    "Scheduler": "repro.serving.scheduler:Scheduler",
    "Request": "repro.serving.request:Request",
    "RequestState": "repro.serving.request:RequestState",
    "synthesize_requests": "repro.serving.request:synthesize_requests",
    "poisson_arrivals": "repro.serving.request:poisson_arrivals",
    "latency_percentiles": "repro.serving.request:latency_percentiles",
    # shared-prefix reuse + chunked prefill (DESIGN.md §14)
    "PrefixConfig": "repro.prefix:PrefixConfig",
    "PrefixIndex": "repro.prefix:PrefixIndex",
    "PrefixEntry": "repro.prefix:PrefixEntry",
    # paged cache backend (DESIGN.md §9)
    "PagingConfig": "repro.paging.block_pool:PagingConfig",
    "PoolExhausted": "repro.paging.block_pool:PoolExhausted",
    "BlockPool": "repro.paging.block_pool:BlockPool",
    "PagedCache": "repro.paging.paged_cache:PagedCache",
    "CacheBackend": "repro.serving.cache_backend:CacheBackend",
    "make_cache_backend": "repro.serving.cache_backend:make_cache_backend",
    # executor layer (DESIGN.md §10)
    "Executor": "repro.exec.base:Executor",
    "ExecutorConfig": "repro.exec.base:ExecutorConfig",
    "make_executor": "repro.exec.base:make_executor",
    # observability (DESIGN.md §12)
    "Obs": "repro.obs:Obs",
    "ObsConfig": "repro.obs:ObsConfig",
    "MetricsRegistry": "repro.obs:MetricsRegistry",
    "TraceBuffer": "repro.obs:TraceBuffer",
    # serving front end (DESIGN.md §13)
    "FrontendConfig": "repro.frontend.config:FrontendConfig",
    "PriorityClass": "repro.frontend.config:PriorityClass",
    "FrontendScheduler": "repro.frontend.core:FrontendScheduler",
    "run_frontend_trace": "repro.frontend.core:run_frontend_trace",
    "EngineLoop": "repro.frontend.bridge:EngineLoop",
    "FrontendServer": "repro.frontend.http:FrontendServer",
    "serve_http": "repro.frontend.http:serve_http",
}

__all__ = sorted(
    ["ASSIGNMENT_ENGINE_REGISTRY", "CACHE_BACKEND_REGISTRY",
     "EXECUTOR_REGISTRY", "POLICY_REGISTRY", "Registry",
     "get_assignment_engine", "get_cache_backend", "get_executor",
     "get_policy", "list_cache_backends", "list_engines", "list_executors",
     "list_policies", "register_assignment_engine", "register_cache_backend",
     "register_executor", "register_policy", *_LAZY])


def __getattr__(name: str):
    try:
        target = _LAZY[name]
    except KeyError:
        raise AttributeError(
            f"module 'repro.api' has no attribute {name!r}") from None
    import importlib
    module, attr = target.split(":")
    value = getattr(importlib.import_module(module), attr)
    globals()[name] = value  # cache for subsequent lookups
    return value


def __dir__():
    return __all__
