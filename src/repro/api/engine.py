"""The `Engine` facade: one front door for FairKV serving.

Owns the full serving composition — parameter init, plan construction,
slot-layout weight permutation, and cache state — behind a handful of
methods, so no caller re-wires
``ModelConfig → init params → plan → slot weights → prefill → decode``
by hand (DESIGN.md §8):

- **one-shot batch**: `Engine.generate(prompts, max_new_tokens)` runs
  prefill + compression + a jitted decode loop and returns a
  `GenerationResult` (tokens, logits, realized per-head lengths, plan
  metrics, timings).
- **continuous**: `submit` / `step` / `stream` / `run_trace` wrap the
  request scheduler (`repro.serving.scheduler.Scheduler`, DESIGN.md §7);
  `stream` yields per-token `StreamEvent`s as requests progress.
- **replanning**: `replan()` rebuilds the head placement — from a measured
  profile and/or per-shard speed factors in one-shot mode, or from the
  realized live-cache profile (migrating the cache in place) in continuous
  mode — the PR-1 online-replanning path as a first-class method.
- **profiling**: `measure_profile(batch)` runs a profiling prefill and
  returns the (L, H) realized per-head retained lengths (the paper's §4.1
  offline statistic) for feeding back into `replan` or a fresh `build`.

The facade holds the *original-layout* parameters (`.params`) so replans
can re-slotify, and exposes the low-level pieces (`.plan`,
`.plan_arrays`, `.serve_params`, `.scheduler`) for telemetry and tests.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.config import DTYPES as _DTYPES
from repro.api.config import EngineConfig
from repro.api.stats import EngineStats, collect_stats
from repro.cache.slot_cache import PlanArrays
from repro.core.placement import HeadPlacement
from repro.core.planner import PlannerConfig, build_plan
from repro.core.profiles import profile_from_lengths, synthetic_profile
from repro.exec.base import make_executor
from repro.models import init_params
from repro.obs import Obs
from repro.serving import engine as _serve
from repro.serving.cache_backend import make_cache_backend
from repro.serving.request import Request
from repro.serving.scheduler import Scheduler

# ---------------------------------------------------------------------------
# Result / event types
# ---------------------------------------------------------------------------


@dataclass
class GenerationResult:
    """Output of `Engine.generate` (one-shot batch mode).

    ``tokens[:, 0]`` is the prefill argmax (the first generated token);
    ``tokens[:, 1:]`` come from the decode loop.  ``logits`` aligns with
    ``tokens``: entry t is the distribution the t-th token was taken from.
    ``lengths`` is the realized per-head retained-length tensor
    (L, Hkv, B) — the paper's workload observable; ``realized_profile``,
    ``efficiency`` and ``makespan`` are derived from it against the active
    plan (None for attention-free models).
    """

    tokens: np.ndarray  # (B, 1 + steps)
    logits: Optional[np.ndarray]  # (B, 1 + steps, V) when collected
    lengths: np.ndarray  # (L, Hkv, B) realized retained lengths
    realized_profile: Optional[np.ndarray]  # (L, Hkv)
    efficiency: Optional[float]  # plan E (Eq. 5) on the realized profile
    makespan: Optional[float]  # plan max-shard load on the realized profile
    prefill_s: float
    step_s: List[float] = field(default_factory=list)  # per-decode-step wall


@dataclass(frozen=True)
class StreamEvent:
    """One generated token from the continuous-mode `Engine.stream`."""

    req_id: int
    token: int
    index: int  # position within the request's generated sequence
    step: int  # scheduler step that produced it
    finished: bool  # True on the request's last token


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------


class Engine:
    """Facade over the FairKV serving stack.  Construct via `Engine.build`."""

    def __init__(self, cfg: EngineConfig, params: dict, plan: HeadPlacement,
                 profile: Optional[np.ndarray],
                 head_importance: Optional[np.ndarray] = None,
                 mesh=None):
        if mesh is not None and cfg.executor == "local":
            raise ValueError(
                "mesh= was passed but executor='local' runs on a single "
                "device and would silently ignore it; set "
                "EngineConfig(executor='mesh') to run on the mesh")
        self.cfg = cfg
        self.params = params  # original layout — kept for re-slotify
        self.plan = plan
        self.profile = profile  # (L, H) planning profile (None: attn-free)
        self.head_importance = head_importance  # headkv per-head weights
        self.mesh = mesh
        self.pa = PlanArrays.from_plan(plan)
        self.sp = _serve.slotify_params(params, plan, cfg.model)
        # observability (DESIGN.md §12): one registry + trace per engine,
        # threaded through the executor, backend, and (lazily) the scheduler
        self.obs = Obs.build(cfg.obs)
        # executor (DESIGN.md §10): owns the compiled prefill/decode StepFns;
        # weights and plan arrays are StepFn *arguments*, so replans swap
        # placements without recompiling
        self.executor = make_executor(cfg.executor, cfg.model,
                                      cfg.compression,
                                      exec_cfg=cfg.executor_cfg, mesh=mesh,
                                      paging=cfg.paging, obs=self.obs)
        # cache storage backend (DESIGN.md §9): "slot" | "paged" | plugin
        self.backend = make_cache_backend(
            cfg.cache_backend, cfg.model, cfg.compression,
            max_live_tokens=cfg.scheduler.max_live_tokens, paging=cfg.paging,
            n_shards=cfg.n_shards,
            max_live_tokens_per_shard=cfg.scheduler.max_live_tokens_per_shard,
            pool_partitions=self.executor.pool_partitions,
            row_partitions=self.executor.row_partitions, obs=self.obs)
        self.state: Optional[_serve.ServeState] = None
        self._mode: Optional[str] = None  # "oneshot" | "continuous" (last used)
        # persisted straggler speed factors (set by a speed-aware replan);
        # later replans and a lazily-created scheduler inherit them so the
        # mitigation is never silently reverted
        self._shard_speeds: Optional[np.ndarray] = None
        self._scheduler: Optional[Scheduler] = None
        self._next_req_id = 0
        # drain() before the scheduler exists (e.g. a signal landing during
        # build) must still stick — applied on first _ensure_scheduler
        self._drain_pending = False

    # ---- construction ------------------------------------------------------

    @classmethod
    def build(cls, cfg: EngineConfig, *, params: Optional[dict] = None,
              profile: Optional[np.ndarray] = None, rng=None, mesh=None,
              head_importance: Optional[np.ndarray] = None) -> "Engine":
        """Assemble an engine: params (init'd if not given), plan, slot
        weights.

        ``profile`` is the (L, H) expected per-head workload the planner
        optimizes; default is a synthetic profile seeded from
        ``cfg.profile_seed`` / ``cfg.profile_skew`` (swap in a measured one
        from `measure_profile` for paper-faithful planning).  ``mesh`` is
        the (data, model) device mesh the ``mesh`` executor runs on
        (DESIGN.md §10) — required there, rejected with ``executor='local'``
        (a silently ignored mesh is a misconfiguration, not a fallback).
        """
        model = cfg.model
        dtype = _DTYPES[cfg.dtype]
        if params is None:
            rng = jax.random.PRNGKey(cfg.seed) if rng is None else rng
            params = init_params(model, rng, dtype=dtype,
                                 max_seq_len=cfg.max_seq_len)
        if model.attention_free:
            plan = build_plan(np.ones((model.n_layers, 1)), 1,
                              PlannerConfig(mode="sha", slots_per_shard=1))
            profile = None
        else:
            if profile is None:
                profile = synthetic_profile(
                    model.n_layers, model.n_kv_heads,
                    budget=cfg.compression.budget, skew=cfg.profile_skew,
                    seed=cfg.profile_seed)
            plan = build_plan(profile, cfg.n_shards, cfg.planner)
        return cls(cfg, params, plan, profile,
                   head_importance=head_importance, mesh=mesh)

    # ---- low-level views ---------------------------------------------------

    @property
    def plan_arrays(self) -> PlanArrays:
        return self.pa

    @property
    def serve_params(self) -> dict:
        return self.sp

    @property
    def dtype(self):
        return _DTYPES[self.cfg.dtype]

    def _invalidate(self) -> None:
        """Plan changed: rebuild slot weights + plan arrays.  The executor's
        StepFn takes both as arguments, so nothing recompiles (the shapes
        are replan-invariant — slot grid and capacity are fixed)."""
        self.pa = PlanArrays.from_plan(self.plan)
        self.sp = _serve.slotify_params(self.params, self.plan, self.cfg.model)

    # ---- one-shot serving --------------------------------------------------

    def prefill(self, batch: Union[Dict[str, jnp.ndarray], np.ndarray],
                rows: Optional[jnp.ndarray] = None):
        """Run the prompt through prefill+compression; holds the resulting
        cache on ``self.state``.  Returns (logits (B, V), lengths
        (L, Hkv, B))."""
        batch = self._as_batch(batch)
        state, logits, lengths = self.executor.prefill(
            self.sp, batch, self.pa, rows=rows,
            head_importance=self.head_importance)
        self.state = state
        self._mode = "oneshot"
        return logits, lengths

    def generate(self, prompts: Union[Dict[str, jnp.ndarray], np.ndarray],
                 max_new_tokens: int,
                 teacher_tokens: Optional[np.ndarray] = None,
                 collect_logits: bool = True) -> GenerationResult:
        """One-shot batch generation: prefill + ``max_new_tokens`` decode
        steps.

        ``prompts`` is a (B, T) int token array or a prepared batch dict.
        ``teacher_tokens`` (B, max_new_tokens), when given, forces the token
        *fed* at each decode step (teacher forcing for fidelity evals); the
        returned ``tokens`` are still the model's argmax choices.
        """
        t0 = time.perf_counter()
        logits, lengths = self.prefill(prompts)
        jax.block_until_ready(logits)
        prefill_s = time.perf_counter() - t0
        # one-shot TTFT is the prefill wall (no queue to wait in)
        self.obs.metrics.histogram(
            "ttft_s", help="time to first token (queue wait + prefill "
                           "wall time)").observe(prefill_s)
        # re-house the prefilled cache in the configured backend's layout
        # (identity for "slot"; "paged" allocates blocks proportional to the
        # realized retained lengths).  One-shot mode has no request queue to
        # preempt into, so an undersized pool is a config error, not a
        # scheduling event — fail with the remedy instead of a raw signal.
        from repro.paging.block_pool import PoolExhausted
        try:
            self.state = self.backend.from_prefill(self.state, self.pa)
        except PoolExhausted as e:
            raise ValueError(
                f"cache pool too small for one-shot generation ({e}); "
                f"raise PagingConfig.n_blocks or leave it 0 for "
                f"worst-case sizing") from e
        state = self.state
        tokens = [np.asarray(state.last_tokens)]
        logits_all = [np.asarray(logits)] if collect_logits else None
        step_s: List[float] = []
        for t in range(max_new_tokens):
            tok = (state.last_tokens if teacher_tokens is None
                   else jnp.asarray(teacher_tokens[:, t], jnp.int32))
            try:
                state = self.backend.prepare_decode(state, None)
            except PoolExhausted as e:
                raise ValueError(
                    f"cache pool ran dry at decode step {t} ({e}); one-shot "
                    f"generation cannot preempt — raise "
                    f"PagingConfig.n_blocks") from e
            t0 = time.perf_counter()
            state, lg = self.executor.decode(self.sp, state, self.pa, tok)
            # rebind immediately: decode donated the previous state's
            # buffers, so self.state must never outlive a step — a failure
            # on a later iteration would otherwise leave the engine holding
            # deleted arrays
            self.state = state
            jax.block_until_ready(lg)
            step_s.append(time.perf_counter() - t0)
            self.obs.metrics.histogram(
                "itl_s", help="inter-token latency (per-request mean in "
                              "continuous mode; per-step in one-shot mode)"
                ).observe(step_s[-1])
            tokens.append(np.asarray(state.last_tokens))
            if collect_logits:
                logits_all.append(np.asarray(lg))
        lengths_np = np.asarray(lengths)
        realized = eff = mk = None
        if lengths_np.size:
            realized = profile_from_lengths(np.asarray(lengths_np, np.float64))
            eff = float(self.plan.efficiency(realized))
            mk = float(self.plan.makespan(realized))
        return GenerationResult(
            tokens=np.stack(tokens, axis=1),
            logits=(np.stack(logits_all, axis=1) if collect_logits else None),
            lengths=lengths_np, realized_profile=realized, efficiency=eff,
            makespan=mk, prefill_s=prefill_s, step_s=step_s)

    def measure_profile(self, batch: Union[Dict, np.ndarray]) -> np.ndarray:
        """Profiling pass (paper §4.1): run prefill+compression on a sample
        batch and return the (L, H) mean realized per-head lengths.

        The compression selection is plan-independent, so the measurement is
        valid for planning *any* layout.  Engine state is left untouched.
        """
        saved = self.state
        try:
            _, lengths = self.prefill(batch)
            return profile_from_lengths(np.asarray(lengths, np.float64))
        finally:
            self.state = saved

    def _as_batch(self, batch) -> Dict[str, jnp.ndarray]:
        if isinstance(batch, dict):
            return batch
        return {"tokens": jnp.asarray(batch, jnp.int32)}

    # ---- replanning --------------------------------------------------------

    def replan(self, profile: Optional[np.ndarray] = None,
               shard_speeds: Optional[Sequence[float]] = None) -> dict:
        """Rebuild the head placement and swap it in.

        Continuous mode (scheduler live): delegates to the scheduler's
        online replan — live-cache migration with accept/reject scoring
        (DESIGN.md §7) — planning from the realized profile unless
        ``profile`` and/or ``shard_speeds`` (straggler mitigation,
        DESIGN.md §6) override the inputs.  One-shot mode: the plan is
        rebuilt from ``profile`` (default: the build-time profile) and
        optional ``shard_speeds``; a live one-shot cache is migrated into
        the new layout.
        """
        if self._scheduler is not None:
            event = self._scheduler.replan(profile=profile,
                                           shard_speeds=shard_speeds)
            self._sync_from_scheduler()
            return event
        if self.cfg.model.attention_free:
            raise ValueError("attention-free models have no head placement "
                             "to replan")
        prof = self.profile if profile is None else np.asarray(profile)
        if shard_speeds is not None:
            self._shard_speeds = np.asarray(shard_speeds, float)
        old_pa = self.pa
        self.plan = build_plan(prof, self.cfg.n_shards, self.cfg.planner,
                               shard_speeds=self._shard_speeds)
        self.profile = prof
        self._invalidate()
        migrated = False
        if self.state is not None and self.state.cache is not None:
            from repro.cache.slot_cache import SlotCache, migrate_cache
            if isinstance(self.state.cache, SlotCache):
                # prefill leaves the cache in slot layout regardless of
                # backend (generate() adopts it later); migrate it in place
                cache = migrate_cache(self.state.cache, old_pa, self.pa)
            else:
                _, commit = self.backend.migrate_cache(self.state.cache,
                                                       old_pa, self.pa)
                cache = commit()
            self.state = dataclasses.replace(self.state, cache=cache)
            migrated = True
        return {"plan": self.plan, "migrated_cache": migrated,
                "shard_speeds": (None if self._shard_speeds is None
                                 else list(self._shard_speeds))}

    # ---- continuous serving ------------------------------------------------

    @property
    def scheduler(self) -> Optional[Scheduler]:
        """The live continuous-batching scheduler (None until first
        `submit` / `step` / `stream`)."""
        return self._scheduler

    def _ensure_scheduler(self) -> Scheduler:
        self._mode = "continuous"
        if self._scheduler is None:
            # the scheduler gets its OWN backend instance: backends carry
            # allocator state (pool + table mirror), and a later one-shot
            # generate() resets the engine's backend — sharing one instance
            # would silently invalidate the scheduler's live block topology
            self._scheduler = Scheduler(
                self.cfg.model, self.params, self.plan,
                self.cfg.compression, self.cfg.scheduler,
                planner_cfg=self.cfg.planner, dtype=self.dtype,
                serve_params=self.sp,  # same plan -> reuse slot weights
                backend=make_cache_backend(
                    self.cfg.cache_backend, self.cfg.model,
                    self.cfg.compression,
                    max_live_tokens=self.cfg.scheduler.max_live_tokens,
                    paging=self.cfg.paging,
                    n_shards=self.cfg.n_shards,
                    max_live_tokens_per_shard=(
                        self.cfg.scheduler.max_live_tokens_per_shard),
                    pool_partitions=self.executor.pool_partitions,
                    row_partitions=self.executor.row_partitions,
                    obs=self.obs),
                # the executor is shared: its StepFn caches are keyed by
                # batch shape and cache layout, so one-shot and continuous
                # traces coexist without evicting each other
                executor=self.executor,
                head_importance=self.head_importance,
                obs=self.obs, plan_profile=self.profile,
                prefix_cfg=self.cfg.prefix,
                spec_cfg=self.cfg.speculation)
            # inherit any one-shot straggler mitigation
            self._scheduler.shard_speeds = self._shard_speeds
            if self._drain_pending:
                self._scheduler.drain()
        return self._scheduler

    def _sync_from_scheduler(self) -> None:
        """Adopt the scheduler's plan/weights after an online replan (the
        scheduler owns them in continuous mode)."""
        sched = self._scheduler
        if sched is not None and sched.plan is not self.plan:
            self.plan, self.pa, self.sp = sched.plan, sched.pa, sched.sp

    def warmup(self) -> None:
        """Compile the continuous decode step outside any timed region (an
        all-inactive step has the same trace signature as live ones).

        The decode StepFn donates its state argument, so the warmup result
        must be adopted — holding the old state would keep deleted buffers.
        An all-inactive tick leaves cache contents/lengths/positions
        untouched; only ``decode_steps`` (the ring-write phase) is restored
        so a warmed scheduler stays step-for-step identical to a cold one.
        With requests already live the tick would be a *real* decode
        (appends included), so warmup is a no-op then — the step is
        compiled by that point anyway.
        """
        sched = self._ensure_scheduler()
        if sched.active:
            return
        steps0 = sched.state.decode_steps + 0  # fresh buffer: survives donation
        state, _ = sched._decode(sched.state, sched.active_mask())
        sched.state = dataclasses.replace(state, decode_steps=steps0)

    def submit(self, request: Union[Request, np.ndarray, Sequence[int]],
               max_new_tokens: int = 16, eos_id: Optional[int] = None,
               arrival_step: int = 0, tenant: str = "default",
               priority: int = 1,
               deadline_s: Optional[float] = None) -> Request:
        """Queue a request (continuous mode).  Accepts a prepared `Request`
        or a raw prompt token sequence; ``tenant`` / ``priority`` /
        ``deadline_s`` thread the multi-tenant metadata (DESIGN.md §13)
        onto a raw-prompt submission (a prepared `Request` carries its
        own)."""
        if not isinstance(request, Request):
            request = Request(req_id=self._next_req_id,
                              prompt=np.asarray(request, np.int32),
                              arrival_step=arrival_step,
                              max_new_tokens=max_new_tokens, eos_id=eos_id,
                              tenant=tenant, priority=priority,
                              deadline_s=deadline_s)
        self._next_req_id = max(self._next_req_id, request.req_id + 1)
        self._ensure_scheduler().submit(request)
        return request

    def cancel(self, request_id: int) -> bool:
        """Retire an in-flight or queued request early (continuous mode):
        its batch row and — on the paged backend — its pool blocks are
        released immediately (refcounts decremented), exactly like a
        normal retirement.  The client-disconnect path for SSE streams.
        Returns False when the id is unknown or already finished."""
        if self._scheduler is None:
            return False
        return self._scheduler.cancel(request_id)

    def drain(self) -> None:
        """Graceful shutdown (continuous mode): stop admitting, let live
        rows decode to completion.  `run_trace` then cancels queued and
        unsubmitted requests and returns; safe to call from a signal
        handler mid-trace (it only sets a flag)."""
        self._drain_pending = True
        if self._scheduler is not None:
            self._scheduler.drain()

    def step(self) -> dict:
        """One scheduler tick: admit → decode → retire → (maybe) replan."""
        ev = self._ensure_scheduler().step()
        self._sync_from_scheduler()
        return ev

    def stream(self, requests: Sequence[Request],
               max_steps: int = 10_000) -> Iterator[StreamEvent]:
        """Drive a request trace, yielding a `StreamEvent` per generated
        token as scheduler steps complete (per-request token iteration).

        Requests are submitted at their ``arrival_step``; iteration ends
        when every request has finished or ``max_steps`` elapses.  Trace
        telemetry stays available on `self.scheduler` afterwards.
        """
        sched = self._ensure_scheduler()
        pending = sorted(requests, key=lambda r: (r.arrival_step, r.req_id))
        emitted = {r.req_id: 0 for r in pending}
        i = 0
        # completion is judged on *these* requests, not the scheduler's
        # global finish count — other in-flight requests finishing must not
        # truncate this stream
        while (any(not r.is_finished for r in pending)
               and sched.step_idx < max_steps):
            while (i < len(pending)
                   and pending[i].arrival_step <= sched.step_idx):
                self.submit(pending[i])
                i += 1
            ev = sched.step()
            self._sync_from_scheduler()
            for req in pending:
                n = req.n_generated
                while emitted[req.req_id] < n:
                    k = emitted[req.req_id]
                    emitted[req.req_id] = k + 1
                    yield StreamEvent(
                        req_id=req.req_id, token=req.generated[k], index=k,
                        step=ev["step"],
                        finished=req.is_finished and k == n - 1)

    def run_trace(self, requests: Sequence[Request],
                  max_steps: int = 10_000) -> dict:
        """Drive a full trace to completion; returns the scheduler's summary
        telemetry (steps, tokens/s, mid-stream admissions, replan log)."""
        out = self._ensure_scheduler().run(requests, max_steps=max_steps)
        self._sync_from_scheduler()
        return out

    # ---- observability (DESIGN.md §12) -------------------------------------

    def stats(self) -> EngineStats:
        """One typed snapshot of the engine's operational state: nested
        ``scheduler`` / ``pool`` / ``prefix`` / ``plan`` / ``speculation``
        sections (`repro.api.stats.EngineStats`).  Always constructible —
        sections without a live source come back with ``None`` fields and
        an empty ``detail`` instead of raising.  Supersedes the loose
        `memory_stats` / `prefix_stats` / `imbalance` / `replan_log`
        accessors, which remain as thin delegates (DESIGN.md §8)."""
        return collect_stats(self)

    def prefix_stats(self) -> dict:
        """Deprecated: use ``stats().prefix`` (typed) — this returns its
        raw ``detail`` dict (empty until a continuous scheduler with
        sharing enabled exists)."""
        return self.stats().prefix.detail

    def metrics(self) -> dict:
        """Deterministic snapshot of every metric family (counters, gauges,
        histograms with cumulative buckets); ``{}`` when obs is disabled."""
        return self.obs.metrics.snapshot()

    def metrics_prometheus(self) -> str:
        """Prometheus text exposition of the metrics registry."""
        return self.obs.metrics.to_prometheus()

    def metrics_jsonl(self) -> str:
        """One JSON object per metric series (appendable log format)."""
        return self.obs.metrics.to_jsonl()

    def trace_export(self) -> str:
        """Chrome trace-event JSON of the recent span window — load in
        Perfetto or chrome://tracing."""
        return self.obs.trace.export_json()

    # ---- continuous-mode telemetry ----------------------------------------

    @property
    def finished_requests(self) -> List[Request]:
        return [] if self._scheduler is None else self._scheduler.finished

    @property
    def replan_log(self) -> List[dict]:
        """Deprecated: use ``stats().scheduler.replan_log``."""
        return self.stats().scheduler.replan_log

    def imbalance(self) -> float:
        """Deprecated: use ``stats().scheduler.imbalance``.  max/mean
        realized per-shard KV load (continuous mode); raises until the
        continuous scheduler exists (the typed field is None instead)."""
        v = self.stats().scheduler.imbalance
        if v is None:
            raise RuntimeError("imbalance() requires the continuous "
                               "scheduler; call submit/stream first")
        return v

    def memory_stats(self) -> dict:
        """Deprecated: use ``stats().pool`` (typed) — this returns its raw
        ``detail`` dict.  Reports whichever mode (one-shot / continuous)
        ran most recently, so interleaved use never returns a stale idle
        cache; raises with no live cache (the typed section is empty
        instead)."""
        pool = self.stats().pool
        if not pool.detail:
            raise RuntimeError("memory_stats() needs a live cache; call "
                               "generate/prefill or submit/stream first")
        return pool.detail
