"""`EngineStats`: one typed snapshot of the engine's operational state.

Callers used to peek at four loose accessors (`Engine.memory_stats`,
`prefix_stats`, `imbalance`, `replan_log`) plus raw metric-registry
counters to build a picture of a running engine; each returned a
different shape (dict / float / list) with availability rules scattered
across docstrings.  `Engine.stats()` consolidates them into one nested
frozen dataclass — ``scheduler`` / ``pool`` / ``prefix`` / ``plan`` /
``speculation`` — that is always constructible: sections that have no
live source (no scheduler yet, obs disabled, slot backend) come back
with ``None``-valued fields and an empty ``detail`` dict instead of
raising.

Every section keeps the *typed* fields a dashboard or benchmark wants to
key on, and carries the full backing dict in ``detail`` so nothing the
old accessors exposed is lost.  The old accessors remain as thin
delegates over `stats()` (deprecated — see DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import List, Optional


@dataclass(frozen=True)
class PoolStats:
    """Cache-memory footprint (the old ``memory_stats`` dict, typed)."""

    backend: Optional[str] = None  # "slot" | "paged" | plugin name
    blocks_total: Optional[int] = None  # paged only
    blocks_in_use: Optional[int] = None
    cache_bytes: Optional[int] = None
    slot_equivalent_bytes: Optional[int] = None
    detail: dict = field(default_factory=dict)  # full memory_stats payload


@dataclass(frozen=True)
class PrefixStats:
    """Shared-prefix cache census (the old ``prefix_stats`` dict, typed)."""

    enabled: bool = False
    entries: Optional[int] = None
    blocks_held: Optional[int] = None
    hits: Optional[int] = None
    misses: Optional[int] = None
    evictions: Optional[int] = None
    detail: dict = field(default_factory=dict)


@dataclass(frozen=True)
class SchedulerStats:
    """Continuous-batching lifecycle counters + the replan history."""

    mode: str = "idle"  # "idle" | "oneshot" | "continuous"
    steps: Optional[int] = None
    active_rows: Optional[int] = None
    queued: Optional[int] = None
    finished: Optional[int] = None
    replans: Optional[int] = None
    replans_accepted: Optional[int] = None  # accepted online replans
    replans_rejected: Optional[int] = None
    preemptions: Optional[int] = None
    cancellations: Optional[int] = None
    imbalance: Optional[float] = None  # realized max/mean per-shard load
    replan_log: List[dict] = field(default_factory=list)
    detail: dict = field(default_factory=dict)


@dataclass(frozen=True)
class PlanStats:
    """The live `HeadPlacement` summarized (replans update it in place)."""

    mode: Optional[str] = None  # planner mode the plan was built under
    n_shards: Optional[int] = None
    slots_per_shard: Optional[int] = None
    replicated_heads: Optional[int] = None  # heads with replica_count > 1
    max_replication: Optional[int] = None
    detail: dict = field(default_factory=dict)


@dataclass(frozen=True)
class SpeculationStats:
    """Speculative-decoding effectiveness (DESIGN.md §16)."""

    enabled: bool = False
    max_k: Optional[int] = None
    draft_layers: Optional[int] = None
    proposed: Optional[int] = None  # lifetime draft tokens proposed
    accepted: Optional[int] = None  # lifetime draft tokens accepted
    acceptance: Optional[float] = None  # accepted / proposed
    detail: dict = field(default_factory=dict)


@dataclass(frozen=True)
class EngineStats:
    """The consolidated `Engine.stats()` snapshot."""

    scheduler: SchedulerStats = field(default_factory=SchedulerStats)
    pool: PoolStats = field(default_factory=PoolStats)
    prefix: PrefixStats = field(default_factory=PrefixStats)
    plan: PlanStats = field(default_factory=PlanStats)
    speculation: SpeculationStats = field(default_factory=SpeculationStats)

    def to_dict(self) -> dict:
        """Plain nested-dict form (JSON-serializable)."""
        return dataclasses.asdict(self)


def collect_stats(engine) -> EngineStats:
    """Build an `EngineStats` from a live `Engine` (the implementation
    behind `Engine.stats()`; lives here so the facade stays readable)."""
    sched = engine.scheduler

    # -- pool: whichever mode ran most recently has the live cache --------
    pool = PoolStats()
    mem = None
    if engine._mode == "continuous" and sched is not None:
        mem = sched.backend.memory_stats(sched.state)
    elif engine.state is not None:
        mem = engine.backend.memory_stats(engine.state)
    elif sched is not None:
        mem = sched.backend.memory_stats(sched.state)
    if mem is not None:
        pool = PoolStats(
            backend=mem.get("backend"),
            blocks_total=mem.get("blocks_total"),
            blocks_in_use=mem.get("blocks_in_use"),
            cache_bytes=mem.get("cache_bytes"),
            slot_equivalent_bytes=mem.get("slot_equivalent_bytes"),
            detail=dict(mem))

    # -- prefix -----------------------------------------------------------
    prefix = PrefixStats()
    if sched is not None:
        pst = sched.prefix_stats()
        if pst:
            prefix = PrefixStats(
                enabled=True, entries=pst.get("entries"),
                blocks_held=pst.get("blocks_held"), hits=pst.get("hits"),
                misses=pst.get("misses"), evictions=pst.get("evictions"),
                detail=dict(pst))

    # -- scheduler --------------------------------------------------------
    scheduler = SchedulerStats(mode=engine._mode or "idle")
    if sched is not None:
        acc = rej = None
        if sched.obs.enabled:
            acc = int(sched.obs.metrics.counter_value(
                "sched_replans_total", outcome="accepted"))
            rej = int(sched.obs.metrics.counter_value(
                "sched_replans_total", outcome="rejected"))
        scheduler = SchedulerStats(
            mode="continuous", steps=sched.step_idx,
            active_rows=len(sched.active), queued=len(sched.queue),
            finished=len(sched.finished), replans=sched.n_replans,
            replans_accepted=acc, replans_rejected=rej,
            preemptions=sched.n_preemptions,
            cancellations=sched.n_cancellations,
            imbalance=sched.imbalance(),
            replan_log=list(sched.replan_log))

    # -- plan -------------------------------------------------------------
    plan_obj = engine.plan
    plan = PlanStats()
    if plan_obj is not None:
        import numpy as np
        rc = np.concatenate([np.asarray(lp.replica_count).ravel()
                             for lp in plan_obj.layers])
        plan = PlanStats(
            mode=plan_obj.mode, n_shards=plan_obj.n_shards,
            slots_per_shard=plan_obj.slots_per_shard,
            replicated_heads=int((rc > 1).sum()),
            max_replication=int(rc.max()) if rc.size else None)

    # -- speculation ------------------------------------------------------
    scfg = engine.cfg.speculation
    speculation = SpeculationStats(enabled=scfg.enabled)
    if scfg.enabled:
        proposed = accepted = 0
        if sched is not None:
            reqs = list(sched.finished) + list(sched.active.values())
            proposed = sum(r.spec_proposed for r in reqs)
            accepted = sum(r.spec_accepted for r in reqs)
        speculation = SpeculationStats(
            enabled=True, max_k=scfg.max_k, draft_layers=scfg.draft_layers,
            proposed=proposed, accepted=accepted,
            acceptance=(accepted / proposed) if proposed else None,
            detail={"adaptive": scfg.adaptive, "min_k": scfg.min_k})

    return EngineStats(scheduler=scheduler, pool=pool, prefix=prefix,
                       plan=plan, speculation=speculation)
