"""Logical-axis sharding rules (MaxText-style) + constraint helper.

Models are written against *logical* axis names; the launcher installs a
``ShardingRules`` mapping (logical name → mesh axis/axes) for the current
(mesh × shape-kind).  ``constrain(x, *axes)`` is a no-op outside a rules
context, so all model code runs unmodified on a single CPU device.
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]


@dataclass(frozen=True)
class ShardingRules:
    """Logical → physical axis mapping."""

    mesh: Mesh
    rules: Dict[str, MeshAxes] = field(default_factory=dict)

    def spec(self, *logical: Optional[str]) -> P:
        parts = []
        for name in logical:
            if name is None:
                parts.append(None)
                continue
            ax = self.rules.get(name, None)
            parts.append(ax)
        return P(*parts)

    def sharding(self, *logical: Optional[str]) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(*logical))


_state = threading.local()


def current_rules() -> Optional[ShardingRules]:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Optional[ShardingRules]):
    prev = getattr(_state, "rules", None)
    _state.rules = rules
    try:
        yield
    finally:
        _state.rules = prev


def constrain(x, *logical: Optional[str]):
    """Apply with_sharding_constraint under the active rules (else no-op)."""
    rules = current_rules()
    if rules is None:
        return x
    return jax.lax.with_sharding_constraint(x, rules.sharding(*logical))


# Default logical-axis rule sets ------------------------------------------------


def train_rules(mesh: Mesh) -> ShardingRules:
    """FSDP(data) × TP(model); batch over pod too when present."""
    axes = mesh.axis_names
    batch = ("pod", "data") if "pod" in axes else ("data",)
    return ShardingRules(mesh=mesh, rules={
        "batch": batch,
        "seq": None,
        "seq_act": "model",  # Megatron-SP: residual stream seq-sharded over TP
        "d_model": None,
        "ff": "model",
        "heads": "model",
        "kv_slot": "model",
        "kv_heads": "model",
        "vocab": "model",
        "expert": "model",
        "fsdp": "data",  # weight shards gathered per-layer (ZeRO-3)
        "cache_len": None,
    })


def serve_rules(mesh: Mesh, long_context: bool = False,
                weights_2d: bool = False) -> ShardingRules:
    """Decode: batch over data, slots/ff over model.  Long-context (B==1):
    the data axis shards the retained-KV capacity instead (split-S
    flash-decode; the o-projection psum over 'data' recombines partials).

    ``weights_2d``: additionally shard every weight's d_model-side dim over
    the data axis (2D tensor parallelism).  Decode activations are tiny, so
    the per-layer reshard collectives cost MBs while weight memory drops by
    |data|× — required for ≥100B params on 16 GiB chips, and the main §Perf
    lever for weight-read-bound decode.
    """
    axes = mesh.axis_names
    batch = ("pod", "data") if "pod" in axes else ("data",)
    rules = {
        "batch": None if long_context else batch,
        "seq": None,
        "seq_act": None,
        "d_model": None,
        "ff": "model",
        "heads": "model",
        "kv_slot": "model",
        "kv_heads": "model",
        "vocab": "model",
        "expert": "model",
        "fsdp": None,
        "fsdp_w": "data" if weights_2d else None,
        "cache_len": batch if long_context else None,
    }
    return ShardingRules(mesh=mesh, rules=rules)
