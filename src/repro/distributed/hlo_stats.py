"""Post-SPMD HLO statistics: collective bytes + while-body bookkeeping.

Collective-bytes convention (per device, documented in EXPERIMENTS.md):
- all-gather          → output bytes (each device materializes the gather)
- all-reduce          → 2 × tensor bytes (ring: reduce-scatter + all-gather)
- reduce-scatter      → input bytes
- all-to-all          → tensor bytes
- collective-permute  → tensor bytes

While-loop bodies appear once in the HLO; their trip counts are known to the
caller (scan lengths), so ``while_body_stats`` reports per-body collective
bytes for the roofline to scale.
"""
from __future__ import annotations

import re
from typing import Dict


_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")

# one shaped value like bf16[16,128]{1,0}
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
# an HLO instruction: %name = <shape or tuple> opcode(
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w\.\-]+\s*=\s*(\([^)]*\)|[^\s]+)\s+([\w\-]+)")
_COMP_RE = re.compile(r"^(\%?[\w\.\-]+)\s*(?:\([^)]*\))?\s*->.*\{\s*$")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _comm_bytes(op: str, out_bytes: int) -> int:
    if op == "all-reduce":
        return 2 * out_bytes
    return out_bytes


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Total per-device collective traffic by op type (whole module,
    while bodies counted once)."""
    stats: Dict[str, Dict[str, float]] = {}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        base = None
        for c in _COLL_OPS:
            if op == c or op.startswith(c + "-"):  # e.g. all-gather-start
                base = c
                break
        if base is None or op.endswith("-done"):
            continue
        nbytes = _comm_bytes(base, _shape_bytes(shape_str))
        d = stats.setdefault(base, {"count": 0, "bytes": 0})
        d["count"] += 1
        d["bytes"] += nbytes
    return stats


def while_body_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Collective bytes inside each named computation that looks like a loop
    body (name contains 'while' or 'body'), for trip-count scaling."""
    out: Dict[str, Dict[str, float]] = {}
    current = None
    for line in hlo_text.splitlines():
        if line and not line.startswith(" ") and "{" in line and "->" in line:
            name = line.split()[0].lstrip("%")
            current = name if ("while" in name or "body" in name) else None
            continue
        if line.strip() == "}":
            current = None
            continue
        if current is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        for c in _COLL_OPS:
            if (op == c or op.startswith(c + "-")) and not op.endswith("-done"):
                nbytes = _comm_bytes(c, _shape_bytes(shape_str))
                d = out.setdefault(current, {"count": 0, "bytes": 0})
                d["count"] += 1
                d["bytes"] += nbytes
                break
    return out
