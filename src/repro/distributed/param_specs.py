"""Parameter / state PartitionSpecs by leaf path.

Rules are *divisibility-guarded*: a logical axis is only mapped onto mesh
axes when the dimension divides the mesh-axis product (e.g. hymba's 25 query
heads cannot shard 16 ways → replicated), so every assigned arch lowers on
every mesh without bespoke cases.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.sharding import ShardingRules


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def guarded(rules: ShardingRules, dim: int, logical: Optional[str]):
    """logical axis name if dim divides its mesh extent, else None."""
    if logical is None:
        return None
    phys = rules.rules.get(logical)
    if phys is None:
        return None
    if dim % _axis_size(rules.mesh, phys) != 0:
        return None
    return phys


def spec_for(rules: ShardingRules, shape: Tuple[int, ...],
             logical: Tuple[Optional[str], ...]) -> P:
    assert len(shape) == len(logical), (shape, logical)
    return P(*(guarded(rules, d, l) for d, l in zip(shape, logical)))


# leaf-name → logical axes per dim (original / train layout)
_TRAIN_MAP = {
    "embed": ("vocab", "fsdp"),
    "head": ("vocab", "fsdp"),
    # train attention is sequence-parallel (scores shard over the query
    # dim), so attention weights shard over fsdp only
    "wq": ("fsdp", None, None),
    "wk": ("fsdp", None, None),
    "wv": ("fsdp", None, None),
    "wo": (None, None, "fsdp"),
    "bq": (None, None),
    "bk": (None, None),
    "bv": (None, None),
    "w1": ("fsdp", "ff"),
    "w3": ("fsdp", "ff"),
    "w2": ("ff", "fsdp"),
    "router": (None, "expert"),
    "we1": ("expert", "fsdp", None),
    "we3": ("expert", "fsdp", None),
    "we2": ("expert", None, "fsdp"),
    "in_proj": ("fsdp", "ff"),
    "out_proj": ("ff", "fsdp"),
    "conv_w": (None, "ff"),
    "enc_pos": (None, None),
    "dec_pos": (None, None),
}

# serve layout additions (slot weights).  "fsdp_w" maps to the data axis in
# 2D weight sharding mode (serve_rules(weights_2d=True)), else to None.
_SERVE_MAP = {
    **_TRAIN_MAP,
    "wq_s": ("kv_slot", "fsdp_w", None, None),
    "wk_s": ("kv_slot", "fsdp_w", None),
    "wv_s": ("kv_slot", "fsdp_w", None),
    "wo_s": ("kv_slot", None, None, "fsdp_w"),
    "bq_s": ("kv_slot", None, None),
    "bk_s": ("kv_slot", None),
    "bv_s": ("kv_slot", None),
    "attn_out_norm_s": ("kv_slot", None, None),
    # serving keeps weights weight-stationary on the model axis (+ data in 2D)
    "embed": ("vocab", "fsdp_w"),
    "head": ("vocab", "fsdp_w"),
    "w1": ("fsdp_w", "ff"),
    "w3": ("fsdp_w", "ff"),
    "w2": ("ff", "fsdp_w"),
    "we1": ("expert", "fsdp_w", None),
    "we3": ("expert", "fsdp_w", None),
    "we2": ("expert", None, "fsdp_w"),
    "in_proj": ("fsdp_w", "ff"),
    "out_proj": ("ff", "fsdp_w"),
    "wq": ("fsdp_w", "heads", None),
    "wk": ("fsdp_w", "kv_heads", None),
    "wv": ("fsdp_w", "kv_heads", None),
    "wo": ("heads", None, "fsdp_w"),
    "c_wq": ("fsdp_w", "heads", None),
    "c_wk": ("fsdp_w", "kv_heads", None),
    "c_wv": ("fsdp_w", "kv_heads", None),
    "c_wo": ("heads", None, "fsdp_w"),
}
# cross-attn weights in train layout
for _k in ("wq", "wk", "wv", "wo", "bq", "bk", "bv"):
    _TRAIN_MAP["c_" + _k] = _TRAIN_MAP[_k]


def _leaf_key(path) -> str:
    """Last dict key on the path; QTensor fields resolve to the parent weight
    name ('q' carries the weight's spec; 'scale' is replicated)."""
    last = None
    attr = None
    for p in path:
        if hasattr(p, "key"):
            last = str(p.key)
            attr = None
        elif hasattr(p, "name"):
            attr = str(p.name)
    if attr == "scale":
        return "__scale__"
    return last or "root"


def tree_pspecs(tree: Any, rules: ShardingRules, mode: str = "train") -> Any:
    """PartitionSpec pytree matching ``tree`` (params or optimizer state)."""
    table = _TRAIN_MAP if mode == "train" else _SERVE_MAP

    def one(path, leaf):
        key = _leaf_key(path)
        logical = table.get(key)
        if logical is None or len(logical) != len(leaf.shape):
            # norms / scalars / unknown: shard nothing
            return P()
        return spec_for(rules, leaf.shape, logical)

    return jax.tree_util.tree_map_with_path(one, tree)


def tree_shardings(tree: Any, rules: ShardingRules, mode: str = "train") -> Any:
    return jax.tree.map(lambda s: NamedSharding(rules.mesh, s),
                        tree_pspecs(tree, rules, mode))
