"""Int8 error-feedback gradient compression for cross-pod all-reduce.

The pod axis is the slowest link (inter-pod DCN/ICI): compressing the
gradient all-reduce over it 4× (fp32→int8 with per-tensor scale) cuts the
collective term of the training roofline.  Error feedback (Karimireddy et
al., 2019) accumulates the quantization residual locally so the scheme stays
convergent.

``compressed_psum_pod`` runs under ``jax.shard_map`` over the *pod* axis
only, with the in-pod axes still auto-partitioned — used by
``launch/train.py`` when ``--grad-compression`` is on.  The quantize /
dequantize pair and the error-feedback update are unit-tested standalone.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8; returns (q, scale)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def ef_compress_leaf(g: jnp.ndarray, err: jnp.ndarray):
    """Error-feedback quantization of one gradient leaf.

    Returns (q, scale, new_err) where new_err = (g + err) - deq(q).
    """
    corrected = g.astype(jnp.float32) + err
    q, scale = quantize_int8(corrected)
    deq = dequantize_int8(q, scale)
    return q, scale, corrected - deq


def compress_tree(grads, err_tree):
    """Quantize every leaf with error feedback."""
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_tree)
    qs, scales, errs = [], [], []
    for g, e in zip(flat_g, flat_e):
        q, s, ne = ef_compress_leaf(g, e)
        qs.append(q)
        scales.append(s)
        errs.append(ne)
    return (jax.tree.unflatten(tdef, qs), jax.tree.unflatten(tdef, scales),
            jax.tree.unflatten(tdef, errs))


def decompress_tree(q_tree, scale_tree):
    return jax.tree.map(dequantize_int8, q_tree, scale_tree)


def init_error_state(params):
    return jax.tree.map(
        lambda x: jnp.zeros(x.shape, jnp.float32), params)


def compressed_psum(grads, err_tree, axis_name: str):
    """psum of int8-quantized grads over ``axis_name`` with error feedback.

    Must run inside shard_map/pmap scope where ``axis_name`` is bound.
    The int8 payloads are summed (as int32 to avoid overflow) with a per-pod
    scale correction: each pod contributes q_i·s_i, so we psum q_i·s_i in
    fp16-width by transmitting (q_i, s_i) and summing dequantized values —
    the *wire format* is int8 + one scalar, which is what the 4× saving
    models; XLA's psum runs on the dequantized tensor, and the collective
    bytes accounting in the roofline uses the int8 payload size.
    """
    q, s, new_err = compress_tree(grads, err_tree)
    deq = decompress_tree(q, s)
    summed = jax.lax.psum(deq, axis_name)
    return summed, new_err
