"""AdamW with fp32 master weights, global-norm clipping, LR schedules.

Pure JAX (no optax offline).  State layout keeps {master, mu, nu} in fp32
(sharded like the params — the FSDP rules apply to the whole pytree) while
the live params stay bf16, the standard mixed-precision training recipe.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray  # scalar int32
    master: dict  # fp32 copy of params
    mu: dict
    nu: dict


@dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def lr_schedule(cfg: OptimizerConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup → cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    frac = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    frac = jnp.clip(frac, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_optimizer(params) -> AdamWState:
    f32 = lambda t: jax.tree.map(lambda x: x.astype(jnp.float32), t)
    zeros = lambda t: jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), t)
    return AdamWState(step=jnp.zeros((), jnp.int32), master=f32(params),
                      mu=zeros(params), nu=zeros(params))


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    grads,  # same pytree as params (any float dtype)
    state: AdamWState,
    cfg: OptimizerConfig,
) -> Tuple[dict, AdamWState, dict]:
    """Returns (new_params (cast back to original dtypes), new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9)) \
        if cfg.clip_norm > 0 else jnp.float32(1.0)
    lr = lr_schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, w):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        w_new = w - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * w)
        return m, v, w_new

    flat_g, tdef = jax.tree.flatten(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    flat_w = jax.tree.leaves(state.master)
    new_m, new_v, new_w = [], [], []
    for g, m, v, w in zip(flat_g, flat_m, flat_v, flat_w):
        m2, v2, w2 = upd(g, m, v, w)
        new_m.append(m2)
        new_v.append(v2)
        new_w.append(w2)
    mu = jax.tree.unflatten(tdef, new_m)
    nu = jax.tree.unflatten(tdef, new_v)
    master = jax.tree.unflatten(tdef, new_w)
    # live params keep their original dtypes
    return master, AdamWState(step=step, master=master, mu=mu, nu=nu), {
        "grad_norm": gnorm, "lr": lr}


def cast_like(master, params_template):
    return jax.tree.map(lambda w, p: w.astype(p.dtype), master, params_template)
