"""Fault tolerance & straggler mitigation (DESIGN.md §6).

- ``TrainingSupervisor`` — wraps the step loop: periodic async checkpoints,
  restore-on-start, preemption-signal-safe final snapshot, deterministic
  data replay (the pipeline is a pure function of step).
- ``StragglerDetector`` — per-shard step-time EMA; a shard whose EMA exceeds
  ``threshold ×`` the median is flagged; the registered callback receives
  per-shard speed factors.  The serving runtime plugs
  ``core.planner.replan_for_stragglers`` in here: the FairKV planner
  generalizes Eq. 4's makespan to heterogeneous shard speeds, so a slow shard
  simply receives proportionally fewer retained-KV tokens.  This closes the
  loop between the paper's load balancing and cluster-level health.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import numpy as np

from repro.training.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint


@dataclass
class StragglerDetector:
    n_shards: int
    ema_alpha: float = 0.2
    threshold: float = 1.3  # flag shards slower than 1.3x the median
    min_samples: int = 5
    _ema: Optional[np.ndarray] = None
    _count: int = 0

    def observe(self, per_shard_times: np.ndarray) -> Optional[np.ndarray]:
        """Feed one step's per-shard wall times; returns speed factors when a
        straggler is detected (else None)."""
        t = np.asarray(per_shard_times, dtype=np.float64)
        if self._ema is None:
            self._ema = t.copy()
        else:
            self._ema = (1 - self.ema_alpha) * self._ema + self.ema_alpha * t
        self._count += 1
        if self._count < self.min_samples:
            return None
        med = np.median(self._ema)
        if med <= 0:
            return None
        ratio = self._ema / med
        if (ratio > self.threshold).any():
            # speed factor = med/ema (slow shard < 1) — feeds the planner
            return np.clip(med / self._ema, 0.1, 1.0)
        return None


@dataclass
class SupervisorConfig:
    checkpoint_dir: str = "/tmp/repro_ckpt"
    checkpoint_every: int = 100
    keep: int = 3


class TrainingSupervisor:
    """Step-loop harness with restore/checkpoint/straggler hooks."""

    def __init__(self, cfg: SupervisorConfig, n_shards: int = 1,
                 on_straggler: Optional[Callable[[np.ndarray], None]] = None):
        self.cfg = cfg
        self.ckpt = AsyncCheckpointer(cfg.checkpoint_dir, cfg.keep)
        self.detector = StragglerDetector(n_shards)
        self.on_straggler = on_straggler

    def restore_or_init(self, init_state):
        """Resume from the newest committed checkpoint if one exists."""
        step = latest_step(self.cfg.checkpoint_dir)
        if step is None:
            return 0, init_state
        state = restore_checkpoint(self.cfg.checkpoint_dir, step, init_state)
        return step, state

    def run(self, state, step_fn, get_batch, n_steps: int,
            start_step: int = 0, per_shard_times_fn=None):
        """Run steps [start_step, n_steps); returns final (step, state).

        ``step_fn(state, batch) -> (state, metrics)`` must be pure so the
        deterministic ``get_batch(step)`` replay makes restarts bit-exact.
        """
        metrics = None
        for step in range(start_step, n_steps):
            batch = get_batch(step)
            state, metrics = step_fn(state, batch)
            if per_shard_times_fn is not None:
                speeds = self.detector.observe(per_shard_times_fn())
                if speeds is not None and self.on_straggler is not None:
                    self.on_straggler(speeds)
            if (step + 1) % self.cfg.checkpoint_every == 0:
                self.ckpt.save(step + 1, state)
        self.ckpt.wait()
        return n_steps, state, metrics

    def emergency_save(self, step: int, state) -> None:
        """Preemption hook: synchronous final snapshot."""
        self.ckpt.wait()
        from repro.training.checkpoint import save_checkpoint
        save_checkpoint(self.cfg.checkpoint_dir, step, state, self.cfg.keep)
