"""Deterministic synthetic data pipeline.

Restart/elasticity contract: ``get_batch(step)`` is a pure function of
(seed, step, shapes) — after a failure the resumed job replays the identical
batch stream regardless of host count or mesh shape, which is what makes the
checkpoint/restart test bit-exact (DESIGN.md §6).

Tokens follow a Zipf-ish marginal with short-range repetition structure so
attention has non-trivial statistics (compression policies see realistic
score skew during serving tests).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import InputShape, ModelConfig


@dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    zipf_alpha: float = 1.2
    repeat_prob: float = 0.2  # probability a token repeats one from a window
    repeat_window: int = 64


def _zipf_logits(vocab: int, alpha: float) -> np.ndarray:
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = ranks ** (-alpha)
    return np.log(p / p.sum())


class SyntheticLM:
    """Deterministic synthetic LM batches for an (arch × shape) cell."""

    def __init__(self, cfg: ModelConfig, shape: InputShape,
                 data_cfg: Optional[DataConfig] = None):
        self.cfg = cfg
        self.shape = shape
        self.dc = data_cfg or DataConfig()
        self._logits = jnp.asarray(
            _zipf_logits(cfg.vocab_size, self.dc.zipf_alpha), jnp.float32)

    def text_len(self) -> int:
        s = self.shape.seq_len
        if self.cfg.is_vlm:
            s = max(1, s - self.cfg.num_image_tokens)
        return s

    def get_batch(self, step: int) -> Dict[str, jnp.ndarray]:
        key = jax.random.fold_in(jax.random.PRNGKey(self.dc.seed), step)
        kt, kr, kw, kf, ki = jax.random.split(key, 5)
        B, S = self.shape.global_batch, self.text_len()
        base = jax.random.categorical(kt, self._logits, shape=(B, S))
        # inject short-range repeats (structure for attention stats)
        rep = jax.random.uniform(kr, (B, S)) < self.dc.repeat_prob
        off = jax.random.randint(kw, (B, S), 1, self.dc.repeat_window + 1)
        src = jnp.maximum(jnp.arange(S)[None, :] - off, 0)
        tokens = jnp.where(rep, jnp.take_along_axis(base, src, axis=1), base)
        batch: Dict[str, jnp.ndarray] = {"tokens": tokens.astype(jnp.int32)}
        if self.cfg.is_vlm:
            batch["image_embeds"] = 0.02 * jax.random.normal(
                ki, (B, self.cfg.num_image_tokens, self.cfg.d_model),
                jnp.bfloat16)
        if self.cfg.is_encoder_decoder:
            batch["frames"] = 0.02 * jax.random.normal(
                kf, (B, self.cfg.encoder_seq_len, self.cfg.d_model),
                jnp.bfloat16)
        return batch
