"""Training substrate: optimizer, step, data, checkpointing, resilience."""
from repro.training.checkpoint import (  # noqa: F401
    AsyncCheckpointer,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.training.data import DataConfig, SyntheticLM  # noqa: F401
from repro.training.optimizer import (  # noqa: F401
    AdamWState,
    OptimizerConfig,
    adamw_update,
    init_optimizer,
    lr_schedule,
)
from repro.training.resilience import (  # noqa: F401
    StragglerDetector,
    SupervisorConfig,
    TrainingSupervisor,
)
from repro.training.train_loop import (  # noqa: F401
    cross_entropy,
    loss_fn,
    make_train_step,
    train_step,
)
