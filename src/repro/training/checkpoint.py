"""Sharded, mesh-reshapeable checkpointing with atomic commit.

Layout on disk:
    <dir>/step_<N>.tmp/         (written)
    <dir>/step_<N>/             (atomically renamed on commit)
        manifest.json           step, leaf paths, shapes, dtypes
        <leaf>.npy              one file per pytree leaf (full array)

Restore never assumes the saving mesh: leaves are placed with the *target*
shardings, so a 256-chip checkpoint restores onto 512 chips (elastic
scaling) — the logical-axis rules recompute the physical layout.

Multi-host note: on a real cluster each leaf is fetched with
``jax.experimental.multihost_utils.process_allgather``-style collection and
only process 0 writes (the standard single-writer pattern); this container is
single-process so ``jax.device_get`` covers it.  The API keeps the
process-index check so the code is cluster-correct as written.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _leaf_name(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "__".join(parts) or "root"


def save_checkpoint(directory: str, step: int, tree: Any,
                    keep: int = 3) -> str:
    """Write a checkpoint; atomic rename commit; prune to ``keep`` newest."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(tree)
    manifest = {"step": step, "leaves": []}
    if jax.process_index() == 0:
        for path, leaf in leaves_with_paths:
            name = _leaf_name(path)
            arr = np.asarray(jax.device_get(leaf))
            np.save(os.path.join(tmp, name + ".npy"), arr)
            manifest["leaves"].append(
                {"name": name, "shape": list(arr.shape), "dtype": str(arr.dtype)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _prune(directory, keep)
    return final


def _prune(directory: str, keep: int) -> None:
    ckpts = sorted(
        d for d in os.listdir(directory)
        if re.fullmatch(r"step_\d{8}", d))
    for d in ckpts[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, d))


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if re.fullmatch(r"step_\d{8}", d)]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, target: Any,
                       shardings: Any = None) -> Any:
    """Restore into the structure of ``target``; place with ``shardings``
    (same pytree prefix) when given — this is the elastic-resharding path."""
    src = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(src, "manifest.json")) as f:
        manifest = json.load(f)
    by_name = {m["name"]: m for m in manifest["leaves"]}
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(target)
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(leaves_with_paths))
    out = []
    for (path, leaf), shd in zip(leaves_with_paths, shard_leaves):
        name = _leaf_name(path)
        if name not in by_name:
            raise KeyError(f"checkpoint missing leaf {name}")
        arr = np.load(os.path.join(src, name + ".npy"))
        if list(arr.shape) != list(leaf.shape):
            raise ValueError(
                f"leaf {name}: checkpoint shape {arr.shape} != target {leaf.shape}")
        arr = arr.astype(np.dtype(jnp.dtype(leaf.dtype).name))
        out.append(jax.device_put(arr, shd) if shd is not None
                   else jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


class AsyncCheckpointer:
    """Snapshot-then-write-in-background (bounded to one in-flight save)."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def save(self, step: int, tree: Any) -> None:
        self.wait()
        snapshot = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self._thread = threading.Thread(
            target=save_checkpoint,
            args=(self.directory, step, snapshot, self.keep), daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
