"""Training step: loss, grads, AdamW — pure function for pjit.

Loss is next-token cross-entropy (+ MoE aux).  Logit softcap (gemma2) is
inside the model.  The step is written params-functional so XLA can donate
buffers: (params, opt_state, batch) → (params, opt_state, metrics).
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import forward_train
from repro.training.optimizer import (
    AdamWState,
    OptimizerConfig,
    adamw_update,
    cast_like,
)


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean next-token xent; logits (B, S, V) fp32, labels (B, S)."""
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return (logz - gold).mean()


def loss_fn(params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig,
            remat: bool = True):
    logits, aux = forward_train(params, batch, cfg, remat=remat)
    tokens = batch["tokens"]
    xent = cross_entropy(logits[:, :-1], tokens[:, 1:])
    total = xent + cfg.moe.router_aux_coef * aux
    return total, {"xent": xent, "aux": aux}


def train_step(
    params,
    opt_state: AdamWState,
    batch: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
    ocfg: OptimizerConfig,
    remat: bool = True,
) -> Tuple[dict, AdamWState, Dict[str, jnp.ndarray]]:
    (loss, parts), grads = jax.value_and_grad(
        loss_fn, has_aux=True)(params, batch, cfg, remat)
    master, opt_state, opt_metrics = adamw_update(grads, opt_state, ocfg)
    new_params = cast_like(master, params)
    metrics = {"loss": loss, **parts, **opt_metrics}
    return new_params, opt_state, metrics


def make_train_step(cfg: ModelConfig, ocfg: OptimizerConfig, remat: bool = True):
    return partial(train_step, cfg=cfg, ocfg=ocfg, remat=remat)
