"""Asyncio HTTP front end (DESIGN.md §13) — stdlib only.

A deliberately small HTTP/1.1 server over ``asyncio.start_server`` (no
web-framework dependency; the repo's environment pins against new
packages), serving four routes:

- ``POST /v1/generate`` — JSON in, JSON out (blocks until the request is
  terminal);
- ``POST /v1/stream``   — Server-Sent Events: one ``token`` event per
  generated token (the `StreamEvent` fields), then one ``end`` event;
- ``GET /metrics``      — Prometheus text from the engine's §12 registry
  (per-tenant goodput/latency families included);
- ``GET /healthz``      — liveness + draining state.

Request body for the generate/stream routes::

    {"prompt": [1, 2, 3],          # token ids (models are token-level)
     "max_new_tokens": 16,         # optional
     "eos_id": null,               # optional
     "tenant": "acme",             # optional (default "default")
     "priority": 1,                # optional class index (0 most urgent)
     "deadline_s": 2.5}            # optional wall-clock budget

Every handler is a thin adapter over `EngineLoop`: submissions land on the
engine thread's inbox, progress comes back through an `asyncio.Queue` fed
via ``loop.call_soon_threadsafe`` — the event loop never blocks on the
engine (even ``/metrics`` rendering runs through an executor, since it
waits for the engine thread to service the ask between decode ticks).
A client disconnect mid-stream cancels the request, releasing its batch
row and pool blocks immediately.
"""
from __future__ import annotations

import asyncio
import json
from typing import Optional, Tuple

from repro.frontend.bridge import EngineLoop
from repro.frontend.config import FrontendConfig

_MAX_BODY = 8 * 1024 * 1024
# terminal reasons → HTTP status for the non-streaming route
_REJECT_STATUS = {
    "draining": 503,
    "tenant_backlog_full": 429,
    "engine_full": 429,
    "slo_blown": 429,
    "deadline_exceeded": 429,
    "cancelled": 499,  # nginx's client-closed-request; best available fit
}


def _status_line(code: int) -> str:
    names = {200: "OK", 400: "Bad Request", 404: "Not Found",
             405: "Method Not Allowed", 408: "Request Timeout",
             413: "Payload Too Large", 422: "Unprocessable Entity",
             429: "Too Many Requests", 499: "Client Closed Request",
             500: "Internal Server Error", 503: "Service Unavailable"}
    return f"HTTP/1.1 {code} {names.get(code, 'Unknown')}\r\n"


class FrontendServer:
    """One engine behind one listening socket; see module docstring."""

    def __init__(self, engine, cfg: Optional[FrontendConfig] = None):
        self.engine_loop = EngineLoop(engine, cfg)
        self.cfg = self.engine_loop.cfg
        self._server: Optional[asyncio.AbstractServer] = None
        self.host = self.cfg.host
        self.port = self.cfg.port  # rebound to the real port on start

    # ---- lifecycle ---------------------------------------------------------

    async def start(self) -> None:
        self.engine_loop.start()
        self._server = await asyncio.start_server(
            self._handle, self.cfg.host, self.cfg.port)
        sock = self._server.sockets[0]
        self.host, self.port = sock.getsockname()[:2]

    async def serve_forever(self) -> None:
        assert self._server is not None, "call start() first"
        async with self._server:
            await self._server.serve_forever()

    async def shutdown(self, drain: bool = True) -> None:
        """Graceful stop: close the listener, drain the engine (finish live
        decodes, shed the queue), stop the loop thread."""
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
        if drain:
            loop = asyncio.get_running_loop()
            await loop.run_in_executor(
                None, self.engine_loop.drain, self.cfg.drain_timeout_s)
        self.engine_loop.stop()

    # ---- HTTP plumbing -----------------------------------------------------

    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        try:
            parsed = await self._read_request(reader)
            if parsed is None:
                return
            method, path, body = parsed
            await self._route(method, path, body, writer)
        except (ConnectionError, asyncio.IncompleteReadError):
            pass  # client went away; request-level cancel already handled
        except Exception as e:  # a handler bug must not kill the server
            try:
                self._send_json(writer, 500, {"error": repr(e)})
            except ConnectionError:
                pass
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except ConnectionError:
                pass

    async def _read_request(
            self, reader: asyncio.StreamReader
    ) -> Optional[Tuple[str, str, bytes]]:
        line = await reader.readline()
        if not line:
            return None
        parts = line.decode("latin-1").split()
        if len(parts) < 2:
            return None
        method, path = parts[0].upper(), parts[1]
        headers = {}
        while True:
            h = await reader.readline()
            if h in (b"\r\n", b"\n", b""):
                break
            k, _, v = h.decode("latin-1").partition(":")
            headers[k.strip().lower()] = v.strip()
        n = int(headers.get("content-length", "0") or 0)
        if n > _MAX_BODY:
            raise ValueError("body too large")
        body = await reader.readexactly(n) if n else b""
        return method, path, body

    def _send(self, writer: asyncio.StreamWriter, code: int, body: bytes,
              ctype: str) -> None:
        writer.write(
            (_status_line(code)
             + f"Content-Type: {ctype}\r\n"
             + f"Content-Length: {len(body)}\r\n"
             + "Connection: close\r\n\r\n").encode("latin-1") + body)

    def _send_json(self, writer, code: int, payload: dict) -> None:
        self._send(writer, code, json.dumps(payload).encode(),
                   "application/json")

    # ---- routing -----------------------------------------------------------

    async def _route(self, method: str, path: str, body: bytes,
                     writer: asyncio.StreamWriter) -> None:
        if path == "/healthz" and method == "GET":
            err = self.engine_loop.error
            self._send_json(writer, 200 if err is None else 500, {
                "status": ("error" if err is not None else
                           "draining" if self.engine_loop.draining else "ok"),
                "error": repr(err) if err is not None else None})
        elif path == "/metrics" and method == "GET":
            loop = asyncio.get_running_loop()
            text = await loop.run_in_executor(
                None, self.engine_loop.prometheus)
            self._send(writer, 200, text.encode(),
                       "text/plain; version=0.0.4")
        elif path == "/v1/generate" and method == "POST":
            await self._generate(body, writer, stream=False)
        elif path == "/v1/stream" and method == "POST":
            await self._generate(body, writer, stream=True)
        elif path in ("/healthz", "/metrics", "/v1/generate", "/v1/stream"):
            self._send_json(writer, 405, {"error": f"{method} not allowed"})
        else:
            self._send_json(writer, 404, {"error": f"no route {path}"})

    # ---- generate / stream -------------------------------------------------

    def _parse_generate(self, body: bytes) -> dict:
        try:
            payload = json.loads(body.decode() or "{}")
        except (json.JSONDecodeError, UnicodeDecodeError) as e:
            raise ValueError(f"invalid JSON body: {e}") from e
        prompt = payload.get("prompt")
        if (not isinstance(prompt, list) or not prompt
                or not all(isinstance(t, int) and t >= 0 for t in prompt)):
            raise ValueError(
                "'prompt' must be a non-empty list of token ids")
        if self.cfg.max_prompt_tokens and (
                len(prompt) > self.cfg.max_prompt_tokens):
            raise ValueError(
                f"prompt too long ({len(prompt)} tokens > "
                f"{self.cfg.max_prompt_tokens})")
        mnt = int(payload.get("max_new_tokens", 16))
        if mnt < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if self.cfg.max_new_tokens_cap:
            mnt = min(mnt, self.cfg.max_new_tokens_cap)
        eos = payload.get("eos_id")
        deadline = payload.get("deadline_s")
        return {
            "prompt": prompt, "max_new_tokens": mnt,
            "eos_id": None if eos is None else int(eos),
            "tenant": str(payload.get("tenant", "default")) or "default",
            "priority": int(payload.get("priority", 1)),
            "deadline_s": None if deadline is None else float(deadline)}

    async def _generate(self, body: bytes, writer: asyncio.StreamWriter,
                        stream: bool) -> None:
        try:
            kw = self._parse_generate(body)
        except ValueError as e:
            self._send_json(writer, 400, {"error": str(e)})
            return
        if self.engine_loop.draining:
            self._send_json(writer, 503, {"error": "draining"})
            return
        loop = asyncio.get_running_loop()
        events: "asyncio.Queue[dict]" = asyncio.Queue()
        req = self.engine_loop.submit(
            kw.pop("prompt"), **kw,
            deliver=lambda ev: loop.call_soon_threadsafe(
                events.put_nowait, ev))
        try:
            if stream:
                await self._pump_sse(req, events, writer)
            else:
                await self._pump_json(req, events, writer)
        except (asyncio.CancelledError, ConnectionError):
            # client went away mid-request: release the row/blocks now
            self.engine_loop.cancel(req.req_id)
            raise

    async def _pump_json(self, req, events: "asyncio.Queue",
                         writer: asyncio.StreamWriter) -> None:
        while True:
            ev = await events.get()
            if ev["type"] != "end":
                continue
            if ev["state"] == "finished":
                code = 200
            elif ev["state"] == "error":
                code = 500
            else:
                code = _REJECT_STATUS.get(ev["reason"], 422)
            self._send_json(writer, code, {
                "req_id": ev["req_id"], "state": ev["state"],
                "reason": ev["reason"], "tokens": ev["tokens"],
                "n_generated": ev["n_generated"],
                "degraded_from": ev["degraded_from"],
                "tenant": req.tenant, "priority": req.priority})
            return

    async def _pump_sse(self, req, events: "asyncio.Queue",
                        writer: asyncio.StreamWriter) -> None:
        writer.write(
            (_status_line(200)
             + "Content-Type: text/event-stream\r\n"
             + "Cache-Control: no-cache\r\n"
             + "Connection: close\r\n\r\n").encode("latin-1"))
        await writer.drain()
        while True:
            ev = await events.get()
            writer.write(
                (f"event: {ev['type']}\n"
                 f"data: {json.dumps(ev)}\n\n").encode())
            # drain() surfaces a torn connection so the except-path in
            # _generate cancels the request instead of decoding to a ghost
            await writer.drain()
            if ev["type"] == "end":
                return


async def serve_http(engine, cfg: Optional[FrontendConfig] = None,
                     install_signals: bool = True) -> FrontendServer:
    """Start the server (returned running; caller owns `serve_forever` /
    `shutdown`).  With ``install_signals``, SIGINT/SIGTERM trigger a
    graceful drain-and-stop instead of killing mid-decode."""
    server = FrontendServer(engine, cfg)
    await server.start()
    if install_signals:
        import signal

        loop = asyncio.get_running_loop()

        def _graceful() -> None:
            asyncio.ensure_future(server.shutdown(drain=True))

        for sig in (signal.SIGINT, signal.SIGTERM):
            try:
                loop.add_signal_handler(sig, _graceful)
            except (NotImplementedError, RuntimeError):
                pass  # platform without loop signal support
    return server
