"""Frontend core: fair queuing + admission + accounting around one
`Scheduler` (DESIGN.md §13).

`FrontendScheduler` is the synchronous heart of the serving front end — the
HTTP layer (`repro.frontend.http`) and the engine loop thread
(`repro.frontend.bridge`) are adapters over it, and the fig10 goodput
bench drives it directly with a synthetic trace (`run_frontend_trace`).

Per `pump()` tick, in order:

1. **fair queuing** — one DRR round over the per-tenant queues; every
   request the round surfaces is *offered* to the admission controller;
2. **admission** — the controller's verdict maps onto the queue protocol:
   admit/degrade → `Scheduler.submit` (charging the tenant's deficit),
   reject → terminal CANCELLED without ever touching the engine,
   queue → stays queued (optionally arming one lower-priority preemption);
3. **engine tick** — `Scheduler.step()` (prefill-admit, decode, retire);
4. **accounting** — newly retired requests are judged for SLO attainment
   and goodput, per-tenant queue-depth/deficit gauges are refreshed.

The frontend only hands the engine what it has row capacity for *now*
(``free rows − engine-owned requeues``), so the engine's own FCFS queue
stays empty except for preemption victims — all waiting happens in the
tenant-fair queues where priority and quota policy apply.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.frontend import admission as adm
from repro.frontend import queues as q
from repro.frontend.accounting import TenantAccounting
from repro.frontend.config import FrontendConfig
from repro.serving.request import Request, RequestState
from repro.serving.scheduler import Scheduler


class FrontendScheduler:
    """Multi-tenant ingress for one engine `Scheduler` (single-threaded:
    the caller's loop owns every method here)."""

    def __init__(self, sched: Scheduler, cfg: Optional[FrontendConfig] = None):
        self.sched = sched
        self.cfg = cfg if cfg is not None else FrontendConfig()
        self.obs = sched.obs
        self.controller = adm.make_admission(self.cfg)
        prios = [c.priority for c in self.cfg.classes]
        self._prio_lo, self._prio_hi = min(prios), max(prios)
        if self.cfg.admission == "slo":
            # FrontendConfig's quota knobs are denominated in *request*
            # tokens (prompt + generation), but the DRR charges the
            # backend's request_cost — L·H-scaled projected tokens (slot)
            # or blocks (paged).  Calibrate the quantum/cap into backend
            # units so a "512-token" quantum means 512 request tokens on
            # any model geometry; without this, every request on a
            # many-layer model outprices the cap and could never be
            # admitted (the DRR's saturation path still guarantees
            # liveness, but fairness would degenerate).
            unit = self._cost_unit()
            self.queue = q.DeficitRoundRobin(
                max(1, round(self.cfg.quantum_tokens * unit)),
                max(1, round(self.cfg.quota_cap_tokens * unit)),
                self.cfg.max_queue_per_tenant)
        else:  # fcfs baseline: one global queue, tenant- and quota-blind
            self.queue = q.SingleQueue()
        self.accounting = TenantAccounting(self.cfg, self.obs.metrics)
        self.draining = False
        # terminal requests the engine never saw (rejected / shed at the
        # frontend) plus engine-finished ones, in completion order
        self.finished: List[Request] = []
        self.reject_reasons: Dict[int, str] = {}
        self._engine_seen = 0  # high-water mark into sched.finished
        self._seen_tenants: set = set()
        # optional terminal-event callback (the async bridge wires this to
        # wake waiting HTTP handlers); called with each newly terminal req
        self.on_terminal: Optional[Callable[[Request], None]] = None

    def _cost_unit(self) -> float:
        """Backend cost units per request token, measured with a canonical
        64-token probe against the live backend's projection machinery."""
        probe = Request(req_id=-1, prompt=np.zeros(32, np.int32),
                        max_new_tokens=32)
        return max(1.0, float(self.sched.backend.request_cost(probe))) / 64.0

    # ---- ingress -----------------------------------------------------------

    def submit(self, req: Request) -> bool:
        """Enqueue into the tenant's fair queue.  False = refused outright
        (draining, or the tenant's backlog bound is hit) — the request is
        terminal immediately with a recorded reason."""
        if req.arrival_time is None:
            req.arrival_time = time.time()
        req.arrival_step = self.sched.step_idx
        # clamp client-supplied priority to the configured ladder: an
        # out-of-range value (e.g. a negative one) would otherwise outrank
        # every configured class in the scheduler's queue pick and arm
        # preemption against all of them
        req.priority = max(self._prio_lo, min(self._prio_hi, req.priority))
        self._seen_tenants.add(req.tenant)
        if self.draining:
            self._reject(req, "draining")
            return False
        if not self.queue.push(req.tenant, req):
            self.accounting.on_decision(req.tenant, "reject")
            self._reject(req, "tenant_backlog_full")
            return False
        return True

    def cancel(self, req_id: int) -> bool:
        """Cancel wherever the request lives: still tenant-queued (remove,
        terminal here) or already inside the engine (row/blocks released
        by `Scheduler.cancel`)."""
        for tenant in list(self._seen_tenants):
            for req in self.queue.items(tenant):
                if req.req_id == req_id:
                    self.queue.remove(tenant, req)
                    self._reject(req, "cancelled")
                    return True
        if self.sched.cancel(req_id):
            self._collect_engine_finished()
            return True
        return False

    def drain(self) -> None:
        """Graceful shutdown: refuse new ingress, shed every queued (not
        yet admitted) request, let the engine decode its live rows out.
        `pump()` keeps working until `idle`."""
        self.draining = True
        for tenant in list(self._seen_tenants):
            for req in self.queue.items(tenant):
                self.queue.remove(tenant, req)
                self._reject(req, "draining")
        self.sched.drain()

    @property
    def idle(self) -> bool:
        return (len(self.queue) == 0 and not self.sched.active
                and not self.sched.queue)

    # ---- terminal bookkeeping ----------------------------------------------

    def _reject(self, req: Request, reason: str) -> None:
        req.state = RequestState.CANCELLED
        req.finish_step = self.sched.step_idx
        req.finish_time = time.time()
        self.reject_reasons[req.req_id] = reason
        self.finished.append(req)
        self.accounting.on_finished(req)
        self.obs.metrics.counter(
            "frontend_rejections_total",
            help="requests refused or shed by the frontend, by reason"
        ).inc(tenant=req.tenant, reason=reason)
        if self.on_terminal is not None:
            self.on_terminal(req)

    def _collect_engine_finished(self) -> None:
        new = self.sched.finished[self._engine_seen:]
        self._engine_seen = len(self.sched.finished)
        for req in new:
            self.finished.append(req)
            self.accounting.on_finished(req)
            if self.on_terminal is not None:
                self.on_terminal(req)

    # ---- the pump tick -----------------------------------------------------

    def pump(self) -> dict:
        """One frontend tick (see module docstring).  Returns the engine
        step events extended with the frontend's admission activity."""
        # requests handed to the engine THIS tick: they are in sched.queue
        # but not yet spliced, so the backend's admissible() cannot see
        # their charge — the controller gets them as ``pending`` so later
        # admissions this tick are checked against the joint budget, not
        # each against the same un-spliced state
        pending: List[Request] = []
        preempted_this_tick = False
        # rows the engine can fill this tick: free rows minus the requeues
        # it already owned at tick start (preemption victims re-admit
        # first).  Snapshot the backlog NOW — our own in-tick submissions
        # land in ``sched.queue`` too and are counted via ``pending``,
        # and a mid-tick preemption that frees a row must enlarge the room
        # for the urgent request that armed it, not for its victim.
        engine_backlog = len(self.sched.queue)

        def room() -> int:
            return len(self.sched.freelist) - engine_backlog - len(pending)

        def cost(req: Request) -> float:
            return float(self.sched.backend.request_cost(req))

        def offer(tenant: str, req: Request) -> str:
            nonlocal preempted_this_tick
            d = self.controller.decide(self.sched, req, pending)
            if (d.action == adm.QUEUE and d.preempt
                    and not preempted_this_tick
                    and self.sched.preempt_lower_priority(req.priority)):
                # the eviction freed a row for THIS request — re-decide so
                # it can take the opening this very tick (the engine's
                # priority-aware queue pick would otherwise hand the row
                # straight back to the victim at step()).  At most one
                # eviction per tick: one opening is one row; more is thrash.
                preempted_this_tick = True
                d = self.controller.decide(self.sched, req, pending)
            if (d.action in (adm.ADMIT, adm.DEGRADE) and room() <= 0):
                # controller sized against the backend, but every free row
                # is already spoken for this tick — wait, engine-full
                d = adm.Decision(adm.QUEUE, reason="engine_full",
                                 global_block=True)
            self.accounting.on_decision(tenant, d.action)
            if d.action == adm.REJECT:
                self._reject(req, d.reason)
                return q.REJECTED
            if d.action in (adm.ADMIT, adm.DEGRADE):
                if d.action == adm.DEGRADE:
                    if req.degraded_from is None:
                        req.degraded_from = req.max_new_tokens
                    req.max_new_tokens = int(d.degrade_to)
                self.sched.submit(req)
                pending.append(req)
                return q.ADMITTED
            return q.STALL if d.global_block else q.BLOCKED

        admitted = self.queue.tick(cost, offer)
        ev = self.sched.step()
        self._collect_engine_finished()
        for tenant in sorted(self._seen_tenants):
            self.accounting.on_queue_sample(
                tenant, self.queue.backlog(tenant),
                self.queue.deficit(tenant))
        ev["frontend_admitted"] = [(t, r.req_id) for t, r in admitted]
        ev["frontend_queued"] = len(self.queue)
        return ev

    # ---- programmatic summary ----------------------------------------------

    def summary(self) -> dict:
        att = self.accounting.attained.total()
        mis = self.accounting.missed.total()
        steps = max(1, self.sched.step_idx)
        goodput = self.accounting.goodput_tokens.total()
        return {
            "admission": self.controller.name,
            "steps": self.sched.step_idx,
            "finished": len(self.finished),
            "rejected": len(self.reject_reasons),
            "generated_tokens": self.accounting.tokens.total(),
            "goodput_tokens": goodput,
            "goodput_tokens_per_step": goodput / steps,
            "slo_attained": att,
            "slo_missed": mis,
            "slo_attainment": att / (att + mis) if att + mis else None,
            "preemptions": self.sched.n_preemptions,
            "tenants": self.accounting.summary(),
        }


def run_frontend_trace(fe: FrontendScheduler, requests: List[Request],
                       max_steps: int = 10_000) -> dict:
    """Drive a synthetic trace through the frontend synchronously (the
    fig10 harness and tests): submit by ``arrival_step``, pump until every
    request is terminal (engine-finished or frontend-rejected)."""
    pending = sorted(requests, key=lambda r: (r.arrival_step, r.req_id))
    n_total = len(pending)
    i = 0
    t0 = time.time()
    while len(fe.finished) < n_total and fe.sched.step_idx < max_steps:
        while (i < len(pending)
               and pending[i].arrival_step <= fe.sched.step_idx):
            fe.submit(pending[i])
            i += 1
        fe.pump()
    out = fe.summary()
    out["total"] = n_total
    out["wall_s"] = time.time() - t0
    out["converged"] = len(fe.finished) >= n_total
    return out
