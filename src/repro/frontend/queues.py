"""Per-tenant fair queuing: deficit round robin over token-budget quotas
(DESIGN.md §13).

Classic DRR (Shreedhar & Varghese) with the packet length replaced by the
cache backend's *projected request cost* in tokens — admission fairness is
therefore cost-aware: a tenant whose requests pin more projected KV (long
prompts, imbalanced per-head budgets) drains its quota proportionally
faster than one sending cheap requests, even at equal request counts.

Mechanics per `tick`:

- every backlogged tenant banks ``quantum`` tokens of deficit (clamped to
  ``cap`` — an idle-then-bursting tenant cannot hoard unbounded credit);
- tenants are visited in round-robin order starting after the last tenant
  served first in the previous tick (no positional bias);
- a tenant admits requests from its FIFO head while its deficit covers the
  head's cost (clamped to ``cap`` — see oversized items below); each
  admission charges the deficit by the cost, clamped to the banked amount;
- a tenant whose queue empties forfeits its remaining deficit (classic DRR
  — credit only banks while backlogged).

Starvation-freedom (property-tested): while a tenant stays backlogged its
deficit grows by ``quantum`` per tick and is never charged except by its
own admissions, so any head request with cost ≤ ``cap`` becomes admissible
within ``ceil(cost / quantum)`` ticks; the visit order guarantees the
tenant is offered the admission attempt each tick.  An *oversized* head
request (cost > ``cap``) can never be covered by banked deficit, so the
quota gate saturates instead of starving: once the tenant's deficit
reaches ``cap`` — the maximum wait any request can be charged,
``ceil(cap / quantum)`` ticks — the item is offered anyway and, if
admitted, charged the entire banked deficit.  Every queued item therefore
reaches the admission controller (which may admit, reject, or shed it) in
bounded ticks; nothing is silently head-of-line blocked forever.  Token
conservation (also property-tested): for every tenant,
``deficit == refilled - charged - forfeited`` exactly, and the deficit is
always within ``[0, cap]``.

The structure is engine-agnostic and synchronous — the decision of *what
happens* to an offered request (admit / reject / leave queued / stop the
tick) is delegated to a callback, so the same queue drives the admission
controller, the property tests, and the goodput bench.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

# callback verdicts for one offered head request
ADMITTED = "admitted"  # dequeue + charge the tenant's deficit
REJECTED = "rejected"  # dequeue without charging (no capacity consumed)
BLOCKED = "blocked"  # leave queued, move on to the next tenant
STALL = "stall"  # leave queued, stop the whole tick (engine full)


@dataclass
class _Tenant:
    queue: deque = field(default_factory=deque)
    deficit: float = 0.0
    refilled: float = 0.0  # Σ quantum actually banked (post-clamp)
    charged: float = 0.0  # Σ admitted costs
    forfeited: float = 0.0  # Σ deficit dropped on queue-empty


class DeficitRoundRobin:
    """Cost-aware fair queue over per-tenant FIFOs; see module docstring."""

    def __init__(self, quantum: int, cap: int,
                 max_queue_per_tenant: int = 0):
        if quantum < 1:
            raise ValueError(f"quantum must be >= 1, got {quantum}")
        if cap < quantum:
            raise ValueError(f"cap ({cap}) must be >= quantum ({quantum})")
        self.quantum = float(quantum)
        self.cap = float(cap)
        self.max_queue_per_tenant = int(max_queue_per_tenant)
        self._tenants: Dict[str, _Tenant] = {}
        self._order: List[str] = []  # visit order (insertion, rotated)

    # ---- enqueue -----------------------------------------------------------

    def push(self, tenant: str, item) -> bool:
        """FIFO-append ``item`` to ``tenant``'s queue.  Returns False (and
        drops the item) when the tenant's backlog bound is hit — the
        caller's overload rejection, not a silent tail drop."""
        t = self._tenants.get(tenant)
        if t is None:
            t = self._tenants[tenant] = _Tenant()
            self._order.append(tenant)
        if (self.max_queue_per_tenant
                and len(t.queue) >= self.max_queue_per_tenant):
            return False
        t.queue.append(item)
        return True

    def remove(self, tenant: str, item) -> bool:
        """Withdraw a queued item (cancellation before admission)."""
        t = self._tenants.get(tenant)
        if t is None or item not in t.queue:
            return False
        t.queue.remove(item)
        self._settle(t)
        return True

    # ---- introspection -----------------------------------------------------

    def __len__(self) -> int:
        return sum(len(t.queue) for t in self._tenants.values())

    def backlog(self, tenant: str) -> int:
        t = self._tenants.get(tenant)
        return 0 if t is None else len(t.queue)

    def backlogged(self) -> List[str]:
        return [n for n in self._order if self._tenants[n].queue]

    def deficit(self, tenant: str) -> float:
        t = self._tenants.get(tenant)
        return 0.0 if t is None else t.deficit

    def counters(self, tenant: str) -> Tuple[float, float, float]:
        """(refilled, charged, forfeited) — the conservation observables."""
        t = self._tenants.get(tenant)
        return ((0.0, 0.0, 0.0) if t is None
                else (t.refilled, t.charged, t.forfeited))

    def items(self, tenant: str) -> List:
        t = self._tenants.get(tenant)
        return [] if t is None else list(t.queue)

    # ---- the DRR tick ------------------------------------------------------

    def _settle(self, t: _Tenant) -> None:
        """Queue drained: forfeit banked deficit (classic DRR)."""
        if not t.queue and t.deficit:
            t.forfeited += t.deficit
            t.deficit = 0.0

    def tick(self, cost: Callable[[object], float],
             offer: Callable[[str, object], str],
             refill: bool = True) -> List[Tuple[str, object]]:
        """One DRR round.  ``cost(item)`` prices an item in tokens;
        ``offer(tenant, item)`` decides its fate (ADMITTED / REJECTED /
        BLOCKED / STALL).  Returns the ``(tenant, item)`` pairs admitted
        this round, in admission order."""
        admitted: List[Tuple[str, object]] = []
        names = self.backlogged()
        if not names:
            return admitted
        if refill:
            for name in names:
                t = self._tenants[name]
                add = min(self.quantum, self.cap - t.deficit)
                t.deficit += add
                t.refilled += add
        stalled = False
        for name in names:
            t = self._tenants[name]
            while t.queue:
                item = t.queue[0]
                c = float(cost(item))
                # an oversized item (c > cap) can never be covered by
                # banked deficit — gate it on quota *saturation* instead,
                # so it still reaches the controller (admit/reject there)
                # rather than head-of-line blocking its tenant forever
                if min(c, self.cap) > t.deficit:
                    break  # quota exhausted: bank and wait for refills
                verdict = offer(name, item)
                if verdict == ADMITTED:
                    t.queue.popleft()
                    charge = min(c, t.deficit)  # oversized: drain the bank
                    t.deficit -= charge
                    t.charged += charge
                    admitted.append((name, item))
                elif verdict == REJECTED:
                    t.queue.popleft()
                elif verdict == BLOCKED:
                    break
                elif verdict == STALL:
                    stalled = True
                    break
                else:
                    raise ValueError(f"unknown offer verdict {verdict!r}")
            self._settle(t)
            if stalled:
                break
        # rotate: next tick starts the visit after this tick's first tenant
        if names:
            first = names[0]
            idx = self._order.index(first)
            self._order = self._order[idx + 1:] + self._order[:idx + 1]
        return admitted


class SingleQueue:
    """Degenerate fair queue for ``admission="fcfs"``: one global FIFO,
    tenant-blind, quota-free — the baseline the goodput bench compares
    DRR+SLO admission against.  Implements the `DeficitRoundRobin` surface
    the frontend core uses."""

    def __init__(self, max_queue: int = 0):
        self.max_queue = int(max_queue)
        self._queue: deque = deque()

    def push(self, tenant: str, item) -> bool:
        if self.max_queue and len(self._queue) >= self.max_queue:
            return False
        self._queue.append((tenant, item))
        return True

    def remove(self, tenant: str, item) -> bool:
        if (tenant, item) in self._queue:
            self._queue.remove((tenant, item))
            return True
        return False

    def __len__(self) -> int:
        return len(self._queue)

    def backlog(self, tenant: str) -> int:
        return sum(1 for t, _ in self._queue if t == tenant)

    def backlogged(self) -> List[str]:
        seen, out = set(), []
        for t, _ in self._queue:
            if t not in seen:
                seen.add(t)
                out.append(t)
        return out

    def deficit(self, tenant: str) -> float:
        return 0.0

    def counters(self, tenant: str) -> Tuple[float, float, float]:
        return (0.0, 0.0, 0.0)

    def items(self, tenant: Optional[str] = None) -> List:
        return [i for t, i in self._queue if tenant is None or t == tenant]

    def tick(self, cost, offer, refill: bool = True):
        admitted = []
        while self._queue:
            tenant, item = self._queue[0]
            verdict = offer(tenant, item)
            if verdict == ADMITTED:
                self._queue.popleft()
                admitted.append((tenant, item))
            elif verdict == REJECTED:
                self._queue.popleft()
            else:  # BLOCKED / STALL: strict FCFS head-of-line blocks
                break
        return admitted
