"""`FrontendConfig`: the multi-tenant serving front end's knobs
(DESIGN.md §13).

Deliberately dependency-free (dataclasses only): `EngineConfig` composes a
`FrontendConfig`, so this module must be importable without dragging the
asyncio/HTTP machinery — or anything above ``repro.serving`` — into config
validation.

Two layered policies are configured here:

- **fairness** between tenants: deficit-round-robin over per-tenant FIFO
  queues with a token-budget quota (``quantum_tokens`` refilled per pump
  tick, banked deficit capped at ``quota_cap_tokens``).  Costs are the
  backend's *projected* request tokens/blocks, so fairness is cost-aware —
  a tenant sending long imbalanced-budget prompts drains its quota faster
  than one sending short ones, exactly the FairKV premise that per-request
  cost is heterogeneous.
- **admission** within the engine: each request belongs to a
  `PriorityClass` carrying a TTFT SLO; the controller decides
  admit / queue / degrade / reject per pump tick (the decision table lives
  in `repro.frontend.admission` and DESIGN.md §13).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class PriorityClass:
    """One latency class: SLO targets + the admission levers it may use.

    ``priority`` is the class index carried on `Request.priority` — lower
    is more urgent.  ``ttft_slo_steps`` is the time-to-first-token target
    in *scheduler steps* (deterministic across hardware; wall-clock SLOs
    are optional refinements used for attainment accounting only).

    Levers:
    - ``shed_after_steps``: REJECT a request still queued after this many
      steps (0 disables) — serving a request whose SLO is already blown
      wastes tokens that could be goodput for still-viable ones.
    - ``degrade_floor``: admission may shrink ``max_new_tokens`` down to
      this floor to fit the free budget (0 disables degradation).
    - ``preempt_below``: under pressure, queued requests of this class may
      evict an active lower-priority row via the scheduler's preemption
      path (the §13 enforcement lever).
    """

    name: str
    priority: int
    ttft_slo_steps: int = 32
    ttft_slo_s: Optional[float] = None  # optional wall-clock attainment SLO
    itl_slo_s: Optional[float] = None  # optional per-token cadence SLO
    shed_after_steps: int = 0
    degrade_floor: int = 0
    preempt_below: bool = False

    def __post_init__(self):
        if not self.name:
            raise ValueError("PriorityClass.name must be non-empty")
        if self.priority < 0:
            raise ValueError(
                f"priority must be >= 0, got {self.priority}")
        if self.ttft_slo_steps < 1:
            raise ValueError(
                f"ttft_slo_steps must be >= 1, got {self.ttft_slo_steps}")
        if self.shed_after_steps < 0:
            raise ValueError(
                f"shed_after_steps must be >= 0, got "
                f"{self.shed_after_steps}")
        if self.degrade_floor < 0:
            raise ValueError(
                f"degrade_floor must be >= 0, got {self.degrade_floor}")


# the default three-class ladder: interactive chat (tight TTFT, may preempt
# and shed), standard API traffic, and best-effort batch (degradable, never
# sheds — it would rather wait than waste its tokens)
DEFAULT_CLASSES: Tuple[PriorityClass, ...] = (
    PriorityClass(name="interactive", priority=0, ttft_slo_steps=8,
                  shed_after_steps=16, preempt_below=True),
    PriorityClass(name="standard", priority=1, ttft_slo_steps=24,
                  shed_after_steps=64),
    PriorityClass(name="batch", priority=2, ttft_slo_steps=200,
                  degrade_floor=4),
)


@dataclass(frozen=True)
class FrontendConfig:
    """Everything the serving front end needs, validated at construction.

    ``admission`` selects the controller: ``"slo"`` (the §13 decision
    table) or ``"fcfs"`` (admit-when-possible, never reject/degrade — the
    baseline the fig10 goodput bench compares against; it also bypasses
    tenant fairness, modelling a single global queue).
    """

    host: str = "127.0.0.1"
    port: int = 8000
    admission: str = "slo"  # "slo" | "fcfs"
    classes: Tuple[PriorityClass, ...] = DEFAULT_CLASSES
    # --- tenant fairness (deficit round robin) ------------------------------
    quantum_tokens: int = 512  # per-tenant token refill per pump tick
    quota_cap_tokens: int = 8192  # banked-deficit cap (>= largest request)
    max_queue_per_tenant: int = 256  # hard backlog bound -> reject
    # --- accounting ---------------------------------------------------------
    latency_window: int = 256  # rolling per-tenant percentile window
    # --- serving loop -------------------------------------------------------
    idle_sleep_s: float = 0.002  # engine-loop sleep when no work is live
    drain_timeout_s: float = 30.0  # graceful-shutdown decode budget
    max_prompt_tokens: int = 0  # per-request prompt bound (0 = engine's)
    max_new_tokens_cap: int = 0  # per-request generation bound (0 = none)

    def __post_init__(self):
        if self.admission not in ("slo", "fcfs"):
            raise ValueError(
                f"unknown admission mode {self.admission!r}; "
                f"known: ['slo', 'fcfs']")
        if not self.classes:
            raise ValueError("classes must be non-empty")
        prios = [c.priority for c in self.classes]
        if len(set(prios)) != len(prios):
            raise ValueError(
                f"duplicate PriorityClass.priority values: {sorted(prios)}")
        if self.quantum_tokens < 1:
            raise ValueError(
                f"quantum_tokens must be >= 1, got {self.quantum_tokens}")
        if self.quota_cap_tokens < self.quantum_tokens:
            raise ValueError(
                f"quota_cap_tokens ({self.quota_cap_tokens}) must be >= "
                f"quantum_tokens ({self.quantum_tokens}) or the deficit "
                f"can never bank a full refill")
        if self.max_queue_per_tenant < 1:
            raise ValueError(
                f"max_queue_per_tenant must be >= 1, got "
                f"{self.max_queue_per_tenant}")
        if self.latency_window < 2:
            raise ValueError(
                f"latency_window must be >= 2, got {self.latency_window}")
        if self.idle_sleep_s < 0 or self.drain_timeout_s < 0:
            raise ValueError("idle_sleep_s / drain_timeout_s must be >= 0")
        if not 0 <= self.port <= 65535:
            raise ValueError(f"port must be in [0, 65535], got {self.port}")

    def class_for(self, priority: int) -> PriorityClass:
        """The class whose index matches, else the *least* urgent class at
        or above the requested index (unknown priorities degrade to the
        closest configured class instead of crashing the ingress)."""
        best = None
        for c in sorted(self.classes, key=lambda c: c.priority):
            if c.priority == priority:
                return c
            if c.priority < priority:
                best = c
        return best if best is not None else min(
            self.classes, key=lambda c: c.priority)
