"""Engine-loop thread ↔ asyncio bridge (DESIGN.md §13).

JAX decode steps are blocking compiled calls — they cannot yield to an
event loop.  So the engine runs in ONE dedicated background thread (the
only thread that ever touches the scheduler, the cache state, or the
metrics registry), and the asyncio HTTP layer talks to it through queues:

- **ingress**: handlers enqueue thread-safe commands (submit / cancel /
  drain / metrics) on the loop's inbox; the engine thread absorbs the
  inbox between pump ticks;
- **egress**: each submission carries a ``deliver`` callable; the engine
  thread invokes it with per-token event dicts and a terminal ``end``
  event.  An asyncio handler passes
  ``lambda ev: loop.call_soon_threadsafe(aq.put_nowait, ev)`` to land the
  events on its own `asyncio.Queue`; synchronous callers (tests) pass
  ``queue.SimpleQueue().put``.

Event shapes (plain dicts, JSON-ready):

- ``{"type": "token", "req_id", "token", "index", "step", "finished"}`` —
  mirrors `repro.api.engine.StreamEvent` field-for-field;
- ``{"type": "end", "req_id", "state", "reason", "tokens",
  "n_generated", "degraded_from"}`` — exactly once per request, after its
  last token event (or immediately, for rejected requests).
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.frontend.config import FrontendConfig
from repro.frontend.core import FrontendScheduler
from repro.serving.request import Request

Deliver = Callable[[dict], None]


@dataclass
class _Watch:
    request: Request
    deliver: Deliver
    emitted: int = 0


@dataclass
class _Submit:
    request: Request
    deliver: Deliver


@dataclass
class _Reply:
    """A synchronous ask serviced by the engine thread between ticks."""

    kind: str  # "metrics" | "summary" | "trace"
    out: "queue.Queue" = field(default_factory=lambda: queue.Queue(1))


class EngineLoop:
    """Background pump thread around one `FrontendScheduler`."""

    def __init__(self, engine, cfg: Optional[FrontendConfig] = None):
        self.engine = engine
        self.cfg = cfg if cfg is not None else getattr(
            engine.cfg, "frontend", None) or FrontendConfig()
        # the scheduler must exist before the thread owns it exclusively
        self.fe = FrontendScheduler(engine._ensure_scheduler(), self.cfg)
        self._inbox: "queue.SimpleQueue" = queue.SimpleQueue()
        self._watch: Dict[int, _Watch] = {}
        self._ids = iter(range(engine._next_req_id, 2 ** 62))
        self._ids_lock = threading.Lock()
        self._stop = threading.Event()
        self._drained = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.error: Optional[BaseException] = None

    # ---- lifecycle ---------------------------------------------------------

    def start(self) -> "EngineLoop":
        if self._thread is not None:
            raise RuntimeError("EngineLoop already started")
        self._thread = threading.Thread(target=self._run,
                                        name="repro-engine-loop", daemon=True)
        self._thread.start()
        return self

    def drain(self, timeout: Optional[float] = None) -> bool:
        """Graceful shutdown: stop admitting, decode live rows out.
        Blocks until the frontend is idle (or ``timeout``); the loop thread
        keeps serving metrics asks afterwards until `stop`."""
        self._inbox.put("drain")
        return self._drained.wait(
            timeout if timeout is not None else self.cfg.drain_timeout_s)

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=10.0)

    @property
    def draining(self) -> bool:
        return self.fe.draining

    # ---- thread-safe command surface (callable from any thread) ------------

    def submit(self, prompt, *, max_new_tokens: int = 16,
               eos_id: Optional[int] = None, tenant: str = "default",
               priority: int = 1, deadline_s: Optional[float] = None,
               deliver: Deliver) -> Request:
        """Build + enqueue a request; returns it immediately (its req_id is
        final).  All progress arrives through ``deliver``."""
        with self._ids_lock:
            rid = next(self._ids)
        req = Request(req_id=rid, prompt=np.asarray(prompt, np.int32),
                      max_new_tokens=max_new_tokens, eos_id=eos_id,
                      tenant=tenant, priority=priority, deadline_s=deadline_s,
                      arrival_time=time.time())
        self._inbox.put(_Submit(req, deliver))
        return req

    def cancel(self, req_id: int) -> None:
        self._inbox.put(("cancel", req_id))

    def _ask(self, kind: str, timeout: float = 5.0):
        ask = _Reply(kind)
        self._inbox.put(ask)
        try:
            return ask.out.get(timeout=timeout)
        except queue.Empty:
            return None

    def prometheus(self) -> str:
        """Prometheus text, rendered BY the engine thread between ticks (the
        registry is single-writer; rendering off-thread could iterate a
        mutating dict).  Falls back to a direct read once the loop exited."""
        out = self._ask("metrics")
        if out is None:
            out = self.engine.obs.metrics.to_prometheus()
        return out

    def summary(self) -> dict:
        out = self._ask("summary")
        if out is None:
            out = self.fe.summary()
        return out

    # ---- engine thread -----------------------------------------------------

    def _absorb_inbox(self) -> None:
        while True:
            try:
                cmd = self._inbox.get_nowait()
            except queue.Empty:
                return
            if isinstance(cmd, _Submit):
                # watch BEFORE submit: a synchronous rejection (draining /
                # backlog bound) is already terminal and the emission sweep
                # delivers its end event
                self._watch[cmd.request.req_id] = _Watch(cmd.request,
                                                         cmd.deliver)
                self.fe.submit(cmd.request)
            elif isinstance(cmd, _Reply):
                if cmd.kind == "metrics":
                    cmd.out.put(self.engine.obs.metrics.to_prometheus())
                elif cmd.kind == "summary":
                    cmd.out.put(self.fe.summary())
                else:
                    cmd.out.put(None)
            elif cmd == "drain":
                self.fe.drain()
            elif isinstance(cmd, tuple) and cmd[0] == "cancel":
                self.fe.cancel(cmd[1])

    def _emit(self) -> None:
        for rid in list(self._watch):
            w = self._watch[rid]
            req = w.request
            n = req.n_generated
            while w.emitted < n:
                k = w.emitted
                w.emitted = k + 1
                w.deliver({
                    "type": "token", "req_id": rid,
                    "token": int(req.generated[k]), "index": k,
                    "step": self.fe.sched.step_idx,
                    "finished": bool(req.is_finished and k == n - 1)})
            if req.is_finished:
                w.deliver({
                    "type": "end", "req_id": rid, "state": req.state.value,
                    "reason": self.fe.reject_reasons.get(rid, ""),
                    "tokens": [int(t) for t in req.generated],
                    "n_generated": n,
                    "degraded_from": req.degraded_from})
                del self._watch[rid]

    def _run(self) -> None:
        try:
            while not self._stop.is_set():
                self._absorb_inbox()
                # emit BEFORE the idle gate: a request made terminal during
                # absorption itself (synchronous rejection while draining /
                # over backlog, cancel of a still-queued request) must
                # deliver its end event even when no pump tick follows —
                # otherwise the awaiting handler hangs forever
                self._emit()
                if self.fe.idle:
                    if self.fe.draining:
                        self._drained.set()
                    time.sleep(self.cfg.idle_sleep_s)
                    continue
                self.fe.pump()
                self._emit()
        except BaseException as e:  # deliver the failure, don't hang clients
            self.error = e
            for rid, w in list(self._watch.items()):
                w.deliver({"type": "end", "req_id": rid, "state": "error",
                           "reason": f"{type(e).__name__}: {e}",
                           "tokens": [], "n_generated": 0,
                           "degraded_from": None})
            self._watch.clear()
            self._drained.set()
            raise
