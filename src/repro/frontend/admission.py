"""SLO-aware admission control (DESIGN.md §13).

The controller prices a queued request with the cache backend's *projected*
cost machinery (`CacheBackend.request_cost` / `admissible` /
`never_fits` — the same §7/§9 projections scheduler admission enforces) and
decides one of four actions per pump tick:

====================  =====================================================
action                when
====================  =====================================================
``admit``             a free row exists and the backend's projected-cost
                      check passes at the full ask
``degrade``           the full ask does not fit but a shrunken
                      ``max_new_tokens`` (>= the class's ``degrade_floor``)
                      does — trade generation length for latency
``queue``             no capacity now, but the request's TTFT SLO is still
                      attainable; optionally evict a lower-priority active
                      row (``preempt_below``) to make room next tick
``reject``            the request can never fit (`never_fits`), its
                      deadline elapsed, or it queued past the class's
                      ``shed_after_steps`` — its SLO is already blown, so
                      decoding it would burn tokens that can still be
                      goodput for viable requests
====================  =====================================================

The ``"fcfs"`` controller is the deliberately naive baseline: admit when
possible, otherwise wait — no shedding, no degradation, no priorities.
The fig10 goodput bench measures exactly the gap between the two.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Sequence

from repro.frontend.config import FrontendConfig, PriorityClass
from repro.serving.request import Request

ADMIT = "admit"
QUEUE = "queue"
DEGRADE = "degrade"
REJECT = "reject"


@dataclass(frozen=True)
class Decision:
    """One admission verdict.

    ``degrade_to`` is the shrunken ``max_new_tokens`` when action is
    ``degrade``; ``preempt`` asks the pump to evict one lower-priority
    active row via `Scheduler.preempt_lower_priority`; ``global_block``
    marks a queue verdict whose cause (no free batch row) blocks every
    tenant equally — the DRR tick stalls instead of probing other tenants.
    """

    action: str
    reason: str = ""
    degrade_to: Optional[int] = None
    preempt: bool = False
    global_block: bool = False


class AdmissionController:
    """The ``"slo"`` decision table above, stateless per decision."""

    name = "slo"

    def __init__(self, cfg: FrontendConfig):
        self.cfg = cfg

    # ---- helpers -----------------------------------------------------------

    def _fits_now(self, sched, req: Request,
                  pending: Sequence[Request]) -> bool:
        """Free row + backend projected-cost admission at the current ask,
        charged jointly with the ``pending`` requests already admitted this
        tick (submitted but not yet spliced, so invisible to ``state``)."""
        return (len(sched.freelist) > 0
                and sched.backend.admissible(sched.state, req,
                                             pending=pending))

    def _degrade_ask(self, sched, req: Request, cls: PriorityClass,
                     pending: Sequence[Request]) -> Optional[int]:
        """Largest ``max_new_tokens`` in [floor, current) whose projected
        cost fits right now (admissibility is monotone in the ask, so
        binary search); None when even the floor does not fit."""
        if not cls.degrade_floor or req.max_new_tokens <= cls.degrade_floor:
            return None
        if len(sched.freelist) == 0:
            return None

        def fits(m: int) -> bool:
            probe = dataclasses.replace(req, max_new_tokens=m)
            return sched.backend.admissible(sched.state, probe,
                                            pending=pending)

        lo, hi = cls.degrade_floor, req.max_new_tokens - 1
        if not fits(lo):
            return None
        while lo < hi:
            mid = (lo + hi + 1) // 2
            if fits(mid):
                lo = mid
            else:
                hi = mid - 1
        return lo

    # ---- the decision table ------------------------------------------------

    def decide(self, sched, req: Request,
               pending: Sequence[Request] = ()) -> Decision:
        """One verdict for ``req``.  ``pending`` are requests already
        admitted this pump tick (in the engine's queue, not yet spliced):
        capacity checks charge them too, so a burst admitted in one tick
        cannot jointly overshoot the backend budget."""
        cls = self.cfg.class_for(req.priority)
        waited = sched.step_idx - req.arrival_step
        # 1. dead on arrival or already past its latency budget: shed
        if req.deadline_exceeded():
            return Decision(REJECT, reason="deadline_exceeded")
        if cls.shed_after_steps and waited > cls.shed_after_steps:
            return Decision(REJECT, reason="slo_blown")
        # 2. structurally impossible at the current ask
        never = sched.backend.never_fits(req)
        if never is not None:
            floor = cls.degrade_floor
            if floor and req.max_new_tokens > floor:
                probe = dataclasses.replace(req, max_new_tokens=floor)
                if sched.backend.never_fits(probe) is None:
                    return Decision(DEGRADE, reason="never_fits_full_ask",
                                    degrade_to=floor)
            return Decision(REJECT, reason=f"never_fits: {never}")
        # 3. capacity now?
        if self._fits_now(sched, req, pending):
            return Decision(ADMIT, reason="fits")
        degrade_to = self._degrade_ask(sched, req, cls, pending)
        if degrade_to is not None and waited >= cls.ttft_slo_steps // 2:
            # only trade length for latency once the SLO is actually at
            # risk — a young request would rather wait for the full ask
            return Decision(DEGRADE, reason="pressure", degrade_to=degrade_to)
        # 4. wait — with the preemption lever armed for urgent classes
        # whose SLO clock is running out (§13 enforcement path)
        preempt = (cls.preempt_below
                   and waited >= max(1, cls.ttft_slo_steps // 2))
        return Decision(QUEUE, reason="no_capacity", preempt=preempt,
                        global_block=len(sched.freelist) == 0)


class FCFSController:
    """Baseline: admit-when-possible, never shed/degrade/preempt.  Still
    rejects structural `never_fits` requests — the scheduler itself
    fail-fasts those at submit, so queueing them would just crash later."""

    name = "fcfs"

    def __init__(self, cfg: FrontendConfig):
        self.cfg = cfg

    def decide(self, sched, req: Request,
               pending: Sequence[Request] = ()) -> Decision:
        never = sched.backend.never_fits(req)
        if never is not None:
            return Decision(REJECT, reason=f"never_fits: {never}")
        if (len(sched.freelist) > 0
                and sched.backend.admissible(sched.state, req,
                                             pending=pending)):
            return Decision(ADMIT, reason="fits")
        return Decision(QUEUE, reason="no_capacity",
                        global_block=True)  # strict FCFS: head blocks all


def make_admission(cfg: FrontendConfig):
    return (AdmissionController(cfg) if cfg.admission == "slo"
            else FCFSController(cfg))
