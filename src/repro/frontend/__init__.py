"""`repro.frontend` — multi-tenant async serving front end (DESIGN.md §13).

Layers, bottom-up (each importable without the ones above it):

- `repro.frontend.config`     — `FrontendConfig` / `PriorityClass`
  (dependency-free; composed into `EngineConfig`);
- `repro.frontend.queues`     — deficit-round-robin tenant fair queuing
  over token-budget quotas;
- `repro.frontend.admission`  — the SLO-aware admit/queue/degrade/reject
  decision table (and the FCFS baseline);
- `repro.frontend.accounting` — per-tenant rolling TTFT/ITL percentiles,
  SLO attainment, goodput counters (through the §12 metrics registry);
- `repro.frontend.core`       — `FrontendScheduler`: the synchronous pump
  gluing the above around one engine `Scheduler`; `run_frontend_trace`
  drives synthetic traces (the fig10 goodput harness);
- `repro.frontend.bridge`     — `EngineLoop`: the single engine thread +
  thread-safe command/event queues;
- `repro.frontend.http`       — `FrontendServer` / `serve_http`: stdlib
  asyncio HTTP/1.1 + SSE ingress.
"""
from __future__ import annotations

from repro.frontend.accounting import TenantAccounting  # noqa: F401
from repro.frontend.admission import (  # noqa: F401
    AdmissionController,
    Decision,
    FCFSController,
    make_admission,
)
from repro.frontend.bridge import EngineLoop  # noqa: F401
from repro.frontend.config import (  # noqa: F401
    DEFAULT_CLASSES,
    FrontendConfig,
    PriorityClass,
)
from repro.frontend.core import (  # noqa: F401
    FrontendScheduler,
    run_frontend_trace,
)
from repro.frontend.http import FrontendServer, serve_http  # noqa: F401
from repro.frontend.queues import (  # noqa: F401
    DeficitRoundRobin,
    SingleQueue,
)

__all__ = [
    "AdmissionController", "DEFAULT_CLASSES", "Decision",
    "DeficitRoundRobin", "EngineLoop", "FCFSController", "FrontendConfig",
    "FrontendScheduler", "FrontendServer", "PriorityClass", "SingleQueue",
    "TenantAccounting", "make_admission", "run_frontend_trace",
    "serve_http",
]
