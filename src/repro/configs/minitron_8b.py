"""minitron-8b — dense, 32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000.

Pruned Nemotron.  [arXiv:2407.14679; hf]
"""
from repro.configs.base import FULL_ATTENTION_SKIP, ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="minitron-8b",
        family="dense",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=16384,
        vocab_size=256000,
        shape_skips={"long_500k": FULL_ATTENTION_SKIP},
        source="arXiv:2407.14679 (nvidia/Minitron-8B-Base)",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="minitron-8b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        shape_skips={"long_500k": FULL_ATTENTION_SKIP},
        source="reduced",
    )


register("minitron-8b", full, smoke)
