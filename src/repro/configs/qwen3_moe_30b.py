"""qwen3-moe-30b-a3b — MoE, 48L d_model=2048 32H (GQA kv=4) d_ff=768
vocab=151936, 128 experts top-8.  [hf:Qwen/Qwen3-30B-A3B; hf]
"""
from repro.configs.base import FULL_ATTENTION_SKIP, ModelConfig, MoEConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b",
        family="moe",
        n_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv_heads=4,
        head_dim=128,
        d_ff=768,
        vocab_size=151936,
        rope_theta=1_000_000.0,
        moe=MoEConfig(num_experts=128, top_k=8, d_expert=768, balance_experts=True),
        shape_skips={"long_500k": FULL_ATTENTION_SKIP},
        source="hf:Qwen/Qwen3-30B-A3B",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=32,
        vocab_size=256,
        moe=MoEConfig(num_experts=8, top_k=2, d_expert=32, balance_experts=True),
        shape_skips={"long_500k": FULL_ATTENTION_SKIP},
        source="reduced",
    )


register("qwen3-moe-30b-a3b", full, smoke)
